"""End-to-end training driver with fault tolerance.

    PYTHONPATH=src python -m repro.launch.train --arch qwen1.5-0.5b --smoke \
        --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1

Features: GSPMD sharding from the arch's rules, checkpoint/restart (resume
is automatic if the checkpoint dir has state), keep-k GC, elastic restore
(restarting on a different device count reshards), bounded-retry step
execution (straggler/fault mitigation at the driver level), optional int8
error-feedback gradient compression (--compress, pure-DP path).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpoint import CheckpointManager
from repro.data import synthetic
from repro.distrib import mesh_utils, sharding
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train.step import (init_ef_state, make_compressed_train_step,
                              make_train_step)


def build_mesh(n_devices: int | None = None):
    devs = jax.devices()
    n = n_devices or len(devs)
    # favor a (data, model) split when composite; 1-D data mesh otherwise
    model = 1
    for cand in (8, 4, 2):
        if n % cand == 0 and n >= cand * 2:
            model = cand
            break
    return mesh_utils.make_mesh((n // model, model), ("data", "model"),
                                devices=devs[:n])


def train(arch: str, steps: int, batch: int, seq: int, smoke: bool,
          ckpt_dir: str | None, compress: bool = False, lr: float = 3e-4,
          max_retries: int = 3, log_every: int = 10):
    cfg = configs.get_smoke(arch) if smoke else configs.get(arch)
    model = api.build(cfg)
    mesh = build_mesh()
    optimizer = opt_lib.get(cfg.optimizer)
    lr_fn = lambda c: opt_lib.cosine_lr(c, peak=lr, warmup=min(20, steps // 5),
                                        total=steps)

    p_shard = sharding.param_shardings(cfg, model.spec, mesh)
    o_spec = optimizer.init_spec(model.spec)
    o_shard = sharding.opt_shardings(cfg, o_spec, mesh)

    params = model.init(jax.random.PRNGKey(0))
    params = jax.tree.map(jax.device_put, params, p_shard)
    opt_state = jax.tree.map(jax.device_put, optimizer.init(params), o_shard)

    mgr = CheckpointManager(ckpt_dir) if ckpt_dir else None
    start_step = 0
    if mgr and mgr.latest_step() is not None:
        state = mgr.restore({"params": params, "opt": opt_state},
                            shardings={"params": p_shard, "opt": o_shard})
        params, opt_state = state["params"], state["opt"]
        start_step = mgr.latest_step()
        print(f"[train] resumed from step {start_step} "
              f"(elastic restore onto {len(jax.devices())} devices)")

    if compress:
        step_fn = make_compressed_train_step(model, optimizer, mesh, lr_fn)
        ef = init_ef_state(params)
    else:
        raw = make_train_step(model, optimizer, lr_fn)
        step_fn = jax.jit(raw, in_shardings=(p_shard, o_shard, None),
                          out_shardings=(p_shard, o_shard, None),
                          donate_argnums=(0, 1))

    data = synthetic.lm_batches(batch, seq, cfg.vocab_size, seed=1)
    t0 = time.time()
    for step in range(start_step, steps):
        raw_batch = next(data)
        batch_arrays = {k: jnp.asarray(v) for k, v in raw_batch.items()}
        if cfg.frontend == "embed":
            key = jax.random.PRNGKey(step)
            batch_arrays["embeds"] = jax.random.normal(
                key, (batch, seq, cfg.d_model), cfg.compute_dtype)
        for attempt in range(max_retries):
            try:
                if compress:
                    params, opt_state, ef, loss = step_fn(
                        params, opt_state, ef, batch_arrays)
                    metrics = {"loss": loss}
                else:
                    params, opt_state, metrics = step_fn(
                        params, opt_state, batch_arrays)
                break
            except Exception as e:  # bounded retry (transient-failure model)
                if attempt == max_retries - 1:
                    raise
                print(f"[train] step {step} attempt {attempt} failed: {e}; retrying")
        if (step + 1) % log_every == 0 or step == steps - 1:
            loss = float(metrics["loss"])
            dt = time.time() - t0
            print(f"[train] step {step + 1}/{steps} loss={loss:.4f} "
                  f"({dt / log_every:.2f}s/step)", flush=True)
            t0 = time.time()
            assert np.isfinite(loss), "loss diverged"
        if mgr and ((step + 1) % 50 == 0 or step == steps - 1):
            mgr.save(step + 1, {"params": params, "opt": opt_state})
    if mgr:
        mgr.wait()
    return params, opt_state, metrics


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True, choices=list(configs.ARCHS))
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--smoke", action="store_true",
                    help="use the reduced config (CPU-friendly)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--compress", action="store_true",
                    help="int8+EF gradient compression (pure-DP path)")
    ap.add_argument("--lr", type=float, default=3e-4)
    args = ap.parse_args(argv)
    train(args.arch, args.steps, args.batch, args.seq, args.smoke,
          args.ckpt_dir, args.compress, args.lr)


if __name__ == "__main__":
    main()
