"""Multi-pod dry-run: lower + compile every (architecture x shape x mesh)
cell with ShapeDtypeStruct stand-ins (no allocation), print memory/cost
analysis, and extract the roofline terms (EXPERIMENTS.md reads the JSON
this writes).

Run:  PYTHONPATH=src python -m repro.launch.dryrun --all --mesh single
      PYTHONPATH=src python -m repro.launch.dryrun --arch gemma3-1b --shape train_4k --mesh multi
Results accumulate in dryrun_results.json (resumable; --force to redo).
"""
# The 512 placeholder devices MUST be configured before jax initializes —
# these two lines precede every other import, including repro's.
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

import argparse
import json
import re
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro import configs
from repro.core import lanczos as lz
from repro.core import similarity as sim
from repro.distrib import act_sharding, hlo_analysis, mesh_utils, sharding
from repro.launch.mesh import make_production_mesh, make_spectral_mesh
from repro.models import api
from repro.models import params as pp
from repro.models.config import SHAPES_BY_NAME
from repro.train import optimizer as opt_lib
from repro.train.step import make_train_step

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3": 1, "f8e5m2": 1,
    "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2, "u16": 2,
    "s8": 1, "u8": 1, "pred": 1,
}

_ARRAY_RE = re.compile(r"(f64|f32|f16|bf16|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|pred)\[([0-9,]*)\]")
_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")


def _shape_bytes(segment: str) -> int:
    """Sum byte sizes of every array literal in an HLO type segment."""
    total = 0
    for dt, dims in _ARRAY_RE.findall(segment):
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def collective_bytes(hlo_text: str) -> dict:
    """Per-collective-type result bytes, parsed from compiled HLO."""
    out = {k: 0 for k in _COLL_OPS}
    counts = {k: 0 for k in _COLL_OPS}
    for line in hlo_text.splitlines():
        ls = line.strip()
        eq = ls.find("= ")
        if eq < 0:
            continue
        rhs = ls[eq + 2:]
        for op in _COLL_OPS:
            # match the op as the instruction (e.g. "bf16[...] all-gather(")
            m = re.search(rf"\)*\s({op}|{op}-start|{op}-done)\(", rhs)
            if m:
                seg = rhs[: m.start()]
                if m.group(1).endswith("-done"):
                    continue  # counted at -start
                out[op] += _shape_bytes(seg)
                counts[op] += 1
                break
    return {"bytes": out, "counts": counts,
            "total_bytes": int(sum(out.values()))}


def _memory_dict(compiled) -> dict:
    try:
        ma = compiled.memory_analysis()
    except Exception:
        return {}
    keys = ("argument_size_in_bytes", "output_size_in_bytes",
            "temp_size_in_bytes", "alias_size_in_bytes",
            "generated_code_size_in_bytes")
    d = {}
    for k in keys:
        v = getattr(ma, k, None)
        if v is not None:
            d[k] = int(v)
    if not d:
        d["repr"] = str(ma)
    return d


def _cost_dict(compiled) -> dict:
    try:
        ca = compiled.cost_analysis()
    except Exception:
        return {}
    if isinstance(ca, (list, tuple)):
        ca = ca[0]
    return {k: float(v) for k, v in ca.items()
            if isinstance(v, (int, float)) and (
                k in ("flops", "transcendentals") or k.startswith("bytes accessed"))}


def roofline_terms(hlo: dict) -> dict:
    """Three roofline terms in seconds, from the per-device (SPMD-
    partitioned) HLO costs with while-trip-count correction."""
    t_compute = hlo["flops"] / PEAK_FLOPS
    t_memory = hlo["bytes"] / HBM_BW
    t_collective = hlo["collective_total"] / ICI_BW
    dominant = max(
        (("compute", t_compute), ("memory", t_memory),
         ("collective", t_collective)), key=lambda kv: kv[1])[0]
    bound = max(t_compute, t_memory, t_collective)
    return {"t_compute_s": t_compute, "t_memory_s": t_memory,
            "t_collective_s": t_collective, "dominant": dominant,
            "roofline_fraction": t_compute / bound if bound > 0 else 0.0}


# ---------------------------------------------------------------------------
# cell lowering
# ---------------------------------------------------------------------------

def lower_lm_cell(arch: str, shape_name: str, multi_pod: bool,
                  cfg_override=None):
    cfg = cfg_override or configs.get(arch)
    cell = SHAPES_BY_NAME[shape_name]
    # SP pays off when compute is O(S) per step (prefill); decode streams
    # weights per token, so replicating them regresses — measured in
    # EXPERIMENTS.md §Perf (A4)
    if cell.kind == "prefill" and cfg.serve_sharding_preset \
            and not cfg.sharding_preset:
        cfg = cfg.with_(sharding_preset=cfg.serve_sharding_preset)
    model = api.build(cfg)
    mesh = make_production_mesh(multi_pod=multi_pod)
    p_shard = sharding.param_shardings(cfg, model.spec, mesh)
    abstract_p = model.abstract_params()
    batch = configs.input_specs(cfg, cell)
    b_shard = sharding.input_shardings(
        mesh, batch, seq_axis=sharding.seq_axis_for_inputs(cfg))

    if cell.kind == "train":
        optimizer = opt_lib.get(cfg.optimizer)
        o_spec = optimizer.init_spec(model.spec)
        o_shard = sharding.opt_shardings(cfg, o_spec, mesh)
        abstract_o = pp.abstract_params(o_spec)
        step = make_train_step(model, optimizer)
        jitted = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                         out_shardings=(p_shard, o_shard, None),
                         donate_argnums=(0, 1))
        with act_sharding.use_mesh(mesh):
            lowered = jitted.lower(abstract_p, abstract_o, batch)
    elif cell.kind == "prefill":
        c_spec = model.cache_specs(cell.global_batch, cell.seq_len)
        c_shard = sharding.cache_shardings(cfg, c_spec, mesh)

        def fn(p, b):
            return model.prefill(p, b, max_seq=cell.seq_len)

        jitted = jax.jit(fn, in_shardings=(p_shard, b_shard),
                         out_shardings=(None, c_shard))
        with act_sharding.use_mesh(mesh):
            lowered = jitted.lower(abstract_p, batch)
    elif cell.kind == "decode":
        c_spec = model.cache_specs(cell.global_batch, cell.seq_len)
        c_shard = sharding.cache_shardings(cfg, c_spec, mesh)
        abstract_c = pp.abstract_params(c_spec)
        jitted = jax.jit(model.decode_step,
                         in_shardings=(p_shard, c_shard, b_shard["token"]),
                         out_shardings=(None, c_shard),
                         donate_argnums=(1,))
        with act_sharding.use_mesh(mesh):
            lowered = jitted.lower(abstract_p, abstract_c, batch["token"])
    else:
        raise ValueError(cell.kind)
    return lowered, mesh, model


def lower_spectral_cell(phase: str, multi_pod: bool, n: int | None = None):
    """Dry-run the paper pipeline's three phases on the flat mesh."""
    from repro.configs import spectral_paper
    mesh = make_spectral_mesh(multi_pod=multi_pod)
    m = mesh_utils.mesh_size(mesh)
    n = n or spectral_paper.PRODUCTION_N
    sched = sim.make_schedule(n, m)
    n_pad = sched.n_pad
    d_feat, k = 64, spectral_paper.CONFIG.k
    x_abs = jax.ShapeDtypeStruct((n, d_feat), jnp.float32)

    if phase == "similarity":
        def fn(x):
            up = sim.similarity_upper_blocks(x, 1.0, mesh, schedule=sched)
            return up.U
        lowered = jax.jit(fn).lower(x_abs)
    elif phase == "similarity_full":
        # beyond-paper variant: every device computes its whole row block
        # (2x pair-FLOPs, no triangle bookkeeping / mirror communication)
        def fn(x):
            return sim.distributed_similarity_full(x, 1.0, mesh)
        lowered = jax.jit(fn).lower(x_abs)
    elif phase == "similarity_compact":
        # perf iteration S1: triangular schedule with compact tile storage
        def fn(x):
            return sim.similarity_upper_blocks_compact(x, 1.0, mesh,
                                                       schedule=sched).tiles
        lowered = jax.jit(fn).lower(x_abs)
    elif phase == "lanczos_compact":
        from jax.sharding import NamedSharding, PartitionSpec as P
        m_dev = mesh_utils.mesh_size(mesh)
        tiles_abs = jax.ShapeDtypeStruct(
            (m_dev * (2 * m_dev + 1), sched.b, sched.b), jnp.float32)
        diag_abs = jax.ShapeDtypeStruct((n_pad,), jnp.float32)
        st_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            lz.init_state(n_pad, 32, jax.random.PRNGKey(0)))
        t_shard = NamedSharding(mesh, P("rows", None, None))

        def fn(tiles, diag, state):
            up = sim.UpperSimCompact(tiles=tiles, diag=diag, schedule=sched,
                                     mesh=mesh, axis=("rows",))
            deg = sim.sym_matvec_compact(up, diag)
            inv = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)

            def mv(v):
                return diag * v + inv * sim.sym_matvec_compact(up, inv * v)

            return lz.run(mv, state, 1)

        lowered = jax.jit(fn, in_shardings=(t_shard, None, None),
                          donate_argnums=(2,)).lower(tiles_abs, diag_abs, st_abs)
    elif phase == "lanczos_materialized":
        # paper-faithful alternative: Lanczos against the fully materialized
        # mirrored S (the Hadoop way: both triangles stored in HBase);
        # compare against the sym_matvec path that never mirrors
        from jax.sharding import NamedSharding, PartitionSpec as P
        S_abs = jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32)
        st_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            lz.init_state(n_pad, 32, jax.random.PRNGKey(0)))
        s_shard = NamedSharding(mesh, P("rows", None))

        def fn(S, state):
            valid = (jnp.arange(n_pad) < n).astype(jnp.float32)
            deg = S @ valid
            inv = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)

            def mv(v):
                return valid * v + inv * (S @ (inv * v))

            return lz.run(mv, state, 1)

        lowered = jax.jit(fn, in_shardings=(s_shard, None),
                          donate_argnums=(1,)).lower(S_abs, st_abs)
    elif phase == "lanczos":
        # one Lanczos iteration against row-sharded upper blocks
        from jax.sharding import NamedSharding, PartitionSpec as P
        U_abs = jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32)
        diag_abs = jax.ShapeDtypeStruct((n_pad,), jnp.float32)
        st_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            lz.init_state(n_pad, 32, jax.random.PRNGKey(0)))
        u_shard = NamedSharding(mesh, P("rows", None))

        def fn(U, diag, state):
            up = sim.UpperSim(U=U, diag=diag, schedule=sched, mesh=mesh,
                              axis=("rows",))
            from repro.core import laplacian as lp
            deg = lp.degrees(up)
            mv = lp.make_shifted_operator(up, deg)
            return lz.run(mv, state, 1)

        lowered = jax.jit(fn, in_shardings=(u_shard, None, None),
                          donate_argnums=(2,)).lower(U_abs, diag_abs, st_abs)
    elif phase == "block_lanczos":
        # one BLOCK Lanczos step against row-sharded upper blocks: the
        # (n_pad, b) block stays replicated, each device streams its row
        # block of U once per step — b vectors advanced per matrix pass
        from jax.sharding import NamedSharding, PartitionSpec as P
        blk = 8
        U_abs = jax.ShapeDtypeStruct((n_pad, n_pad), jnp.float32)
        diag_abs = jax.ShapeDtypeStruct((n_pad,), jnp.float32)
        st_abs = jax.tree.map(
            lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
            lz.init_block_state(n_pad, 8, jax.random.PRNGKey(0), blk))
        u_shard = NamedSharding(mesh, P("rows", None))

        def fn(U, diag, state):
            up = sim.UpperSim(U=U, diag=diag, schedule=sched, mesh=mesh,
                              axis=("rows",))
            from repro.core import laplacian as lp
            deg = lp.degrees(up)
            mm = lp.make_shifted_matmat(up, deg)
            return lz.block_run(mm, state, 1)

        lowered = jax.jit(fn, in_shardings=(u_shard, None, None),
                          donate_argnums=(2,)).lower(U_abs, diag_abs, st_abs)
    elif phase == "kmeans":
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.core import kmeans as km
        y_abs = jax.ShapeDtypeStruct((n_pad, k), jnp.float32)
        v_abs = jax.ShapeDtypeStruct((n_pad,), jnp.float32)
        st = km.KMeansState(it=jnp.zeros((), jnp.int32),
                            centers=jnp.zeros((k, k)), shift=jnp.zeros(()))
        st_abs = jax.tree.map(lambda a: jax.ShapeDtypeStruct(a.shape, a.dtype), st)

        def fn(y, valid, state):
            return km.distributed_lloyd_step(y, valid, state, mesh)

        lowered = jax.jit(
            fn, in_shardings=(NamedSharding(mesh, P("rows", None)), None, None)
        ).lower(y_abs, v_abs, st_abs)
    else:
        raise ValueError(phase)
    return lowered, mesh, None


def _parse_overrides(pairs: list[str]):
    """--override key=value: ints, floats, bools, and bare strings."""
    out = {}
    for p in pairs or []:
        k, v = p.split("=", 1)
        if v in ("True", "true"):
            out[k] = True
        elif v in ("False", "false"):
            out[k] = False
        else:
            try:
                out[k] = int(v)
            except ValueError:
                try:
                    out[k] = float(v)
                except ValueError:
                    out[k] = v
    return out


def run_cell(arch: str, shape_name: str, multi_pod: bool, verbose: bool = True,
             overrides: dict | None = None, tag: str = ""):
    t0 = time.perf_counter()
    if arch == "spectral":
        lowered, mesh, model = lower_spectral_cell(shape_name, multi_pod)
    else:
        cfg = configs.get(arch)
        if overrides:
            cfg = cfg.with_(**overrides)
        lowered, mesh, model = lower_lm_cell(arch, shape_name, multi_pod,
                                             cfg_override=cfg)
    t_lower = time.perf_counter() - t0
    t0 = time.perf_counter()
    compiled = lowered.compile()
    t_compile = time.perf_counter() - t0

    n_chips = mesh_utils.mesh_size(mesh)
    mem = _memory_dict(compiled)
    cost = _cost_dict(compiled)        # raw XLA numbers (loop bodies once)
    t0 = time.perf_counter()
    hlo = hlo_analysis.analyze(compiled.as_text())
    t_analyze = time.perf_counter() - t0
    roof = roofline_terms(hlo)
    rec = {
        "arch": arch, "shape": shape_name,
        "mesh": "multi" if multi_pod else "single",
        "tag": tag, "overrides": overrides or {},
        "n_chips": n_chips,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        "analyze_s": round(t_analyze, 1),
        "memory": mem, "cost_analysis_raw": cost, "hlo": hlo,
        "roofline": roof,
    }
    if model is not None:
        rec["num_params"] = model.num_params()
        rec["num_active_params"] = model.num_active_params()
    if verbose:
        print(f"[dryrun] {arch} x {shape_name} x {rec['mesh']}  "
              f"compile={t_compile:.0f}s", flush=True)
        print(f"  memory_analysis: {mem}", flush=True)
        print(f"  hlo(per-dev): flops={hlo['flops']:.3e} bytes={hlo['bytes']:.3e} "
              f"coll={hlo['collective_bytes']}", flush=True)
        print(f"  roofline: {roof}", flush=True)
    return rec


# ---------------------------------------------------------------------------
# CLI with resumable JSON accumulation
# ---------------------------------------------------------------------------

def all_cells():
    for arch in configs.ARCHS:
        for shape in SHAPES_BY_NAME:
            yield arch, shape
    for phase in ("similarity", "lanczos", "block_lanczos", "kmeans"):
        yield "spectral", phase


def cell_key(arch, shape, mesh_name):
    return f"{arch}|{shape}|{mesh_name}"


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", default="single", choices=["single", "multi", "both"])
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--force", action="store_true")
    ap.add_argument("--out", default="dryrun_results.json")
    ap.add_argument("--override", action="append", default=[],
                    help="cfg field override key=value (perf variants)")
    ap.add_argument("--tag", default="",
                    help="variant tag appended to the result key")
    args = ap.parse_args(argv)
    overrides = _parse_overrides(args.override)

    results = {}
    if os.path.exists(args.out):
        with open(args.out) as f:
            results = json.load(f)

    if args.all:
        cells = list(all_cells())
    else:
        assert args.arch and args.shape, "--arch/--shape or --all"
        cells = [(args.arch, args.shape)]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    failures = []
    for arch, shape in cells:
        if arch != "spectral" and not configs.cell_supported(arch, shape):
            for mp in meshes:
                key = cell_key(arch, shape, "multi" if mp else "single")
                results[key] = {"arch": arch, "shape": shape,
                                "mesh": "multi" if mp else "single",
                                "skipped": "unsupported (see DESIGN.md §5)"}
            continue
        for mp in meshes:
            key = cell_key(arch, shape, "multi" if mp else "single")
            if args.tag:
                key += f"|{args.tag}"
            if key in results and not args.force and "error" not in results[key]:
                continue
            try:
                results[key] = run_cell(arch, shape, mp, overrides=overrides,
                                        tag=args.tag)
            except Exception as e:
                traceback.print_exc()
                results[key] = {"arch": arch, "shape": shape,
                                "mesh": "multi" if mp else "single",
                                "error": f"{type(e).__name__}: {e}"}
                failures.append(key)
            with open(args.out, "w") as f:
                json.dump(results, f, indent=1)
    done = sum(1 for r in results.values() if "error" not in r and "skipped" not in r)
    skip = sum(1 for r in results.values() if "skipped" in r)
    print(f"[dryrun] complete: {done} ok, {skip} skipped, {len(failures)} failed")
    if failures:
        print("failed:", failures)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
