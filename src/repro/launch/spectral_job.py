"""Driver for the paper's pipeline: cluster points or a topology graph
file on all local devices, with phase checkpointing.

    PYTHONPATH=src python -m repro.launch.spectral_job --blobs 600 --k 3
    PYTHONPATH=src python -m repro.launch.spectral_job --graph topo.txt --k 8
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.checkpoint import CheckpointManager
from repro.core import spectral
from repro.data import graph_file, synthetic
from repro.distrib import mesh_utils


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--blobs", type=int, default=0, help="n points in k blobs")
    ap.add_argument("--rings", type=int, default=0, help="n points in k rings")
    ap.add_argument("--graph", default=None, help="paper §5.1 topology file")
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--mode", default="triangular", choices=["triangular", "full"])
    ap.add_argument("--lanczos-steps", type=int, default=48)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args(argv)

    mesh = mesh_utils.local_mesh("rows")
    cfg = spectral.SpectralConfig(k=args.k, mode=args.mode,
                                  lanczos_steps=args.lanczos_steps)
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None

    t0 = time.time()
    if args.graph:
        n, edges = graph_file.parse_topology(args.graph)
        S = graph_file.adjacency_dense(n, edges)
        res = spectral.fit_from_similarity(jnp.asarray(S), cfg, mesh)
        truth = None
    else:
        if args.rings:
            pts, truth = synthetic.rings(args.rings, args.k)
        else:
            n = args.blobs or 600
            pts, truth = synthetic.blobs(n, args.k)
        res = spectral.fit(jnp.asarray(pts), cfg, mesh, checkpointer=mgr)
    dt = time.time() - t0

    labels = np.asarray(res.labels)
    sizes = np.bincount(labels, minlength=args.k)
    print(f"[spectral] n={len(labels)} k={args.k} mode={cfg.mode} "
          f"devices={mesh_utils.mesh_size(mesh)} time={dt:.2f}s")
    print(f"[spectral] eigenvalues: {np.asarray(res.eigenvalues)}")
    print(f"[spectral] cluster sizes: {sizes}")
    if truth is not None:
        from itertools import permutations
        k = args.k
        if k <= 6:
            acc = max(np.mean(np.array([p[t] for t in truth]) == labels)
                      for p in permutations(range(k)))
            print(f"[spectral] accuracy vs planted labels: {acc:.3f}")
    return res


if __name__ == "__main__":
    main()
