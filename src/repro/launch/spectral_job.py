"""Driver for the paper's pipeline: cluster points or a topology graph
file on all local devices, with phase checkpointing.

Each pipeline phase is a registry-selected backend of
:class:`repro.cluster.SpectralClustering`:

    PYTHONPATH=src python -m repro.launch.spectral_job --blobs 600 --k 3
    PYTHONPATH=src python -m repro.launch.spectral_job --rings 512 --k 2 \\
        --affinity compact --eigensolver lanczos --assigner minibatch
    PYTHONPATH=src python -m repro.launch.spectral_job --graph topo.txt --k 8
    PYTHONPATH=src python -m repro.launch.spectral_job --blobs 4096 --k 3 \\
        --engine mapreduce --chunk-size 512 --memory-budget 1048576
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.checkpoint import CheckpointManager
from repro.cluster import AFFINITIES, ASSIGNERS, EIGENSOLVERS, SpectralClustering
from repro.data import graph_file, synthetic
from repro.distrib import mesh_utils


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--blobs", type=int, default=0, help="n points in k blobs")
    ap.add_argument("--rings", type=int, default=0, help="n points in k rings")
    ap.add_argument("--graph", default=None, help="paper §5.1 topology file")
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--affinity", default="triangular",
                    choices=AFFINITIES.names(),
                    help="phase-1 backend (forced to 'precomputed' by --graph)")
    ap.add_argument("--eigensolver", default="lanczos",
                    choices=EIGENSOLVERS.names(), help="phase-2 backend")
    ap.add_argument("--assigner", default="lloyd", choices=ASSIGNERS.names(),
                    help="phase-3 backend")
    ap.add_argument("--mode", default=None, choices=["triangular", "full"],
                    help="deprecated alias: triangular/full -> "
                         "--affinity triangular/dense")
    ap.add_argument("--sparsify-t", type=int, default=None,
                    help="top-t per row for --affinity knn-topt / ooc-topt")
    ap.add_argument("--compute-dtype", default=None,
                    choices=["float32", "f32", "bfloat16", "bf16"],
                    help="MXU product precision inside --affinity fused-rbf "
                         "(accumulation is always f32)")
    ap.add_argument("--schedule", default=None,
                    help="kernel schedule for the Pallas-backed paths: "
                         "'default' (built-in tiles), 'auto' (persistent "
                         "schedule cache, see repro.tune), or an inline "
                         "JSON object of Schedule fields, e.g. "
                         "'{\"bm\": 256, \"bn\": 256}'")
    ap.add_argument("--engine", default=None, choices=["mapreduce"],
                    help="run phase 1 out-of-core through repro.engine "
                         "(forces --affinity ooc-topt)")
    ap.add_argument("--chunk-size", type=int, default=1024,
                    help="rows per engine chunk (--engine mapreduce)")
    ap.add_argument("--memory-budget", type=int, default=None,
                    help="engine shard-store RAM budget in bytes; shards "
                         "beyond it spill to --spill-dir")
    ap.add_argument("--spill-dir", default=None,
                    help="engine spill directory (default: temp dir)")
    ap.add_argument("--workers", type=int, default=1,
                    help="engine task-pool width: map/shuffle/reduce run "
                         "dependency-driven on this many threads (results "
                         "are bitwise-identical at any width)")
    ap.add_argument("--prefetch-depth", type=int, default=2,
                    help="engine shard readahead window: how many upcoming "
                         "CSR shards the streaming matmat fetches "
                         "concurrently")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="engine per-task retry budget before the build "
                         "aborts (Hadoop-style task attempts)")
    ap.add_argument("--speculation-factor", type=float, default=0.0,
                    help="launch a speculative backup attempt once a task "
                         "runs this many times longer than the running "
                         "median (0 disables; first completion wins)")
    ap.add_argument("--stage-timeout-s", type=float, default=None,
                    help="engine per-stage wall-clock deadline; on expiry "
                         "the build raises EngineTimeoutError and the "
                         "affinity falls back to the in-memory knn-topt path")
    ap.add_argument("--chaos", default=None, metavar="JSON",
                    help="deterministic fault-injection plan for resilience "
                         "drills, e.g. '{\"fail\": [[\"map\", \"0-0\", 0]], "
                         "\"corrupt\": {\"shard/0\": \"bitflip\"}}' "
                         "(see repro.engine.FaultPlan.from_spec)")
    ap.add_argument("--lanczos-steps", type=int, default=48,
                    help="target Krylov dimension (block solvers run "
                         "ceil(steps / block-size) block steps)")
    ap.add_argument("--block-size", type=int, default=None,
                    help="eigensolve block width b for --eigensolver "
                         "block-lanczos / chebdav (each matrix pass is "
                         "amortized over b vectors)")
    ap.add_argument("--cheb-degree", type=int, default=12,
                    help="Chebyshev filter degree (--eigensolver chebdav)")
    ap.add_argument("--ckpt-dir", default=None)
    ap.add_argument("--trace-out", default=None, metavar="FILE.json",
                    help="write a Chrome-trace of the run (chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.json",
                    help="write the metrics registry snapshot as JSON")
    args = ap.parse_args(argv)

    affinity = args.affinity
    if args.mode is not None:
        affinity = {"triangular": "triangular", "full": "dense"}[args.mode]
    if args.engine:
        if args.graph:
            ap.error("--engine applies to point datasets; --graph feeds the "
                     "precomputed affinity directly")
        affinity = "ooc-topt"

    schedule = args.schedule
    if isinstance(schedule, str) and schedule.lstrip().startswith("{"):
        import json
        schedule = json.loads(schedule)   # inline Schedule-field object

    faults = None
    if args.chaos:
        from repro import engine
        faults = engine.FaultPlan.from_spec(args.chaos)

    mesh = mesh_utils.local_mesh("rows")
    mgr = CheckpointManager(args.ckpt_dir) if args.ckpt_dir else None
    est = SpectralClustering(
        k=args.k, affinity="precomputed" if args.graph else affinity,
        eigensolver=args.eigensolver, assigner=args.assigner,
        lanczos_steps=args.lanczos_steps, block_size=args.block_size,
        cheb_degree=args.cheb_degree, sparsify_t=args.sparsify_t,
        compute_dtype=args.compute_dtype, schedule=schedule,
        chunk_size=args.chunk_size,
        memory_budget=args.memory_budget, spill_dir=args.spill_dir,
        workers=args.workers, prefetch_depth=args.prefetch_depth,
        max_retries=args.max_retries,
        speculation_factor=args.speculation_factor,
        stage_timeout_s=args.stage_timeout_s, faults=faults,
        mesh=mesh)

    t0 = time.perf_counter()
    if args.graph:
        n, edges = graph_file.parse_topology(args.graph)
        S = graph_file.adjacency_dense(n, edges)
        est.fit_affinity(jnp.asarray(S), checkpointer=mgr)
        truth = None
    else:
        if args.rings:
            pts, truth = synthetic.rings(args.rings, args.k)
        else:
            n = args.blobs or 600
            pts, truth = synthetic.blobs(n, args.k)
        est.fit(jnp.asarray(pts), checkpointer=mgr)
    dt = time.perf_counter() - t0

    labels = np.asarray(est.labels_)
    sizes = np.bincount(labels, minlength=args.k)
    print(f"[spectral] n={len(labels)} k={args.k} "
          f"affinity={est.info_['affinity']} eigensolver={est.eigensolver} "
          f"assigner={est.assigner} devices={mesh_utils.mesh_size(mesh)} "
          f"time={dt:.2f}s")
    print(f"[spectral] eigenvalues: {np.asarray(est.eigenvalues_)}")
    if "matrix_passes" in est.info_:
        print(f"[spectral] matrix_passes={est.info_['matrix_passes']}")
    print(f"[spectral] cluster sizes: {sizes}")
    eng = est.info_.get("engine")
    if eng and "map_tasks" in eng:
        print(f"[engine] map={eng['map_tasks']} shuffle={eng['shuffle_tasks']} "
              f"reduce={eng['reduce_tasks']} chunks={eng['chunks']} "
              f"nnz={eng['nnz']}")
        print(f"[engine] spilled_shards={eng['spilled_shards']} "
              f"spills={eng['store_spills']} "
              f"bytes_spilled={eng['store_bytes_spilled']} "
              f"peak_ram={eng['store_peak_ram_bytes']}")
        if "prefetch_hits" in eng:
            print(f"[engine] prefetch_hits={eng['prefetch_hits']} "
                  f"prefetch_misses={eng['prefetch_misses']}")
        if "overlap_s" in eng:
            print(f"[engine] workers={eng['workers']} "
                  f"build_wall_s={eng['build_wall_s']} "
                  f"overlap_s={eng['overlap_s']} "
                  f"spill_joins={eng['store_spill_joins']}")
        print(f"[obs] engine.retries={eng.get('retries', 0)} "
              f"engine.task_failures={eng.get('task_failures', 0)} "
              f"engine.shard_recovered={eng.get('store_recoveries', 0)} "
              f"engine.speculative_launched="
              f"{eng.get('speculative_launched', 0)} "
              f"engine.speculative_won={eng.get('speculative_won', 0)}")
    if "affinity_fallback" in est.info_:
        print(f"[engine] fallback: {est.info_['affinity_fallback']}")
    elif eng and "bytes_streamed" in eng:  # the fused matrix-free affinity
        print(f"[fused] compute_dtype={eng['compute_dtype']} "
              f"passes={eng['matrix_passes']} "
              f"bytes_streamed={eng['bytes_streamed']} "
              f"peak_affinity_bytes={eng['affinity_peak_bytes']} "
              f"(dense equiv {eng['dense_equiv_bytes']})")
    sched_info = est.info_.get("schedule")
    if sched_info:
        print(f"[schedule] source={sched_info['source']} "
              f"value={sched_info['value']}")
    if "obs" in est.info_:
        print(obs.phase_summary(est.info_["obs"]))
    obs.write_artifacts(args.trace_out, args.metrics_out)
    if truth is not None:
        from itertools import permutations
        k = args.k
        if k <= 6:
            acc = max(np.mean(np.array([p[t] for t in truth]) == labels)
                      for p in permutations(range(k)))
            print(f"[spectral] accuracy vs planted labels: {acc:.3f}")
    return est


if __name__ == "__main__":
    main()
