"""Batched serving driver: slot-based continuous batching over the unified
prefill/decode interface.

A fixed pool of B slots holds independent requests; finished slots are
refilled from the queue without stalling the others (continuous batching).
Because XLA shapes are static, the decode step always runs the full B-slot
batch; slot liveness is a mask.  Prefill runs per-request (padded to the
slot prompt length) and its KV is spliced into the batch cache.

    PYTHONPATH=src python -m repro.launch.serve --arch qwen1.5-0.5b --smoke \
        --requests 12 --slots 4 --gen 16
"""
from __future__ import annotations

import argparse
import time
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import api


@dataclass
class Request:
    rid: int
    prompt: np.ndarray                 # (prompt_len,) int32
    max_new: int
    out: list = field(default_factory=list)
    done: bool = False


class Server:
    """Static-shape continuous batching: B slots, shared KV cache."""

    def __init__(self, model: api.Model, slots: int, prompt_len: int,
                 max_seq: int, seed: int = 0):
        self.model = model
        self.cfg = model.cfg
        self.B = slots
        self.prompt_len = prompt_len
        self.max_seq = max_seq
        self.params = model.init(jax.random.PRNGKey(seed))
        self.requests: list[Request | None] = [None] * slots
        self.steps = 0
        # batch cache built by prefilling a dummy batch once
        dummy = {"tokens": jnp.zeros((slots, prompt_len), jnp.int32)}
        if self.cfg.frontend == "embed":
            dummy["embeds"] = jnp.zeros((slots, prompt_len, self.cfg.d_model),
                                        self.cfg.compute_dtype)
        _, self.cache = model.prefill(self.params, dummy, max_seq=max_seq)
        self.next_tok = jnp.zeros((slots, 1), jnp.int32)
        self._decode = jax.jit(model.decode_step)

    def _prefill_slot(self, slot: int, req: Request):
        toks = np.zeros((self.prompt_len,), np.int32)
        toks[-len(req.prompt):] = req.prompt[: self.prompt_len]
        batch = {"tokens": jnp.asarray(toks)[None]}
        if self.cfg.frontend == "embed":
            batch["embeds"] = jnp.zeros(
                (1, self.prompt_len, self.cfg.d_model), self.cfg.compute_dtype)
        logits, cache1 = self.model.prefill(self.params, batch,
                                            max_seq=self.max_seq)
        # splice the single-request cache into the slot (leading batch dim
        # differs per family; match by shape)
        def splice(full, one):
            if one.ndim == 0:
                return full
            for d in range(one.ndim):
                if one.shape[d] == 1 and full.shape[d] == self.B:
                    idx = [slice(None)] * one.ndim
                    idx[d] = slice(slot, slot + 1)
                    return full.at[tuple(idx)].set(one)
            return full

        self.cache = jax.tree.map(splice, self.cache, cache1)
        self.requests[slot] = req
        tok = int(jnp.argmax(logits[0, -1]))
        req.out.append(tok)
        # the prefill token counts toward the budget: a max_new=1 request
        # is complete right here and must not enter the decode loop
        if len(req.out) >= req.max_new:
            req.done = True
        self.next_tok = self.next_tok.at[slot, 0].set(tok)

    def step(self):
        """One decode step for every live slot."""
        logits, self.cache = self._decode(self.params, self.cache, self.next_tok)
        toks = jnp.argmax(logits[:, 0], axis=-1).astype(jnp.int32)
        self.next_tok = toks[:, None]
        self.steps += 1
        for i, req in enumerate(self.requests):
            if req is None or req.done:
                continue
            req.out.append(int(toks[i]))
            if len(req.out) >= req.max_new:
                req.done = True

    def run(self, queue: list[Request]) -> list[Request]:
        finished: list[Request] = []
        pending = list(queue)
        while pending or any(r and not r.done for r in self.requests):
            # refill free slots (continuous batching)
            for i in range(self.B):
                if (self.requests[i] is None or self.requests[i].done) and pending:
                    if self.requests[i] is not None:
                        finished.append(self.requests[i])
                    self._prefill_slot(i, pending.pop(0))
            # every slot may have finished at prefill (max_new=1): don't
            # burn a full-batch decode step with zero live requests
            if any(r is not None and not r.done for r in self.requests):
                self.step()
        finished.extend(r for r in self.requests if r is not None)
        return finished


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="qwen1.5-0.5b", choices=list(configs.ARCHS))
    ap.add_argument("--smoke", action="store_true", default=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--slots", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=16)
    ap.add_argument("--gen", type=int, default=12)
    args = ap.parse_args(argv)

    cfg = configs.get_smoke(args.arch)
    model = api.build(cfg)
    rng = np.random.RandomState(0)
    queue = [Request(rid=i,
                     prompt=rng.randint(0, cfg.vocab_size,
                                        args.prompt_len).astype(np.int32),
                     max_new=args.gen + rng.randint(0, 5))
             for i in range(args.requests)]
    srv = Server(model, args.slots, args.prompt_len,
                 args.prompt_len + args.gen + 8)
    t0 = time.perf_counter()
    done = srv.run(queue)
    dt = time.perf_counter() - t0
    total_toks = sum(len(r.out) for r in done)
    print(f"[serve] {len(done)} requests, {total_toks} tokens, "
          f"{srv.steps} batch steps, {dt:.1f}s "
          f"({total_toks / dt:.1f} tok/s aggregate)")
    for r in done[:4]:
        print(f"  req {r.rid}: {len(r.out)} tokens -> {r.out[:8]}...")
    assert all(r.done for r in done)
    assert len(done) == args.requests


if __name__ == "__main__":
    main()
