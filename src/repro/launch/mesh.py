"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state).

Mesh construction goes through :func:`repro.distrib.mesh_utils.make_mesh`,
which version-guards the ``AxisType`` kwarg (jax 0.4.x predates axis types).
"""
from __future__ import annotations

from jax.sharding import Mesh

from repro.distrib import mesh_utils


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return mesh_utils.make_mesh(shape, axes)


def make_spectral_mesh(*, multi_pod: bool = False) -> Mesh:
    """The spectral pipeline row-shards its matrices over every chip: a
    flat 1-D mesh (the Hadoop "all workers" pool)."""
    n = 512 if multi_pod else 256
    return mesh_utils.make_mesh((n,), ("rows",))
