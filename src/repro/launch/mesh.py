"""Production mesh builders (functions, never module-level constants, so
importing this module never touches jax device state)."""
from __future__ import annotations

import jax
from jax.sharding import AxisType, Mesh


def make_production_mesh(*, multi_pod: bool = False) -> Mesh:
    """Single pod: (16, 16) = 256 chips, axes (data, model).
    Multi-pod: (2, 16, 16) = 512 chips, axes (pod, data, model)."""
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes,
                         axis_types=(AxisType.Auto,) * len(axes))


def make_spectral_mesh(*, multi_pod: bool = False) -> Mesh:
    """The spectral pipeline row-shards its matrices over every chip: a
    flat 1-D mesh (the Hadoop "all workers" pool)."""
    n = 512 if multi_pod else 256
    return jax.make_mesh((n,), ("rows",), axis_types=(AxisType.Auto,))
