"""Batched cluster-assignment service over a persisted spectral model.

The clustering analogue of ``launch/serve.py``'s continuous batching: a
fitted :class:`~repro.cluster.SpectralClustering` model is loaded from
disk (``est.save`` / ``SpectralClustering.load``) and served against a
queue of predict requests, each carrying a variable number of query
points.  XLA shapes are static, so every service step runs ONE fixed
``(B, d)`` predict batch: pending request rows are packed into the batch
buffer (a request larger than B streams through over several steps), a
liveness mask marks the filled rows, and the compiled fused Nystrom
transform embeds + assigns the whole batch in one pass over the training
set — unfilled rows ride along as padding and are discarded on scatter.

    PYTHONPATH=src python -m repro.launch.cluster_serve \\
        --fit-blobs 512 --k 3 --model-dir /tmp/spectral-model \\
        --requests 8 --points-per-request 100

With an existing ``--model-dir`` the fit step is skipped: the service
loads and serves (fit once, serve anywhere — including a different device
count, the checkpoint is elastic).

Admission control (the resilience contract, see API.md "Fault
tolerance"): the server optionally bounds its pending-row backlog
(``max_pending_rows``) — a submit that would blow the bound is *shed*
with a typed :class:`~repro.cluster.serving.QueueFullError` instead of
growing the queue without limit — and every request may carry a deadline
(``deadline_s``, or the server-wide ``default_deadline_s``): requests
that sit past it are *expired* with a typed
:class:`~repro.cluster.serving.DeadlineExceededError` and their
remaining rows never occupy batch slots.  ``serve.queue_depth`` (gauge),
``serve.shed`` and ``serve.expired`` (counters) track it in the obs
registry.
"""
from __future__ import annotations

import argparse
import time
from collections import deque
from dataclasses import dataclass, field

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cluster.serving import DeadlineExceededError, QueueFullError


@dataclass
class PredictRequest:
    rid: int
    points: np.ndarray                       # (m_i, d) float32
    labels: np.ndarray | None = None         # filled on completion
    t_submit: float = 0.0
    t_done: float = 0.0
    _filled: int = field(default=0, repr=False)   # rows already served
    deadline_s: float | None = None          # per-request; None = server's
    status: str = "pending"                  # pending|active|ok|shed|expired
    error: str | None = None                 # typed-rejection message

    @property
    def latency_s(self) -> float:
        return self.t_done - self.t_submit

    @property
    def done(self) -> bool:
        return self.labels is not None and self._filled >= len(self.points)


class ClusterServer:
    """Static-shape batched predict: one (B, d) buffer, liveness mask.

    ``max_pending_rows`` bounds the admission queue (None = unbounded,
    the classic behaviour); ``default_deadline_s`` applies to requests
    that carry no ``deadline_s`` of their own (None = no deadline)."""

    def __init__(self, est, batch_rows: int = 256,
                 max_pending_rows: int | None = None,
                 default_deadline_s: float | None = None):
        est._check_fitted()
        if est._train_x is None:
            raise ValueError("serving needs a feature-space model "
                             "(precomputed-affinity fits cannot predict)")
        if max_pending_rows is not None and max_pending_rows <= 0:
            raise ValueError(f"max_pending_rows must be positive or None, "
                             f"got {max_pending_rows}")
        self.est = est
        self.B = int(batch_rows)
        self.d = int(est._train_x.shape[1])
        self.max_pending_rows = max_pending_rows
        self.default_deadline_s = default_deadline_s
        self.steps = 0
        self.stats = {"batches": 0, "rows_live": 0, "rows_padded": 0,
                      "shed": 0, "expired": 0}
        # the SHARED histogram type backs both the live metrics and
        # summarize()'s p50/p95/p99 (exact nearest-rank at service scale)
        self.batch_ms = obs.histogram("serve.batch_ms")
        self.request_ms = obs.histogram("serve.request_ms")
        # one compiled predict for the one static shape the service runs;
        # est.predict routes (dense/fused) on static metadata, so the
        # whole embed+assign pipeline traces into a single computation
        self._predict = jax.jit(lambda xb: est.predict(xb))

    # -- admission control ---------------------------------------------------

    @staticmethod
    def pending_rows(active: deque) -> int:
        """Rows admitted but not yet served (the backlog the admission
        bound and the queue-depth gauge measure)."""
        return sum(len(r.points) - r._filled for r in active)

    def admit(self, req: PredictRequest, active: deque,
              now: float | None = None) -> bool:
        """Admit ``req`` into the active window, or shed it with a typed
        rejection when the pending-row backlog is at its bound.  A
        request larger than the whole bound is still admitted when the
        queue is empty (it would otherwise be undeliverable) — it streams
        through B rows per step like any oversized request."""
        now = time.perf_counter() if now is None else now
        if req.t_submit == 0.0:
            req.t_submit = now
        rows = len(req.points)
        if self.max_pending_rows is not None:
            pending = self.pending_rows(active)
            if pending > 0 and pending + rows > self.max_pending_rows:
                err = QueueFullError(req.rid, rows, pending,
                                     self.max_pending_rows)
                req.status, req.error, req.t_done = err.status, str(err), now
                self.stats["shed"] += 1
                obs.counter("serve.shed").inc()
                return False
        req.status = "active"
        active.append(req)
        obs.gauge("serve.queue_depth").set(self.pending_rows(active))
        return True

    def _expire(self, active: deque, now: float) -> int:
        """Drop admitted requests that sat past their deadline; their
        remaining rows never occupy batch slots."""
        expired = 0
        for req in list(active):
            ddl = (req.deadline_s if req.deadline_s is not None
                   else self.default_deadline_s)
            if ddl is None or req.done:
                continue
            waited = now - req.t_submit
            if waited > ddl:
                err = DeadlineExceededError(req.rid, ddl, waited)
                req.status, req.error, req.t_done = err.status, str(err), now
                active.remove(req)
                expired += 1
        if expired:
            self.stats["expired"] += expired
            obs.counter("serve.expired").inc(expired)
        return expired

    def _pack(self, active: deque) -> tuple[np.ndarray, np.ndarray, list]:
        """Fill the (B, d) buffer from the active queue (FIFO, splitting
        requests that don't fit); returns (buffer, liveness mask,
        [(request, row_start_in_request, rows, batch_row0), ...])."""
        buf = np.zeros((self.B, self.d), np.float32)
        mask = np.zeros((self.B,), bool)
        placed = []
        row = 0
        for req in active:
            if row == self.B:
                break
            take = min(self.B - row, len(req.points) - req._filled)
            if take <= 0:
                continue
            buf[row: row + take] = req.points[req._filled: req._filled + take]
            mask[row: row + take] = True
            placed.append((req, req._filled, take, row))
            row += take
        return buf, mask, placed

    def step(self, active: deque) -> int:
        """One static-shape predict over the packed batch; scatters labels
        back and retires completed requests (expiring any that outlived
        their deadline first).  Returns rows served."""
        self._expire(active, time.perf_counter())
        buf, mask, placed = self._pack(active)
        if not placed:
            return 0
        with obs.span("serve.step", batch_rows=self.B) as sp:
            t0 = time.perf_counter()
            labels = np.asarray(self._predict(jnp.asarray(buf)))
            now = time.perf_counter()
            self.batch_ms.observe(1e3 * (now - t0))
            for req, start, take, row0 in placed:
                if req.labels is None:
                    req.labels = np.empty(len(req.points), labels.dtype)
                req.labels[start: start + take] = labels[row0: row0 + take]
                req._filled += take
                if req.done:
                    req.t_done = now
                    req.status = "ok"
                    self.request_ms.observe(1e3 * req.latency_s)
            while active and active[0].done:
                active.popleft()
            obs.gauge("serve.queue_depth").set(self.pending_rows(active))
            live = int(mask.sum())
            sp.set(rows_live=live)
        self.steps += 1
        self.stats["batches"] += 1
        self.stats["rows_live"] += live
        self.stats["rows_padded"] += self.B - live
        obs.counter("serve.batches").inc()
        obs.counter("serve.rows_live").inc(live)
        obs.counter("serve.rows_padded").inc(self.B - live)
        obs.gauge("serve.fill").set(
            self.stats["rows_live"]
            / max(self.stats["rows_live"] + self.stats["rows_padded"], 1))
        return live

    def run(self, queue: list[PredictRequest]) -> list[PredictRequest]:
        """Serve every request that survives admission to completion
        (requests enter the active window in arrival order; the window
        drains front-first, so a big request streams through B rows per
        step without starving the batch — trailing slack is refilled from
        the queue).  Shed and expired requests come back with their typed
        status/``error`` set instead of labels."""
        t0 = time.perf_counter()
        active: deque = deque()
        for req in queue:
            req.t_submit = t0
            if len(req.points) == 0:             # degenerate: nothing to do
                req.labels = np.empty((0,), np.int32)
                req.t_done = t0
                req.status = "ok"
                continue
            self.admit(req, active, now=t0)
        while active:
            self.step(active)
        return list(queue)


def summarize(done: list[PredictRequest], wall_s: float) -> dict:
    # the shared histogram type does the percentile math: exact
    # nearest-rank (p50 of [a, b] is a; p99 of n=1 is that sample —
    # no len//2 off-by-one on small n).  Latency percentiles cover
    # COMPLETED requests only; shed/expired are counted separately.
    ok = [r for r in done if r.done]
    hist = obs.Histogram("serve.summary_latency_ms")
    for r in ok:
        hist.observe(1e3 * r.latency_s)
    total = sum(len(r.points) for r in ok)
    return {
        "requests": len(done),
        "completed": len(ok),
        "shed": sum(r.status == "shed" for r in done),
        "expired": sum(r.status == "expired" for r in done),
        "points": total,
        "points_per_s": total / max(wall_s, 1e-9),
        "latency_p50_ms": hist.percentile(50),
        "latency_p95_ms": hist.percentile(95),
        "latency_p99_ms": hist.percentile(99),
        "latency_max_ms": 1e3 * max((r.latency_s for r in ok),
                                    default=0.0),
    }


def main(argv=None):
    from repro.cluster import SpectralClustering
    from repro.data import synthetic

    ap = argparse.ArgumentParser()
    ap.add_argument("--model-dir", required=True,
                    help="persisted model (est.save); with --fit-blobs the "
                         "model is fitted and saved here first")
    ap.add_argument("--fit-blobs", type=int, default=0,
                    help="fit a fresh model on n blob points, save it to "
                         "--model-dir, then reload it (fit -> save -> load "
                         "-> serve round trip)")
    ap.add_argument("--k", type=int, default=3)
    ap.add_argument("--affinity", default="fused-rbf")
    ap.add_argument("--eigensolver", default="block-lanczos")
    ap.add_argument("--lanczos-steps", type=int, default=64)
    ap.add_argument("--transform-path", default="auto",
                    choices=["auto", "dense", "fused"])
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--points-per-request", type=int, default=100)
    ap.add_argument("--batch-rows", type=int, default=256)
    ap.add_argument("--max-pending-rows", type=int, default=None,
                    help="bounded admission queue: shed requests that "
                         "would push the pending backlog past this many "
                         "rows (default: unbounded)")
    ap.add_argument("--deadline-s", type=float, default=None,
                    help="server-wide request deadline; requests that sit "
                         "past it are expired with a typed rejection")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--trace-out", default=None, metavar="FILE.json",
                    help="write a Chrome-trace of the run (chrome://tracing)")
    ap.add_argument("--metrics-out", default=None, metavar="FILE.json",
                    help="write the metrics registry snapshot as JSON")
    args = ap.parse_args(argv)

    if args.fit_blobs:
        pts, _ = synthetic.blobs(args.fit_blobs, args.k, dim=8, spread=0.6,
                                 seed=args.seed)
        est = SpectralClustering(
            k=args.k, affinity=args.affinity, eigensolver=args.eigensolver,
            sigma=1.0, lanczos_steps=args.lanczos_steps,
            transform_path=args.transform_path, seed=args.seed)
        t0 = time.perf_counter()
        est.fit(jnp.asarray(pts))
        print(f"[cluster_serve] fit n={args.fit_blobs} "
              f"affinity={args.affinity} in {time.perf_counter() - t0:.1f}s")
        if "obs" in est.info_:
            print(obs.phase_summary(est.info_["obs"]))
        est.save(args.model_dir)
        print(f"[cluster_serve] saved -> {args.model_dir}")

    est = SpectralClustering.load(args.model_dir)
    est.transform_path = args.transform_path
    n, d = est._train_x.shape
    print(f"[cluster_serve] loaded model: n={n} d={d} k={est.k} "
          f"devices={len(jax.devices())}")

    rng = np.random.RandomState(args.seed + 1)
    train = np.asarray(est._train_x)
    queue = []
    for rid in range(args.requests):
        m = max(1, args.points_per_request + rng.randint(-20, 21))
        idx = rng.choice(n, size=m)
        queue.append(PredictRequest(
            rid=rid, points=(train[idx]
                             + 0.05 * rng.randn(m, d)).astype(np.float32)))

    srv = ClusterServer(est, batch_rows=args.batch_rows,
                        max_pending_rows=args.max_pending_rows,
                        default_deadline_s=args.deadline_s)
    t0 = time.perf_counter()
    done = srv.run(queue)
    wall = time.perf_counter() - t0
    s = summarize(done, wall)
    fill = srv.stats["rows_live"] / max(
        srv.stats["rows_live"] + srv.stats["rows_padded"], 1)
    path = est.info_.get("transform", {}).get("path", "n/a")
    print(f"[cluster_serve] {s['requests']} requests "
          f"({s['completed']} ok, {s['shed']} shed, {s['expired']} "
          f"expired), {s['points']} points, "
          f"{srv.steps} batch steps ({fill:.0%} fill), {wall:.2f}s "
          f"({s['points_per_s']:.0f} pts/s, "
          f"p50={s['latency_p50_ms']:.0f}ms p95={s['latency_p95_ms']:.0f}ms "
          f"p99={s['latency_p99_ms']:.0f}ms max={s['latency_max_ms']:.0f}ms) "
          f"path={path}")
    print(f"[obs] serve wall={wall:.3f}s batches={srv.stats['batches']} "
          f"fill={fill:.0%} request_p99_ms={s['latency_p99_ms']:.1f} "
          f"shed={s['shed']} expired={s['expired']}")
    obs.write_artifacts(args.trace_out, args.metrics_out)
    assert all(r.done for r in done
               if r.status not in ("shed", "expired"))
    assert all(len(r.labels) == len(r.points) for r in done if r.done)


if __name__ == "__main__":
    main()
