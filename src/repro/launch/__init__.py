# Launchers: production mesh construction, the multi-pod dry-run,
# training/serving drivers, and the spectral-clustering job driver.
