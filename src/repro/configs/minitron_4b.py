"""minitron-4b [dense] — 32L, d_model 3072, 24H (GQA kv=8), d_ff 9216,
vocab 256000; width-pruned Nemotron.  [arXiv:2407.14679]

24 heads don't divide the 16-way model axis -> shard head_dim (128)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="minitron-4b",
    family="dense",
    num_layers=32,
    d_model=3072,
    num_heads=24,
    num_kv_heads=8,
    d_ff=9216,
    vocab_size=256_000,
    sharding_overrides={"heads": None, "kv_heads": None, "head_dim": "model"},
    # serving uses sequence parallelism: head_dim TP psums S x S score
    # tiles (EXPERIMENTS.md §Perf — 22x on prefill_32k)
    serve_sharding_preset="sp_serve",
)

SMOKE = CONFIG.with_(num_layers=4, d_model=96, d_ff=192, vocab_size=512,
                     num_heads=6, num_kv_heads=2)
