"""mixtral-8x7b [moe] — 32L, d_model 4096, 32H (GQA kv=8), expert
d_ff 14336, vocab 32000; 8 experts top-2, sliding-window attention
(4096) on every layer.  [arXiv:2401.04088]

SWA makes decode sub-quadratic, so this arch runs the long_500k cell."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="mixtral-8x7b",
    family="moe",
    num_layers=32,
    d_model=4096,
    num_heads=32,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=32_000,
    num_experts=8,
    top_k=2,
    expert_d_ff=14_336,
    local_window=4096,
    local_ratio=-1,
)

SMOKE = CONFIG.with_(num_layers=3, d_model=64, vocab_size=512, num_heads=8,
                     num_kv_heads=2, num_experts=4, top_k=2, expert_d_ff=128,
                     local_window=16)
