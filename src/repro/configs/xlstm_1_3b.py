"""xlstm-1.3b [ssm] — 48L, d_model 2048, 4 heads, vocab 50304; mLSTM
blocks with 1-in-8 sLSTM (xLSTM[7:1]); d_ff 0 (blocks carry their own
pf=2 projections).  [arXiv:2405.04517]

O(1)-state decode -> runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="xlstm",
    num_layers=48,
    d_model=2048,
    num_heads=4,
    num_kv_heads=4,
    d_ff=0,
    vocab_size=50_304,
    xlstm_slstm_every=8,
)

SMOKE = CONFIG.with_(num_layers=8, d_model=64, vocab_size=512, num_heads=2,
                     xlstm_slstm_every=4)
