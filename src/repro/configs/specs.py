"""ShapeDtypeStruct input stand-ins per (architecture x shape cell) — the
dry-run's "no allocation" batch construction (shannon/kernels pattern)."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.config import SHAPES_BY_NAME, ModelConfig, ShapeCell


def input_specs(cfg: ModelConfig, cell: ShapeCell | str) -> dict:
    """Abstract model inputs for one shape cell.

    train/prefill: token (or stub-embedding) batch.
    decode: the single-token step input; the KV/state cache is built from
    ``model.cache_specs`` separately (it is carried state, not input).
    """
    if isinstance(cell, str):
        cell = SHAPES_BY_NAME[cell]
    B, S = cell.global_batch, cell.seq_len
    i32 = jnp.int32

    def tok(shape):
        return jax.ShapeDtypeStruct(shape, i32)

    def emb(shape):
        return jax.ShapeDtypeStruct(shape, cfg.compute_dtype)

    if cell.kind in ("train", "prefill"):
        batch = {"tokens": tok((B, S))}
        if cfg.frontend == "embed":
            batch["embeds"] = emb((B, S, cfg.d_model))
        return batch
    if cell.kind == "decode":
        batch = {"token": tok((B, 1))}
        return batch
    raise ValueError(cell.kind)
