"""kimi-k2-1t-a32b [moe] — 61L, d_model 7168, 64H (GQA kv=8), expert
d_ff 2048, vocab 163840; 384 experts top-8 + 1 shared expert — the
trillion-parameter paper-table config.  [arXiv:2501.kimi2]

Memory notes (why the optimizer deviates): 1.04e12 params; bf16 params +
Adafactor-style factored second moment + ZeRO-1 sharding of optimizer
state over the data axis are required to fit 16 GiB/chip HBM on 512
chips (DESIGN.md).  Kimi's single leading dense layer is folded into the
uniform MoE scan (deviation recorded here and in DESIGN.md §5)."""
import jax.numpy as jnp

from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="kimi-k2-1t-a32b",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=64,
    num_kv_heads=8,
    d_ff=0,
    vocab_size=163_840,
    num_experts=384,
    top_k=8,
    expert_d_ff=2048,
    num_shared_experts=1,
    optimizer="adafactor",
    shard_opt_over_data=True,
    param_dtype=jnp.bfloat16,
    # production settings from the perf hillclimb (EXPERIMENTS.md §Perf):
    # explicit shard_map expert parallelism, ZeRO-3 param sharding (the
    # only way 1T params fit 16 GiB/chip), full activation remat
    moe_impl="ep_shard_map",
    fsdp_params=True,
    remat="full",
)

SMOKE = CONFIG.with_(num_layers=3, d_model=64, vocab_size=512, num_heads=8,
                     num_kv_heads=2, num_experts=8, top_k=2, expert_d_ff=96,
                     param_dtype=jnp.float32)
