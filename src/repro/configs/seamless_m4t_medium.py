"""seamless-m4t-medium [audio] — encoder-decoder backbone: 12L encoder +
12L decoder, d_model 1024, 16H (kv=16), d_ff 4096, vocab 256206.
[arXiv:2308.11596]

The speech frontend is a STUB: ``input_specs()`` provides precomputed
frame embeddings (B, S, d_model) for the encoder (``frontend="embed"``).
Enc-dec: no long_500k cell (encoder position ceiling — DESIGN.md §5);
decode cells exercise self-KV + precomputed cross-KV."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="seamless-m4t-medium",
    family="audio",
    num_layers=12,
    encoder_layers=12,
    d_model=1024,
    num_heads=16,
    num_kv_heads=16,
    d_ff=4096,
    vocab_size=256_206,
    frontend="embed",
)

SMOKE = CONFIG.with_(num_layers=2, encoder_layers=2, d_model=64, d_ff=128,
                     vocab_size=512, num_heads=4, num_kv_heads=4)
