"""zamba2-2.7b [hybrid] — 54 Mamba2 layers, d_model 2560, ssm_state 64;
one weight-SHARED (32H MHA attention + MLP d_ff 10240) block applied
after every 6 Mamba2 layers (9 invocations, each with its own KV cache).
[arXiv:2411.15242]  Per-invocation LoRA deltas on the shared block are
omitted (DESIGN.md §2).  Hybrid O(1) SSM state -> runs long_500k."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="zamba2-2.7b",
    family="hybrid",
    num_layers=54,
    d_model=2560,
    num_heads=32,
    num_kv_heads=32,
    d_ff=10_240,
    vocab_size=32_000,
    ssm_state=64,
    shared_attn_every=6,
)

SMOKE = CONFIG.with_(num_layers=6, d_model=64, d_ff=128, vocab_size=512,
                     num_heads=4, num_kv_heads=4, ssm_state=16,
                     shared_attn_every=3)
