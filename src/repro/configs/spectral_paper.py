"""The paper's own experiment (§5): spectral clustering of a ~10k-vertex
graph (10029 points, 21054 edges in the paper's topology text file).

``PAPER_N`` mirrors the paper's dataset size; ``PRODUCTION_N`` is the
scaled-up configuration used for the 256/512-chip dry-run (the paper's
point is scaling, so the production mesh gets a production-size n)."""
from repro.core.spectral import SpectralConfig

PAPER_N = 10_029
PAPER_EDGES = 21_054
PRODUCTION_N = 262_144          # 2m * b tiles with m=256/512 devices

CONFIG = SpectralConfig(
    k=8,
    sigma=None,                  # median heuristic
    lanczos_steps=64,
    kmeans_iters=50,
    mode="triangular",           # the paper's balanced upper-triangle schedule
)

SMOKE = SpectralConfig(k=3, sigma=1.0, lanczos_steps=24, kmeans_iters=20,
                       mode="triangular")
