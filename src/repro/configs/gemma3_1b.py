"""gemma3-1b [dense] — 26L, d_model 1152, 4H (GQA kv=1, head_dim 256),
d_ff 6912, vocab 262144; 5:1 local:global attention, 512-token sliding
window on local layers.  [hf:google/gemma-3-1b-pt]

TP note: 4 heads / 1 kv head are not divisible by the 16-way model axis,
so attention shards over head_dim (256 % 16 == 0) instead — the
``sharding_overrides`` below.  Supported for long_500k (local layers are
sub-quadratic; the 1-in-6 global layers use the chunked online-softmax).
"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="gemma3-1b",
    family="dense",
    num_layers=26,
    d_model=1152,
    num_heads=4,
    num_kv_heads=1,
    head_dim=256,
    d_ff=6912,
    vocab_size=262_144,
    local_window=512,
    local_ratio=5,
    rope_theta=1_000_000.0,
    sharding_overrides={"heads": None, "kv_heads": None, "head_dim": "model"},
    serve_sharding_preset="sp_serve",   # see EXPERIMENTS.md §Perf
)

SMOKE = CONFIG.with_(
    num_layers=6, d_model=96, head_dim=24, d_ff=192, vocab_size=512,
    local_window=8, dense_attn_max_seq=64, attn_chunk=16)
