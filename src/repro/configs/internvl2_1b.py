"""internvl2-1b [vlm] — InternLM2 backbone: 24L, d_model 896, 14H
(GQA kv=2), d_ff 4864, vocab 151655.  [arXiv:2404.16821]

Per the assignment the InternViT frontend is a STUB: ``input_specs()``
provides precomputed patch embeddings (B, S, d_model) that bypass the
token embedding (``frontend="embed"``).  14 heads don't divide the model
axis -> shard head_dim (64)."""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="internvl2-1b",
    family="vlm",
    num_layers=24,
    d_model=896,
    num_heads=14,
    num_kv_heads=2,
    d_ff=4864,
    vocab_size=151_655,
    frontend="embed",
    sharding_overrides={"heads": None, "kv_heads": None, "head_dim": "model"},
    serve_sharding_preset="sp_serve",   # see EXPERIMENTS.md §Perf
)

SMOKE = CONFIG.with_(num_layers=4, d_model=64, d_ff=128, vocab_size=512,
                     num_heads=4, num_kv_heads=2, head_dim=None)
