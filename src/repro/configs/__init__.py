"""Architecture registry: one module per assigned architecture.

``get(arch_id)`` -> (full ModelConfig, reduced smoke ModelConfig).
``input_specs(cfg, shape_cell, ...)`` -> ShapeDtypeStruct stand-ins.
"""
from __future__ import annotations

import importlib

from repro.models.config import SHAPES_BY_NAME, ModelConfig

ARCHS = (
    "gemma3-1b",
    "minitron-4b",
    "qwen1.5-0.5b",
    "glm4-9b",
    "kimi-k2-1t-a32b",
    "mixtral-8x7b",
    "xlstm-1.3b",
    "internvl2-1b",
    "zamba2-2.7b",
    "seamless-m4t-medium",
)

# cells skipped per assignment: long_500k only runs for sub-quadratic archs
# (windowed/SSM/hybrid); pure full-attention archs + the enc-dec skip it.
LONG_CONTEXT_ARCHS = {"gemma3-1b", "mixtral-8x7b", "xlstm-1.3b", "zamba2-2.7b"}


def _module(arch_id: str):
    return importlib.import_module(f"repro.configs.{arch_id.replace('-', '_').replace('.', '_')}")


def get(arch_id: str) -> ModelConfig:
    return _module(arch_id).CONFIG


def get_smoke(arch_id: str) -> ModelConfig:
    return _module(arch_id).SMOKE


def cell_supported(arch_id: str, shape_name: str) -> bool:
    if shape_name == "long_500k":
        return arch_id in LONG_CONTEXT_ARCHS
    return True


from repro.configs.specs import input_specs  # noqa: E402  (re-export)
