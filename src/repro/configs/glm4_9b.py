"""glm4-9b [dense] — 40L, d_model 4096, 32H (GQA kv=2), d_ff 13696,
vocab 151552; RoPE + GQA.  [hf:THUDM/glm-4-9b]"""
from repro.models.config import ModelConfig

CONFIG = ModelConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    d_ff=13_696,
    vocab_size=151_552,
)

SMOKE = CONFIG.with_(num_layers=4, d_model=64, d_ff=128, vocab_size=512,
                     num_heads=8, num_kv_heads=2)
