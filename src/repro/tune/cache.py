"""Persistent schedule cache: best-known schedules per (kernel, shape
bucket, device kind, dtype), stored as one JSON file.

Shapes are bucketed to the next power of two per dimension, so a schedule
tuned at n=3000 serves n=4096-class problems; the batch width ``b`` is
excluded from the key on purpose (see ``KernelSpec.bucket_dims``) — one
tuned schedule serves every matmat width.  Writes are atomic (tmp +
``os.replace``, same discipline as ``repro.checkpoint``) and re-read the
file before merging, so concurrent tuners lose at most their own entry,
never the whole cache.  A corrupt or foreign-version file is treated as
empty rather than raised — the cache is an optimization, deleting it is
always safe (schedules are re-derived by the next ``tune_sweep``).

Default location: ``$REPRO_SCHEDULE_CACHE`` if set, else
``~/.cache/repro/schedules.json``.  Caches are per-device-kind by
construction of the key, so one file can hold CPU and TPU entries side by
side.
"""
from __future__ import annotations

import json
import os
import threading
from typing import Optional

from repro.tune.schedule import Schedule, spec

CACHE_ENV = "REPRO_SCHEDULE_CACHE"
CACHE_VERSION = 1


def default_cache_path() -> str:
    env = os.environ.get(CACHE_ENV)
    if env:
        return env
    return os.path.join(os.path.expanduser("~"), ".cache", "repro",
                        "schedules.json")


def bucket(x: int) -> int:
    """Next power of two >= x (>= 1): the shape-bucket rounding rule."""
    return 1 << max(0, int(x) - 1).bit_length() if x > 1 else 1


def device_kind() -> str:
    """Normalized device identifier for cache keys, e.g. "cpu" or
    "tpu-v5-lite"."""
    import jax
    try:
        kind = jax.devices()[0].device_kind
    except Exception:  # pragma: no cover - no devices at all
        kind = jax.default_backend()
    return str(kind).strip().lower().replace(" ", "-")


def cache_key(kernel: str, *, device: Optional[str] = None,
              dtype: str = "float32", **shape) -> str:
    """``kernel/shape-bucket/device/dtype`` — the persistent key.  Only the
    kernel's ``bucket_dims`` participate; extra shape kwargs are ignored
    so call sites can pass their full shape dict."""
    sp = spec(kernel)
    missing = [d for d in sp.bucket_dims if d not in shape]
    if missing:
        raise ValueError(f"cache key for {kernel} needs shape dims "
                         f"{sp.bucket_dims}, missing {missing}")
    shp = "-".join(f"{d}{bucket(int(shape[d]))}" for d in sp.bucket_dims)
    return f"{kernel}/{shp}/{device or device_kind()}/{dtype or 'float32'}"


class ScheduleCache:
    """Thread-safe JSON-backed schedule store with hit/miss counters."""

    def __init__(self, path: Optional[str] = None):
        self.path = path or default_cache_path()
        self._lock = threading.Lock()
        self.stats = {"hits": 0, "misses": 0, "puts": 0}

    # -- file I/O -----------------------------------------------------------

    def _read(self) -> dict:
        try:
            with open(self.path) as f:
                data = json.load(f)
        except (OSError, ValueError):
            return {}
        if not isinstance(data, dict) \
                or data.get("version") != CACHE_VERSION:
            return {}
        entries = data.get("entries")
        return entries if isinstance(entries, dict) else {}

    def _write(self, entries: dict) -> None:
        d = os.path.dirname(os.path.abspath(self.path))
        os.makedirs(d, exist_ok=True)
        tmp = f"{self.path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump({"version": CACHE_VERSION, "entries": entries}, f,
                      indent=2, sort_keys=True)
        os.replace(tmp, self.path)

    # -- API ----------------------------------------------------------------

    def get(self, kernel: str, *, device: Optional[str] = None,
            dtype: str = "float32", **shape) -> Optional[Schedule]:
        key = cache_key(kernel, device=device, dtype=dtype, **shape)
        with self._lock:
            rec = self._read().get(key)
            if rec is None:
                self.stats["misses"] += 1
                return None
            try:
                s = Schedule.from_dict(rec["schedule"])
            except (KeyError, ValueError):
                self.stats["misses"] += 1
                return None
            self.stats["hits"] += 1
            return s

    def entry(self, kernel: str, *, device: Optional[str] = None,
              dtype: str = "float32", **shape) -> Optional[dict]:
        """The raw record (schedule dict + tuning metadata), no counters."""
        key = cache_key(kernel, device=device, dtype=dtype, **shape)
        with self._lock:
            return self._read().get(key)

    def put(self, kernel: str, schedule: Schedule, *,
            device: Optional[str] = None, dtype: str = "float32",
            wall_us: Optional[float] = None,
            default_wall_us: Optional[float] = None, **shape) -> str:
        key = cache_key(kernel, device=device, dtype=dtype, **shape)
        rec = {"schedule": schedule.to_dict()}
        if wall_us is not None:
            rec["wall_us"] = round(float(wall_us), 2)
        if default_wall_us is not None:
            rec["default_wall_us"] = round(float(default_wall_us), 2)
        with self._lock:
            entries = self._read()      # merge-on-write: keep peers' keys
            entries[key] = rec
            self._write(entries)
            self.stats["puts"] += 1
        return key

    def keys(self) -> list:
        with self._lock:
            return sorted(self._read())


_default: Optional[ScheduleCache] = None
_default_lock = threading.Lock()


def default_cache() -> ScheduleCache:
    """Process-wide cache at the default path (re-created if the path env
    var changed — tests point it at tmp dirs)."""
    global _default
    with _default_lock:
        path = default_cache_path()
        if _default is None or _default.path != path:
            _default = ScheduleCache(path)
        return _default
