"""Schedule/algorithm separation for the Pallas kernel stack.

Every kernel in ``repro.kernels`` computes a fixed function (the
*algorithm*); how that function is tiled over the grid, what dtype the MXU
products run in, the grid iteration order and where the accumulator lives
are the *schedule* (the SYS_ATL/Exo separation).  A :class:`Schedule` makes
those choices an explicit, serializable value that can be

  * passed to any public kernel wrapper (``ops.block_matmat(...,
    schedule=...)``) — ``schedule=None`` reproduces the old keyword-tile
    behavior bit-for-bit;
  * searched by the autotuner (:mod:`repro.tune.autotune`) and persisted
    per (kernel, shape bucket, device) in :mod:`repro.tune.cache`;
  * checked for *legality* before it ever reaches a ``pallas_call``:
    MXU sublane/lane multiples, per-kernel knob support, and a VMEM
    working-set model — so an illegal tile raises a one-line ValueError
    here instead of an opaque Pallas lowering failure.

:class:`KernelSpec` is the per-kernel contract: the default schedule (the
old hard-coded tiles), which schedule knobs the kernel supports, the VMEM
model, and the FLOPs/bytes models the roofline report uses.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable, Optional

# TPU tiling floor for f32 operands: (sublane, lane) = (8, 128).  Sublane
# multiples are enforced always (they are also what keeps the interpret and
# compiled paths shape-compatible); lane multiples only matter once the
# kernel is actually lowered for the MXU, so interpret-mode schedules may
# relax them (the small-tile test schedules rely on this).
SUBLANE = 8
LANE = 128

# Per-grid-cell VMEM working-set ceiling.  Physical VMEM is ~16 MiB/core
# and the Pallas pipeline double-buffers input tiles, so one cell's tiles
# must fit in about half of it.
VMEM_BYTES = 8 * 1024 * 1024

GRID_ORDERS = ("row-major", "col-major")
ACCS = ("inplace", "scratch")
_DTYPE_NAMES = {None: None, "f32": "float32", "float32": "float32",
                "bf16": "bfloat16", "bfloat16": "bfloat16"}


class ScheduleError(ValueError):
    """An illegal schedule for a given kernel/shape (clear, pre-lowering)."""


@dataclasses.dataclass(frozen=True)
class Schedule:
    """One point in a kernel's schedule space.

    ``None`` fields mean "inherit": the resolver fills them from the call
    site's keyword arguments (which carry the historical defaults), so a
    partial schedule like ``Schedule(compute_dtype="bf16")`` only overrides
    what it names.

    bm / bn:        row / column (reduction-side) tile edges.
    compute_dtype:  MXU product precision ("float32" | "bfloat16") for the
                    kernels that expose it; accumulation stays f32.
    grid_order:     "row-major" (default: last grid dim fastest) or
                    "col-major" (first fastest) — only legal for kernels
                    whose output tiles are written exactly once.
    acc:            accumulator placement for reducing kernels: "inplace"
                    (accumulate into the revisited output tile) or
                    "scratch" (f32 VMEM scratch, one output write at the
                    last reduction step).
    interpret:      force the Pallas interpreter (None = auto-detect:
                    compiled on TPU, interpreted elsewhere).
    """
    bm: Optional[int] = None
    bn: Optional[int] = None
    compute_dtype: Optional[str] = None
    grid_order: str = "row-major"
    acc: str = "inplace"
    interpret: Optional[bool] = None

    def __post_init__(self):
        # normalize dtype aliases ("bf16"/"f32") at construction so equal
        # schedules compare equal regardless of how they were spelled
        cd = self.compute_dtype
        if cd is not None:
            cd = str(cd).lower()
            if cd not in _DTYPE_NAMES:
                raise ScheduleError(
                    f"schedule compute_dtype must be one of "
                    f"{sorted(k for k in _DTYPE_NAMES if k)}, got "
                    f"{self.compute_dtype!r}")
            object.__setattr__(self, "compute_dtype", _DTYPE_NAMES[cd])

    def replace(self, **kw) -> "Schedule":
        return dataclasses.replace(self, **kw)

    def to_dict(self) -> dict:
        d = dataclasses.asdict(self)
        return {k: v for k, v in d.items() if v is not None}

    @classmethod
    def from_dict(cls, d: dict) -> "Schedule":
        fields = {f.name for f in dataclasses.fields(cls)}
        extra = set(d) - fields
        if extra:
            raise ScheduleError(
                f"unknown schedule field(s) {sorted(extra)}; "
                f"expected a subset of {sorted(fields)}")
        d = dict(d)
        if "compute_dtype" in d and d["compute_dtype"] is not None:
            spec = str(d["compute_dtype"]).lower()
            if spec not in _DTYPE_NAMES:
                raise ScheduleError(
                    f"schedule compute_dtype must be one of "
                    f"{sorted(k for k in _DTYPE_NAMES if k)}, "
                    f"got {d['compute_dtype']!r}")
            d["compute_dtype"] = _DTYPE_NAMES[spec]
        return cls(**d)


def _check_tile(name: str, value: int, *, lane: bool, interpret: bool,
                kernel: str) -> None:
    if value <= 0 or value % SUBLANE:
        raise ScheduleError(
            f"{kernel}: tile {name}={value} must be a positive multiple of "
            f"{SUBLANE} (the f32 sublane count)")
    if lane and not interpret and value % LANE:
        raise ScheduleError(
            f"{kernel}: tile {name}={value} must be a multiple of {LANE} "
            f"(the TPU lane width) for the compiled path; pass "
            f"interpret=True to relax, or pick {name} from "
            f"{{128, 256, 512, ...}}")


@dataclasses.dataclass(frozen=True)
class KernelSpec:
    """Per-kernel schedule contract: defaults, supported knobs, models.

    ``shape_dims`` names the shape keywords the models take (and, prefixed
    subset ``bucket_dims``, the ones that key the schedule cache — batch
    width ``b`` is deliberately NOT bucketed so one tuned schedule serves
    every matmat width).  All byte models are f32-per-element: the bf16
    compute_dtype cast happens in-register, after the VMEM load.
    """
    name: str
    default: "Schedule"
    shape_dims: tuple
    bucket_dims: tuple
    reduces: bool                     # output revisited across grid dim 1
    has_bn: bool = True
    has_compute_dtype: bool = False
    # models: fn(schedule, **shape) -> bytes / flops
    vmem_model: Optional[Callable[..., int]] = None
    flops_model: Optional[Callable[..., int]] = None
    bytes_model: Optional[Callable[..., int]] = None

    def check(self, s: "Schedule", **shape) -> "Schedule":
        """Validate a fully-resolved schedule for this kernel (+ shape,
        when given, for the VMEM model).  Returns ``s`` for chaining."""
        interp = bool(s.interpret) if s.interpret is not None else False
        if s.bm is None or (self.has_bn and s.bn is None):
            raise ScheduleError(f"{self.name}: schedule tiles not resolved "
                                f"(bm={s.bm}, bn={s.bn})")
        _check_tile("bm", s.bm, lane=False, interpret=interp,
                    kernel=self.name)
        if self.has_bn:
            _check_tile("bn", s.bn, lane=True, interpret=interp,
                        kernel=self.name)
        elif s.bn is not None and s.bn != self.default.bn:
            raise ScheduleError(f"{self.name} has no bn tile (1-D grid); "
                                f"got bn={s.bn}")
        if s.grid_order not in GRID_ORDERS:
            raise ScheduleError(f"{self.name}: grid_order must be one of "
                                f"{GRID_ORDERS}, got {s.grid_order!r}")
        if s.grid_order == "col-major" and self.reduces:
            raise ScheduleError(
                f"{self.name}: grid_order='col-major' is illegal for a "
                f"reducing kernel — the output row tile is accumulated "
                f"across the column grid dimension, which must stay "
                f"innermost")
        if s.acc not in ACCS:
            raise ScheduleError(f"{self.name}: acc must be one of {ACCS}, "
                                f"got {s.acc!r}")
        if s.acc == "scratch" and not self.reduces:
            raise ScheduleError(
                f"{self.name}: acc='scratch' is only meaningful for "
                f"reducing kernels (this kernel writes each output tile "
                f"exactly once)")
        if s.compute_dtype is not None and not self.has_compute_dtype:
            raise ScheduleError(
                f"{self.name} has no compute_dtype knob (its products are "
                f"always f32); got compute_dtype={s.compute_dtype!r}")
        if shape and self.vmem_model is not None:
            need = self.vmem_model(s, **shape)
            if need > VMEM_BYTES:
                raise ScheduleError(
                    f"{self.name}: schedule bm={s.bm} bn={s.bn} needs "
                    f"{need} bytes of VMEM per grid cell at shape {shape}, "
                    f"over the {VMEM_BYTES} budget (tiles are "
                    f"double-buffered); shrink the tiles")
        return s


# -- per-kernel VMEM / FLOPs / bytes models ---------------------------------
# Shapes use the kernels' own letters: n/m point counts, d feature dim,
# b block width, k centers.  f32 = 4 bytes everywhere (see KernelSpec).

def _rbf_vmem(s, *, n, m, d):
    return (s.bm * d + s.bn * d + s.bm * s.bn) * 4


def _rbf_flops(s, *, n, m, d):
    return n * m * (2 * d + 4)        # |x|^2+|y|^2-2xy + exp per entry


def _rbf_bytes(s, *, n, m, d):
    cells = -(-n // s.bm) * (-(-m // s.bn))
    return cells * (s.bm + s.bn) * d * 4 + n * m * 4


def _fused_vmem(s, *, n, m, d, b=8):
    acc = s.bm * b if s.acc == "scratch" else 0
    return (s.bm * d + s.bn * d + s.bn * b + s.bm * s.bn
            + s.bm * b + s.bm + s.bn + acc) * 4


def _fused_flops(s, *, n, m, d, b=8):
    return n * m * (2 * d + 4 + 2 * b)


def _fused_bytes(s, *, n, m, d, b=8):
    from repro.kernels.fused_rbf_matmat import pass_bytes
    return pass_bytes(n, m, d, b, bm=s.bm, bn=s.bn)


def _matmat_vmem(s, *, n, m, b=8):
    acc = s.bm * b if s.acc == "scratch" else 0
    return (s.bm * s.bn + s.bn * b + s.bm * b + acc) * 4


def _matmat_flops(s, *, n, m, b=8):
    return 2 * n * m * b


def _matmat_bytes(s, *, n, m, b=8):
    rows = -(-n // s.bm)
    return n * m * 4 + rows * m * b * 4 + n * b * 4


def _assign_vmem(s, *, n, d, k=8):
    return (s.bm * d + k * d + s.bm * k + 2 * s.bm) * 4


def _assign_flops(s, *, n, d, k=8):
    return n * k * (2 * d + 2)


def _assign_bytes(s, *, n, d, k=8):
    rows = -(-n // s.bm)
    return n * d * 4 + rows * k * d * 4 + n * 8


KERNELS: dict[str, KernelSpec] = {}


def _register(spec: KernelSpec) -> KernelSpec:
    KERNELS[spec.name] = spec
    return spec


_register(KernelSpec(
    name="rbf_similarity",
    default=Schedule(bm=128, bn=128),
    shape_dims=("n", "m", "d"), bucket_dims=("n", "m", "d"),
    reduces=False,
    vmem_model=_rbf_vmem, flops_model=_rbf_flops, bytes_model=_rbf_bytes))

_register(KernelSpec(
    name="fused_rbf_matmat",
    default=Schedule(bm=128, bn=128),
    shape_dims=("n", "m", "d", "b"), bucket_dims=("n", "m", "d"),
    reduces=True, has_compute_dtype=True,
    vmem_model=_fused_vmem, flops_model=_fused_flops,
    bytes_model=_fused_bytes))

_register(KernelSpec(
    name="fused_nystrom_matmat",
    default=Schedule(bm=128, bn=128),
    shape_dims=("n", "m", "d", "b"), bucket_dims=("n", "m", "d"),
    reduces=True, has_compute_dtype=True,
    vmem_model=_fused_vmem, flops_model=_fused_flops,
    bytes_model=_fused_bytes))

_register(KernelSpec(
    name="block_matmat",
    default=Schedule(bm=256, bn=512),
    shape_dims=("n", "m", "b"), bucket_dims=("n", "m"),
    reduces=True,
    vmem_model=_matmat_vmem, flops_model=_matmat_flops,
    bytes_model=_matmat_bytes))

_register(KernelSpec(
    name="kmeans_assign",
    default=Schedule(bm=512),
    shape_dims=("n", "d", "k"), bucket_dims=("n", "d"),
    reduces=False, has_bn=False,
    vmem_model=_assign_vmem, flops_model=_assign_flops,
    bytes_model=_assign_bytes))


def spec(kernel: str) -> KernelSpec:
    try:
        return KERNELS[kernel]
    except KeyError:
        raise ScheduleError(
            f"unknown kernel {kernel!r}; schedulable kernels are "
            f"{sorted(KERNELS)}") from None


def as_schedule(value: Any) -> Optional["Schedule"]:
    """Normalize a user-facing schedule value: None / "default" -> None
    (use call-site defaults), a dict -> Schedule, a Schedule passes
    through.  The "auto" string is handled by :func:`resolve` (it needs
    the kernel/shape for the cache lookup)."""
    if value is None or value == "default":
        return None
    if isinstance(value, Schedule):
        return value
    if isinstance(value, dict):
        return Schedule.from_dict(value)
    raise ScheduleError(
        f"schedule must be None, 'default', 'auto', a Schedule or a dict "
        f"of Schedule fields, got {value!r}")


def validate_spec(value: Any) -> Any:
    """Eager constructor-time validation (estimator kwarg): accepts the
    full user-facing domain including "auto"; returns the value."""
    if value == "auto":
        return value
    as_schedule(value)
    return value


def resolve(kernel: str, schedule: Any = None, *, bm: Optional[int] = None,
            bn: Optional[int] = None, compute_dtype: Any = None,
            interpret: Optional[bool] = None,
            **shape) -> tuple["Schedule", str]:
    """Turn a user-facing schedule value + call-site keywords into one
    concrete, legality-checked :class:`Schedule`.

    Returns ``(schedule, source)`` where source is "default" (built from
    the call-site keywords — the pre-schedule behavior, bit-for-bit),
    "explicit" (caller passed a Schedule/dict), "cache" ("auto" hit the
    persistent cache) or "auto-default" ("auto" missed — the default
    schedule runs, and the miss is visible in the cache stats).
    """
    sp = spec(kernel)
    if isinstance(compute_dtype, str):
        compute_dtype = _DTYPE_NAMES.get(compute_dtype.lower(),
                                         compute_dtype)
    elif compute_dtype is not None:
        import jax.numpy as jnp
        compute_dtype = jnp.dtype(compute_dtype).name
    fallback = Schedule(
        bm=bm if bm is not None else sp.default.bm,
        bn=(bn if bn is not None else sp.default.bn) if sp.has_bn else None,
        compute_dtype=compute_dtype if sp.has_compute_dtype else None,
        interpret=interpret)

    source = "default"
    if schedule == "auto":
        from repro import obs
        from repro.tune.cache import default_cache
        cache = default_cache()
        cached = cache.get(
            kernel, dtype=compute_dtype or "float32",
            **{k: v for k, v in shape.items() if k in sp.bucket_dims})
        if cached is None:
            s, source = fallback, "auto-default"
        else:
            s, source = cached, "cache"
        obs.absorb_stats("tune.cache", cache.stats)
    else:
        s = as_schedule(schedule)
        if s is None:
            s = fallback
        else:
            source = "explicit"
    # fill unset fields from the call site (partial schedules only
    # override what they name)
    s = s.replace(
        bm=s.bm if s.bm is not None else fallback.bm,
        bn=(s.bn if s.bn is not None else fallback.bn) if sp.has_bn
        else s.bn,
        compute_dtype=s.compute_dtype if s.compute_dtype is not None
        else fallback.compute_dtype,
        interpret=s.interpret if s.interpret is not None else interpret)
    if s.interpret is None:
        from repro.kernels.block_matvec import interpret_default
        s = s.replace(interpret=interpret_default())
    sp.check(s, **{k: v for k, v in shape.items() if k in sp.shape_dims})
    return s, source
