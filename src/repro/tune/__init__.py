"""Kernel schedule layer + autotuner (the SYS_ATL/Exo separation).

``Schedule`` is the searchable half of every Pallas kernel: tile sizes,
compute dtype, grid iteration order, accumulator placement and the
interpret flag, with per-kernel legality checks (``KERNELS`` specs).
``ScheduleCache`` persists the best-known schedule per (kernel, shape
bucket, device kind, dtype) as one JSON file; ``autotune`` /
``tune_all`` fill it by timing real kernel calls and scoring them
against the roofline peak model (``benchmarks/roofline.py``).

Entry points:
  * ``ops.<kernel>(..., schedule=...)`` — None (defaults), "auto"
    (cache), or an explicit Schedule/dict.
  * ``SpectralClustering(schedule="auto")`` — the fused affinity and
    serving paths consult the cache; the chosen schedule lands in
    ``info_``.
  * ``python benchmarks/run.py tune_sweep [--quick]`` — sweep + cache
    write + BENCH_tune.json.
"""
from repro.tune.autotune import autotune, candidates, tune_all
from repro.tune.cache import (ScheduleCache, bucket, cache_key,
                              default_cache, default_cache_path,
                              device_kind)
from repro.tune.schedule import (KERNELS, KernelSpec, Schedule,
                                 ScheduleError, as_schedule, resolve, spec,
                                 validate_spec)

__all__ = [
    "KERNELS", "Schedule", "ScheduleError", "KernelSpec", "as_schedule",
    "resolve", "spec", "validate_spec", "ScheduleCache", "bucket",
    "cache_key", "default_cache", "default_cache_path", "device_kind",
    "autotune", "candidates", "tune_all",
]
