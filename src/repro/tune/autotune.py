"""Schedule autotuner: sweep legal candidates per (kernel, shape bucket,
device), timing REAL kernel calls through the public ``ops`` wrappers,
score them against the roofline peak model, and persist the winner in the
schedule cache.

The candidate grid is small on purpose (tile edges from the MXU-multiple
ladder, accumulator placement, grid order): the point is not exhaustive
search but moving each kernel from "whatever 128/256 guess was hard-coded"
to "the best of the legal ladder for THIS shape on THIS device".  The
default schedule is always among the candidates, so the tuned pick can
never regress it (up to timing noise — winners are best-of-``iters``).

``autotune`` returns a full report (every candidate with wall time and
achieved-vs-peak FLOPs/bytes via ``benchmarks/roofline.py``); ``tune_all``
sweeps the standard kernel set.  A cache hit short-circuits the sweep
unless ``force=True`` — re-running a sweep is free once tuned.
"""
from __future__ import annotations

import time
from typing import Any, Optional

import jax
import jax.numpy as jnp

from repro.tune.cache import ScheduleCache, bucket, default_cache
from repro.tune.schedule import Schedule, ScheduleError, spec

# tile-edge ladder: MXU/lane multiples only (every entry legal compiled)
TILE_LADDER = (128, 256, 512)
QUICK_TILES = (128, 256)

# the standard sweep set: every schedulable kernel with a nominal shape
# builder (n is the sweep variable; d/b/k are the repo's workhorse sizes)
SWEEP_KERNELS = ("rbf_similarity", "fused_rbf_matmat",
                 "fused_nystrom_matmat", "block_matmat", "kmeans_assign")


def _kernel_shape(kernel: str, n: int, *, d: int = 8, b: int = 8,
                  k: int = 8) -> dict:
    return {
        "rbf_similarity": {"n": n, "m": n, "d": d},
        "fused_rbf_matmat": {"n": n, "m": n, "d": d, "b": b},
        "fused_nystrom_matmat": {"n": n, "m": n, "d": d, "b": b},
        "block_matmat": {"n": n, "m": n, "b": b},
        "kmeans_assign": {"n": n, "d": d, "k": k},
    }[kernel]


def candidates(kernel: str, *, quick: bool = False,
               compute_dtype: Optional[str] = None,
               interpret: Optional[bool] = None, **shape) -> list:
    """Legal schedule candidates for one kernel/shape (default included,
    always first).  Tiles larger than the padded problem edge are skipped
    (they only add padding work); illegal combinations are filtered by the
    spec's own legality check."""
    sp = spec(kernel)
    tiles = QUICK_TILES if quick else TILE_LADDER
    n_cap = bucket(int(shape.get("n", tiles[-1])))
    m_cap = bucket(int(shape.get("m", tiles[-1])))
    bms = sorted({t for t in tiles if t <= max(n_cap, tiles[0])})
    bns = sorted({t for t in tiles if t <= max(m_cap, tiles[0])}) \
        if sp.has_bn else [None]
    accs = ("inplace",) if (quick or not sp.reduces) \
        else ("inplace", "scratch")
    orders = ("row-major",) if (sp.reduces or not sp.has_bn or quick) \
        else ("row-major", "col-major")

    base = sp.default.replace(
        compute_dtype=compute_dtype if sp.has_compute_dtype else None,
        interpret=interpret)
    out = [base]
    for bm in bms:
        for bn in bns:
            for acc in accs:
                for order in orders:
                    s = base.replace(bm=bm, bn=bn, acc=acc, grid_order=order)
                    if s in out:
                        continue
                    try:
                        sp.check(s.replace(
                            interpret=s.interpret if s.interpret is not None
                            else True), **shape)
                    except ScheduleError:
                        continue
                    out.append(s)
    return out


def _bench_fn(kernel: str, **shape):
    """A closure running one real call of the kernel's public wrapper on
    synthetic data of the given shape (data built once, outside timing)."""
    from repro.kernels import ops

    def rand(shp, seed):
        return jax.random.normal(jax.random.PRNGKey(seed), shp, jnp.float32)

    n, m = shape.get("n", 0), shape.get("m", 0)
    d, b, k = shape.get("d", 8), shape.get("b", 8), shape.get("k", 8)
    if kernel == "rbf_similarity":
        x, y = rand((n, d), 0), rand((m, d), 1)
        return lambda s: ops.rbf_similarity(x, y, 1.0, schedule=s)
    if kernel == "fused_rbf_matmat":
        x, y, V = rand((n, d), 0), rand((m, d), 1), rand((m, b), 2)
        return lambda s: ops.fused_rbf_matmat(x, y, V, 1.0, schedule=s)
    if kernel == "fused_nystrom_matmat":
        x, y, V = rand((m, d), 0), rand((n, d), 1), rand((n, b), 2)
        cs = jnp.ones((n,), jnp.float32)
        return lambda s: ops.fused_nystrom_matmat(x, y, V, 1.0, cs,
                                                  schedule=s)[0]
    if kernel == "block_matmat":
        A, V = rand((n, m), 0), rand((m, b), 1)
        return lambda s: ops.block_matmat(A, V, schedule=s)
    if kernel == "kmeans_assign":
        p, c = rand((n, d), 0), rand((k, d), 1)
        return lambda s: ops.kmeans_assign(p, c, schedule=s)[1]
    raise ScheduleError(f"no benchmark harness for kernel {kernel!r}")


def _time(fn, s: Schedule, *, warmup: int, iters: int) -> float:
    for _ in range(warmup):
        jax.block_until_ready(fn(s))
    best = float("inf")
    for _ in range(iters):
        t0 = time.perf_counter()
        jax.block_until_ready(fn(s))
        best = min(best, time.perf_counter() - t0)
    return best * 1e6


def _roofline_mod():
    try:
        from benchmarks import roofline
        return roofline
    except ImportError:
        pass
    try:  # repo-layout fallback: src/repro/tune -> repo root/benchmarks
        import importlib.util
        import os
        here = os.path.dirname(os.path.abspath(__file__))
        path = os.path.join(here, "..", "..", "..", "benchmarks",
                            "roofline.py")
        s = importlib.util.spec_from_file_location("_repro_roofline",
                                                   os.path.normpath(path))
        mod = importlib.util.module_from_spec(s)
        s.loader.exec_module(mod)
        return mod
    except Exception:
        return None


def autotune(kernel: str, n: int, *, d: int = 8, b: int = 8, k: int = 8,
             compute_dtype: Optional[str] = None,
             cache: Optional[ScheduleCache] = None, quick: bool = False,
             force: bool = False, warmup: int = 1, iters: int = 3,
             log: Any = None) -> dict:
    """Tune one kernel at one shape; returns the report dict and stores
    the winner in ``cache`` (default: the process cache).

    Report: ``{"kernel", "shape", "cache_hit", "default_us", "best_us",
    "speedup", "best" (schedule dict), "rows": [per-candidate {schedule,
    wall_us, gflops, frac_peak_flops, gbs, frac_peak_bytes}]}``.
    """
    from repro import obs

    cache = cache or default_cache()
    shape = _kernel_shape(kernel, n, d=d, b=b, k=k)
    dtype = compute_dtype or "float32"
    sp = spec(kernel)

    with obs.span("tune.autotune", kernel=kernel, n=n) as sp_tune:
        if not force:
            hit = cache.entry(kernel, dtype=dtype, **shape)
            if hit is not None:
                rep = {"kernel": kernel, "shape": shape, "cache_hit": True,
                       "best": hit["schedule"],
                       "best_us": hit.get("wall_us"),
                       "default_us": hit.get("default_wall_us"), "rows": []}
                if log:
                    log(f"tune/{kernel}_n{n}: cache_hit=True "
                        f"schedule={hit['schedule']}")
                sp_tune.set(cache_hit=True)
                obs.absorb_stats("tune.cache", cache.stats)
                return rep

        fn = _bench_fn(kernel, **shape)
        cands = candidates(kernel, quick=quick, compute_dtype=compute_dtype,
                           **shape)
        roofline = _roofline_mod()
        if quick:
            iters = 1
        rows, default_us = [], None
        for s in cands:
            wall_us = _time(fn, s, warmup=warmup, iters=iters)
            rec = {"schedule": s.to_dict(), "wall_us": round(wall_us, 1)}
            if roofline is not None and sp.flops_model and sp.bytes_model:
                rec.update(roofline.kernel_roofline(
                    sp.flops_model(s, **shape), sp.bytes_model(s, **shape),
                    wall_us * 1e-6))
            rows.append(rec)
            if default_us is None:
                default_us = wall_us        # candidate 0 IS the default
            if log:
                log(f"tune/{kernel}_n{n}: bm={s.bm} bn={s.bn} acc={s.acc} "
                    f"order={s.grid_order} -> {wall_us:.0f}us")
        best_i = min(range(len(rows)), key=lambda i: rows[i]["wall_us"])
        best = cands[best_i]
        best_us = rows[best_i]["wall_us"]
        cache.put(kernel, best, dtype=dtype, wall_us=best_us,
                  default_wall_us=default_us, **shape)
        sp_tune.set(cache_hit=False, candidates=len(cands))
        obs.counter("tune.candidates_timed").inc(len(cands))
        obs.absorb_stats("tune.cache", cache.stats)
    return {"kernel": kernel, "shape": shape, "cache_hit": False,
            "default_us": round(default_us, 1),
            "best_us": round(best_us, 1),
            "speedup": round(default_us / max(best_us, 1e-9), 3),
            "best": best.to_dict(), "rows": rows}


def tune_all(ns=(1024, 4096), *, kernels=SWEEP_KERNELS, d: int = 8,
             b: int = 8, k: int = 8, cache: Optional[ScheduleCache] = None,
             quick: bool = False, force: bool = False,
             log: Any = None) -> list:
    """The standard sweep: every schedulable kernel at each n.  Returns
    the list of :func:`autotune` reports (cache hits included)."""
    reports = []
    for kernel in kernels:
        for n in ns:
            reports.append(autotune(kernel, n, d=d, b=b, k=k, cache=cache,
                                    quick=quick, force=force, log=log))
    return reports
