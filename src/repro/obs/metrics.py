"""Process-wide metrics registry: named counters, gauges, and fixed-bucket
histograms, with labeled children and a JSON snapshot API.

The Hadoop analogue is the per-job counter page: every subsystem's numbers
land in ONE namespace instead of six ad-hoc ``stats`` dicts::

    from repro import obs

    obs.counter("engine.map_tasks").inc(12)
    obs.gauge("serve.fill").set(0.97)
    obs.histogram("serve.request_ms").observe(3.4)
    obs.histogram("serve.request_ms", model="blobs").observe(2.1)  # labeled
    obs.metrics.snapshot()     # {"engine.map_tasks": {"type": "counter", ...}}

:func:`absorb_stats` is the adapter for the repo's existing ad-hoc stats
dicts (shard-store spills, prefetch hits, schedule-cache hits, fused-rbf
``matrix_passes``/``bytes_streamed``): it upserts each numeric value as an
absolute counter/gauge under a prefix, idempotently — re-absorbing a live
dict updates rather than double-counts.

Histogram percentiles serve the latency SLO path (p50/p95/p99): exact
nearest-rank over retained samples up to ``sample_cap`` observations, then
a fixed-bucket upper-edge estimate — both monotone, both safe on n=1.
"""
from __future__ import annotations

import json
import math
import os
import threading
from bisect import bisect_left
from typing import Any, Dict, List, Optional, Sequence

# default histogram edges: a geometric ms ladder covering sub-ms kernel
# calls through minute-scale fits (finite edges; +inf overflow is implicit)
DEFAULT_BUCKETS_MS = (0.1, 0.25, 0.5, 1.0, 2.5, 5.0, 10.0, 25.0, 50.0,
                      100.0, 250.0, 500.0, 1000.0, 2500.0, 5000.0, 10000.0,
                      30000.0, 60000.0)


def nearest_rank(sorted_values: Sequence[float], q: float) -> float:
    """The nearest-rank percentile (q in [0, 100]) of an ascending
    sequence: the ceil(q/100 * n)-th smallest value, 1-indexed — exact on
    small n, no interpolation, no off-by-one (p50 of [a, b] is ``a``,
    p100 is the max, p0 the min)."""
    n = len(sorted_values)
    if n == 0:
        return 0.0
    rank = max(1, math.ceil((q / 100.0) * n))
    return float(sorted_values[min(rank, n) - 1])


class Counter:
    """Monotone event count.  ``set_to`` exists for the absorb adapter
    (re-publishing an external cumulative stat) and clamps to >= current
    only in spirit — absorb semantics are absolute."""

    __slots__ = ("name", "_value", "_lock")
    kind = "counter"

    def __init__(self, name: str):
        self.name = name
        self._value = 0
        self._lock = threading.Lock()

    def inc(self, v: float = 1) -> None:
        with self._lock:
            self._value += v

    def set_to(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self._value}


class Gauge:
    """Last-write-wins instantaneous value."""

    __slots__ = ("name", "_value", "_lock")
    kind = "gauge"

    def __init__(self, name: str):
        self.name = name
        self._value = 0.0
        self._lock = threading.Lock()

    def set(self, v: float) -> None:
        with self._lock:
            self._value = v

    @property
    def value(self):
        return self._value

    def snapshot(self) -> Dict[str, Any]:
        return {"type": self.kind, "value": self._value}


class Histogram:
    """Fixed-bucket histogram with exact small-n percentiles.

    Observations land in cumulative-style bucket counts (``buckets`` are
    ascending finite upper edges; an implicit +inf bucket catches the
    rest).  The first ``sample_cap`` raw values are retained so
    ``percentile`` is EXACT nearest-rank until the reservoir fills —
    serving runs and tests live well under the cap; beyond it the estimate
    degrades gracefully to the containing bucket's upper edge."""

    __slots__ = ("name", "buckets", "sample_cap", "_counts", "_samples",
                 "_sorted", "_count", "_sum", "_min", "_max", "_lock")
    kind = "histogram"

    def __init__(self, name: str, buckets: Sequence[float] = None,
                 sample_cap: int = 8192):
        self.name = name
        self.buckets = tuple(sorted(buckets or DEFAULT_BUCKETS_MS))
        self.sample_cap = sample_cap
        self._counts = [0] * (len(self.buckets) + 1)   # +1: overflow
        self._samples: List[float] = []
        self._sorted = True
        self._count = 0
        self._sum = 0.0
        self._min = math.inf
        self._max = -math.inf
        self._lock = threading.Lock()

    def observe(self, v: float) -> None:
        v = float(v)
        with self._lock:
            self._counts[bisect_left(self.buckets, v)] += 1
            self._count += 1
            self._sum += v
            self._min = min(self._min, v)
            self._max = max(self._max, v)
            if len(self._samples) < self.sample_cap:
                self._samples.append(v)
                self._sorted = False

    @property
    def count(self) -> int:
        return self._count

    def percentile(self, q: float) -> float:
        """q in [0, 100].  Exact nearest-rank while every observation is
        retained; bucket-upper-edge estimate after the reservoir fills."""
        with self._lock:
            if self._count == 0:
                return 0.0
            if self._count <= len(self._samples):
                if not self._sorted:
                    self._samples.sort()
                    self._sorted = True
                return nearest_rank(self._samples, q)
            rank = max(1, math.ceil((q / 100.0) * self._count))
            cum = 0
            for i, c in enumerate(self._counts):
                cum += c
                if cum >= rank:
                    return self.buckets[i] if i < len(self.buckets) \
                        else self._max
            return self._max

    def snapshot(self) -> Dict[str, Any]:
        with self._lock:
            count, total = self._count, self._sum
            lo = self._min if count else 0.0
            hi = self._max if count else 0.0
            counts = list(self._counts)
        return {"type": self.kind, "count": count,
                "sum": round(total, 6), "min": lo, "max": hi,
                "p50": self.percentile(50), "p95": self.percentile(95),
                "p99": self.percentile(99),
                "buckets": {("+inf" if i == len(self.buckets)
                             else str(self.buckets[i])): c
                            for i, c in enumerate(counts) if c}}


def _labeled(name: str, labels: Dict[str, Any]) -> str:
    if not labels:
        return name
    inner = ",".join(f"{k}={labels[k]}" for k in sorted(labels))
    return f"{name}{{{inner}}}"


class MetricsRegistry:
    """Name -> metric map.  ``counter``/``gauge``/``histogram`` get or
    create; a name can hold only one metric type (a mismatch raises).
    Labeled children are separate metrics keyed ``name{k=v,...}``."""

    def __init__(self, enabled: bool = True):
        self.enabled = enabled
        self._metrics: "Dict[str, Any]" = {}
        self._lock = threading.Lock()

    def _get(self, cls, name: str, labels: Dict[str, Any], **kw):
        key = _labeled(name, labels)
        with self._lock:
            m = self._metrics.get(key)
            if m is None:
                m = self._metrics[key] = cls(key, **kw)
            elif not isinstance(m, cls):
                raise TypeError(f"metric {key!r} is a {m.kind}, not a "
                                f"{cls.kind}")
            return m

    def counter(self, name: str, **labels) -> Counter:
        return self._get(Counter, name, labels)

    def gauge(self, name: str, **labels) -> Gauge:
        return self._get(Gauge, name, labels)

    def histogram(self, name: str, *, buckets: Sequence[float] = None,
                  sample_cap: int = 8192, **labels) -> Histogram:
        return self._get(Histogram, name, labels, buckets=buckets,
                         sample_cap=sample_cap)

    def get(self, key: str):
        """Look up an existing metric by its full (labeled) key."""
        with self._lock:
            return self._metrics.get(key)

    def absorb_stats(self, prefix: str, stats: Dict[str, Any]) -> None:
        """Adapter for ad-hoc stats dicts: each numeric value upserts the
        metric ``<prefix>.<key>`` ABSOLUTELY — ints become counters set to
        the value, floats become gauges — so re-absorbing a live dict
        (engine store counters keep moving during an eigensolve) updates
        in place instead of double-counting.  Non-numeric values are
        skipped (they belong in span attributes, not metrics)."""
        if not self.enabled or not stats:
            return
        for k, v in stats.items():
            if hasattr(v, "item") and not isinstance(v, (bool, int, float,
                                                         str)):
                try:                 # numpy/jax scalar -> python scalar
                    v = v.item()
                except Exception:
                    continue
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            name = f"{prefix}.{k}"
            if isinstance(v, int):
                self.counter(name).set_to(v)
            else:
                self.gauge(name).set(v)

    # -- snapshot / export ---------------------------------------------------

    def snapshot(self, prefix: str = "") -> Dict[str, Dict[str, Any]]:
        with self._lock:
            items = sorted(self._metrics.items())
        return {k: m.snapshot() for k, m in items if k.startswith(prefix)}

    def to_json(self, path: Optional[str] = None, prefix: str = "") -> str:
        """Serialize the snapshot; atomically written to ``path`` when
        given.  Round-trips: ``json.loads(reg.to_json())`` equals
        ``reg.snapshot()``."""
        text = json.dumps(self.snapshot(prefix), indent=2, sort_keys=True)
        if path is not None:
            d = os.path.dirname(os.path.abspath(path))
            os.makedirs(d, exist_ok=True)
            tmp = f"{path}.tmp.{os.getpid()}"
            with open(tmp, "w") as f:
                f.write(text)
            os.replace(tmp, path)
        return text

    def reset(self) -> None:
        with self._lock:
            self._metrics.clear()
