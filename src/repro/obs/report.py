"""Surfacing helpers: the ``info_["obs"]`` schema, the ``[obs]`` one-line
phase summary, and the CLI artifact writer.

``fit_obs`` turns the estimator's phase spans into the stable dict every
fit publishes (see API.md "Observability")::

    {"wall_s": 1.23,
     "coverage": 0.98,                     # phase wall / total wall
     "phases": {"affinity":   {"wall_s": 0.45, "frac": 0.37},
                "eigensolve": {"wall_s": 0.61, "frac": 0.50},
                "assign":     {"wall_s": 0.12, "frac": 0.10}},
     "counters": {"matrix_passes": 17, ...}}

``phase_summary`` renders that dict as the end-of-run ``[obs]`` line the
CLIs print (and the CI obs-smoke job greps).
"""
from __future__ import annotations

from typing import Any, Dict, Optional


def fit_obs(total_span, phase_spans: Dict[str, Any],
            counters: Optional[Dict[str, Any]] = None) -> Dict[str, Any]:
    """Assemble ``info_["obs"]`` from one finished parent span and its
    finished phase spans.  Coverage is the fraction of the parent's wall
    the (non-overlapping) phases account for — the acceptance gate is
    >= 0.95 on every fit path."""
    total = max(total_span.duration_s, 1e-12)
    phases = {}
    covered = 0.0
    for name, sp in phase_spans.items():
        d = sp.duration_s
        covered += d
        phases[name] = {"wall_s": round(d, 6), "frac": round(d / total, 4)}
    out: Dict[str, Any] = {
        "wall_s": round(total_span.duration_s, 6),
        "coverage": round(min(covered / total, 1.0), 4),
        "phases": phases,
    }
    if counters:
        out["counters"] = {k: v for k, v in counters.items()
                           if isinstance(v, (int, float))
                           and not isinstance(v, bool)}
    return out


def phase_summary(obs_info: Dict[str, Any], tag: str = "fit") -> str:
    """One ``[obs]`` line: total wall, per-phase wall + share, coverage."""
    parts = [f"[obs] {tag}={obs_info.get('wall_s', 0.0):.3f}s"]
    for name, ph in obs_info.get("phases", {}).items():
        parts.append(f"{name}={ph['wall_s']:.3f}s({ph['frac']:.0%})")
    parts.append(f"coverage={obs_info.get('coverage', 0.0):.0%}")
    counters = obs_info.get("counters") or {}
    if "matrix_passes" in counters:
        parts.append(f"matrix_passes={counters['matrix_passes']}")
    return " ".join(parts)


def write_artifacts(trace_out: Optional[str] = None,
                    metrics_out: Optional[str] = None,
                    tracer=None, registry=None) -> None:
    """CLI tail shared by ``spectral_job`` and ``cluster_serve``: export
    the Chrome trace and/or the metrics snapshot when the flags were
    given, printing where each landed."""
    from repro.obs import metrics as default_metrics
    from repro.obs import tracer as default_tracer

    if trace_out:
        (tracer or default_tracer).export(trace_out)
        print(f"[obs] trace -> {trace_out} (open in chrome://tracing)")
    if metrics_out:
        (registry or default_metrics).to_json(metrics_out)
        print(f"[obs] metrics -> {metrics_out}")
