"""Hierarchical tracing spans with a Chrome-trace exporter.

The paper's Hadoop pipeline is legible because every stage is a named job
with counters; this module gives the jax_pallas reproduction the same
property.  A :class:`Span` is one named, timed region::

    from repro import obs

    with obs.span("fit.affinity", backend="fused-rbf") as sp:
        op = build(...)            # sp.duration_s after exit

    @obs.traced("engine.map")
    def run_map_task(...): ...

Spans nest through a thread-local stack (each thread has its own), use
monotonic clocks (``time.perf_counter``), and carry arbitrary JSON-able
attributes.  Finished spans accumulate in a process-wide :class:`Tracer`
and export as Chrome-trace / Perfetto JSON (``obs.export_trace(path)``)
viewable at ``chrome://tracing`` or https://ui.perfetto.dev.

When ``jax.profiler`` is importable, every span also enters a
``TraceAnnotation`` so the same region names appear inside XLA/perfetto
device profiles — purely best-effort, the module has NO required
dependencies beyond the stdlib.
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Any, Callable, Dict, List, Optional

try:  # optional pass-through into XLA profiles; never required
    from jax.profiler import TraceAnnotation as _JaxAnnotation
except Exception:  # pragma: no cover - jax absent or too old
    _JaxAnnotation = None


class Span:
    """One named, timed region.  ``t0``/``t1`` are perf_counter seconds
    relative to the owning tracer's epoch; ``t1`` is None while open."""

    __slots__ = ("name", "attrs", "t0", "t1", "tid", "depth", "_ann")

    def __init__(self, name: str, attrs: Dict[str, Any], t0: float,
                 tid: int, depth: int):
        self.name = name
        self.attrs = attrs
        self.t0 = t0
        self.t1: Optional[float] = None
        self.tid = tid
        self.depth = depth
        self._ann = None

    @property
    def duration_s(self) -> float:
        return (self.t1 if self.t1 is not None else self.t0) - self.t0

    def set(self, **attrs) -> "Span":
        """Attach attributes to an open (or finished) span."""
        self.attrs.update(attrs)
        return self

    def __repr__(self) -> str:
        state = f"{self.duration_s * 1e3:.2f}ms" if self.t1 is not None \
            else "open"
        return f"Span({self.name!r}, {state}, depth={self.depth})"


class _NullSpan:
    """Returned while tracing is disabled: accepts the same calls, records
    nothing (the <=2% overhead contract of BENCH_obs.json)."""

    name = ""
    t0 = t1 = 0.0
    depth = 0
    duration_s = 0.0
    attrs: Dict[str, Any] = {}

    def set(self, **attrs):
        return self

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        return False


_NULL = _NullSpan()


class _SpanCtx:
    """Context manager binding one Span to one tracer (also what the
    ``traced`` decorator runs around each call)."""

    __slots__ = ("_tracer", "_name", "_attrs", "_span")

    def __init__(self, tracer: "Tracer", name: str, attrs: Dict[str, Any]):
        self._tracer = tracer
        self._name = name
        self._attrs = attrs
        self._span: Optional[Span] = None

    def __enter__(self) -> Span:
        self._span = self._tracer._push(self._name, self._attrs)
        return self._span

    def __exit__(self, exc_type, exc, tb) -> bool:
        if exc_type is not None:
            self._span.attrs.setdefault("error", exc_type.__name__)
        self._tracer._pop(self._span)
        return False


class Tracer:
    """Thread-safe collector of finished spans.

    One process-wide instance (``repro.obs.tracer``) backs the module-level
    ``span``/``traced``/``export_trace`` helpers; tests may build private
    tracers.  The epoch is captured at construction (and on ``reset``), so
    exported timestamps always start near zero.
    """

    def __init__(self, enabled: bool = True, jax_annotations: bool = True):
        self.enabled = enabled
        self.jax_annotations = jax_annotations
        self._lock = threading.Lock()
        self._events: List[Span] = []
        self._tls = threading.local()
        self.epoch = time.perf_counter()

    # -- span lifecycle -----------------------------------------------------

    def _stack(self) -> List[Span]:
        st = getattr(self._tls, "stack", None)
        if st is None:
            st = self._tls.stack = []
        return st

    def span(self, name: str, **attrs) -> Any:
        """Open a span: ``with tracer.span("fit.affinity") as sp: ...``."""
        if not self.enabled:
            return _NULL
        return _SpanCtx(self, name, attrs)

    def traced(self, name: Optional[str] = None, **attrs) -> Callable:
        """Decorator form: the whole call body becomes one span."""

        def deco(fn: Callable) -> Callable:
            sp_name = name or fn.__qualname__

            def wrapper(*args, **kwargs):
                with self.span(sp_name, **attrs):
                    return fn(*args, **kwargs)

            wrapper.__name__ = fn.__name__
            wrapper.__qualname__ = fn.__qualname__
            wrapper.__doc__ = fn.__doc__
            wrapper.__wrapped__ = fn
            return wrapper

        return deco

    def current(self) -> Optional[Span]:
        """The innermost open span on THIS thread (None at top level)."""
        st = self._stack()
        return st[-1] if st else None

    def _push(self, name: str, attrs: Dict[str, Any]) -> Span:
        st = self._stack()
        sp = Span(name, attrs, time.perf_counter() - self.epoch,
                  threading.get_ident(), len(st))
        st.append(sp)
        if self.jax_annotations and _JaxAnnotation is not None:
            try:
                sp._ann = _JaxAnnotation(name)
                sp._ann.__enter__()
            except Exception:   # annotation failure must never break a span
                sp._ann = None
        return sp

    def _pop(self, sp: Span) -> None:
        sp.t1 = time.perf_counter() - self.epoch
        if sp._ann is not None:
            try:
                sp._ann.__exit__(None, None, None)
            except Exception:
                pass
            sp._ann = None
        st = self._stack()
        # exits normally come LIFO; tolerate leaks (an abandoned inner span
        # must not corrupt the outer ones)
        while st and st[-1] is not sp:
            st.pop()
        if st:
            st.pop()
        with self._lock:
            self._events.append(sp)

    # -- inspection / export ------------------------------------------------

    def spans(self, prefix: str = "") -> List[Span]:
        """Finished spans (oldest first), optionally name-filtered."""
        with self._lock:
            return [s for s in self._events if s.name.startswith(prefix)]

    def reset(self) -> None:
        with self._lock:
            self._events.clear()
        self.epoch = time.perf_counter()

    def to_chrome_trace(self) -> Dict[str, Any]:
        """The Chrome-trace JSON object: complete ("ph": "X") events with
        microsecond ``ts``/``dur``, one row per thread.  Nesting is implied
        by containment on a tid, which the span stack guarantees."""
        pid = os.getpid()
        events: List[Dict[str, Any]] = []
        tids = {}
        for sp in self.spans():
            # renumber thread ids densely so the viewer rows are stable
            tid = tids.setdefault(sp.tid, len(tids))
            ev = {"name": sp.name, "ph": "X", "pid": pid, "tid": tid,
                  "ts": round(sp.t0 * 1e6, 3),
                  "dur": round(max(sp.duration_s, 0.0) * 1e6, 3),
                  "cat": sp.name.split(".", 1)[0]}
            if sp.attrs:
                ev["args"] = {k: v if isinstance(v, (int, float, bool,
                                                     str, type(None)))
                              else str(v) for k, v in sp.attrs.items()}
            events.append(ev)
        events.sort(key=lambda e: e["ts"])
        meta = [{"name": "process_name", "ph": "M", "pid": pid,
                 "args": {"name": "repro"}}]
        meta += [{"name": "thread_name", "ph": "M", "pid": pid, "tid": t,
                  "args": {"name": "main" if t == 0 else f"thread-{t}"}}
                 for t in sorted(tids.values())]
        return {"traceEvents": meta + events, "displayTimeUnit": "ms"}

    def export(self, path: str) -> str:
        """Write the Chrome-trace JSON; open it in ``chrome://tracing`` or
        https://ui.perfetto.dev.  Returns ``path``."""
        d = os.path.dirname(os.path.abspath(path))
        os.makedirs(d, exist_ok=True)
        tmp = f"{path}.tmp.{os.getpid()}"
        with open(tmp, "w") as f:
            json.dump(self.to_chrome_trace(), f)
        os.replace(tmp, path)
        return path
