"""``repro.obs`` — the unified observability layer.

Zero-required-dependency tracing spans, a process-wide metrics registry,
and Chrome-trace export, wired through every subsystem (estimator fit
phases, engine map/shuffle/reduce, the batched predict service, the
autotuner).  See API.md "Observability".

    from repro import obs

    with obs.span("fit.affinity"): ...          # hierarchical, thread-safe
    obs.counter("engine.map_tasks").inc()
    obs.histogram("serve.request_ms").observe(3.2)
    obs.absorb_stats("engine.store", store.stats)   # ad-hoc dicts -> metrics
    obs.export_trace("trace.json")              # chrome://tracing
    obs.metrics.to_json("metrics.json")

``obs.set_enabled(False)`` turns both spans and stat absorption into
no-ops (the overhead benchmark's baseline).
"""
from __future__ import annotations

from repro.obs.metrics import (DEFAULT_BUCKETS_MS, Counter, Gauge, Histogram,
                               MetricsRegistry, nearest_rank)
from repro.obs.report import fit_obs, phase_summary, write_artifacts
from repro.obs.trace import Span, Tracer

# the process-wide instances every subsystem shares
tracer = Tracer()
metrics = MetricsRegistry()

# bound module-level helpers (the common call sites)
span = tracer.span
traced = tracer.traced
current_span = tracer.current
spans = tracer.spans
export_trace = tracer.export
counter = metrics.counter
gauge = metrics.gauge
histogram = metrics.histogram
absorb_stats = metrics.absorb_stats
snapshot = metrics.snapshot


def set_enabled(on: bool) -> None:
    """Toggle span recording AND stat absorption process-wide (direct
    metric objects already held by callers keep working either way)."""
    tracer.enabled = on
    metrics.enabled = on


def enabled() -> bool:
    return tracer.enabled


def reset() -> None:
    """Clear all recorded spans and metrics (tests; between CLI runs)."""
    tracer.reset()
    metrics.reset()


__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "Span", "Tracer",
    "DEFAULT_BUCKETS_MS", "absorb_stats", "counter", "current_span",
    "enabled", "export_trace", "fit_obs", "gauge", "histogram", "metrics",
    "nearest_rank", "phase_summary", "reset", "set_enabled", "snapshot",
    "span", "spans", "traced", "tracer", "write_artifacts",
]
