"""Phase 3 of the paper: parallel k-means (Alg. in §4.3.3).

map  = assign each point to the nearest center        -> per-device argmin
reduce = per-cluster coordinate sums -> new centers   -> jax.lax.psum

Points are row-sharded; centers are replicated (the paper's "center file"
read by every worker).  Empty clusters keep their previous center.  A
k-means++ initializer replaces the paper's unspecified init (standard
practice; plain random init frequently collapses on spectral embeddings).
"""
from __future__ import annotations

from dataclasses import dataclass

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.core.seeding import kmeans_plusplus_init  # noqa: F401  (shared
# D^2-sampling seeder, re-exported: callers historically import it from here)
from repro.core.similarity import pairwise_sq_dists
from repro.distrib import mesh_utils


@jax.tree_util.register_pytree_node_class
@dataclass
class KMeansState:
    """Checkpointable k-means iteration state (the paper's "center file")."""
    it: jax.Array        # scalar int32
    centers: jax.Array   # (k, dim) replicated
    shift: jax.Array     # scalar: last center movement (convergence signal)

    def tree_flatten(self):
        return (self.it, self.centers, self.shift), None

    @staticmethod
    def tree_unflatten(aux, children):
        return KMeansState(*children)


def normalize_rows(Z: jax.Array, eps: float = 1e-12) -> jax.Array:
    """Alg. 4.1 step 5: Y = Z with unit-norm rows."""
    norms = jnp.linalg.norm(Z, axis=1, keepdims=True)
    return Z / jnp.maximum(norms, eps)


def assign(y: jax.Array, centers: jax.Array) -> jax.Array:
    """Nearest-center index per point (the paper's map function)."""
    return jnp.argmin(pairwise_sq_dists(y, centers), axis=1)


def _update(y, valid, centers):
    """One Lloyd step on a local block; caller psums (sums, counts)."""
    k = centers.shape[0]
    d2 = pairwise_sq_dists(y, centers)
    idx = jnp.argmin(d2, axis=1)
    onehot = jax.nn.one_hot(idx, k, dtype=y.dtype) * valid[:, None]
    sums = onehot.T @ y                       # (k, dim)
    counts = jnp.sum(onehot, axis=0)          # (k,)
    inertia = jnp.sum(jnp.min(d2, axis=1) * valid)
    return sums, counts, inertia


def lloyd_step(y: jax.Array, valid: jax.Array, state: KMeansState) -> KMeansState:
    """Single-device Lloyd iteration (reference; also the per-shard body)."""
    sums, counts, _ = _update(y, valid, state.centers)
    new = jnp.where(counts[:, None] > 0, sums / jnp.maximum(counts[:, None], 1), state.centers)
    return KMeansState(it=state.it + 1, centers=new,
                       shift=jnp.linalg.norm(new - state.centers))


def kmeans(y: jax.Array, k: int, key: jax.Array, iters: int = 50,
            centers0: jax.Array | None = None) -> tuple[jax.Array, jax.Array]:
    """Reference single-device k-means. Returns (labels, centers)."""
    centers = centers0 if centers0 is not None else kmeans_plusplus_init(y, k, key)
    valid = jnp.ones((y.shape[0],), y.dtype)
    state = KMeansState(it=jnp.zeros((), jnp.int32), centers=centers,
                        shift=jnp.asarray(jnp.inf, y.dtype))

    def body(_, s):
        return lloyd_step(y, valid, s)

    state = lax.fori_loop(0, iters, body, state)
    return assign(y, state.centers), state.centers


def minibatch_kmeans(y: jax.Array, valid: jax.Array, k: int, key: jax.Array,
                     iters: int = 50, batch: int = 256,
                     centers0: jax.Array | None = None
                     ) -> tuple[jax.Array, jax.Array]:
    """Mini-batch Lloyd (Sculley-style per-center learning rates).

    For large ``n`` a full Lloyd pass per round is the dominant cost; each
    round here touches only ``batch`` sampled points, with center c moving
    toward its batch mean at rate (batch count)/(lifetime count).  ``valid``
    weights the sampling so padding rows are never drawn.  Returns
    ``(labels, centers)`` with labels from one final full assignment.
    """
    n = y.shape[0]
    batch = int(min(batch, n))
    key, init_key = jax.random.split(key)
    if centers0 is None:
        centers0 = kmeans_plusplus_init(y, k, init_key, weights=valid)
    p = valid / jnp.maximum(jnp.sum(valid), 1.0)

    def body(_, carry):
        centers, counts, key = carry
        key, sub = jax.random.split(key)
        idx = jax.random.choice(sub, n, (batch,), replace=True, p=p)
        yb = y[idx]
        a = jnp.argmin(pairwise_sq_dists(yb, centers), axis=1)
        onehot = jax.nn.one_hot(a, k, dtype=y.dtype)
        bc = jnp.sum(onehot, axis=0)                 # (k,) batch counts
        bmean = (onehot.T @ yb) / jnp.maximum(bc[:, None], 1.0)
        counts = counts + bc
        lr = bc / jnp.maximum(counts, 1.0)
        centers = jnp.where(bc[:, None] > 0,
                            centers + lr[:, None] * (bmean - centers), centers)
        return centers, counts, key

    centers, _, _ = lax.fori_loop(
        0, iters, body, (centers0, jnp.zeros((k,), y.dtype), key))
    return assign(y, centers), centers


def distributed_lloyd_step(y_sharded: jax.Array, valid: jax.Array,
                           state: KMeansState, mesh: Mesh) -> KMeansState:
    """One MapReduce round: shard-local assign+sum, psum reduce, new centers."""
    axes = mesh_utils.flat_axes(mesh)
    axis = axes[0] if len(axes) == 1 else axes

    def body(y_local, valid_local, centers):
        sums, counts, inertia = _update(y_local, valid_local, centers)
        sums = lax.psum(sums, axis)
        counts = lax.psum(counts, axis)
        return sums, counts

    shard = mesh_utils.shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), P(axes), P()),
        out_specs=(P(), P()),
    )
    sums, counts = shard(y_sharded, valid, state.centers)
    new = jnp.where(counts[:, None] > 0,
                    sums / jnp.maximum(counts[:, None], 1), state.centers)
    return KMeansState(it=state.it + 1, centers=new,
                       shift=jnp.linalg.norm(new - state.centers))


def distributed_kmeans(y_sharded: jax.Array, valid: jax.Array, k: int,
                       key: jax.Array, mesh: Mesh, iters: int = 50,
                       centers0: jax.Array | None = None,
                       tol: float = 1e-6) -> tuple[jax.Array, KMeansState]:
    """Paper §4.3.3 on a device mesh. ``y_sharded`` is (n_pad, dim) row-sharded,
    ``valid`` the padding mask. Runs a fixed ``iters`` rounds with early-exit
    semantics folded into the state (shift < tol keeps centers fixed)."""
    if centers0 is None:
        # ++-init needs a global view; the embedding (n, k) is small (the
        # paper also keeps centers in a single HBase "center file").
        centers0 = kmeans_plusplus_init(
            jnp.asarray(y_sharded), k, key, weights=valid)
    state = KMeansState(it=jnp.zeros((), jnp.int32), centers=centers0,
                        shift=jnp.asarray(jnp.inf, y_sharded.dtype))

    def body(_, s):
        nxt = distributed_lloyd_step(y_sharded, valid, s, mesh)
        frozen = s.shift < tol
        centers = jnp.where(frozen, s.centers, nxt.centers)
        shift = jnp.where(frozen, s.shift, nxt.shift)
        return KMeansState(it=nxt.it, centers=centers, shift=shift)

    state = lax.fori_loop(0, iters, body, state)
    labels = assign(jnp.asarray(y_sharded), state.centers)
    return labels, state
