"""k-means++ seeding (D^2 sampling) — the ONE implementation every
phase-3 consumer shares.

The paper leaves the k-means init unspecified; plain random init
frequently collapses on spectral embeddings, so every assigner here seeds
with D^2 sampling.  Two substrate twins of the same algorithm live in
this module so it is written (and fixed) exactly once per substrate:

  * :func:`kmeans_plusplus_init` — jax, jit-traceable (``lax.fori_loop``),
    used by ``core.kmeans`` (reference/distributed/mini-batch Lloyd) and
    by the registry assigners in ``cluster.assigners``;
  * :func:`kmeans_plusplus_np` — host numpy over a seeded
    ``RandomState``, used by the engine's streaming k-means, whose whole
    point is never materializing the embedding on device.

Both draw the first center weight-proportionally, then k-1 centers
proportionally to the weighted squared distance to the nearest chosen
center; ``weights`` masks padding rows out of the draw.
"""
from __future__ import annotations

from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


def kmeans_plusplus_init(y: jax.Array, k: int, key: jax.Array,
                         weights: jax.Array | None = None) -> jax.Array:
    """k-means++ seeding (D^2 sampling), jax substrate."""
    n = y.shape[0]
    w = weights if weights is not None else jnp.ones((n,), y.dtype)
    key, sub = jax.random.split(key)
    first = jax.random.choice(sub, n, p=w / jnp.sum(w))
    centers = jnp.zeros((k, y.shape[1]), y.dtype).at[0].set(y[first])
    d2 = jnp.sum((y - y[first]) ** 2, axis=1) * w

    def body(i, carry):
        centers, d2, key = carry
        key, sub = jax.random.split(key)
        p = d2 / jnp.maximum(jnp.sum(d2), 1e-12)
        idx = jax.random.choice(sub, n, p=p)
        c = y[idx]
        centers = centers.at[i].set(c)
        d2 = jnp.minimum(d2, jnp.sum((y - c) ** 2, axis=1) * w)
        return centers, d2, key

    centers, _, _ = lax.fori_loop(1, k, body, (centers, d2, key))
    return centers


def kmeans_plusplus_np(y: np.ndarray, k: int, rng: np.random.RandomState,
                       w: Optional[np.ndarray] = None) -> np.ndarray:
    """k-means++ seeding, host-numpy substrate (for samples that fit in
    RAM — the engine's reservoir sample)."""
    n = len(y)
    w = np.ones(n) if w is None else np.asarray(w, np.float64)
    centers = np.empty((k, y.shape[1]), np.float64)
    centers[0] = y[rng.choice(n, p=w / w.sum())]
    d2 = np.sum((y - centers[0]) ** 2, axis=1) * w
    for i in range(1, k):
        s = d2.sum()
        # all remaining distances zero (coincident points / k > #distinct):
        # fall back to weight-uniform draws instead of an invalid p vector
        p = d2 / s if s > 0 else w / w.sum()
        centers[i] = y[rng.choice(n, p=p)]
        d2 = np.minimum(d2, np.sum((y - centers[i]) ** 2, axis=1) * w)
    return centers
