"""Normalized Laplacian operators (paper §3.2.2 / Alg. 4.1 steps 2-3).

L_sym = I - D^{-1/2} S D^{-1/2}.  Lanczos converges to *extremal*
eigenvalues, so to get the k smallest of L_sym (spectrum in [0, 2]) we run
it on the shifted operator A = 2I - L_sym = I + D^{-1/2} S D^{-1/2}, whose
largest eigenpairs are exactly L_sym's smallest (DESIGN.md §2).
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp

from repro.core.similarity import UpperSim, sym_matmat, sym_matvec


def dense_degrees(S: jax.Array) -> jax.Array:
    return jnp.sum(S, axis=1)


def masked_inv_sqrt(deg: jax.Array) -> jax.Array:
    """D^{-1/2} with zero-degree rows (padding, isolated vertices) pinned to 0
    so they stay in the null space of the normalized-similarity term."""
    return jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)


def make_dense_operator(S: jax.Array, valid: jax.Array):
    """Shifted normalized operator from a dense padded similarity matrix.

    ``A V = valid * V + D^{-1/2} S D^{-1/2} V`` — the single construction
    shared by the full/dense/precomputed affinity paths.  ``S`` is
    (n_pad, n_pad) with zero padding rows/cols; ``valid`` the (n_pad,)
    1/0 mask.  Returns ``(matmat, inv_sqrt)``: the canonical multi-vector
    product (one pass of S per (n_pad, b) block — with S row-sharded and
    the block replicated, ``S @ .`` is the one collective) plus D^{-1/2}
    for out-of-sample extension.  The width-1 matvec view is derived by
    :class:`~repro.cluster.operator.NormalizedOperator`.
    """
    deg = S @ valid  # padded cols are zero already
    inv_sqrt = masked_inv_sqrt(deg)

    def matmat(V: jax.Array) -> jax.Array:
        return valid[:, None] * V + inv_sqrt[:, None] * (
            S @ (inv_sqrt[:, None] * V))

    return matmat, inv_sqrt


def dense_shifted_matrix(S: jax.Array, valid: jax.Array,
                         inv_sqrt: jax.Array | None = None) -> jax.Array:
    """Materialized A = diag(valid) + D^{-1/2} S D^{-1/2} (for exact eigh).

    Pass the operator build's ``inv_sqrt`` when you have it — recomputing
    it here costs a redundant full pass over S."""
    if inv_sqrt is None:
        inv_sqrt = masked_inv_sqrt(S @ valid)
    return jnp.diag(valid) + S * (inv_sqrt[:, None] * inv_sqrt[None, :])


def dense_lsym(S: jax.Array, deg: jax.Array | None = None) -> jax.Array:
    inv_sqrt = masked_inv_sqrt(dense_degrees(S) if deg is None else deg)
    N = S * inv_sqrt[:, None] * inv_sqrt[None, :]
    return jnp.eye(S.shape[0], dtype=S.dtype) - N


def degrees(upper: UpperSim) -> jax.Array:
    """d_i = sum_j S_ij via one symmetric mat-vec with the ones vector."""
    ones = upper.diag  # 1.0 on valid (permuted) rows, 0 on padding
    return sym_matvec(upper, ones)


def make_shifted_matmat(
    upper: UpperSim, deg: jax.Array
) -> Callable[[jax.Array], jax.Array]:
    """A V = V + D^{-1/2} S D^{-1/2} V on (n_pad, b) blocks, padding rows
    mapped to 0.

    Padding rows have degree 0; we pin their inv-sqrt to 0 so they stay in
    the null space of the S-term and contribute nothing.  The identity term
    is masked to valid rows so pad rows don't pollute the Krylov basis.
    The inner :func:`~repro.core.similarity.sym_matmat` streams each
    device's triangle tiles once per block.
    """
    valid = upper.diag  # (n_pad,) 1/0
    inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)

    def matmat(V: jax.Array) -> jax.Array:
        SV = sym_matmat(upper, inv_sqrt[:, None] * V)
        return valid[:, None] * V + inv_sqrt[:, None] * SV

    return matmat


def make_shifted_operator(
    upper: UpperSim, deg: jax.Array
) -> Callable[[jax.Array], jax.Array]:
    """Width-1 matvec view of :func:`make_shifted_matmat` (kept for
    single-vector consumers like the dry-run lowering harness)."""
    matmat = make_shifted_matmat(upper, deg)

    def matvec(v: jax.Array) -> jax.Array:
        return matmat(v[:, None])[:, 0]

    return matvec


def make_dense_shifted_matmat(
    S: jax.Array, deg: jax.Array | None = None
) -> Callable[[jax.Array], jax.Array]:
    """``deg`` threads a degree vector the caller already computed through
    (one full pass over S saved per operator construction)."""
    inv_sqrt = masked_inv_sqrt(dense_degrees(S) if deg is None else deg)

    def matmat(V: jax.Array) -> jax.Array:
        return V + inv_sqrt[:, None] * (S @ (inv_sqrt[:, None] * V))

    return matmat


def make_dense_shifted_operator(
    S: jax.Array, deg: jax.Array | None = None
) -> Callable[[jax.Array], jax.Array]:
    matmat = make_dense_shifted_matmat(S, deg)

    def matvec(v: jax.Array) -> jax.Array:
        return matmat(v[:, None])[:, 0]

    return matvec
