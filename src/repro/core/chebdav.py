"""Block Chebyshev–Davidson: phase-2 alternative to (block) Lanczos.

The distributed block Chebyshev–Davidson method for spectral clustering
(Pang & Yang 2022) computes the k *largest* eigenpairs of the shifted
normalized operator A = 2I - L_sym (spectrum in [0, 2]) by repeatedly

  1. taking the current best block of b Ritz vectors,
  2. pushing it through a degree-d Chebyshev polynomial filter that damps
     the unwanted (lower) part of the spectrum and amplifies the wanted
     (upper) end — d matrix passes that need NO inner products or
     orthogonalization, the cheap streaming part,
  3. orthogonalizing the filtered block against the search basis (CGS2 +
     QR) and appending it,
  4. Rayleigh–Ritz on the grown basis, restarting when it exceeds
     ``max_subspace``.

Every matrix pass is a width-b ``matmat``, so like block Lanczos each
sweep of the similarity matrix is amortized over the whole block; unlike
Lanczos the filter concentrates the spectrum first, so far fewer passes
reach the same residual on clustered spectra.

Everything here is a host-side driver over jitted jnp kernels: the n×b
block algebra is XLA, the convergence control flow is Python (the same
split as the engine's streaming consumers).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable, Optional

import jax
import jax.numpy as jnp
import numpy as np


@dataclass
class ChebDavResult:
    """Top-k eigenpairs of the operator the filter ran on (A, descending
    wanted end), plus convergence counters."""
    evals: jax.Array      # (k,) eigenvalues of A, descending
    evecs: jax.Array      # (n, k) unit columns
    iters: int            # outer Davidson iterations
    passes: int           # matrix passes (matmat applications, any width)
    max_residual: float   # max ||A x - theta x|| over the k wanted pairs


def chebyshev_filter(matmat: Callable, X: jax.Array, degree: int,
                     a: float, b: float, a0: float) -> jax.Array:
    """Scaled Chebyshev filter: damps the operator spectrum inside
    ``[a, b]``, amplifies outside, normalized at ``a0`` (the wanted end)
    so iterates neither overflow nor vanish (Zhou & Saad's three-term
    recurrence, mirrored to the upper end of the spectrum).

    ``degree`` matrix passes of width X.shape[1]; no orthogonalization.
    """
    e = max(0.5 * (b - a), 1e-6)
    c = 0.5 * (b + a)
    sigma = e / (c - a0)
    tau = 2.0 / sigma
    Y = (matmat(X) - c * X) * (sigma / e)
    for _ in range(2, degree + 1):
        sigma_new = 1.0 / (tau - sigma)
        Yt = (matmat(Y) - c * Y) * (2.0 * sigma_new / e) \
            - (sigma * sigma_new) * X
        X, Y = Y, Yt
        sigma = sigma_new
    return Y


def _orthonormalize_against(basis: jax.Array, W: jax.Array,
                            eps: float = 1e-8) -> jax.Array:
    """CGS2 against ``basis`` then QR within ``W``; (near-)dependent
    columns are dropped, so the returned block may be narrower than W."""
    for _ in range(2):
        W = W - basis @ (basis.T @ W)
    Q, R = jnp.linalg.qr(W)
    keep = np.asarray(jnp.abs(jnp.diagonal(R))) > eps
    if not keep.any():
        return Q[:, :0]
    return Q[:, np.flatnonzero(keep)]


def chebdav(matmat: Callable, n: int, k: int, key: jax.Array, *,
            block_size: Optional[int] = None, degree: int = 12,
            tol: float = 1e-5, max_iters: int = 100,
            max_subspace: Optional[int] = None,
            valid: Optional[jax.Array] = None,
            dtype=jnp.float32) -> ChebDavResult:
    """k largest eigenpairs of the symmetric operator behind ``matmat``
    (spectrum assumed within [0, 2] — the shifted normalized operator).

    ``valid`` optionally zeroes padding rows of the random start block so
    they never enter the search space (the operator annihilates them, so
    the invariant then holds for every later block).
    """
    b = int(block_size or max(2, min(k, n)))
    b = max(1, min(b, n))
    m_max = int(max_subspace or min(n, max(3 * b + k, 2 * k + b)))

    passes = 0

    def apply(X):
        nonlocal passes
        passes += 1
        return matmat(X)

    X0 = jax.random.normal(key, (n, b), dtype)
    if valid is not None:
        X0 = X0 * valid[:, None].astype(dtype)
    V = _orthonormalize_against(jnp.zeros((n, 0), dtype), X0)
    AV = apply(V)

    up = 2.0          # spectrum ceiling of A = I + D^-1/2 S D^-1/2
    lo = 0.0          # spectrum floor (padding rows / L_sym upper end)
    it = 0
    theta = jnp.zeros((k,), dtype)
    Z = V[:, :k]
    max_res = float("inf")
    best_res, stale = float("inf"), 0
    for it in range(1, max_iters + 1):
        H = V.T @ AV
        H = 0.5 * (H + H.T)
        evals, U = jnp.linalg.eigh(H)            # ascending
        m = int(H.shape[0])
        kw = min(k, m)                           # wanted pairs available
        Uw = U[:, m - kw:][:, ::-1]              # wanted, descending
        theta = evals[m - kw:][::-1]
        Rw = V @ Uw                              # wanted Ritz vectors
        ARw = AV @ Uw
        res = jnp.linalg.norm(ARw - Rw * theta[None, :], axis=0)
        res_np = np.asarray(res)
        max_res = float(res_np.max()) if kw else float("inf")
        Z = Rw
        if kw == k and max_res < tol:
            break
        # Stagnation guard: float32 operators (e.g. the engine's callback
        # stream) bottom out above very tight tolerances — stop burning
        # matrix passes once the residual has stopped improving.
        if kw == k:
            if max_res < 0.7 * best_res:
                best_res, stale = max_res, 0
            else:
                stale += 1
                if stale >= 8:
                    break

        # Filter bounds: damp [lo, cut] — everything below the wanted
        # set.  cut = largest unwanted Ritz value when one exists, else
        # mid-gap between the floor and the smallest wanted value.
        evn = np.asarray(evals)
        lo = float(min(lo, evn.min()))
        if m > kw:
            cut = float(evn[m - kw - 1])
        else:
            cut = 0.5 * (lo + float(evn[0]))
        cut = min(max(cut, lo + 1e-3), up - 1e-3)
        a0 = max(float(np.asarray(theta).max()), cut + 1e-2)

        # Next block: the b best not-yet-converged wanted directions,
        # topped up with the next-best Ritz vectors when most converged.
        order = [i for i in range(kw) if res_np[i] >= tol] \
            + [i for i in range(kw) if res_np[i] < tol]
        cols = jnp.asarray(order[:b], jnp.int32)
        X = Rw[:, cols]

        Y = chebyshev_filter(apply, X, int(degree), lo, cut, a0)
        Y = _orthonormalize_against(V, Y)
        if Y.shape[1] == 0:
            break                                # subspace exhausted
        if m + Y.shape[1] > m_max:               # thick restart first:
            keep = max(kw, min(m_max - int(Y.shape[1]), m))
            Uk = U[:, m - keep:]                 # top Ritz directions of
            V = V @ Uk                           # the current basis (Y is
            AV = AV @ Uk                         # orthogonal to any subspan)
        V = jnp.concatenate([V, Y], axis=1)
        AV = jnp.concatenate([AV, apply(Y)], axis=1)

    norms = jnp.linalg.norm(Z, axis=0, keepdims=True)
    Z = Z / jnp.maximum(norms, 1e-12)
    return ChebDavResult(evals=theta, evecs=Z, iters=it, passes=passes,
                         max_residual=max_res)
