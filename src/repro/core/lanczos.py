"""Phase 2 of the paper: (block) Lanczos for the k smallest eigenvectors
(Alg. 4.3).

The mat-vec ``L @ v`` is the distributed hot spot — the caller passes a
``matvec``/``matmat`` closure (row-sharded symmetric operator from
``core.similarity`` / ``core.laplacian``), and the recurrence itself runs
on replicated vectors/blocks, exactly the paper's "move the vector to the
data" split.

The canonical recurrence is **block** Lanczos: a block-tridiagonal
three-term recurrence on ``b`` vectors at once, so every eigensolver step
costs ONE pass over the matrix (one ``matmat``) amortized across the whole
block, instead of one pass per vector — the key trick of CPU-GPU spectral
clustering implementations (Jin & JaJa 2018).  The classic single-vector
Lanczos below is the ``b = 1`` view of the same step body.

Deviations from the paper (correctness-driven, DESIGN.md §2):
  * full reorthogonalization (CGS2) against the whole basis — plain
    Lanczos loses orthogonality in finite precision and returns wrong
    small eigenvectors;
  * the iteration runs on the *shifted* operator A = 2I - L_sym supplied
    by ``laplacian.make_shifted_operator``, so extremal (largest) Ritz
    pairs of A are the smallest of L_sym.

Both states are explicit pytrees so the launcher can checkpoint/restore
the iteration mid-run (fault tolerance; the paper gets this from Hadoop
task re-execution).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax


# ---------------------------------------------------------------------------
# Block Lanczos: the canonical recurrence
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class BlockLanczosState:
    """Checkpointable block-Lanczos iteration state.

    ``block_size`` b is static; after ``step`` completed block steps the
    first ``(step + 1) * b`` rows of ``V`` hold the orthonormal basis.
    """

    step: jax.Array    # scalar int32: number of completed block steps
    V: jax.Array       # ((s+1)*b, n) basis rows; blocks > step are zero
    A: jax.Array       # (s, b, b) block-diagonal of T; symmetric blocks
    B: jax.Array       # (s+1, b, b) subdiagonal blocks of T; B[0] == 0
    block_size: int    # static

    def tree_flatten(self):
        return (self.step, self.V, self.A, self.B), (self.block_size,)

    @staticmethod
    def tree_unflatten(aux, children):
        return BlockLanczosState(*children, block_size=aux[0])


def _qr_pos(U: jax.Array, eps: float = 1e-8) -> tuple[jax.Array, jax.Array]:
    """Reduced QR with non-negative R diagonal; (near-)dependent columns
    are zeroed instead of admitting junk directions into the basis (the
    block analogue of the scalar ``beta < 1e-8 -> v_next = 0`` guard: the
    dead direction decouples from T and lands at the spectrum floor)."""
    Q, R = jnp.linalg.qr(U)
    d = jnp.diagonal(R)
    sgn = jnp.where(d < 0, -1.0, 1.0).astype(U.dtype)
    Q = Q * sgn[None, :]
    R = R * sgn[:, None]
    keep = (jnp.diagonal(R) > eps).astype(U.dtype)
    return Q * keep[None, :], R * keep[:, None]


def init_block_state(n: int, num_steps: int, key: jax.Array, block_size: int,
                     V0: jax.Array | None = None,
                     dtype=jnp.float32) -> BlockLanczosState:
    """Random (or caller-supplied) orthonormal (b, n) start block."""
    b = block_size
    if V0 is None:
        V0 = jax.random.normal(key, (b, n), dtype)
    Q, _ = _qr_pos(V0.T.astype(dtype))
    V = jnp.zeros(((num_steps + 1) * b, n), dtype).at[:b].set(Q.T)
    return BlockLanczosState(
        step=jnp.zeros((), jnp.int32),
        V=V,
        A=jnp.zeros((num_steps, b, b), dtype),
        B=jnp.zeros((num_steps + 1, b, b), dtype),
        block_size=b,
    )


def _current_block(state: BlockLanczosState) -> jax.Array:
    """The (b, n) basis block the next step multiplies the operator by."""
    b = state.block_size
    _, n = state.V.shape
    return lax.dynamic_slice(state.V, (state.step * b, 0), (b, n))


def _block_step_update(state: BlockLanczosState,
                       W: jax.Array) -> BlockLanczosState:
    """Everything in a block step AFTER the matrix pass: given
    ``W = A @ Vj.T`` for the current block, orthogonalize and append the
    next block.  Split out from :func:`_block_step_body` so host-streaming
    operators can run the matmat as plain Python between two jitted halves
    (:func:`block_run_host`) instead of through ``pure_callback``."""
    j = state.step
    b = state.block_size
    rows, n = state.V.shape
    Vj = lax.dynamic_slice(state.V, (j * b, 0), (b, n))          # (b, n)
    Vp = lax.dynamic_slice(state.V, (jnp.maximum(j - 1, 0) * b, 0), (b, n))
    Vp = jnp.where(j > 0, 1.0, 0.0).astype(Vp.dtype) * Vp
    Bj = lax.dynamic_slice(state.B, (j, 0, 0), (1, b, b))[0]     # (b, b)

    W = W.astype(state.V.dtype) - Vp.T @ Bj.T
    Aj = Vj @ W                                                  # (b, b)
    Aj = 0.5 * (Aj + Aj.T)          # symmetric operator -> symmetric block
    W = W - Vj.T @ Aj
    # Full reorthogonalization against the whole block basis, "twice is
    # enough" (CGS2); the row mask limits it to the filled blocks.
    mask = (jnp.arange(rows) < (j + 1) * b).astype(W.dtype)
    for _ in range(2):
        C = (state.V @ W) * mask[:, None]
        W = W - state.V.T @ C
    Qn, R = _qr_pos(W)
    return BlockLanczosState(
        step=j + 1,
        V=lax.dynamic_update_slice(state.V, Qn.T, ((j + 1) * b, 0)),
        A=lax.dynamic_update_slice(
            state.A, Aj[None].astype(state.A.dtype), (j, 0, 0)),
        B=lax.dynamic_update_slice(
            state.B, R[None].astype(state.B.dtype), (j + 1, 0, 0)),
        block_size=b,
    )


def _block_step_body(matmat: Callable,
                     state: BlockLanczosState) -> BlockLanczosState:
    W = matmat(_current_block(state).T)                          # (n, b)
    return _block_step_update(state, W)


def block_run(matmat: Callable, state: BlockLanczosState,
              num_iters: int) -> BlockLanczosState:
    """Advance the block recurrence ``num_iters`` block steps — each step
    is ONE matrix pass (one matmat of width b).  Checkpoint-friendly.

    The returned state is synchronized (``block_until_ready``): ``matmat``
    may embed a host callback, and returning while that computation is
    still in flight lets the caller's op-by-op dispatch race the callback
    on the CPU runtime's single work queue — a deadlock, not just a
    slowdown.  The caller consumes the state immediately, so the barrier
    costs nothing.  (Host-streaming operators should prefer
    :func:`block_run_host`, which keeps the matrix pass out of the traced
    computation entirely.)"""
    def body(_, s):
        return _block_step_body(matmat, s)
    return jax.block_until_ready(lax.fori_loop(0, num_iters, body, state))


def _block_step_advance(state: BlockLanczosState, W: jax.Array
                        ) -> tuple[BlockLanczosState, jax.Array]:
    """One host-driver dispatch: apply the post-matmat half of a step AND
    slice out the next block to multiply — fusing what would otherwise be
    two jitted calls per iteration (the slice is trivial next to the CGS2
    reorthogonalization it piggybacks on)."""
    new = _block_step_update(state, W)
    return new, _current_block(new)


_current_block_jit = jax.jit(_current_block)
_block_step_update_jit = jax.jit(_block_step_update)
_block_step_advance_jit = jax.jit(_block_step_advance)


def block_run_host(host_matmat: Callable, state: BlockLanczosState,
                   num_iters: int) -> BlockLanczosState:
    """:func:`block_run` for HOST-STREAMING operators: ``host_matmat`` is
    plain host code (numpy (n, b) -> (n, b)) invoked between jitted step
    updates, NOT traced into the computation.

    Rationale: embedding the host matmat via ``jax.pure_callback`` puts
    the Python callback on the CPU runtime's worker pool; on small hosts
    that pool has ONE thread, and the callback machinery's own
    ``device_put`` of the operands can queue a deferred copy behind the
    very computation that is blocked waiting for the callback — a
    self-deadlock (observed repeatedly under the async engine).  Driving
    the step from Python keeps the runtime free while the host pass runs,
    and the numerics are unchanged: the step halves execute the exact
    same primitives the fused step body traces around the callback."""
    Vj = np.asarray(_current_block_jit(state))                   # (b, n)
    for _ in range(num_iters):
        W = host_matmat(np.ascontiguousarray(Vj.T))              # (n, b)
        state, nxt = _block_step_advance_jit(state, jnp.asarray(W))
        Vj = np.asarray(nxt)
    return jax.block_until_ready(state)


def block_lanczos(matmat: Callable, n: int, num_steps: int, key: jax.Array,
                  block_size: int = 8, dtype=jnp.float32,
                  V0: jax.Array | None = None,
                  host_matmat: Callable | None = None) -> BlockLanczosState:
    state = init_block_state(n, num_steps, key, block_size, V0=V0,
                             dtype=dtype)
    if host_matmat is not None:
        return block_run_host(host_matmat, state, num_steps)
    return block_run(matmat, state, num_steps)


def block_tridiagonal(state: BlockLanczosState) -> jax.Array:
    """Dense block-tridiagonal T_(sb x sb) from (A, B) — s*b is small,
    eigh on it is cheap."""
    s, b, _ = state.A.shape
    T = jnp.zeros((s * b, s * b), state.A.dtype)
    for j in range(s):
        T = lax.dynamic_update_slice(T, state.A[j], (j * b, j * b))
        if j + 1 < s:
            T = lax.dynamic_update_slice(T, state.B[j + 1], ((j + 1) * b, j * b))
            T = lax.dynamic_update_slice(T, state.B[j + 1].T, (j * b, (j + 1) * b))
    return T


def block_ritz_pairs(state: BlockLanczosState) -> tuple[jax.Array, jax.Array]:
    """Ritz values (ascending) and vectors (n, s*b) of the operator."""
    T = block_tridiagonal(state)
    evals, evecs = jnp.linalg.eigh(T)            # ascending
    s, b, _ = state.A.shape
    ritz_vecs = state.V[: s * b].T @ evecs       # (n, s*b)
    return evals, ritz_vecs


def block_topk_of_shifted(state: BlockLanczosState, k: int,
                          shift: float = 2.0) -> tuple[jax.Array, jax.Array]:
    """k smallest eigenpairs of L given block Lanczos ran on
    A = shift*I - L.  Returns (eigvals ascending (k,), eigvecs (n, k))."""
    evals_A, vecs = block_ritz_pairs(state)
    return _topk_from_ritz(evals_A, vecs, k, shift)


def _topk_from_ritz(evals_A: jax.Array, vecs: jax.Array, k: int,
                    shift: float) -> tuple[jax.Array, jax.Array]:
    # largest of A  <->  smallest of L
    topk = vecs[:, -k:][:, ::-1]
    vals_L = (shift - evals_A[-k:])[::-1]
    norms = jnp.linalg.norm(topk, axis=0, keepdims=True)
    topk = topk / jnp.maximum(norms, 1e-12)
    return vals_L, topk


# ---------------------------------------------------------------------------
# Single-vector Lanczos: the b = 1 view of the block recurrence
# ---------------------------------------------------------------------------

@jax.tree_util.register_pytree_node_class
@dataclass
class LanczosState:
    step: jax.Array    # scalar int32: number of completed iterations
    V: jax.Array       # (m+1, n) basis rows; rows > step are zero
    alpha: jax.Array   # (m,)
    beta: jax.Array    # (m+1,); beta[0] == 0

    def tree_flatten(self):
        return (self.step, self.V, self.alpha, self.beta), None

    @staticmethod
    def tree_unflatten(aux, children):
        return LanczosState(*children)


def _as_block(state: LanczosState) -> BlockLanczosState:
    return BlockLanczosState(
        step=state.step, V=state.V,
        A=state.alpha[:, None, None], B=state.beta[:, None, None],
        block_size=1)


def _from_block(bstate: BlockLanczosState) -> LanczosState:
    return LanczosState(step=bstate.step, V=bstate.V,
                        alpha=bstate.A[:, 0, 0], beta=bstate.B[:, 0, 0])


def init_state(n: int, num_steps: int, key: jax.Array,
               v0: jax.Array | None = None, dtype=jnp.float32) -> LanczosState:
    if v0 is None:
        v0 = jax.random.normal(key, (n,), dtype)
    v0 = v0 / jnp.linalg.norm(v0)
    V = jnp.zeros((num_steps + 1, n), dtype).at[0].set(v0)
    return LanczosState(
        step=jnp.zeros((), jnp.int32),
        V=V,
        alpha=jnp.zeros((num_steps,), dtype),
        beta=jnp.zeros((num_steps + 1,), dtype),
    )


def run(matvec: Callable, state: LanczosState, num_iters: int) -> LanczosState:
    """Advance the recurrence ``num_iters`` steps (checkpoint-friendly) —
    the width-1 view of :func:`block_run`, synchronized for the same
    host-callback reason."""
    def matmat(V):
        return matvec(V[:, 0])[:, None]

    def body(_, s):
        return _block_step_body(matmat, s)

    out = lax.fori_loop(0, num_iters, body, _as_block(state))
    return _from_block(jax.block_until_ready(out))


def lanczos(matvec: Callable, n: int, num_steps: int, key: jax.Array,
            dtype=jnp.float32, v0: jax.Array | None = None,
            host_matmat: Callable | None = None) -> LanczosState:
    state = init_state(n, num_steps, key, v0=v0, dtype=dtype)
    if host_matmat is not None:
        # width-1 host-streaming drive (same deadlock avoidance as
        # block_run_host; the host pass sees an (n, 1) block)
        out = block_run_host(host_matmat, _as_block(state), num_steps)
        return _from_block(out)
    return run(matvec, state, num_steps)


def tridiagonal(state: LanczosState) -> jax.Array:
    """Dense T_mm from (alpha, beta) — m is small, eigh on it is cheap."""
    m = state.alpha.shape[0]
    T = jnp.diag(state.alpha)
    off = state.beta[1:m]
    T = T + jnp.diag(off, 1) + jnp.diag(off, -1)
    return T


def ritz_pairs(state: LanczosState) -> tuple[jax.Array, jax.Array]:
    """Ritz values (ascending) and vectors (n, m) of the operator."""
    T = tridiagonal(state)
    evals, evecs = jnp.linalg.eigh(T)           # ascending
    m = state.alpha.shape[0]
    ritz_vecs = state.V[:m].T @ evecs           # (n, m)
    return evals, ritz_vecs


def topk_of_shifted(state: LanczosState, k: int,
                    shift: float = 2.0) -> tuple[jax.Array, jax.Array]:
    """k smallest eigenpairs of L given Lanczos ran on A = shift*I - L.

    Returns (eigvals_of_L ascending (k,), eigvecs (n, k), unit columns).
    """
    evals_A, vecs = ritz_pairs(state)
    return _topk_from_ritz(evals_A, vecs, k, shift)


def residuals(matvec: Callable, vals: jax.Array, vecs: jax.Array,
              shift: float | None = None) -> jax.Array:
    """||Op v - lambda v|| per Ritz pair (convergence diagnostics)."""
    def one(v, lam):
        Av = matvec(v)
        lam_op = (shift - lam) if shift is not None else lam
        return jnp.linalg.norm(Av - lam_op * v)
    return jax.vmap(one, in_axes=(1, 0))(vecs, vals)
