"""Phase 2 of the paper: Lanczos for the k smallest eigenvectors (Alg. 4.3).

The mat-vec ``L @ v`` is the distributed hot spot — the caller passes a
``matvec`` closure (row-sharded symmetric operator from ``core.similarity`` /
``core.laplacian``), and the 3-term recurrence itself runs on replicated
(n,)-vectors, exactly the paper's "move the vector to the data" split.

Deviations from the paper (correctness-driven, DESIGN.md §2):
  * full reorthogonalization (CGS2) — plain Lanczos loses orthogonality in
    finite precision and returns wrong small eigenvectors;
  * the iteration runs on the *shifted* operator A = 2I - L_sym supplied by
    ``laplacian.make_shifted_operator``, so extremal (largest) Ritz pairs of
    A are the smallest of L_sym.

The state is an explicit pytree so the launcher can checkpoint/restore the
iteration mid-run (fault tolerance; the paper gets this from Hadoop task
re-execution).
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax


@jax.tree_util.register_pytree_node_class
@dataclass
class LanczosState:
    step: jax.Array    # scalar int32: number of completed iterations
    V: jax.Array       # (m+1, n) basis rows; rows > step are zero
    alpha: jax.Array   # (m,)
    beta: jax.Array    # (m+1,); beta[0] == 0

    def tree_flatten(self):
        return (self.step, self.V, self.alpha, self.beta), None

    @staticmethod
    def tree_unflatten(aux, children):
        return LanczosState(*children)


def init_state(n: int, num_steps: int, key: jax.Array,
               v0: jax.Array | None = None, dtype=jnp.float32) -> LanczosState:
    if v0 is None:
        v0 = jax.random.normal(key, (n,), dtype)
    v0 = v0 / jnp.linalg.norm(v0)
    V = jnp.zeros((num_steps + 1, n), dtype).at[0].set(v0)
    return LanczosState(
        step=jnp.zeros((), jnp.int32),
        V=V,
        alpha=jnp.zeros((num_steps,), dtype),
        beta=jnp.zeros((num_steps + 1,), dtype),
    )


def _step_body(matvec: Callable, state: LanczosState) -> LanczosState:
    j = state.step
    m1 = state.V.shape[0]
    vj = state.V[j]
    v_prev = jnp.where(j > 0, 1.0, 0.0) * state.V[jnp.maximum(j - 1, 0)]
    w = matvec(vj) - state.beta[j] * v_prev
    alpha_j = jnp.vdot(w, vj)
    w = w - alpha_j * vj
    # Full reorthogonalization, "twice is enough" (CGS2).
    mask = (jnp.arange(m1) <= j).astype(w.dtype)
    for _ in range(2):
        coeffs = (state.V @ w) * mask
        w = w - state.V.T @ coeffs
    beta_next = jnp.linalg.norm(w)
    safe = jnp.maximum(beta_next, jnp.asarray(1e-12, w.dtype))
    v_next = jnp.where(beta_next > 1e-8, w / safe, jnp.zeros_like(w))
    return LanczosState(
        step=j + 1,
        V=state.V.at[j + 1].set(v_next),
        alpha=state.alpha.at[j].set(alpha_j.real.astype(state.alpha.dtype)),
        beta=state.beta.at[j + 1].set(beta_next.astype(state.beta.dtype)),
    )


def run(matvec: Callable, state: LanczosState, num_iters: int) -> LanczosState:
    """Advance the recurrence ``num_iters`` steps (checkpoint-friendly)."""
    def body(_, s):
        return _step_body(matvec, s)
    return lax.fori_loop(0, num_iters, body, state)


def lanczos(matvec: Callable, n: int, num_steps: int, key: jax.Array,
            dtype=jnp.float32, v0: jax.Array | None = None) -> LanczosState:
    state = init_state(n, num_steps, key, v0=v0, dtype=dtype)
    return run(matvec, state, num_steps)


def tridiagonal(state: LanczosState) -> jax.Array:
    """Dense T_mm from (alpha, beta) — m is small, eigh on it is cheap."""
    m = state.alpha.shape[0]
    T = jnp.diag(state.alpha)
    off = state.beta[1:m]
    T = T + jnp.diag(off, 1) + jnp.diag(off, -1)
    return T


def ritz_pairs(state: LanczosState) -> tuple[jax.Array, jax.Array]:
    """Ritz values (ascending) and vectors (n, m) of the operator."""
    T = tridiagonal(state)
    evals, evecs = jnp.linalg.eigh(T)           # ascending
    m = state.alpha.shape[0]
    ritz_vecs = state.V[:m].T @ evecs           # (n, m)
    return evals, ritz_vecs


def topk_of_shifted(state: LanczosState, k: int,
                    shift: float = 2.0) -> tuple[jax.Array, jax.Array]:
    """k smallest eigenpairs of L given Lanczos ran on A = shift*I - L.

    Returns (eigvals_of_L ascending (k,), eigvecs (n, k), unit columns).
    """
    evals_A, vecs = ritz_pairs(state)
    # largest of A  <->  smallest of L
    topk = vecs[:, -k:][:, ::-1]
    vals_L = (shift - evals_A[-k:])[::-1]
    norms = jnp.linalg.norm(topk, axis=0, keepdims=True)
    topk = topk / jnp.maximum(norms, 1e-12)
    return vals_L, topk


def residuals(matvec: Callable, vals: jax.Array, vecs: jax.Array,
              shift: float | None = None) -> jax.Array:
    """||Op v - lambda v|| per Ritz pair (convergence diagnostics)."""
    def one(v, lam):
        Av = matvec(v)
        lam_op = (shift - lam) if shift is not None else lam
        return jnp.linalg.norm(Av - lam_op * v)
    return jax.vmap(one, in_axes=(1, 0))(vecs, vals)
