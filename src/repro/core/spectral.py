"""Legacy entry points for the paper's pipeline (Alg. 4.1).

DEPRECATED: the pipeline now lives behind the pluggable estimator in
:mod:`repro.cluster` — one ``SpectralClustering`` class whose three phases
(affinity, eigensolver, assigner) are registry-selected backends:

    from repro.cluster import SpectralClustering
    est = SpectralClustering(k=3, affinity="triangular",
                             eigensolver="lanczos", assigner="lloyd")
    labels = est.fit(x).labels_

``fit`` / ``fit_dense`` / ``fit_from_similarity`` remain as thin shims so
existing callers keep working; they forward to the estimator and return the
same :class:`SpectralResult`.  Migration map:

    fit(x, cfg)  mode="triangular"  -> affinity="triangular" (bit-for-bit)
    fit(x, cfg)  mode="full"        -> affinity="dense"      (bit-for-bit)
    fit_dense(x, cfg)               -> affinity="dense" (or "knn-topt" when
                                       cfg.sparsify_t), eigensolver="eigh"
    fit_from_similarity(S, cfg)     -> affinity="precomputed"
"""
from __future__ import annotations

import warnings
from dataclasses import dataclass
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh

from repro.cluster.operator import SpectralResult

__all__ = ["SpectralConfig", "SpectralResult", "fit", "fit_dense",
           "fit_from_similarity"]

_MODE_TO_AFFINITY = {"triangular": "triangular", "full": "dense"}


@dataclass(frozen=True)
class SpectralConfig:
    """Legacy config bundle; maps 1:1 onto SpectralClustering kwargs."""
    k: int = 8                       # number of clusters
    sigma: float | None = None       # RBF bandwidth; None = median heuristic
    lanczos_steps: int | None = None # None = max(4k, 32), capped below n
    kmeans_iters: int = 50
    mode: str = "triangular"         # "triangular" (paper) | "full" (beyond)
    sparsify_t: int | None = None    # top-t sparsification (dense path only)
    seed: int = 0
    dtype: Any = jnp.float32


def _estimator(cfg: SpectralConfig, *, affinity: str, eigensolver: str,
               mesh: Optional[Mesh]):
    # Imported lazily: repro.core.__init__ -> spectral -> repro.cluster ->
    # repro.core.* would otherwise cycle during package initialization.
    from repro.cluster.estimator import SpectralClustering
    return SpectralClustering(
        k=cfg.k, affinity=affinity, eigensolver=eigensolver,
        assigner="lloyd", sigma=cfg.sigma, lanczos_steps=cfg.lanczos_steps,
        kmeans_iters=cfg.kmeans_iters, sparsify_t=cfg.sparsify_t,
        seed=cfg.seed, dtype=cfg.dtype, mesh=mesh)


def _deprecated(old: str, new: str):
    warnings.warn(
        f"repro.core.spectral.{old} is deprecated; use "
        f"repro.cluster.SpectralClustering({new})", DeprecationWarning,
        stacklevel=3)


def fit(x: jax.Array, cfg: SpectralConfig, mesh: Optional[Mesh] = None,
        checkpointer: Any = None) -> SpectralResult:
    """Deprecated shim: distributed spectral clustering on a mesh."""
    if cfg.mode not in _MODE_TO_AFFINITY:
        raise ValueError(f"unknown mode {cfg.mode!r}")
    affinity = _MODE_TO_AFFINITY[cfg.mode]
    _deprecated("fit", f'affinity="{affinity}"')
    est = _estimator(cfg, affinity=affinity, eigensolver="lanczos", mesh=mesh)
    return est.fit(x, checkpointer=checkpointer).result_


def fit_from_similarity(S: jax.Array, cfg: SpectralConfig,
                        mesh: Optional[Mesh] = None) -> SpectralResult:
    """Deprecated shim: cluster a precomputed similarity/adjacency matrix."""
    _deprecated("fit_from_similarity", 'affinity="precomputed"')
    est = _estimator(cfg, affinity="precomputed", eigensolver="lanczos",
                     mesh=mesh)
    return est.fit_affinity(jnp.asarray(S, cfg.dtype)).result_


def fit_dense(x: jax.Array, cfg: SpectralConfig) -> SpectralResult:
    """Deprecated shim: the exact-eigh oracle (dense S, full eigh)."""
    affinity = "knn-topt" if cfg.sparsify_t else "dense"
    _deprecated("fit_dense", f'affinity="{affinity}", eigensolver="eigh"')
    est = _estimator(cfg, affinity=affinity, eigensolver="eigh", mesh=None)
    return est.fit(x).result_
