"""End-to-end parallel spectral clustering (paper Alg. 4.1, distributed §4.3).

    fit(x)  ->  labels

Phases (each separately checkpointable, mirroring the paper's HBase-persisted
intermediates):
  1. similarity  — triangular (paper) or full (beyond-paper) block schedule
  2. eigen       — shifted Lanczos for the k smallest eigenvectors of L_sym
  3. kmeans      — distributed Lloyd on the row-normalized embedding

``fit_dense`` is the single-device oracle (full eigh) used by the tests.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.core import kmeans as km
from repro.core import lanczos as lz
from repro.core import laplacian as lp
from repro.core import similarity as sim
from repro.distrib import mesh_utils


@dataclass(frozen=True)
class SpectralConfig:
    k: int = 8                       # number of clusters
    sigma: float | None = None       # RBF bandwidth; None = median heuristic
    lanczos_steps: int | None = None # None = max(4k, 32), capped below n
    kmeans_iters: int = 50
    mode: str = "triangular"         # "triangular" (paper) | "full" (beyond)
    sparsify_t: int | None = None    # top-t sparsification (dense path only)
    seed: int = 0
    dtype: Any = jnp.float32


@dataclass
class SpectralResult:
    labels: jax.Array            # (n,) original point order
    embedding: jax.Array         # (n, k) row-normalized eigenvector rows
    eigenvalues: jax.Array       # (k,) smallest of L_sym, ascending
    centers: jax.Array           # (k, k)
    sigma: jax.Array
    info: dict = field(default_factory=dict)


def _num_steps(cfg: SpectralConfig, n: int) -> int:
    m = cfg.lanczos_steps or max(4 * cfg.k, 32)
    return int(min(m, n - 1))


def fit(x: jax.Array, cfg: SpectralConfig, mesh: Optional[Mesh] = None,
        checkpointer: Any = None) -> SpectralResult:
    """Distributed spectral clustering on mesh (defaults to all local devices)."""
    x = jnp.asarray(x, cfg.dtype)
    n = int(x.shape[0])
    mesh = mesh or mesh_utils.local_mesh("rows")
    key = jax.random.PRNGKey(cfg.seed)
    k_eig, k_lan, k_km = jax.random.split(key, 3)

    sigma = jnp.asarray(cfg.sigma, cfg.dtype) if cfg.sigma is not None \
        else sim.median_sigma(x)

    # -- phase 1: similarity ------------------------------------------------
    if cfg.mode == "full":
        S = sim.distributed_similarity_full(x, sigma, mesh)
        n_pad = S.shape[0]
        valid = (jnp.arange(n_pad) < n).astype(cfg.dtype)
        deg = S @ valid  # padded cols are zero already; (n_pad,)
        inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)

        def matvec(v):
            return valid * v + inv_sqrt * (S @ (inv_sqrt * v))

        perm_back = None
    elif cfg.mode == "triangular":
        upper = sim.similarity_upper_blocks(x, sigma, mesh)
        n_pad = upper.schedule.n_pad
        valid = upper.diag
        deg = lp.degrees(upper)
        matvec = lp.make_shifted_operator(upper, deg)
        perm_back = upper.schedule
    else:
        raise ValueError(f"unknown mode {cfg.mode!r}")
    if checkpointer is not None:
        checkpointer.save_phase("similarity", {"sigma": sigma})

    # -- phase 2: k smallest eigenvectors ------------------------------------
    steps = _num_steps(cfg, n)
    state = lz.lanczos(matvec, n_pad, steps, k_lan, dtype=cfg.dtype)
    if checkpointer is not None:
        checkpointer.save_phase("lanczos", state)
    evals, Z = lz.topk_of_shifted(state, cfg.k)          # (k,), (n_pad, k)

    # -- phase 3: k-means on the normalized embedding -------------------------
    Y = km.normalize_rows(Z) * valid[:, None]
    Y = jax.lax.with_sharding_constraint(
        Y, NamedSharding(mesh, P(mesh_utils.flat_axes(mesh), None)))
    labels_pad, km_state = km.distributed_kmeans(
        Y, valid, cfg.k, k_km, mesh, iters=cfg.kmeans_iters)
    if checkpointer is not None:
        checkpointer.save_phase("kmeans", km_state)

    if perm_back is not None:
        labels = sim.unpermute_rows(labels_pad, perm_back)
        Y_out = Y[jnp.asarray(perm_back.inv_perm)][:n]
    else:
        labels = labels_pad[:n]
        Y_out = Y[:n]
    return SpectralResult(labels=labels, embedding=Y_out, eigenvalues=evals,
                          centers=km_state.centers, sigma=sigma,
                          info={"lanczos_steps": steps, "n_pad": n_pad,
                                "mode": cfg.mode})


def fit_from_similarity(S: jax.Array, cfg: SpectralConfig,
                        mesh: Optional[Mesh] = None) -> SpectralResult:
    """Cluster from a precomputed similarity/adjacency matrix (the paper's
    §5 graph dataset).  S is (n, n) symmetric non-negative; it is padded and
    row-sharded over the mesh, then phases 2-3 run as in :func:`fit`."""
    S = jnp.asarray(S, cfg.dtype)
    n = int(S.shape[0])
    mesh = mesh or mesh_utils.local_mesh("rows")
    m = mesh_utils.mesh_size(mesh)
    n_pad = mesh_utils.pad_to_multiple(n, m)
    axes = mesh_utils.flat_axes(mesh)
    Sp = jnp.zeros((n_pad, n_pad), cfg.dtype).at[:n, :n].set(S)
    Sp = jax.lax.with_sharding_constraint(
        Sp, NamedSharding(mesh, P(axes, None)))
    valid = (jnp.arange(n_pad) < n).astype(cfg.dtype)
    deg = Sp @ valid
    inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)

    def matvec(v):
        return valid * v + inv_sqrt * (Sp @ (inv_sqrt * v))

    key = jax.random.PRNGKey(cfg.seed)
    k_lan, k_km = jax.random.split(key)
    steps = _num_steps(cfg, n)
    state = lz.lanczos(matvec, n_pad, steps, k_lan, dtype=cfg.dtype)
    evals, Z = lz.topk_of_shifted(state, cfg.k)
    Y = km.normalize_rows(Z) * valid[:, None]
    Y = jax.lax.with_sharding_constraint(Y, NamedSharding(mesh, P(axes, None)))
    labels_pad, km_state = km.distributed_kmeans(
        Y, valid, cfg.k, k_km, mesh, iters=cfg.kmeans_iters)
    return SpectralResult(labels=labels_pad[:n], embedding=Y[:n],
                          eigenvalues=evals, centers=km_state.centers,
                          sigma=jnp.asarray(0.0), info={"mode": "similarity"})


def fit_dense(x: jax.Array, cfg: SpectralConfig) -> SpectralResult:
    """Single-device oracle: dense S, exact eigh, plain k-means."""
    x = jnp.asarray(x, cfg.dtype)
    sigma = jnp.asarray(cfg.sigma, cfg.dtype) if cfg.sigma is not None \
        else sim.median_sigma(x)
    S = sim.dense_similarity(x, sigma)
    if cfg.sparsify_t:
        S = sim.sparsify_topt(S, cfg.sparsify_t)
    L = lp.dense_lsym(S)
    evals, evecs = jnp.linalg.eigh(L)
    Z = evecs[:, : cfg.k]
    Y = km.normalize_rows(Z)
    labels, centers = km.kmeans(Y, cfg.k, jax.random.PRNGKey(cfg.seed),
                                iters=cfg.kmeans_iters)
    return SpectralResult(labels=labels, embedding=Y,
                          eigenvalues=evals[: cfg.k], centers=centers,
                          sigma=sigma, info={"mode": "dense"})
