"""Phase 1 of the paper: parallel similarity-matrix construction.

The paper computes only the upper triangle of the RBF similarity matrix
(S is symmetric) and balances load by assigning row ``i`` and row ``n-i+1``
to the same worker (Alg. 4.2).  On a TPU mesh the same idea becomes a
*block-triangular schedule*: the ``n`` (padded) rows are split into ``2m``
blocks (``m`` = number of devices); device ``d`` owns blocks ``d`` and
``2m-1-d``, so every device computes exactly ``2m+1`` upper-triangle tiles
of size ``b×b`` — perfectly balanced, like the paper's pairing.

Rows are stored *block-permuted* so each device's two blocks are contiguous
(a NamedSharding over dim 0).  Columns stay in the same permuted order, so
the result ``U`` is the masked upper triangle of the (permuted) similarity
matrix: S_perm = U + Uᵀ - diag(U).

Two execution modes:
  * ``triangular`` (paper-faithful): each unordered pair computed once;
    downstream consumers either materialize S (transpose = all-to-all,
    like Hadoop's shuffle) or use :func:`sym_matvec` which never
    materializes the mirror (beyond-paper optimization).
  * ``full`` (beyond-paper trade): every device computes its whole row
    block — 2x the pair-FLOPs, but zero mirror communication and no
    permutation bookkeeping.
"""
from __future__ import annotations

from dataclasses import dataclass
from typing import Any

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.distrib import mesh_utils


# ---------------------------------------------------------------------------
# Dense / reference pieces (also used inside the sharded kernels)
# ---------------------------------------------------------------------------

def pairwise_sq_dists(x: jax.Array, y: jax.Array) -> jax.Array:
    """||x_i - y_j||^2 via the MXU-friendly decomposition."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = x @ y.T
    return jnp.maximum(xx + yy - 2.0 * xy, 0.0)


def rbf_kernel(x: jax.Array, y: jax.Array, sigma: float | jax.Array) -> jax.Array:
    """S_ij = exp(-||x_i-y_j||^2 / (2 sigma^2))  (paper §3.2.3)."""
    return jnp.exp(-pairwise_sq_dists(x, y) / (2.0 * sigma**2))


def dense_similarity(x: jax.Array, sigma: float | jax.Array) -> jax.Array:
    return rbf_kernel(x, x, sigma)


def median_sigma(x: jax.Array, sample: int = 1024) -> jax.Array:
    """Median-distance heuristic for the RBF bandwidth."""
    xs = x[: min(sample, x.shape[0])]
    d2 = pairwise_sq_dists(xs, xs)
    n = d2.shape[0]
    off = d2[jnp.triu_indices(n, k=1)]
    return jnp.sqrt(jnp.median(off) + 1e-12)


def sparsify_topt(S: jax.Array, t: int) -> jax.Array:
    """Keep the top-``t`` entries per row (paper step 1 "and then sparse it"),
    then symmetrize with max(S, S^T) so the graph stays undirected."""
    n = S.shape[0]
    t = min(t, n)
    thresh = -jnp.sort(-S, axis=1)[:, t - 1][:, None]
    St = jnp.where(S >= thresh, S, 0.0)
    return jnp.maximum(St, St.T)


# ---------------------------------------------------------------------------
# Block-triangular schedule (the paper's i / n-i+1 pairing, block level)
# ---------------------------------------------------------------------------

@dataclass(frozen=True)
class BlockSchedule:
    """Host-side static schedule for the triangular mode.

    n:        true number of points
    n_pad:    padded to a multiple of 2*m
    m:        number of devices (flattened mesh)
    b:        tile side = n_pad // (2m)
    perm:     (n_pad,) permuted-row -> original-row index map
    inv_perm: (n_pad,) original-row -> permuted-row
    table:    (m, 2m+1, 3) int32: [local sub-block (0/1), col block, is_diag]
    """

    n: int
    n_pad: int
    m: int
    b: int
    perm: np.ndarray
    inv_perm: np.ndarray
    table: np.ndarray


def make_schedule(n: int, m: int) -> BlockSchedule:
    n_pad = mesh_utils.pad_to_multiple(n, 2 * m)
    b = n_pad // (2 * m)
    # Block-interleave: device d owns original blocks {d, 2m-1-d} contiguously.
    block_of_dev = np.stack([np.arange(m), 2 * m - 1 - np.arange(m)], axis=1)  # (m, 2)
    perm_blocks = block_of_dev.reshape(-1)  # permuted block p -> original block
    perm = (perm_blocks[:, None] * b + np.arange(b)[None, :]).reshape(-1)
    inv_perm = np.argsort(perm)
    # orig block id of permuted block p
    orig_of_perm = perm_blocks
    # For each device: tiles (p_local, q) with orig(p) <= orig(q); q is a
    # *permuted* column block (columns live in permuted order too).
    rows_per_dev = []
    for d in range(m):
        entries = []
        for p_local in range(2):
            op = block_of_dev[d, p_local]
            for q in range(2 * m):
                oq = orig_of_perm[q]
                if op <= oq:
                    entries.append((p_local, q, 1 if op == oq else 0))
        assert len(entries) == 2 * m + 1, (d, len(entries))
        rows_per_dev.append(entries)
    table = np.asarray(rows_per_dev, dtype=np.int32)  # (m, 2m+1, 3)
    return BlockSchedule(n=n, n_pad=n_pad, m=m, b=b, perm=perm,
                         inv_perm=inv_perm, table=table)


@jax.tree_util.register_pytree_node_class
@dataclass
class UpperSim:
    """Row-sharded masked-upper similarity in block-permuted order."""

    U: jax.Array          # (n_pad, n_pad) row-sharded; zero below the schedule triangle
    diag: jax.Array       # (n_pad,) diagonal of S (1.0 on valid points, 0 on pad)
    schedule: Any         # BlockSchedule (static)
    mesh: Any             # Mesh (static)
    axis: str             # mesh axis name used for row sharding (flattened)

    def tree_flatten(self):
        return (self.U, self.diag), (self.schedule, self.mesh, self.axis)

    def tree_unflatten(aux, children):
        U, diag = children
        schedule, mesh, axis = aux
        return UpperSim(U=U, diag=diag, schedule=schedule, mesh=mesh, axis=axis)

    tree_unflatten = staticmethod(tree_unflatten)


def _row_axes(mesh: Mesh) -> tuple[str, ...]:
    return mesh_utils.flat_axes(mesh)


def similarity_upper_blocks(
    x: jax.Array,
    sigma: float | jax.Array,
    mesh: Mesh,
    schedule: BlockSchedule | None = None,
) -> UpperSim:
    """Paper-faithful phase 1: balanced triangular tile computation.

    ``x`` is (n, d) replicated (points are small next to the n x n matrix —
    same assumption as the paper storing them in an HBase table every worker
    reads).  Returns the permuted, row-sharded upper blocks.
    """
    axes = _row_axes(mesh)
    m = mesh_utils.mesh_size(mesh)
    sched = schedule or make_schedule(int(x.shape[0]), m)
    n, n_pad, b = sched.n, sched.n_pad, sched.b
    d_feat = x.shape[1]

    xp = jnp.zeros((n_pad, d_feat), x.dtype).at[: n].set(x)[sched.perm]
    table = jnp.asarray(sched.table)            # (m, 2m+1, 3)
    valid_perm = jnp.asarray((sched.perm < n))  # (n_pad,) bool, permuted order
    sigma = jnp.asarray(sigma, x.dtype)

    axis = axes[0] if len(axes) == 1 else axes  # shard_map spec entry
    n_tiles = 2 * m + 1

    def body(x_local, table_local, valid_local):
        # x_local: (2b, d) this device's two permuted blocks
        # table_local: (1, 2m+1, 3); valid_local: (2b,)
        x_full = lax.all_gather(x_local, axis, tiled=True)       # (n_pad, d)
        valid_full = lax.all_gather(valid_local, axis, tiled=True)
        tbl = table_local[0]

        def tile_step(t, U):
            p_local = tbl[t, 0]
            q = tbl[t, 1]
            is_diag = tbl[t, 2]
            rows = lax.dynamic_slice(x_local, (p_local * b, 0), (b, d_feat))
            cols = lax.dynamic_slice(x_full, (q * b, 0), (b, d_feat))
            tile = rbf_kernel(rows, cols, sigma)
            # diagonal tile: keep upper-inclusive only (pairs counted once)
            tri = jnp.triu(jnp.ones((b, b), tile.dtype))
            tile = jnp.where(is_diag > 0, tile * tri, tile)
            # padding mask
            rv = lax.dynamic_slice(valid_local, (p_local * b,), (b,))
            cv = lax.dynamic_slice(valid_full, (q * b,), (b,))
            tile = tile * rv[:, None].astype(tile.dtype) * cv[None, :].astype(tile.dtype)
            return lax.dynamic_update_slice(U, tile, (p_local * b, q * b))

        U_local = jnp.zeros((2 * b, n_pad), x.dtype)
        U_local = mesh_utils.pvary(U_local, tuple(axes))  # mark carry device-varying
        U_local = lax.fori_loop(0, n_tiles, tile_step, U_local)
        return U_local

    shard = mesh_utils.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes, None, None), P(axes)),
        out_specs=P(axes, None),
    )
    U = shard(xp, table, valid_perm)
    diag = valid_perm.astype(x.dtype)  # RBF diagonal is exp(0) = 1 on valid rows
    return UpperSim(U=U, diag=diag, schedule=sched, mesh=mesh, axis=axes)


def sym_matmat(upper: UpperSim, V: jax.Array) -> jax.Array:
    """S @ V without materializing the mirror:  SV = UV + UᵀV - diag*V.

    ``V`` replicated (n_pad, b), result replicated (n_pad, b).  One psum
    per call *regardless of the block width* — each device streams its
    row block of U once and amortizes it over all b columns, the matmat
    generalization of the paper's "move the vector to the data" MapReduce
    (with the transpose term folded in locally; Hadoop would store both
    triangles or shuffle twice).
    """
    sched: BlockSchedule = upper.schedule
    mesh = upper.mesh
    axes = upper.axis
    axis = axes[0] if len(axes) == 1 else axes
    b2 = 2 * sched.b
    width = int(V.shape[1])

    def body(U_local, diag_local, V_full):
        idx = lax.axis_index(axis)
        r0 = idx * b2
        V_rows = lax.dynamic_slice(V_full, (r0, 0), (b2, width))
        part = jnp.zeros_like(V_full)
        part = lax.dynamic_update_slice(part, U_local @ V_full, (r0, 0))
        part = part + U_local.T @ V_rows
        part = part - lax.dynamic_update_slice(
            jnp.zeros_like(V_full), diag_local[:, None] * V_rows, (r0, 0))
        return lax.psum(part, axis)

    shard = mesh_utils.shard_map(
        body,
        mesh=mesh,
        in_specs=(P(axes, None), P(axes), P()),
        out_specs=P(),
    )
    return shard(upper.U, upper.diag, V)


def sym_matvec(upper: UpperSim, v: jax.Array) -> jax.Array:
    """S @ v — the width-1 view of :func:`sym_matmat`."""
    return sym_matmat(upper, v[:, None])[:, 0]


def materialize(upper: UpperSim) -> jax.Array:
    """Full symmetric S (row-sharded, permuted order): U + Uᵀ - diag.

    The transpose of a row-sharded matrix is GSPMD's all-to-all — the direct
    analogue of the Hadoop shuffle that mirrors the triangle.
    """
    S = upper.U + upper.U.T - jnp.diag(upper.diag)
    axes = upper.axis
    return lax.with_sharding_constraint(
        S, NamedSharding(upper.mesh, P(axes, None)))


@jax.tree_util.register_pytree_node_class
@dataclass
class UpperSimCompact:
    """Triangular similarity stored as COMPACT per-device tile stacks
    (n_tiles, b, b) instead of the wide (2b, n_pad) row blocks.

    Perf iteration S1 (EXPERIMENTS.md §Perf): the wide layout pays a
    dynamic-update-slice into a 2b x n_pad buffer per tile — XLA
    materializes copies, ~100x the useful traffic.  The compact layout
    writes each tile once; sym_matvec reads each tile once and touches
    only two b-slices of the vector per tile.
    """

    tiles: jax.Array      # (m * (2m+1), b, b) sharded on dim 0
    diag: jax.Array       # (n_pad,) diagonal of S
    schedule: Any
    mesh: Any
    axis: Any

    def tree_flatten(self):
        return (self.tiles, self.diag), (self.schedule, self.mesh, self.axis)

    @staticmethod
    def tree_unflatten(aux, children):
        tiles, diag = children
        schedule, mesh, axis = aux
        return UpperSimCompact(tiles=tiles, diag=diag, schedule=schedule,
                               mesh=mesh, axis=axis)


def similarity_upper_blocks_compact(
    x: jax.Array,
    sigma: float | jax.Array,
    mesh: Mesh,
    schedule: BlockSchedule | None = None,
) -> UpperSimCompact:
    """Paper-faithful balanced triangular schedule, compact tile storage."""
    axes = _row_axes(mesh)
    m = mesh_utils.mesh_size(mesh)
    sched = schedule or make_schedule(int(x.shape[0]), m)
    n, n_pad, b = sched.n, sched.n_pad, sched.b
    d_feat = x.shape[1]

    xp = jnp.zeros((n_pad, d_feat), x.dtype).at[:n].set(x)[sched.perm]
    table = jnp.asarray(sched.table)
    valid_perm = jnp.asarray(sched.perm < n)
    sigma = jnp.asarray(sigma, x.dtype)
    axis = axes[0] if len(axes) == 1 else axes
    n_tiles = 2 * m + 1

    def body(x_local, table_local, valid_local):
        x_full = lax.all_gather(x_local, axis, tiled=True)
        valid_full = lax.all_gather(valid_local, axis, tiled=True)
        tbl = table_local[0]

        def one_tile(_, t):
            p_local, q, is_diag = tbl[t, 0], tbl[t, 1], tbl[t, 2]
            rows = lax.dynamic_slice(x_local, (p_local * b, 0), (b, d_feat))
            cols = lax.dynamic_slice(x_full, (q * b, 0), (b, d_feat))
            tile = rbf_kernel(rows, cols, sigma)
            tri = jnp.triu(jnp.ones((b, b), tile.dtype))
            tile = jnp.where(is_diag > 0, tile * tri, tile)
            rv = lax.dynamic_slice(valid_local, (p_local * b,), (b,))
            cv = lax.dynamic_slice(valid_full, (q * b,), (b,))
            return None, tile * rv[:, None].astype(tile.dtype) * cv[None, :].astype(tile.dtype)

        _, tiles = lax.scan(one_tile, None, jnp.arange(n_tiles))
        return tiles

    shard = mesh_utils.shard_map(
        body, mesh=mesh,
        in_specs=(P(axes, None), P(axes, None, None), P(axes)),
        out_specs=P(axes, None, None),
    )
    tiles = shard(xp, table, valid_perm)
    return UpperSimCompact(tiles=tiles, diag=valid_perm.astype(x.dtype),
                           schedule=sched, mesh=mesh, axis=axes)


def sym_matmat_compact(upper: UpperSimCompact, V: jax.Array) -> jax.Array:
    """S @ V from compact tiles: each tile is read ONCE PER BLOCK (not
    once per vector); only two b-row slices of the block are touched per
    tile; one psum combines."""
    sched: BlockSchedule = upper.schedule
    axes = upper.axis
    axis = axes[0] if len(axes) == 1 else axes
    b = sched.b
    m = sched.m
    n_tiles = 2 * m + 1
    width = int(V.shape[1])

    def body(tiles_local, table_local, diag_local, V_full):
        idx = lax.axis_index(axis)
        dev_r0 = idx * 2 * b
        tbl = table_local[0]

        def one(t, partial):
            p_local, q = tbl[t, 0], tbl[t, 1]
            r0 = dev_r0 + p_local * b
            c0 = q * b
            tile = tiles_local[t]
            Vr = lax.dynamic_slice(V_full, (r0, 0), (b, width))
            Vc = lax.dynamic_slice(V_full, (c0, 0), (b, width))
            # rows += tile @ V[cols]
            cur = lax.dynamic_slice(partial, (r0, 0), (b, width))
            partial = lax.dynamic_update_slice(partial, cur + tile @ Vc,
                                               (r0, 0))
            # cols += tile^T @ V[rows]  (the mirror, never materialized)
            cur = lax.dynamic_slice(partial, (c0, 0), (b, width))
            partial = lax.dynamic_update_slice(partial, cur + tile.T @ Vr,
                                               (c0, 0))
            return partial

        partial = jnp.zeros_like(V_full)
        partial = mesh_utils.pvary(partial, tuple(axes))
        partial = lax.fori_loop(0, n_tiles, one, partial)
        # diagonal tiles contribute their diagonal twice via the mirror
        Vr2 = lax.dynamic_slice(V_full, (dev_r0, 0), (2 * b, width))
        corr = lax.dynamic_update_slice(
            jnp.zeros_like(V_full), diag_local[:, None] * Vr2, (dev_r0, 0))
        return lax.psum(partial - corr, axis)

    shard = mesh_utils.shard_map(
        body, mesh=upper.mesh,
        in_specs=(P(axes, None, None), P(axes, None, None), P(axes), P()),
        out_specs=P(),
    )
    table = jnp.asarray(sched.table)
    return shard(upper.tiles, table, upper.diag, V)


def sym_matvec_compact(upper: UpperSimCompact, v: jax.Array) -> jax.Array:
    """S @ v — the width-1 view of :func:`sym_matmat_compact`."""
    return sym_matmat_compact(upper, v[:, None])[:, 0]


def materialize_compact(upper: UpperSimCompact) -> jax.Array:
    """Full symmetric S (permuted order) from the compact tile stacks.

    The schedule table is host-static, so this is a plain unrolled scatter —
    used by the exact-eigh backend, not by the iterative path.
    """
    sched: BlockSchedule = upper.schedule
    b, m = sched.b, sched.m
    n_tiles = 2 * m + 1
    U = jnp.zeros((sched.n_pad, sched.n_pad), upper.tiles.dtype)
    for d in range(m):
        for t, (p_local, q, _is_diag) in enumerate(sched.table[d]):
            r0 = d * 2 * b + int(p_local) * b
            c0 = int(q) * b
            U = U.at[r0:r0 + b, c0:c0 + b].set(upper.tiles[d * n_tiles + t])
    return U + U.T - jnp.diag(upper.diag)


def distributed_similarity_full(
    x: jax.Array, sigma: float | jax.Array, mesh: Mesh
) -> jax.Array:
    """Beyond-paper "full" mode: each device computes its whole row block.

    2x pair-FLOPs vs triangular, but no mirror/all-to-all and no permutation.
    Returns (n_pad, n_pad) row-sharded symmetric S in *original* order.
    """
    axes = _row_axes(mesh)
    m = mesh_utils.mesh_size(mesh)
    n = int(x.shape[0])
    n_pad = mesh_utils.pad_to_multiple(n, m)
    d_feat = x.shape[1]
    xp = jnp.zeros((n_pad, d_feat), x.dtype).at[:n].set(x)
    valid = (jnp.arange(n_pad) < n)
    sigma = jnp.asarray(sigma, x.dtype)
    axis = axes[0] if len(axes) == 1 else axes

    def body(x_local, valid_local):
        x_full = lax.all_gather(x_local, axis, tiled=True)
        valid_full = lax.all_gather(valid_local, axis, tiled=True)
        S_local = rbf_kernel(x_local, x_full, sigma)
        S_local = S_local * valid_local[:, None].astype(S_local.dtype)
        S_local = S_local * valid_full[None, :].astype(S_local.dtype)
        return S_local

    shard = mesh_utils.shard_map(
        body, mesh=mesh, in_specs=(P(axes, None), P(axes)), out_specs=P(axes, None)
    )
    return shard(xp, valid)


def unpermute_rows(values_perm: jax.Array, schedule: BlockSchedule) -> jax.Array:
    """Map a per-(permuted-)row vector back to original point order."""
    return values_perm[jnp.asarray(schedule.inv_perm)][: schedule.n]


def permute_rows(values: jax.Array, schedule: BlockSchedule) -> jax.Array:
    n_pad = schedule.n_pad
    padded = jnp.zeros((n_pad,) + values.shape[1:], values.dtype).at[: schedule.n].set(values)
    return padded[jnp.asarray(schedule.perm)]
