# The paper's primary contribution: parallel spectral clustering
# (similarity -> Lanczos eigenvectors -> k-means), distributed over a
# device mesh via shard_map. See DESIGN.md for the Hadoop -> TPU mapping.
from repro.core.spectral import SpectralConfig, SpectralResult, fit, fit_dense
