# The paper's primary contribution: parallel spectral clustering
# (similarity -> Lanczos eigenvectors -> k-means), distributed over a
# device mesh via shard_map. See DESIGN.md for the Hadoop -> TPU mapping.
#
# The public entry point is repro.cluster.SpectralClustering (pluggable
# affinity/eigensolver/assigner backends); the functions re-exported here
# are deprecated shims kept for existing callers.
from repro.core.spectral import (  # noqa: F401
    SpectralConfig,
    SpectralResult,
    fit,
    fit_dense,
    fit_from_similarity,
)
