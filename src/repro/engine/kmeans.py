"""Streaming mini-batch k-means: phase 3 when the embedding is consumed
row-chunk by row-chunk.

Sculley's per-center learning-rate update (the same math as
``core.kmeans.minibatch_kmeans``) with the mini-batch being one embedding
chunk per round — the natural fit for the engine, where embedding rows
arrive in row-range order and nothing requires holding all n rows hot.
Host-side numpy throughout: the embedding is (chunk, k), far below any
device-memory concern, and determinism comes from one seeded RandomState.
"""
from __future__ import annotations

from typing import Callable, Optional

import numpy as np

from repro.core.seeding import kmeans_plusplus_np as _kmeanspp


def _sq_dists(y: np.ndarray, centers: np.ndarray) -> np.ndarray:
    yy = np.sum(y * y, axis=1)[:, None]
    cc = np.sum(centers * centers, axis=1)[None, :]
    return np.maximum(yy + cc - 2.0 * (y @ centers.T), 0.0)


def streaming_kmeans(get_chunk: Callable[[int], np.ndarray], nchunks: int,
                     k: int, *, rounds: int = 50, seed: int = 0,
                     sample_rows: int = 4096,
                     valid_chunk: Optional[Callable[[int], np.ndarray]] = None
                     ) -> tuple[np.ndarray, np.ndarray]:
    """Cluster rows served chunk-by-chunk by ``get_chunk(c)``.

    Three streaming passes over the chunks: (1) reservoir-style sample for
    the k-means++ init, (2) ``rounds`` Sculley updates, each consuming one
    chunk (in seeded random order) as the mini-batch, (3) a final
    assignment pass.  ``valid_chunk(c)`` optionally masks rows (padding);
    masked rows get label of their nearest center anyway but never move
    centers.  Returns ``(labels (n,), centers (k, dim))``.
    """
    rng = np.random.RandomState(seed)

    # Pass 1: sample rows across chunks for the ++ init.
    sample, sample_w = [], []
    per_chunk = max(k, sample_rows // max(nchunks, 1))
    for c in range(nchunks):
        y = np.asarray(get_chunk(c), np.float64)
        w = np.ones(len(y)) if valid_chunk is None \
            else np.asarray(valid_chunk(c), np.float64)
        take = min(per_chunk, len(y))
        idx = rng.choice(len(y), take, replace=False)
        sample.append(y[idx])
        sample_w.append(w[idx])
    sample = np.concatenate(sample)
    sample_w = np.concatenate(sample_w)
    if sample_w.sum() <= 0:
        sample_w = np.ones_like(sample_w)
    centers = _kmeanspp(sample, k, rng, sample_w)

    # Pass 2: Sculley rounds, one chunk per round.
    counts = np.zeros(k)
    order = rng.permutation(nchunks)
    for r in range(rounds):
        c = int(order[r % nchunks])
        if r % nchunks == nchunks - 1:
            order = rng.permutation(nchunks)
        y = np.asarray(get_chunk(c), np.float64)
        w = np.ones(len(y)) if valid_chunk is None \
            else np.asarray(valid_chunk(c), np.float64)
        a = np.argmin(_sq_dists(y, centers), axis=1)
        onehot = np.zeros((len(y), k))
        onehot[np.arange(len(y)), a] = w
        bc = onehot.sum(axis=0)
        bmean = (onehot.T @ y) / np.maximum(bc[:, None], 1.0)
        counts += bc
        lr = bc / np.maximum(counts, 1.0)
        moved = bc > 0
        centers[moved] += lr[moved, None] * (bmean[moved] - centers[moved])

    # Pass 3: final assignment, chunk by chunk.
    labels = [np.argmin(_sq_dists(np.asarray(get_chunk(c), np.float64),
                                  centers), axis=1)
              for c in range(nchunks)]
    return np.concatenate(labels).astype(np.int32), centers
