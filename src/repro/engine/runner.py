"""The job runner: executes a :class:`JobPlan` as map/shuffle/reduce
tasks and drives the eigensolve + streaming k-means off the resulting
shards — ``engine.run_job(plan, reader)`` is the out-of-core analogue of
``SpectralClustering.fit``.

The build is a **dependency-driven scheduler** over a worker pool of
``plan.workers`` threads (the Hadoop fan-out, one host): each chunk's
shuffle is submitted the moment its last input tile lands — no per-stage
barrier — and the reduces fan out the instant the final shuffle finishes
(a reduce folds mirror blocks that ANY shuffle may emit, the same
all-map-outputs dependency Hadoop's reduce fetch has).  All state between
tasks lives in the thread-safe ShardStore, so the working set is bounded
by the memory budget regardless of n; tasks never share mutable state
beyond it, and each task's arithmetic is order-independent, so results
are bitwise-identical at every pool width (``workers=1`` reproduces the
classic sequential schedule exactly).
"""
from __future__ import annotations

import threading
import time
from concurrent.futures import FIRST_COMPLETED, ThreadPoolExecutor, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import kmeans as km
from repro.core import lanczos as lz
from repro.core import similarity as sim
from repro.engine import kmeans as skm
from repro.engine import tasks
from repro.engine.operator import (ShardedCSRGraph, make_normalized_operator)
from repro.engine.plan import JobPlan, route_path
from repro.engine.store import ShardStore


@dataclass
class JobResult:
    labels: np.ndarray           # (n,) int32
    embedding: np.ndarray        # (n, k) row-normalized
    eigenvalues: np.ndarray      # (k,) smallest of L_sym, ascending
    centers: np.ndarray          # (k, k)
    sigma: float
    graph: Optional[ShardedCSRGraph]   # None on the fused (matrix-free) path
    stats: Dict = field(default_factory=dict)


def _resolve_sigma(reader, plan: JobPlan, sample_rows: int = 1024) -> float:
    """Median-distance heuristic on a sample STRIDED across all chunks.

    Sampling only the leading chunks (the pre-PR8 behaviour) skews sigma
    whenever the chunk order is meaningful — class-sorted data would
    estimate the bandwidth of one cluster instead of the dataset — so up
    to 8 evenly-spaced chunks each contribute an equal share of the
    sample."""
    if plan.sigma is not None:
        return float(plan.sigma)
    nc = plan.nchunks
    idx = np.unique(np.linspace(0, nc - 1, min(nc, 8)).round().astype(int))
    per = -(-sample_rows // len(idx))            # equal share per chunk
    xs = np.concatenate([np.asarray(reader[int(c)])[:per]
                         for c in idx])[:sample_rows]
    return float(sim.median_sigma(jnp.asarray(xs)))


def _schedule_build(reader, sigma, plan: JobPlan, store: ShardStore,
                    overlap_work: Optional[Callable[[], None]] = None
                    ) -> tuple[np.ndarray, int, Dict]:
    """Run every map/shuffle/reduce task on a ``plan.workers``-wide pool,
    releasing each task the moment its inputs exist:

      map (i, j)   no deps — all submitted up front
      shuffle c    the map tiles touching chunk c (row i == c or j == c)
      reduce c     ALL shuffles (any shuffle may mirror triplets into c)

    ``overlap_work`` (if given) runs ONCE on the scheduler thread as soon
    as the last shuffle finishes — i.e. while the reduce tail is still
    draining on the workers — so callers can overlap eigensolver seeding
    with the end of the build.  Returns (deg, nnz, stats)."""
    tiles = plan.tiles
    nc = plan.nchunks
    workers = max(1, int(plan.workers))
    busy = {"map": 0.0, "shuffle": 0.0, "reduce": 0.0}
    busy_lock = threading.Lock()
    deg = np.zeros(plan.n, np.float32)
    nnz_total = 0

    def timed(stage, fn, *args):
        t0 = time.perf_counter()
        out = fn(*args)
        with busy_lock:
            busy[stage] += time.perf_counter() - t0
        return out

    waiting = {c: {tl for tl in tiles if c in tl} for c in range(nc)}
    shuffles_left = nc
    overlap_pending = overlap_work is not None
    t_start = time.perf_counter()
    with ThreadPoolExecutor(max_workers=workers,
                            thread_name_prefix="repro-engine-task") as pool:
        futures: Dict = {}

        def submit(kind, key, fn):
            futures[pool.submit(fn)] = (kind, key)

        for (i, j) in tiles:
            submit("map", (i, j),
                   lambda i=i, j=j: timed("map", tasks.run_map_task,
                                          reader, sigma, plan, i, j, store))
        while futures:
            if overlap_pending and shuffles_left == 0:
                overlap_pending = False          # reduce tail is draining
                overlap_work()
            done, _ = wait(list(futures), return_when=FIRST_COMPLETED)
            for fut in done:
                kind, key = futures.pop(fut)
                out = fut.result()               # propagate task errors
                if kind == "map":
                    for c in set(key):
                        deps = waiting[c]
                        deps.discard(key)
                        if not deps:             # last tile for chunk c
                            submit("shuffle", c, lambda c=c: timed(
                                "shuffle", tasks.run_shuffle_task,
                                plan, c, store))
                elif kind == "shuffle":
                    shuffles_left -= 1
                    if shuffles_left == 0:       # mirrors all emitted
                        for c in range(nc):
                            submit("reduce", c, lambda c=c: timed(
                                "reduce", tasks.run_reduce_task,
                                plan, c, store))
                else:                            # reduce: disjoint slices
                    r0, r1 = plan.ranges[key]
                    deg[r0:r1] = out["deg"]
                    nnz_total += out["nnz"]
    if overlap_pending:                          # degenerate tiny jobs
        overlap_work()
    wall = time.perf_counter() - t_start
    busy_s = sum(busy.values())
    stats = {
        "map_tasks": len(tiles), "shuffle_tasks": nc, "reduce_tasks": nc,
        "chunks": nc, "chunk_size": plan.chunk_size, "t": plan.t_eff,
        "workers": workers, "prefetch_depth": plan.prefetch_depth,
        # per-stage numbers are BUSY task-seconds (the stages interleave,
        # so they no longer tile a wall-clock interval); overlap_s is the
        # task-seconds the pool hid inside the build wall
        "map_s": round(busy["map"], 4),
        "shuffle_s": round(busy["shuffle"], 4),
        "reduce_s": round(busy["reduce"], 4),
        "build_wall_s": round(wall, 4),
        "overlap_s": round(max(0.0, busy_s - wall), 4),
    }
    return deg, nnz_total, stats


def build_graph(reader, plan: JobPlan,
                store: Optional[ShardStore] = None,
                overlap_work: Optional[Callable[[], None]] = None,
                prewarm: bool = True) -> tuple[ShardedCSRGraph, float]:
    """Run the map + shuffle + reduce stages on the dependency-driven
    scheduler; returns the sharded graph (with per-stage stats attached)
    and the resolved sigma.  See :func:`_schedule_build` for the task
    dependency structure and the ``overlap_work`` hook.

    ``prewarm`` starts the first shard-window fetches before returning,
    so the consumer's first pass starts hot (off for A/B baselines)."""
    store = store or ShardStore(memory_budget=plan.memory_budget,
                                spill_dir=plan.spill_dir,
                                async_spill=plan.async_spill)
    sigma = _resolve_sigma(reader, plan)
    with obs.span("engine.build", path="ooc", workers=plan.workers,
                  tasks=len(plan.tiles) + 2 * plan.nchunks):
        deg, nnz, stats = _schedule_build(reader, sigma, plan, store,
                                          overlap_work=overlap_work)
    for key in ("map_tasks", "shuffle_tasks", "reduce_tasks"):
        obs.counter(f"engine.{key}").inc(stats[key])
    graph = ShardedCSRGraph(store=store, plan=plan, deg=deg, nnz=nnz,
                            stats=stats)
    if prewarm:
        graph.prewarm()
    return graph, sigma


def _run_fused(plan: JobPlan, reader) -> JobResult:
    """The planner's fused route: the points fit in memory even though the
    dense similarity would not, so instead of spilling CSR shards the job
    runs the matrix-free fused-RBF operator (O(n*d) affinity memory) with
    the same block eigensolve + streaming k-means tail as the ooc path."""
    from repro.cluster.affinity import build_fused_rbf_operator
    from repro.distrib import mesh_utils

    sigma = _resolve_sigma(reader, plan)
    x = np.concatenate([np.asarray(reader[c], np.float32)
                        for c in range(plan.nchunks)])
    mesh = mesh_utils.local_mesh("rows")
    with obs.span("engine.build", path="fused") as sp_build:
        op = build_fused_rbf_operator(jnp.asarray(x), sigma, mesh,
                                      compute_dtype=plan.compute_dtype)

    key = jax.random.PRNGKey(plan.seed)
    _, k_lan, _k_km = jax.random.split(key, 3)
    b = plan.eff_block_size()
    block_steps = plan.num_block_steps()
    with obs.span("engine.eigensolve", path="fused",
                  block_steps=block_steps) as sp_eig:
        state = lz.block_lanczos(op.matmat, op.n_pad, block_steps, k_lan,
                                 block_size=b)
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)

    Y = np.asarray(km.normalize_rows(Z) * op.valid[:, None])[:plan.n]
    ranges = plan.ranges
    with obs.span("engine.kmeans", path="fused") as sp_km:
        labels, centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)

    stats = dict(op.stats_snapshot(), path="fused", chunks=plan.nchunks,
                 points_bytes=int(x.nbytes),
                 lanczos_steps=plan.num_lanczos_steps(),
                 block_size=b, block_steps=block_steps,
                 build_s=round(sp_build.duration_s, 4),
                 eigensolve_s=round(sp_eig.duration_s, 4),
                 kmeans_s=round(sp_km.duration_s, 4))
    obs.absorb_stats("engine", stats)
    return JobResult(labels=labels, embedding=Y,
                     eigenvalues=np.asarray(evals), centers=centers,
                     sigma=sigma, graph=None, stats=stats)


def run_job(plan: JobPlan, reader) -> JobResult:
    """Full out-of-core pipeline: dependency-scheduled graph build,
    shard-streaming block Lanczos, chunked mini-batch k-means.
    ``reader[c]`` must yield the (rows, d) point chunk for range
    ``plan.ranges[c]``.

    Phase 1 honours the planner's routing (:func:`repro.engine.plan.
    route_path`): jobs whose points fit the memory budget but whose dense
    similarity does not take the fused matrix-free path instead of
    spilling CSR shards (``plan.path`` forces either way).

    On the ooc path the eigensolve is the *block* recurrence: each block
    step pulls every CSR shard from the store exactly once and amortizes
    it over the b-wide block, so the same Krylov dimension costs ~1/b the
    shard loads (and spill-reloads) of the single-vector iteration.  The
    eigensolver's start block is drawn WHILE the reduce tail drains
    (bitwise-identical to drawing it after — same key, same shape), and
    the graph's prefetch pool is shut down before returning, so a job
    never strands background threads."""
    if plan.path == "fused":
        return _run_fused(plan, reader)
    if plan.path == "auto":         # probe d only when routing needs it
        d = int(np.asarray(reader[0]).shape[1])
        if route_path(plan, d) == "fused":
            return _run_fused(plan, reader)

    key = jax.random.PRNGKey(plan.seed)
    _, k_lan, _k_km = jax.random.split(key, 3)
    b = plan.eff_block_size()
    block_steps = plan.num_block_steps()
    seed_box: Dict = {}

    def _warm_start():
        # exactly the draw lz.init_block_state would make (same key,
        # shape, dtype -> bitwise-identical eigensolve), issued while the
        # reduce tail is still draining on the task pool
        seed_box["V0"] = jax.block_until_ready(
            jax.random.normal(k_lan, (b, plan.n), jnp.float32))

    graph, sigma = build_graph(reader, plan, overlap_work=_warm_start)
    op = make_normalized_operator(graph)

    with obs.span("engine.eigensolve", path="ooc",
                  block_steps=block_steps) as sp_eig:
        state = lz.block_lanczos(op.matmat, plan.n, block_steps, k_lan,
                                 block_size=b, V0=seed_box["V0"],
                                 host_matmat=op.host_matmat)
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)

    Y = np.asarray(km.normalize_rows(Z))
    ranges = plan.ranges
    with obs.span("engine.kmeans", path="ooc") as sp_km:
        labels, centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)

    stats = dict(graph.stats_snapshot(), path="ooc",
                 lanczos_steps=plan.num_lanczos_steps(),
                 block_size=b, block_steps=block_steps,
                 matrix_passes=block_steps,
                 eigensolve_s=round(sp_eig.duration_s, 4),
                 kmeans_s=round(sp_km.duration_s, 4))
    obs.absorb_stats("engine", stats)
    graph.close()                   # no stray prefetch threads after a job
    return JobResult(labels=labels, embedding=Y,
                     eigenvalues=np.asarray(evals), centers=centers,
                     sigma=sigma, graph=graph, stats=stats)
