"""The job runner: executes a :class:`JobPlan` as staged map/shuffle/reduce
tasks and drives the eigensolve + streaming k-means off the resulting
shards — ``engine.run_job(plan, reader)`` is the out-of-core analogue of
``SpectralClustering.fit``.

The runner is deliberately a dumb sequential scheduler: tasks within a
stage are independent (Hadoop would fan them out over workers; here they
share one host and the device executes the Pallas tiles), and all state
between stages lives in the ShardStore, so the working set is bounded by
the memory budget regardless of n.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import kmeans as km
from repro.core import lanczos as lz
from repro.core import similarity as sim
from repro.engine import kmeans as skm
from repro.engine import tasks
from repro.engine.operator import (ShardedCSRGraph, make_normalized_operator)
from repro.engine.plan import JobPlan, route_path
from repro.engine.store import ShardStore


@dataclass
class JobResult:
    labels: np.ndarray           # (n,) int32
    embedding: np.ndarray        # (n, k) row-normalized
    eigenvalues: np.ndarray      # (k,) smallest of L_sym, ascending
    centers: np.ndarray          # (k, k)
    sigma: float
    graph: Optional[ShardedCSRGraph]   # None on the fused (matrix-free) path
    stats: Dict = field(default_factory=dict)


def _resolve_sigma(reader, plan: JobPlan, sample_rows: int = 1024) -> float:
    """Median-distance heuristic on a streamed sample (first rows of the
    leading chunks; the heuristic only needs a representative handful)."""
    if plan.sigma is not None:
        return float(plan.sigma)
    rows, have = [], 0
    for c in range(plan.nchunks):
        x = np.asarray(reader[c])
        rows.append(x)
        have += len(x)
        if have >= sample_rows:
            break
    xs = np.concatenate(rows)[:sample_rows]
    return float(sim.median_sigma(jnp.asarray(xs)))


def build_graph(reader, plan: JobPlan,
                store: Optional[ShardStore] = None
                ) -> tuple[ShardedCSRGraph, float]:
    """Run the map + shuffle + reduce stages; returns the sharded graph
    (with per-stage stats attached) and the resolved sigma."""
    store = store or ShardStore(memory_budget=plan.memory_budget,
                                spill_dir=plan.spill_dir)
    sigma = _resolve_sigma(reader, plan)

    tiles = plan.tiles
    with obs.span("engine.map", tasks=len(tiles)) as sp_map:
        for (i, j) in tiles:
            tasks.run_map_task(reader, sigma, plan, i, j, store)

    with obs.span("engine.shuffle", tasks=plan.nchunks) as sp_shuf:
        for c in range(plan.nchunks):
            tasks.run_shuffle_task(plan, c, store)

    with obs.span("engine.reduce", tasks=plan.nchunks) as sp_red:
        deg = np.zeros(plan.n, np.float32)
        nnz = 0
        for c, (r0, r1) in enumerate(plan.ranges):
            out = tasks.run_reduce_task(plan, c, store)
            deg[r0:r1] = out["deg"]
            nnz += out["nnz"]

    # static stage counters only — live store numbers are merged in by
    # ShardedCSRGraph.stats_snapshot() at read time; stage walls come
    # from the spans (0.0 when obs is disabled)
    stats = {
        "map_tasks": len(tiles), "shuffle_tasks": plan.nchunks,
        "reduce_tasks": plan.nchunks, "chunks": plan.nchunks,
        "chunk_size": plan.chunk_size, "t": plan.t_eff,
        "map_s": round(sp_map.duration_s, 4),
        "shuffle_s": round(sp_shuf.duration_s, 4),
        "reduce_s": round(sp_red.duration_s, 4),
    }
    for key in ("map_tasks", "shuffle_tasks", "reduce_tasks"):
        obs.counter(f"engine.{key}").inc(stats[key])
    return ShardedCSRGraph(store=store, plan=plan, deg=deg, nnz=nnz,
                           stats=stats), sigma


def _run_fused(plan: JobPlan, reader) -> JobResult:
    """The planner's fused route: the points fit in memory even though the
    dense similarity would not, so instead of spilling CSR shards the job
    runs the matrix-free fused-RBF operator (O(n*d) affinity memory) with
    the same block eigensolve + streaming k-means tail as the ooc path."""
    from repro.cluster.affinity import build_fused_rbf_operator
    from repro.distrib import mesh_utils

    sigma = _resolve_sigma(reader, plan)
    x = np.concatenate([np.asarray(reader[c], np.float32)
                        for c in range(plan.nchunks)])
    mesh = mesh_utils.local_mesh("rows")
    with obs.span("engine.build", path="fused") as sp_build:
        op = build_fused_rbf_operator(jnp.asarray(x), sigma, mesh,
                                      compute_dtype=plan.compute_dtype)

    key = jax.random.PRNGKey(plan.seed)
    _, k_lan, _k_km = jax.random.split(key, 3)
    b = plan.eff_block_size()
    block_steps = plan.num_block_steps()
    with obs.span("engine.eigensolve", path="fused",
                  block_steps=block_steps) as sp_eig:
        state = lz.block_lanczos(op.matmat, op.n_pad, block_steps, k_lan,
                                 block_size=b)
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)

    Y = np.asarray(km.normalize_rows(Z) * op.valid[:, None])[:plan.n]
    ranges = plan.ranges
    with obs.span("engine.kmeans", path="fused") as sp_km:
        labels, centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)

    stats = dict(op.stats_snapshot(), path="fused", chunks=plan.nchunks,
                 points_bytes=int(x.nbytes),
                 lanczos_steps=plan.num_lanczos_steps(),
                 block_size=b, block_steps=block_steps,
                 build_s=round(sp_build.duration_s, 4),
                 eigensolve_s=round(sp_eig.duration_s, 4),
                 kmeans_s=round(sp_km.duration_s, 4))
    obs.absorb_stats("engine", stats)
    return JobResult(labels=labels, embedding=Y,
                     eigenvalues=np.asarray(evals), centers=centers,
                     sigma=sigma, graph=None, stats=stats)


def run_job(plan: JobPlan, reader) -> JobResult:
    """Full out-of-core pipeline: staged graph build, shard-streaming
    block Lanczos, chunked mini-batch k-means.  ``reader[c]`` must yield
    the (rows, d) point chunk for range ``plan.ranges[c]``.

    Phase 1 honours the planner's routing (:func:`repro.engine.plan.
    route_path`): jobs whose points fit the memory budget but whose dense
    similarity does not take the fused matrix-free path instead of
    spilling CSR shards (``plan.path`` forces either way).

    On the ooc path the eigensolve is the *block* recurrence: each block
    step pulls every CSR shard from the store exactly once and amortizes
    it over the b-wide block, so the same Krylov dimension costs ~1/b the
    shard loads (and spill-reloads) of the single-vector iteration."""
    if plan.path == "fused":
        return _run_fused(plan, reader)
    if plan.path == "auto":         # probe d only when routing needs it
        d = int(np.asarray(reader[0]).shape[1])
        if route_path(plan, d) == "fused":
            return _run_fused(plan, reader)
    graph, sigma = build_graph(reader, plan)
    op = make_normalized_operator(graph)

    key = jax.random.PRNGKey(plan.seed)
    _, k_lan, _k_km = jax.random.split(key, 3)
    b = plan.eff_block_size()
    block_steps = plan.num_block_steps()
    with obs.span("engine.eigensolve", path="ooc",
                  block_steps=block_steps) as sp_eig:
        state = lz.block_lanczos(op.matmat, plan.n, block_steps, k_lan,
                                 block_size=b)
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)

    Y = np.asarray(km.normalize_rows(Z))
    ranges = plan.ranges
    with obs.span("engine.kmeans", path="ooc") as sp_km:
        labels, centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)

    stats = dict(graph.stats_snapshot(), path="ooc",
                 lanczos_steps=plan.num_lanczos_steps(),
                 block_size=b, block_steps=block_steps,
                 matrix_passes=block_steps,
                 eigensolve_s=round(sp_eig.duration_s, 4),
                 kmeans_s=round(sp_km.duration_s, 4))
    obs.absorb_stats("engine", stats)
    return JobResult(labels=labels, embedding=Y,
                     eigenvalues=np.asarray(evals), centers=centers,
                     sigma=sigma, graph=graph, stats=stats)
