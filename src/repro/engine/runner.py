"""The job runner: executes a :class:`JobPlan` as map/shuffle/reduce
tasks and drives the eigensolve + streaming k-means off the resulting
shards — ``engine.run_job(plan, reader)`` is the out-of-core analogue of
``SpectralClustering.fit``.

The build is a **dependency-driven scheduler** over a worker pool of
``plan.workers`` threads (the Hadoop fan-out, one host): each chunk's
shuffle is submitted the moment its last input tile lands — no per-stage
barrier — and the reduces fan out the instant the final shuffle finishes
(a reduce folds mirror blocks that ANY shuffle may emit, the same
all-map-outputs dependency Hadoop's reduce fetch has).  All state between
tasks lives in the thread-safe ShardStore, so the working set is bounded
by the memory budget regardless of n; tasks never share mutable state
beyond it, and each task's arithmetic is order-independent, so results
are bitwise-identical at every pool width (``workers=1`` reproduces the
classic sequential schedule exactly).
"""
from __future__ import annotations

import queue
import statistics
import threading
import time
from concurrent.futures import FIRST_COMPLETED, Future, wait
from dataclasses import dataclass, field
from typing import Callable, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.core import kmeans as km, lanczos as lz, similarity as sim
from repro.engine import kmeans as skm, tasks
from repro.engine.operator import (ShardedCSRGraph, make_normalized_operator)
from repro.engine.plan import JobPlan, route_path
from repro.engine.store import ShardStore


class EngineError(RuntimeError):
    """Base class for engine scheduling failures."""


class EngineTimeoutError(EngineError):
    """A build stage blew its ``plan.stage_timeout_s`` deadline.  Raised
    by the scheduler after cancelling every queued task; running attempts
    are NOT joined (threads cannot be killed) — they are abandoned on
    their daemon worker threads (see :class:`_DaemonPool`), so the
    deadline genuinely bounds the caller's wall time even when an attempt
    hangs in blocked I/O or an infinite loop.  An abandoned attempt may
    still write into the failed job's store before its thread exits; the
    store is job-private and discarded with the job, so nothing observes
    those writes."""

    def __init__(self, stage: str, seconds: float):
        super().__init__(f"engine stage {stage!r} exceeded its "
                         f"{seconds:g}s deadline")
        self.stage = stage
        self.seconds = seconds


class _DaemonPool:
    """Minimal executor over DAEMON worker threads: ``submit`` returns a
    real :class:`concurrent.futures.Future` (so ``wait`` interoperates),
    ``shutdown`` matches the stdlib signature.

    Exists because ``ThreadPoolExecutor`` joins its non-daemon workers at
    shutdown *and* interpreter exit: one attempt stuck in blocked I/O
    would hang the job (and the process) forever, which is exactly what
    ``plan.stage_timeout_s`` promises cannot happen.  Daemon workers let
    the deadline path call ``shutdown(wait=False)`` and abandon a hung
    attempt — the zombie thread can finish in the background or die with
    the interpreter, but it can no longer block anyone.  Every other
    failure path keeps ``wait=True`` and loses nothing."""

    def __init__(self, max_workers: int, thread_name_prefix: str = "pool"):
        self._q: "queue.SimpleQueue" = queue.SimpleQueue()
        self._lock = threading.Lock()
        self._pending: set = set()          # submitted, not yet picked up
        self._shutdown = False
        self._threads = []
        for i in range(max(1, int(max_workers))):
            t = threading.Thread(target=self._worker, daemon=True,
                                 name=f"{thread_name_prefix}_{i}")
            t.start()
            self._threads.append(t)

    def _worker(self) -> None:
        while True:
            item = self._q.get()
            if item is None:                # shutdown sentinel
                return
            fut, fn = item
            with self._lock:
                self._pending.discard(fut)
            if not fut.set_running_or_notify_cancel():
                continue                    # cancelled while queued
            try:
                fut.set_result(fn())
            except BaseException as e:      # noqa: BLE001 — future carries it
                fut.set_exception(e)

    def submit(self, fn: Callable) -> Future:
        fut: Future = Future()
        with self._lock:
            if self._shutdown:
                raise RuntimeError("cannot submit to a shut-down pool")
            self._pending.add(fut)
        self._q.put((fut, fn))
        return fut

    def shutdown(self, wait: bool = True,
                 cancel_futures: bool = False) -> None:
        with self._lock:
            first = not self._shutdown
            self._shutdown = True
            doomed = list(self._pending) if cancel_futures else []
        for fut in doomed:
            fut.cancel()                    # running ones decline, as stdlib
        if first:
            for _ in self._threads:
                self._q.put(None)
        if wait:
            for t in self._threads:
                t.join()


@dataclass
class JobResult:
    labels: np.ndarray           # (n,) int32
    embedding: np.ndarray        # (n, k) row-normalized
    eigenvalues: np.ndarray      # (k,) smallest of L_sym, ascending
    centers: np.ndarray          # (k, k)
    sigma: float
    graph: Optional[ShardedCSRGraph]   # None on the fused (matrix-free) path
    stats: Dict = field(default_factory=dict)


def _resolve_sigma(reader, plan: JobPlan, sample_rows: int = 1024) -> float:
    """Median-distance heuristic on a sample STRIDED across all chunks.

    Sampling only the leading chunks (the pre-PR8 behaviour) skews sigma
    whenever the chunk order is meaningful — class-sorted data would
    estimate the bandwidth of one cluster instead of the dataset — so up
    to 8 evenly-spaced chunks each contribute an equal share of the
    sample."""
    if plan.sigma is not None:
        return float(plan.sigma)
    nc = plan.nchunks
    idx = np.unique(np.linspace(0, nc - 1, min(nc, 8)).round().astype(int))
    per = -(-sample_rows // len(idx))            # equal share per chunk
    xs = np.concatenate([np.asarray(reader[int(c)])[:per]
                         for c in idx])[:sample_rows]
    return float(sim.median_sigma(jnp.asarray(xs)))


@dataclass
class _TaskState:
    """Scheduler-side bookkeeping for one logical task across attempts."""
    kind: str
    key: object
    attempts: int = 0            # attempts launched so far
    failures: int = 0
    inflight: int = 0            # attempts currently submitted/running
    done: bool = False           # first successful completion landed
    backup: bool = False         # a speculative duplicate was launched


def _schedule_build(reader, sigma, plan: JobPlan, store: ShardStore,
                    overlap_work: Optional[Callable[[], None]] = None
                    ) -> tuple[np.ndarray, int, Dict]:
    """Run every map/shuffle/reduce task on a ``plan.workers``-wide pool,
    releasing each task the moment its inputs exist:

      map (i, j)   no deps — all submitted up front
      shuffle c    the map tiles touching chunk c (row i == c or j == c)
      reduce c     ALL shuffles (any shuffle may mirror triplets into c)

    Fault tolerance (the Hadoop task-attempt model):

      * a failed attempt is resubmitted with exponential backoff up to
        ``plan.max_retries`` times; tasks are deterministic functions of
        the store, so a retried success is bitwise-identical.  In consume
        mode a failed shuffle/reduce attempt may have already deleted
        part of its input set (it consumes blocks as it folds), so the
        retry first re-materializes every missing input via the lineage
        path (``tasks.recompute_entry`` — a bitwise replay): a mid-fold
        failure can never make the retry fold a partial input set and
        silently drop neighbours;
      * with ``plan.speculation_factor`` k > 0, a running task whose wall
        exceeds k x the running median of completed walls for its stage
        gets ONE speculative backup attempt — first completion wins, the
        loser's (identical) output is discarded.  In speculation mode
        tasks run ``consume=False`` and the scheduler deletes a task's
        inputs only after every attempt has settled, so a duplicate can
        never read half-deleted inputs;
      * ``plan.stage_timeout_s`` bounds each stage's wall; on expiry
        every queued task is cancelled, running attempts are ABANDONED on
        their daemon workers (joining could hang forever on a stuck
        attempt — see :class:`_DaemonPool`), and the typed error
        propagates, so the deadline bounds the job's wall time.  On retry
        exhaustion the scheduler cancels the queue but does join running
        attempts — a failed-but-not-hung job never leaks tasks that keep
        spilling into the store.

    ``overlap_work`` (if given) runs ONCE on the scheduler thread as soon
    as the last shuffle finishes — i.e. while the reduce tail is still
    draining on the workers — so callers can overlap eigensolver seeding
    with the end of the build.  Returns (deg, nnz, stats)."""
    tiles = plan.tiles
    nc = plan.nchunks
    workers = max(1, int(plan.workers))
    faults = plan.faults
    speculate = plan.speculation_factor > 0
    consume = not speculate
    busy = {"map": 0.0, "shuffle": 0.0, "reduce": 0.0}
    walls = {"map": [], "shuffle": [], "reduce": []}
    busy_lock = threading.Lock()
    deg = np.zeros(plan.n, np.float32)
    nnz_total = 0
    counters = {"retries": 0, "task_failures": 0, "inputs_healed": 0,
                "speculative_launched": 0, "speculative_won": 0}

    def timed(stage, fn, *args, **kw):
        t0 = time.perf_counter()
        out = fn(*args, **kw)
        dt = time.perf_counter() - t0
        with busy_lock:
            busy[stage] += dt
            walls[stage].append(dt)
        return out

    def run_task(kind, key):
        if kind == "map":
            return timed("map", tasks.run_map_task,
                         reader, sigma, plan, key[0], key[1], store)
        if kind == "shuffle":
            return timed("shuffle", tasks.run_shuffle_task,
                         plan, key, store, consume=consume)
        return timed("reduce", tasks.run_reduce_task,
                     plan, key, store, consume=consume)

    tstate: Dict[tuple, _TaskState] = {}
    starts: Dict[tuple, float] = {}       # (kind, key, attempt) -> exec start
    stage_t0: Dict[str, float] = {}
    stage_left = {"map": len(tiles), "shuffle": nc, "reduce": nc}
    waiting = {c: {tl for tl in tiles if c in tl} for c in range(nc)}
    mirror_srcs: Dict[int, set] = {}      # reduce c <- shuffles that fed it
    shuffles_left = nc
    overlap_pending = overlap_work is not None
    t_start = time.perf_counter()
    # speculation / deadlines need a clock tick even when nothing finishes
    poll = 0.05 if (speculate or plan.stage_timeout_s is not None) else None
    pool = _DaemonPool(workers, thread_name_prefix="repro-engine-task")
    futures: Dict = {}

    def heal_inputs(kind, key):
        """Consume-mode retries only: a failed shuffle/reduce attempt
        deletes inputs as it folds, so the retry would otherwise see —
        and silently fold — only the not-yet-consumed remainder.
        Re-materialize every missing input from lineage (a bitwise replay
        of its producing task) before re-running the fold; ``store.keys``
        then presents the full set in the original sorted order, so the
        retried fold is bitwise-identical to an untouched first run."""
        if kind == "shuffle":
            expected = [f"cand/{key}/{min(key, o)}-{max(key, o)}"
                        for o in range(nc)]
        elif kind == "reduce":
            expected = ([f"topt/{key}"] +
                        [f"mirror/{key}/{s}"
                         for s in sorted(mirror_srcs.get(key, ()))])
        else:
            return                        # map tasks consume nothing
        for skey in expected:
            if skey in store:
                continue
            store.put(skey, tasks.recompute_entry(reader, sigma, plan, skey))
            with busy_lock:
                counters["inputs_healed"] += 1
            obs.counter("engine.inputs_healed").inc()

    def submit(kind, key, attempt=0, speculative=False):
        st = tstate.setdefault((kind, key), _TaskState(kind, key))
        st.attempts += 1
        st.inflight += 1
        stage_t0.setdefault(kind, time.perf_counter())

        def body(kind=kind, key=key, attempt=attempt,
                 speculative=speculative):
            if attempt > 0 and not speculative and plan.retry_backoff_s:
                time.sleep(min(plan.retry_backoff_s * 2 ** (attempt - 1),
                               2.0))
            if attempt > 0 and consume:
                heal_inputs(kind, key)
            starts[(kind, key, attempt)] = time.perf_counter()
            if faults is not None:
                faults.on_task_start(kind, key, attempt)
            return run_task(kind, key)

        futures[pool.submit(body)] = (kind, key, attempt, speculative)

    def finish(kind, key, out):
        nonlocal shuffles_left, nnz_total
        if kind == "map":
            for c in set(key):
                deps = waiting[c]
                deps.discard(key)
                if not deps:                 # last tile for chunk c
                    submit("shuffle", c)
        elif kind == "shuffle":
            for d in out:                    # record reduce d's input set
                mirror_srcs.setdefault(d, set()).add(key)
            shuffles_left -= 1
            if shuffles_left == 0:           # mirrors all emitted
                for c in range(nc):
                    submit("reduce", c)
        else:                                # reduce: disjoint slices
            r0, r1 = plan.ranges[key]
            deg[r0:r1] = out["deg"]
            nnz_total += out["nnz"]
        stage_left[kind] -= 1

    def settle(st: _TaskState):
        # speculation mode defers a winning task's input deletes until no
        # attempt (winner or loser) can still be reading them
        if consume or not st.done or st.inflight > 0:
            return
        if st.kind == "shuffle":
            doomed = list(store.keys(f"cand/{st.key}/"))
        elif st.kind == "reduce":
            doomed = [f"topt/{st.key}"] + list(store.keys(f"mirror/{st.key}/"))
        else:
            return
        for k in doomed:
            store.delete(k)

    fatal = None
    timed_out = False
    try:
        for (i, j) in tiles:
            submit("map", (i, j))
        while futures and fatal is None:
            if overlap_pending and shuffles_left == 0:
                overlap_pending = False      # reduce tail is draining
                overlap_work()
            done, _ = wait(list(futures), return_when=FIRST_COMPLETED,
                           timeout=poll)
            now = time.perf_counter()
            for fut in done:
                kind, key, attempt, speculative = futures.pop(fut)
                st = tstate[(kind, key)]
                st.inflight -= 1
                starts.pop((kind, key, attempt), None)
                err = fut.exception()
                if err is None:
                    if not st.done:          # first completion wins
                        st.done = True
                        if speculative:
                            counters["speculative_won"] += 1
                        finish(kind, key, fut.result())
                    # else: the losing duplicate — identical output,
                    # already superseded; discard
                elif not st.done:
                    st.failures += 1
                    counters["task_failures"] += 1
                    if st.failures <= plan.max_retries:
                        counters["retries"] += 1
                        submit(kind, key, attempt=st.attempts)
                    else:
                        fatal = err          # budget exhausted: abort job
                # a losing attempt's error is moot — the task completed
                settle(st)
            if fatal is not None:
                break
            if plan.stage_timeout_s is not None:
                for stage, left in stage_left.items():
                    t0s = stage_t0.get(stage)
                    if (t0s is not None and left > 0
                            and now - t0s > plan.stage_timeout_s):
                        timed_out = True
                        raise EngineTimeoutError(stage, plan.stage_timeout_s)
            if speculate:
                with busy_lock:
                    meds = {s: statistics.median(w) if len(w) >= 3 else None
                            for s, w in walls.items()}
                for kind, key, attempt, spec in list(futures.values()):
                    st = tstate[(kind, key)]
                    med = meds[kind]
                    if st.done or st.backup or spec or med is None:
                        continue
                    t0a = starts.get((kind, key, attempt))
                    if t0a is None:          # queued, not yet running
                        continue
                    if now - t0a > plan.speculation_factor * max(med, 1e-3):
                        st.backup = True     # one backup per task
                        counters["speculative_launched"] += 1
                        submit(kind, key, attempt=st.attempts,
                               speculative=True)
        if fatal is not None:
            raise fatal
    finally:
        # the first unrecoverable failure cancels every queued task and
        # joins the running ones — a failed job never leaks attempts that
        # keep spilling into the store.  A blown stage deadline must NOT
        # join (a hung attempt would hang the join too, defeating the
        # deadline): its running attempts are abandoned on their daemon
        # workers instead, and the job's private store is discarded with
        # the job, so their late writes are unobservable.
        pool.shutdown(wait=not timed_out, cancel_futures=True)
    if not consume:
        # deferred-GC stragglers: losing attempts that re-put an input
        # after its consumer settled (all attempts have joined by now)
        for prefix in ("cand/", "topt/", "mirror/"):
            for k in list(store.keys(prefix)):
                store.delete(k)
    if overlap_pending:                      # degenerate tiny jobs
        overlap_work()
    wall = time.perf_counter() - t_start
    busy_s = sum(busy.values())
    stats = {
        "map_tasks": len(tiles), "shuffle_tasks": nc, "reduce_tasks": nc,
        "chunks": nc, "chunk_size": plan.chunk_size, "t": plan.t_eff,
        "workers": workers, "prefetch_depth": plan.prefetch_depth,
        "max_retries": plan.max_retries,
        "retries": counters["retries"],
        "task_failures": counters["task_failures"],
        "inputs_healed": counters["inputs_healed"],
        "speculative_launched": counters["speculative_launched"],
        "speculative_won": counters["speculative_won"],
        # per-stage numbers are BUSY task-seconds (the stages interleave,
        # so they no longer tile a wall-clock interval); overlap_s is the
        # task-seconds the pool hid inside the build wall
        "map_s": round(busy["map"], 4),
        "shuffle_s": round(busy["shuffle"], 4),
        "reduce_s": round(busy["reduce"], 4),
        "build_wall_s": round(wall, 4),
        "overlap_s": round(max(0.0, busy_s - wall), 4),
    }
    return deg, nnz_total, stats


def _install_lineage_recovery(store: ShardStore, reader, sigma,
                              plan: JobPlan) -> None:
    """Arm the store's recovery hook with the planner's task lineage: a
    corrupt or lost spill entry is rebuilt by re-running the math of its
    producing task (``tasks.recompute_entry`` — bitwise-identical to the
    original), so a ``get`` mid-eigensolve heals instead of crashing.
    Installed BEFORE the build so corruption of any intermediate —
    candidate block, top-t, mirror, CSR shard — recovers too."""
    def recover(key: str, exc: Exception) -> bool:
        try:
            arrays = tasks.recompute_entry(reader, sigma, plan, key)
        except KeyError:
            return False                     # no lineage for this key
        store.put(key, arrays)
        obs.counter("engine.shard_recovered").inc()
        return True

    store.recovery = recover


def build_graph(reader, plan: JobPlan,
                store: Optional[ShardStore] = None,
                overlap_work: Optional[Callable[[], None]] = None,
                prewarm: bool = True) -> tuple[ShardedCSRGraph, float]:
    """Run the map + shuffle + reduce stages on the dependency-driven
    scheduler; returns the sharded graph (with per-stage stats attached)
    and the resolved sigma.  See :func:`_schedule_build` for the task
    dependency structure and the ``overlap_work`` hook.

    ``prewarm`` starts the first shard-window fetches before returning,
    so the consumer's first pass starts hot (off for A/B baselines)."""
    store = store or ShardStore(memory_budget=plan.memory_budget,
                                spill_dir=plan.spill_dir,
                                async_spill=plan.async_spill)
    if plan.faults is not None:
        store.faults = plan.faults
    sigma = _resolve_sigma(reader, plan)
    _install_lineage_recovery(store, reader, sigma, plan)
    with obs.span("engine.build", path="ooc", workers=plan.workers,
                  tasks=len(plan.tiles) + 2 * plan.nchunks):
        deg, nnz, stats = _schedule_build(reader, sigma, plan, store,
                                          overlap_work=overlap_work)
    for key in ("map_tasks", "shuffle_tasks", "reduce_tasks"):
        obs.counter(f"engine.{key}").inc(stats[key])
    graph = ShardedCSRGraph(store=store, plan=plan, deg=deg, nnz=nnz,
                            stats=stats)
    if prewarm:
        graph.prewarm()
    return graph, sigma


def _run_fused(plan: JobPlan, reader) -> JobResult:
    """The planner's fused route: the points fit in memory even though the
    dense similarity would not, so instead of spilling CSR shards the job
    runs the matrix-free fused-RBF operator (O(n*d) affinity memory) with
    the same block eigensolve + streaming k-means tail as the ooc path."""
    from repro.cluster.affinity import build_fused_rbf_operator
    from repro.distrib import mesh_utils

    sigma = _resolve_sigma(reader, plan)
    x = np.concatenate([np.asarray(reader[c], np.float32)
                        for c in range(plan.nchunks)])
    mesh = mesh_utils.local_mesh("rows")
    with obs.span("engine.build", path="fused") as sp_build:
        op = build_fused_rbf_operator(jnp.asarray(x), sigma, mesh,
                                      compute_dtype=plan.compute_dtype)

    key = jax.random.PRNGKey(plan.seed)
    _, k_lan, _k_km = jax.random.split(key, 3)
    b = plan.eff_block_size()
    block_steps = plan.num_block_steps()
    with obs.span("engine.eigensolve", path="fused",
                  block_steps=block_steps) as sp_eig:
        state = lz.block_lanczos(op.matmat, op.n_pad, block_steps, k_lan,
                                 block_size=b)
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)

    Y = np.asarray(km.normalize_rows(Z) * op.valid[:, None])[:plan.n]
    ranges = plan.ranges
    with obs.span("engine.kmeans", path="fused") as sp_km:
        labels, centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)

    stats = dict(op.stats_snapshot(), path="fused", chunks=plan.nchunks,
                 points_bytes=int(x.nbytes),
                 lanczos_steps=plan.num_lanczos_steps(),
                 block_size=b, block_steps=block_steps,
                 build_s=round(sp_build.duration_s, 4),
                 eigensolve_s=round(sp_eig.duration_s, 4),
                 kmeans_s=round(sp_km.duration_s, 4))
    obs.absorb_stats("engine", stats)
    return JobResult(labels=labels, embedding=Y,
                     eigenvalues=np.asarray(evals), centers=centers,
                     sigma=sigma, graph=None, stats=stats)


def run_job(plan: JobPlan, reader) -> JobResult:
    """Full out-of-core pipeline: dependency-scheduled graph build,
    shard-streaming block Lanczos, chunked mini-batch k-means.
    ``reader[c]`` must yield the (rows, d) point chunk for range
    ``plan.ranges[c]``.

    Phase 1 honours the planner's routing (:func:`repro.engine.plan.
    route_path`): jobs whose points fit the memory budget but whose dense
    similarity does not take the fused matrix-free path instead of
    spilling CSR shards (``plan.path`` forces either way).

    On the ooc path the eigensolve is the *block* recurrence: each block
    step pulls every CSR shard from the store exactly once and amortizes
    it over the b-wide block, so the same Krylov dimension costs ~1/b the
    shard loads (and spill-reloads) of the single-vector iteration.  The
    eigensolver's start block is drawn WHILE the reduce tail drains
    (bitwise-identical to drawing it after — same key, same shape), and
    the graph's prefetch pool is shut down before returning, so a job
    never strands background threads."""
    fallback = None
    if plan.path == "fused":
        return _run_fused(plan, reader)
    if plan.path == "auto":         # probe d only when routing needs it
        d = int(np.asarray(reader[0]).shape[1])
        if route_path(plan, d) == "fused":
            try:
                return _run_fused(plan, reader)
            except Exception as e:
                # graceful degradation: an auto-routed fused job that
                # fails falls back to the ooc pipeline (an explicitly
                # forced path propagates its error instead)
                obs.counter("engine.path_fallbacks").inc()
                fallback = f"fused->ooc ({type(e).__name__})"

    key = jax.random.PRNGKey(plan.seed)
    _, k_lan, _k_km = jax.random.split(key, 3)
    b = plan.eff_block_size()
    block_steps = plan.num_block_steps()
    seed_box: Dict = {}

    def _warm_start():
        # exactly the draw lz.init_block_state would make (same key,
        # shape, dtype -> bitwise-identical eigensolve), issued while the
        # reduce tail is still draining on the task pool
        seed_box["V0"] = jax.block_until_ready(
            jax.random.normal(k_lan, (b, plan.n), jnp.float32))

    graph, sigma = build_graph(reader, plan, overlap_work=_warm_start)
    op = make_normalized_operator(graph)

    with obs.span("engine.eigensolve", path="ooc",
                  block_steps=block_steps) as sp_eig:
        state = lz.block_lanczos(op.matmat, plan.n, block_steps, k_lan,
                                 block_size=b, V0=seed_box["V0"],
                                 host_matmat=op.host_matmat)
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)

    Y = np.asarray(km.normalize_rows(Z))
    ranges = plan.ranges
    with obs.span("engine.kmeans", path="ooc") as sp_km:
        labels, centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)

    stats = dict(graph.stats_snapshot(), path="ooc",
                 lanczos_steps=plan.num_lanczos_steps(),
                 block_size=b, block_steps=block_steps,
                 matrix_passes=block_steps,
                 eigensolve_s=round(sp_eig.duration_s, 4),
                 kmeans_s=round(sp_km.duration_s, 4))
    if fallback is not None:
        stats["path_fallback"] = fallback
    obs.absorb_stats("engine", stats)
    graph.close()                   # no stray prefetch threads after a job
    return JobResult(labels=labels, embedding=Y,
                     eigenvalues=np.asarray(evals), centers=centers,
                     sigma=sigma, graph=graph, stats=stats)
