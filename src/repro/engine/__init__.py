# MapReduce-style out-of-core execution engine (the paper's Hadoop phase
# structure as staged map/shuffle/reduce tasks over fixed-size chunks):
# map computes top-t sparse similarity tiles with the Pallas RBF kernel,
# shuffle merges tile output into symmetrized per-row-range CSR shards
# (spilled to disk under a memory budget), reduce wires the shards into a
# streaming NormalizedOperator for Lanczos plus a chunked mini-batch
# k-means.  See API.md §repro.engine for the job-plan and shard contracts.
from repro.engine.kmeans import streaming_kmeans
from repro.engine.operator import ShardedCSRGraph, make_normalized_operator
from repro.engine.plan import (JobPlan, chunk_ranges, map_tiles, num_chunks,
                               route_path)
from repro.engine.runner import JobResult, build_graph, run_job
from repro.engine.store import ShardStore

__all__ = [
    "JobPlan",
    "JobResult",
    "ShardStore",
    "ShardedCSRGraph",
    "build_graph",
    "chunk_ranges",
    "make_normalized_operator",
    "map_tiles",
    "num_chunks",
    "route_path",
    "run_job",
    "streaming_kmeans",
]
