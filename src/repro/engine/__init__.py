# MapReduce-style out-of-core execution engine (the paper's Hadoop phase
# structure as staged map/shuffle/reduce tasks over fixed-size chunks):
# map computes top-t sparse similarity tiles with the Pallas RBF kernel,
# shuffle merges tile output into symmetrized per-row-range CSR shards
# (spilled to disk under a memory budget), reduce wires the shards into a
# streaming NormalizedOperator for Lanczos plus a chunked mini-batch
# k-means.  Fault tolerance mirrors Hadoop too: task retry + speculative
# re-execution in the scheduler, checksummed atomic spills with
# lineage-based re-materialization in the store, and a deterministic
# FaultPlan injection harness.  See API.md §repro.engine for the
# job-plan, shard and fault-tolerance contracts.
from repro.engine.faults import FaultPlan, InjectedFault
from repro.engine.kmeans import streaming_kmeans
from repro.engine.operator import ShardedCSRGraph, make_normalized_operator
from repro.engine.plan import (JobPlan, chunk_ranges, map_tiles, num_chunks,
                               producer_of, route_path)
from repro.engine.runner import (EngineError, EngineTimeoutError, JobResult,
                                 build_graph, run_job)
from repro.engine.store import (ShardCorruptionError, ShardLostError,
                                ShardStore)

__all__ = [
    "EngineError",
    "EngineTimeoutError",
    "FaultPlan",
    "InjectedFault",
    "JobPlan",
    "JobResult",
    "ShardCorruptionError",
    "ShardLostError",
    "ShardStore",
    "ShardedCSRGraph",
    "build_graph",
    "chunk_ranges",
    "make_normalized_operator",
    "map_tiles",
    "num_chunks",
    "producer_of",
    "route_path",
    "run_job",
    "streaming_kmeans",
]
