"""Job plans: the static description a driver hands to the engine.

A :class:`JobPlan` is the analogue of the paper's Hadoop job configuration —
it fixes the chunking of the n points, the top-t sparsity of the similarity
graph, and the resource envelope (memory budget, spill directory), and the
planner derives the static task lists from it: one **map** task per
upper-triangle (i-chunk, j-chunk) tile, one **reduce** task per row-range
shard.  Everything here is host-side and deterministic, so a job can be
re-planned (and individual tasks re-executed) without any hidden state —
the same property Hadoop gets from its immutable job config.
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Optional, Tuple, Union

from repro.data.chunked import chunk_ranges  # noqa: F401  (re-exported)


def num_chunks(n: int, chunk_size: int) -> int:
    return len(chunk_ranges(n, chunk_size))


def map_tiles(nc: int) -> list[tuple[int, int]]:
    """Upper-triangle tile list (i <= j): each unordered chunk pair is
    computed once (the paper's Alg. 4.2 triangle), and the map task emits
    candidates for both row ranges."""
    return [(i, j) for i in range(nc) for j in range(i, nc)]


@dataclass(frozen=True)
class JobPlan:
    """Static configuration of one out-of-core clustering job.

    n:              number of points.
    chunk_size:     rows per chunk (clamped to [1, n] by the planner).
    t:              top-t neighbours kept per row before symmetrization.
    k:              number of clusters / embedding dims.
    sigma:          RBF bandwidth; None = median heuristic on a sample.
    memory_budget:  shard-store RAM budget in bytes; None = unlimited
                    (nothing spills).
    spill_dir:      where spilled shards go; None = fresh temp dir.
    lanczos_steps:  target Krylov dimension; None = max(4k, 32), capped
                    below n.
    block_size:     eigensolve block width b: the shard-streaming matmat
                    pulls each CSR shard from the store once per b-wide
                    block, so one Krylov dimension costs ~1/b the
                    spill-reload traffic of the single-vector iteration.
    kmeans_rounds:  streaming mini-batch rounds (one chunk per round).
    seed:           base seed for Lanczos start block and k-means init.
    workers:        task-pool width for the map/shuffle/reduce build: the
                    dependency-driven scheduler keeps up to ``workers``
                    tasks in flight (1 = the classic sequential order —
                    results are bitwise-identical at any width; the tasks
                    are order-independent, see ``runner.build_graph``).
    prefetch_depth: shard readahead window of the streaming matmat: up to
                    this many upcoming CSR shards are fetched from the
                    (possibly spilled) store concurrently while the
                    current shard multiplies.
    async_spill:    evictions hand their npz write to the store's
                    background writer instead of blocking the task that
                    triggered them (False = the PR-7 synchronous write,
                    kept for A/B benchmarking).
    path:           phase-1 execution path: "ooc" (CSR shards through the
                    spilling store — the classic engine pipeline),
                    "fused" (matrix-free fused-RBF operator over
                    in-memory points), or "auto" (:func:`route_path`
                    picks per the memory budget).
    compute_dtype:  fused-kernel MXU precision (None/"float32"/"bf16"),
                    only read on the fused path.
    max_retries:    per-task re-execution budget (Hadoop's
                    mapred.map.max.attempts minus one): a failed attempt
                    is resubmitted up to this many times with exponential
                    backoff before the job aborts.  Retried tasks are
                    bitwise-identical to first-try successes (tasks are
                    deterministic functions of the store; a retry of a
                    shuffle/reduce that consumed part of its inputs
                    before failing first re-materializes the missing
                    blocks from lineage).
    retry_backoff_s: base backoff before retry attempt a (sleeps
                    ``retry_backoff_s * 2**(a-1)``, capped at 2s).
    speculation_factor: straggler threshold k — a running task whose wall
                    exceeds k x the running median of completed walls for
                    its stage gets one speculative backup attempt; first
                    completion wins, the loser is discarded.  0 disables
                    speculation (the default: non-speculative runs keep
                    the consume-on-fold input lifecycle).
    stage_timeout_s: per-stage deadline for the build scheduler; on
                    expiry every queued task is cancelled, running
                    attempts are ABANDONED on their daemon workers (a
                    hung attempt cannot drag the job past the deadline),
                    and a typed ``EngineTimeoutError`` propagates
                    (callers fall back per :func:`route_path` — see
                    ``cluster.affinity.ooc_topt_affinity``).
    faults:         optional :class:`~repro.engine.faults.FaultPlan`
                    threaded through the runner and store — deterministic
                    fault injection for tests/benchmarks (None = no-op).
    """

    n: int
    chunk_size: int = 1024
    t: int = 16
    k: int = 8
    sigma: Optional[float] = None
    memory_budget: Optional[int] = None
    spill_dir: Optional[str] = None
    lanczos_steps: Optional[int] = None
    block_size: int = 8
    kmeans_rounds: int = 50
    seed: int = 0
    path: str = "ooc"
    compute_dtype: Optional[str] = None
    workers: int = 1
    prefetch_depth: int = 2
    async_spill: bool = True
    max_retries: int = 2
    retry_backoff_s: float = 0.05
    speculation_factor: float = 0.0
    stage_timeout_s: Optional[float] = None
    faults: Optional[Any] = field(default=None, compare=False)

    def __post_init__(self):
        if self.path not in ("ooc", "fused", "auto"):
            raise ValueError(
                f"path must be 'ooc', 'fused' or 'auto', got {self.path!r}")
        # fail at plan construction, not after the dataset is streamed in
        from repro.kernels.fused_rbf_matmat import resolve_compute_dtype
        resolve_compute_dtype(self.compute_dtype)
        if self.n <= 0:
            raise ValueError(f"n must be positive, got {self.n}")
        if self.t <= 0:
            raise ValueError(f"t must be positive, got {self.t}")
        if self.memory_budget is not None and self.memory_budget <= 0:
            raise ValueError(
                f"memory_budget must be positive bytes or None, "
                f"got {self.memory_budget}")
        if self.block_size <= 0:
            raise ValueError(
                f"block_size must be positive, got {self.block_size}")
        if self.workers < 1:
            raise ValueError(
                f"workers must be >= 1, got {self.workers}")
        if self.prefetch_depth < 1:
            raise ValueError(
                f"prefetch_depth must be >= 1, got {self.prefetch_depth}")
        if self.max_retries < 0:
            raise ValueError(
                f"max_retries must be >= 0, got {self.max_retries}")
        if self.retry_backoff_s < 0:
            raise ValueError(
                f"retry_backoff_s must be >= 0, got {self.retry_backoff_s}")
        if self.speculation_factor < 0:
            raise ValueError(f"speculation_factor must be >= 0 (0 = off), "
                             f"got {self.speculation_factor}")
        if self.stage_timeout_s is not None and self.stage_timeout_s <= 0:
            raise ValueError(f"stage_timeout_s must be positive seconds or "
                             f"None, got {self.stage_timeout_s}")

    @property
    def ranges(self) -> list[tuple[int, int]]:
        return chunk_ranges(self.n, self.chunk_size)

    @property
    def nchunks(self) -> int:
        return len(self.ranges)

    @property
    def tiles(self) -> list[tuple[int, int]]:
        return map_tiles(self.nchunks)

    @property
    def t_eff(self) -> int:
        return int(min(self.t, self.n))

    def num_lanczos_steps(self) -> int:
        m = self.lanczos_steps or max(4 * self.k, 32)
        return int(max(1, min(m, self.n - 1))) if self.n > 1 else 1

    def eff_block_size(self) -> int:
        return int(max(1, min(self.block_size, self.n)))

    def num_block_steps(self) -> int:
        """Block steps spanning the same Krylov dimension as
        ``num_lanczos_steps`` single-vector iterations."""
        return max(1, -(-self.num_lanczos_steps() // self.eff_block_size()))


def producer_of(key: str) -> Tuple[str, Union[int, Tuple[int, int]]]:
    """Task lineage: map a store key back to the (stage, task-key) that
    produced it.  This is the planner's re-materialization index — every
    intermediate's producer is a pure function of the key string, so a
    corrupt or lost entry can be rebuilt by re-running its producing task
    (see ``runner._install_lineage_recovery``):

      ``cand/<c>/<i>-<j>`` -> ("map", (i, j))      pure; re-run directly
      ``topt/<c>``         -> ("shuffle", c)       inputs consumed: re-run
      ``mirror/<d>/<c>``   -> ("shuffle", c)       via recompute (tasks.py)
      ``shard/<c>``        -> ("reduce", c)        via recompute (tasks.py)
    """
    parts = key.split("/")
    if parts[0] == "cand" and len(parts) == 3:
        i, j = parts[2].split("-")
        return "map", (int(i), int(j))
    if parts[0] == "topt" and len(parts) == 2:
        return "shuffle", int(parts[1])
    if parts[0] == "mirror" and len(parts) == 3:
        return "shuffle", int(parts[2])
    if parts[0] == "shard" and len(parts) == 2:
        return "reduce", int(parts[1])
    raise KeyError(f"no known producer for store key {key!r}")


def route_path(plan: JobPlan, d: int, *, itemsize: int = 4,
               slack: float = 4.0) -> str:
    """Pick the phase-1 path for a job given the feature dimension ``d``.

    A forced ``plan.path`` ("ooc" / "fused") wins.  With ``path="auto"``
    the budget decides:

    * dense similarity fits the budget      -> "ooc" (the CSR graph is a
      strict subset of dense S; nothing would spill anyway);
    * points * ``slack`` fit, dense doesn't -> "fused": the matrix-free
      operator clusters it at in-memory speed with an O(n*d) working set
      instead of spilling CSR shards to disk (``slack`` reserves room for
      the eigensolver block and scale vectors);
    * even the points don't fit             -> "ooc": stream chunks,
      spill shards — disk is the only option left.

    No budget (None) means unlimited RAM: the classic in-RAM ooc pipeline
    keeps its historical behaviour.

    NOTE the routes are not numerically identical: the fused operator
    eigensolves the FULL RBF graph (``plan.t`` does not apply — there is
    no matrix to sparsify), while the ooc path eigensolves the top-t
    sparsified graph.  Labels agree on separated clusters (the engine's
    ARI >= 0.95 backend contract), but pin ``path=`` explicitly when the
    exact graph matters.
    """
    if plan.path != "auto":
        return plan.path
    if plan.memory_budget is None:
        return "ooc"
    points_bytes = plan.n * d * itemsize
    dense_bytes = plan.n * plan.n * itemsize
    if dense_bytes <= plan.memory_budget:
        return "ooc"
    if points_bytes * slack <= plan.memory_budget:
        return "fused"
    return "ooc"
