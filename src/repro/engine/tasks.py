"""The engine's three task kinds — map, shuffle-merge, reduce — as plain
deterministic functions over a :class:`~repro.engine.store.ShardStore`.

Stage layout (the paper's Hadoop phases, chunk-granular):

  map        one task per upper-triangle (i, j) chunk tile: compute the
             RBF tile with the Pallas kernel, reduce it on-device to
             per-row top-t candidates for row range i (and, mirrored,
             for row range j), emit candidate blocks keyed by the
             destination row range               -> ``cand/<c>/<i>-<j>``
  shuffle    one merge task per row range: fold all candidate blocks
             into the final per-row top-t, then re-emit the transposed
             triplets toward each neighbour's row range (the
             symmetrization shuffle)             -> ``topt/<c>``,
                                                    ``mirror/<dest>/<c>``
  reduce     one task per row range: max-merge the row's own top-t with
             every incoming mirror block into a sorted CSR shard
                                                 -> ``shard/<c>``

All intermediates flow through the store, so they count against the memory
budget and spill exactly like Hadoop's map-side spill files.  Map tasks
are pure (re-running one just overwrites its candidate blocks); shuffle
and reduce tasks *consume* their inputs to keep the working set bounded
(``consume=False`` — used when speculative backups may run a duplicate
attempt — defers the deletes to the scheduler).  A consume-mode attempt
that fails MID-fold has therefore already destroyed part of its input
set, so before retrying one the scheduler re-materializes every missing
input through the ``recompute_*`` lineage path below (see
``runner._schedule_build``) — the same recovery granularity Hadoop gets
by re-fetching map output.

The ``recompute_*`` functions at the bottom are that recovery path: they
re-derive any store entry directly from the reader, replaying the exact
fold order of the original build (candidate keys in sorted-string order,
mirrors after the own top-t), so a recovered entry is **bitwise
identical** to the one it replaces — ``deg`` and the eigensolve stay
valid mid-flight.
"""
from __future__ import annotations

import numpy as np

from repro.engine.plan import JobPlan, producer_of
from repro.engine.store import ShardStore
from repro.kernels import ops as kops, topt


def _chunk_of(cols: np.ndarray, plan: JobPlan) -> np.ndarray:
    c = max(1, min(int(plan.chunk_size), plan.n))
    return cols // c


def run_map_task(reader, sigma, plan: JobPlan, i: int, j: int,
                 store: ShardStore) -> None:
    """Compute tile (i, j) (i <= j) and emit top-t candidate blocks."""
    t = plan.t_eff
    xi = np.asarray(reader[i])
    xj = xi if i == j else np.asarray(reader[j])
    tile = kops.rbf_similarity(xi, xj, sigma)
    # column ids travel as int32 from here on: every intermediate (and the
    # final shard ``indices``) spills through the budgeted store, and the
    # engine's n always fits — half the candidate-block spill bytes
    vals, cols = topt.tile_topt(tile, plan.ranges[j][0], t)
    store.put(f"cand/{i}/{i}-{j}", {"vals": vals,
                                    "cols": cols.astype(np.int32)})
    if i != j:
        vals_t, cols_t = topt.tile_topt(tile.T, plan.ranges[i][0], t)
        store.put(f"cand/{j}/{i}-{j}", {"vals": vals_t,
                                        "cols": cols_t.astype(np.int32)})


def _fold_topt(blocks, plan: JobPlan):
    """Fold ``(vals, cols)`` candidate blocks IN ITERATION ORDER into the
    final per-row top-t: running width stays <= 2t, and the final
    ``merge_topt`` always runs (it canonicalizes the single-block case).
    The fold order is part of the bitwise contract — replays must present
    blocks in the same (sorted-string key) order."""
    vals = cols = None
    for bv, bc in blocks:
        if vals is None:
            vals, cols = bv, bc
        else:
            vals = np.concatenate([vals, bv], axis=1)
            cols = np.concatenate([cols, bc], axis=1)
            vals, cols = topt.merge_topt(vals, cols, plan.t_eff)
    return topt.merge_topt(vals, cols, plan.t_eff)


def _topt_triplets(vals, cols, plan: JobPlan, c: int):
    """Flatten a folded top-t block to kept (rows, cols, vals) triplets."""
    r0, r1 = plan.ranges[c]
    rows = np.repeat(np.arange(r0, r1, dtype=np.int32), vals.shape[1])
    cols = cols.reshape(-1)
    vals = vals.reshape(-1)
    keep = cols >= 0                      # drop the ragged-tile sentinels
    return rows[keep], cols[keep], vals[keep]


def _mirror_groups(rows, cols, vals, plan: JobPlan):
    """The symmetrization shuffle's destination grouping: each kept entry
    shipped to its column's row range as a transposed triplet.  Returns
    {dest_chunk: (m_rows, m_cols, m_vals)} in the store's mirror-block
    layout."""
    dest = _chunk_of(cols, plan)
    order = np.argsort(dest, kind="stable")
    rows, cols, vals, dest = rows[order], cols[order], vals[order], dest[order]
    bounds = np.flatnonzero(np.diff(dest)) + 1
    dests = dest[np.r_[0, bounds]] if len(dest) else np.empty(0, np.int64)
    groups = zip(np.split(cols, bounds), np.split(rows, bounds),
                 np.split(vals, bounds))
    return {int(d): (m_rows, m_cols, m_vals.astype(np.float32))
            for (m_rows, m_cols, m_vals), d in zip(groups, dests)}


def run_shuffle_task(plan: JobPlan, c: int, store: ShardStore,
                     consume: bool = True) -> list:
    """Merge row range ``c``'s candidate blocks into its final top-t and
    emit the mirror triplets that symmetrize the graph.  Returns the
    sorted list of destination chunks it mirrored into — the scheduler
    records them as the matching reduce task's expected input set (for
    retry-time input healing).

    ``consume=True`` drops each candidate block the moment it is folded
    (bounded working set); the scheduler passes ``False`` when a
    speculative duplicate of this task may still be reading the inputs,
    and deletes them itself once every attempt has settled."""
    def blocks():
        # fold candidate blocks one at a time (running width <= 2t): the
        # shuffle working set stays O(chunk * t) under any n —
        # concatenating all blocks first would pin an O(n * t) buffer
        # regardless of the memory budget
        for k in list(store.keys(f"cand/{c}/")):
            b = store.get(k)
            yield b["vals"], b["cols"]
            if consume:
                store.delete(k)
                if plan.faults is not None:
                    plan.faults.on_input_consumed("shuffle", c)

    vals, cols = _fold_topt(blocks(), plan)
    rows, cols, vals = _topt_triplets(vals, cols, plan, c)
    store.put(f"topt/{c}", {"rows": rows, "cols": cols,
                            "vals": vals.astype(np.float32)})
    groups = sorted(_mirror_groups(rows, cols, vals, plan).items())
    for d, (m_rows, m_cols, m_vals) in groups:
        store.put(f"mirror/{d}/{c}",
                  {"rows": m_rows, "cols": m_cols, "vals": m_vals})
    return [d for d, _ in groups]


def _dedupe_max(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray):
    """Lexsort (row, col) triplets and max-merge duplicates — the
    max(S, S^T) symmetrization on whatever is resident."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if len(rows):
        new = np.r_[True, (np.diff(rows) != 0) | (np.diff(cols) != 0)]
        starts = np.flatnonzero(new)
        rows, cols = rows[starts], cols[starts]
        vals = np.maximum.reduceat(vals, starts)
    return rows, cols, vals


def _fold_shard(block_triplets, plan: JobPlan, c: int):
    """Fold (rows, cols, vals) triplet blocks in iteration order — dedupe
    (max-merge) after every block — and build the CSR shard arrays.
    Returns (arrays, deg, nnz)."""
    r0, r1 = plan.ranges[c]
    nrows = r1 - r0
    rows = cols = vals = None
    for b_rows, b_cols, b_vals in block_triplets:
        if rows is None:
            rows, cols, vals = b_rows, b_cols, b_vals
        else:
            rows = np.concatenate([rows, b_rows])
            cols = np.concatenate([cols, b_cols])
            vals = np.concatenate([vals, b_vals])
        rows, cols, vals = _dedupe_max(rows, cols, vals)

    rows_local = rows - r0
    counts = np.bincount(rows_local, minlength=nrows)
    indptr = np.zeros(nrows + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    data = vals.astype(np.float32)
    arrays = {"indptr": indptr, "indices": cols.astype(np.int32),
              "data": data}
    deg = np.bincount(rows_local, weights=data, minlength=nrows)
    return arrays, deg.astype(np.float32), int(len(data))


def run_reduce_task(plan: JobPlan, c: int, store: ShardStore,
                    consume: bool = True) -> dict:
    """Max-merge row range ``c``'s top-t with all incoming mirrors into a
    sorted CSR shard ``shard/<c>``.  Returns {"nnz": ..., "deg": (rows,)}.

    Mirrors are folded one block at a time (dedupe after each) so the
    resident triplet set never exceeds the final shard size plus one
    block, even when data skew routes most mirrors to one row range.
    ``consume=False`` defers input deletes to the scheduler (speculative
    duplicates may still be reading them).
    """
    def blocks():
        for k in [f"topt/{c}"] + list(store.keys(f"mirror/{c}/")):
            b = store.get(k)
            yield b["rows"], b["cols"], b["vals"]
            if consume:
                store.delete(k)
                if plan.faults is not None:
                    plan.faults.on_input_consumed("reduce", c)

    arrays, deg, nnz = _fold_shard(blocks(), plan, c)
    store.put(f"shard/{c}", arrays)
    return {"nnz": nnz, "deg": deg}


# -- lineage recovery: recompute any store entry from the reader -------------

def _candidate_block(reader, sigma, plan: JobPlan, c: int, i: int, j: int):
    """Bitwise replay of the candidate block :func:`run_map_task` emits at
    ``cand/<c>/<i>-<j>`` (``c`` is ``i`` or ``j``)."""
    t = plan.t_eff
    xi = np.asarray(reader[i])
    xj = xi if i == j else np.asarray(reader[j])
    tile = kops.rbf_similarity(xi, xj, sigma)
    if c == i:
        vals, cols = topt.tile_topt(tile, plan.ranges[j][0], t)
    else:
        vals, cols = topt.tile_topt(tile.T, plan.ranges[i][0], t)
    return vals, cols.astype(np.int32)


def recompute_topt_triplets(reader, sigma, plan: JobPlan, c: int):
    """Re-derive ``topt/<c>``'s kept (rows, cols, vals) triplets straight
    from the reader, replaying the shuffle's exact fold order (candidate
    keys in sorted-STRING order, the order ``store.keys`` yields them)."""
    nc = plan.nchunks
    keyed = sorted((f"cand/{c}/{min(c, o)}-{max(c, o)}",
                    min(c, o), max(c, o)) for o in range(nc))
    blocks = (_candidate_block(reader, sigma, plan, c, i, j)
              for _, i, j in keyed)
    vals, cols = _fold_topt(blocks, plan)
    return _topt_triplets(vals, cols, plan, c)


def recompute_shard(reader, sigma, plan: JobPlan, c: int):
    """Lineage recovery for ``shard/<c>``: replay the map + shuffle math
    of every contributing row range and the reduce fold.  Costs about two
    map stages of compute (each chunk's top-t is re-derived to learn what
    it mirrored into ``c``) but touches none of the consumed
    intermediates — and the result is bitwise-identical to the original
    shard, so ``deg`` and an in-flight eigensolve stay valid.  Returns
    the shard's {indptr, indices, data} arrays."""
    own = None
    mirrors = {}
    for s in range(plan.nchunks):
        tr = recompute_topt_triplets(reader, sigma, plan, s)
        if s == c:
            own = (tr[0], tr[1], tr[2].astype(np.float32))
        g = _mirror_groups(*tr, plan)
        if c in g:
            mirrors[s] = g[c]
    ordered = sorted(mirrors.items(), key=lambda kv: f"mirror/{c}/{kv[0]}")
    arrays, _deg, _nnz = _fold_shard(
        [own] + [m for _, m in ordered], plan, c)
    return arrays


def recompute_entry(reader, sigma, plan: JobPlan, key: str):
    """Rebuild ANY store entry from its lineage (see
    :func:`repro.engine.plan.producer_of`).  Used by the runner's
    store-recovery hook when a spill file is corrupt or lost."""
    stage, tkey = producer_of(key)
    parts = key.split("/")
    if stage == "map":
        i, j = tkey
        vals, cols = _candidate_block(reader, sigma, plan,
                                      int(parts[1]), i, j)
        return {"vals": vals, "cols": cols}
    if parts[0] == "topt":
        rows, cols, vals = recompute_topt_triplets(reader, sigma, plan, tkey)
        return {"rows": rows, "cols": cols, "vals": vals.astype(np.float32)}
    if parts[0] == "mirror":
        d = int(parts[1])
        tr = recompute_topt_triplets(reader, sigma, plan, tkey)
        groups = _mirror_groups(*tr, plan)
        if d not in groups:
            raise KeyError(f"lineage of {key!r} produced no block for "
                           f"chunk {d} (entry never existed)")
        m_rows, m_cols, m_vals = groups[d]
        return {"rows": m_rows, "cols": m_cols, "vals": m_vals}
    return recompute_shard(reader, sigma, plan, tkey)
