"""The engine's three task kinds — map, shuffle-merge, reduce — as plain
deterministic functions over a :class:`~repro.engine.store.ShardStore`.

Stage layout (the paper's Hadoop phases, chunk-granular):

  map        one task per upper-triangle (i, j) chunk tile: compute the
             RBF tile with the Pallas kernel, reduce it on-device to
             per-row top-t candidates for row range i (and, mirrored,
             for row range j), emit candidate blocks keyed by the
             destination row range               -> ``cand/<c>/<i>-<j>``
  shuffle    one merge task per row range: fold all candidate blocks
             into the final per-row top-t, then re-emit the transposed
             triplets toward each neighbour's row range (the
             symmetrization shuffle)             -> ``topt/<c>``,
                                                    ``mirror/<dest>/<c>``
  reduce     one task per row range: max-merge the row's own top-t with
             every incoming mirror block into a sorted CSR shard
                                                 -> ``shard/<c>``

All intermediates flow through the store, so they count against the memory
budget and spill exactly like Hadoop's map-side spill files.  Map tasks
are pure (re-running one just overwrites its candidate blocks); shuffle
and reduce tasks *consume* their inputs to keep the working set bounded,
so re-executing one after a failure means re-running its producing stage
for that row range first — the same recovery granularity Hadoop gets by
re-fetching map output.
"""
from __future__ import annotations

import numpy as np

from repro.engine.plan import JobPlan
from repro.engine.store import ShardStore
from repro.kernels import ops as kops
from repro.kernels import topt


def _chunk_of(cols: np.ndarray, plan: JobPlan) -> np.ndarray:
    c = max(1, min(int(plan.chunk_size), plan.n))
    return cols // c


def run_map_task(reader, sigma, plan: JobPlan, i: int, j: int,
                 store: ShardStore) -> None:
    """Compute tile (i, j) (i <= j) and emit top-t candidate blocks."""
    t = plan.t_eff
    xi = np.asarray(reader[i])
    xj = xi if i == j else np.asarray(reader[j])
    tile = kops.rbf_similarity(xi, xj, sigma)
    # column ids travel as int32 from here on: every intermediate (and the
    # final shard ``indices``) spills through the budgeted store, and the
    # engine's n always fits — half the candidate-block spill bytes
    vals, cols = topt.tile_topt(tile, plan.ranges[j][0], t)
    store.put(f"cand/{i}/{i}-{j}", {"vals": vals,
                                    "cols": cols.astype(np.int32)})
    if i != j:
        vals_t, cols_t = topt.tile_topt(tile.T, plan.ranges[i][0], t)
        store.put(f"cand/{j}/{i}-{j}", {"vals": vals_t,
                                        "cols": cols_t.astype(np.int32)})


def run_shuffle_task(plan: JobPlan, c: int, store: ShardStore) -> None:
    """Merge row range ``c``'s candidate blocks into its final top-t and
    emit the mirror triplets that symmetrize the graph."""
    # fold candidate blocks one at a time (running width <= 2t): the
    # shuffle working set stays O(chunk * t) under any n, and each block
    # is dropped from the store the moment it is folded — concatenating
    # all blocks first would pin an O(n * t) buffer regardless of the
    # memory budget
    vals = cols = None
    for k in list(store.keys(f"cand/{c}/")):
        b = store.get(k)
        if vals is None:
            vals, cols = b["vals"], b["cols"]
        else:
            vals = np.concatenate([vals, b["vals"]], axis=1)
            cols = np.concatenate([cols, b["cols"]], axis=1)
            vals, cols = topt.merge_topt(vals, cols, plan.t_eff)
        store.delete(k)
    vals, cols = topt.merge_topt(vals, cols, plan.t_eff)

    r0, r1 = plan.ranges[c]
    rows = np.repeat(np.arange(r0, r1, dtype=np.int32), vals.shape[1])
    cols = cols.reshape(-1)
    vals = vals.reshape(-1)
    keep = cols >= 0                      # drop the ragged-tile sentinels
    rows, cols, vals = rows[keep], cols[keep], vals[keep]
    store.put(f"topt/{c}", {"rows": rows, "cols": cols,
                            "vals": vals.astype(np.float32)})

    # Symmetrization shuffle: ship each kept entry to its column's row range
    # as a transposed triplet (max-merged there by the reduce task).
    dest = _chunk_of(cols, plan)
    order = np.argsort(dest, kind="stable")
    rows, cols, vals, dest = rows[order], cols[order], vals[order], dest[order]
    bounds = np.flatnonzero(np.diff(dest)) + 1
    dests = dest[np.r_[0, bounds]] if len(dest) else np.empty(0, np.int64)
    groups = zip(np.split(cols, bounds), np.split(rows, bounds),
                 np.split(vals, bounds))
    for (m_rows, m_cols, m_vals), d in zip(groups, dests):
        store.put(f"mirror/{int(d)}/{c}",
                  {"rows": m_rows, "cols": m_cols,
                   "vals": m_vals.astype(np.float32)})


def _dedupe_max(rows: np.ndarray, cols: np.ndarray, vals: np.ndarray):
    """Lexsort (row, col) triplets and max-merge duplicates — the
    max(S, S^T) symmetrization on whatever is resident."""
    order = np.lexsort((cols, rows))
    rows, cols, vals = rows[order], cols[order], vals[order]
    if len(rows):
        new = np.r_[True, (np.diff(rows) != 0) | (np.diff(cols) != 0)]
        starts = np.flatnonzero(new)
        rows, cols = rows[starts], cols[starts]
        vals = np.maximum.reduceat(vals, starts)
    return rows, cols, vals


def run_reduce_task(plan: JobPlan, c: int, store: ShardStore) -> dict:
    """Max-merge row range ``c``'s top-t with all incoming mirrors into a
    sorted CSR shard ``shard/<c>``.  Returns {"nnz": ..., "deg": (rows,)}.

    Mirrors are folded one block at a time (dedupe after each) so the
    resident triplet set never exceeds the final shard size plus one
    block, even when data skew routes most mirrors to one row range.
    """
    r0, r1 = plan.ranges[c]
    nrows = r1 - r0
    rows = cols = vals = None
    for k in [f"topt/{c}"] + list(store.keys(f"mirror/{c}/")):
        b = store.get(k)
        if rows is None:
            rows, cols, vals = b["rows"], b["cols"], b["vals"]
        else:
            rows = np.concatenate([rows, b["rows"]])
            cols = np.concatenate([cols, b["cols"]])
            vals = np.concatenate([vals, b["vals"]])
        store.delete(k)
        rows, cols, vals = _dedupe_max(rows, cols, vals)

    rows_local = rows - r0
    counts = np.bincount(rows_local, minlength=nrows)
    indptr = np.zeros(nrows + 1, np.int64)
    np.cumsum(counts, out=indptr[1:])
    data = vals.astype(np.float32)
    store.put(f"shard/{c}", {"indptr": indptr, "indices": cols.astype(np.int32),
                             "data": data})
    deg = np.bincount(rows_local, weights=data, minlength=nrows)
    return {"nnz": int(len(data)), "deg": deg.astype(np.float32)}
