"""Deterministic fault injection: the chaos harness for the engine.

A :class:`FaultPlan` is a static list of fault directives keyed by
``(stage, task-key, attempt)`` — the same coordinates the scheduler uses
for its retry bookkeeping — plus spill-corruption directives keyed by
store key.  Threaded through the runner (``JobPlan.faults``) and the
:class:`~repro.engine.store.ShardStore` (``store.faults``), it lets a
test or benchmark script say exactly which attempt of which task fails,
which spill file gets truncated or bit-flipped, and which task stalls —
and nothing else changes.  The default (``faults=None``) is a no-op on
every hot path.

Task keys are strings: ``"<i>-<j>"`` for map tiles, ``"<c>"`` for
shuffle/reduce chunks (see :func:`task_key`).  Every *fail* and *delay*
directive is keyed by attempt number, so "fail attempt 0, succeed on the
retry" is one directive; *fail_midfold* directives fire once, inside the
named shuffle/reduce fold AFTER it has consumed (deleted) a given number
of its input blocks — the partially-executed-task failure mode whose
retry must re-materialize the consumed inputs; *corrupt* directives fire
exactly once, on the first spill write of the named store key (re-spills
after a recovery write a clean file — otherwise a
corrupt->recover->re-spill loop would never converge).
"""
from __future__ import annotations

import json
import os
import threading
import time
from typing import Dict, Optional, Tuple, Union


class InjectedFault(RuntimeError):
    """The exception a ``fail`` / ``fail_midfold`` directive raises inside
    a task attempt (``attempt`` is None for mid-fold fire-once
    directives, which hit whichever attempt consumes enough inputs)."""

    def __init__(self, stage: str, key: str, attempt: Optional[int] = None,
                 where: str = "task start"):
        at = "" if attempt is None else f" attempt {attempt}"
        super().__init__(f"injected fault: {stage} task {key}{at} ({where})")
        self.stage = stage
        self.key = key
        self.attempt = attempt


def task_key(key: Union[int, Tuple[int, int], str]) -> str:
    """Normalize a scheduler task key to the FaultPlan string form:
    map tiles ``(i, j)`` -> ``"i-j"`` (lists too, so JSON specs may
    write ``[i, j]``), shuffle/reduce chunk ``c`` -> ``"c"``."""
    if isinstance(key, (tuple, list)):
        return f"{key[0]}-{key[1]}"
    return str(key)


class FaultPlan:
    """A deterministic set of fault directives.  Thread-safe: arming and
    firing both take the plan's lock, so directives may even be armed
    while a job runs; each fires at most once and is recorded in
    :attr:`fired`."""

    _CORRUPT_MODES = ("truncate", "bitflip")

    def __init__(self):
        self._lock = threading.Lock()
        self._fail: Dict[Tuple[str, str, int], bool] = {}
        self._delay: Dict[Tuple[str, str, int], float] = {}
        self._midfold: Dict[Tuple[str, str], int] = {}   # inputs left
        self._corrupt: Dict[str, str] = {}       # store key -> mode
        self.fired: Dict[str, int] = {"fail": 0, "delay": 0, "midfold": 0,
                                      "corrupt": 0}

    # -- arming --------------------------------------------------------------

    def fail(self, stage: str, key: Union[int, Tuple[int, int], str],
             attempt: int = 0) -> "FaultPlan":
        """Raise :class:`InjectedFault` when ``attempt`` of the named task
        starts."""
        with self._lock:
            self._fail[(stage, task_key(key), int(attempt))] = True
        return self

    def fail_n(self, stage: str, key, n: int) -> "FaultPlan":
        """Fail the first ``n`` attempts of a task (it succeeds on attempt
        ``n`` if the retry budget allows)."""
        for a in range(int(n)):
            self.fail(stage, key, a)
        return self

    def fail_midfold(self, stage: str, key,
                     after_inputs: int = 1) -> "FaultPlan":
        """Raise :class:`InjectedFault` inside the named shuffle/reduce
        task AFTER its consume-mode fold has deleted ``after_inputs`` of
        its input blocks — the partially-executed failure a start-keyed
        ``fail`` can never produce (it fires before any input is
        touched).  Fires once, so the retry runs to completion."""
        if stage not in ("shuffle", "reduce"):
            raise ValueError(f"fail_midfold stage must be 'shuffle' or "
                             f"'reduce' (only they consume inputs), "
                             f"got {stage!r}")
        if int(after_inputs) < 1:
            raise ValueError(f"after_inputs must be >= 1, "
                             f"got {after_inputs}")
        with self._lock:
            self._midfold[(stage, task_key(key))] = int(after_inputs)
        return self

    def delay(self, stage: str, key, seconds: float,
              attempt: int = 0) -> "FaultPlan":
        """Sleep ``seconds`` at the start of ``attempt`` of the named task
        — the straggler injector (speculative backups run a different
        attempt number, so they dodge the delay)."""
        with self._lock:
            self._delay[(stage, task_key(key), int(attempt))] = float(seconds)
        return self

    def corrupt(self, store_key: str, mode: str = "bitflip") -> "FaultPlan":
        """Corrupt the spill file of ``store_key`` right after its first
        write lands: ``"truncate"`` halves the file, ``"bitflip"`` flips
        the file's last byte (always inside the v2 checksum's header +
        payload coverage).  Fires once."""
        if mode not in self._CORRUPT_MODES:
            raise ValueError(f"corrupt mode must be one of "
                             f"{self._CORRUPT_MODES}, got {mode!r}")
        with self._lock:
            self._corrupt[store_key] = mode
        return self

    @classmethod
    def from_spec(cls, spec: Union[str, dict, None]) -> Optional["FaultPlan"]:
        """Build a plan from a JSON string / dict, e.g.::

            {"fail":         [["map", "0-0", 0], ["reduce", "1", 0]],
             "fail_midfold": [["shuffle", "1", 2]],
             "delay":        [["map", "0-1", 2.0, 0]],
             "corrupt":      {"shard/0": "bitflip"}}

        fail entries are ``[stage, key, attempt]`` (attempt optional,
        default 0); fail_midfold entries are ``[stage, key,
        after_inputs]`` (after_inputs optional, default 1); delay entries
        are ``[stage, key, seconds, attempt]``.  Returns None for an
        empty/None spec (the no-op default)."""
        if spec is None or spec == "":
            return None
        if isinstance(spec, str):
            spec = json.loads(spec)
        plan = cls()
        for ent in spec.get("fail", []):
            stage, key = ent[0], ent[1]
            plan.fail(stage, key, ent[2] if len(ent) > 2 else 0)
        for ent in spec.get("fail_midfold", []):
            plan.fail_midfold(ent[0], ent[1], ent[2] if len(ent) > 2 else 1)
        for ent in spec.get("delay", []):
            stage, key, seconds = ent[0], ent[1], float(ent[2])
            plan.delay(stage, key, seconds, ent[3] if len(ent) > 3 else 0)
        for store_key, mode in spec.get("corrupt", {}).items():
            plan.corrupt(store_key, mode)
        return plan

    # -- firing (runner / store hooks) --------------------------------------

    def on_task_start(self, stage: str, key, attempt: int) -> None:
        """Runner hook, called at the start of every task attempt: applies
        a matching delay, then raises a matching injected failure."""
        tk = (stage, task_key(key), int(attempt))
        with self._lock:
            seconds = self._delay.pop(tk, None)
            if seconds is not None:
                self.fired["delay"] += 1
        if seconds is not None:
            time.sleep(seconds)
        with self._lock:
            if self._fail.pop(tk, None):
                self.fired["fail"] += 1
                raise InjectedFault(stage, tk[1], int(attempt))

    def on_input_consumed(self, stage: str, key) -> None:
        """Task hook, called right after a consume-mode shuffle/reduce
        fold deletes one of its input blocks: counts an armed
        ``fail_midfold`` directive down and raises when it reaches zero —
        by then the attempt has genuinely destroyed part of its input
        set, so the retry must exercise the scheduler's input healing."""
        mk = (stage, task_key(key))
        with self._lock:
            left = self._midfold.get(mk)
            if left is None:
                return
            if left > 1:
                self._midfold[mk] = left - 1
                return
            del self._midfold[mk]
            self.fired["midfold"] += 1
        raise InjectedFault(stage, mk[1], where="mid-fold")

    def on_spill(self, store_key: str, path: str) -> None:
        """Store hook, called after a spill write lands: corrupts the file
        on disk if a directive names this key (once)."""
        with self._lock:
            mode = self._corrupt.pop(store_key, None)
            if mode is not None:
                self.fired["corrupt"] += 1
        if mode is None:
            return
        size = os.path.getsize(path)
        if mode == "truncate":
            os.truncate(path, size // 2)
        else:
            # bitflip: flip the file's LAST byte — the final payload byte
            # when the entry has one, or (all arrays empty: payload_len 0)
            # the last byte of the pickled header.  Either way the byte
            # sits inside the v2 checksum's coverage (header + payload),
            # so the drill always exercises the CRC-detect path.
            with open(path, "r+b") as f:
                f.seek(size - 1)
                b = f.read(1)
                f.seek(size - 1)
                f.write(bytes([b[0] ^ 0xFF]))
