"""Sharded-CSR graph + the streaming :class:`NormalizedOperator` reduce
tasks build on top of it.

The whole point of the engine: the similarity graph exists only as
per-row-range CSR shards inside a (possibly spilled) shard store, and the
eigensolve consumes it through a **matmat** that *streams* the shards —
one shard resident at a time, never a dense (n, n) anything, and each
shard pulled once per (n, b) block rather than once per vector.  The
host-side stream is lifted into the jitted eigensolver loops with
``jax.pure_callback``, so every registry backend (``lanczos``,
``block-lanczos``, ``chebdav``, ``eigh``) works unchanged.
"""
from __future__ import annotations

from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cluster.operator import NormalizedOperator
from repro.engine.plan import JobPlan
from repro.engine.store import ShardStore


@dataclass
class ShardedCSRGraph:
    """Symmetrized top-t similarity graph as per-row-range CSR shards.

    ``store`` holds one ``shard/<c>`` entry per row range (indptr/indices/
    data, see the store docstring); ``deg`` is the full degree vector
    (small: (n,)), accumulated by the reduce tasks.
    """

    store: ShardStore
    plan: JobPlan
    deg: np.ndarray                      # (n,) float32 row sums of S
    nnz: int
    stats: Dict = field(default_factory=dict)
    # single prefetch worker: ALL store reads during a matmat go through
    # it, so the (not thread-safe) LRU/spill bookkeeping stays serialized
    # while the readahead overlaps the previous shard's compute
    _prefetch_pool: Optional[ThreadPoolExecutor] = field(
        default=None, init=False, repr=False, compare=False)
    # cross-call warm start: the future for shard 0 of the NEXT matmat,
    # submitted as the previous one returns (see matmat docstring)
    _warm0: object = field(default=None, init=False, repr=False,
                           compare=False)

    @property
    def n(self) -> int:
        return self.plan.n

    def shard(self, c: int) -> Dict[str, np.ndarray]:
        return self.store.get(f"shard/{c}")

    def _drain_prefetch(self) -> None:
        """Settle any in-flight warm-start get.  The store's LRU/spill
        bookkeeping is not thread-safe, so every main-thread store access
        (dense materialization, stats reads) must first wait out the
        background fetch that :meth:`matmat` leaves behind."""
        fut, self._warm0 = self._warm0, None
        if fut is not None:
            try:
                fut.result()
            except Exception:       # a failed warm fetch only loses warmth
                pass

    def stats_snapshot(self) -> Dict:
        """Static stage counters + live store counters (the store keeps
        spilling/loading while consumers stream the shards) — the one
        merge every stats reporter uses."""
        self._drain_prefetch()
        snap = dict(self.stats, nnz=self.nnz,
                    spilled_shards=len(self.store.spilled_keys()),
                    **{f"store_{k}": v for k, v in self.store.stats.items()})
        obs.absorb_stats("engine", snap)   # mirror into the shared registry
        return snap

    def _pool(self) -> ThreadPoolExecutor:
        if self._prefetch_pool is None:
            self._prefetch_pool = ThreadPoolExecutor(
                max_workers=1, thread_name_prefix="repro-shard-prefetch")
        return self._prefetch_pool

    def matmat(self, V: np.ndarray) -> np.ndarray:
        """S @ V streaming one shard at a time — each CSR shard is pulled
        from the (possibly spilled) store ONCE PER BLOCK and its product
        amortized over all b columns, instead of once per vector; under a
        memory budget this divides the spill-reload traffic of an
        eigensolve by the block width.

        Shard gets are double-buffered: while shard c multiplies, shard
        c+1 is already being fetched (spill-reload I/O included) on a
        background thread, and as the call returns the NEXT call's shard
        0 starts loading — that one overlaps the eigensolver's QR /
        reorthogonalization work between passes, so the stream stays warm
        across the whole eigensolve, not just within one product.  A
        fetch that finished before the consumer asked counts as a
        ``prefetch_hit`` (misses = the consumer had to wait); both land
        in ``stats_snapshot()`` and hence ``info_["engine"]``."""
        V = np.asarray(V)
        if V.ndim == 1:
            V = V[:, None]
        Y = np.zeros((self.n, V.shape[1]), np.float32)
        self.stats.setdefault("prefetch_hits", 0)
        self.stats.setdefault("prefetch_misses", 0)
        pool = self._pool()
        ranges = self.plan.ranges
        fut, self._warm0 = self._warm0 or pool.submit(self.shard, 0), None
        for c, (r0, r1) in enumerate(ranges):
            self.stats["prefetch_hits" if fut.done()
                       else "prefetch_misses"] += 1
            sh = fut.result()
            if c + 1 < len(ranges):          # readahead before multiplying
                fut = pool.submit(self.shard, c + 1)
            indptr, indices, data = sh["indptr"], sh["indices"], sh["data"]
            prods = data[:, None] * V[indices]              # (nnz_c, b)
            rows = np.repeat(np.arange(r1 - r0), np.diff(indptr))
            for j in range(V.shape[1]):                     # bincount beats
                Y[r0:r1, j] = np.bincount(rows, weights=prods[:, j],
                                          minlength=r1 - r0)
        # warm the next pass's first shard while the caller (eigensolver)
        # crunches its Rayleigh-Ritz / orthogonalization step
        self._warm0 = pool.submit(self.shard, 0)
        return Y

    def matvec(self, v: np.ndarray) -> np.ndarray:
        """S @ v — the width-1 view of :meth:`matmat`."""
        return self.matmat(np.asarray(v)[:, None])[:, 0]

    def to_dense(self) -> np.ndarray:
        """Dense S — test/oracle path only; defeats the engine if used at
        scale."""
        self._drain_prefetch()      # serialize vs the background worker
        S = np.zeros((self.n, self.n), np.float32)
        for c, (r0, r1) in enumerate(self.plan.ranges):
            sh = self.shard(c)
            indptr, indices, data = sh["indptr"], sh["indices"], sh["data"]
            rows = np.repeat(np.arange(r0, r1), np.diff(indptr))
            S[rows, indices] = data
        return S


def make_normalized_operator(graph: ShardedCSRGraph, dtype=jnp.float32,
                             mesh=None, pad_to: int | None = None
                             ) -> NormalizedOperator:
    """Wrap the sharded graph as the estimator's common operator interface:
    ``A v = valid*v + D^{-1/2} S D^{-1/2} v`` with the S-matvec streaming
    shards through a host callback.

    ``pad_to`` rounds n_pad up (the estimator's mesh-divisibility
    invariant — every other affinity pads to a device-count multiple, and
    downstream shard_map stages require it); padding rows are zero-degree
    and masked out of ``valid`` exactly like the dense backends'.
    """
    n = graph.n
    n_pad = max(n, pad_to or n)
    deg = jnp.zeros((n_pad,), dtype).at[:n].set(jnp.asarray(graph.deg, dtype))
    inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)
    valid = (jnp.arange(n_pad) < n).astype(dtype)

    def host_matmat(V):
        return graph.matmat(np.asarray(V, np.float32))

    def matmat(V: jax.Array) -> jax.Array:
        b = V.shape[1]
        out_shape = jax.ShapeDtypeStruct((n, b), jnp.float32)
        SV = jax.pure_callback(host_matmat, out_shape,
                               (inv_sqrt[:, None] * V)[:n].astype(jnp.float32))
        SV = jnp.zeros((n_pad, b), dtype).at[:n].set(SV.astype(dtype))
        return valid[:, None] * V + inv_sqrt[:, None] * SV

    def dense() -> jax.Array:
        S = jnp.zeros((n_pad, n_pad), dtype).at[:n, :n].set(
            jnp.asarray(graph.to_dense(), dtype))
        return jnp.diag(valid) + S * (inv_sqrt[:, None] * inv_sqrt[None, :])

    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt, n=n, n_pad=n_pad,
        mesh=mesh, schedule=None, dense=dense, stats=graph.stats_snapshot)
