"""Sharded-CSR graph + the streaming :class:`NormalizedOperator` reduce
tasks build on top of it.

The whole point of the engine: the similarity graph exists only as
per-row-range CSR shards inside a (possibly spilled) shard store, and the
eigensolve consumes it through a **matmat** that *streams* the shards —
one shard resident at a time, never a dense (n, n) anything, and each
shard pulled once per (n, b) block rather than once per vector.  The
host-side stream is lifted into the jitted eigensolver loops with
``jax.pure_callback``, so every registry backend (``lanczos``,
``block-lanczos``, ``chebdav``, ``eigh``) works unchanged.

Asynchrony (PR 8): shard fetches run on a pool of up to
``plan.prefetch_depth`` readahead workers (the store is thread-safe, so
spill-reloads overlap each other AND the compute), and on accelerator
backends the per-shard CSR product runs as a jitted device segment-sum —
shard c+1's fetch/upload overlaps shard c's multiply, with the
single-pass host scatter kept as the CPU fallback.
"""
from __future__ import annotations

import threading
import weakref
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from functools import partial
from typing import Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.cluster.operator import NormalizedOperator
from repro.engine.plan import JobPlan
from repro.engine.store import ShardStore

_SCATTER_IMPLS = ("auto", "device", "host", "loop")


def _shutdown_pool(pool: ThreadPoolExecutor) -> None:
    """Finalizer-safe pool shutdown: joins the workers unless it is
    ITSELF running on one (a worker can drop a graph's last reference;
    self-join would raise and strand the pool)."""
    pool.shutdown(wait=threading.current_thread() not in pool._threads)


def scatter_rows(Y: np.ndarray, rows: np.ndarray,
                 prods: np.ndarray) -> None:
    """Accumulate ``prods`` (nnz, b) into ``Y`` (nrows, b) by row id, in
    ONE pass over ``prods`` (the old path ran b ``np.bincount`` passes —
    one per column).  CSR-derived ``rows`` are non-decreasing, so the
    fast path reduces each row's contiguous run with ``np.add.reduceat``;
    unsorted ids fall back to a single ``np.add.at`` scatter."""
    if len(rows) == 0:
        return
    if np.all(rows[:-1] <= rows[1:]):
        starts = np.flatnonzero(np.r_[True, np.diff(rows) != 0])
        Y[rows[starts]] += np.add.reduceat(prods, starts, axis=0)
    else:
        np.add.at(Y, rows, prods)


def _bincount_loop_rows(rows: np.ndarray, prods: np.ndarray,
                        nrows: int) -> np.ndarray:
    """The pre-async per-column scatter — b ``np.bincount`` passes over
    ``prods``.  Kept verbatim as the parity oracle and the "PR 7
    sequential engine" benchmark baseline (``matmat_impl="loop"``)."""
    Y = np.empty((nrows, prods.shape[1]), np.float32)
    for j in range(prods.shape[1]):
        Y[:, j] = np.bincount(rows, weights=prods[:, j], minlength=nrows)
    return Y


@partial(jax.jit, static_argnames=("nrows",))
def _csr_segment_matmat(data: jax.Array, indices: jax.Array,
                        rows: jax.Array, V: jax.Array,
                        nrows: int) -> jax.Array:
    """Device-side shard product: gather V rows, scale, segment-sum by
    local row id.  Padding entries carry data == 0, so they contribute
    nothing wherever their (clipped) indices land."""
    prods = data[:, None] * jnp.take(V, indices, axis=0)
    return jax.ops.segment_sum(prods, rows, num_segments=nrows)


def _pad_nnz(a: np.ndarray, target: int) -> np.ndarray:
    return np.pad(a, (0, target - len(a))) if len(a) < target else a


@dataclass
class ShardedCSRGraph:
    """Symmetrized top-t similarity graph as per-row-range CSR shards.

    ``store`` holds one ``shard/<c>`` entry per row range (indptr/indices/
    data, see the store docstring); ``deg`` is the full degree vector
    (small: (n,)), accumulated by the reduce tasks.
    """

    store: ShardStore
    plan: JobPlan
    deg: np.ndarray                      # (n,) float32 row sums of S
    nnz: int
    stats: Dict = field(default_factory=dict)
    # per-shard scatter implementation: "auto" routes to the jitted
    # device segment-sum on accelerators and the single-pass host scatter
    # on CPU; "loop" pins the pre-async per-column bincount reference
    matmat_impl: str = field(default="auto", init=False, compare=False)
    # readahead pool: up to plan.prefetch_depth workers fetch upcoming
    # shards from the (thread-safe) store while the current one multiplies
    _prefetch_pool: Optional[ThreadPoolExecutor] = field(
        default=None, init=False, repr=False, compare=False)
    _pool_finalizer: object = field(default=None, init=False, repr=False,
                                    compare=False)
    # cross-call warm start: futures for the NEXT matmat's first window
    # of shards, submitted as the previous call returns (see matmat
    # docstring)
    _warm: Optional[Dict[int, object]] = field(default=None, init=False,
                                               repr=False, compare=False)

    @property
    def n(self) -> int:
        return self.plan.n

    def shard(self, c: int) -> Dict[str, np.ndarray]:
        return self.store.get(f"shard/{c}")

    def _submit_fetch(self, pool: ThreadPoolExecutor, c: int):
        """Queue a background fetch of shard ``c``.  The work item closes
        over the STORE, not the graph: a submitted bound ``self.shard``
        would let a prefetch worker hold the graph's last reference, and
        the pool finalizer firing on its own worker cannot join it."""
        return pool.submit(self.store.get, f"shard/{c}")

    def _drain_prefetch(self) -> None:
        """Settle the in-flight warm-start gets (a failed warm fetch only
        loses warmth; matmat consumes every window future it submits)."""
        warm, self._warm = self._warm, None
        for fut in (warm or {}).values():
            try:
                fut.result()
            except Exception:
                pass

    def prewarm(self) -> None:
        """Start fetching the first ``prefetch_depth`` shards in the
        background, so the FIRST matmat finds its window already loaded.
        ``build_graph`` calls this as the build finishes: the fetches
        overlap the eigensolver's own warm-up (start-block QR, jit entry)
        instead of stalling its first pass.  Idempotent."""
        if self._warm is None:
            pool = self._pool()
            depth = max(1, int(getattr(self.plan, "prefetch_depth", 1)))
            nshards = len(self.plan.ranges)
            self._warm = {c: self._submit_fetch(pool, c)
                          for c in range(min(depth, nshards))}

    def stats_snapshot(self) -> Dict:
        """Static stage counters + live store counters (the store keeps
        spilling/loading while consumers stream the shards) — the one
        merge every stats reporter uses."""
        self._drain_prefetch()
        self.store.flush()          # settle async spill accounting
        snap = dict(self.stats, nnz=self.nnz,
                    spilled_shards=len(self.store.spilled_keys()),
                    **{f"store_{k}": v for k, v in self.store.stats.items()})
        obs.absorb_stats("engine", snap)   # mirror into the shared registry
        return snap

    def _pool(self) -> ThreadPoolExecutor:
        if self._prefetch_pool is None:
            depth = max(1, int(getattr(self.plan, "prefetch_depth", 1)))
            pool = ThreadPoolExecutor(
                max_workers=depth, thread_name_prefix="repro-shard-prefetch")
            self._prefetch_pool = pool
            # a graph dropped without close() must not strand its workers
            self._pool_finalizer = weakref.finalize(
                self, _shutdown_pool, pool)
        return self._prefetch_pool

    def close(self) -> None:
        """Shut down the prefetch pool and settle in-flight fetches.
        Idempotent, and not final: a later :meth:`matmat` lazily recreates
        the pool.  ``run_job`` and the estimator call this at teardown so
        fits never strand ``repro-shard-prefetch`` threads."""
        self._drain_prefetch()
        pool, self._prefetch_pool = self._prefetch_pool, None
        fin, self._pool_finalizer = self._pool_finalizer, None
        if pool is not None:
            pool.shutdown(wait=True)
        if fin is not None:
            fin.detach()
        self.store.join_writer()   # nor a store writer thread

    def _resolve_impl(self) -> str:
        impl = self.matmat_impl
        if impl not in _SCATTER_IMPLS:
            raise ValueError(f"matmat_impl must be one of {_SCATTER_IMPLS}, "
                             f"got {impl!r}")
        if impl == "auto":
            return "device" if jax.default_backend() != "cpu" else "host"
        return impl

    def matmat(self, V: np.ndarray) -> np.ndarray:
        """S @ V streaming one shard at a time — each CSR shard is pulled
        from the (possibly spilled) store ONCE PER BLOCK and its product
        amortized over all b columns, instead of once per vector; under a
        memory budget this divides the spill-reload traffic of an
        eigensolve by the block width.

        Shard gets run ``plan.prefetch_depth`` deep on a worker pool:
        while shard c multiplies, shards c+1..c+depth are already being
        fetched (spill-reload I/O included, in parallel — the store is
        thread-safe), and as the call returns the NEXT call's first
        window of shards starts loading, overlapping the eigensolver's
        QR / reorthogonalization work between passes.  On accelerator backends
        the per-shard product is a jitted device segment-sum, so shard
        c+1's upload overlaps shard c's multiply and the host only joins
        the results once at the end (``matmat_impl`` pins the scatter:
        "device" | "host" | "loop").  A fetch that finished before the
        consumer asked counts as a ``prefetch_hit`` (misses = the
        consumer had to wait); both land in ``stats_snapshot()`` and
        hence ``info_["engine"]``."""
        V = np.asarray(V)
        if V.ndim == 1:
            V = V[:, None]
        b = V.shape[1]
        Y = np.zeros((self.n, b), np.float32)
        self.stats.setdefault("prefetch_hits", 0)
        self.stats.setdefault("prefetch_misses", 0)
        impl = self._resolve_impl()
        pool = self._pool()
        ranges = self.plan.ranges
        nshards = len(ranges)
        depth = max(1, int(getattr(self.plan, "prefetch_depth", 1)))
        warm, self._warm = self._warm, None
        futs: Dict[int, object] = dict(warm or {})
        for c in range(min(depth, nshards)):     # fill the readahead window
            if c not in futs:
                futs[c] = self._submit_fetch(pool, c)
        V_dev = jnp.asarray(V, jnp.float32) if impl == "device" else None
        pending = []                             # (r0, r1, device result)
        for c, (r0, r1) in enumerate(ranges):
            fut = futs.pop(c)
            if c + depth < nshards:              # keep the window full —
                futs[c + depth] = self._submit_fetch(  # submitted BEFORE
                    pool, c + depth)             # joining c, so a stall
            self.stats["prefetch_hits" if fut.done()   # here is fetch time
                       else "prefetch_misses"] += 1
            sh = fut.result()
            indptr, indices, data = sh["indptr"], sh["indices"], sh["data"]
            rows = np.repeat(np.arange(r1 - r0), np.diff(indptr))
            if impl == "device":
                # pow2 nnz buckets bound recompiles; zero padding is inert
                target = max(256, 1 << max(0, int(len(data)) - 1).bit_length())
                out = _csr_segment_matmat(
                    jnp.asarray(_pad_nnz(data.astype(np.float32), target)),
                    jnp.asarray(_pad_nnz(indices, target)),
                    jnp.asarray(_pad_nnz(rows, target)),
                    V_dev, r1 - r0)
                pending.append((r0, r1, out))    # don't block: double-buffer
            elif impl == "host":
                scatter_rows(Y[r0:r1], rows, data[:, None] * V[indices])
            else:                                # "loop": PR-7 reference
                Y[r0:r1] = _bincount_loop_rows(rows,
                                               data[:, None] * V[indices],
                                               r1 - r0)
        for r0, r1, out in pending:              # one host join at the end
            Y[r0:r1] = np.asarray(out)
        # warm the next pass's first WINDOW while the caller (eigensolver)
        # crunches its Rayleigh-Ritz / orthogonalization step — without
        # this, every pass would re-miss its first depth-1 shards
        self._warm = {c: self._submit_fetch(pool, c)
                      for c in range(min(depth, nshards))}
        return Y

    def matvec(self, v: np.ndarray) -> np.ndarray:
        """S @ v — the width-1 view of :meth:`matmat`."""
        return self.matmat(np.asarray(v)[:, None])[:, 0]

    def to_dense(self) -> np.ndarray:
        """Dense S — test/oracle path only; defeats the engine if used at
        scale."""
        self._drain_prefetch()      # serialize vs the background workers
        S = np.zeros((self.n, self.n), np.float32)
        for c, (r0, r1) in enumerate(self.plan.ranges):
            sh = self.shard(c)
            indptr, indices, data = sh["indptr"], sh["indices"], sh["data"]
            rows = np.repeat(np.arange(r0, r1), np.diff(indptr))
            S[rows, indices] = data
        return S


def make_normalized_operator(graph: ShardedCSRGraph, dtype=jnp.float32,
                             mesh=None, pad_to: int | None = None
                             ) -> NormalizedOperator:
    """Wrap the sharded graph as the estimator's common operator interface:
    ``A v = valid*v + D^{-1/2} S D^{-1/2} v`` with the S-matvec streaming
    shards through a host callback.

    Two views of the same product are exposed: the traced ``matmat``
    (``pure_callback`` inside the computation — composable with any jitted
    consumer) and ``host_matmat``, the identical arithmetic as plain numpy
    on the host.  Eigensolvers prefer ``host_matmat`` and drive the
    recurrence step-by-step (``lanczos.block_run_host``): on hosts where
    the CPU runtime's worker pool has a single thread, the callback
    machinery can deadlock against its own operand transfer, so the hot
    path must not run the matrix pass inside a traced computation.

    ``pad_to`` rounds n_pad up (the estimator's mesh-divisibility
    invariant — every other affinity pads to a device-count multiple, and
    downstream shard_map stages require it); padding rows are zero-degree
    and masked out of ``valid`` exactly like the dense backends'.
    """
    n = graph.n
    n_pad = max(n, pad_to or n)
    deg = jnp.zeros((n_pad,), dtype).at[:n].set(jnp.asarray(graph.deg, dtype))
    inv_sqrt = jnp.where(deg > 0, 1.0 / jnp.sqrt(jnp.maximum(deg, 1e-12)), 0.0)
    valid = (jnp.arange(n_pad) < n).astype(dtype)

    def host_matmat(V):
        return graph.matmat(np.asarray(V, np.float32))

    def matmat(V: jax.Array) -> jax.Array:
        b = V.shape[1]
        out_shape = jax.ShapeDtypeStruct((n, b), jnp.float32)
        SV = jax.pure_callback(host_matmat, out_shape,
                               (inv_sqrt[:, None] * V)[:n].astype(jnp.float32))
        SV = jnp.zeros((n_pad, b), dtype).at[:n].set(SV.astype(dtype))
        return valid[:, None] * V + inv_sqrt[:, None] * SV

    # the SAME normalized product, entirely on the host (numpy in/out) —
    # elementwise f32 mul/add matches the traced version bitwise, and the
    # S matmat is the identical graph.matmat either way
    inv_np = np.asarray(inv_sqrt, np.float32)
    valid_np = np.asarray(valid, np.float32)

    def host_normalized_matmat(V: np.ndarray) -> np.ndarray:
        V = np.asarray(V, np.float32)
        SV = graph.matmat(np.ascontiguousarray((inv_np[:, None] * V)[:n]))
        SVp = np.zeros((n_pad, V.shape[1]), np.float32)
        SVp[:n] = SV
        return valid_np[:, None] * V + inv_np[:, None] * SVp

    def dense() -> jax.Array:
        S = jnp.zeros((n_pad, n_pad), dtype).at[:n, :n].set(
            jnp.asarray(graph.to_dense(), dtype))
        return jnp.diag(valid) + S * (inv_sqrt[:, None] * inv_sqrt[None, :])

    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt, n=n, n_pad=n_pad,
        mesh=mesh, schedule=None, dense=dense, stats=graph.stats_snapshot,
        close=graph.close,
        # f32-only: the host arithmetic is written in f32; other compute
        # dtypes fall back to the traced callback matmat
        host_matmat=(host_normalized_matmat
                     if jnp.dtype(dtype) == jnp.float32 else None))
