"""Spillable shard store: the engine's HDFS stand-in.

Every intermediate of the map/shuffle/reduce pipeline — map-task candidate
blocks, shuffle mirror partials, final CSR shards — lives in one
:class:`ShardStore`: a key -> {name: ndarray} map with an LRU RAM cache
bounded by ``memory_budget`` bytes.  When a put/get pushes the resident set
over budget, least-recently-used entries are written to ``spill_dir`` as
``.npz`` files and dropped from RAM; a later ``get`` transparently reloads
them.  With ``memory_budget=None`` nothing ever spills (pure in-RAM mode).

On-disk format (the shard-store contract, see API.md): one
``<mangled-key>.npz`` per spilled entry, containing exactly the named
arrays that were ``put``; keys mangle ``/`` to ``__``.  CSR shards use the
names ``indptr`` (int64, rows+1), ``indices`` (int64, nnz) and ``data``
(float32, nnz).
"""
from __future__ import annotations

import os
import shutil
import tempfile
import weakref
from collections import OrderedDict
from typing import Dict, Iterator, Optional

import numpy as np


def _nbytes(arrays: Dict[str, np.ndarray]) -> int:
    return int(sum(a.nbytes for a in arrays.values()))


class ShardStore:
    def __init__(self, memory_budget: Optional[int] = None,
                 spill_dir: Optional[str] = None):
        self.memory_budget = memory_budget
        self._own_dir = spill_dir is None
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="repro-shards-")
        os.makedirs(self.spill_dir, exist_ok=True)
        if self._own_dir:
            # a store-created temp dir must not outlive the store: clean it
            # up at GC / interpreter exit (caller-supplied dirs are the
            # caller's to manage)
            self._finalizer = weakref.finalize(
                self, shutil.rmtree, self.spill_dir, ignore_errors=True)
        self._ram: "OrderedDict[str, Dict[str, np.ndarray]]" = OrderedDict()
        self._disk: Dict[str, str] = {}          # key -> npz path
        self.ram_bytes = 0
        self.stats = {
            "puts": 0, "gets": 0, "spills": 0, "drops": 0, "loads": 0,
            "bytes_spilled": 0, "peak_ram_bytes": 0,
        }

    # -- core ops -----------------------------------------------------------

    def put(self, key: str, arrays: Dict[str, np.ndarray]) -> None:
        arrays = {name: np.asarray(a) for name, a in arrays.items()}
        self.delete(key)
        self._ram[key] = arrays
        self.ram_bytes += _nbytes(arrays)
        self.stats["puts"] += 1
        self.stats["peak_ram_bytes"] = max(self.stats["peak_ram_bytes"],
                                           self.ram_bytes)
        self._enforce_budget()

    def get(self, key: str) -> Dict[str, np.ndarray]:
        self.stats["gets"] += 1
        if key in self._ram:
            self._ram.move_to_end(key)           # LRU touch
            return self._ram[key]
        path = self._disk.get(key)
        if path is None:
            raise KeyError(f"shard store has no entry {key!r}")
        with np.load(path) as z:
            arrays = {name: z[name] for name in z.files}
        self.stats["loads"] += 1
        self._ram[key] = arrays
        self.ram_bytes += _nbytes(arrays)
        self.stats["peak_ram_bytes"] = max(self.stats["peak_ram_bytes"],
                                           self.ram_bytes)
        self._enforce_budget(keep=key)
        return arrays

    def delete(self, key: str) -> None:
        arrays = self._ram.pop(key, None)
        if arrays is not None:
            self.ram_bytes -= _nbytes(arrays)
        path = self._disk.pop(key, None)
        if path is not None and os.path.exists(path):
            os.remove(path)

    def __contains__(self, key: str) -> bool:
        return key in self._ram or key in self._disk

    def keys(self, prefix: str = "") -> Iterator[str]:
        seen = set(self._ram) | set(self._disk)
        return iter(sorted(k for k in seen if k.startswith(prefix)))

    def spilled_keys(self) -> tuple[str, ...]:
        """Entries currently resident on disk only (spilled and not since
        reloaded)."""
        return tuple(sorted(k for k in self._disk if k not in self._ram))

    # -- spilling -----------------------------------------------------------

    def _path(self, key: str) -> str:
        return os.path.join(self.spill_dir, key.replace("/", "__") + ".npz")

    def _spill_one(self, key: str) -> None:
        arrays = self._ram.pop(key)
        nbytes = _nbytes(arrays)
        self.ram_bytes -= nbytes
        if key not in self._disk:                # first eviction: write it
            path = self._path(key)
            np.savez(path, **arrays)
            self._disk[key] = path
            self.stats["bytes_spilled"] += nbytes
            self.stats["spills"] += 1
        else:                                    # reloaded copy: just drop —
            self.stats["drops"] += 1             # the npz is already current

    def _enforce_budget(self, keep: Optional[str] = None) -> None:
        if self.memory_budget is None:
            return
        while self.ram_bytes > self.memory_budget and self._ram:
            victim = next(iter(self._ram))       # least recently used
            if victim == keep:
                if len(self._ram) == 1:
                    # the caller holds a reference to ``keep`` — evicting
                    # it here would make every over-budget get() reload
                    # and re-drop the same entry forever
                    break
                self._ram.move_to_end(victim)
                victim = next(iter(self._ram))
            self._spill_one(victim)

    def close(self) -> None:
        """Drop everything (RAM and spill files; removes the spill dir only
        when the store created it — also triggered automatically when a
        store-owned dir's ShardStore is garbage collected)."""
        for key in list(self._disk):
            path = self._disk.pop(key)
            if os.path.exists(path):
                os.remove(path)
        self._ram.clear()
        self.ram_bytes = 0
        if self._own_dir:
            self._finalizer()     # rmtree now; disarms the GC finalizer
