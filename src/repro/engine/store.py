"""Spillable shard store: the engine's HDFS stand-in.

Every intermediate of the map/shuffle/reduce pipeline — map-task candidate
blocks, shuffle mirror partials, final CSR shards — lives in one
:class:`ShardStore`: a key -> {name: ndarray} map with an LRU RAM cache
bounded by ``memory_budget`` bytes.  When a put/get pushes the resident set
over budget, least-recently-used entries are written to ``spill_dir`` as
raw ``.bin`` files (see :func:`save_entry`) and dropped from RAM; a later
``get`` transparently reloads them.  With ``memory_budget=None`` nothing
ever spills (pure in-RAM mode).

The store is **thread-safe**: concurrent map/shuffle/reduce tasks and the
operator's prefetch workers share one store, with all LRU/spill bookkeeping
behind a lock.  Disk I/O happens *outside* the lock, so prefetch workers
load spilled shards in parallel, and evictions are **asynchronous** by
default (``async_spill=True``): ``_spill_one`` hands the file write to a
single background writer thread and returns immediately — the evicted
entry sits in a "spilling" state until the write lands, a ``get`` during
that window joins the in-flight write (returns the still-held arrays
without touching disk), and ``flush()`` / ``close()`` / ``spilled_keys()``
are the quiescence points where every queued write has completed and the
budget/stat accounting is exact.

On-disk format v2 (the shard-store contract, see API.md): one
``<mangled-key>.bin`` per spilled entry — an 8-byte magic, header length,
payload length and a CRC32 covering everything after the fixed preamble
(the pickled header AND the payload), then the pickled header listing
``(name, dtype, shape)`` for every array that was ``put``, followed by the
raw array buffers back to back; keys mangle ``/`` to ``__``.  Writes are
**atomic** (tmp file + ``os.replace``) and reads **verified**: a
truncated or bit-flipped file — payload bytes or a flipped shape/dtype
literal inside the header alike — raises :class:`ShardCorruptionError`
instead of silently misparsing.  v1 files (no magic; PR 8's unchecked
layout) still load.  CSR shards use the names ``indptr`` (int64, rows+1),
``indices`` (int32, nnz) and ``data`` (float32, nnz).

Resilience hooks: ``store.recovery`` — a ``(key, exc) -> bool`` callable
consulted when a ``get`` hits a corrupt (:class:`ShardCorruptionError`)
or lost (:class:`ShardLostError`) spill file; the runner installs a
task-lineage hook that re-runs the producing task (re-``put``-ing the
entry) and the ``get`` then retries.  ``store.faults`` — an optional
:class:`~repro.engine.faults.FaultPlan` whose ``on_spill`` hook runs
after each spill write lands (deterministic corruption injection).
"""
from __future__ import annotations

import os
import pickle
import shutil
import tempfile
import threading
import weakref
import zlib
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from dataclasses import dataclass, field
from typing import Any, Callable, Dict, Iterator, Optional

import numpy as np

_MAGIC = b"RSHRDv2\n"                 # 8 bytes; v1 files start with a tiny
_V2_HEAD = len(_MAGIC) + 8 + 8 + 4    # little-endian header length instead


class ShardCorruptionError(ValueError):
    """A spill file failed verification (bad length or CRC32)."""

    def __init__(self, path: str, reason: str, key: Optional[str] = None):
        self.path = path
        self.reason = reason
        self.key = key
        super().__init__(f"corrupt spill file {path!r}: {reason}")


class ShardLostError(KeyError):
    """A spilled entry's file vanished from disk (the store still had a
    record of it) — the typed signal the lineage-recovery hook catches."""

    def __init__(self, key: str, path: str):
        self.key = key
        self.path = path
        super().__init__(key)

    def __str__(self) -> str:
        return (f"spill file for entry {self.key!r} lost "
                f"(expected at {self.path!r})")


def _nbytes(arrays: Dict[str, np.ndarray]) -> int:
    return int(sum(a.nbytes for a in arrays.values()))


def save_entry(path: str, arrays: Dict[str, np.ndarray]) -> None:
    """Write ``arrays`` in spill format v2: magic, 8-byte header length,
    8-byte payload length, a 4-byte CRC32 of header-plus-payload, the
    pickled ``[(name, dtype.str, shape), ...]`` header, then the
    contiguous array buffers concatenated in header order.  The CRC
    covers the header bytes too — a flipped byte inside a pickled
    shape/dtype literal could otherwise deserialize cleanly into a
    wrongly-shaped array.  The write is atomic — a tmp file in the same
    directory is ``os.replace``d over ``path``, so a crash mid-write can
    never leave a half-written file under the real name."""
    bufs = [memoryview(np.ascontiguousarray(a)).cast("B")
            for a in arrays.values()]
    hdr = pickle.dumps([(k, a.dtype.str, a.shape) for k, a in arrays.items()],
                       protocol=4)
    crc = zlib.crc32(hdr)
    payload_len = 0
    for b in bufs:
        crc = zlib.crc32(b, crc)
        payload_len += len(b)
    tmp = f"{path}.tmp.{os.getpid()}.{threading.get_ident()}"
    try:
        with open(tmp, "wb") as f:
            f.write(_MAGIC)
            f.write(len(hdr).to_bytes(8, "little"))
            f.write(payload_len.to_bytes(8, "little"))
            f.write((crc & 0xFFFFFFFF).to_bytes(4, "little"))
            f.write(hdr)
            for b in bufs:
                f.write(b)
        os.replace(tmp, path)
    finally:
        if os.path.exists(tmp):
            os.remove(tmp)


def _parse_entries(buf: bytes, hdr_bytes: bytes, off: int,
                   path: str) -> Dict[str, np.ndarray]:
    try:
        entries = pickle.loads(hdr_bytes)
    except Exception as e:
        raise ShardCorruptionError(path, f"unreadable header ({e})") from e
    out: Dict[str, np.ndarray] = {}
    for name, dt, shape in entries:
        count = int(np.prod(shape, dtype=np.int64))
        a = np.frombuffer(buf, dtype=np.dtype(dt), count=count,
                          offset=off).reshape(shape)
        out[name] = a
        off += a.nbytes
    return out


def load_entry(path: str) -> Dict[str, np.ndarray]:
    """Read a :func:`save_entry` file back into {name: ndarray}.  Arrays
    are zero-copy (read-only) views over one contiguous buffer — store
    consumers treat entries as immutable (a ``put`` replaces wholesale).

    v2 files are verified (total length, then the CRC32 of everything
    after the fixed preamble — pickled header and payload) and raise
    :class:`ShardCorruptionError` on any mismatch; legacy v1 files (no
    magic) take the old unchecked parse for compatibility."""
    with open(path, "rb") as f:
        buf = f.read()
    if buf[:len(_MAGIC)] != _MAGIC:               # legacy v1 layout
        hlen = int.from_bytes(buf[:8], "little")
        if 8 + hlen > len(buf):
            raise ShardCorruptionError(path, "truncated v1 header")
        return _parse_entries(buf, buf[8:8 + hlen], 8 + hlen, path)
    hlen = int.from_bytes(buf[8:16], "little")
    plen = int.from_bytes(buf[16:24], "little")
    crc = int.from_bytes(buf[24:28], "little")
    off = _V2_HEAD + hlen
    if len(buf) != off + plen:
        raise ShardCorruptionError(
            path, f"bad length (expected {off + plen} bytes, "
                  f"found {len(buf)})")
    if zlib.crc32(buf[_V2_HEAD:]) & 0xFFFFFFFF != crc:
        raise ShardCorruptionError(path, "CRC32 mismatch (header or payload)")
    return _parse_entries(buf, buf[28:28 + hlen], off, path)


@dataclass
class _Spilling:
    """An evicted entry whose spill write is still in flight."""
    arrays: Dict[str, np.ndarray]
    nbytes: int
    seq: int                     # spill generation: stale writers no-op
    future: Any = field(default=None)


class ShardStore:
    def __init__(self, memory_budget: Optional[int] = None,
                 spill_dir: Optional[str] = None,
                 async_spill: bool = True):
        self.memory_budget = memory_budget
        self.async_spill = async_spill
        self._own_dir = spill_dir is None
        self.spill_dir = spill_dir or tempfile.mkdtemp(prefix="repro-shards-")
        os.makedirs(self.spill_dir, exist_ok=True)
        if self._own_dir:
            # a store-created temp dir must not outlive the store: clean it
            # up at GC / interpreter exit (caller-supplied dirs are the
            # caller's to manage)
            self._finalizer = weakref.finalize(
                self, shutil.rmtree, self.spill_dir, ignore_errors=True)
        self._lock = threading.RLock()
        self._ram: "OrderedDict[str, Dict[str, np.ndarray]]" = OrderedDict()
        self._disk: Dict[str, str] = {}          # key -> spill file path
        self._spilling: Dict[str, _Spilling] = {}
        self._spilling_bytes = 0
        self._seq = 0
        self._writer_pool: Optional[ThreadPoolExecutor] = None
        self._writer_finalizer = None
        self.ram_bytes = 0
        # resilience hooks (see module docstring): the runner installs a
        # lineage-recovery callable; tests/benchmarks install a FaultPlan
        self.recovery: Optional[Callable[[str, Exception], bool]] = None
        self.faults: Any = None
        self.stats = {
            "puts": 0, "gets": 0, "spills": 0, "drops": 0, "loads": 0,
            "spill_joins": 0, "bytes_spilled": 0, "peak_ram_bytes": 0,
            "recoveries": 0,
        }

    def _post_spill(self, key: str, path: str) -> None:
        """Fault-injection hook point: runs after a spill write lands."""
        if self.faults is not None:
            self.faults.on_spill(key, path)

    # -- background writer ---------------------------------------------------

    def _writer(self) -> ThreadPoolExecutor:
        # caller holds the lock (only _spill_one calls this, mid-eviction).
        # Single worker: all spill writes serialize in submission order, so
        # two spills of the same key can never race on one path
        if self._writer_pool is None:
            pool = ThreadPoolExecutor(max_workers=1,
                                      thread_name_prefix="repro-store-spill")
            self._writer_pool = pool
            self._writer_finalizer = weakref.finalize(
                self, ThreadPoolExecutor.shutdown, pool, wait=True)
        return self._writer_pool

    def _write_entry(self, key: str, arrays: Dict[str, np.ndarray],
                     path: str, seq: int) -> None:
        """Writer-thread body: the file write runs outside the lock; the
        commit (or stale-write cleanup) takes it briefly."""
        save_entry(path, arrays)
        self._post_spill(key, path)
        with self._lock:
            ent = self._spilling.get(key)
            if ent is not None and ent.seq == seq:
                del self._spilling[key]
                self._spilling_bytes -= ent.nbytes
                self._disk[key] = path
            elif ent is not None:
                # a newer spill of the same key is queued BEHIND us (single
                # writer, FIFO): it will rewrite the path — leave it alone
                pass
            elif key not in self._disk:
                # deleted (or re-put) while we were writing: the file we
                # just produced is an orphan
                try:
                    os.remove(path)
                except OSError:
                    pass

    def flush(self) -> None:
        """Block until every in-flight spill write has landed (write
        errors propagate).  After ``flush`` returns — and no other thread
        is mutating the store — ``ram_bytes`` / ``spilled_keys()`` /
        ``stats`` describe a fully settled store."""
        while True:
            with self._lock:
                futs = [e.future for e in self._spilling.values()
                        if e.future is not None]
            if not futs:
                return
            for f in futs:
                f.result()

    def join_writer(self) -> None:
        """Flush and shut the background writer down WITHOUT dropping any
        data (unlike :meth:`close`).  Non-final: the next async spill
        lazily restarts the writer — callers use this to guarantee no
        ``repro-store-spill`` thread outlives a finished job."""
        self.flush()
        with self._lock:
            pool, self._writer_pool = self._writer_pool, None
            fin, self._writer_finalizer = self._writer_finalizer, None
        if pool is not None:
            pool.shutdown(wait=True)
        if fin is not None:
            fin.detach()

    def _throttle_spills(self) -> None:
        """Backpressure: never let the writer queue hold more than one
        budget's worth of evicted-but-unwritten bytes (a burst of puts
        could otherwise queue unbounded RAM behind the single writer)."""
        if self.memory_budget is None:
            return
        while True:
            with self._lock:
                if self._spilling_bytes <= self.memory_budget:
                    return
                fut = next(iter(self._spilling.values())).future
            try:
                fut.result()
            except Exception:
                pass

    # -- core ops -----------------------------------------------------------

    def _forget_locked(self, key: str) -> Optional[str]:
        """Drop every trace of ``key`` (RAM, spilling state, disk record);
        returns the spill path to unlink, if any.  Caller holds the lock."""
        arrays = self._ram.pop(key, None)
        if arrays is not None:
            self.ram_bytes -= _nbytes(arrays)
        ent = self._spilling.pop(key, None)
        if ent is not None:
            # the in-flight writer will see its seq gone and remove the
            # file it produces (or a re-put's newer write supersedes it)
            self._spilling_bytes -= ent.nbytes
        return self._disk.pop(key, None)

    def put(self, key: str, arrays: Dict[str, np.ndarray]) -> None:
        arrays = {name: np.asarray(a) for name, a in arrays.items()}
        with self._lock:
            stale = self._forget_locked(key)
            if stale is not None and os.path.exists(stale):
                os.remove(stale)
            self._ram[key] = arrays
            self.ram_bytes += _nbytes(arrays)
            self.stats["puts"] += 1
            self.stats["peak_ram_bytes"] = max(self.stats["peak_ram_bytes"],
                                               self.ram_bytes)
            self._enforce_budget()
        self._throttle_spills()

    def get(self, key: str, *,
            _recovered: bool = False) -> Dict[str, np.ndarray]:
        with self._lock:
            self.stats["gets"] += 1
            if key in self._ram:
                self._ram.move_to_end(key)       # LRU touch
                return self._ram[key]
            ent = self._spilling.get(key)
            if ent is not None:
                # join the in-flight write: promote the still-held arrays
                # straight back to RAM — no disk round-trip.  The write
                # continues and lands in _disk, so a later eviction of
                # this entry is a plain drop.
                self._ram[key] = ent.arrays
                self.ram_bytes += ent.nbytes
                self.stats["spill_joins"] += 1
                self.stats["peak_ram_bytes"] = max(
                    self.stats["peak_ram_bytes"], self.ram_bytes)
                self._enforce_budget(keep=key)
                return ent.arrays
            path = self._disk.get(key)
            if path is None:
                raise KeyError(f"shard store has no entry {key!r}")
        # disk I/O outside the lock: concurrent prefetch workers load
        # different spilled shards in parallel
        try:
            arrays = load_entry(path)
        except (FileNotFoundError, ShardCorruptionError) as e:
            return self._failed_load(key, path, e, _recovered)
        with self._lock:
            self.stats["loads"] += 1
            if key in self._ram:                 # a concurrent get() won
                self._ram.move_to_end(key)
                return self._ram[key]
            self._ram[key] = arrays
            self.ram_bytes += _nbytes(arrays)
            self.stats["peak_ram_bytes"] = max(self.stats["peak_ram_bytes"],
                                               self.ram_bytes)
            self._enforce_budget(keep=key)
        return arrays

    def _failed_load(self, key: str, path: str, err: Exception,
                     already_recovered: bool) -> Dict[str, np.ndarray]:
        """A disk load came back corrupt or file-not-found.  Distinguish
        the benign races (a concurrent put/delete of the same key) from
        genuine data loss; on loss, consult the lineage-recovery hook —
        a successful hook re-``put``s the entry and the get retries."""
        retry = False
        with self._lock:
            if key in self._ram:                 # concurrent re-put won
                self._ram.move_to_end(key)
                return self._ram[key]
            if key in self._spilling:            # re-put/spill in flight
                retry = True
            elif key not in self._disk:          # deleted concurrently
                raise KeyError(f"shard store has no entry {key!r} "
                               f"(deleted concurrently)") from None
        if retry:
            return self.get(key, _recovered=already_recovered)
        if isinstance(err, ShardCorruptionError):
            err.key = key
            exc: Exception = err
        else:
            exc = ShardLostError(key, path)
        hook = self.recovery
        if already_recovered or hook is None or not hook(key, exc):
            # unrecoverable: leave the store's record (and any corrupt
            # file) in place so retries of the consuming task fail the
            # same way instead of silently folding without this entry
            raise exc
        with self._lock:
            self.stats["recoveries"] += 1
        return self.get(key, _recovered=True)

    def delete(self, key: str) -> None:
        with self._lock:
            path = self._forget_locked(key)
            if path is not None and os.path.exists(path):
                os.remove(path)

    def __contains__(self, key: str) -> bool:
        with self._lock:
            return (key in self._ram or key in self._disk
                    or key in self._spilling)

    def keys(self, prefix: str = "") -> Iterator[str]:
        with self._lock:
            seen = set(self._ram) | set(self._disk) | set(self._spilling)
        return iter(sorted(k for k in seen if k.startswith(prefix)))

    def spilled_keys(self) -> tuple[str, ...]:
        """Entries currently resident on disk only (spilled and not since
        reloaded).  A quiescence point: joins in-flight writes first so
        every reported key's spill file actually exists."""
        self.flush()
        with self._lock:
            return tuple(sorted(k for k in self._disk if k not in self._ram))

    # -- spilling -----------------------------------------------------------

    def _path(self, key: str) -> str:
        return os.path.join(self.spill_dir, key.replace("/", "__") + ".bin")

    def _spill_one(self, key: str) -> None:
        # caller holds the lock
        arrays = self._ram.pop(key)
        nbytes = _nbytes(arrays)
        self.ram_bytes -= nbytes
        if key in self._disk or key in self._spilling:
            # the spill file is already current (or an identical write is in
            # flight — promote-and-re-evict shares the same arrays): drop
            self.stats["drops"] += 1
            return
        self.stats["bytes_spilled"] += nbytes
        self.stats["spills"] += 1
        path = self._path(key)
        if not self.async_spill:
            save_entry(path, arrays)
            self._post_spill(key, path)
            self._disk[key] = path
            return
        self._seq += 1
        ent = _Spilling(arrays=arrays, nbytes=nbytes, seq=self._seq)
        self._spilling[key] = ent
        self._spilling_bytes += nbytes
        ent.future = self._writer().submit(self._write_entry, key, arrays,
                                           path, ent.seq)

    def _enforce_budget(self, keep: Optional[str] = None) -> None:
        if self.memory_budget is None:
            return
        while self.ram_bytes > self.memory_budget and self._ram:
            victim = next(iter(self._ram))       # least recently used
            if victim == keep:
                if len(self._ram) == 1:
                    # the caller holds a reference to ``keep`` — evicting
                    # it here would make every over-budget get() reload
                    # and re-drop the same entry forever
                    break
                self._ram.move_to_end(victim)
                victim = next(iter(self._ram))
            self._spill_one(victim)

    def close(self) -> None:
        """Drop everything (RAM and spill files; removes the spill dir only
        when the store created it — also triggered automatically when a
        store-owned dir's ShardStore is garbage collected).  Joins the
        background writer so no write is in flight while files vanish."""
        try:
            self.flush()
        except Exception:
            pass                  # a failed write still must not block close
        with self._lock:
            pool, self._writer_pool = self._writer_pool, None
            fin, self._writer_finalizer = self._writer_finalizer, None
            paths = list(self._disk.values())
            self._disk.clear()
            self._ram.clear()
            self._spilling.clear()
            self._spilling_bytes = 0
            self.ram_bytes = 0
        if pool is not None:
            pool.shutdown(wait=True)
        if fin is not None:
            fin.detach()
        for path in paths:
            if os.path.exists(path):
                os.remove(path)
        if self._own_dir:
            self._finalizer()     # rmtree now; disarms the GC finalizer
