"""CLI: ``python -m repro.analysis check src/ [--baseline F] [--json]``.

Exit code 0 when every finding is baselined (or none exist), 1 when new
findings gate the change, 2 on usage errors.  ``--update-baseline``
rewrites the baseline from the current run (accept-and-move-on for
legacy findings); ``rules`` prints the catalog.
"""
from __future__ import annotations

import argparse
import json
import sys

from repro.analysis import (check, format_human, load_baseline,
                            save_baseline)
from repro.analysis.findings import finalize_fingerprints
from repro.analysis.rules import RULES


def _cmd_check(args: argparse.Namespace) -> int:
    report = check(args.paths, root=args.root,
                   baseline_path=args.baseline, only=args.rules)
    if args.update_baseline:
        if not args.baseline:
            print("--update-baseline requires --baseline", file=sys.stderr)
            return 2
        save_baseline(args.baseline,
                      finalize_fingerprints(report.findings))
        print(f"[analysis] baseline {args.baseline} updated: "
              f"{len(report.findings)} finding(s) accepted "
              f"({len(report.expired)} stale entr"
              f"{'y' if len(report.expired) == 1 else 'ies'} dropped)")
        return 0
    if args.json:
        print(json.dumps(report.to_dict(), indent=2, sort_keys=True))
    else:
        print(format_human(report, baseline_path=args.baseline))
    return 1 if report.new else 0


def _cmd_rules(_args: argparse.Namespace) -> int:
    for rule_id in sorted(RULES):
        info = RULES[rule_id]
        print(f"{rule_id}  [{info.severity:7s}] ({info.family}) "
              f"{info.summary}")
    return 0


def main(argv=None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="Repo-aware static analysis (see API.md).")
    sub = parser.add_subparsers(dest="cmd", required=True)

    p_check = sub.add_parser("check", help="run every rule over paths")
    p_check.add_argument("paths", nargs="+",
                         help="files or directories to analyze")
    p_check.add_argument("--root", default=".",
                         help="repo root paths are relative to")
    p_check.add_argument("--baseline", default=None,
                         help="baseline JSON; findings in it don't gate")
    p_check.add_argument("--update-baseline", action="store_true",
                         help="rewrite the baseline from this run")
    p_check.add_argument("--json", action="store_true",
                         help="machine-readable report on stdout")
    p_check.add_argument("--rules", nargs="*", default=None,
                         help="run only these rule ids")
    p_check.set_defaults(fn=_cmd_check)

    p_rules = sub.add_parser("rules", help="print the rule catalog")
    p_rules.set_defaults(fn=_cmd_rules)

    args = parser.parse_args(argv)
    # a bad --baseline should be a clean usage error, not a traceback
    if getattr(args, "baseline", None):
        try:
            load_baseline(args.baseline)
        except (ValueError, json.JSONDecodeError) as e:
            print(f"error: {e}", file=sys.stderr)
            return 2
    return args.fn(args)


if __name__ == "__main__":
    sys.exit(main())
