"""The analysis driver: parse every ``.py`` under the given paths into a
:class:`Project`, run each registered rule over it, honor inline
suppressions, and return fingerprinted findings.

Rules are *project-scoped*, not file-scoped — the lock-order graph and
the kernel/ref-twin contract both need to see every module at once — so
a rule is one ``check(project) -> [Finding]`` callable (see
:mod:`repro.analysis.rules`).

Inline suppression: a flagged line carrying ``# repro: ignore`` mutes
every rule on that line; ``# repro: ignore[C001,K002]`` mutes only the
named rules.  Suppressions are for deliberate, commented exceptions —
legacy debt belongs in the baseline instead (see
:mod:`repro.analysis.baseline`).
"""
from __future__ import annotations

import ast
import os
import re
from dataclasses import dataclass, field
from typing import Dict, List, Optional, Sequence

from repro.analysis.findings import Finding, finalize_fingerprints

_SUPPRESS_RE = re.compile(r"#\s*repro:\s*ignore(?:\[([A-Za-z0-9_,\s]+)\])?")


@dataclass
class Module:
    """One parsed source file plus everything rules ask of it."""
    path: str                    # repo-relative, forward slashes
    name: str                    # dotted module name best-effort
    source: str
    tree: ast.Module
    lines: List[str] = field(default_factory=list)

    def line(self, lineno: int) -> str:
        if 1 <= lineno <= len(self.lines):
            return self.lines[lineno - 1].strip()
        return ""

    def segment(self, node: ast.AST) -> str:
        """Source text spanned by ``node`` (for comment-scanning rules)."""
        lo = getattr(node, "lineno", 1)
        hi = getattr(node, "end_lineno", lo)
        return "\n".join(self.lines[lo - 1:hi])

    def suppressed(self, lineno: int, rule_id: str) -> bool:
        m = _SUPPRESS_RE.search(self.line(lineno))
        if m is None:
            return False
        if m.group(1) is None:
            return True
        return rule_id in {r.strip() for r in m.group(1).split(",")}


@dataclass
class Project:
    root: str
    modules: List[Module] = field(default_factory=list)

    def by_path(self, suffix: str) -> Optional[Module]:
        for m in self.modules:
            if m.path.endswith(suffix):
                return m
        return None

    def finding(self, module: Module, rule_id: str, severity: str,
                node_or_line, message: str) -> Optional[Finding]:
        """Build a finding at an AST node (or a bare line number); returns
        None when an inline comment suppresses it."""
        lineno = getattr(node_or_line, "lineno", node_or_line)
        if module.suppressed(lineno, rule_id):
            return None
        return Finding(rule=rule_id, severity=severity, path=module.path,
                       line=int(lineno), message=message,
                       snippet=module.line(lineno))


def _module_name(rel_path: str) -> str:
    parts = rel_path[:-3].split("/")            # strip .py
    if parts and parts[0] == "src":
        parts = parts[1:]
    if parts and parts[-1] == "__init__":
        parts = parts[:-1]
    return ".".join(parts)


def collect_files(paths: Sequence[str], root: str) -> List[str]:
    """Every ``.py`` file under the given files/directories, sorted,
    repo-relative to ``root``; hidden and cache dirs skipped."""
    found = set()
    for p in paths:
        ap = os.path.join(root, p) if not os.path.isabs(p) else p
        if os.path.isfile(ap) and ap.endswith(".py"):
            found.add(os.path.relpath(ap, root))
            continue
        for dirpath, dirnames, filenames in os.walk(ap):
            dirnames[:] = [d for d in dirnames
                           if not d.startswith(".") and
                           d != "__pycache__"]
            for fn in filenames:
                if fn.endswith(".py"):
                    found.add(os.path.relpath(os.path.join(dirpath, fn),
                                              root))
    return sorted(f.replace(os.sep, "/") for f in found)


def load_project(paths: Sequence[str], root: str = ".") -> Project:
    root = os.path.abspath(root)
    project = Project(root=root)
    for rel in collect_files(paths, root):
        full = os.path.join(root, rel)
        with open(full, encoding="utf-8") as f:
            source = f.read()
        try:
            tree = ast.parse(source, filename=rel)
        except SyntaxError as e:
            # a file the analyzer cannot parse is itself a finding target,
            # but never a crash; surface it as a pseudo-module with an
            # empty tree and let the syntax rule in rules/__init__ flag it
            tree = ast.Module(body=[], type_ignores=[])
            tree._syntax_error = e               # type: ignore[attr-defined]
        project.modules.append(Module(path=rel, name=_module_name(rel),
                                      source=source, tree=tree,
                                      lines=source.splitlines()))
    return project


def run_rules(project: Project,
              rules: Optional[Dict[str, object]] = None,
              only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run every registered rule (or the ``only`` subset) and return the
    fingerprinted, source-ordered findings."""
    from repro.analysis.rules import RULES
    registry = dict(rules if rules is not None else RULES)
    if only:
        unknown = sorted(set(only) - set(registry))
        if unknown:
            raise KeyError(f"unknown rule ids {unknown}; have "
                           f"{sorted(registry)}")
        registry = {k: v for k, v in registry.items() if k in only}
    findings: List[Finding] = []
    for rule_id in sorted(registry):
        info = registry[rule_id]
        findings.extend(f for f in info.check(project) if f is not None)
    return finalize_fingerprints(findings)


def format_human(report, baseline_path: Optional[str] = None) -> str:
    """The terminal report: new findings first (the gate), then a one-line
    tally of the muted baseline and any expired entries."""
    out = []
    for f in report.new:
        out.append(f.format())
        if f.snippet:
            out.append(f"    {f.snippet}")
    if report.expired:
        out.append(f"[analysis] {len(report.expired)} baseline entr"
                   f"{'y is' if len(report.expired) == 1 else 'ies are'} "
                   f"stale (fixed or moved) — refresh with "
                   f"--update-baseline:")
        for e in report.expired:
            out.append(f"    {e.get('rule')} {e.get('path')}: "
                       f"{e.get('message')}")
    gate = "FAIL" if report.new else "OK"
    base = f", {len(report.baselined)} baselined" if baseline_path else ""
    out.append(f"[analysis] {gate}: {len(report.new)} new finding(s)"
               f"{base}, {report.files_checked} files checked")
    return "\n".join(out)
