"""Rule registry + the AST helpers every rule family shares.

A rule is registered with the :func:`rule` decorator and receives the
whole :class:`~repro.analysis.engine.Project` — rules here are repo-aware
(the lock-order graph spans modules; the kernel contract pairs
``kernels/*.py`` with ``kernels/ref.py``), so per-file scoping would be
the wrong shape.  Rule ids are stable API: they appear in baselines,
suppression comments, and CI logs.
"""
from __future__ import annotations

import ast
from typing import Dict, Iterator, List, Optional, Tuple

from repro.analysis.engine import Module, Project
from repro.analysis.findings import Finding, RuleInfo

RULES: Dict[str, RuleInfo] = {}


def rule(rule_id: str, severity: str, summary: str, family: str):
    def deco(fn):
        if rule_id in RULES:
            raise ValueError(f"duplicate rule id {rule_id}")
        RULES[rule_id] = RuleInfo(rule_id=rule_id, severity=severity,
                                  summary=summary, check=fn, family=family)
        return fn
    return deco


# -- shared AST helpers ------------------------------------------------------

def dotted(node: ast.AST) -> Optional[str]:
    """``a.b.c`` for Name/Attribute chains, else None."""
    parts: List[str] = []
    while isinstance(node, ast.Attribute):
        parts.append(node.attr)
        node = node.value
    if isinstance(node, ast.Name):
        parts.append(node.id)
        return ".".join(reversed(parts))
    return None


def iter_functions(tree: ast.AST, qual: str = ""
                   ) -> Iterator[Tuple[str, ast.FunctionDef]]:
    """Every (qualname, def) in the module — top-level functions, methods,
    and nested defs alike."""
    for node in ast.walk(tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            yield node.name, node


def top_level_functions(tree: ast.Module) -> Dict[str, ast.FunctionDef]:
    return {n.name: n for n in tree.body
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def local_calls(fn: ast.AST) -> List[str]:
    """Names this function calls that could resolve locally: bare names
    and ``self.method`` attributes."""
    out = []
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            if isinstance(node.func, ast.Name):
                out.append(node.func.id)
            elif (isinstance(node.func, ast.Attribute)
                  and isinstance(node.func.value, ast.Name)
                  and node.func.value.id == "self"):
                out.append(node.func.attr)
    return out


def transitive_closure(roots: List[str],
                       graph: Dict[str, List[str]]) -> set:
    seen = set()
    stack = list(roots)
    while stack:
        f = stack.pop()
        if f in seen:
            continue
        seen.add(f)
        stack.extend(graph.get(f, ()))
    return seen


def call_graph(defs: Dict[str, ast.FunctionDef]) -> Dict[str, List[str]]:
    return {name: [c for c in local_calls(fn) if c in defs]
            for name, fn in defs.items()}


# -- the one engine-level rule ----------------------------------------------

@rule("S000", "error", "file fails to parse", family="general")
def check_syntax(project: Project) -> List[Finding]:
    out = []
    for m in project.modules:
        err = getattr(m.tree, "_syntax_error", None)
        if err is not None:
            out.append(Finding(rule="S000", severity="error", path=m.path,
                               line=int(err.lineno or 1),
                               message=f"syntax error: {err.msg}",
                               snippet=m.line(int(err.lineno or 1))))
    return out


# Importing the families registers their rules.
from repro.analysis.rules import concurrency   # noqa: E402,F401
from repro.analysis.rules import jax_purity    # noqa: E402,F401
from repro.analysis.rules import kernel_contract  # noqa: E402,F401

__all__ = ["RULES", "rule", "dotted", "iter_functions",
           "top_level_functions", "local_calls", "transitive_closure",
           "call_graph", "Module", "Project"]
