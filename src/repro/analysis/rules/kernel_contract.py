"""Kernel-contract rules: the repo's Pallas discipline, mechanized.

Every kernel ships with three artifacts that drift independently: the
kernel module (``kernels/<name>.py``), its pure-JAX oracle twin
(``kernels/ref.py``), and the public padded wrapper (``kernels/ops.py``)
that resolves tiles through the schedule layer.  These rules pin the
triangle together:

K001  every public kernel entry point (a top-level public function that
      transitively calls ``pallas_call`` within its module) must have a
      same-named oracle in ``kernels/ref.py``.
K002  every public ``ops.py`` wrapper that dispatches into a kernel
      module must route through ``ops._resolve`` (the one schedule /
      legality / interpret-autodetect boilerplate site).
K003  tile sizes are :class:`~repro.tune.Schedule` business: outside
      ``kernels/`` and ``tune/``, a call passing a literal ``bm=``/
      ``bn=``/``bq=``/``bk=`` (or a literal-shaped ``pl.BlockSpec``)
      re-hardcodes what the autotuner owns.
"""
from __future__ import annotations

import ast
from typing import List, Set

from repro.analysis.engine import Module, Project
from repro.analysis.findings import Finding
from repro.analysis.rules import (call_graph, dotted, rule,
                                  top_level_functions, transitive_closure)

_KERNELS_DIR = "repro/kernels/"
_EXEMPT_KERNEL_MODULES = {"__init__", "ops", "ref"}
_TILE_KEYWORDS = {"bm", "bn", "bq", "bk"}
_SCHEDULE_FREE_DIRS = ("repro/kernels/", "repro/tune/")


def _kernel_modules(project: Project) -> List[Module]:
    out = []
    for m in project.modules:
        if _KERNELS_DIR not in m.path:
            continue
        stem = m.path.rsplit("/", 1)[-1][:-3]
        if stem not in _EXEMPT_KERNEL_MODULES:
            out.append(m)
    return out


def _calls_pallas(fn: ast.AST) -> bool:
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            d = dotted(node.func)
            if d is not None and d.endswith("pallas_call"):
                return True
    return False


def _pallas_entry_points(module: Module) -> List[ast.FunctionDef]:
    """Public top-level functions that reach a ``pallas_call`` through
    module-local calls — the functions a ref twin must oracle."""
    defs = top_level_functions(module.tree)
    graph = call_graph(defs)
    out = []
    for name, fn in defs.items():
        if name.startswith("_"):
            continue
        closure = transitive_closure([name], graph)
        if any(_calls_pallas(defs[c]) for c in closure if c in defs):
            out.append(fn)
    return out


@rule("K001", "error",
      "Pallas kernel entry point has no ref.py oracle twin",
      family="kernel-contract")
def check_ref_twin(project: Project) -> List[Finding]:
    ref = project.by_path("repro/kernels/ref.py")
    out: List[Finding] = []
    for m in _kernel_modules(project):
        entries = _pallas_entry_points(m)
        if not entries:
            continue
        if ref is None:
            out.append(project.finding(
                m, "K001", "error", entries[0],
                "kernels/ref.py is missing — every Pallas kernel needs "
                "its pure-JAX oracle twin"))
            continue
        ref_names = set(top_level_functions(ref.tree))
        for fn in entries:
            if fn.name not in ref_names:
                f = project.finding(
                    m, "K001", "error", fn,
                    f"kernel entry point {fn.name}() has no same-named "
                    f"oracle in kernels/ref.py — add the reference twin "
                    f"(tests diff kernel vs oracle)")
                if f is not None:
                    out.append(f)
    return out


def _kernel_import_aliases(fn_or_mod: ast.AST) -> Set[str]:
    """Local names bound to kernel modules by ``from repro.kernels
    import X [as Y]`` anywhere in the given scope (``ref`` excluded —
    calling the oracle is not a kernel dispatch)."""
    out: Set[str] = set()
    for node in ast.walk(fn_or_mod):
        if (isinstance(node, ast.ImportFrom)
                and node.module == "repro.kernels"):
            for alias in node.names:
                if alias.name not in ("ref", "ops"):
                    out.add(alias.asname or alias.name)
    return out


@rule("K002", "error",
      "ops.py kernel wrapper does not route through _resolve",
      family="kernel-contract")
def check_wrapper_resolves(project: Project) -> List[Finding]:
    ops = project.by_path("repro/kernels/ops.py")
    if ops is None:
        return []
    aliases = _kernel_import_aliases(ops.tree)
    defs = top_level_functions(ops.tree)
    graph = call_graph(defs)
    out: List[Finding] = []
    for name, fn in defs.items():
        if name.startswith("_"):
            continue
        closure = transitive_closure([name], graph)
        fns = [defs[c] for c in closure if c in defs]
        local_aliases = set(aliases)
        for f in fns:
            local_aliases |= _kernel_import_aliases(f)
        dispatches = False
        resolves = False
        for f in fns:
            for node in ast.walk(f):
                if isinstance(node, ast.Call):
                    if (isinstance(node.func, ast.Attribute)
                            and isinstance(node.func.value, ast.Name)
                            and node.func.value.id in local_aliases):
                        dispatches = True
                    elif (isinstance(node.func, ast.Name)
                          and node.func.id == "_resolve"):
                        resolves = True
        if dispatches and not resolves:
            f = project.finding(
                ops, "K002", "error", fn,
                f"wrapper {name}() dispatches into a kernel module "
                f"without calling _resolve() — tiles bypass the "
                f"schedule layer's legality checks and cache")
            if f is not None:
                out.append(f)
    return out


@rule("K003", "warning",
      "tile-size literal outside the schedule layer",
      family="kernel-contract")
def check_hardcoded_tiles(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for m in project.modules:
        if any(d in m.path for d in _SCHEDULE_FREE_DIRS):
            continue
        for node in ast.walk(m.tree):
            if not isinstance(node, ast.Call):
                continue
            d = dotted(node.func) or ""
            for kw in node.keywords:
                if (kw.arg in _TILE_KEYWORDS
                        and isinstance(kw.value, ast.Constant)
                        and isinstance(kw.value.value, int)):
                    f = project.finding(
                        m, "K003", "warning", node,
                        f"hardcoded tile {kw.arg}={kw.value.value} — "
                        f"tile sizes come from a tune.Schedule "
                        f"(pass schedule=... or leave the default)")
                    if f is not None:
                        out.append(f)
            if d.endswith("BlockSpec") and node.args:
                shape = node.args[0]
                if (isinstance(shape, ast.Tuple)
                        and shape.elts
                        and all(isinstance(e, ast.Constant)
                                and isinstance(e.value, int)
                                for e in shape.elts)):
                    f = project.finding(
                        m, "K003", "warning", node,
                        "literal BlockSpec shape outside kernels/ — "
                        "block shapes belong to the kernel module and "
                        "its Schedule")
                    if f is not None:
                        out.append(f)
    return out
