"""JAX purity rules: traced code must be pure — a ``time.time()`` baked
into a jitted function is a constant after the first trace, an unseeded
``np.random`` call silently freezes, and a host side effect inside a
``pallas_call`` body runs once at trace time (or not at all on TPU).

J001  impure host calls (wall clocks, unseeded numpy RNG) lexically
      reachable from a jitted function or a Pallas kernel body, via the
      module-local call graph.
J002  host side effects (print/open/os/logging/...) inside a Pallas
      kernel body; ``jax.debug.*`` and ``pl.debug_print`` are the
      sanctioned escape hatches and stay allowed.
J003  tracer concretization: ``.item()`` in jit-reachable code, and
      ``float()``/``int()``/``bool()`` applied directly to a positional
      parameter of a jitted function (positional params are tracers;
      keyword-only params are static and stay allowed).

Reachability is per-module and name-based — deliberately conservative;
the cross-module surface is covered by the kernel-contract rules and the
runtime lockcheck's dynamic cousin philosophy: cheap, repo-tuned, zero
false negatives on the patterns we actually shipped.
"""
from __future__ import annotations

import ast
from typing import Dict, List, Set, Tuple

from repro.analysis.engine import Module, Project
from repro.analysis.findings import Finding
from repro.analysis.rules import (call_graph, dotted, rule,
                                  transitive_closure)

_CLOCK_CALLS = {"time.time", "time.perf_counter", "time.monotonic",
                "time.process_time", "datetime.now", "datetime.utcnow",
                "datetime.datetime.now", "datetime.datetime.utcnow",
                "datetime.date.today", "date.today"}
_UNSEEDED_RNG = {"rand", "randn", "random", "normal", "uniform", "randint",
                 "choice", "permutation", "shuffle", "random_sample",
                 "standard_normal", "seed"}
_HOST_EFFECT_CALLS = {"print", "open", "input", "breakpoint", "exec",
                      "eval"}
_HOST_EFFECT_PREFIXES = ("os.", "sys.", "logging.", "shutil.", "time.",
                         "np.save", "np.load", "numpy.save", "numpy.load")
_ALLOWED_DEBUG_PREFIXES = ("jax.debug.", "pl.debug_print", "pallas.debug")


def _impure_call(d: str) -> bool:
    if d in _CLOCK_CALLS:
        return True
    for prefix in ("np.random.", "numpy.random.", "random."):
        if d.startswith(prefix) and d.rsplit(".", 1)[-1] in _UNSEEDED_RNG:
            return True
    return False


def _is_jit_decorator(node: ast.AST) -> bool:
    d = dotted(node)
    if d in ("jax.jit", "jit"):
        return True
    if isinstance(node, ast.Call):
        f = dotted(node.func)
        if f in ("jax.jit", "jit"):
            return True
        if f in ("partial", "functools.partial") and node.args:
            return dotted(node.args[0]) in ("jax.jit", "jit")
    return False


def _collect_roots(module: Module) -> Dict[str, str]:
    """Function name -> why it's traced ("jit" | "kernel") for every
    jit-decorated / jax.jit()-wrapped function and every function passed
    as a ``pallas_call`` body (directly or through ``partial``)."""
    methods = {n.name for n in ast.walk(module.tree)
               if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}
    roots: Dict[str, str] = {}
    for node in ast.walk(module.tree):
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
            if any(_is_jit_decorator(dec) for dec in node.decorator_list):
                roots[node.name] = "jit"
        elif isinstance(node, ast.Call):
            f = dotted(node.func)
            if f in ("jax.jit", "jit") and node.args:
                target = node.args[0]
                if isinstance(target, ast.Name) and target.id in methods:
                    roots.setdefault(target.id, "jit")
            elif f is not None and f.endswith("pallas_call") and node.args:
                target = node.args[0]
                if (isinstance(target, ast.Call)
                        and dotted(target.func) in ("partial",
                                                    "functools.partial")
                        and target.args):
                    target = target.args[0]
                if isinstance(target, ast.Name) and target.id in methods:
                    roots[target.id] = "kernel"
    return roots


def _all_defs(module: Module) -> Dict[str, ast.FunctionDef]:
    """name -> def for every function in the module (methods included;
    last definition wins — conservative for reachability)."""
    return {n.name: n for n in ast.walk(module.tree)
            if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef))}


def _reachable(module: Module, roots: Dict[str, str]
               ) -> Tuple[Set[str], Dict[str, ast.FunctionDef]]:
    defs = _all_defs(module)
    graph = call_graph(defs)
    return transitive_closure(list(roots), graph), defs


@rule("J001", "error",
      "impure host call (clock / unseeded RNG) reachable from traced code",
      family="jax-purity")
def check_impure_in_traced(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for m in project.modules:
        roots = _collect_roots(m)
        if not roots:
            continue
        reach, defs = _reachable(m, roots)
        for name in sorted(reach):
            fn = defs.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d is not None and _impure_call(d):
                    why = roots.get(name, "traced code")
                    out.append(project.finding(
                        m, "J001", "error", node,
                        f"impure call {d}() inside {name}() which is "
                        f"reachable from {why} code — its value freezes "
                        f"at trace time; pass it in as an argument"))
    return [f for f in out if f is not None]


@rule("J002", "error",
      "host side effect inside a Pallas kernel body", family="jax-purity")
def check_kernel_side_effects(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for m in project.modules:
        kernels = [name for name, why in _collect_roots(m).items()
                   if why == "kernel"]
        if not kernels:
            continue
        defs = _all_defs(m)
        for name in kernels:
            fn = defs.get(name)
            if fn is None:
                continue
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                d = dotted(node.func)
                if d is None:
                    continue
                if d.startswith(_ALLOWED_DEBUG_PREFIXES):
                    continue
                if (d in _HOST_EFFECT_CALLS
                        or d.startswith(_HOST_EFFECT_PREFIXES)):
                    out.append(project.finding(
                        m, "J002", "error", node,
                        f"host side effect {d}() inside Pallas kernel "
                        f"body {name}() — kernels run on device; use "
                        f"jax.debug / pl.debug_print or hoist it out"))
    return [f for f in out if f is not None]


@rule("J003", "error",
      "tracer concretized (.item() / float() on a traced value)",
      family="jax-purity")
def check_tracer_concretization(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for m in project.modules:
        roots = _collect_roots(m)
        if not roots:
            continue
        reach, defs = _reachable(m, roots)
        for name in sorted(reach):
            fn = defs.get(name)
            if fn is None:
                continue
            # positional params of a traced ROOT are tracers for sure;
            # reached helpers get only the .item() check (their args may
            # be static python by the time they're called)
            tracer_params: Set[str] = set()
            if name in roots:
                tracer_params = {a.arg for a in fn.args.args
                                 if a.arg not in ("self", "cls")}
            for node in ast.walk(fn):
                if not isinstance(node, ast.Call):
                    continue
                if (isinstance(node.func, ast.Attribute)
                        and node.func.attr == "item"
                        and not node.args):
                    out.append(project.finding(
                        m, "J003", "error", node,
                        f".item() inside traced {name}() forces the "
                        f"tracer to a host scalar — this fails (or "
                        f"silently constant-folds) under jit"))
                elif (isinstance(node.func, ast.Name)
                      and node.func.id in ("float", "int", "bool")
                      and len(node.args) == 1
                      and isinstance(node.args[0], ast.Name)
                      and node.args[0].id in tracer_params):
                    out.append(project.finding(
                        m, "J003", "error", node,
                        f"{node.func.id}() applied to traced parameter "
                        f"'{node.args[0].id}' of {name}() concretizes a "
                        f"tracer"))
    return [f for f in out if f is not None]
