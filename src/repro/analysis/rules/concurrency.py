"""Concurrency rules: the hand-rolled runtime (daemon pools, spilling
store, span stacks, metrics registry) is all guarded by per-object
``threading.Lock``s — these rules catch the three drift patterns that
actually bite such code:

C001  an attribute mutated both under ``with self._lock`` and bare —
      the classic torn-update race.
C002  inconsistent lock acquisition order across the codebase (a static
      lock-order graph with cycle detection; the runtime twin is
      :mod:`repro.analysis.lockcheck`), plus nested re-acquisition of a
      known non-reentrant ``threading.Lock``.
C003  concurrency results dropped on the floor: a ``.submit()`` Future
      discarded (its exception is silently lost) or a non-daemon
      ``threading.Thread`` that is never joined.

Methods named ``*_locked``, ``__init__``/``__new__``/``__del__``, and
methods whose text declares the convention ("caller holds the lock")
count as lock-held for C001.
"""
from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Set, Tuple

from repro.analysis.engine import Module, Project
from repro.analysis.findings import Finding
from repro.analysis.rules import dotted, rule

_LOCK_FACTORIES = {"threading.Lock", "threading.RLock",
                   "threading.Condition", "Lock", "RLock", "Condition"}
_LOCK_NAME_RE = re.compile(r"(^|_)(lock|rlock|mutex|mu)$")
_HELD_COMMENT_RE = re.compile(r"caller[\s\S]{0,60}?hold[\s\S]{0,60}?lock|"
                              r"hold[\s\S]{0,40}?lock[\s\S]{0,40}?caller",
                              re.IGNORECASE)
_EXEMPT_METHODS = {"__init__", "__new__", "__del__", "__post_init__"}


def _lock_attrs(cls: ast.ClassDef) -> Dict[str, str]:
    """Attr name -> factory kind for every ``self.X = threading.Lock()``-
    style assignment in the class, plus any ``with self.X`` whose name
    looks lock-like (covers locks injected from outside)."""
    locks: Dict[str, str] = {}
    for node in ast.walk(cls):
        if isinstance(node, ast.Assign) and isinstance(node.value, ast.Call):
            kind = dotted(node.value.func)
            if kind in _LOCK_FACTORIES:
                for t in node.targets:
                    if (isinstance(t, ast.Attribute)
                            and isinstance(t.value, ast.Name)
                            and t.value.id == "self"):
                        locks[t.attr] = kind.split(".")[-1]
        elif isinstance(node, ast.With):
            for item in node.items:
                e = item.context_expr
                if (isinstance(e, ast.Attribute)
                        and isinstance(e.value, ast.Name)
                        and e.value.id == "self"
                        and _LOCK_NAME_RE.search(e.attr)):
                    locks.setdefault(e.attr, "unknown")
    return locks


def _mutated_attr(target: ast.AST) -> Optional[str]:
    """The ``self.X`` attribute a store-target mutates, unwrapping
    subscripts (``self.stats["k"] += 1`` mutates ``stats``) and slices."""
    while isinstance(target, (ast.Subscript, ast.Starred)):
        target = target.value
    if (isinstance(target, ast.Attribute)
            and isinstance(target.value, ast.Name)
            and target.value.id == "self"):
        return target.attr
    return None


def _is_lock_with(item: ast.withitem, lock_names: Set[str]) -> bool:
    e = item.context_expr
    return (isinstance(e, ast.Attribute) and isinstance(e.value, ast.Name)
            and e.value.id == "self" and e.attr in lock_names)


def _method_lock_context(module: Module, fn: ast.FunctionDef
                         ) -> Optional[str]:
    """"construct" for lifecycle methods whose mutations predate (or
    postdate) sharing and count for neither side; "held" for methods the
    code declares lock-held by convention (``*_locked`` names, "caller
    holds the lock" comments), whose mutations count as locked; None for
    ordinary methods."""
    if fn.name in _EXEMPT_METHODS:
        return "construct"
    if fn.name.endswith("_locked"):
        return "held"
    if _HELD_COMMENT_RE.search(module.segment(fn)) is not None:
        return "held"
    return None


def _scan_mutations(fn: ast.FunctionDef, lock_names: Set[str]
                    ) -> List[Tuple[str, int, bool]]:
    """(attr, line, under_lock) for every self-attribute store in the
    method.  Nested function bodies are skipped: they run later, on a
    thread we cannot see, so charging them to the lexical lock scope
    would be wrong in both directions."""
    out: List[Tuple[str, int, bool]] = []

    def visit(node: ast.AST, locked: bool) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)):
            return
        if isinstance(node, ast.With):
            inner = locked or any(_is_lock_with(i, lock_names)
                                  for i in node.items)
            for child in node.body:
                visit(child, inner)
            return
        targets: List[ast.AST] = []
        if isinstance(node, ast.Assign):
            targets = list(node.targets)
        elif isinstance(node, (ast.AugAssign, ast.AnnAssign)):
            targets = [node.target]
        elif isinstance(node, ast.Delete):
            targets = list(node.targets)
        for t in targets:
            if isinstance(t, ast.Tuple):
                sub = list(t.elts)
            else:
                sub = [t]
            for s in sub:
                attr = _mutated_attr(s)
                if attr is not None and attr not in lock_names:
                    out.append((attr, node.lineno, locked))
        for child in ast.iter_child_nodes(node):
            visit(child, locked)

    for stmt in fn.body:
        visit(stmt, False)
    return out


@rule("C001", "error",
      "attribute mutated both inside and outside the class's lock",
      family="concurrency")
def check_mixed_lock_discipline(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for m in project.modules:
        for cls in [n for n in ast.walk(m.tree)
                    if isinstance(n, ast.ClassDef)]:
            locks = _lock_attrs(cls)
            if not locks:
                continue
            lock_names = set(locks)
            locked_sites: Dict[str, List[int]] = {}
            unlocked_sites: Dict[str, List[Tuple[str, int]]] = {}
            for fn in [n for n in cls.body
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))]:
                ctx = _method_lock_context(m, fn)
                for attr, line, locked in _scan_mutations(fn, lock_names):
                    if ctx == "construct" and not locked:
                        continue             # lifecycle: pre-sharing
                    if locked or ctx == "held":
                        locked_sites.setdefault(attr, []).append(line)
                    else:
                        unlocked_sites.setdefault(attr, []).append(
                            (fn.name, line))
            for attr in sorted(set(locked_sites) & set(unlocked_sites)):
                guarded = min(locked_sites[attr])
                for fn_name, line in unlocked_sites[attr]:
                    out.append(project.finding(
                        m, "C001", "error", line,
                        f"'self.{attr}' of {cls.name} is mutated in "
                        f"{fn_name}() without the lock, but under it at "
                        f"line {guarded} — guard every mutation or mark "
                        f"the method as lock-held"))
    return [f for f in out if f is not None]


# -- C002: static lock-order graph ------------------------------------------

def _lock_node(module: Module, cls: Optional[str], func: str,
               expr: ast.AST) -> Optional[str]:
    """A stable cross-codebase id for a lock expression, or None when the
    expression doesn't look like a lock.  ``self.X`` keys on the class
    (every instance shares the discipline); bare names key on the
    enclosing function."""
    if isinstance(expr, ast.Attribute) and isinstance(expr.value, ast.Name):
        if expr.value.id == "self" and _LOCK_NAME_RE.search(expr.attr):
            scope = cls if cls is not None else func
            return f"{module.name}.{scope}.{expr.attr}"
        d = dotted(expr)
        if d is not None and _LOCK_NAME_RE.search(expr.attr):
            return f"{module.name}.{d}"
    elif isinstance(expr, ast.Name) and _LOCK_NAME_RE.search(expr.id):
        return f"{module.name}.{func}.{expr.id}"
    return None


def _walk_lock_nesting(module: Module, cls: Optional[str],
                       fn: ast.FunctionDef, edges, self_nests) -> None:
    def visit(node: ast.AST, held: List[str]) -> None:
        if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef,
                             ast.Lambda)) and node is not fn:
            for child in ast.iter_child_nodes(node):
                visit(child, [])            # deferred body: fresh stack
            return
        if isinstance(node, ast.With):
            pushed = list(held)
            for item in node.items:
                nid = _lock_node(module, cls, fn.name, item.context_expr)
                if nid is None:
                    continue
                if nid in pushed:
                    self_nests.append((nid, module, node.lineno))
                else:
                    if pushed:
                        edges.setdefault((pushed[-1], nid), []).append(
                            (module, node.lineno))
                    pushed.append(nid)
            for child in node.body:
                visit(child, pushed)
            return
        for child in ast.iter_child_nodes(node):
            visit(child, held)

    for stmt in fn.body:
        visit(stmt, [])


def _find_cycle(edges: Dict[Tuple[str, str], list]) -> Optional[List[str]]:
    graph: Dict[str, List[str]] = {}
    for a, b in edges:
        graph.setdefault(a, []).append(b)
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in
             set(graph) | {b for vs in graph.values() for b in vs}}
    parent: Dict[str, str] = {}

    def dfs(n: str) -> Optional[List[str]]:
        color[n] = GREY
        for nxt in graph.get(n, ()):
            if color[nxt] == GREY:           # back edge: reconstruct
                cyc = [nxt, n]
                cur = n
                while cur != nxt:
                    cur = parent[cur]
                    cyc.append(cur)
                return list(reversed(cyc))
            if color[nxt] == WHITE:
                parent[nxt] = n
                got = dfs(nxt)
                if got is not None:
                    return got
        color[n] = BLACK
        return None

    for n in sorted(color):
        if color[n] == WHITE:
            got = dfs(n)
            if got is not None:
                return got
    return None


def build_lock_order_graph(project: Project):
    """(edges, self_nests): every lexical outer->inner lock nesting in the
    project, and every re-entry of a lock already held.  Exposed for
    tests and for cross-validation against the runtime lockcheck."""
    edges: Dict[Tuple[str, str], list] = {}
    self_nests: list = []
    for m in project.modules:
        classes = {id(fn): cls.name for cls in ast.walk(m.tree)
                   if isinstance(cls, ast.ClassDef)
                   for fn in cls.body
                   if isinstance(fn, (ast.FunctionDef,
                                      ast.AsyncFunctionDef))}
        for node in m.tree.body:
            stack = [(node, None)]
            while stack:
                cur, cls = stack.pop()
                if isinstance(cur, ast.ClassDef):
                    for child in cur.body:
                        stack.append((child, cur.name))
                elif isinstance(cur, (ast.FunctionDef,
                                      ast.AsyncFunctionDef)):
                    _walk_lock_nesting(m, cls or classes.get(id(cur)),
                                       cur, edges, self_nests)
    return edges, self_nests


def _nonreentrant_locks(project: Project) -> Set[str]:
    """Node ids known to be plain ``threading.Lock`` (not RLock)."""
    out: Set[str] = set()
    for m in project.modules:
        for cls in [n for n in ast.walk(m.tree)
                    if isinstance(n, ast.ClassDef)]:
            for attr, kind in _lock_attrs(cls).items():
                if kind == "Lock":
                    out.add(f"{m.name}.{cls.name}.{attr}")
    return out


@rule("C002", "error",
      "inconsistent lock acquisition order (cycle in the lock-order graph)",
      family="concurrency")
def check_lock_order(project: Project) -> List[Finding]:
    edges, self_nests = build_lock_order_graph(project)
    out: List[Finding] = []
    nonreentrant = _nonreentrant_locks(project)
    for nid, module, lineno in self_nests:
        if nid in nonreentrant:
            out.append(project.finding(
                module, "C002", "error", lineno,
                f"non-reentrant lock {nid} acquired while already held "
                f"— this deadlocks at runtime"))
    cycle = _find_cycle(edges)
    if cycle is not None:
        a, b = cycle[0], cycle[1]
        module, lineno = edges[(a, b)][0]
        out.append(project.finding(
            module, "C002", "error", lineno,
            "lock-order cycle: " + " -> ".join(cycle) +
            " — acquire these locks in one global order"))
    return [f for f in out if f is not None]


# -- C003: dropped concurrency results --------------------------------------

@rule("C003", "warning",
      "thread/executor result consumed without join/result",
      family="concurrency")
def check_unconsumed_results(project: Project) -> List[Finding]:
    out: List[Finding] = []
    for m in project.modules:
        for node in ast.walk(m.tree):
            # a bare-statement submit: the Future (and its exception)
            # is unreachable from that point on
            if (isinstance(node, ast.Expr)
                    and isinstance(node.value, ast.Call)
                    and isinstance(node.value.func, ast.Attribute)
                    and node.value.func.attr == "submit"):
                out.append(project.finding(
                    m, "C003", "warning", node,
                    "Future from .submit() is discarded — its exception "
                    "can never be observed; keep it and call .result() "
                    "(or wait on it)"))
            # a non-daemon Thread nobody joins outlives (and can hang)
            # the process
            if isinstance(node, ast.Call):
                d = dotted(node.func)
                if d in ("threading.Thread", "Thread"):
                    daemon = any(k.arg == "daemon" and
                                 isinstance(k.value, ast.Constant) and
                                 k.value.value is True
                                 for k in node.keywords)
                    if not daemon and ".join(" not in m.source:
                        out.append(project.finding(
                            m, "C003", "warning", node,
                            "non-daemon Thread is never joined in this "
                            "module — pass daemon=True or join it"))
    return [f for f in out if f is not None]
