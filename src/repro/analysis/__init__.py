"""Repo-aware static analysis + runtime lock-discipline checking.

The paper's thesis is that the *framework* guarantees correct parallel
execution; this package is that guarantee for our hand-rolled concurrent
runtime.  ``python -m repro.analysis check src/`` runs an AST pass with
three repo-tuned rule families — concurrency (C0xx), jax-purity (J0xx),
kernel-contract (K0xx) — against a committed baseline, and
:mod:`repro.analysis.lockcheck` cross-validates the static lock-order
rule at test time (``REPRO_LOCKCHECK=1``).  See API.md "Static analysis"
for the rule catalog.
"""
from repro.analysis.baseline import (apply_baseline, load_baseline,
                                     save_baseline)
from repro.analysis.engine import (Module, Project, format_human,
                                   load_project, run_rules)
from repro.analysis.findings import CheckReport, Finding, RuleInfo


def check(paths, root=".", baseline_path=None, only=None) -> CheckReport:
    """Parse, run every rule, apply the baseline; the one-call API the
    CLI and tests share."""
    project = load_project(paths, root=root)
    findings = run_rules(project, only=only)
    baseline = load_baseline(baseline_path) if baseline_path else {}
    return apply_baseline(findings, baseline,
                          files_checked=len(project.modules))


__all__ = ["check", "CheckReport", "Finding", "RuleInfo", "Module",
           "Project", "load_project", "run_rules", "format_human",
           "load_baseline", "save_baseline", "apply_baseline"]
