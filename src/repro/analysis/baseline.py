"""Baseline file: the committed ledger of accepted legacy findings.

CI gates on *unbaselined* findings only — the pass can land on a codebase
with known, deliberate violations (e.g. ``flash_attention`` predating the
schedule layer) without blocking every PR, while any NEW violation fails.
``--update-baseline`` rewrites the file from the current run (adding new
findings, dropping expired entries), so the workflow is:

    python -m repro.analysis check src/ --baseline .analysis-baseline.json
    # fix what you can; for the rest:
    python -m repro.analysis check src/ --baseline .analysis-baseline.json \
        --update-baseline
"""
from __future__ import annotations

import json
import os
from typing import Any, Dict, List

from repro.analysis.findings import CheckReport, Finding

BASELINE_VERSION = 1


def load_baseline(path: str) -> Dict[str, Dict[str, Any]]:
    """fingerprint -> entry map; a missing file is an empty baseline, a
    corrupt or version-mismatched one is an error (a silently ignored
    baseline would re-flag hundreds of accepted findings)."""
    if not os.path.exists(path):
        return {}
    with open(path) as f:
        doc = json.load(f)
    if doc.get("version") != BASELINE_VERSION:
        raise ValueError(f"baseline {path!r} has version "
                         f"{doc.get('version')!r}, expected "
                         f"{BASELINE_VERSION}")
    return {e["fingerprint"]: e for e in doc.get("findings", [])}


def save_baseline(path: str, findings: List[Finding]) -> None:
    doc = {"version": BASELINE_VERSION,
           "findings": [{"fingerprint": f.fingerprint, "rule": f.rule,
                         "path": f.path, "message": f.message,
                         "snippet": f.snippet}
                        for f in sorted(findings,
                                        key=lambda f: (f.path, f.line,
                                                       f.rule))]}
    tmp = f"{path}.tmp.{os.getpid()}"
    with open(tmp, "w") as f:
        json.dump(doc, f, indent=2, sort_keys=True)
        f.write("\n")
    os.replace(tmp, path)


def apply_baseline(findings: List[Finding],
                   baseline: Dict[str, Dict[str, Any]],
                   files_checked: int = 0) -> CheckReport:
    """Split findings into new vs baselined; baseline entries whose
    fingerprint no longer matches any finding are reported as expired."""
    report = CheckReport(findings=list(findings), files_checked=files_checked)
    live = set()
    for f in findings:
        if f.fingerprint in baseline:
            live.add(f.fingerprint)
            report.baselined.append(f)
        else:
            report.new.append(f)
    report.expired = [e for fp, e in sorted(baseline.items())
                      if fp not in live]
    return report
