"""Typed findings: what a rule reports, how it serializes, and the
line-number-stable fingerprint the baseline matches on.

A :class:`Finding` is one (rule, severity, file:line, message, snippet)
record.  Its ``fingerprint`` deliberately EXCLUDES the line number: it
hashes ``rule | path | normalized snippet | occurrence index`` (the index
disambiguates identical snippets in one file), so unrelated edits above a
baselined finding don't expire it, while moving the code to another file
or changing the flagged line itself does.
"""
from __future__ import annotations

import hashlib
from dataclasses import dataclass, field
from typing import Any, Dict, List

SEVERITIES = ("error", "warning")


@dataclass(frozen=True)
class Finding:
    rule: str                    # e.g. "C001"
    severity: str                # "error" | "warning"
    path: str                    # repo-relative, forward slashes
    line: int                    # 1-based
    message: str
    snippet: str = ""            # the flagged source line, stripped
    fingerprint: str = ""        # filled by finalize_fingerprints

    def to_dict(self) -> Dict[str, Any]:
        return {"rule": self.rule, "severity": self.severity,
                "path": self.path, "line": self.line,
                "message": self.message, "snippet": self.snippet,
                "fingerprint": self.fingerprint}

    def format(self) -> str:
        return (f"{self.path}:{self.line}: {self.rule} "
                f"[{self.severity}] {self.message}")


def _digest(rule: str, path: str, snippet: str, occurrence: int) -> str:
    norm = " ".join(snippet.split())
    raw = f"{rule}|{path}|{norm}|{occurrence}"
    return hashlib.sha1(raw.encode("utf-8")).hexdigest()[:16]


def finalize_fingerprints(findings: List[Finding]) -> List[Finding]:
    """Assign stable fingerprints: findings sharing (rule, path, snippet)
    are numbered by source order so duplicates stay distinct."""
    seen: Dict[tuple, int] = {}
    out: List[Finding] = []
    for f in sorted(findings, key=lambda f: (f.path, f.line, f.rule)):
        key = (f.rule, f.path, " ".join(f.snippet.split()))
        occ = seen.get(key, 0)
        seen[key] = occ + 1
        out.append(Finding(rule=f.rule, severity=f.severity, path=f.path,
                           line=f.line, message=f.message, snippet=f.snippet,
                           fingerprint=_digest(f.rule, f.path, f.snippet,
                                               occ)))
    return out


@dataclass
class RuleInfo:
    """Registry entry: one rule id, its severity, and the checker."""
    rule_id: str
    severity: str
    summary: str
    check: Any                   # Callable[[Project], List[Finding]]
    family: str = "general"

    def __post_init__(self):
        if self.severity not in SEVERITIES:
            raise ValueError(f"bad severity {self.severity!r}")


@dataclass
class CheckReport:
    """Everything one ``check`` run produced, pre-split against the
    baseline (``new`` fails the gate; ``baselined`` is muted legacy;
    ``expired`` names baseline entries no longer found in the code)."""
    findings: List[Finding] = field(default_factory=list)
    new: List[Finding] = field(default_factory=list)
    baselined: List[Finding] = field(default_factory=list)
    expired: List[Dict[str, Any]] = field(default_factory=list)
    files_checked: int = 0

    def to_dict(self) -> Dict[str, Any]:
        return {"version": 1,
                "files_checked": self.files_checked,
                "counts": {"total": len(self.findings),
                           "new": len(self.new),
                           "baselined": len(self.baselined),
                           "expired": len(self.expired)},
                "findings": [f.to_dict() for f in self.new],
                "baselined": [f.to_dict() for f in self.baselined],
                "expired": self.expired}
