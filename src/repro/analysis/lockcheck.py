"""Runtime lock-discipline mode: the dynamic twin of the static C002
lock-order rule.

``install()`` replaces ``threading.Lock`` / ``threading.RLock`` with
factories returning *tracked* proxies.  Every acquisition is recorded
against the acquiring thread's held-stack; holding lock A while
acquiring lock B adds the edge ``site(A) -> site(B)`` to a global
acquisition-order graph, where a lock's *site* is the ``file:line`` that
allocated it (all instances from one allocation site share a node — the
discipline is per-site, not per-instance, so ``ShardStore._lock`` is one
node no matter how many stores a test builds).  A cycle in that graph is
a latent deadlock even if this run interleaved safely —
:func:`assert_acyclic` turns it into a hard failure.  Only locks
allocated from repo code are tracked; stdlib / site-packages allocators
get a plain untracked lock (their internal orderings are not this
repo's discipline).

Tests enable it with ``REPRO_LOCKCHECK=1`` (see ``tests/conftest.py``);
the CI lockcheck job runs the tier-1 suite under it and fails on any
ordering cycle.  Same-site edges (two instances of the same class locked
in sequence) are not recorded: they are overwhelmingly the benign
"iterate over stores" pattern, and the static rule still flags genuine
nested self-acquisition of a non-reentrant lock.

The proxies implement the full lock protocol including the private
``_release_save`` / ``_acquire_restore`` / ``_is_owned`` hooks
``threading.Condition`` uses, so wrapped locks work inside Condition,
Future, Queue, and friends.
"""
from __future__ import annotations

import os
import sys
import sysconfig
import threading
from collections import defaultdict
from typing import Any, Dict, List, Optional, Tuple

_REAL_LOCK = threading.Lock
_REAL_RLOCK = threading.RLock

# locks allocated by the stdlib or third-party packages are NOT tracked:
# their internal orderings (e.g. ThreadPoolExecutor's per-executor lock
# vs. concurrent.futures' module-global shutdown lock) are CPython's
# discipline to keep, not this repo's, and tracking them produces
# false-positive cycles.  Only repo-allocated locks enter the graph.
_STDLIB_PREFIX = sysconfig.get_paths()["stdlib"]


class LockOrderError(RuntimeError):
    """The acquisition-order graph has a cycle (latent deadlock)."""


class _State:
    def __init__(self) -> None:
        self.mu = _REAL_LOCK()              # guards everything below
        self.sites: Dict[str, int] = {}     # site -> locks allocated there
        self.edges: Dict[Tuple[str, str], int] = defaultdict(int)
        self.held: Dict[int, List[str]] = defaultdict(list)  # tid -> sites
        self.acquisitions = 0


_state: Optional[_State] = None
_installed = False


def _foreign(filename: str) -> bool:
    """True when ``filename`` belongs to the stdlib or an installed
    package rather than this repo — such allocation sites are untracked."""
    fn = filename.replace(os.sep, "/")
    return (filename.startswith(_STDLIB_PREFIX)
            or "site-packages" in fn or "dist-packages" in fn
            or filename.startswith("<"))


def _allocation_site() -> Optional[str]:
    """file:line of the frame that called the lock factory, skipping this
    module and threading internals; paths shortened to their last three
    components so sites are stable across checkouts.  Returns None for
    foreign (stdlib / site-packages) allocators — those locks stay
    untracked."""
    f = sys._getframe(2)
    while f is not None:
        fn = f.f_code.co_filename
        if not (fn.endswith("lockcheck.py") or fn.endswith("threading.py")
                or fn.endswith("_weakrefset.py")):
            if _foreign(fn):
                return None
            short = "/".join(fn.replace(os.sep, "/").split("/")[-3:])
            return f"{short}:{f.f_lineno}"
        f = f.f_back
    return None


def _record_acquire(site: str) -> None:
    st = _state
    if st is None:
        return
    tid = threading.get_ident()
    with st.mu:
        st.acquisitions += 1
        stack = st.held[tid]
        if stack and stack[-1] != site:
            st.edges[(stack[-1], site)] += 1
        stack.append(site)


def _record_release(site: str) -> None:
    st = _state
    if st is None:
        return
    tid = threading.get_ident()
    with st.mu:
        stack = st.held.get(tid)
        if stack is not None:
            for i in range(len(stack) - 1, -1, -1):
                if stack[i] == site:
                    del stack[i]
                    break
            if not stack:
                st.held.pop(tid, None)


class _TrackedLock:
    """Proxy over a real Lock/RLock recording acquisition order.  RLock
    re-entries are counted per thread and only the outermost
    acquire/release touch the graph."""

    def __init__(self, inner: Any, site: str, reentrant: bool):
        self._inner = inner
        self._site = site
        self._reentrant = reentrant
        self._depth: Dict[int, int] = {}    # tid -> re-entry depth

    # -- bookkeeping ---------------------------------------------------------

    def _enter(self) -> None:
        tid = threading.get_ident()
        if self._reentrant:
            d = self._depth.get(tid, 0)
            self._depth[tid] = d + 1
            if d:                           # re-entry: no new edge
                return
        _record_acquire(self._site)

    def _exit(self) -> None:
        tid = threading.get_ident()
        if self._reentrant:
            d = self._depth.get(tid, 1) - 1
            if d > 0:
                self._depth[tid] = d
                return
            self._depth.pop(tid, None)
        _record_release(self._site)

    # -- the lock protocol ---------------------------------------------------

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        ok = self._inner.acquire(blocking, timeout)
        if ok:
            self._enter()
        return ok

    def release(self) -> None:
        self._exit()
        self._inner.release()

    def locked(self) -> bool:
        return self._inner.locked()

    def __enter__(self):
        self.acquire()
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<tracked {self._inner!r} from {self._site}>"

    def __getattr__(self, name: str):
        # anything else of the lock protocol (_at_fork_reinit, ...) passes
        # straight through to the real lock, untracked
        return getattr(self._inner, name)

    # -- Condition integration (private CPython protocol) --------------------

    def _is_owned(self):
        if hasattr(self._inner, "_is_owned"):
            return self._inner._is_owned()
        if self._inner.acquire(False):
            self._inner.release()
            return False
        return True

    def _release_save(self):
        tid = threading.get_ident()
        depth = self._depth.pop(tid, 0) if self._reentrant else 0
        _record_release(self._site)
        if hasattr(self._inner, "_release_save"):
            return (self._inner._release_save(), depth)
        self._inner.release()
        return (None, depth)

    def _acquire_restore(self, state) -> None:
        saved, depth = state
        if hasattr(self._inner, "_acquire_restore"):
            self._inner._acquire_restore(saved)
        else:
            self._inner.acquire()
        _record_acquire(self._site)
        if self._reentrant and depth:
            self._depth[threading.get_ident()] = depth


def _tracked_lock_factory():
    site = _allocation_site()
    if site is None:
        return _REAL_LOCK()
    st = _state
    if st is not None:
        with st.mu:
            st.sites[site] = st.sites.get(site, 0) + 1
    return _TrackedLock(_REAL_LOCK(), site, reentrant=False)


def _tracked_rlock_factory():
    site = _allocation_site()
    if site is None:
        return _REAL_RLOCK()
    st = _state
    if st is not None:
        with st.mu:
            st.sites[site] = st.sites.get(site, 0) + 1
    return _TrackedLock(_REAL_RLOCK(), site, reentrant=True)


def install() -> None:
    """Patch the ``threading`` lock factories; locks created *after* this
    point are tracked (module-import-time locks are not, which is fine:
    the interesting locks are per-object)."""
    global _state, _installed
    if _installed:
        return
    _state = _State()
    threading.Lock = _tracked_lock_factory          # type: ignore
    threading.RLock = _tracked_rlock_factory        # type: ignore
    _installed = True


def uninstall() -> None:
    global _state, _installed
    threading.Lock = _REAL_LOCK                     # type: ignore
    threading.RLock = _REAL_RLOCK                   # type: ignore
    _installed = False
    _state = None


def enabled() -> bool:
    return _installed


def _snapshot_edges() -> Dict[Tuple[str, str], int]:
    st = _state
    if st is None:
        return {}
    with st.mu:
        return dict(st.edges)


def find_cycles() -> List[List[str]]:
    """Every elementary cycle-witness found by DFS over the current
    acquisition-order graph (one witness per back edge)."""
    edges = _snapshot_edges()
    graph: Dict[str, List[str]] = defaultdict(list)
    for a, b in edges:
        graph[a].append(b)
    WHITE, GREY, BLACK = 0, 1, 2
    color = {n: WHITE for n in
             set(graph) | {b for vs in graph.values() for b in vs}}
    parent: Dict[str, str] = {}
    cycles: List[List[str]] = []

    def dfs(n: str) -> None:
        color[n] = GREY
        for nxt in graph.get(n, ()):
            if color[nxt] == GREY:
                cyc = [nxt, n]
                cur = n
                while cur != nxt:
                    cur = parent[cur]
                    cyc.append(cur)
                cycles.append(list(reversed(cyc)))
            elif color[nxt] == WHITE:
                parent[nxt] = n
                dfs(nxt)
        color[n] = BLACK

    for n in sorted(color):
        if color[n] == WHITE:
            dfs(n)
    return cycles


def report() -> Dict[str, Any]:
    st = _state
    locks = 0
    acquisitions = 0
    if st is not None:
        with st.mu:
            locks = sum(st.sites.values())
            acquisitions = st.acquisitions
    edges = _snapshot_edges()
    return {"locks": locks, "sites": len(st.sites) if st else 0,
            "acquisitions": acquisitions,
            "edges": [{"from": a, "to": b, "count": c}
                      for (a, b), c in sorted(edges.items())],
            "cycles": find_cycles()}


def assert_acyclic() -> None:
    cycles = find_cycles()
    if cycles:
        lines = ["lock acquisition-order cycle(s) detected:"]
        for cyc in cycles:
            lines.append("  " + " -> ".join(cyc))
        lines.append("acquire these locks in one global order "
                     "(see repro.analysis rule C002)")
        raise LockOrderError("\n".join(lines))
