"""Synthetic data: clustering datasets (blobs / rings — the shapes spectral
clustering handles and k-means alone cannot), a paper-like sparse graph,
and deterministic LM token streams for the training examples."""
from __future__ import annotations

from typing import Iterator

import numpy as np


def blobs(n: int, k: int, dim: int = 2, spread: float = 0.15,
          seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """k well-separated Gaussian blobs. Returns (points (n,dim) f32, labels)."""
    rng = np.random.RandomState(seed)
    centers = rng.randn(k, dim) * 4.0
    labels = np.arange(n) % k
    pts = centers[labels] + rng.randn(n, dim) * spread
    return pts.astype(np.float32), labels


def rings(n: int, k: int = 2, noise: float = 0.03,
          seed: int = 0) -> tuple[np.ndarray, np.ndarray]:
    """Concentric rings: the classic non-convex case where spectral beats
    k-means (paper §3.1's 'arbitrary shape' claim)."""
    rng = np.random.RandomState(seed)
    labels = np.arange(n) % k
    radii = 1.0 + labels.astype(np.float64)
    theta = rng.uniform(0, 2 * np.pi, n)
    pts = np.stack([radii * np.cos(theta), radii * np.sin(theta)], 1)
    pts += rng.randn(n, 2) * noise
    return pts.astype(np.float32), labels


def synthetic_graph(n: int = 10_029, n_edges: int = 21_054, k: int = 8,
                    p_in: float = 0.9, seed: int = 0):
    """Planted-partition graph shaped like the paper's dataset (§5.1:
    10029 vertices / 21054 edges). Returns (edges (m,3) int [i,j,w], labels)."""
    rng = np.random.RandomState(seed)
    labels = rng.randint(0, k, n)
    edges = set()
    rows = []
    while len(rows) < n_edges:
        i = rng.randint(n)
        same = rng.rand() < p_in
        if same:
            cand = np.flatnonzero(labels == labels[i])
        else:
            cand = np.flatnonzero(labels != labels[i])
        j = int(cand[rng.randint(len(cand))])
        if i == j or (min(i, j), max(i, j)) in edges:
            continue
        edges.add((min(i, j), max(i, j)))
        rows.append((min(i, j), max(i, j), 1))
    return np.asarray(rows, np.int64), labels


def lm_batches(batch: int, seq: int, vocab: int, seed: int = 0,
               structured: bool = True) -> Iterator[dict]:
    """Deterministic synthetic token stream.  ``structured`` makes it
    learnable (next token = (token + fixed stride) % vocab with noise) so
    the examples' loss curves actually go down."""
    rng = np.random.RandomState(seed)
    stride = max(1, vocab // 7)
    while True:
        if structured:
            start = rng.randint(0, vocab, (batch, 1))
            steps = np.arange(seq)[None, :] * stride
            toks = (start + steps) % vocab
            noise = rng.rand(batch, seq) < 0.05
            toks = np.where(noise, rng.randint(0, vocab, (batch, seq)), toks)
        else:
            toks = rng.randint(0, vocab, (batch, seq))
        yield {"tokens": toks.astype(np.int32)}
