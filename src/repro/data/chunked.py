"""Chunked point readers for the out-of-core engine.

A reader is anything with ``reader[c] -> (rows, d) float32 chunk`` plus
``n`` / ``dim`` / ``chunk_size`` / ``ranges``; map tasks address chunks
randomly and repeatedly, so ``__getitem__`` must be pure (same chunk every
call).  Two implementations:

  ArrayChunks   view over an in-memory array — the oracle/agreement path,
                where engine and dense backends must see identical data.
  BlobChunks    deterministic per-chunk synthesis of the Gaussian-blobs
                dataset: chunk c is regenerated from a chunk-local seed on
                every access, so datasets far beyond RAM/device memory
                never exist as one array anywhere.
"""
from __future__ import annotations

import numpy as np


def chunk_ranges(n: int, chunk_size: int) -> list[tuple[int, int]]:
    """[(start, stop), ...] covering [0, n) in fixed-size chunks; the last
    chunk is ragged when ``chunk_size`` does not divide ``n``.  Lives in
    the (numpy-only) data layer so readers and the engine planner share it
    without ``import repro.data`` dragging in jax."""
    if n <= 0:
        raise ValueError(f"n must be positive, got {n}")
    c = max(1, min(int(chunk_size), n))
    return [(r0, min(r0 + c, n)) for r0 in range(0, n, c)]


class ArrayChunks:
    """Chunk view over an (n, d) in-memory array."""

    def __init__(self, x: np.ndarray, chunk_size: int):
        self.x = np.ascontiguousarray(np.asarray(x, np.float32))
        if self.x.ndim != 2:
            raise ValueError(f"expected (n, d) points, got {self.x.shape}")
        self.n, self.dim = self.x.shape
        self.chunk_size = chunk_size
        self.ranges = chunk_ranges(self.n, chunk_size)

    def __len__(self) -> int:
        return len(self.ranges)

    def __getitem__(self, c: int) -> np.ndarray:
        r0, r1 = self.ranges[c]
        return self.x[r0:r1]


class BlobChunks:
    """k Gaussian blobs synthesized chunk-by-chunk (never materialized).

    Matches the *distribution* of :func:`repro.data.synthetic.blobs` —
    cluster centers come from the same seeded draw; the per-point noise is
    chunk-local so any chunk is reproducible in isolation.  ``labels(c)``
    returns the planted labels of chunk ``c``; ``all_labels()`` the full
    (n,) vector (labels are 8-byte ints — always RAM-cheap next to the
    points).
    """

    def __init__(self, n: int, k: int, chunk_size: int, dim: int = 2,
                 spread: float = 0.15, seed: int = 0):
        self.n, self.k, self.dim = n, k, dim
        self.spread = spread
        self.seed = seed
        self.chunk_size = chunk_size
        self.ranges = chunk_ranges(n, chunk_size)
        self.centers = np.random.RandomState(seed).randn(k, dim) * 4.0

    def __len__(self) -> int:
        return len(self.ranges)

    def _rng(self, c: int) -> np.random.RandomState:
        return np.random.RandomState((self.seed * 1_000_003 + c + 1)
                                     % (2**31 - 1))

    def labels(self, c: int) -> np.ndarray:
        r0, r1 = self.ranges[c]
        return (np.arange(r0, r1) % self.k).astype(np.int64)

    def all_labels(self) -> np.ndarray:
        return (np.arange(self.n) % self.k).astype(np.int64)

    def __getitem__(self, c: int) -> np.ndarray:
        r0, r1 = self.ranges[c]
        noise = self._rng(c).randn(r1 - r0, self.dim) * self.spread
        return (self.centers[self.labels(c)] + noise).astype(np.float32)
