from repro.data.chunked import ArrayChunks, BlobChunks
from repro.data.graph_file import parse_topology, write_topology
from repro.data.synthetic import (blobs, lm_batches, rings,
                                  synthetic_graph)
