"""Parser/writer for the paper's §5.1 topology text format:

    t <graph-label>
    v <id> <label>
    e <src> <dst> <weight>

The parsed graph feeds ``SpectralClustering(affinity="precomputed")``
(adjacency-weight similarity) — the paper clusters graph vertices
directly."""
from __future__ import annotations

import numpy as np


def parse_topology(path: str) -> tuple[int, np.ndarray]:
    """Returns (num_vertices, edges (m, 3) int64 [src, dst, weight])."""
    n = 0
    edges = []
    with open(path) as f:
        for line in f:
            parts = line.split()
            if not parts:
                continue
            tag = parts[0]
            if tag == "v":
                n = max(n, int(parts[1]) + 1)
            elif tag == "e":
                i, j = int(parts[1]), int(parts[2])
                w = int(parts[3]) if len(parts) > 3 else 1
                edges.append((i, j, w))
                n = max(n, i + 1, j + 1)
    return n, np.asarray(edges, np.int64).reshape(-1, 3)


def write_topology(path: str, n: int, edges: np.ndarray, label: int = 0):
    with open(path, "w") as f:
        f.write(f"t # {label}\n")
        for i in range(n):
            f.write(f"v {i} 0\n")
        for i, j, w in edges:
            f.write(f"e {i} {j} {w}\n")


def adjacency_dense(n: int, edges: np.ndarray, dtype=np.float32) -> np.ndarray:
    A = np.zeros((n, n), dtype)
    A[edges[:, 0], edges[:, 1]] = edges[:, 2]
    A[edges[:, 1], edges[:, 0]] = edges[:, 2]
    np.fill_diagonal(A, 1.0)
    return A
