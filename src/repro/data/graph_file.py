"""Parser/writer for the paper's §5.1 topology text format:

    t <graph-label>
    v <id> <label>
    e <src> <dst> <weight>

The parsed graph feeds ``SpectralClustering(affinity="precomputed")``
(adjacency-weight similarity) — the paper clusters graph vertices
directly.

The parser streams the file in ~1 MiB line batches and converts each batch
to integers with one numpy tokenize/reshape instead of per-line Python
tuple appends, so multi-GB edge lists parse without a Python-object blowup;
:func:`iter_topology_edges` exposes the same batches as a generator for
consumers (the out-of-core engine) that never want the whole edge array.
"""
from __future__ import annotations

from typing import Iterator, Optional

import numpy as np

_READ_HINT = 1 << 20  # ~1 MiB of lines per batch


def _parse_tagged_batch(lines: list[str], width: int,
                        default_last: int) -> np.ndarray:
    """Tokenize same-tag lines ('e i j w' / 'v i l') in one numpy pass.

    ``width`` counts the integer fields; the last one defaults to
    ``default_last`` when omitted.  Falls back to a row loop only for
    batches that mix both arities (rare; the fast reshape handles the
    uniform case).
    """
    if not lines:
        return np.empty((0, width), np.int64)
    toks = np.array("".join(lines).split())
    nrows = len(lines)
    if toks.size == nrows * (width + 1):          # tag + all fields
        return toks.reshape(nrows, width + 1)[:, 1:].astype(np.int64)
    if toks.size == nrows * width:                # tag + fields-but-last
        out = np.empty((nrows, width), np.int64)
        out[:, :-1] = toks.reshape(nrows, width)[:, 1:].astype(np.int64)
        out[:, -1] = default_last
        return out
    rows = []                                     # mixed arities
    for ln in lines:
        parts = ln.split()
        vals = [int(p) for p in parts[1:width + 1]]
        if len(vals) < width - 1:                 # only the last field may
            raise ValueError(                     # be omitted
                f"malformed topology line {ln.strip()!r}: expected "
                f"{width} or {width - 1} fields after the tag")
        vals += [default_last] * (width - len(vals))
        rows.append(vals)
    return np.asarray(rows, np.int64).reshape(-1, width)


def _tag(line: str) -> str:
    """First whitespace-separated token ('' for blank lines) — tags must
    match exactly, so ' v 1 0' still parses and 'edge ...' stays ignored."""
    parts = line.split(None, 1)
    return parts[0] if parts else ""


def _batched_lines(path: str) -> Iterator[tuple[np.ndarray, np.ndarray]]:
    """Yields (vertex (b, 2) [id, label], edge (b, 3) [src, dst, w]) batches."""
    with open(path) as f:
        while True:
            lines = f.readlines(_READ_HINT)
            if not lines:
                return
            v_lines = [ln for ln in lines if _tag(ln) == "v"]
            e_lines = [ln for ln in lines if _tag(ln) == "e"]
            yield (_parse_tagged_batch(v_lines, 2, 0),
                   _parse_tagged_batch(e_lines, 3, 1))


def iter_topology_edges(path: str) -> Iterator[np.ndarray]:
    """Stream (b, 3) int64 [src, dst, weight] edge batches (for consumers
    that never materialize the full edge list)."""
    for _verts, edges in _batched_lines(path):
        if len(edges):
            yield edges


def parse_topology(path: str, with_labels: bool = False):
    """Returns (num_vertices, edges (m, 3) int64 [src, dst, weight]) — and,
    with ``with_labels=True``, a third (num_vertices,) int64 vertex-label
    array (0 for vertices the file never declares)."""
    n = 0
    edge_batches = []
    vert_batches = []
    for verts, edges in _batched_lines(path):
        if len(verts):
            n = max(n, int(verts[:, 0].max()) + 1)
            if with_labels:
                vert_batches.append(verts)
        if len(edges):
            n = max(n, int(edges[:, :2].max()) + 1)
            edge_batches.append(edges)
    all_edges = (np.concatenate(edge_batches) if edge_batches
                 else np.empty((0, 3), np.int64))
    if not with_labels:
        return n, all_edges
    labels = np.zeros(n, np.int64)
    for verts in vert_batches:
        labels[verts[:, 0]] = verts[:, 1]
    return n, all_edges, labels


def write_topology(path: str, n: int, edges: np.ndarray, label: int = 0,
                   vertex_labels: Optional[np.ndarray] = None):
    """Inverse of :func:`parse_topology`: vertex labels round-trip (the old
    writer hardcoded ``v {i} 0``, losing them)."""
    if vertex_labels is None:
        vertex_labels = np.zeros(n, np.int64)
    vertex_labels = np.asarray(vertex_labels, np.int64)
    if vertex_labels.shape != (n,):
        raise ValueError(
            f"vertex_labels must be ({n},), got {vertex_labels.shape}")
    with open(path, "w") as f:
        f.write(f"t # {label}\n")
        for i in range(n):
            f.write(f"v {i} {vertex_labels[i]}\n")
        for i, j, w in np.asarray(edges).reshape(-1, 3):
            f.write(f"e {i} {j} {w}\n")


def adjacency_dense(n: int, edges: np.ndarray, dtype=np.float32) -> np.ndarray:
    A = np.zeros((n, n), dtype)
    A[edges[:, 0], edges[:, 1]] = edges[:, 2]
    A[edges[:, 1], edges[:, 0]] = edges[:, 2]
    np.fill_diagonal(A, 1.0)
    return A
