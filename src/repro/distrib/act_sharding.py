"""Activation-sharding constraints (sequence parallelism).

GSPMD propagates parameter shardings well, but with fully replicated
weights (the ``sp_serve`` preset) nothing anchors the activations — it
happily replicates the whole sequence on every device (16x the flops).
The launcher installs the concrete mesh here; model code then pins the
layer-boundary activations to (batch -> data axes, seq -> "model").
"""
from __future__ import annotations

import contextlib
import contextvars

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

_MESH: contextvars.ContextVar[Mesh | None] = contextvars.ContextVar(
    "act_sharding_mesh", default=None)


@contextlib.contextmanager
def use_mesh(mesh: Mesh):
    tok = _MESH.set(mesh)
    try:
        yield
    finally:
        _MESH.reset(tok)


def current_mesh() -> Mesh | None:
    return _MESH.get()


def replicate_seq(x: jax.Array, cfg) -> jax.Array:
    """Force (B, T, ...) to be replicated along T (batch may stay on data):
    one all-gather, after which chunk-scans along T are free."""
    mesh = _MESH.get()
    if mesh is None or getattr(cfg, "sharding_preset", "") != "sp_serve":
        return x
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in ba:
        bsize *= mesh.shape[a]
    entries = [ba if (ba and x.shape[0] % bsize == 0 and x.shape[0] > 1) else None]
    entries += [None] * (x.ndim - 1)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def constrain_layer_params(lp, layer_specs, cfg):
    """Pin per-layer param slices (inside a scan body) to their rule-derived
    shardings.  with_sharding_constraint is its own transpose, so the
    *cotangents* — the backward scan's gradient accumulators, which GSPMD
    otherwise replicates at full f32 size — inherit the same sharding."""
    mesh = _MESH.get()
    if mesh is None:
        return lp
    from repro.distrib import sharding as shd
    from repro.models import params as pp
    rules = shd.rules_for(cfg)

    def one(x, spec):
        if not pp.is_spec(spec) or x.ndim != len(spec.shape):
            return x
        pspec = pp.partition_spec(spec, rules, mesh)
        return jax.lax.with_sharding_constraint(x, NamedSharding(mesh, pspec))

    return jax.tree.map(one, lp, layer_specs, is_leaf=pp.is_spec)


def constrain_dims(x: jax.Array, dim_axes: dict) -> jax.Array:
    """Pin several dims of x to mesh axes (each entry dropped if the mesh
    lacks the axis or the dim isn't divisible). No-op without a mesh."""
    mesh = _MESH.get()
    if mesh is None:
        return x
    entries = [None] * x.ndim
    for dim, axes in dim_axes.items():
        if axes is None:
            continue
        ax_tuple = axes if isinstance(axes, tuple) else (axes,)
        if any(a not in mesh.axis_names for a in ax_tuple):
            continue
        size = 1
        for a in ax_tuple:
            size *= mesh.shape[a]
        if x.shape[dim] % size == 0 and size > 1:
            entries[dim] = axes
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))


def batch_axes_in_mesh() -> tuple[str, ...]:
    mesh = _MESH.get()
    if mesh is None:
        return ()
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def constrain_seq(x: jax.Array, cfg) -> jax.Array:
    """Pin (B, S, ...) activations to batch->data, seq->model (sp preset)."""
    mesh = _MESH.get()
    if mesh is None or getattr(cfg, "sharding_preset", "") != "sp_serve":
        return x
    if "model" not in mesh.axis_names or x.ndim < 2:
        return x
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)
    bsize = 1
    for a in ba:
        bsize *= mesh.shape[a]
    entries = [ba if (ba and x.shape[0] % bsize == 0 and x.shape[0] > 1) else None]
    entries.append("model" if x.shape[1] % mesh.shape["model"] == 0 else None)
    entries += [None] * (x.ndim - 2)
    return jax.lax.with_sharding_constraint(
        x, NamedSharding(mesh, P(*entries)))
