"""Logical-axis -> mesh-axis sharding rules for the LM stack (DP/TP/EP/SP),
plus input/cache/optimizer sharding builders used by the launcher."""
from __future__ import annotations

from typing import Mapping

import jax
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models import params as pp
from repro.models.config import ModelConfig

# default logical->mesh rules; per-arch overrides come from
# ModelConfig.sharding_overrides (e.g. gemma3 shards head_dim, not heads).
DEFAULT_RULES: dict[str, str | None] = {
    "vocab": "model",
    "embed": None,
    "heads": "model",
    "kv_heads": "model",
    "head_dim": None,
    "mlp": "model",
    "experts": "model",   # expert parallelism
    "layers": None,
    "seq": "data",        # KV-cache sequence axis (context parallelism)
}


PRESETS: dict[str, dict[str, str | None]] = {
    "": {},
    # attention weights replicated (for head counts that don't divide the
    # model axis — avoids contracting-dim psums of S x S score tiles)
    "replicate_attn": {"heads": None, "kv_heads": None, "head_dim": None},
    # sequence parallelism for serving: weights replicated (embed/vocab
    # stay sharded), activations shard the sequence over "model" (the
    # launcher shards token inputs and KV-cache seq accordingly)
    "sp_serve": {"heads": None, "kv_heads": None, "head_dim": None,
                 "mlp": None, "experts": None, "seq": "model"},
    # tensor parallelism INSIDE each expert (for expert counts that don't
    # divide the model axis, e.g. mixtral 8e on 16-way: E replicated would
    # replicate expert FLOPs; sharding the expert hidden dim instead keeps
    # the matmuls distributed)
    "expert_tp": {"experts": None},
}


def rules_for(cfg: ModelConfig) -> dict[str, str | None]:
    rules = dict(DEFAULT_RULES)
    if cfg.sharding_overrides:
        rules.update(cfg.sharding_overrides)
    # presets are explicit perf variants: they take precedence over the
    # arch's default overrides
    rules.update(PRESETS[cfg.sharding_preset])
    return rules


def seq_axis_for_inputs(cfg: ModelConfig) -> str | None:
    """Mesh axis the token sequence dim shards over (SP presets only)."""
    return "model" if cfg.sharding_preset == "sp_serve" else None


def batch_axes(mesh: Mesh) -> tuple[str, ...]:
    """Axes the global batch shards over: ("pod","data") when pods exist."""
    return tuple(a for a in ("pod", "data") if a in mesh.axis_names)


def _with_data_axis(spec_tree, mesh: Mesh, rules):
    """Augment a sharding tree: shard the first data-divisible unsharded
    dim over "data" (ZeRO/FSDP). Skips tiny tensors (norm scales etc.)."""
    dsize = mesh.shape.get("data", 1)

    def one(s: pp.Spec) -> NamedSharding:
        entries = list(pp.partition_spec(s, rules, mesh))
        entries += [None] * (len(s.shape) - len(entries))
        used = {a for e in entries if e
                for a in (e if isinstance(e, tuple) else (e,))}
        if "data" not in used:
            for i, (dim, e) in enumerate(zip(s.shape, entries)):
                if e is None and dim % dsize == 0 and dim >= dsize:
                    entries[i] = "data"
                    break
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, spec_tree, is_leaf=pp.is_spec)


def param_shardings(cfg: ModelConfig, spec_tree, mesh: Mesh):
    rules = rules_for(cfg)
    if cfg.fsdp_params:
        return _with_data_axis(spec_tree, mesh, rules)
    return pp.sharding_tree(spec_tree, mesh, rules)


def opt_shardings(cfg: ModelConfig, spec_tree, mesh: Mesh):
    """Optimizer-state sharding: like params; with ZeRO-1/FSDP, moments
    additionally shard their largest unsharded dim over the data axis."""
    rules = rules_for(cfg)
    if not (cfg.shard_opt_over_data or cfg.fsdp_params):
        return pp.sharding_tree(spec_tree, mesh, rules)
    return _with_data_axis(spec_tree, mesh, rules)


def input_shardings(mesh: Mesh, batch_specs: Mapping[str, jax.ShapeDtypeStruct],
                    seq_axis: str | None = None):
    """Token/embedding batches shard dim0 over ("pod","data"); a batch of 1
    (long-context decode) falls back to replication (its KV cache carries
    the sequence sharding instead).  ``seq_axis`` additionally shards dim 1
    (the sequence) — sequence parallelism."""
    ba = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in ba]))

    def one(s: jax.ShapeDtypeStruct) -> NamedSharding:
        entries = []
        if s.ndim >= 1 and s.shape[0] % bsize == 0:
            entries.append(ba)
        elif s.ndim >= 1:
            entries.append(None)
        if s.ndim >= 2:
            if seq_axis and s.shape[1] % mesh.shape[seq_axis] == 0:
                entries.append(seq_axis)
            else:
                entries.append(None)
        entries += [None] * (s.ndim - len(entries))
        return NamedSharding(mesh, P(*entries))

    return {k: one(v) for k, v in batch_specs.items()}


def cache_shardings(cfg: ModelConfig, cache_spec_tree, mesh: Mesh):
    """KV caches: the batch dim shards over ("pod","data") when divisible;
    otherwise (batch 1, long-context decode) the *sequence* axis takes the
    data axes instead — context/sequence parallelism.  kv_heads/head_dim/
    mlp follow the model rules.  Each mesh axis is used at most once."""
    full_rules = rules_for(cfg)
    rules = dict(full_rules)
    rules["seq"] = None                     # assigned manually below
    ba = batch_axes(mesh)
    bsize = int(np.prod([mesh.shape[a] for a in ba]))
    seq_rule = full_rules.get("seq")

    def one(s: pp.Spec) -> NamedSharding:
        entries = list(pp.partition_spec(s, rules, mesh))
        entries += [None] * (len(s.shape) - len(entries))
        used = {a for e in entries if e
                for a in (e if isinstance(e, tuple) else (e,))}
        # find the batch dim: first axes==None dim (after any "layers" dims)
        batch_dim = next((i for i, (ax, e) in enumerate(zip(s.axes, entries))
                          if ax is None and e is None), None)
        if batch_dim is not None and s.shape[batch_dim] % bsize == 0 \
                and s.shape[batch_dim] > 1:
            entries[batch_dim] = ba
            used.update(ba)
        else:
            # batch too small: give the data axes to the sequence dim (SP)
            for i, (ax, dim) in enumerate(zip(s.axes, s.shape)):
                if ax == "seq" and dim % bsize == 0:
                    entries[i] = ba
                    used.update(ba)
                    break
        # an explicit seq rule (sp_serve) shards seq over its axis too
        if seq_rule and seq_rule not in used:
            for i, (ax, dim, e) in enumerate(zip(s.axes, s.shape, entries)):
                if ax == "seq" and e is None and dim % mesh.shape[seq_rule] == 0:
                    entries[i] = seq_rule
                    break
        return NamedSharding(mesh, P(*entries))

    return jax.tree.map(one, cache_spec_tree, is_leaf=pp.is_spec)
