"""Roofline cost extraction from compiled HLO text.

``compiled.cost_analysis()`` counts while-loop bodies ONCE (scan bodies are
not multiplied by trip count), which under-reports every scanned-layer
model by ~num_layers and chunked attention by ~num_chunks.  This module
re-derives flops / HBM bytes / collective bytes from the compiled module
text itself:

  * computations are parsed with a per-instruction symbol table (operand
    shapes resolve by name);
  * ``while`` ops carry ``backend_config={"known_trip_count":{"n":...}}`` —
    body and condition costs are multiplied by the trip count, nested loops
    multiply through;
  * fusion computations (referenced via ``calls=``) roll up into their
    fusion op: one op's worth of HBM traffic (operands + result), which is
    exactly the fusion semantics;
  * dot flops = 2 * numel(result) * prod(contracting dims of lhs).

This is the per-device (SPMD-partitioned) cost: the dry-run compiles the
partitioned module, so terms divide by per-chip peaks directly.
"""
from __future__ import annotations

import re
from dataclasses import dataclass, field

_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "f16": 2, "bf16": 2, "f8e4m3fn": 1, "f8e4m3": 1,
    "f8e5m2": 1, "s64": 8, "u64": 8, "s32": 4, "u32": 4, "s16": 2,
    "u16": 2, "s8": 1, "u8": 1, "s4": 1, "u4": 1, "pred": 1, "token": 0,
}

_ARRAY_RE = re.compile(
    r"\b(f64|f32|f16|bf16|f8e4m3fn|f8e4m3|f8e5m2|s64|u64|s32|u32|s16|u16|s8|u8|s4|u4|pred)\[([0-9,]*)\]")
_COMP_HDR = re.compile(r"^(ENTRY\s+)?%?([\w.\-]+)\s*\(.*\)\s*->")
_INSTR = re.compile(r"^\s*(?:ROOT\s+)?%([\w.\-]+)\s*=\s*(.+)$")
_OP_RE = re.compile(r"^((?:\([^)]*\)|[\w\[\],{}\s]*?))\s*([\w\-]+)\(")
_TRIP_RE = re.compile(r'"known_trip_count":\{"n":"(\d+)"\}')
_CALLS_RE = re.compile(r"calls=%?([\w.\-]+)")
_BODY_RE = re.compile(r"body=%?([\w.\-]+)")
_COND_RE = re.compile(r"condition=%?([\w.\-]+)")
_BRANCH_RE = re.compile(r"branch_computations=\{([^}]*)\}")
_TC_RE = re.compile(r"(?:true|false)_computation=%?([\w.\-]+)")
_CONTRACT_RE = re.compile(r"lhs_contracting_dims=\{([0-9,]*)\}")
_OPERANDS_RE = re.compile(r"%([\w.\-]+)")

COLLECTIVES = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
               "collective-permute")

_TRANSCENDENTAL = {"exponential", "tanh", "log", "rsqrt", "sqrt", "power",
                   "logistic", "sine", "cosine", "exponential-minus-one",
                   "log-plus-one", "erf", "cbrt", "atan2", "divide"}

_FREE_OPS = {"parameter", "constant", "tuple", "get-tuple-element",
             "after-all", "bitcast", "partition-id", "replica-id",
             "add-dependency", "opt-barrier", "custom-call"}


def _type_bytes(seg: str) -> int:
    total = 0
    for dt, dims in _ARRAY_RE.findall(seg):
        numel = 1
        if dims:
            for d in dims.split(","):
                numel *= int(d)
        total += numel * _DTYPE_BYTES[dt]
    return total


def _result_dims(seg: str) -> list[list[int]]:
    out = []
    for _, dims in _ARRAY_RE.findall(seg):
        out.append([int(d) for d in dims.split(",")] if dims else [])
    return out


@dataclass
class Instr:
    name: str
    opcode: str
    type_seg: str          # result type segment
    rest: str              # full rhs after type
    operands: list[str] = field(default_factory=list)


@dataclass
class Computation:
    name: str
    instrs: list[Instr] = field(default_factory=list)
    types: dict = field(default_factory=dict)   # instr name -> type seg


def parse_module(text: str) -> dict[str, Computation]:
    comps: dict[str, Computation] = {}
    cur: Computation | None = None
    entry_name = None
    for raw in text.splitlines():
        line = raw.rstrip()
        if not line:
            continue
        if not line.startswith(" ") and ("->" in line) and ("= " not in line.split("->")[0]):
            m = _COMP_HDR.match(line.strip())
            if m:
                cur = Computation(name=m.group(2))
                comps[cur.name] = cur
                if m.group(1):
                    entry_name = cur.name
                continue
        if line.strip() == "}":
            continue
        if cur is None:
            continue
        mi = _INSTR.match(line)
        if not mi:
            continue
        name, rhs = mi.group(1), mi.group(2)
        mo = _OP_RE.match(rhs)
        if mo:
            type_seg, opcode = mo.group(1), mo.group(2)
        else:
            # ops without parens (rare)
            parts = rhs.split()
            type_seg, opcode = parts[0], parts[1] if len(parts) > 1 else ""
        # operand names: inside the first (...) after opcode
        paren = rhs.find(opcode + "(")
        ops = []
        if paren >= 0:
            depth = 0
            start = paren + len(opcode)
            for i in range(start, len(rhs)):
                if rhs[i] == "(":
                    depth += 1
                elif rhs[i] == ")":
                    depth -= 1
                    if depth == 0:
                        ops = _OPERANDS_RE.findall(rhs[start:i + 1])
                        break
        cur.types[name] = type_seg
        cur.instrs.append(Instr(name=name, opcode=opcode, type_seg=type_seg,
                                rest=rhs, operands=ops))
    if entry_name:
        comps["__entry__"] = comps[entry_name]
    return comps


def _fusion_traffic(ins: Instr, comp: Computation, fus: Computation | None,
                    rb: int, ob: int) -> int:
    """HBM bytes for a fusion op, walking the fused computation: a fusion
    parameter whose only consumers are dynamic-slice/gather ops is read
    only at slice granularity; a dynamic-update-slice root writes only the
    update (the buffer is aliased in place)."""
    if fus is None or not fus.instrs:
        return rb + ob
    # parameter index -> instr name, in declaration order
    params = [fi for fi in fus.instrs if fi.opcode == "parameter"]
    params.sort(key=lambda fi: int(re.search(r"parameter\((\d+)\)", fi.rest).group(1))
                if re.search(r"parameter\((\d+)\)", fi.rest) else 0)
    pname_to_opidx = {fi.name: i for i, fi in enumerate(params)}
    # consumers of each fused parameter
    slice_bytes: dict[int, int] = {}
    full_needed: set[int] = set()
    for fi in fus.instrs:
        for o in fi.operands:
            if o in pname_to_opidx:
                idx = pname_to_opidx[o]
                if fi.opcode in ("dynamic-slice", "gather") and fi.operands \
                        and fi.operands[0] == o:
                    slice_bytes[idx] = slice_bytes.get(idx, 0) + _type_bytes(fi.type_seg)
                else:
                    full_needed.add(idx)
    read = 0
    root = fus.instrs[-1]
    dus_buffer_idx = None
    if root.opcode == "dynamic-update-slice" and root.operands \
            and root.operands[0] in pname_to_opidx:
        dus_buffer_idx = pname_to_opidx[root.operands[0]]
    for i, o in enumerate(ins.operands):
        if i >= len(params):
            break
        if i == dus_buffer_idx and i not in full_needed:
            continue  # aliased in-place buffer: not re-read
        if i in full_needed or i not in slice_bytes:
            read += _type_bytes(comp.types.get(o, ""))
        else:
            read += slice_bytes[i]
    if root.opcode == "dynamic-update-slice":
        upd = root.operands[1] if len(root.operands) > 1 else None
        write = _type_bytes(fus.types.get(upd, "")) if upd else rb
    else:
        write = rb
    return read + write


def _dot_flops(ins: Instr, comp: Computation) -> float:
    dims_list = _result_dims(ins.type_seg)
    numel = 1
    for d in (dims_list[0] if dims_list else []):
        numel *= d
    k = 1
    mc = _CONTRACT_RE.search(ins.rest)
    if mc and ins.operands:
        lhs_seg = comp.types.get(ins.operands[0], "")
        lhs_dims = _result_dims(lhs_seg)
        if lhs_dims:
            for ci in (int(c) for c in mc.group(1).split(",") if c):
                if ci < len(lhs_dims[0]):
                    k *= lhs_dims[0][ci]
    return 2.0 * numel * k


def analyze(text: str) -> dict:
    comps = parse_module(text)
    entry = comps.get("__entry__")
    if entry is None:
        return {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0,
                "collective_bytes": {}, "collective_total": 0}

    fusion_comps = set()
    for comp in comps.values():
        for ins in comp.instrs:
            for m in _CALLS_RE.finditer(ins.rest):
                fusion_comps.add(m.group(1))

    totals = {"flops": 0.0, "bytes": 0.0, "transcendentals": 0.0}
    coll = {k: 0.0 for k in COLLECTIVES}
    coll_counts = {k: 0.0 for k in COLLECTIVES}
    visited_stack = []

    def comp_cost(cname: str, mult: float):
        comp = comps.get(cname)
        if comp is None or cname in visited_stack:
            return
        visited_stack.append(cname)
        for ins in comp.instrs:
            op = ins.opcode
            if op in _FREE_OPS and not op.startswith("all-"):
                # custom-call etc: count result bytes only
                if op == "custom-call":
                    totals["bytes"] += _type_bytes(ins.type_seg) * mult
                continue
            if op == "while":
                mt = _TRIP_RE.search(ins.rest)
                trips = int(mt.group(1)) if mt else 1
                mb = _BODY_RE.search(ins.rest)
                mc2 = _COND_RE.search(ins.rest)
                if mb:
                    comp_cost(mb.group(1), mult * trips)
                if mc2:
                    comp_cost(mc2.group(1), mult * (trips + 1))
                continue
            if op == "conditional":
                mbr = _BRANCH_RE.search(ins.rest)
                branches = ([b.strip().lstrip("%") for b in mbr.group(1).split(",")]
                            if mbr else [m.group(1) for m in _TC_RE.finditer(ins.rest)])
                for b in branches:
                    comp_cost(b, mult)   # upper bound: all branches
                continue
            if op in ("call", "async-start"):
                for m in _CALLS_RE.finditer(ins.rest):
                    comp_cost(m.group(1), mult)
                continue
            # HBM traffic: operands + result (fusion == one roll-up op).
            # Sliced/in-place ops count only touched bytes (XLA
            # HloCostAnalysis semantics): DUS writes the update slice into
            # an aliased buffer; DS/gather read only the slice.
            rb = _type_bytes(ins.type_seg)
            ob = sum(_type_bytes(comp.types.get(o, "")) for o in ins.operands)
            if op == "dynamic-update-slice":
                upd = (_type_bytes(comp.types.get(ins.operands[1], ""))
                       if len(ins.operands) > 1 else rb)
                traffic = 2 * upd
            elif op in ("dynamic-slice", "gather"):
                traffic = 2 * rb
            elif op == "fusion":
                m = _CALLS_RE.search(ins.rest)
                fus = comps.get(m.group(1)) if m else None
                traffic = _fusion_traffic(ins, comp, fus, rb, ob)
            else:
                traffic = rb + ob
            totals["bytes"] += traffic * mult
            base = op.replace("-start", "")
            if base in COLLECTIVES:
                if op.endswith("-done"):
                    continue
                coll[base] += rb * mult
                coll_counts[base] += mult
                continue
            if op == "dot":
                totals["flops"] += _dot_flops(ins, comp) * mult
            elif op == "convolution":
                totals["flops"] += 2.0 * _type_bytes(ins.type_seg) * mult  # loose
            elif op == "fusion":
                fus = None
                m = _CALLS_RE.search(ins.rest)
                if m:
                    fus = comps.get(m.group(1))
                if fus:
                    for fi in fus.instrs:
                        if fi.opcode == "dot":
                            totals["flops"] += _dot_flops(fi, fus) * mult
                        elif fi.opcode in _TRANSCENDENTAL:
                            tb = _result_dims(fi.type_seg)
                            n = 1
                            for d in (tb[0] if tb else []):
                                n *= d
                            totals["transcendentals"] += n * mult
            elif op in _TRANSCENDENTAL:
                tb = _result_dims(ins.type_seg)
                n = 1
                for d in (tb[0] if tb else []):
                    n *= d
                totals["transcendentals"] += n * mult
        visited_stack.pop()

    comp_cost(entry.name, 1.0)
    return {
        "flops": totals["flops"],
        "bytes": totals["bytes"],
        "transcendentals": totals["transcendentals"],
        "collective_bytes": {k: int(v) for k, v in coll.items()},
        "collective_counts": {k: int(v) for k, v in coll_counts.items()},
        "collective_total": int(sum(coll.values())),
    }
