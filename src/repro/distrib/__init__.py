from repro.distrib.mesh_utils import (
    flat_axes,
    local_mesh,
    make_mesh,
    mesh_size,
    pad_to_multiple,
    replicated,
    row_sharding,
)
