"""Mesh / sharding helpers shared by the spectral-clustering core and the LM stack.

The paper row-shards its matrices over HBase region servers; here the analogue
is a NamedSharding over one or more mesh axes.  All helpers are functions (no
module-level jax device access) so importing never touches device state.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

try:  # jax >= 0.5 has explicit axis types; 0.4.x predates them.
    from jax.sharding import AxisType
    HAS_AXIS_TYPES = True
except ImportError:  # pragma: no cover - exercised only on old jax
    class AxisType:  # minimal stand-in so `AxisType.Auto` stays importable
        Auto = "auto"

    HAS_AXIS_TYPES = False

try:
    _shard_map_impl = jax.shard_map
    _SHARD_MAP_KW: dict = {}
except AttributeError:  # jax 0.4.x: experimental namespace, check_rep kwarg
    from jax.experimental.shard_map import shard_map as _shard_map_impl
    _SHARD_MAP_KW = {"check_rep": False}


def shard_map(f, *, mesh: Mesh, in_specs, out_specs, check_vma: bool | None = None):
    """``jax.shard_map`` across jax versions (0.4 experimental -> 0.9 public).

    ``check_vma`` maps to 0.4's ``check_rep``; ``None`` means the caller's
    default (which on 0.4 must be off — its replication checker predates the
    varying-marker semantics the kernels rely on)."""
    kwargs = dict(_SHARD_MAP_KW)
    if check_vma is not None:
        if "check_rep" in kwargs:
            kwargs["check_rep"] = check_vma
        else:
            kwargs["check_vma"] = check_vma
    return _shard_map_impl(f, mesh=mesh, in_specs=in_specs,
                           out_specs=out_specs, **kwargs)


def pvary(x, axes):
    """``lax.pvary`` where it exists (varying-marker for shard_map carries);
    a no-op on jax versions without per-axis varying tracking."""
    fn = getattr(jax.lax, "pvary", None)
    return fn(x, axes) if fn is not None else x


def make_mesh(shape: Sequence[int], axis_names: Sequence[str], devices=None) -> Mesh:
    """jax.make_mesh pinned to Auto axis types (stable across jax 0.4/0.8/0.9)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    if HAS_AXIS_TYPES:
        kwargs["axis_types"] = (AxisType.Auto,) * len(axis_names)
    return jax.make_mesh(tuple(shape), tuple(axis_names), **kwargs)


def local_mesh(axis_name: str = "rows", n_devices: int | None = None) -> Mesh:
    """A 1-D mesh over all (or the first ``n_devices``) local devices."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return make_mesh((len(devs),), (axis_name,), devices=devs)


def mesh_size(mesh: Mesh, axes: Sequence[str] | None = None) -> int:
    if axes is None:
        return math.prod(mesh.shape.values())
    return math.prod(mesh.shape[a] for a in axes)


def flat_axes(mesh: Mesh) -> tuple[str, ...]:
    """All axis names of a mesh, for sharding over the flattened device set."""
    return tuple(mesh.axis_names)


def row_sharding(mesh: Mesh, ndim: int = 2, axes: Sequence[str] | None = None) -> NamedSharding:
    """Shard dim 0 over ``axes`` (default: every mesh axis), replicate the rest."""
    axes = tuple(axes) if axes is not None else flat_axes(mesh)
    spec = P(axes, *([None] * (ndim - 1)))
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def pad_to_multiple(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n``."""
    return ((n + m - 1) // m) * m
