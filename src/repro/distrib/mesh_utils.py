"""Mesh / sharding helpers shared by the spectral-clustering core and the LM stack.

The paper row-shards its matrices over HBase region servers; here the analogue
is a NamedSharding over one or more mesh axes.  All helpers are functions (no
module-level jax device access) so importing never touches device state.
"""
from __future__ import annotations

import math
from typing import Sequence

import jax
from jax.sharding import AxisType, Mesh, NamedSharding, PartitionSpec as P


def make_mesh(shape: Sequence[int], axis_names: Sequence[str], devices=None) -> Mesh:
    """jax.make_mesh pinned to Auto axis types (stable across jax 0.8/0.9)."""
    kwargs = {}
    if devices is not None:
        kwargs["devices"] = devices
    return jax.make_mesh(
        tuple(shape),
        tuple(axis_names),
        axis_types=(AxisType.Auto,) * len(axis_names),
        **kwargs,
    )


def local_mesh(axis_name: str = "rows", n_devices: int | None = None) -> Mesh:
    """A 1-D mesh over all (or the first ``n_devices``) local devices."""
    devs = jax.devices()
    if n_devices is not None:
        devs = devs[:n_devices]
    return make_mesh((len(devs),), (axis_name,), devices=devs)


def mesh_size(mesh: Mesh, axes: Sequence[str] | None = None) -> int:
    if axes is None:
        return math.prod(mesh.shape.values())
    return math.prod(mesh.shape[a] for a in axes)


def flat_axes(mesh: Mesh) -> tuple[str, ...]:
    """All axis names of a mesh, for sharding over the flattened device set."""
    return tuple(mesh.axis_names)


def row_sharding(mesh: Mesh, ndim: int = 2, axes: Sequence[str] | None = None) -> NamedSharding:
    """Shard dim 0 over ``axes`` (default: every mesh axis), replicate the rest."""
    axes = tuple(axes) if axes is not None else flat_axes(mesh)
    spec = P(axes, *([None] * (ndim - 1)))
    return NamedSharding(mesh, spec)


def replicated(mesh: Mesh) -> NamedSharding:
    return NamedSharding(mesh, P())


def pad_to_multiple(n: int, m: int) -> int:
    """Smallest multiple of ``m`` that is >= ``n``."""
    return ((n + m - 1) // m) * m
