"""Affinity backends: phase 1 of the pipeline as pluggable strategies.

Every backend has the signature

    backend(est, x, sigma, mesh) -> NormalizedOperator

where ``est`` is the :class:`~repro.cluster.SpectralClustering` estimator
(carrying k, sparsify_t, dtype, ...), ``x`` is (n, d) points — or, for
``precomputed``, the (n, n) similarity matrix itself — and ``sigma`` the RBF
bandwidth (ignored by ``precomputed``).

Backends:
  dense       full row-block similarity (beyond-paper "full" mode): every
              device computes its whole row block; 2x pair-FLOPs, zero
              mirror communication.
  triangular  the paper's balanced upper-triangle block schedule (Alg. 4.2),
              wide row-block storage.
  compact     same schedule, compact per-device tile stacks (perf S1).
  precomputed caller supplies S directly (paper §5 topology graphs).
  knn-topt    dense similarity then top-t row sparsification lifted into the
              distributed path (paper step 1 "and then sparse it"), keeping
              the graph symmetric via max(S, S^T).
  ooc-topt    the same top-t graph built out-of-core by the repro.engine
              map/shuffle/reduce pipeline: chunked Pallas tiles -> spillable
              CSR shards -> shard-streaming matmat (each shard loaded once
              per block); n is bounded by disk, not device memory.
  fused-rbf   matrix-free: a flash-style Pallas kernel recomputes RBF tiles
              in-register on every matmat and applies the D^{-1/2}
              normalization in place, so the similarity matrix NEVER
              exists — affinity memory is O(n*d), and a mixed-precision
              knob (est.compute_dtype) runs the tile products in bf16
              with f32 accumulation.

Every backend returns a NormalizedOperator with a NATIVE matmat — one
pass over its similarity storage per (n_pad, b) block — and lets the
operator derive the width-1 matvec view (see operator.py).
"""
from __future__ import annotations

import math

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.cluster.operator import NormalizedOperator
from repro.cluster.registry import Registry
from repro.core import laplacian as lp, similarity as sim
from repro.distrib import mesh_utils

AFFINITIES = Registry("affinity")


def _row_constraint(A: jax.Array, mesh) -> jax.Array:
    axes = mesh_utils.flat_axes(mesh)
    return jax.lax.with_sharding_constraint(
        A, NamedSharding(mesh, P(axes, *([None] * (A.ndim - 1)))))


def operator_from_dense(S: jax.Array, n: int, mesh) -> NormalizedOperator:
    """Shared tail for every dense-S backend: pad, row-shard, build the
    shifted operator via :func:`laplacian.make_dense_operator` — a native
    matmat (S stays row-sharded, the (n_pad, b) block replicated, so one
    GSPMD pass of S serves the whole block)."""
    m = mesh_utils.mesh_size(mesh)
    n_pad = mesh_utils.pad_to_multiple(n, m)
    if n_pad != int(S.shape[0]):
        S = jnp.zeros((n_pad, n_pad), S.dtype).at[:n, :n].set(S[:n, :n])
    S = _row_constraint(S, mesh)
    valid = (jnp.arange(n_pad) < n).astype(S.dtype)
    matmat, inv_sqrt = lp.make_dense_operator(S, valid)
    # inv_sqrt threaded through so materializing for eigh doesn't pay a
    # second degree pass over S
    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt, n=n, n_pad=n_pad,
        mesh=mesh, schedule=None,
        dense=lambda: lp.dense_shifted_matrix(S, valid, inv_sqrt))


@AFFINITIES.register("dense")
def dense_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Full row-block RBF similarity (the old ``mode="full"`` path)."""
    S = sim.distributed_similarity_full(x, sigma, mesh)  # already padded
    return operator_from_dense(S, int(x.shape[0]), mesh)


@AFFINITIES.register("triangular")
def triangular_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Paper-faithful balanced triangular schedule, wide storage."""
    upper = sim.similarity_upper_blocks(x, sigma, mesh)
    deg = lp.degrees(upper)
    matmat = lp.make_shifted_matmat(upper, deg)
    inv_sqrt = lp.masked_inv_sqrt(deg)
    return NormalizedOperator(
        matmat=matmat, valid=upper.diag, inv_sqrt=inv_sqrt,
        n=upper.schedule.n, n_pad=upper.schedule.n_pad, mesh=mesh,
        schedule=upper.schedule,
        dense=lambda: lp.dense_shifted_matrix(sim.materialize(upper),
                                              upper.diag, inv_sqrt))


@AFFINITIES.register("compact")
def compact_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Triangular schedule with compact per-device tile stacks."""
    upper = sim.similarity_upper_blocks_compact(x, sigma, mesh)
    deg = sim.sym_matvec_compact(upper, upper.diag)
    inv_sqrt = lp.masked_inv_sqrt(deg)
    valid = upper.diag

    def matmat(V: jax.Array) -> jax.Array:
        SV = sim.sym_matmat_compact(upper, inv_sqrt[:, None] * V)
        return valid[:, None] * V + inv_sqrt[:, None] * SV

    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt,
        n=upper.schedule.n, n_pad=upper.schedule.n_pad, mesh=mesh,
        schedule=upper.schedule,
        dense=lambda: lp.dense_shifted_matrix(sim.materialize_compact(upper),
                                              valid, inv_sqrt))


@AFFINITIES.register("precomputed")
def precomputed_affinity(est, S, sigma, mesh) -> NormalizedOperator:
    """Caller-supplied symmetric non-negative similarity/adjacency matrix."""
    S = jnp.asarray(S, est.dtype)
    if S.ndim != 2 or S.shape[0] != S.shape[1]:
        raise ValueError(
            f"precomputed affinity expects a square (n, n) similarity "
            f"matrix, got shape {tuple(S.shape)}")
    return operator_from_dense(S, int(S.shape[0]), mesh)


@AFFINITIES.register("knn-topt")
def knn_topt_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Top-t sparsified RBF graph in the distributed path.

    Rows are sharded, so the per-row top-t threshold is a purely local
    sort; the max(S, S^T) symmetrization is the one transpose (GSPMD
    all-to-all — the Hadoop shuffle analogue).  On a single-device mesh the
    pair computation reuses the Pallas ``rbf_similarity`` kernel.
    """
    n = int(x.shape[0])
    t = est.sparsify_t or max(est.k + 2, 10)
    if mesh_utils.mesh_size(mesh) == 1:
        from repro.kernels import ops as kops
        S = kops.rbf_similarity(x, x, sigma,
                                schedule=getattr(est, "schedule", None))
        S = jnp.asarray(S, est.dtype)
    else:
        S = sim.distributed_similarity_full(x, sigma, mesh)
    # per-row threshold is local to a device (rows are sharded); the
    # max(S, S^T) symmetrization inside sparsify_topt is the one transpose
    St = sim.sparsify_topt(S, int(min(t, n)))
    return operator_from_dense(St, n, mesh)


def _fused_tile(n: int) -> int:
    from repro.kernels.fused_rbf_matmat import default_tile
    return default_tile(n)


def build_fused_rbf_operator(x, sigma, mesh, *, compute_dtype=None,
                             dtype=jnp.float32,
                             schedule=None) -> NormalizedOperator:
    """Matrix-free shifted normalized operator over raw points.

    Two fused passes, both row-sharded over the mesh with ONE psum each:
    the degree pass (the fused kernel against a ones column, masked to
    valid rows) and then, per matmat call, the normalized product
    ``D^{-1/2} S D^{-1/2} V`` with both scales applied inside the kernel.
    The (n, n) similarity never exists anywhere — points, scales and the
    (n_pad, b) block are the whole working set.

    Exposed directly (besides ``affinity="fused-rbf"``) so the engine's
    planner can route beyond-dense-memory jobs here without an estimator.

    ``schedule`` takes the estimator-facing domain (None / "default" /
    "auto" / Schedule / dict): tiles, accumulator placement and compute
    dtype of the fused kernel become one searchable value; "auto" consults
    the persistent schedule cache (:mod:`repro.tune.cache`) for this
    (shape bucket, device) and the chosen schedule + source land in the
    operator's ``stats()`` -> estimator ``info_["engine"]``.
    """
    from repro.kernels import fused_rbf_matmat as frm
    from repro.tune.schedule import resolve

    n, d = int(x.shape[0]), int(x.shape[1])
    m = mesh_utils.mesh_size(mesh)
    axes = mesh_utils.flat_axes(mesh)
    axis = axes[0] if len(axes) == 1 else axes
    tile = _fused_tile(n)
    sched, sched_src = resolve("fused_rbf_matmat", schedule, bm=tile,
                               bn=tile, compute_dtype=compute_dtype,
                               n=n, m=n, d=d, b=8)
    bm, bn = sched.bm, sched.bn
    # local row count must divide the row-tile side AND the mesh; padding
    # also covers the column tile (x serves as both sides of the kernel)
    lcm = bm * bn // math.gcd(bm, bn)
    n_pad = mesh_utils.pad_to_multiple(n, m * lcm)
    rows_local = n_pad // m
    xp = jnp.zeros((n_pad, d), jnp.float32).at[:n].set(
        jnp.asarray(x, jnp.float32))
    valid = (jnp.arange(n_pad) < n).astype(dtype)
    sigma32 = jnp.asarray(sigma, jnp.float32)
    cdtype = frm.resolve_compute_dtype(sched.compute_dtype or compute_dtype)

    def _sharded_pass(width: int):
        """Row-sharded fused pass for one block width: each device
        computes its (local, b) output stripe from its point rows vs the
        all-gathered columns, then one psum assembles the replicated
        (n_pad, b) block."""

        def body(x_local, rs_local, V_full, cs_full):
            x_full = lax.all_gather(x_local, axis, tiled=True)
            O_local = frm.fused_rbf_matmat(
                x_local, x_full, V_full, sigma32, rs_local[:, 0],
                cs_full[:, 0], bm=bm, bn=bn, compute_dtype=cdtype,
                acc=sched.acc, interpret=sched.interpret)
            out = jnp.zeros((n_pad, width), jnp.float32)
            out = lax.dynamic_update_slice(
                out, O_local, (lax.axis_index(axis) * rows_local, 0))
            return lax.psum(out, axis)

        return jax.jit(mesh_utils.shard_map(
            body, mesh=mesh,
            in_specs=(P(axes, None), P(axes, None), P(), P()),
            out_specs=P()))

    # the eigensolvers call matmat at a handful of widths, each possibly
    # hundreds of times — cache one jitted pass per width so the shard_map
    # (and the interpret-mode kernel on CPU) traces once, not per call
    _passes: dict = {}

    def fused(V, row_scale, col_scale):
        if m == 1:  # no collective needed: the kernel IS the whole pass
            return frm.fused_rbf_matmat(
                xp, xp, V.astype(jnp.float32), sigma32, row_scale,
                col_scale, bm=bm, bn=bn, compute_dtype=cdtype,
                acc=sched.acc, interpret=sched.interpret)
        width = int(V.shape[1])
        fn = _passes.get(width)
        if fn is None:
            fn = _passes.setdefault(width, _sharded_pass(width))
        return fn(xp, row_scale[:, None].astype(jnp.float32),
                  V.astype(jnp.float32),
                  col_scale[:, None].astype(jnp.float32))

    # pass 1: degrees = S @ 1 with padding masked on both sides
    deg = fused(jnp.ones((n_pad, 1), jnp.float32), valid, valid)[:, 0]
    inv_sqrt = lp.masked_inv_sqrt(deg).astype(dtype)

    # live HBM-traffic accounting (the dense paths stream n_pad^2 floats
    # per pass; the fused path streams point tiles instead)
    counters = {"matrix_passes": 1,
                "bytes_streamed": frm.pass_bytes(n_pad, n_pad, d, 1,
                                                 bm=bm, bn=bn)}

    def _bump(width) -> None:
        counters["matrix_passes"] += 1
        counters["bytes_streamed"] += frm.pass_bytes(
            n_pad, n_pad, d, int(width), bm=bm, bn=bn)

    def matmat(V: jax.Array) -> jax.Array:
        SV = fused(V.astype(jnp.float32), inv_sqrt, inv_sqrt)
        # debug.callback fires once per *execution* (also inside scans),
        # so the counters stay honest under jitted eigensolver loops
        jax.debug.callback(_bump, V.shape[1])
        return valid[:, None] * V + SV.astype(V.dtype)

    def dense() -> jax.Array:
        # oracle/eigh-only escape hatch: the one place the matrix exists
        from repro.core import similarity as sim_mod
        S = sim_mod.rbf_kernel(xp, xp, sigma32) \
            * valid[:, None] * valid[None, :]
        return lp.dense_shifted_matrix(jnp.asarray(S, dtype), valid,
                                       inv_sqrt)

    # O(n*d) affinity working set vs the dense paths' O(n^2) matrix
    peak = (n_pad * d + 3 * n_pad) * 4 \
        + ((bm + bn) * d + bm * bn + bm + bn) * 4  # + VMEM tiles

    def stats():
        try:                         # flush pending debug callbacks so the
            jax.effects_barrier()    # pass counters are read-consistent
        except Exception:
            pass
        return dict(counters, affinity_peak_bytes=peak,
                    dense_equiv_bytes=n_pad * n_pad * 4,
                    compute_dtype=jnp.dtype(cdtype).name, tile=bm,
                    schedule=sched.to_dict(), schedule_source=sched_src)

    baseline = dict(counters)        # post-build state: the degree pass

    def reset():
        # restore the post-build baseline so a reused operator reports
        # per-fit passes instead of accumulating across eigensolves
        try:
            jax.effects_barrier()    # flush in-flight _bump callbacks
        except Exception:
            pass
        counters.update(baseline)

    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt, n=n, n_pad=n_pad,
        mesh=mesh, schedule=None, dense=dense, stats=stats, reset=reset)


@AFFINITIES.register("fused-rbf")
def fused_rbf_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Flash-style matrix-free RBF affinity (O(n*d) memory).

    The similarity matrix is recomputed tile-by-tile inside a Pallas
    kernel on every pass and normalized in-register; ``est.compute_dtype``
    ('float32' | 'bfloat16') selects the MXU product precision (f32
    accumulation always).  Runs problem sizes whose dense similarity
    would not fit in memory at in-memory speed — the in-RAM complement
    of ``ooc-topt``.
    """
    return build_fused_rbf_operator(
        x, sigma, mesh, compute_dtype=getattr(est, "compute_dtype", None),
        dtype=est.dtype, schedule=getattr(est, "schedule", None))


@AFFINITIES.register("ooc-topt")
def ooc_topt_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Out-of-core top-t graph via the repro.engine MapReduce pipeline.

    The similarity matrix never exists densely: map tasks turn Pallas RBF
    tiles into per-row top-t candidates, the shuffle/reduce stages merge
    them into symmetrized CSR shards spilled to disk under
    ``est.memory_budget``, and the returned operator's matmat streams the
    shards through a host callback (one shard load per block).  Drop-in
    for any eigensolver/assigner.

    Resilience: the build inherits the estimator's retry/speculation
    knobs, and when ``est.stage_timeout_s`` trips (a stage deadline
    expired: queued tasks cancelled, hung attempts abandoned on daemon
    workers, so the deadline bounds this call's wall time) the fit
    degrades gracefully to the in-memory "knn-topt" affinity — the same
    top-t graph built without the engine — instead of failing the job.
    """
    import numpy as np

    from repro import engine, obs
    from repro.data.chunked import ArrayChunks

    n = int(x.shape[0])
    t = est.sparsify_t or max(est.k + 2, 10)
    plan = engine.JobPlan(
        n=n, chunk_size=est.chunk_size or 1024, t=int(min(t, n)), k=est.k,
        sigma=float(sigma), memory_budget=est.memory_budget,
        spill_dir=est.spill_dir, seed=est.seed,
        workers=getattr(est, "workers", 1),
        prefetch_depth=getattr(est, "prefetch_depth", 2),
        max_retries=getattr(est, "max_retries", 2),
        speculation_factor=getattr(est, "speculation_factor", 0.0),
        stage_timeout_s=getattr(est, "stage_timeout_s", None),
        faults=getattr(est, "faults", None))
    reader = ArrayChunks(np.asarray(x), plan.chunk_size)
    try:
        graph, _sigma = engine.build_graph(reader, plan)
    except engine.EngineTimeoutError as e:
        obs.counter("engine.path_fallbacks").inc()
        est._affinity_fallback = f"ooc-topt->knn-topt ({e})"
        return AFFINITIES.get("knn-topt")(est, x, sigma, mesh)
    # same padding invariant as the dense backends: downstream shard_map
    # stages need row counts divisible by the mesh
    n_pad = mesh_utils.pad_to_multiple(n, mesh_utils.mesh_size(mesh))
    return engine.make_normalized_operator(graph, dtype=est.dtype, mesh=mesh,
                                           pad_to=n_pad)
