"""Affinity backends: phase 1 of the pipeline as pluggable strategies.

Every backend has the signature

    backend(est, x, sigma, mesh) -> NormalizedOperator

where ``est`` is the :class:`~repro.cluster.SpectralClustering` estimator
(carrying k, sparsify_t, dtype, ...), ``x`` is (n, d) points — or, for
``precomputed``, the (n, n) similarity matrix itself — and ``sigma`` the RBF
bandwidth (ignored by ``precomputed``).

Backends:
  dense       full row-block similarity (beyond-paper "full" mode): every
              device computes its whole row block; 2x pair-FLOPs, zero
              mirror communication.
  triangular  the paper's balanced upper-triangle block schedule (Alg. 4.2),
              wide row-block storage.
  compact     same schedule, compact per-device tile stacks (perf S1).
  precomputed caller supplies S directly (paper §5 topology graphs).
  knn-topt    dense similarity then top-t row sparsification lifted into the
              distributed path (paper step 1 "and then sparse it"), keeping
              the graph symmetric via max(S, S^T).
  ooc-topt    the same top-t graph built out-of-core by the repro.engine
              map/shuffle/reduce pipeline: chunked Pallas tiles -> spillable
              CSR shards -> shard-streaming matmat (each shard loaded once
              per block); n is bounded by disk, not device memory.

Every backend returns a NormalizedOperator with a NATIVE matmat — one
pass over its similarity storage per (n_pad, b) block — and lets the
operator derive the width-1 matvec view (see operator.py).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core import laplacian as lp
from repro.core import similarity as sim
from repro.cluster.operator import NormalizedOperator
from repro.cluster.registry import Registry
from repro.distrib import mesh_utils

AFFINITIES = Registry("affinity")


def _row_constraint(A: jax.Array, mesh) -> jax.Array:
    axes = mesh_utils.flat_axes(mesh)
    return jax.lax.with_sharding_constraint(
        A, NamedSharding(mesh, P(axes, *([None] * (A.ndim - 1)))))


def operator_from_dense(S: jax.Array, n: int, mesh) -> NormalizedOperator:
    """Shared tail for every dense-S backend: pad, row-shard, build the
    shifted operator via :func:`laplacian.make_dense_operator` — a native
    matmat (S stays row-sharded, the (n_pad, b) block replicated, so one
    GSPMD pass of S serves the whole block)."""
    m = mesh_utils.mesh_size(mesh)
    n_pad = mesh_utils.pad_to_multiple(n, m)
    if n_pad != int(S.shape[0]):
        S = jnp.zeros((n_pad, n_pad), S.dtype).at[:n, :n].set(S[:n, :n])
    S = _row_constraint(S, mesh)
    valid = (jnp.arange(n_pad) < n).astype(S.dtype)
    matmat, inv_sqrt = lp.make_dense_operator(S, valid)
    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt, n=n, n_pad=n_pad,
        mesh=mesh, schedule=None,
        dense=lambda: lp.dense_shifted_matrix(S, valid))


@AFFINITIES.register("dense")
def dense_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Full row-block RBF similarity (the old ``mode="full"`` path)."""
    S = sim.distributed_similarity_full(x, sigma, mesh)  # already padded
    return operator_from_dense(S, int(x.shape[0]), mesh)


@AFFINITIES.register("triangular")
def triangular_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Paper-faithful balanced triangular schedule, wide storage."""
    upper = sim.similarity_upper_blocks(x, sigma, mesh)
    deg = lp.degrees(upper)
    matmat = lp.make_shifted_matmat(upper, deg)
    return NormalizedOperator(
        matmat=matmat, valid=upper.diag, inv_sqrt=lp.masked_inv_sqrt(deg),
        n=upper.schedule.n, n_pad=upper.schedule.n_pad, mesh=mesh,
        schedule=upper.schedule,
        dense=lambda: lp.dense_shifted_matrix(sim.materialize(upper),
                                              upper.diag))


@AFFINITIES.register("compact")
def compact_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Triangular schedule with compact per-device tile stacks."""
    upper = sim.similarity_upper_blocks_compact(x, sigma, mesh)
    deg = sim.sym_matvec_compact(upper, upper.diag)
    inv_sqrt = lp.masked_inv_sqrt(deg)
    valid = upper.diag

    def matmat(V: jax.Array) -> jax.Array:
        SV = sim.sym_matmat_compact(upper, inv_sqrt[:, None] * V)
        return valid[:, None] * V + inv_sqrt[:, None] * SV

    return NormalizedOperator(
        matmat=matmat, valid=valid, inv_sqrt=inv_sqrt,
        n=upper.schedule.n, n_pad=upper.schedule.n_pad, mesh=mesh,
        schedule=upper.schedule,
        dense=lambda: lp.dense_shifted_matrix(sim.materialize_compact(upper),
                                              valid))


@AFFINITIES.register("precomputed")
def precomputed_affinity(est, S, sigma, mesh) -> NormalizedOperator:
    """Caller-supplied symmetric non-negative similarity/adjacency matrix."""
    S = jnp.asarray(S, est.dtype)
    if S.ndim != 2 or S.shape[0] != S.shape[1]:
        raise ValueError(
            f"precomputed affinity expects a square (n, n) similarity "
            f"matrix, got shape {tuple(S.shape)}")
    return operator_from_dense(S, int(S.shape[0]), mesh)


@AFFINITIES.register("knn-topt")
def knn_topt_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Top-t sparsified RBF graph in the distributed path.

    Rows are sharded, so the per-row top-t threshold is a purely local
    sort; the max(S, S^T) symmetrization is the one transpose (GSPMD
    all-to-all — the Hadoop shuffle analogue).  On a single-device mesh the
    pair computation reuses the Pallas ``rbf_similarity`` kernel.
    """
    n = int(x.shape[0])
    t = est.sparsify_t or max(est.k + 2, 10)
    if mesh_utils.mesh_size(mesh) == 1:
        from repro.kernels import ops as kops
        S = kops.rbf_similarity(x, x, sigma)
        S = jnp.asarray(S, est.dtype)
    else:
        S = sim.distributed_similarity_full(x, sigma, mesh)
    # per-row threshold is local to a device (rows are sharded); the
    # max(S, S^T) symmetrization inside sparsify_topt is the one transpose
    St = sim.sparsify_topt(S, int(min(t, n)))
    return operator_from_dense(St, n, mesh)


@AFFINITIES.register("ooc-topt")
def ooc_topt_affinity(est, x, sigma, mesh) -> NormalizedOperator:
    """Out-of-core top-t graph via the repro.engine MapReduce pipeline.

    The similarity matrix never exists densely: map tasks turn Pallas RBF
    tiles into per-row top-t candidates, the shuffle/reduce stages merge
    them into symmetrized CSR shards spilled to disk under
    ``est.memory_budget``, and the returned operator's matmat streams the
    shards through a host callback (one shard load per block).  Drop-in
    for any eigensolver/assigner.
    """
    import numpy as np

    from repro import engine
    from repro.data.chunked import ArrayChunks

    n = int(x.shape[0])
    t = est.sparsify_t or max(est.k + 2, 10)
    plan = engine.JobPlan(
        n=n, chunk_size=est.chunk_size or 1024, t=int(min(t, n)), k=est.k,
        sigma=float(sigma), memory_budget=est.memory_budget,
        spill_dir=est.spill_dir, seed=est.seed)
    reader = ArrayChunks(np.asarray(x), plan.chunk_size)
    graph, _sigma = engine.build_graph(reader, plan)
    # same padding invariant as the dense backends: downstream shard_map
    # stages need row counts divisible by the mesh
    n_pad = mesh_utils.pad_to_multiple(n, mesh_utils.mesh_size(mesh))
    return engine.make_normalized_operator(graph, dtype=est.dtype, mesh=mesh,
                                           pad_to=n_pad)
