"""The unified estimator: one entry point, three pluggable phases.

    est = SpectralClustering(k=3, affinity="triangular",
                             eigensolver="lanczos", assigner="lloyd")
    est.fit(x)                 # points (n, d)
    est.labels_                # (n,) cluster ids, original point order
    est.predict(x_new)         # nearest-center assignment of new points
                               # in embedding space (Nystrom extension)

``fit`` runs the paper's three phases — similarity, eigendecomposition,
k-means — each selected by a registry string; any affinity composes with
any eigensolver and any assigner because they meet at the
:class:`~repro.cluster.operator.NormalizedOperator` interface.

RNG discipline matches the legacy ``spectral.fit`` exactly (one PRNGKey
split three ways), so ``SpectralClustering(affinity="triangular",
eigensolver="lanczos", assigner="lloyd").fit(x)`` reproduces the old
pipeline bit-for-bit.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro import obs
from repro.cluster import serving
from repro.cluster.affinity import AFFINITIES
from repro.cluster.assigners import ASSIGNERS
from repro.cluster.eigensolvers import EIGENSOLVERS
from repro.cluster.operator import SpectralResult
from repro.core import kmeans as km, laplacian as lp, similarity as sim
from repro.distrib import mesh_utils

# on-disk model layout version (est.save / SpectralClustering.load)
MODEL_FORMAT = 1
_MODEL_ARRAYS = ("train_x", "eigvecs", "inv_sqrt", "eigenvalues", "centers",
                 "sigma", "labels", "embedding")


class SpectralClustering:
    """Parallel spectral clustering with pluggable phase backends.

    Parameters
    ----------
    k:              number of clusters (and embedding dimensions).
    affinity:       name in :data:`~repro.cluster.AFFINITIES`
                    ("dense" | "triangular" | "compact" | "precomputed"
                    | "knn-topt" | "ooc-topt" | "fused-rbf").  With
                    "precomputed", ``fit(S)`` treats its argument as the
                    (n, n) similarity matrix; "ooc-topt" builds the graph
                    out-of-core through ``repro.engine``; "fused-rbf"
                    never materializes the similarity at all (O(n*d)
                    affinity memory, see ``compute_dtype``).
    eigensolver:    name in :data:`~repro.cluster.EIGENSOLVERS`
                    ("lanczos" | "block-lanczos" | "chebdav" | "eigh").
    assigner:       name in :data:`~repro.cluster.ASSIGNERS`
                    ("lloyd" | "minibatch" | "streaming").
    sigma:          RBF bandwidth; None = median heuristic.
    lanczos_steps:  None = max(4k, 32), capped below n.  For
                    "block-lanczos" this is the target Krylov dimension:
                    the solver runs ceil(steps / block_size) block steps
                    (same subspace, ~1/block_size the matrix passes).
    block_size:     block width b for "block-lanczos" / "chebdav"
                    (None = 8 for block-lanczos, max(2, k) for chebdav).
    cheb_degree:    Chebyshev filter degree for "chebdav".
    sparsify_t:     top-t per row for the "knn-topt" / "ooc-topt"
                    affinities (None = max(k + 2, 10)).
    compute_dtype:  MXU product precision inside the "fused-rbf" kernel:
                    None/"float32" (default) or "bfloat16"/"bf16"
                    (halved MXU operand volume; accumulation stays f32
                    either way, so only the similarity entries lose
                    precision).  Also read by the fused transform path.
    schedule:       kernel schedule for the Pallas-backed paths
                    (fused-rbf affinity, knn-topt similarity, fused
                    transform): None/"default" (the built-in tiles),
                    "auto" (consult the persistent schedule cache filled
                    by ``repro.tune.autotune`` — falls back to the
                    default on a miss), or an explicit
                    :class:`repro.tune.Schedule` / dict of its fields.
                    The schedule actually used is recorded in
                    ``info_["schedule"]`` (fit) and
                    ``info_["transform"]["schedule"]`` (transform).
    transform_path: out-of-sample extension path for transform/predict:
                    "auto" (default — the (m, n) kernel's bytes against
                    ``memory_budget`` or a 64 MiB default decide, like
                    ``engine.route_path``), "dense" (materialize the
                    query-vs-train kernel) or "fused" (matrix-free
                    dual-output kernel, O((m+n)*d + n*k) memory).
    chunk_size:     rows per chunk for the out-of-core "ooc-topt"
                    affinity and "streaming" assigner (None = 1024/4096).
    memory_budget:  engine shard-store RAM budget in bytes
                    (None = unlimited, nothing spills to disk).
    spill_dir:      where the engine spills shards (None = temp dir).
    workers:        engine task-pool width for the "ooc-topt" graph build
                    (map/shuffle/reduce run dependency-driven on this
                    many threads; 1 = sequential order, results are
                    bitwise-identical at any width).
    prefetch_depth: shard readahead window of the engine's streaming
                    matmat (how many upcoming CSR shards are fetched
                    concurrently while the current one multiplies).
    max_retries:    engine per-task re-execution budget for the
                    "ooc-topt" build (failed attempts retry with
                    exponential backoff; retried results are
                    bitwise-identical).
    speculation_factor: engine straggler threshold k — a running task
                    whose wall exceeds k x the stage's running-median
                    wall gets one speculative backup attempt (0 = off).
    stage_timeout_s: per-stage deadline for the engine build; on expiry
                    the job cancels queued tasks, abandons hung attempts
                    (the deadline bounds the fit's wall time even when a
                    task sticks in blocked I/O) and the fit FALLS BACK to
                    the in-memory "knn-topt" affinity (the same top-t
                    graph, no spilling) instead of failing.
    faults:         optional ``engine.FaultPlan`` for deterministic
                    fault injection (tests/benchmarks; None = no-op).
    mesh:           device mesh; None = all local devices.

    Fitted attributes (original point order): ``labels_``, ``embedding_``,
    ``eigenvalues_``, ``centers_``, ``sigma_``, ``info_``, ``result_``.
    """

    def __init__(self, k: int = 8, *, affinity: str = "triangular",
                 eigensolver: str = "lanczos", assigner: str = "lloyd",
                 sigma: float | None = None, lanczos_steps: int | None = None,
                 block_size: int | None = None, cheb_degree: int = 12,
                 kmeans_iters: int = 50, sparsify_t: int | None = None,
                 compute_dtype: Any = None, schedule: Any = None,
                 transform_path: str = "auto",
                 minibatch_size: int = 256, chunk_size: int | None = None,
                 memory_budget: int | None = None,
                 spill_dir: str | None = None,
                 workers: int = 1, prefetch_depth: int = 2,
                 max_retries: int = 2, speculation_factor: float = 0.0,
                 stage_timeout_s: float | None = None, faults: Any = None,
                 seed: int = 0,
                 dtype: Any = jnp.float32, mesh: Optional[Mesh] = None):
        # Resolve backends eagerly so a typo fails at construction, not
        # after an expensive similarity phase.
        self._affinity_fn = AFFINITIES.get(affinity)
        self._eigensolver_fn = EIGENSOLVERS.get(eigensolver)
        self._assigner_fn = ASSIGNERS.get(assigner)
        if cheb_degree < 1:
            raise ValueError(
                f"cheb_degree must be >= 1, got {cheb_degree}")
        self.k = k
        self.affinity = affinity
        self.eigensolver = eigensolver
        self.assigner = assigner
        self.sigma = sigma
        self.lanczos_steps = lanczos_steps
        self.block_size = block_size
        self.cheb_degree = cheb_degree
        self.kmeans_iters = kmeans_iters
        self.sparsify_t = sparsify_t
        # validate eagerly (same philosophy as the registry lookups)
        from repro.kernels.fused_rbf_matmat import resolve_compute_dtype
        resolve_compute_dtype(compute_dtype)
        self.compute_dtype = compute_dtype
        from repro.tune.schedule import validate_spec
        self.schedule = validate_spec(schedule)
        serving.check_transform_path(transform_path)
        self.transform_path = transform_path
        self._transform_cache: dict = {}
        self.minibatch_size = minibatch_size
        self.chunk_size = chunk_size
        self.memory_budget = memory_budget
        self.spill_dir = spill_dir
        if workers < 1:
            raise ValueError(f"workers must be >= 1, got {workers}")
        if prefetch_depth < 1:
            raise ValueError(
                f"prefetch_depth must be >= 1, got {prefetch_depth}")
        self.workers = workers
        self.prefetch_depth = prefetch_depth
        if max_retries < 0:
            raise ValueError(f"max_retries must be >= 0, got {max_retries}")
        if speculation_factor < 0:
            raise ValueError(f"speculation_factor must be >= 0 (0 = off), "
                             f"got {speculation_factor}")
        if stage_timeout_s is not None and stage_timeout_s <= 0:
            raise ValueError(f"stage_timeout_s must be positive seconds or "
                             f"None, got {stage_timeout_s}")
        self.max_retries = max_retries
        self.speculation_factor = speculation_factor
        self.stage_timeout_s = stage_timeout_s
        self.faults = faults
        self.seed = seed
        self.dtype = dtype
        self.mesh = mesh
        self.result_: SpectralResult | None = None

    # -- configuration helpers ------------------------------------------------

    def num_lanczos_steps(self, n: int) -> int:
        m = self.lanczos_steps or max(4 * self.k, 32)
        return int(min(m, n - 1))

    def num_block_size(self, n: int | None = None) -> int:
        if self.block_size is not None:
            if self.block_size <= 0:
                raise ValueError(
                    f"block_size must be positive, got {self.block_size}")
            b = int(self.block_size)
        else:
            b = 8 if self.eigensolver == "block-lanczos" else max(2, self.k)
        return b if n is None else max(1, min(b, n))

    def num_block_steps(self, n: int) -> int:
        """Block steps covering the same Krylov dimension as the
        single-vector iteration would (ceil division by the block width),
        so accuracy is comparable at ~1/b the matrix passes."""
        b = self.num_block_size(n)
        return max(1, -(-self.num_lanczos_steps(n) // b))

    def _mesh(self) -> Mesh:
        return self.mesh or mesh_utils.local_mesh("rows")

    # -- fitting --------------------------------------------------------------

    def fit(self, x: jax.Array, checkpointer: Any = None) -> "SpectralClustering":
        """Cluster points (n, d) — or, with ``affinity="precomputed"``, a
        similarity matrix (n, n).  Returns ``self``."""
        if self.affinity == "precomputed":
            return self.fit_affinity(x, checkpointer=checkpointer)
        mesh = self._mesh()
        phases: dict = {}
        with obs.span("fit", affinity=self.affinity,
                      eigensolver=self.eigensolver, assigner=self.assigner,
                      n=int(x.shape[0])) as sp_fit:
            with obs.span("fit.affinity", backend=self.affinity) as sp_aff:
                x = jnp.asarray(x, self.dtype)
                key = jax.random.PRNGKey(self.seed)
                _k_eig, k_lan, k_km = jax.random.split(key, 3)
                sigma = jnp.asarray(self.sigma, self.dtype) \
                    if self.sigma is not None else sim.median_sigma(x)
                op = self._affinity_fn(self, x, sigma, mesh)
            phases["affinity"] = sp_aff
            if checkpointer is not None:
                checkpointer.save_phase("similarity", {"sigma": sigma})
            self._finish(op, sigma, k_lan, k_km, mesh, checkpointer,
                         train_x=x, affinity_used=self.affinity,
                         phases=phases)
        self._record_obs(sp_fit, phases)
        return self

    def fit_affinity(self, S: jax.Array,
                     checkpointer: Any = None) -> "SpectralClustering":
        """Cluster from a precomputed (n, n) similarity/adjacency matrix
        (the paper's §5 graph dataset), regardless of ``self.affinity``."""
        mesh = self._mesh()
        phases: dict = {}
        with obs.span("fit", affinity="precomputed",
                      eigensolver=self.eigensolver, assigner=self.assigner,
                      n=int(S.shape[0])) as sp_fit:
            with obs.span("fit.affinity", backend="precomputed") as sp_aff:
                key = jax.random.PRNGKey(self.seed)
                _k_eig, k_lan, k_km = jax.random.split(key, 3)
                op = AFFINITIES.get("precomputed")(self, S, None, mesh)
            phases["affinity"] = sp_aff
            self._finish(op, jnp.asarray(0.0, self.dtype), k_lan, k_km,
                         mesh, checkpointer, train_x=None,
                         affinity_used="precomputed", phases=phases)
        self._record_obs(sp_fit, phases)
        return self

    def fit_predict(self, x: jax.Array) -> jax.Array:
        return self.fit(x).labels_

    def _finish(self, op, sigma, k_lan, k_km, mesh, checkpointer, train_x,
                affinity_used, phases=None):
        phases = phases if phases is not None else {}
        # a reused operator starts a fresh counter window here (fresh
        # operators are already at their post-build baseline: no-op)
        op.reset_stats()
        with obs.span("fit.eigensolve", backend=self.eigensolver) as sp_eig:
            evals, Z, info = self._eigensolver_fn(self, op, k_lan)
            jax.block_until_ready(Z)
        phases["eigensolve"] = sp_eig
        if checkpointer is not None:
            checkpointer.save_phase("eigen", {"eigenvalues": evals})
        with obs.span("fit.assign", backend=self.assigner) as sp_asg:
            Y = km.normalize_rows(Z) * op.valid[:, None]
            Y = jax.lax.with_sharding_constraint(
                Y, NamedSharding(mesh, P(mesh_utils.flat_axes(mesh), None)))
            labels_pad, centers = self._assigner_fn(self, Y, op.valid, k_km,
                                                    mesh)
            labels_unp = op.unpermute(labels_pad)
            emb_unp = op.unpermute(Y)
            jax.block_until_ready(labels_unp)
        phases["assign"] = sp_asg
        if checkpointer is not None:
            checkpointer.save_phase("kmeans", {"centers": centers})

        self.labels_ = labels_unp
        self.embedding_ = emb_unp
        self.eigenvalues_ = evals
        self.centers_ = centers
        self.sigma_ = sigma
        self.info_ = dict(info, affinity=affinity_used,
                          eigensolver=self.eigensolver,
                          assigner=self.assigner, n_pad=op.n_pad)
        op_stats = op.stats_snapshot()
        if op_stats:
            self.info_["engine"] = op_stats
        fb = getattr(self, "_affinity_fallback", None)
        if fb is not None:             # graceful-degradation audit trail
            self.info_["affinity_fallback"] = fb
            self._affinity_fallback = None
        # release backend worker resources (the engine's shard-prefetch
        # pool) — a fit must not strand background threads
        if getattr(op, "close", None) is not None:
            op.close()
        # surface the kernel schedule that actually ran: the fused
        # operator reports its resolved schedule (incl. "auto" cache
        # hits); other affinities record the estimator-level request
        if op_stats and "schedule" in op_stats:
            self.info_["schedule"] = {
                "value": op_stats["schedule"],
                "source": op_stats.get("schedule_source", "default")}
        elif self.schedule is not None:
            from repro.tune.schedule import as_schedule
            s = None if self.schedule == "auto" \
                else as_schedule(self.schedule)
            self.info_["schedule"] = {
                "value": "auto" if s is None else s.to_dict(),
                "source": "requested"}
        # Nystrom-extension state for transform()/predict(): unnormalized
        # eigenvector rows and D^{-1/2}, both in original point order.
        self._train_x = train_x
        self._eigvecs = op.unpermute(Z)
        self._inv_sqrt = op.unpermute(op.inv_sqrt)
        self.result_ = SpectralResult(
            labels=self.labels_, embedding=self.embedding_,
            eigenvalues=evals, centers=centers, sigma=sigma,
            info=self.info_)
        return self

    def _record_obs(self, fit_span, phases):
        """Publish ``info_["obs"]`` (phase walls + coverage + counters)
        and mirror the numeric fit stats into the process registry."""
        counters: dict = {}
        info = getattr(self, "info_", None) or {}
        for k, v in list(info.items()) + list((info.get("engine")
                                               or {}).items()):
            if hasattr(v, "item") and not isinstance(v, (bool, int, float,
                                                         str)):
                try:
                    v = v.item()
                except Exception:
                    continue
            if isinstance(v, bool) or not isinstance(v, (int, float)):
                continue
            counters.setdefault(k, v)
        self.info_["obs"] = obs.fit_obs(fit_span, phases, counters=counters)
        obs.absorb_stats("fit", counters)
        obs.gauge("fit.coverage").set(self.info_["obs"]["coverage"])

    # -- out-of-sample extension ----------------------------------------------

    def transform(self, x: jax.Array) -> jax.Array:
        """Embed new points (m, d) into the fitted spectral space.

        Nystrom extension: z_j(x) = (1/mu_j) sum_i N(x, i) z_j(i) with
        N the degree-normalized kernel and mu_j = 1 - lambda_j the
        eigenvalue of N; rows are then unit-normalized like the training
        embedding.  Requires a feature-space fit (not "precomputed").

        Routed per ``transform_path``: the dense path materializes the
        (m, n) query-vs-train kernel (fine for small problems); the fused
        path streams it through the dual-output Pallas kernel and never
        builds it (O((m+n)*d + n*k) memory).  Both agree to <= 1e-4 in
        f32; the route taken is recorded in ``info_["transform"]``.
        """
        self._check_fitted()
        if self._train_x is None:
            raise ValueError(
                "transform/predict need the training points; an estimator "
                "fitted from a precomputed similarity matrix cannot embed "
                "new points")
        x = jnp.asarray(x, self.dtype)
        m, n = int(x.shape[0]), int(self._train_x.shape[0])
        path = serving.route_transform(n, m, path=self.transform_path,
                                       memory_budget=self.memory_budget)
        mu = serving.shifted_mu(self.eigenvalues_)
        with obs.span("transform", path=path, m=m, n=n):
            if path == "dense":
                K = sim.rbf_kernel(x, self._train_x, self.sigma_)
                O = K @ (self._inv_sqrt[:, None] * self._eigvecs)
                emb = serving.extension_from_product(O, jnp.sum(K, axis=1),
                                                     mu)
                peak = m * n * 4
            else:
                sched_info: dict = {}
                emb = serving.fused_transform(
                    x, self._train_x, self._eigvecs, self._inv_sqrt,
                    self.sigma_, mu, mesh=self._mesh(),
                    compute_dtype=self.compute_dtype,
                    schedule=getattr(self, "schedule", None),
                    _cache=self._transform_cache, _info=sched_info)
                peak = serving.transform_peak_bytes(
                    m, n, int(x.shape[1]), self.k,
                    mesh_size=mesh_utils.mesh_size(self._mesh()))
        obs.counter("transform.calls", path=path).inc()
        self.info_.setdefault("transform", {}).update(
            path=path, m=m, peak_bytes=int(peak),
            dense_equiv_bytes=m * n * 4)
        if path == "fused" and sched_info:
            self.info_["transform"].update(sched_info)
        return emb

    def predict(self, x: jax.Array) -> jax.Array:
        """Nearest-center cluster assignment of new points in embedding
        space (the fitted centers are the reference)."""
        with obs.span("predict", m=int(x.shape[0])):
            return km.assign(self.transform(x), self.centers_)

    def _check_fitted(self):
        if self.result_ is None:
            raise ValueError("this SpectralClustering instance is not "
                             "fitted yet; call fit() first")

    # -- persistence ----------------------------------------------------------

    def save(self, directory: str) -> str:
        """Persist the fitted model (the Nystrom serving state: training
        points, eigenvector block, D^{-1/2}, eigenvalues, centers, sigma,
        plus labels/embedding) to ``directory`` — one ``CheckpointManager``
        npz of logical, unsharded arrays plus a ``config.json`` of the
        constructor parameters.  Restore with
        :meth:`SpectralClustering.load`, on any device count (elastic:
        arrays re-place onto whatever mesh the loading process has)."""
        import json
        import os

        from repro.checkpoint import CheckpointManager
        from repro.kernels.fused_rbf_matmat import resolve_compute_dtype

        self._check_fitted()
        if self._train_x is None:
            raise ValueError(
                "cannot save a model fitted from a precomputed similarity "
                "matrix; transform/predict would have no training points")
        os.makedirs(directory, exist_ok=True)
        state = {"train_x": self._train_x, "eigvecs": self._eigvecs,
                 "inv_sqrt": self._inv_sqrt,
                 "eigenvalues": self.eigenvalues_, "centers": self.centers_,
                 "sigma": self.sigma_, "labels": self.labels_,
                 "embedding": self.embedding_}
        mgr = CheckpointManager(directory, keep=1, async_write=False)
        path = mgr.save(0, state, name="model")
        cfg = {
            "format": MODEL_FORMAT,
            "params": {
                "k": self.k, "affinity": self.affinity,
                "eigensolver": self.eigensolver, "assigner": self.assigner,
                "sigma": self.sigma, "lanczos_steps": self.lanczos_steps,
                "block_size": self.block_size,
                "cheb_degree": self.cheb_degree,
                "kmeans_iters": self.kmeans_iters,
                "sparsify_t": self.sparsify_t,
                # normalize to the string form (the constructor may have
                # been handed a dtype object, which JSON can't encode)
                "compute_dtype": None if self.compute_dtype is None else
                jnp.dtype(resolve_compute_dtype(self.compute_dtype)).name,
                # Schedule objects serialize to their field dict; strings
                # ("auto"/"default") and None pass through as-is
                "schedule": (self.schedule.to_dict()
                             if hasattr(self.schedule, "to_dict")
                             else self.schedule),
                "transform_path": self.transform_path,
                "minibatch_size": self.minibatch_size,
                "chunk_size": self.chunk_size,
                "memory_budget": self.memory_budget,
                "workers": self.workers,
                "prefetch_depth": self.prefetch_depth,
                "seed": self.seed, "dtype": jnp.dtype(self.dtype).name,
            },
            "fitted": {"n": int(self._train_x.shape[0]),
                       "d": int(self._train_x.shape[1]),
                       "info": {k: v for k, v in self.info_.items()
                                if isinstance(v, (str, int, float))}},
        }
        tmp = os.path.join(directory, "config.json.tmp")
        with open(tmp, "w") as f:
            json.dump(cfg, f, indent=2)
        os.replace(tmp, os.path.join(directory, "config.json"))
        return path

    @classmethod
    def load(cls, directory: str, *,
             mesh: Optional[Mesh] = None) -> "SpectralClustering":
        """Rebuild a fitted estimator from :meth:`save` output.  The
        restored model predicts bitwise-identically to the estimator that
        was saved (same routing, same kernel passes); ``mesh`` defaults to
        all local devices, whatever their count was at save time."""
        import json
        import os

        from repro.checkpoint import CheckpointManager

        with open(os.path.join(directory, "config.json")) as f:
            cfg = json.load(f)
        if cfg.get("format") != MODEL_FORMAT:
            raise ValueError(
                f"unsupported model format {cfg.get('format')!r} in "
                f"{directory} (this build reads format {MODEL_FORMAT})")
        params = dict(cfg["params"])
        params["dtype"] = jnp.dtype(params["dtype"])
        est = cls(mesh=mesh, **params)
        mgr = CheckpointManager(directory, keep=1, async_write=False)
        # the template only supplies the pytree structure; leaf values and
        # shapes come from the checkpoint itself
        state = mgr.restore({name: 0 for name in _MODEL_ARRAYS},
                            name="model")
        est._train_x = jnp.asarray(state["train_x"], est.dtype)
        est._eigvecs = state["eigvecs"]
        est._inv_sqrt = state["inv_sqrt"]
        est.eigenvalues_ = state["eigenvalues"]
        est.centers_ = state["centers"]
        est.sigma_ = state["sigma"]
        est.labels_ = state["labels"]
        est.embedding_ = state["embedding"]
        est.info_ = dict(cfg["fitted"].get("info", {}))
        est.result_ = SpectralResult(
            labels=est.labels_, embedding=est.embedding_,
            eigenvalues=est.eigenvalues_, centers=est.centers_,
            sigma=est.sigma_, info=est.info_)
        return est
