"""String-keyed backend registries for the pluggable estimator.

Each pipeline phase (affinity, eigensolver, assigner) owns one
:class:`Registry`; backends self-register at import time with the
``@REGISTRY.register("name")`` decorator, and user code selects them by
string — no ``if/elif`` ladders in the pipeline, and downstream projects can
plug in their own backends without touching this package:

    from repro.cluster import AFFINITIES

    @AFFINITIES.register("my-kernel")
    def my_affinity(est, x, sigma, mesh):
        ...
"""
from __future__ import annotations

from typing import Callable, Iterator


class Registry:
    """A named string -> callable map with self-describing error messages."""

    def __init__(self, kind: str):
        self.kind = kind
        self._entries: dict[str, Callable] = {}

    def register(self, name: str) -> Callable[[Callable], Callable]:
        def deco(fn: Callable) -> Callable:
            if name in self._entries:
                raise ValueError(
                    f"{self.kind} backend {name!r} is already registered")
            self._entries[name] = fn
            return fn
        return deco

    def get(self, name: str) -> Callable:
        try:
            return self._entries[name]
        except KeyError:
            raise ValueError(
                f"unknown {self.kind} backend {name!r}; "
                f"registered backends: {sorted(self._entries)}") from None

    def names(self) -> tuple[str, ...]:
        return tuple(sorted(self._entries))

    def __contains__(self, name: str) -> bool:
        return name in self._entries

    def __iter__(self) -> Iterator[str]:
        return iter(self.names())
