"""The common product of every affinity backend.

All affinity backends — dense, triangular, compact, precomputed, knn-topt —
reduce to the same object: the *shifted normalized operator*

    A v = valid * v + D^{-1/2} S D^{-1/2} v

whose largest eigenpairs are the smallest of L_sym = I - D^{-1/2} S D^{-1/2}
(see ``core.laplacian``).  Eigensolver backends consume only this interface,
so any affinity composes with any eigensolver; the ``schedule`` /
``unpermute`` bookkeeping hides whether rows are block-permuted (triangular
schedules) or in original order (dense paths).
"""
from __future__ import annotations

from dataclasses import dataclass, field
from typing import Any, Callable, Optional

import jax
import jax.numpy as jnp


@dataclass
class SpectralResult:
    """Result bundle in original point order (also what the legacy
    ``repro.core.spectral`` entry points return)."""
    labels: jax.Array            # (n,) original point order
    embedding: jax.Array         # (n, k) row-normalized eigenvector rows
    eigenvalues: jax.Array       # (k,) smallest of L_sym, ascending
    centers: jax.Array           # (k, k)
    sigma: jax.Array
    info: dict = field(default_factory=dict)


@dataclass
class NormalizedOperator:
    """Shifted normalized-similarity operator plus its padding/permutation
    bookkeeping.

    matmat:    (n_pad, b) -> (n_pad, b) replicated; ``A V`` as above — the
               CANONICAL product.  Every in-tree affinity backend supplies
               a native matmat (one pass over the similarity per block);
               when a third-party backend supplies only ``matvec``, a
               column-loop fallback is derived (correct, but it pays one
               matrix pass per column — see API.md's migration note).
    matvec:    (n_pad,) -> (n_pad,) replicated; derived width-1 view of
               ``matmat`` unless the backend supplied its own.
    valid:     (n_pad,) 1/0 mask — 0 on padding rows.
    inv_sqrt:  (n_pad,) D^{-1/2} of the (padded) similarity; kept so the
               estimator can Nystrom-extend the embedding to new points.
    n, n_pad:  true vs padded point count; rows may be permuted (schedule).
    mesh:      device mesh the similarity is sharded over.
    schedule:  ``BlockSchedule`` when rows are block-permuted, else None.
    dense:     optional zero-arg callable materializing A (n_pad, n_pad)
               exactly — used by the ``eigh`` backend; falls back to
               applying ``matmat`` to identity blocks when absent.
    stats:     backend-reported build statistics (e.g. the engine's
               map/shuffle/reduce counters); merged into ``est.info_``.
               Either a dict or a zero-arg callable returning one — a
               callable is re-evaluated at read time, so backends whose
               counters keep moving after construction (shard-store
               spills during the eigensolve) report live numbers.
    reset:     optional zero-arg callable restoring the backend's live
               counters to their post-build baseline.  The estimator
               calls :meth:`reset_stats` before each eigensolve so a
               REUSED operator reports per-fit numbers instead of
               accumulating across fits (fresh operators: no-op).
    close:     optional zero-arg callable releasing backend worker
               resources (the engine's shard-prefetch pool).  The
               estimator calls it (when set) as a fit finishes so no
               background threads outlive it; backends must treat it as
               non-final (a reused operator's next matmat restarts
               whatever close released).
    host_matmat: optional plain-host (numpy (n_pad, b) -> (n_pad, b))
               view of the SAME product, set by streaming backends whose
               matmat wraps host code in ``pure_callback``.  Eigensolvers
               that see it drive the recurrence step-by-step from Python
               (``core.lanczos.block_run_host``) instead of tracing the
               callback into one computation — the callback machinery can
               self-deadlock on single-thread CPU runtimes.
    """

    valid: jax.Array
    inv_sqrt: jax.Array
    n: int
    n_pad: int
    mesh: Any
    matmat: Optional[Callable[[jax.Array], jax.Array]] = None
    matvec: Optional[Callable[[jax.Array], jax.Array]] = None
    schedule: Any = None
    dense: Optional[Callable[[], jax.Array]] = None
    stats: Any = field(default_factory=dict)
    reset: Optional[Callable[[], None]] = None
    close: Optional[Callable[[], None]] = None
    host_matmat: Optional[Callable] = None

    def __post_init__(self):
        if self.matmat is None and self.matvec is None:
            raise ValueError(
                "NormalizedOperator needs matmat (preferred) or matvec")
        if self.matmat is None:
            # Third-party matvec-only backend: column loop.  ``lax.map``
            # keeps one column in flight (a vmap batch would defeat
            # streaming backends) without unrolling b calls per trace.
            mv = self.matvec

            def matmat(V: jax.Array) -> jax.Array:
                return jax.lax.map(mv, V.T).T

            self.matmat = matmat
        if self.matvec is None:
            mm = self.matmat
            self.matvec = lambda v: mm(v[:, None])[:, 0]

    def stats_snapshot(self) -> dict:
        return dict(self.stats() if callable(self.stats) else self.stats)

    def reset_stats(self) -> None:
        """Restore live backend counters to their post-build baseline
        (no-op for backends without one)."""
        if self.reset is not None:
            self.reset()

    def unpermute(self, values: jax.Array) -> jax.Array:
        """Per-(padded-)row values -> original point order, padding dropped."""
        if self.schedule is not None:
            return values[jnp.asarray(self.schedule.inv_perm)][: self.n]
        return values[: self.n]

    def materialize(self, block: int = 128) -> jax.Array:
        """Dense A — exact if the backend provided ``dense``, else assembled
        through ``matmat`` applied to identity column blocks (small-n
        fallback).  Blocks keep the working set bounded for streaming
        backends while still amortizing each matrix pass over ``block``
        columns."""
        if self.dense is not None:
            return self.dense()
        eye = jnp.eye(self.n_pad, dtype=self.valid.dtype)
        cols = [self.matmat(eye[:, c0: c0 + block])
                for c0 in range(0, self.n_pad, block)]
        return jnp.concatenate(cols, axis=1)
