"""Assigner backends: phase 3 (k-means on the spectral embedding) as
pluggable strategies.

Signature:

    backend(est, Y, valid, key, mesh) -> (labels_pad, centers)

``Y`` is the row-normalized (n_pad, k) embedding, row-sharded over the
mesh and still in the affinity backend's row order; ``labels_pad`` must
match that order (the estimator unpermutes).

Backends:
  lloyd      full distributed Lloyd (paper §4.3.3 MapReduce rounds).
  minibatch  Sculley-style mini-batch Lloyd — O(batch) per round instead
             of O(n); the large-n assigner.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import kmeans as km
from repro.cluster.registry import Registry

ASSIGNERS = Registry("assigner")


@ASSIGNERS.register("lloyd")
def lloyd_assigner(est, Y, valid, key, mesh):
    labels_pad, state = km.distributed_kmeans(
        Y, valid, est.k, key, mesh, iters=est.kmeans_iters)
    return labels_pad, state.centers


@ASSIGNERS.register("minibatch")
def minibatch_assigner(est, Y, valid, key, mesh):
    return km.minibatch_kmeans(jnp.asarray(Y), valid, est.k, key,
                               iters=est.kmeans_iters,
                               batch=est.minibatch_size)
