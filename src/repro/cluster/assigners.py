"""Assigner backends: phase 3 (k-means on the spectral embedding) as
pluggable strategies.

Signature:

    backend(est, Y, valid, key, mesh) -> (labels_pad, centers)

``Y`` is the row-normalized (n_pad, k) embedding, row-sharded over the
mesh and still in the affinity backend's row order; ``labels_pad`` must
match that order (the estimator unpermutes).

Backends:
  lloyd      full distributed Lloyd (paper §4.3.3 MapReduce rounds).
  minibatch  Sculley-style mini-batch Lloyd — O(batch) per round instead
             of O(n); the large-n assigner.
  streaming  the engine's chunked mini-batch Lloyd: consumes embedding
             rows chunk by chunk (one chunk = one mini-batch round), the
             phase-3 pairing for the out-of-core ``ooc-topt`` affinity.
"""
from __future__ import annotations

import jax.numpy as jnp
import numpy as np

from repro.cluster.registry import Registry
from repro.core import kmeans as km

ASSIGNERS = Registry("assigner")


@ASSIGNERS.register("lloyd")
def lloyd_assigner(est, Y, valid, key, mesh):
    # seeding happens inside distributed_kmeans via the one shared
    # D^2 sampler, core.seeding.kmeans_plusplus_init
    labels_pad, state = km.distributed_kmeans(
        Y, valid, est.k, key, mesh, iters=est.kmeans_iters)
    return labels_pad, state.centers


@ASSIGNERS.register("minibatch")
def minibatch_assigner(est, Y, valid, key, mesh):
    return km.minibatch_kmeans(jnp.asarray(Y), valid, est.k, key,
                               iters=est.kmeans_iters,
                               batch=est.minibatch_size)


@ASSIGNERS.register("streaming")
def streaming_assigner(est, Y, valid, key, mesh):
    from repro.data.chunked import chunk_ranges
    from repro.engine import streaming_kmeans

    Yh = np.asarray(Y, np.float64)
    vh = np.asarray(valid, np.float64)
    ranges = chunk_ranges(Yh.shape[0], est.chunk_size or 4096)
    labels, centers = streaming_kmeans(
        lambda c: Yh[ranges[c][0]:ranges[c][1]], len(ranges), est.k,
        rounds=est.kmeans_iters, seed=est.seed,
        valid_chunk=lambda c: vh[ranges[c][0]:ranges[c][1]])
    return jnp.asarray(labels), jnp.asarray(centers, Y.dtype)
