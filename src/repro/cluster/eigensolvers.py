"""Eigensolver backends: phase 2 as pluggable strategies.

Signature:

    backend(est, op, key) -> (eigenvalues, Z, info)

``op`` is a :class:`~repro.cluster.operator.NormalizedOperator`;
``eigenvalues`` are the k smallest of L_sym (ascending) and ``Z`` the
matching (n_pad, k) eigenvector columns (unit norm), still in the
operator's (possibly permuted) row order.

Backends:
  lanczos  shifted Lanczos with full reorthogonalization — the paper's
           Alg. 4.3, distributed through ``op.matvec``.
  eigh     exact dense eigendecomposition of the materialized operator —
           the oracle, O(n^3), for tests / small n.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.core import lanczos as lz
from repro.cluster.registry import Registry

EIGENSOLVERS = Registry("eigensolver")

_SHIFT = 2.0  # A = shift*I - L_sym; see core.laplacian docstring


@EIGENSOLVERS.register("lanczos")
def lanczos_solver(est, op, key):
    steps = est.num_lanczos_steps(op.n)
    state = lz.lanczos(op.matvec, op.n_pad, steps, key, dtype=est.dtype)
    evals, Z = lz.topk_of_shifted(state, est.k, shift=_SHIFT)
    return evals, Z, {"lanczos_steps": steps}


@EIGENSOLVERS.register("eigh")
def eigh_solver(est, op, key):
    A = op.materialize()
    evals_A, evecs = jnp.linalg.eigh(A)  # ascending
    k = est.k
    # Largest of A are the smallest of L_sym; padding rows sit at A's
    # spectrum floor (eigenvalue 0) and never reach the top-k.
    Z = evecs[:, -k:][:, ::-1]
    vals = (_SHIFT - evals_A[-k:])[::-1]
    return vals, Z, {"solver": "eigh"}
