"""Eigensolver backends: phase 2 as pluggable strategies.

Signature:

    backend(est, op, key) -> (eigenvalues, Z, info)

``op`` is a :class:`~repro.cluster.operator.NormalizedOperator`;
``eigenvalues`` are the k smallest of L_sym (ascending) and ``Z`` the
matching (n_pad, k) eigenvector columns (unit norm), still in the
operator's (possibly permuted) row order.  Every backend reports
``info["matrix_passes"]`` — full sweeps over the similarity matrix
(one ``matmat`` of any width = one pass), the distributed cost unit of
the paper's §4.3 hot spot.

Backends:
  lanczos        shifted single-vector Lanczos with full
                 reorthogonalization — the paper's Alg. 4.3, distributed
                 through ``op.matvec``; one matrix pass per step.
  block-lanczos  the block-tridiagonal recurrence through ``op.matmat``:
                 the same Krylov dimension in ~1/b the matrix passes
                 (each pass amortized over the b-wide block).
  chebdav        block Chebyshev–Davidson (Pang & Yang 2022): degree-d
                 Chebyshev filtering of the current Ritz block between
                 Rayleigh–Ritz steps; ``est.block_size`` and
                 ``est.cheb_degree`` control the block width and filter
                 degree.
  eigh           exact dense eigendecomposition of the materialized
                 operator — the oracle, O(n^3), for tests / small n.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.cluster.registry import Registry
from repro.core import chebdav as cd, lanczos as lz

EIGENSOLVERS = Registry("eigensolver")

_SHIFT = 2.0  # A = shift*I - L_sym; see core.laplacian docstring


@EIGENSOLVERS.register("lanczos")
def lanczos_solver(est, op, key):
    steps = est.num_lanczos_steps(op.n)
    state = lz.lanczos(op.matvec, op.n_pad, steps, key, dtype=est.dtype,
                       host_matmat=getattr(op, "host_matmat", None))
    evals, Z = lz.topk_of_shifted(state, est.k, shift=_SHIFT)
    return evals, Z, {"lanczos_steps": steps, "matrix_passes": steps}


@EIGENSOLVERS.register("block-lanczos")
def block_lanczos_solver(est, op, key):
    b = est.num_block_size(op.n)       # same n as the step count below,
    steps = est.num_block_steps(op.n)  # so width and steps stay consistent
    state = lz.block_lanczos(op.matmat, op.n_pad, steps, key,
                             block_size=b, dtype=est.dtype,
                             host_matmat=getattr(op, "host_matmat", None))
    evals, Z = lz.block_topk_of_shifted(state, est.k, shift=_SHIFT)
    return evals, Z, {"block_size": b, "block_steps": steps,
                      "matrix_passes": steps}


@EIGENSOLVERS.register("chebdav")
def chebdav_solver(est, op, key):
    b = est.num_block_size(op.n)
    res = cd.chebdav(op.matmat, op.n_pad, est.k, key, block_size=b,
                     degree=est.cheb_degree, valid=op.valid,
                     dtype=est.dtype)
    # res.evals are the largest of A, descending <-> smallest of L ascending
    vals = _SHIFT - res.evals
    return vals, res.evecs, {
        "block_size": b, "cheb_degree": est.cheb_degree,
        "chebdav_iters": res.iters, "matrix_passes": res.passes,
        "max_residual": res.max_residual}


@EIGENSOLVERS.register("eigh")
def eigh_solver(est, op, key):
    A = op.materialize()
    evals_A, evecs = jnp.linalg.eigh(A)  # ascending
    k = est.k
    # Largest of A are the smallest of L_sym; padding rows sit at A's
    # spectrum floor (eigenvalue 0) and never reach the top-k.
    Z = evecs[:, -k:][:, ::-1]
    vals = (_SHIFT - evals_A[-k:])[::-1]
    # Pass accounting for cross-solver comparability (the benchmark
    # sweep): the O(n^3) dense factorization sweeps the n_pad-row matrix
    # ~n_pad times — the iterative solvers' cost unit applied to eigh.
    return vals, Z, {"solver": "eigh", "matrix_passes": int(op.n_pad)}
