"""Serving-side out-of-sample extension: the fused Nystrom transform.

``SpectralClustering.transform`` embeds m new points into the fitted
spectral space via the Nystrom extension

    z(x) = D_new^{-1/2} K(x, X_train) D_train^{-1/2} Z / mu

The straightforward implementation materializes the (m, n) query-vs-train
RBF kernel — O(m*n) memory, which undoes everything the fused-rbf affinity
bought at fit time the moment the model is served against real traffic.
This module provides the matrix-free path: one pass of the dual-output
Pallas kernel (:func:`repro.kernels.ops.fused_nystrom_matmat`) streams
(bm, d) query tiles against (bn, d) training tiles, builds the RBF entries
in-register, and accumulates BOTH ``K @ (D_train^{-1/2} Z)`` and the query
degree column ``K @ 1`` — so transform/predict memory is
O((m + n)·d + n·k) and the kernel matrix never exists.

Routing mirrors :func:`repro.engine.plan.route_path`: the dense path is
kept for small problems (one jnp matmul beats a tiled interpret-mode
kernel there), the fused path takes over once the (m, n) kernel would
outgrow the budget.  On a multi-device mesh the fused pass row-shards the
QUERIES via ``shard_map`` — each device embeds its own query stripe
against the replicated training set, no collective needed.
"""
from __future__ import annotations

from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.core import kmeans as km, laplacian as lp
from repro.distrib import mesh_utils

TRANSFORM_PATHS = ("auto", "dense", "fused")


class RequestRejected(RuntimeError):
    """Base class for typed serving admission-control rejections (the
    load-shedding contract of ``launch.cluster_serve.ClusterServer``)."""
    status = "rejected"


class QueueFullError(RequestRejected):
    """Admission denied: accepting the request would push the pending-row
    backlog past the server's bounded admission queue."""
    status = "shed"

    def __init__(self, rid: int, rows: int, pending_rows: int,
                 max_pending_rows: int):
        super().__init__(
            f"request {rid} shed: {rows} rows would push the pending "
            f"backlog ({pending_rows} rows) past the admission bound "
            f"({max_pending_rows} rows)")
        self.rid = rid
        self.rows = rows
        self.pending_rows = pending_rows
        self.max_pending_rows = max_pending_rows


class DeadlineExceededError(RequestRejected):
    """An admitted request sat past its deadline before completing; its
    remaining rows are dropped from the batch window."""
    status = "expired"

    def __init__(self, rid: int, deadline_s: float, waited_s: float):
        super().__init__(f"request {rid} expired: waited {waited_s:.3f}s "
                         f"against a {deadline_s:g}s deadline")
        self.rid = rid
        self.deadline_s = deadline_s
        self.waited_s = waited_s

# default ceiling on the materialized (m, n) query-vs-train kernel when the
# estimator carries no memory_budget: 64 MiB ~= the m = n = 4096 f32 kernel
# (same spirit as engine.route_path, which routes on the dense similarity)
DENSE_TRANSFORM_MAX_BYTES = 64 * 1024 * 1024


def check_transform_path(path: str) -> str:
    if path not in TRANSFORM_PATHS:
        raise ValueError(f"transform_path must be one of {TRANSFORM_PATHS}, "
                         f"got {path!r}")
    return path


def route_transform(n: int, m: int, *, path: str = "auto",
                    memory_budget: Optional[int] = None,
                    itemsize: int = 4) -> str:
    """Pick the transform path for m queries against n training points.

    A forced ``path`` ("dense" / "fused") wins.  With ``path="auto"`` the
    materialized (m, n) kernel's bytes decide: under the budget (the
    estimator's ``memory_budget``, else :data:`DENSE_TRANSFORM_MAX_BYTES`)
    the dense path is kept — one jnp matmul, no tiling overhead; over it,
    the fused kernel streams the training tiles instead.  Both paths
    compute the same extension (fused-vs-dense parity is a test contract,
    <= 1e-4 in f32)."""
    check_transform_path(path)
    if path != "auto":
        return path
    budget = memory_budget if memory_budget is not None \
        else DENSE_TRANSFORM_MAX_BYTES
    return "dense" if m * n * itemsize <= budget else "fused"


def transform_tile(n: int) -> int:
    """MXU-aligned tile side for the serving kernel — the one fit-side
    rule, shared so retuning it can never split the two paths."""
    from repro.kernels.fused_rbf_matmat import default_tile
    return default_tile(n)


def transform_peak_bytes(m: int, n: int, d: int, k: int, *,
                         tile: Optional[int] = None, mesh_size: int = 1,
                         itemsize: int = 4) -> int:
    """Working-set model of one fused transform: padded queries + training
    points + the (n, k) eigenvector block + the (m, k+1) outputs + scale
    columns, plus the VMEM tiles — compare against the dense path's
    ``m * n * itemsize`` kernel matrix.  ``mesh_size`` matters: on a mesh
    the queries pad to a multiple of ``mesh_size * tile`` (every device's
    stripe must divide the row tile), exactly like ``fused_transform``."""
    t = tile or transform_tile(max(m, n))
    m_pad = mesh_utils.pad_to_multiple(m, max(1, mesh_size) * t)
    n_pad = mesh_utils.pad_to_multiple(n, t)
    host = (m_pad * d + n_pad * d + n_pad * (k + 2) + m_pad * (k + 1)) \
        * itemsize
    vmem = (2 * t * d + t * t + t * (k + 3)) * itemsize
    return host + vmem


def extension_from_product(O: jax.Array, deg: jax.Array,
                           mu: jax.Array) -> jax.Array:
    """Finish the Nystrom extension from the fused pass outputs: apply the
    query-side D^{-1/2} (zero-degree queries — points far from every
    training point — pin to the all-zero row instead of NaN), divide by
    the operator eigenvalues, unit-normalize rows."""
    inv_new = lp.masked_inv_sqrt(deg)
    emb = (inv_new[:, None] * O) / mu[None, :]
    return km.normalize_rows(emb)


def shifted_mu(eigenvalues: jax.Array) -> jax.Array:
    """Eigenvalues of the normalized similarity N = D^{-1/2} S D^{-1/2}
    from the stored L_sym eigenvalues, clamped away from zero (shared by
    the dense and fused transform paths)."""
    mu = 1.0 - eigenvalues
    return jnp.where(jnp.abs(mu) > 1e-6, mu, 1e-6)


def fused_transform(x: jax.Array, train_x: jax.Array, eigvecs: jax.Array,
                    inv_sqrt: jax.Array, sigma, mu: jax.Array, *,
                    mesh: Any = None, compute_dtype=None,
                    interpret: bool | None = None, schedule=None,
                    _cache: Optional[dict] = None,
                    _info: Optional[dict] = None) -> jax.Array:
    """Matrix-free Nystrom embedding of ``x`` (m, d) -> (m, k).

    Single-device: one padded call of the dual-output kernel.  Multi-
    device: queries are row-sharded over the mesh via ``shard_map`` and
    each device streams the replicated training set against its own query
    stripe — output rows are disjoint, so there is no collective at all
    (the fit-side fused pass needs one psum because there the OPERATOR
    rows are sharded; here the query rows are).

    ``_cache`` (optional dict) memoizes the jitted sharded pass per
    (mesh, shape) key so a serving loop pays one trace, not one per batch.

    ``schedule`` (None / "default" / "auto" / Schedule / dict) selects the
    serving kernel's tiles/dtype/accumulator; "auto" consults the
    persistent schedule cache for this shape bucket and device.
    """
    from repro.kernels import fused_rbf_matmat as frm
    from repro.kernels import ops as kops
    from repro.tune.schedule import resolve

    mesh = mesh or mesh_utils.local_mesh("rows")
    m, d = int(x.shape[0]), int(x.shape[1])
    n, k = int(eigvecs.shape[0]), int(eigvecs.shape[1])
    tile = transform_tile(max(m, n))
    msize = mesh_utils.mesh_size(mesh)
    sigma32 = jnp.asarray(sigma, jnp.float32)
    sched, _src = resolve("fused_nystrom_matmat", schedule, bm=tile,
                          bn=tile, compute_dtype=compute_dtype,
                          interpret=interpret, n=n, m=m, d=d, b=k)
    if _info is not None:   # caller-visible record of what actually ran
        _info["schedule"] = sched.to_dict()
        _info["schedule_source"] = _src

    if msize == 1:
        O, deg = kops.fused_nystrom_matmat(
            x, train_x, eigvecs, sigma32, inv_sqrt, None, schedule=sched)
        return extension_from_product(O, deg, mu)

    axes = mesh_utils.flat_axes(mesh)
    # queries pad to (mesh x row tile) so every device's stripe divides the
    # row tile; training-side padding is column-tile-only (replicated)
    m_pad = mesh_utils.pad_to_multiple(m, msize * sched.bm)
    n_pad = mesh_utils.pad_to_multiple(n, sched.bn)
    cdtype = frm.resolve_compute_dtype(sched.compute_dtype)

    key = ("nystrom", mesh, m_pad, n_pad, d, k, sched.bm, sched.bn,
           jnp.dtype(cdtype).name, sched.acc, sched.interpret)
    fn = _cache.get(key) if _cache is not None else None
    if fn is None:
        def body(xq_local, y_full, Z_full, cs_full, cv_full, sig):
            return frm.fused_nystrom_matmat(
                xq_local, y_full, Z_full, sig, cs_full[:, 0], cv_full[:, 0],
                bm=sched.bm, bn=sched.bn, compute_dtype=cdtype,
                acc=sched.acc, interpret=sched.interpret)

        fn = jax.jit(mesh_utils.shard_map(
            body, mesh=mesh,
            in_specs=(P(axes, None), P(), P(), P(), P(), P()),
            out_specs=(P(axes, None), P(axes, None))))
        if _cache is not None:
            _cache[key] = fn

    xq = jnp.zeros((m_pad, d), jnp.float32).at[:m].set(
        jnp.asarray(x, jnp.float32))
    yp = jnp.zeros((n_pad, d), jnp.float32).at[:n].set(
        jnp.asarray(train_x, jnp.float32))
    Zp = jnp.zeros((n_pad, k), jnp.float32).at[:n].set(
        jnp.asarray(eigvecs, jnp.float32))
    cs = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(
        jnp.asarray(inv_sqrt, jnp.float32))
    cv = jnp.zeros((n_pad, 1), jnp.float32).at[:n, 0].set(1.0)
    O, deg = fn(xq, yp, Zp, cs, cv, sigma32)
    return extension_from_product(O[:m], deg[:m, 0], mu)
