# Unified pluggable estimator API for the paper's pipeline: one
# SpectralClustering entry point, three backend registries (affinity,
# eigensolver, assigner) meeting at the NormalizedOperator interface.
# See API.md at the repo root for the backend protocols.
from repro.cluster import serving
from repro.cluster.affinity import AFFINITIES
from repro.cluster.assigners import ASSIGNERS
from repro.cluster.eigensolvers import EIGENSOLVERS
from repro.cluster.estimator import SpectralClustering
from repro.cluster.metrics import ari, nmi, purity
from repro.cluster.operator import NormalizedOperator, SpectralResult
from repro.cluster.registry import Registry

__all__ = [
    "AFFINITIES",
    "ASSIGNERS",
    "EIGENSOLVERS",
    "NormalizedOperator",
    "Registry",
    "SpectralClustering",
    "SpectralResult",
    "ari",
    "nmi",
    "purity",
    "serving",
]
