"""Clustering agreement metrics — the paper's §5 experiment measures.

All three compare a predicted labeling against a reference labeling
through their contingency table; none assumes the label ids line up
(clustering is only defined up to permutation):

  ari     adjusted Rand index — pair-counting agreement, chance-corrected
          (1 = identical partitions, ~0 = random, can go negative).
  nmi     normalized mutual information, arithmetic-mean normalization
          (sklearn's default), in [0, 1].
  purity  each predicted cluster votes its majority reference class;
          fraction of points covered by the votes, in (0, 1].

Pure numpy on (n,) integer label vectors; label values need not be
contiguous or aligned between the two vectors.
"""
from __future__ import annotations

import numpy as np


def contingency(labels_a: np.ndarray, labels_b: np.ndarray) -> np.ndarray:
    """Contingency table C[i, j] = #points with a-label i and b-label j."""
    a = np.asarray(labels_a).ravel()
    b = np.asarray(labels_b).ravel()
    if a.shape != b.shape:
        raise ValueError(
            f"label vectors differ in length: {a.shape} vs {b.shape}")
    _, ai = np.unique(a, return_inverse=True)
    _, bi = np.unique(b, return_inverse=True)
    na, nb = ai.max() + 1, bi.max() + 1
    return np.bincount(ai * nb + bi, minlength=na * nb).reshape(na, nb)


def ari(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """Adjusted Rand index (Hubert & Arabie 1985)."""
    C = contingency(labels_true, labels_pred).astype(np.float64)
    n = C.sum()
    sum_comb = (C * (C - 1) / 2).sum()
    a = C.sum(axis=1)
    b = C.sum(axis=0)
    comb_a = (a * (a - 1) / 2).sum()
    comb_b = (b * (b - 1) / 2).sum()
    total = n * (n - 1) / 2
    expected = comb_a * comb_b / total if total else 0.0
    max_index = (comb_a + comb_b) / 2
    if max_index == expected:          # both partitions trivial -> perfect
        return 1.0
    return float((sum_comb - expected) / (max_index - expected))


def nmi(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """Normalized mutual information, arithmetic-mean normalization."""
    C = contingency(labels_true, labels_pred).astype(np.float64)
    n = C.sum()
    pa = C.sum(axis=1) / n
    pb = C.sum(axis=0) / n
    nz = C > 0
    pab = C / n
    outer = pa[:, None] * pb[None, :]
    mi = float((pab[nz] * np.log(pab[nz] / outer[nz])).sum())
    ha = float(-(pa[pa > 0] * np.log(pa[pa > 0])).sum())
    hb = float(-(pb[pb > 0] * np.log(pb[pb > 0])).sum())
    denom = (ha + hb) / 2
    if denom <= 0:                     # both partitions trivial
        return 1.0
    return max(0.0, min(1.0, mi / denom))


def purity(labels_true: np.ndarray, labels_pred: np.ndarray) -> float:
    """Fraction of points in their predicted cluster's majority true class."""
    C = contingency(labels_true, labels_pred)
    return float(C.max(axis=0).sum() / C.sum())
