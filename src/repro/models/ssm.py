"""Mamba2 (SSD) blocks and the Zamba2 hybrid stack.

SSD is structured linear attention: per head, state S += dt * (B x^T) with
scalar decay exp(-exp(A_log) dt); readout y = C . S.  We reuse the chunked
machinery in ``recurrent.py`` (q=C, k=B, v=dt*x, log_a=-exp(A_log)*dt).

Zamba2: ``num_layers`` Mamba2 blocks; after every ``shared_attn_every``
blocks, ONE weight-shared (attention + MLP) block is applied (Zamba's
shared-block design; we omit its per-invocation LoRA deltas — DESIGN.md §2).
Each invocation keeps its own KV cache.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as ll, recurrent as rec
from repro.models.config import ModelConfig
from repro.models.params import Spec


def _dims(cfg: ModelConfig):
    di = 2 * cfg.d_model
    hd = 64
    H = di // hd
    N = cfg.ssm_state or 64
    return di, H, hd, N


def mamba_specs(cfg: ModelConfig, lead: tuple[int, ...], lead_axes) -> dict:
    di, H, hd, N = _dims(cfg)
    D = cfg.d_model
    pd = cfg.param_dtype
    proj_out = di + di + 2 * N + H   # z, x, B, C, dt

    def s(shape, axes, **kw):
        return Spec(lead + shape, lead_axes + axes, pd, **kw)

    return {
        "ln": s((D,), ("embed",), init="zeros"),
        "in_proj": s((D, proj_out), ("embed", "mlp")),
        "conv": s((4, di + 2 * N), (None, "mlp"), init="normal", scale=0.1),
        "A_log": s((H,), ("heads",), init="zeros"),
        "dt_bias": s((H,), ("heads",), init="zeros"),
        "D_skip": s((H,), ("heads",), init="ones"),
        "ln_out": s((di,), ("mlp",), init="zeros"),
        "out_proj": s((di, D), ("mlp", "embed")),
    }


def _split_proj(proj, cfg):
    di, H, hd, N = _dims(cfg)
    z = proj[..., :di]
    xin = proj[..., di:2 * di]
    Bv = proj[..., 2 * di:2 * di + N]
    Cv = proj[..., 2 * di + N:2 * di + 2 * N]
    dt = proj[..., 2 * di + 2 * N:]
    return z, xin, Bv, Cv, dt


def _gates(dt, lp):
    dt = jax.nn.softplus(dt.astype(jnp.float32) + lp["dt_bias"].astype(jnp.float32))
    A = -jnp.exp(lp["A_log"].astype(jnp.float32))
    log_a = A * dt                       # (..., H), <= 0
    log_i = jnp.log(jnp.maximum(dt, 1e-9))
    return dt, log_a, log_i


def mamba_block(x, lp, cfg: ModelConfig, state=None, chunk=256):
    """x (B,S,D) -> (y, (conv_tail, S_mat)). SSD chunked form."""
    di, H, hd, N = _dims(cfg)
    B, S, D = x.shape
    h = ll.rms_norm(x, lp["ln"], cfg.norm_eps)
    proj = jnp.einsum("bsd,df->bsf", h, lp["in_proj"].astype(x.dtype))
    z, xin, Bv, Cv, dt = _split_proj(proj, cfg)
    conv_in = jnp.concatenate([xin, Bv, Cv], axis=-1)
    if state is not None:
        conv_tail, S0 = state
        conv_in_eff = jnp.concatenate([conv_tail, conv_in], axis=1)
    else:
        conv_tail = None
        S0 = jnp.zeros((B, H, N, hd), jnp.float32)
        conv_in_eff = conv_in
    K = lp["conv"].shape[0]
    cp = jnp.pad(conv_in_eff, ((0, 0), (K - 1 if state is None else 0, 0), (0, 0)))
    conv_out = sum(cp[:, i:i + S] * lp["conv"].astype(x.dtype)[i][None, None]
                   for i in range(K))
    conv_out = jax.nn.silu(conv_out)
    xc = conv_out[..., :di].reshape(B, S, H, hd)
    Bc = conv_out[..., di:di + N]
    Cc = conv_out[..., di + N:]
    dt_f, log_a, log_i = _gates(dt, lp)
    # per-head: q=C (shared across heads), k=B, v=x
    q = jnp.broadcast_to(Cc[:, None], (B, H, S, N)).astype(x.dtype)
    k = jnp.broadcast_to(Bc[:, None], (B, H, S, N)).astype(x.dtype)
    v = xc.transpose(0, 2, 1, 3)                                   # (B,H,S,hd)
    y, S_f, _ = rec.chunked_linear_attention(
        q, k, v, log_a.transpose(0, 2, 1), log_i.transpose(0, 2, 1),
        S0, chunk=min(chunk, S), normalize=False)
    y = y + lp["D_skip"].astype(jnp.float32)[None, :, None, None] * v.astype(jnp.float32)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di).astype(x.dtype)
    y = ll.rms_norm(y, lp["ln_out"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, lp["out_proj"].astype(x.dtype))
    new_tail = conv_in_eff[:, -(K - 1):]
    return x + out, (new_tail, S_f)


def mamba_decode(x, lp, cfg: ModelConfig, state):
    """One-token decode; state = (conv_tail (B,K-1,C), S_mat (B,H,N,hd))."""
    y, (new_tail, S_f) = mamba_block(x, lp, cfg, state=state, chunk=1)
    return y, (new_tail, S_f)


# ---------------------------------------------------------------------------
# stacks
# ---------------------------------------------------------------------------

def _zamba_layout(cfg: ModelConfig):
    period = cfg.shared_attn_every or cfg.num_layers
    assert cfg.num_layers % period == 0
    G = cfg.num_layers // period
    return G, period


def specs(cfg: ModelConfig) -> dict:
    """Pure Mamba2 stack, or Zamba2 hybrid when shared_attn_every > 0."""
    tree = {
        "embed": ll.embed_spec(cfg),
        "final_norm": ll.norm_spec(cfg.d_model, cfg.param_dtype),
    }
    if cfg.shared_attn_every:
        G, period = _zamba_layout(cfg)
        tree["mamba"] = mamba_specs(cfg, (G, period), ("layers", "layers"))
        shared = {
            "ln1": ll.norm_spec(cfg.d_model, cfg.param_dtype),
            "attn": ll.attention_specs(cfg),
            "ln2": ll.norm_spec(cfg.d_model, cfg.param_dtype),
            "mlp": ll.mlp_specs(cfg),
        }
        tree["shared"] = shared
    else:
        tree["mamba"] = mamba_specs(cfg, (cfg.num_layers,), ("layers",))
    return tree


def forward(params, batch, cfg: ModelConfig):
    x = ll.embed(batch["tokens"], params["embed"], cfg.compute_dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def mstep(x, lp):
        y, _ = mamba_block(x, lp, cfg)
        return y, None

    if cfg.shared_attn_every:
        shared = params["shared"]

        def group(x, gp):
            x, _ = lax.scan(mstep, x, gp)
            h = ll.rms_norm(x, shared["ln1"], cfg.norm_eps)
            x = x + ll.gqa_attention(h, shared["attn"], cfg, -1, positions)
            h = ll.rms_norm(x, shared["ln2"], cfg.norm_eps)
            x = x + ll.mlp(h, shared["mlp"], cfg)
            return x, None

        x, _ = lax.scan(group, x, params["mamba"])
    else:
        x, _ = lax.scan(mstep, x, params["mamba"])
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x, params["embed"]).astype(jnp.float32)
    return logits, {"lb_loss": jnp.zeros((), jnp.float32)}


def cache_specs(cfg: ModelConfig, batch_size: int, max_seq: int) -> dict:
    di, H, hd, N = _dims(cfg)
    f32 = jnp.float32
    conv_c = di + 2 * N
    if cfg.shared_attn_every:
        G, period = _zamba_layout(cfg)
        lead, la = (G, period), ("layers", "layers")
    else:
        lead, la = (cfg.num_layers,), ("layers",)
    tree = {
        "conv": Spec(lead + (batch_size, 3, conv_c), la + (None, None, "mlp"), f32, init="zeros"),
        "S": Spec(lead + (batch_size, H, N, hd), la + (None, "heads", None, "head_dim"), f32, init="zeros"),
        "pos": Spec((), (), jnp.int32, init="zeros"),
    }
    if cfg.shared_attn_every:
        G, _ = _zamba_layout(cfg)
        kv, ahd = cfg.num_kv_heads, cfg.hd()
        kvs = ("layers", None, "seq", "kv_heads", "head_dim")
        tree["shared_k"] = Spec((G, batch_size, max_seq, kv, ahd), kvs,
                                cfg.compute_dtype, init="zeros")
        tree["shared_v"] = Spec((G, batch_size, max_seq, kv, ahd), kvs,
                                cfg.compute_dtype, init="zeros")
    return tree


def prefill(params, batch, cfg: ModelConfig, max_seq: int | None = None):
    """Run the prompt, return (last-token logits, state cache)."""
    x = ll.embed(batch["tokens"], params["embed"], cfg.compute_dtype)
    B, S = x.shape[:2]
    max_seq = max_seq or S
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def mstep(x, lp):
        y, (tail, S_f) = mamba_block(x, lp, cfg)
        return y, (tail.astype(jnp.float32), S_f)

    if cfg.shared_attn_every:
        shared = params["shared"]

        def group(x, gp):
            x, (tail, S_f) = lax.scan(mstep, x, gp)
            h = ll.rms_norm(x, shared["ln1"], cfg.norm_eps)
            out, k, v = ll.gqa_attention(h, shared["attn"], cfg, -1, positions,
                                         return_kv=True)
            x = x + out
            h = ll.rms_norm(x, shared["ln2"], cfg.norm_eps)
            x = x + ll.mlp(h, shared["mlp"], cfg)
            pad = max_seq - S
            kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cfg.compute_dtype)
            vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0))).astype(cfg.compute_dtype)
            return x, (tail, S_f, kc, vc)

        x, (tail, S_f, k_all, v_all) = lax.scan(group, x, params["mamba"])
        cache = {"conv": tail, "S": S_f, "shared_k": k_all, "shared_v": v_all,
                 "pos": jnp.asarray(S, jnp.int32)}
    else:
        x, (tail, S_f) = lax.scan(mstep, x, params["mamba"])
        cache = {"conv": tail, "S": S_f, "pos": jnp.asarray(S, jnp.int32)}
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x[:, -1:], params["embed"]).astype(jnp.float32)
    return logits, cache


def decode_step(params, cache, token, cfg: ModelConfig):
    x = ll.embed(token, params["embed"], cfg.compute_dtype)
    pos = cache["pos"]

    def mstep(x, lxs):
        lp, conv, S0 = lxs
        y, (conv2, S2) = mamba_decode(x, lp, cfg, (conv, S0))
        return y, (conv2, S2)

    if cfg.shared_attn_every:
        shared = params["shared"]

        def group(x, gxs):
            gp, conv, S0, kc, vc = gxs
            x, (conv2, S2) = lax.scan(mstep, x, (gp, conv, S0))
            h = ll.rms_norm(x, shared["ln1"], cfg.norm_eps)
            out, kc, vc = ll.gqa_decode(h, shared["attn"], cfg, -1, kc, vc, pos)
            x = x + out
            h = ll.rms_norm(x, shared["ln2"], cfg.norm_eps)
            x = x + ll.mlp(h, shared["mlp"], cfg)
            return x, (conv2, S2, kc, vc)

        x, (conv_n, S_n, k_n, v_n) = lax.scan(
            group, x, (params["mamba"], cache["conv"], cache["S"],
                       cache["shared_k"], cache["shared_v"]))
        new_cache = {"conv": conv_n, "S": S_n, "shared_k": k_n,
                     "shared_v": v_n, "pos": pos + 1}
    else:
        x, (conv_n, S_n) = lax.scan(mstep, x, (params["mamba"], cache["conv"], cache["S"]))
        new_cache = {"conv": conv_n, "S": S_n, "pos": pos + 1}
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x, params["embed"]).astype(jnp.float32)
    return logits, new_cache
