"""Unified model interface: ``build(cfg)`` -> Model with spec/forward/loss/
prefill/decode, dispatching on the architecture family."""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

from repro.models import encdec, params as pp, ssm, transformer, xlstm
from repro.models.config import ModelConfig

_FAMILIES = {
    "dense": transformer,
    "moe": transformer,
    "vlm": transformer,
    "xlstm": xlstm,
    "hybrid": ssm,
    "ssm": ssm,
    "audio": encdec,
    "encdec": encdec,
}


def cross_entropy(logits: jax.Array, targets: jax.Array,
                  mask: jax.Array | None = None, z_weight: float = 1e-4):
    """Next-token CE with z-loss; logits (B,S,V) f32, targets (B,S)."""
    logits = logits[:, :-1]
    targets = targets[:, 1:]
    lse = jax.nn.logsumexp(logits, axis=-1)
    gold = jnp.take_along_axis(logits, targets[..., None], axis=-1)[..., 0]
    nll = lse - gold
    zl = z_weight * lse**2
    if mask is None:
        mask = jnp.ones_like(nll)
    else:
        mask = mask[:, 1:].astype(nll.dtype)
    denom = jnp.maximum(jnp.sum(mask), 1.0)
    return jnp.sum((nll + zl) * mask) / denom


@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ModelConfig
    spec: Any                                   # param Spec pytree
    forward: Callable                           # (params, batch) -> (logits, aux)
    prefill: Callable                           # (params, batch, max_seq) -> (logits, cache)
    decode_step: Callable                       # (params, cache, token) -> (logits, cache)
    cache_specs: Callable                       # (batch, max_seq) -> Spec pytree

    def loss_fn(self, params, batch):
        logits, aux = self.forward(params, batch)
        loss = cross_entropy(logits, batch["tokens"], batch.get("mask"))
        if self.cfg.num_experts:
            loss = loss + 1e-2 * aux["lb_loss"]
        return loss, aux

    def init(self, key: jax.Array):
        return pp.init_params(self.spec, key)

    def abstract_params(self):
        return pp.abstract_params(self.spec)

    def num_params(self) -> int:
        return pp.count_params(self.spec)

    def num_active_params(self) -> int:
        """Parameters touched per token (MoE: top_k + shared experts only)."""
        cfg = self.cfg
        if not cfg.num_experts:
            return self.num_params()
        total = self.num_params()
        per_expert = cfg.d_model * cfg.expert_d_ff * 3
        inactive = (cfg.num_experts - cfg.top_k) * per_expert * cfg.num_layers
        return int(total - inactive)


def build(cfg: ModelConfig) -> Model:
    mod = _FAMILIES[cfg.family]
    return Model(
        cfg=cfg,
        spec=mod.specs(cfg),
        forward=lambda p, b: mod.forward(p, b, cfg),
        prefill=lambda p, b, max_seq=None: mod.prefill(p, b, cfg, max_seq=max_seq),
        decode_step=lambda p, c, t: mod.decode_step(p, c, t, cfg),
        cache_specs=lambda bs, max_seq: mod.cache_specs(cfg, bs, max_seq),
    )
