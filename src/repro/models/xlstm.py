"""xLSTM stack (arXiv:2405.04517 backbone): mLSTM blocks with periodic
sLSTM blocks (xLSTM[7:1] style), built on the shared chunked linear
recurrence in ``recurrent.py``.

Layout: ``num_layers`` = G groups x ``xlstm_slstm_every`` layers; the last
layer of each group is an sLSTM, the rest are mLSTMs.  Params are stacked
(G, per-group) and double-scanned so the HLO holds one mLSTM + one sLSTM
body.  d_ff == 0 in the assigned config: blocks carry their own up/down
projections (pf=2), no separate FFN.

State is O(1) in sequence length, so ``long_500k`` decode is exercised for
this family (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as ll, recurrent as rec
from repro.models.config import ModelConfig
from repro.models.params import Spec


def _dims(cfg: ModelConfig):
    di = 2 * cfg.d_model           # pf = 2 up-projection
    H = cfg.num_heads
    hd = di // H
    return di, H, hd


def _groups(cfg: ModelConfig):
    every = cfg.xlstm_slstm_every or cfg.num_layers + 1
    if cfg.num_layers % every == 0 and cfg.xlstm_slstm_every:
        G = cfg.num_layers // every
        n_m = every - 1
    else:  # no sLSTM layers
        G, n_m = 1, cfg.num_layers
    return G, n_m


def specs(cfg: ModelConfig) -> dict:
    di, H, hd = _dims(cfg)
    D = cfg.d_model
    pd = cfg.param_dtype
    G, n_m = _groups(cfg)
    has_s = cfg.xlstm_slstm_every and cfg.num_layers % cfg.xlstm_slstm_every == 0

    def stk(shape, axes, **kw):
        return Spec((G, n_m) + shape, ("layers", "layers") + axes, pd, **kw)

    mlstm = {
        "ln": stk((D,), ("embed",), init="zeros"),
        "wu": stk((D, 2, di), ("embed", None, "mlp")),
        "conv": stk((4, di), (None, "mlp"), init="normal", scale=0.1),
        # block-diagonal per-head projections (xLSTM's design): (H, hd, hd)
        "wq": stk((H, hd, hd), ("heads", None, "head_dim")),
        "wk": stk((H, hd, hd), ("heads", None, "head_dim")),
        "wv": stk((H, hd, hd), ("heads", None, "head_dim")),
        "wgate": stk((D, 2, H), ("embed", None, "heads"), init="normal", scale=0.02),
        "gbias": stk((2, H), (None, "heads"), init="ones"),
        "ln_out": stk((di,), ("mlp",), init="zeros"),
        "wd": stk((di, D), ("mlp", "embed")),
    }
    tree = {
        "embed": ll.embed_spec(cfg),
        "final_norm": ll.norm_spec(D, pd),
        "mlstm": mlstm,
    }
    if has_s:
        def sts(shape, axes, **kw):
            return Spec((G,) + shape, ("layers",) + axes, pd, **kw)
        tree["slstm"] = {
            "ln": sts((D,), ("embed",), init="zeros"),
            "wzifo": sts((D, 4, D), ("embed", None, "mlp")),
            "ln_out": sts((D,), ("embed",), init="zeros"),
            "wd": sts((D, D), ("mlp", "embed")),
        }
    return tree


def _mlstm_qkv_gates(x, lp, cfg):
    """Shared by train and decode: projections + gate logs."""
    di, H, hd = _dims(cfg)
    gu = jnp.einsum("bsd,dcf->bscf", x, lp["wu"].astype(x.dtype))
    inner, z = gu[:, :, 0], gu[:, :, 1]
    return inner, z


def _causal_conv(x, w):
    """Depthwise causal conv: x (B,S,C), w (K,C)."""
    K = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (K - 1, 0), (0, 0)))
    out = sum(xp[:, i:i + x.shape[1]] * w[i][None, None, :] for i in range(K))
    return jax.nn.silu(out)


def mlstm_block(x, lp, cfg: ModelConfig, state=None, chunk=256):
    """x (B,S,D) -> (y, (S_mat, n, conv_tail)). state: (S_mat (B,H,hd,hd), n (B,H,hd))."""
    di, H, hd = _dims(cfg)
    B, S, D = x.shape
    h = ll.rms_norm(x, lp["ln"], cfg.norm_eps)
    inner, z = _mlstm_qkv_gates(h, lp, cfg)
    cx = _causal_conv(inner, lp["conv"].astype(x.dtype))
    cxh = cx.reshape(B, S, H, hd)
    innh = inner.reshape(B, S, H, hd)
    q = jnp.einsum("bshf,hfk->bhsk", cxh, lp["wq"].astype(x.dtype)) / (hd ** 0.5)
    k = jnp.einsum("bshf,hfk->bhsk", cxh, lp["wk"].astype(x.dtype)) / (hd ** 0.5)
    v = jnp.einsum("bshf,hfk->bhsk", innh, lp["wv"].astype(x.dtype))
    gates = jnp.einsum("bsd,dch->bsch", h, lp["wgate"].astype(x.dtype)) \
        + lp["gbias"].astype(x.dtype)[None, None]
    i_log = gates[:, :, 0].transpose(0, 2, 1).astype(jnp.float32)     # (B,H,S)
    f_log = gates[:, :, 1].transpose(0, 2, 1).astype(jnp.float32)
    log_a = -jax.nn.softplus(-f_log)     # log sigmoid(f)
    if state is None:
        S0 = jnp.zeros((B, H, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, H, hd), jnp.float32)
    else:
        S0, n0 = state
    y, S_f, n_f = rec.chunked_linear_attention(
        q, k, v, log_a, i_log, S0, n0, chunk=min(chunk, S), normalize=True)
    y = y.transpose(0, 2, 1, 3).reshape(B, S, di)
    y = ll.rms_norm(y, lp["ln_out"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, lp["wd"].astype(x.dtype))
    conv_tail = inner[:, -3:].astype(jnp.float32)     # K-1 = 3 for decode conv
    return x + out, (S_f, n_f, conv_tail)


def mlstm_decode(x, lp, cfg: ModelConfig, state):
    """One-token decode. x (B,1,D)."""
    di, H, hd = _dims(cfg)
    B = x.shape[0]
    h = ll.rms_norm(x, lp["ln"], cfg.norm_eps)
    inner, z = _mlstm_qkv_gates(h, lp, cfg)
    conv_state, S0, n0 = state                    # conv_state (B, K-1, di)
    K = lp["conv"].shape[0]
    window = jnp.concatenate([conv_state, inner], axis=1)          # (B,K,di)
    cx = jax.nn.silu(jnp.einsum("bkf,kf->bf", window, lp["conv"].astype(x.dtype)))
    cxh = cx.reshape(B, H, hd)
    innh = inner[:, 0].reshape(B, H, hd)
    q = jnp.einsum("bhf,hfk->bhk", cxh, lp["wq"].astype(x.dtype)) / (hd ** 0.5)
    kk = jnp.einsum("bhf,hfk->bhk", cxh, lp["wk"].astype(x.dtype)) / (hd ** 0.5)
    vv = jnp.einsum("bhf,hfk->bhk", innh, lp["wv"].astype(x.dtype))
    gates = jnp.einsum("bd,dch->bch", h[:, 0], lp["wgate"].astype(x.dtype)) \
        + lp["gbias"].astype(x.dtype)[None]
    i_log = gates[:, 0].astype(jnp.float32)
    log_a = -jax.nn.softplus(-gates[:, 1].astype(jnp.float32))
    y, S_f, n_f = rec.recurrent_step(q, kk, vv, log_a, i_log, S0, n0, normalize=True)
    y = y.reshape(B, 1, di).astype(x.dtype)
    y = ll.rms_norm(y, lp["ln_out"], cfg.norm_eps) * jax.nn.silu(z)
    out = jnp.einsum("bsf,fd->bsd", y, lp["wd"].astype(x.dtype))
    new_conv = jnp.concatenate([conv_state[:, 1:], inner], axis=1)
    return x + out, (new_conv, S_f, n_f)


def slstm_block(x, lp, cfg: ModelConfig, state=None):
    B, S, D = x.shape
    h = ll.rms_norm(x, lp["ln"], cfg.norm_eps)
    zifo = jnp.einsum("bsd,dcf->bscf", h, lp["wzifo"].astype(x.dtype))
    z, i_g, f_g, o_g = (zifo[:, :, j] for j in range(4))
    hidden, new_state = rec.slstm_scan(jnp.tanh(z), i_g, f_g, o_g, state0=state)
    hidden = ll.rms_norm(hidden, lp["ln_out"], cfg.norm_eps)
    return x + jnp.einsum("bsf,fd->bsd", hidden, lp["wd"].astype(x.dtype)), new_state


def forward(params, batch, cfg: ModelConfig):
    x = ll.embed(batch["tokens"], params["embed"], cfg.compute_dtype)
    G, n_m = _groups(cfg)
    has_s = "slstm" in params

    def group(x, gp):
        mp = gp["mlstm"]

        def mstep(x, lp):
            y, _ = mlstm_block(x, lp, cfg)
            return y, None

        x, _ = lax.scan(mstep, x, mp)
        if has_s:
            x, _ = slstm_block(x, gp["slstm"], cfg)
        return x, None

    gxs = {"mlstm": params["mlstm"]}
    if has_s:
        gxs["slstm"] = params["slstm"]
    x, _ = lax.scan(group, x, gxs)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x, params["embed"]).astype(jnp.float32)
    return logits, {"lb_loss": jnp.zeros((), jnp.float32)}


def cache_specs(cfg: ModelConfig, batch_size: int, max_seq: int) -> dict:
    del max_seq  # state is O(1) in sequence length
    di, H, hd = _dims(cfg)
    G, n_m = _groups(cfg)
    f32 = jnp.float32
    tree = {
        "conv": Spec((G, n_m, batch_size, 3, di), ("layers", "layers", None, None, "mlp"), f32, init="zeros"),
        "S": Spec((G, n_m, batch_size, H, hd, hd), ("layers", "layers", None, "heads", None, "head_dim"), f32, init="zeros"),
        "n": Spec((G, n_m, batch_size, H, hd), ("layers", "layers", None, "heads", "head_dim"), f32, init="zeros"),
        "pos": Spec((), (), jnp.int32, init="zeros"),
    }
    if cfg.xlstm_slstm_every and cfg.num_layers % cfg.xlstm_slstm_every == 0:
        D = cfg.d_model
        tree["slstm_c"] = Spec((G, batch_size, D), ("layers", None, "embed"), f32, init="zeros")
        tree["slstm_n"] = Spec((G, batch_size, D), ("layers", None, "embed"), f32, init="zeros")
    return tree


def prefill(params, batch, cfg: ModelConfig, max_seq: int | None = None):
    """Run the prompt, return (last-token logits, recurrent-state cache).
    The cache is O(1) in sequence length — no KV growth (the point of the
    long_500k cell for this family)."""
    del max_seq
    x = ll.embed(batch["tokens"], params["embed"], cfg.compute_dtype)
    S = x.shape[1]
    has_s = "slstm" in params

    def group(x, gp):
        def mstep(x, lp):
            y, (S_f, n_f, tail) = mlstm_block(x, lp, cfg)
            return y, {"S": S_f, "n": n_f, "conv": tail}

        x, mcache = lax.scan(mstep, x, gp["mlstm"])
        out = dict(mcache)
        if has_s:
            x, (c2, n2) = slstm_block(x, gp["slstm"], cfg)
            out["slstm_c"] = c2
            out["slstm_n"] = n2
        return x, out

    gxs = {"mlstm": params["mlstm"]}
    if has_s:
        gxs["slstm"] = params["slstm"]
    x, cache = lax.scan(group, x, gxs)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x[:, -1:], params["embed"]).astype(jnp.float32)
    cache["pos"] = jnp.asarray(S, jnp.int32)
    return logits, cache


def decode_step(params, cache, token, cfg: ModelConfig):
    x = ll.embed(token, params["embed"], cfg.compute_dtype)
    has_s = "slstm" in params

    def group(x, xs):
        gp = xs

        def mstep(carry, lxs):
            x = carry
            lp, conv, S0, n0 = lxs["p"], lxs["conv"], lxs["S"], lxs["n"]
            y, (conv2, S2, n2) = mlstm_decode(x, lp, cfg, (conv, S0, n0))
            return y, {"conv": conv2, "S": S2, "n": n2}

        x, mcache = lax.scan(
            mstep, x, {"p": gp["mlstm"], "conv": gp["conv"], "S": gp["S"], "n": gp["n"]})
        out_cache = dict(mcache)
        if has_s:
            y, (c2, n2) = slstm_block(x, gp["slstm"], cfg,
                                      state=(gp["slstm_c"], gp["slstm_n"]))
            x = y
            out_cache["slstm_c"] = c2
            out_cache["slstm_n"] = n2
        return x, out_cache

    gxs = {"mlstm": params["mlstm"], "conv": cache["conv"], "S": cache["S"], "n": cache["n"]}
    if has_s:
        gxs.update(slstm=params["slstm"], slstm_c=cache["slstm_c"], slstm_n=cache["slstm_n"])
    x, new_cache = lax.scan(group, x, gxs)
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x, params["embed"]).astype(jnp.float32)
    out = {"conv": new_cache["conv"], "S": new_cache["S"], "n": new_cache["n"],
           "pos": cache["pos"] + 1}
    if has_s:
        out["slstm_c"] = new_cache["slstm_c"]
        out["slstm_n"] = new_cache["slstm_n"]
    return logits, out
