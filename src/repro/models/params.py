"""Lightweight parameter-spec system: shapes + logical axes -> init /
abstract (ShapeDtypeStruct) / NamedSharding trees.

Every model module builds a pytree of :class:`Spec`; the launcher turns it
into real arrays (smoke tests), abstract stand-ins (dry-run) or shardings
(pjit in/out specs).  Logical-axis -> mesh-axis rules live in
``distrib/sharding.py`` and are overridable per architecture config.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Mapping

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import Mesh, NamedSharding, PartitionSpec


@dataclasses.dataclass(frozen=True)
class Spec:
    shape: tuple[int, ...]
    axes: tuple[str | None, ...]          # logical axis names per dim
    dtype: Any = jnp.float32
    init: str = "fan_in"                   # fan_in | zeros | ones | normal
    scale: float | None = None

    def __post_init__(self):
        assert len(self.shape) == len(self.axes), (self.shape, self.axes)


def is_spec(x) -> bool:
    return isinstance(x, Spec)


def _init_one(spec: Spec, key: jax.Array) -> jax.Array:
    if spec.init == "zeros":
        return jnp.zeros(spec.shape, spec.dtype)
    if spec.init == "ones":
        return jnp.ones(spec.shape, spec.dtype)
    if spec.init == "normal":
        s = spec.scale if spec.scale is not None else 0.02
    else:  # fan_in
        fan = spec.shape[0] if spec.shape else 1
        s = spec.scale if spec.scale is not None else 1.0 / np.sqrt(max(fan, 1))
    return (jax.random.normal(key, spec.shape, jnp.float32) * s).astype(spec.dtype)


def init_params(tree, key: jax.Array):
    leaves, treedef = jax.tree.flatten(tree, is_leaf=is_spec)
    keys = jax.random.split(key, len(leaves))
    vals = [_init_one(l, k) if is_spec(l) else l for l, k in zip(leaves, keys)]
    return jax.tree.unflatten(treedef, vals)


def abstract_params(tree):
    """ShapeDtypeStruct tree for the dry-run (no allocation)."""
    return jax.tree.map(
        lambda s: jax.ShapeDtypeStruct(s.shape, s.dtype),
        tree, is_leaf=is_spec)


def partition_spec(spec: Spec, rules: Mapping[str, str | None],
                   mesh: Mesh) -> PartitionSpec:
    """Map logical axes to mesh axes.  Skips non-divisible dims, and each
    mesh axis is used at most once per spec (first dim wins — e.g. MoE
    expert weights shard over experts, not also over mlp)."""
    entries = []
    used: set[str] = set()
    for dim, ax in zip(spec.shape, spec.axes):
        mesh_ax = rules.get(ax) if ax is not None else None
        if mesh_ax is None:
            entries.append(None)
            continue
        axes_tuple = mesh_ax if isinstance(mesh_ax, tuple) else (mesh_ax,)
        if any(a in used for a in axes_tuple):
            entries.append(None)
            continue
        size = np.prod([mesh.shape[a] for a in axes_tuple])
        if dim % size == 0:
            entries.append(mesh_ax)
            used.update(axes_tuple)
        else:
            entries.append(None)
    return PartitionSpec(*entries)


def sharding_tree(tree, mesh: Mesh, rules: Mapping[str, str | None]):
    return jax.tree.map(
        lambda s: NamedSharding(mesh, partition_spec(s, rules, mesh)),
        tree, is_leaf=is_spec)


def count_params(tree) -> int:
    leaves = jax.tree.leaves(tree, is_leaf=is_spec)
    return int(sum(np.prod(l.shape) for l in leaves))
