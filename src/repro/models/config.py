"""Architecture configuration shared by every model family."""
from __future__ import annotations

import dataclasses
from typing import Any

import jax.numpy as jnp


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    name: str = "model"
    family: str = "dense"     # dense | moe | xlstm | hybrid | encdec | vlm | audio

    # transformer trunk
    num_layers: int = 2
    d_model: int = 128
    num_heads: int = 4
    num_kv_heads: int = 4
    head_dim: int | None = None          # default: d_model // num_heads
    d_ff: int = 512
    vocab_size: int = 1024
    qkv_bias: bool = False               # qwen1.5 style
    tie_embeddings: bool = True
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    act: str = "silu"                    # silu (SwiGLU) | gelu (GeGLU)

    # attention pattern: window per layer; -1 = global.  ``local_ratio``:
    # n local layers then 1 global (gemma3 5:1); 0 = all global;
    # -1 = every layer local (mixtral SWA).
    local_window: int = -1
    local_ratio: int = 0

    # MoE
    num_experts: int = 0
    top_k: int = 0
    expert_d_ff: int = 0
    num_shared_experts: int = 0
    capacity_factor: float = 1.25

    # SSM / xLSTM / hybrid
    ssm_state: int = 0                   # mamba2 state dim per head
    conv_kernel: int = 4
    xlstm_slstm_every: int = 0           # 1 sLSTM per this many mLSTM (0 = none)
    shared_attn_every: int = 0           # zamba2: shared attn block period

    # enc-dec
    encoder_layers: int = 0              # >0 selects encoder-decoder

    # modality frontend stub: "none" = token ids; "embed" = precomputed
    # frame/patch embeddings (B, S, d_model) from input_specs()
    frontend: str = "none"

    # numerics
    param_dtype: Any = jnp.float32
    compute_dtype: Any = jnp.bfloat16

    # serving
    attn_chunk: int = 1024               # online-softmax KV chunk for long seq
    dense_attn_max_seq: int = 8192       # below this, plain dense attention
    # materialize attention score tiles in bf16 (flash-style kernels keep
    # them in VMEM; this is the XLA-graph analogue: halves HBM traffic of
    # the mask/softmax passes, f32 running max/denominator retained)
    attn_scores_bf16: bool = False
    # use the Pallas flash-attention kernel (kernels/flash_attention.py)
    # for full-sequence attention. TPU-targeted; on CPU it runs in
    # interpret mode (slow — tests only). Scores never touch HBM.
    use_flash_attention: bool = False

    # training
    remat: str = "dots"                  # none | dots | full
    optimizer: str = "adamw"             # adamw | adafactor
    shard_opt_over_data: bool = False    # ZeRO-1 over the data axis
    fsdp_params: bool = False            # ZeRO-3: params also shard over data
                                         # (XLA all-gathers per-layer at use)
    microbatches: int = 1                # grad-accumulation steps per batch
                                         # (divides activation memory)

    # sharding rule overrides (logical axis -> mesh axis name)
    sharding_overrides: dict | None = None
    # named sharding presets (perf variants; see distrib/sharding.py):
    #   ""              - default TP/EP rules
    #   "replicate_attn"- attention weights replicated (indivisible heads)
    #   "sp_serve"      - sequence parallelism: activations shard seq over
    #                     "model", weights replicated (except embed/vocab)
    sharding_preset: str = ""
    # preset applied to prefill/decode lowering only (training keeps the
    # TP rules; serving of small models prefers SP — EXPERIMENTS.md §Perf)
    serve_sharding_preset: str = ""
    # MoE execution: "gather" (GSPMD resolves dispatch) or "ep_shard_map"
    # (explicit replicated-dispatch expert parallelism, psum combine)
    moe_impl: str = "gather"

    def hd(self) -> int:
        return self.head_dim if self.head_dim is not None else self.d_model // self.num_heads

    def window_for_layer(self, i: int) -> int:
        if self.local_window <= 0:
            return -1
        if self.local_ratio == -1:            # every layer windowed (SWA)
            return self.local_window
        if self.local_ratio <= 0:
            return -1
        # pattern: `local_ratio` local layers, then 1 global
        return self.local_window if (i + 1) % (self.local_ratio + 1) != 0 else -1

    def windows(self) -> list[int]:
        return [self.window_for_layer(i) for i in range(self.num_layers)]

    def with_(self, **kw) -> "ModelConfig":
        return dataclasses.replace(self, **kw)


@dataclasses.dataclass(frozen=True)
class ShapeCell:
    """One assigned (shape) cell: what gets lowered in the dry-run."""
    name: str                 # train_4k | prefill_32k | decode_32k | long_500k
    kind: str                 # train | prefill | decode
    seq_len: int
    global_batch: int


SHAPES = (
    ShapeCell("train_4k", "train", 4_096, 256),
    ShapeCell("prefill_32k", "prefill", 32_768, 32),
    ShapeCell("decode_32k", "decode", 32_768, 128),
    ShapeCell("long_500k", "decode", 524_288, 1),
)

SHAPES_BY_NAME = {s.name: s for s in SHAPES}
