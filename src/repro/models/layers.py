"""Core transformer layers: norms, RoPE, GQA attention (dense / chunked
online-softmax / decode-with-KV-cache), gated MLPs, embeddings.

All functions are pure: params are pytrees built from ``params.Spec`` trees.
Logical sharding axes used here: embed, heads, kv_heads, head_dim, mlp,
vocab, layers.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models.config import ModelConfig
from repro.models.params import Spec

NEG_INF = -1e30


# ---------------------------------------------------------------------------
# norms / embeddings
# ---------------------------------------------------------------------------

def rms_norm(x: jax.Array, w: jax.Array, eps: float = 1e-6) -> jax.Array:
    dt = x.dtype
    x = x.astype(jnp.float32)
    x = x * jax.lax.rsqrt(jnp.mean(x * x, axis=-1, keepdims=True) + eps)
    return (x * (1.0 + w.astype(jnp.float32))).astype(dt)


def norm_spec(d: int, dtype) -> Spec:
    return Spec((d,), ("embed",), dtype, init="zeros")


def embed_spec(cfg: ModelConfig) -> Spec:
    return Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                cfg.param_dtype, init="normal", scale=0.02)


def embed(tokens: jax.Array, table: jax.Array, compute_dtype) -> jax.Array:
    return table.astype(compute_dtype)[tokens]


def unembed(x: jax.Array, table: jax.Array) -> jax.Array:
    return jnp.einsum("bsd,vd->bsv", x, table.astype(x.dtype))


# ---------------------------------------------------------------------------
# RoPE
# ---------------------------------------------------------------------------

def _rope_freqs(hd: int, theta: float, dtype=jnp.float32) -> jax.Array:
    return 1.0 / (theta ** (jnp.arange(0, hd, 2, dtype=dtype) / hd))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """x: (..., S, n, hd); positions: (..., S) int."""
    hd = x.shape[-1]
    freqs = _rope_freqs(hd, theta)
    ang = positions[..., None].astype(jnp.float32) * freqs      # (..., S, hd/2)
    cos = jnp.cos(ang)[..., None, :]
    sin = jnp.sin(ang)[..., None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x1 * sin + x2 * cos], axis=-1)
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# GQA attention
# ---------------------------------------------------------------------------

def attention_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    hd = cfg.hd()
    L = (layers,) if layers is not None else ()
    lax_ = ("layers",) if layers is not None else ()
    pd = cfg.param_dtype
    spec = {
        "wq": Spec(L + (cfg.d_model, cfg.num_heads, hd),
                   lax_ + ("embed", "heads", "head_dim"), pd),
        "wk": Spec(L + (cfg.d_model, cfg.num_kv_heads, hd),
                   lax_ + ("embed", "kv_heads", "head_dim"), pd),
        "wv": Spec(L + (cfg.d_model, cfg.num_kv_heads, hd),
                   lax_ + ("embed", "kv_heads", "head_dim"), pd),
        "wo": Spec(L + (cfg.num_heads, hd, cfg.d_model),
                   lax_ + ("heads", "head_dim", "embed"), pd),
    }
    if cfg.qkv_bias:
        spec["bq"] = Spec(L + (cfg.num_heads, hd), lax_ + ("heads", "head_dim"), pd, init="zeros")
        spec["bk"] = Spec(L + (cfg.num_kv_heads, hd), lax_ + ("kv_heads", "head_dim"), pd, init="zeros")
        spec["bv"] = Spec(L + (cfg.num_kv_heads, hd), lax_ + ("kv_heads", "head_dim"), pd, init="zeros")
    return spec


def _qkv(x: jax.Array, p: dict, cfg: ModelConfig, positions: jax.Array):
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"].astype(x.dtype))
    if "bq" in p:
        q = q + p["bq"].astype(x.dtype)
        k = k + p["bk"].astype(x.dtype)
        v = v + p["bv"].astype(x.dtype)
    q = apply_rope(q, positions, cfg.rope_theta)
    k = apply_rope(k, positions, cfg.rope_theta)
    return q, k, v


def _mask(q_pos: jax.Array, k_pos: jax.Array, window, causal: bool) -> jax.Array:
    """(..., S_q, S_k) additive mask. window: -1/traced; causal: static."""
    dq = q_pos[..., :, None]
    dk = k_pos[..., None, :]
    ok = jnp.ones(jnp.broadcast_shapes(dq.shape, dk.shape), jnp.bool_)
    if causal:
        ok = ok & (dk <= dq)
    window = jnp.asarray(window)
    ok = ok & jnp.where(window > 0, dq - dk < window, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(jnp.float32)


def _sdpa_dense(q, k, v, mask, scale):
    """q: (b,s,h,hd) k/v: (b,t,kv,hd) grouped; mask (b or 1, s, t)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    qg = q.reshape(b, s, kv, g, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    scores = scores + mask[:, None, None, :, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v)
    return out.reshape(b, s, h, hd)


def _sdpa_kv_chunked(q, k, v, q_pos, k_pos, window, causal, scale,
                     k_chunk: int, scores_bf16: bool = False):
    """Online-softmax over KV chunks with q kept whole.  Used under
    sequence parallelism: q rows are sharded over the model axis (so the
    per-device q extent is small), and scanning over a *sharded* q axis
    would force re-replication; k/v are small and pre-replicated."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    t = k.shape[1]
    k_chunk = min(k_chunk, t)
    assert t % k_chunk == 0
    nk = t // k_chunk
    qg = q.reshape(b, s, kv, g, hd)
    kc = k.reshape(b, nk, k_chunk, kv, hd)
    vc = v.reshape(b, nk, k_chunk, kv, hd)
    kp = k_pos.reshape(k_pos.shape[0], nk, k_chunk)

    m0 = jnp.full((b, kv, g, s), NEG_INF, jnp.float32)
    l0 = jnp.zeros((b, kv, g, s), jnp.float32)
    a0 = jnp.zeros((b, kv, g, s, hd), jnp.float32)

    sc_dt = jnp.bfloat16 if scores_bf16 else jnp.float32

    def kv_step(acc, ki):
        m, l, a = acc
        kblk, vblk, kpos = ki
        sc = jax.lax.dot_general(
            qg, kblk, ((( 4,), (3,)), ((0, 2), (0, 2))),
            preferred_element_type=sc_dt)            # (b,kv,s,g? ...)
        # dot_general with batch dims (b, kv): result (b, kv, s, g, t)
        sc = jnp.transpose(sc, (0, 1, 3, 2, 4)) * jnp.asarray(scale, sc_dt)
        mask = _mask(q_pos, kpos, window, causal)[:, None, None].astype(sc_dt)
        sc = sc + mask
        m_new = jnp.maximum(m, jnp.max(sc, axis=-1).astype(jnp.float32))
        p = jnp.exp(sc.astype(jnp.float32) - m_new[..., None]).astype(sc_dt)
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=-1).astype(jnp.float32)
        a_new = a * corr[..., None] + jnp.einsum(
            "bkgqt,btkh->bkgqh", p.astype(q.dtype), vblk)
        return (m_new, l_new, a_new), None

    (m, l, a), _ = lax.scan(
        kv_step, (m0, l0, a0),
        (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(kp, 1, 0)))
    out = a / jnp.maximum(l[..., None], 1e-30)
    out = jnp.transpose(out, (0, 3, 1, 2, 4)).reshape(b, s, h, hd)
    return out.astype(q.dtype)


def _sdpa_chunked(q, k, v, q_pos, k_pos, window, causal, scale,
                  q_chunk: int, k_chunk: int):
    """Online-softmax attention, scanning KV chunks inside a q-chunk scan.
    Keeps peak memory at O(q_chunk * k_chunk) per (batch, head) instead of
    O(S^2). FLOPs are unchanged (masked tiles still computed)."""
    b, s, h, hd = q.shape
    kv = k.shape[2]
    g = h // kv
    t = k.shape[1]
    q_chunk = min(q_chunk, s)
    k_chunk = min(k_chunk, t)
    assert s % q_chunk == 0 and t % k_chunk == 0, (s, t, q_chunk, k_chunk)
    nq, nk = s // q_chunk, t // k_chunk

    qg = q.reshape(b, nq, q_chunk, kv, g, hd)
    qp = q_pos.reshape(q_pos.shape[0], nq, q_chunk)
    kc = k.reshape(b, nk, k_chunk, kv, hd)
    vc = v.reshape(b, nk, k_chunk, kv, hd)
    kp = k_pos.reshape(k_pos.shape[0], nk, k_chunk)

    def q_step(carry, qi):
        qblk, qpos = qi              # (b,qc,kv,g,hd), (b*,qc)
        m0 = jnp.full((b, kv, g, q_chunk), NEG_INF, jnp.float32)
        l0 = jnp.zeros((b, kv, g, q_chunk), jnp.float32)
        a0 = jnp.zeros((b, kv, g, q_chunk, hd), jnp.float32)

        def kv_step(acc, ki):
            m, l, a = acc
            kblk, vblk, kpos = ki
            sc = jnp.einsum("bqkgh,btkh->bkgqt", qblk, kblk).astype(jnp.float32) * scale
            sc = sc + _mask(qpos, kpos, window, causal)[:, None, None]
            m_new = jnp.maximum(m, jnp.max(sc, axis=-1))
            p = jnp.exp(sc - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + jnp.sum(p, axis=-1)
            a_new = a * corr[..., None] + jnp.einsum(
                "bkgqt,btkh->bkgqh", p.astype(qblk.dtype), vblk)
            return (m_new, l_new, a_new), None

        (m, l, a), _ = lax.scan(
            kv_step, (m0, l0, a0),
            (jnp.moveaxis(kc, 1, 0), jnp.moveaxis(vc, 1, 0), jnp.moveaxis(kp, 1, 0)),
            unroll=1)
        out = a / jnp.maximum(l[..., None], 1e-30)
        out = jnp.transpose(out, (0, 3, 1, 2, 4))        # (b,qc,kv,g,hd)
        return carry, out.astype(qblk.dtype)

    _, outs = lax.scan(q_step, None, (jnp.moveaxis(qg, 1, 0), jnp.moveaxis(qp, 1, 0)))
    out = jnp.moveaxis(outs, 0, 1).reshape(b, s, h, hd)
    return out


def gqa_attention(x: jax.Array, p: dict, cfg: ModelConfig, window,
                  positions: jax.Array, return_kv: bool = False):
    """Full-sequence (train / prefill) GQA attention with causal + window mask."""
    scale = 1.0 / (cfg.hd() ** 0.5)
    q, k, v = _qkv(x, p, cfg, positions)
    s = x.shape[1]
    if cfg.use_flash_attention:
        # Pallas fused kernel: scores stay in VMEM (EXPERIMENTS.md §Perf).
        # window must be static here: only all-local (-1 ratio) or
        # all-global patterns route through the kernel.
        from repro.kernels import ops as kops
        win = cfg.local_window if cfg.local_ratio == -1 else -1
        out = kops.flash_attention(
            jnp.moveaxis(q, 2, 1), jnp.moveaxis(k, 2, 1), jnp.moveaxis(v, 2, 1),
            causal=True, window=win)
        out = jnp.moveaxis(out, 1, 2)
    elif s <= cfg.dense_attn_max_seq:
        mask = _mask(positions, positions, window, causal=True)
        out = _sdpa_dense(q, k, v, mask, scale)
    elif cfg.sharding_preset == "sp_serve":
        # sequence parallelism: q stays sharded over "model"; k/v are
        # replicated once per layer (they are small next to scores)
        from repro.distrib import act_sharding
        k = act_sharding.replicate_seq(k, cfg)
        v = act_sharding.replicate_seq(v, cfg)
        out = _sdpa_kv_chunked(q, k, v, positions, positions, window, True,
                               scale, k_chunk=cfg.attn_chunk,
                               scores_bf16=cfg.attn_scores_bf16)
    else:
        out = _sdpa_chunked(q, k, v, positions, positions, window, True, scale,
                            q_chunk=cfg.attn_chunk, k_chunk=cfg.attn_chunk)
    out = jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))
    if return_kv:
        return out, k, v
    return out


def gqa_decode(x: jax.Array, p: dict, cfg: ModelConfig, window,
               k_cache: jax.Array, v_cache: jax.Array, pos: jax.Array):
    """One-token decode: x (b,1,d); cache (b,S,kv,hd); pos scalar int32.
    Returns (out (b,1,d), k_cache, v_cache) with the new KV written at pos."""
    scale = 1.0 / (cfg.hd() ** 0.5)
    positions = jnp.broadcast_to(pos, (x.shape[0], 1))
    q, k, v = _qkv(x, p, cfg, positions)
    k_cache = lax.dynamic_update_slice(k_cache, k.astype(k_cache.dtype), (0, pos, 0, 0))
    v_cache = lax.dynamic_update_slice(v_cache, v.astype(v_cache.dtype), (0, pos, 0, 0))
    b, S, kv, hd = k_cache.shape
    h = q.shape[2]
    g = h // kv
    qg = q.reshape(b, 1, kv, g, hd)
    k_pos = jnp.arange(S)[None, :]
    valid = (k_pos <= pos)
    win = jnp.asarray(window)
    valid = valid & jnp.where(win > 0, pos - k_pos < win, True)
    mask = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
    scores = jnp.einsum("bqkgh,btkh->bkgqt", qg, k_cache.astype(q.dtype))
    scores = scores.astype(jnp.float32) * scale + mask[:, None, None, None, :]
    w = jax.nn.softmax(scores, axis=-1).astype(q.dtype)
    out = jnp.einsum("bkgqt,btkh->bqkgh", w, v_cache.astype(q.dtype))
    out = out.reshape(b, 1, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype)), k_cache, v_cache


# ---------------------------------------------------------------------------
# gated MLP
# ---------------------------------------------------------------------------

def mlp_specs(cfg: ModelConfig, layers: int | None = None,
              d_ff: int | None = None) -> dict:
    d_ff = d_ff or cfg.d_ff
    L = (layers,) if layers is not None else ()
    lax_ = ("layers",) if layers is not None else ()
    pd = cfg.param_dtype
    return {
        "wi": Spec(L + (cfg.d_model, 2, d_ff), lax_ + ("embed", None, "mlp"), pd),
        "wo": Spec(L + (d_ff, cfg.d_model), lax_ + ("mlp", "embed"), pd),
    }


def _act(name: str):
    return {"silu": jax.nn.silu, "gelu": jax.nn.gelu}[name]


def mlp(x: jax.Array, p: dict, cfg: ModelConfig) -> jax.Array:
    gu = jnp.einsum("bsd,dcf->bscf", x, p["wi"].astype(x.dtype))
    h = _act(cfg.act)(gu[:, :, 0]) * gu[:, :, 1]
    return jnp.einsum("bsf,fd->bsd", h, p["wo"].astype(x.dtype))
