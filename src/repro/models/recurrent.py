"""Chunked linear-recurrence machinery shared by mLSTM (xLSTM) and SSD
(Mamba2): both maintain a matrix state S_t = a_t * S_{t-1} + i_t * k_t v_t^T
and read y_t = q_t . S_t (mLSTM adds a normalizer state n_t).

Training/prefill uses the chunk-parallel form: within a chunk the quadratic
(C x C) masked-decay attention runs on the MXU; between chunks only the
(hd_k x hd_v) state is carried — O(S) total, sub-quadratic, which is what
makes the ``long_500k`` cells feasible for the SSM/hybrid architectures.

Decode is the O(1) recurrent update.  Stabilization: per-chunk max-shift of
the log-gates (a simplification of the xLSTM running-max stabilizer —
recorded in DESIGN.md).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

NEG_INF = -1e30


def chunked_linear_attention(q, k, v, log_a, log_i, state0, norm0=None,
                             chunk: int = 256, normalize: bool = False):
    """q,k,v: (B, H, S, hd_k/hd_k/hd_v); log_a/log_i: (B, H, S) decay and
    input-gate logs (log_a <= 0).  Returns (y (B,H,S,hd_v), state, norm).

    y_t = q_t^T [ sum_{u<=t} (prod_{w=u+1..t} a_w) i_u k_u v_u^T  + (prod a) S_0 ]
    """
    B, H, S, dk = q.shape
    dv = v.shape[-1]
    chunk = min(chunk, S)
    assert S % chunk == 0, (S, chunk)
    n = S // chunk

    def resh(x):
        return x.reshape(x.shape[0], x.shape[1], n, chunk, *x.shape[3:])

    qc, kc, vc = resh(q), resh(k), resh(v)
    lac = log_a.reshape(B, H, n, chunk)
    lic = log_i.reshape(B, H, n, chunk)

    def step(carry, xs):
        S_prev, n_prev = carry
        qb, kb, vb, la, li = xs                 # (B,H,C,*) / (B,H,C)
        cum = jnp.cumsum(la, axis=-1)           # inclusive prefix log-decay
        total = cum[..., -1:]                   # (B,H,1)

        # intra-chunk: D[s,t] = exp(cum[s]-cum[t]+li[t]) for t<=s
        ds = cum[..., :, None] - cum[..., None, :] + li[..., None, :]
        tri = jnp.tril(jnp.ones((chunk, chunk), bool))
        ds = jnp.where(tri, ds, NEG_INF)
        # stabilize the exp with a per-row max shift
        m = jnp.maximum(jnp.max(ds, axis=-1, keepdims=True), -30.0)
        D = jnp.exp(ds - m)                                        # (B,H,C,C)
        scores = jnp.einsum("bhsk,bhtk->bhst", qb.astype(jnp.float32),
                            kb.astype(jnp.float32)) * D
        y_intra = jnp.einsum("bhst,bhtv->bhsv", scores, vb.astype(jnp.float32))
        # inter-chunk: q_s * exp(cum[s]) @ S_prev  (same max shift)
        w_inter = jnp.exp(cum[..., :, None] - m)                   # (B,H,C,1)
        y_inter = jnp.einsum("bhsk,bhkv->bhsv", qb.astype(jnp.float32) * w_inter,
                             S_prev)
        y = (y_intra + y_inter) * jnp.exp(m)                       # undo shift

        if normalize:
            # normalizer rows: n_s = sum_t D[s,t] k_t  (+ decayed carry-in)
            s_norm = jnp.einsum("bhst,bhtk->bhsk", D, kb.astype(jnp.float32))
            n_vec = (s_norm + w_inter * n_prev[:, :, None, :]) * jnp.exp(m)
            denom = jnp.abs(jnp.einsum("bhsk,bhsk->bhs", qb.astype(jnp.float32),
                                       n_vec))
            y = y / jnp.maximum(denom[..., None], 1.0)

        # state update: S_new = e^total S_prev + sum_t e^{total-cum[t]+li[t]} k_t v_t^T
        wk = jnp.exp(total - cum + li)                             # (B,H,C)
        S_new = jnp.exp(total)[..., None] * S_prev + jnp.einsum(
            "bhtk,bhtv->bhkv", (kb.astype(jnp.float32) * wk[..., None]), vb.astype(jnp.float32))
        n_new = jnp.exp(total) * n_prev + jnp.einsum(
            "bht,bhtk->bhk", wk, kb.astype(jnp.float32)) if normalize else n_prev
        return (S_new, n_new), y.astype(q.dtype)

    norm0 = norm0 if norm0 is not None else jnp.zeros((B, H, dk), jnp.float32)
    xs = (jnp.moveaxis(qc, 2, 0), jnp.moveaxis(kc, 2, 0), jnp.moveaxis(vc, 2, 0),
          jnp.moveaxis(lac, 2, 0), jnp.moveaxis(lic, 2, 0))
    (S_f, n_f), ys = lax.scan(step, (state0, norm0), xs)
    y = jnp.moveaxis(ys, 0, 2).reshape(B, H, S, dv)
    return y, S_f, n_f


def recurrent_step(q, k, v, log_a, log_i, state, norm=None, normalize=False):
    """O(1) decode update. q,k,v: (B,H,hd); log_a/log_i: (B,H)."""
    a = jnp.exp(log_a.astype(jnp.float32))[..., None, None]
    i = jnp.exp(jnp.minimum(log_i.astype(jnp.float32), 30.0))[..., None, None]
    S_new = a * state + i * jnp.einsum("bhk,bhv->bhkv", k.astype(jnp.float32),
                                       v.astype(jnp.float32))
    y = jnp.einsum("bhk,bhkv->bhv", q.astype(jnp.float32), S_new)
    n_new = norm
    if normalize:
        n_new = a[..., 0] * norm + i[..., 0] * k.astype(jnp.float32)
        denom = jnp.abs(jnp.einsum("bhk,bhk->bh", q.astype(jnp.float32), n_new))
        y = y / jnp.maximum(denom[..., None], 1.0)
    return y.astype(q.dtype), S_new, n_new


def slstm_scan(z, i_log, f_log, o, state0=None):
    """sLSTM scalar recurrence via associative scan.
    z, o: (B, S, D); i_log, f_log: (B, S, D) gate pre-activations (log space).
    c_t = f c_{t-1} + i z_t;  n_t = f n_{t-1} + i;  h = o * c / n.
    """
    f = jax.nn.sigmoid(f_log.astype(jnp.float32))
    i = jnp.exp(jnp.minimum(i_log.astype(jnp.float32), 20.0))

    def combine(a, b):
        (fa, ca, na) = a
        (fb, cb, nb) = b
        return (fa * fb, fb * ca + cb, fb * na + nb)

    elems = (f, i * z.astype(jnp.float32), i)
    fs, cs, ns = lax.associative_scan(combine, elems, axis=1)
    if state0 is not None:
        c0, n0 = state0
        cs = cs + fs * c0[:, None]
        ns = ns + fs * n0[:, None]
    h = jax.nn.sigmoid(o.astype(jnp.float32)) * cs / jnp.maximum(jnp.abs(ns), 1.0)
    return h.astype(z.dtype), (cs[:, -1], ns[:, -1])
