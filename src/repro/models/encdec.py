"""Encoder-decoder backbone (seamless-m4t-medium): bidirectional encoder over
stub frame embeddings (``frontend="embed"``), causal decoder with cross
attention.  Self-attention uses RoPE GQA from ``layers.py``; cross-attention
is position-free (DESIGN.md notes this simplification vs. the conformer
speech encoder — the assignment stubs the modality frontend).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.models import layers as ll
from repro.models.config import ModelConfig
from repro.models.params import Spec


def specs(cfg: ModelConfig) -> dict:
    Le, Ld = cfg.encoder_layers, cfg.num_layers
    D, pd = cfg.d_model, cfg.param_dtype

    def stack(L):
        return {
            "ln1": Spec((L, D), ("layers", "embed"), pd, init="zeros"),
            "attn": ll.attention_specs(cfg, layers=L),
            "ln2": Spec((L, D), ("layers", "embed"), pd, init="zeros"),
            "mlp": ll.mlp_specs(cfg, layers=L),
        }

    enc = stack(Le)
    dec = stack(Ld)
    dec["ln_cross"] = Spec((Ld, D), ("layers", "embed"), pd, init="zeros")
    dec["cross"] = ll.attention_specs(cfg, layers=Ld)
    return {
        "embed": ll.embed_spec(cfg),
        "enc_norm": ll.norm_spec(D, pd),
        "final_norm": ll.norm_spec(D, pd),
        "encoder": enc,
        "decoder": dec,
    }


def _cross_attention(x, memory, p, cfg: ModelConfig):
    """x (B,S,D) queries over encoder memory (B,T,D); no RoPE."""
    scale = 1.0 / (cfg.hd() ** 0.5)
    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"].astype(x.dtype))
    k = jnp.einsum("btd,dhk->bthk", memory, p["wk"].astype(x.dtype))
    v = jnp.einsum("btd,dhk->bthk", memory, p["wv"].astype(x.dtype))
    b, s, h, hd = q.shape
    kv = k.shape[2]
    qg = q.reshape(b, s, kv, h // kv, hd)
    scores = jnp.einsum("bskgh,btkh->bkgst", qg, k).astype(jnp.float32) * scale
    w = jax.nn.softmax(scores, axis=-1).astype(x.dtype)
    out = jnp.einsum("bkgst,btkh->bskgh", w, v).reshape(b, s, h, hd)
    return jnp.einsum("bshk,hkd->bsd", out, p["wo"].astype(x.dtype))


def encode(params, src_embeds, cfg: ModelConfig):
    x = src_embeds.astype(cfg.compute_dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def layer(x, lp):
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        q, k, v = ll._qkv(h, lp["attn"], cfg, positions)
        scale = 1.0 / (cfg.hd() ** 0.5)
        if S <= cfg.dense_attn_max_seq:
            mask = jnp.zeros((B, S, S), jnp.float32)    # bidirectional
            out = ll._sdpa_dense(q, k, v, mask, scale)
        else:
            out = ll._sdpa_chunked(q, k, v, positions, positions, -1, False,
                                   scale, cfg.attn_chunk, cfg.attn_chunk)
        x = x + jnp.einsum("bshk,hkd->bsd", out, lp["attn"]["wo"].astype(x.dtype))
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + ll.mlp(h, lp["mlp"], cfg), None

    x, _ = lax.scan(layer, x, params["encoder"])
    return ll.rms_norm(x, params["enc_norm"], cfg.norm_eps)


def forward(params, batch, cfg: ModelConfig):
    """Training forward: src embeddings + target tokens -> decoder logits."""
    memory = encode(params, batch["embeds"], cfg)
    x = ll.embed(batch["tokens"], params["embed"], cfg.compute_dtype)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))

    def layer(x, lp):
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + ll.gqa_attention(h, lp["attn"], cfg, -1, positions)
        h = ll.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        x = x + _cross_attention(h, memory, lp["cross"], cfg)
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        return x + ll.mlp(h, lp["mlp"], cfg), None

    x, _ = lax.scan(layer, x, params["decoder"])
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x, params["embed"]).astype(jnp.float32)
    return logits, {"lb_loss": jnp.zeros((), jnp.float32)}


def cache_specs(cfg: ModelConfig, batch_size: int, max_seq: int) -> dict:
    Ld, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd()
    kvs = ("layers", None, "seq", "kv_heads", "head_dim")
    cd = cfg.compute_dtype
    return {
        "self_k": Spec((Ld, batch_size, max_seq, kv, hd), kvs, cd, init="zeros"),
        "self_v": Spec((Ld, batch_size, max_seq, kv, hd), kvs, cd, init="zeros"),
        "cross_k": Spec((Ld, batch_size, max_seq, kv, hd), kvs, cd, init="zeros"),
        "cross_v": Spec((Ld, batch_size, max_seq, kv, hd), kvs, cd, init="zeros"),
        "pos": Spec((), (), jnp.int32, init="zeros"),
    }


def prefill(params, batch, cfg: ModelConfig, max_seq: int | None = None):
    """Encode the source, precompute every decoder layer's cross-KV, and run
    the BOS decode step (translation-style serving: 1-token target prompt)."""
    memory = encode(params, batch["embeds"], cfg)
    B, T = memory.shape[:2]
    max_seq = max_seq or T

    def layer_kv(_, lp):
        k = jnp.einsum("btd,dhk->bthk", memory, lp["cross"]["wk"].astype(memory.dtype))
        v = jnp.einsum("btd,dhk->bthk", memory, lp["cross"]["wv"].astype(memory.dtype))
        return None, (k.astype(cfg.compute_dtype), v.astype(cfg.compute_dtype))

    _, (ck, cv) = lax.scan(layer_kv, None, params["decoder"])
    Ld, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd()
    cache = {
        "self_k": jnp.zeros((Ld, B, max_seq, kv, hd), cfg.compute_dtype),
        "self_v": jnp.zeros((Ld, B, max_seq, kv, hd), cfg.compute_dtype),
        "cross_k": jnp.pad(ck, ((0, 0), (0, 0), (0, max_seq - T), (0, 0), (0, 0))),
        "cross_v": jnp.pad(cv, ((0, 0), (0, 0), (0, max_seq - T), (0, 0), (0, 0))),
        "pos": jnp.asarray(0, jnp.int32),
    }
    bos = jnp.zeros((B, 1), jnp.int32)
    return decode_step(params, cache, bos, cfg)


def decode_step(params, cache, token, cfg: ModelConfig):
    """One decoder token; cross-KV precomputed at prefill (encode) time."""
    x = ll.embed(token, params["embed"], cfg.compute_dtype)
    pos = cache["pos"]

    def layer(x, xs):
        lp, sk, sv, ck, cv = xs
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, sk, sv = ll.gqa_decode(h, lp["attn"], cfg, -1, sk, sv, pos)
        x = x + out
        # cross attention against the precomputed memory KV
        h = ll.rms_norm(x, lp["ln_cross"], cfg.norm_eps)
        scale = 1.0 / (cfg.hd() ** 0.5)
        q = jnp.einsum("bsd,dhk->bshk", h, lp["cross"]["wq"].astype(h.dtype))
        b, s, nh, hd = q.shape
        kvh = ck.shape[2]
        qg = q.reshape(b, s, kvh, nh // kvh, hd)
        sc = jnp.einsum("bqkgh,btkh->bkgqt", qg, ck.astype(h.dtype)).astype(jnp.float32) * scale
        w = jax.nn.softmax(sc, axis=-1).astype(h.dtype)
        o = jnp.einsum("bkgqt,btkh->bqkgh", w, cv.astype(h.dtype)).reshape(b, s, nh, hd)
        x = x + jnp.einsum("bshk,hkd->bsd", o, lp["cross"]["wo"].astype(h.dtype))
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        x = x + ll.mlp(h, lp["mlp"], cfg)
        return x, (sk, sv)

    x, (sk_n, sv_n) = lax.scan(
        layer, x, (params["decoder"], cache["self_k"], cache["self_v"],
                   cache["cross_k"], cache["cross_v"]))
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    logits = ll.unembed(x, params["embed"]).astype(jnp.float32)
    new = dict(cache, self_k=sk_n, self_v=sv_n, pos=pos + 1)
    return logits, new
