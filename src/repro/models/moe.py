"""Mixture-of-Experts FFN with sort-based capacity dispatch (EP-shardable).

Dispatch: tokens are replicated ``top_k`` times, sorted by assigned expert,
and packed into an (E, C, D) buffer (C = capacity per expert).  The expert
matmuls are dense einsums with E sharded over the ``model`` axis (expert
parallelism); GSPMD turns the gather/scatter across the data->expert layout
change into the all-to-all pair.  Overflowing tokens are dropped (weights
renormalized), standard capacity-factor semantics.

Router stats (load per expert, drop fraction) are returned for the
spectral-clustering integration (examples/moe_spectral_routing.py): the
expert co-activation matrix is clustering input for balanced expert
placement — the paper's pipeline consuming the LM substrate.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distrib import mesh_utils
from repro.models.config import ModelConfig
from repro.models.layers import _act
from repro.models.params import Spec


def moe_specs(cfg: ModelConfig, layers: int | None = None) -> dict:
    L = (layers,) if layers is not None else ()
    lax_ = ("layers",) if layers is not None else ()
    pd = cfg.param_dtype
    E, F = cfg.num_experts, cfg.expert_d_ff
    spec = {
        "router": Spec(L + (cfg.d_model, E), lax_ + ("embed", "experts"), pd,
                       init="normal", scale=0.02),
        "wi": Spec(L + (E, cfg.d_model, 2, F), lax_ + ("experts", "embed", None, "mlp"), pd),
        "wo": Spec(L + (E, F, cfg.d_model), lax_ + ("experts", "mlp", "embed"), pd),
    }
    if cfg.num_shared_experts:
        Fs = cfg.expert_d_ff * cfg.num_shared_experts
        spec["shared_wi"] = Spec(L + (cfg.d_model, 2, Fs), lax_ + ("embed", None, "mlp"), pd)
        spec["shared_wo"] = Spec(L + (Fs, cfg.d_model), lax_ + ("mlp", "embed"), pd)
    return spec


def _dispatch_indices(flat_expert: jax.Array, T: int, K: int, E: int, C: int):
    """Sort-based capacity packing: returns (buf_idx (E*C,) token ids with
    T as the pad sentinel, dest (T*K,), keep (T*K,), order (T*K,))."""
    order = jnp.argsort(flat_expert, stable=True)
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=E)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    slot_in_expert = jnp.arange(T * K) - offsets[sorted_expert]
    keep = slot_in_expert < C
    dest = jnp.where(keep, sorted_expert * C + slot_in_expert, E * C)
    src_token = order // K
    buf_idx = jnp.full((E * C + 1,), T, jnp.int32)
    buf_idx = buf_idx.at[dest].set(src_token.astype(jnp.int32))[: E * C]
    return buf_idx, dest, keep, order


def moe_ffn_ep_shard_map(x: jax.Array, p: dict, cfg: ModelConfig):
    """Explicit expert parallelism (the paper's map/shuffle/reduce, as a
    shard_map): tokens stay batch-sharded and are *replicated* over the
    model axis; each model column dispatches only to its own E/ep experts
    locally (no dispatch collective at all — the redundant router math is
    trivial), computes them, and a single psum over "model" combines the
    weighted expert outputs.  Requires E % ep == 0.

    vs. the GSPMD "gather" path, the all-gather of the full token matrix
    disappears: the only collective is one (T_loc, D) psum per layer.
    """
    from jax.sharding import PartitionSpec as P
    from repro.distrib import act_sharding

    mesh = act_sharding.current_mesh()
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.top_k
    ep = mesh.shape["model"]
    assert E % ep == 0, (E, ep)
    E_loc = E // ep
    C = min(T, max(1, int(T * K * cfg.capacity_factor) // E))
    ba = tuple(a for a in ("pod", "data") if a in mesh.axis_names)

    def body(xt, router, wi, wo):
        # xt (T_loc, D) batch shard; wi/wo local expert slices (E_loc, ...)
        T_loc = xt.shape[0]
        col = lax.axis_index("model")
        logits = jnp.einsum("td,de->te", xt, router.astype(xt.dtype)).astype(jnp.float32)
        probs = jax.nn.softmax(logits, axis=-1)
        gate, expert_idx = lax.top_k(probs, K)
        gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)
        C_loc = min(T_loc, max(1, int(T_loc * K * cfg.capacity_factor) // E))
        buf_idx, dest, keep, order = _dispatch_indices(
            expert_idx.reshape(-1), T_loc, K, E, C_loc)
        # my expert rows only
        my = lax.dynamic_slice(buf_idx, (col * E_loc * C_loc,), (E_loc * C_loc,))
        xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
        dispatched = xt_pad[my].reshape(E_loc, C_loc, D)
        gu = jnp.einsum("ecd,edzf->eczf", dispatched, wi.astype(xt.dtype))
        h = _act(cfg.act)(gu[:, :, 0]) * gu[:, :, 1]
        out_buf = jnp.einsum("ecf,efd->ecd", h, wo.astype(xt.dtype))
        # local combine: weighted scatter-add of my experts' outputs
        flat_gate = gate.reshape(-1)[order]
        slot_gate = jnp.zeros((E * C_loc + 1,), jnp.float32).at[dest].set(
            jnp.where(keep, flat_gate, 0.0))[: E * C_loc]
        my_gate = lax.dynamic_slice(slot_gate, (col * E_loc * C_loc,),
                                    (E_loc * C_loc,))
        weighted = out_buf.reshape(E_loc * C_loc, D) * my_gate[:, None].astype(out_buf.dtype)
        partial = jnp.zeros((T_loc + 1, D), xt.dtype).at[my].add(weighted)[:T_loc]
        out = lax.psum(partial, "model")
        # aux (identical on every model column)
        me = jnp.mean(probs, axis=0)
        ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
        lb = E * jnp.sum(me * ce)
        counts = jnp.bincount(expert_idx.reshape(-1), length=E)
        return out, lb, lax.psum(counts, ba) if ba else counts

    shard = mesh_utils.shard_map(
        body, mesh=mesh,
        in_specs=(P(ba, None), P(None, None), P("model", None, None, None),
                  P("model", None, None)),
        out_specs=(P(ba, None), P(), P()),
        check_vma=False,
    )
    out, lb, counts = shard(x.reshape(T, D), p["router"], p["wi"], p["wo"])
    out = out.reshape(B, S, D)
    if cfg.num_shared_experts:
        xt = x.reshape(T, D)
        gu_s = jnp.einsum("td,dzf->tzf", xt, p["shared_wi"].astype(x.dtype))
        hs = _act(cfg.act)(gu_s[:, 0]) * gu_s[:, 1]
        out = out + jnp.einsum("tf,fd->td", hs, p["shared_wo"].astype(x.dtype)).reshape(B, S, D)
    aux = {"lb_loss": lb, "expert_load": counts,
           "frac_dropped": jnp.zeros((), jnp.float32)}
    return out, aux


def moe_ffn(x: jax.Array, p: dict, cfg: ModelConfig):
    """x: (B, S, D) -> (B, S, D), aux dict with router stats + load-balance loss."""
    from repro.distrib import act_sharding
    if cfg.moe_impl == "ep_shard_map" and act_sharding.current_mesh() is not None:
        return moe_ffn_ep_shard_map(x, p, cfg)
    B, S, D = x.shape
    T = B * S
    E, K = cfg.num_experts, cfg.top_k
    # capacity per expert; capped at T (an expert can never receive more
    # than every token), which also makes small decode batches drop-free
    C = min(T, max(1, int(T * K * cfg.capacity_factor) // E))
    xt = x.reshape(T, D)

    logits = jnp.einsum("td,de->te", xt, p["router"].astype(x.dtype)).astype(jnp.float32)
    probs = jax.nn.softmax(logits, axis=-1)
    gate, expert_idx = lax.top_k(probs, K)                     # (T, K)
    gate = gate / jnp.maximum(jnp.sum(gate, axis=-1, keepdims=True), 1e-9)

    # ---- pack: sort the T*K assignments by expert, take first C per expert
    flat_expert = expert_idx.reshape(-1)                       # (T*K,)
    order = jnp.argsort(flat_expert, stable=True)              # (T*K,)
    sorted_expert = flat_expert[order]
    counts = jnp.bincount(flat_expert, length=E)               # (E,)
    offsets = jnp.concatenate([jnp.zeros((1,), counts.dtype), jnp.cumsum(counts)[:-1]])
    slot_in_expert = jnp.arange(T * K) - offsets[sorted_expert]
    keep = slot_in_expert < C
    # destination slot in the (E*C) buffer; dropped tokens go to a trash slot
    dest = jnp.where(keep, sorted_expert * C + slot_in_expert, E * C)
    src_token = order // K                                      # token id per sorted slot

    buf_idx = jnp.full((E * C + 1,), T, jnp.int32)              # T = pad token row
    buf_idx = buf_idx.at[dest].set(src_token.astype(jnp.int32))
    buf_idx = buf_idx[: E * C]
    xt_pad = jnp.concatenate([xt, jnp.zeros((1, D), xt.dtype)], axis=0)
    dispatched = xt_pad[buf_idx].reshape(E, C, D)

    # ---- expert compute: E over "model" when divisible; the capacity dim
    # shards over the data axes either way, so expert matmuls use the FULL
    # mesh (without this, E < mesh width leaves the data axis idle and
    # replicates expert FLOPs |data|-fold)
    from repro.distrib import act_sharding
    ba = act_sharding.batch_axes_in_mesh()
    espec = {0: "model", 1: ba or None}   # E over model when divisible
    dispatched = act_sharding.constrain_dims(dispatched, espec)
    gu = jnp.einsum("ecd,edzf->eczf", dispatched, p["wi"].astype(x.dtype))
    h = _act(cfg.act)(gu[:, :, 0]) * gu[:, :, 1]                # (E, C, F)
    out_buf = jnp.einsum("ecf,efd->ecd", h, p["wo"].astype(x.dtype))
    out_buf = act_sharding.constrain_dims(out_buf, espec)

    # ---- combine: scatter-add back with gate weights
    flat_gate = gate.reshape(-1)[order]                         # aligned with sorted slots
    slot_gate = jnp.zeros((E * C + 1,), jnp.float32).at[dest].set(
        jnp.where(keep, flat_gate, 0.0))[: E * C]
    weighted = out_buf.reshape(E * C, D) * slot_gate[:, None].astype(out_buf.dtype)
    out = jnp.zeros((T + 1, D), x.dtype).at[buf_idx].add(weighted)[:T]

    if cfg.num_shared_experts:
        gu_s = jnp.einsum("td,dzf->tzf", xt, p["shared_wi"].astype(x.dtype))
        hs = _act(cfg.act)(gu_s[:, 0]) * gu_s[:, 1]
        out = out + jnp.einsum("tf,fd->td", hs, p["shared_wo"].astype(x.dtype))

    # ---- aux: load-balance loss (Switch) + stats for spectral routing
    me = jnp.mean(probs, axis=0)                                # (E,)
    ce = jnp.mean(jax.nn.one_hot(expert_idx[:, 0], E, dtype=jnp.float32), axis=0)
    lb_loss = E * jnp.sum(me * ce)
    frac_dropped = 1.0 - jnp.sum(jnp.where(keep, 1.0, 0.0)) / (T * K)
    aux = {"lb_loss": lb_loss, "expert_load": counts, "frac_dropped": frac_dropped}
    return out.reshape(B, S, D), aux
