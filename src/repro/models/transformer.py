"""Generic decoder-only transformer (dense FFN or MoE), scan-over-layers.

Covers: gemma3 (5:1 local:global windows), minitron, qwen1.5 (qkv bias),
glm4, mixtral & kimi-k2 (MoE), and the internvl2 VLM backbone
(``frontend="embed"``: the stub modality frontend feeds precomputed patch
embeddings straight past the token embedding).

Layer params are stacked with a leading "layers" axis and scanned, so the
compiled HLO contains ONE layer body regardless of depth (critical for the
40-cell dry-run compile budget).  Per-layer heterogeneity (gemma's window
pattern) rides along as scanned xs, not as separate programs.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distrib import act_sharding
from repro.models import layers as ll, moe as moe_lib
from repro.models.config import ModelConfig
from repro.models.params import Spec


def specs(cfg: ModelConfig) -> dict:
    L = cfg.num_layers
    layer = {
        "ln1": Spec((L, cfg.d_model), ("layers", "embed"), cfg.param_dtype, init="zeros"),
        "ln2": Spec((L, cfg.d_model), ("layers", "embed"), cfg.param_dtype, init="zeros"),
        "attn": ll.attention_specs(cfg, layers=L),
    }
    if cfg.family == "moe" or cfg.num_experts:
        layer["moe"] = moe_lib.moe_specs(cfg, layers=L)
    else:
        layer["mlp"] = ll.mlp_specs(cfg, layers=L)
    tree = {
        "embed": ll.embed_spec(cfg),
        "final_norm": ll.norm_spec(cfg.d_model, cfg.param_dtype),
        "layers": layer,
    }
    if not cfg.tie_embeddings:
        tree["unembed"] = Spec((cfg.vocab_size, cfg.d_model), ("vocab", "embed"),
                               cfg.param_dtype, init="normal", scale=0.02)
    return tree


def _maybe_remat(fn, cfg: ModelConfig):
    if cfg.remat == "none":
        return fn
    policy = (jax.checkpoint_policies.dots_with_no_batch_dims_saveable
              if cfg.remat == "dots" else None)
    return jax.checkpoint(fn, policy=policy)


def _inputs_to_hidden(params, batch, cfg: ModelConfig):
    if cfg.frontend == "embed":
        x = batch["embeds"].astype(cfg.compute_dtype)
    else:
        x = ll.embed(batch["tokens"], params["embed"], cfg.compute_dtype)
    return act_sharding.constrain_seq(x, cfg)


def forward(params, batch, cfg: ModelConfig) -> tuple[jax.Array, dict]:
    """Full-sequence forward -> (logits (B,S,V) f32, aux)."""
    x = _inputs_to_hidden(params, batch, cfg)
    B, S = x.shape[:2]
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    windows = jnp.asarray(cfg.windows(), jnp.int32)
    layer_specs = jax.tree.map(
        lambda s: Spec(s.shape[1:], s.axes[1:], s.dtype),
        specs(cfg)["layers"], is_leaf=lambda s: isinstance(s, Spec))

    def layer(x, xs):
        lp, window = xs
        lp = act_sharding.constrain_layer_params(lp, layer_specs, cfg)
        x = act_sharding.constrain_seq(x, cfg)
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        x = x + ll.gqa_attention(h, lp["attn"], cfg, window, positions)
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, aux = moe_lib.moe_ffn(h, lp["moe"], cfg)
            return x + y, aux["lb_loss"]
        return x + ll.mlp(h, lp["mlp"], cfg), jnp.zeros((), jnp.float32)

    layer = _maybe_remat(layer, cfg)
    x, lb = lax.scan(layer, x, (params["layers"], windows))
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = ll.unembed(x, table).astype(jnp.float32)
    return logits, {"lb_loss": jnp.sum(lb)}


# ---------------------------------------------------------------------------
# serving
# ---------------------------------------------------------------------------

def cache_specs(cfg: ModelConfig, batch_size: int, max_seq: int) -> dict:
    L, kv, hd = cfg.num_layers, cfg.num_kv_heads, cfg.hd()
    kvs = ("layers", None, "seq", "kv_heads", "head_dim")
    return {
        "k": Spec((L, batch_size, max_seq, kv, hd), kvs, cfg.compute_dtype, init="zeros"),
        "v": Spec((L, batch_size, max_seq, kv, hd), kvs, cfg.compute_dtype, init="zeros"),
        "pos": Spec((), (), jnp.int32, init="zeros"),
    }


def prefill(params, batch, cfg: ModelConfig, max_seq: int | None = None):
    """Run the prompt, return (last-token logits, filled cache)."""
    x = _inputs_to_hidden(params, batch, cfg)
    B, S = x.shape[:2]
    max_seq = max_seq or S
    positions = jnp.broadcast_to(jnp.arange(S)[None, :], (B, S))
    windows = jnp.asarray(cfg.windows(), jnp.int32)

    def layer(x, xs):
        lp, window = xs
        x = act_sharding.constrain_seq(x, cfg)
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        attn_out, k, v = ll.gqa_attention(h, lp["attn"], cfg, window, positions,
                                          return_kv=True)
        x = x + attn_out
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, _ = moe_lib.moe_ffn(h, lp["moe"], cfg)
            x = x + y
        else:
            x = x + ll.mlp(h, lp["mlp"], cfg)
        pad = max_seq - S
        kc = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        vc = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        return x, (kc.astype(cfg.compute_dtype), vc.astype(cfg.compute_dtype))

    layer = _maybe_remat(layer, cfg)
    x, (k_all, v_all) = lax.scan(layer, x, (params["layers"], windows))
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = ll.unembed(x[:, -1:], table).astype(jnp.float32)
    cache = {"k": k_all, "v": v_all, "pos": jnp.asarray(S, jnp.int32)}
    return logits, cache


def decode_step(params, cache, token, cfg: ModelConfig):
    """One decode step: token (B, 1) int32 -> (logits (B,1,V), new cache).
    Generated tokens are always text tokens — even for the VLM backbone,
    whose stub frontend only feeds the *prompt* as patch embeddings."""
    x = ll.embed(token, params["embed"], cfg.compute_dtype)
    pos = cache["pos"]
    windows = jnp.asarray(cfg.windows(), jnp.int32)

    def layer(x, xs):
        lp, window, kc, vc = xs
        h = ll.rms_norm(x, lp["ln1"], cfg.norm_eps)
        out, kc, vc = ll.gqa_decode(h, lp["attn"], cfg, window, kc, vc, pos)
        x = x + out
        h = ll.rms_norm(x, lp["ln2"], cfg.norm_eps)
        if "moe" in lp:
            y, _ = moe_lib.moe_ffn(h, lp["moe"], cfg)
            x = x + y
        else:
            x = x + ll.mlp(h, lp["mlp"], cfg)
        return x, (kc, vc)

    x, (k_all, v_all) = lax.scan(layer, x, (params["layers"], windows,
                                            cache["k"], cache["v"]))
    x = ll.rms_norm(x, params["final_norm"], cfg.norm_eps)
    table = params.get("unembed", params["embed"])
    logits = ll.unembed(x, table).astype(jnp.float32)
    return logits, {"k": k_all, "v": v_all, "pos": pos + 1}
