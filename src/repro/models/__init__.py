# LM substrate for the assigned architectures: layer library, family
# stacks (dense/MoE transformer, xLSTM, Mamba2/Zamba hybrid, enc-dec,
# VLM/audio backbones), KV-cache serving, and sharding rules.
