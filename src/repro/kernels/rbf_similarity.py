"""Pallas TPU kernel for the paper's phase-1 hot spot: tiled RBF similarity.

One grid cell computes a (bm, bn) output tile from a (bm, d) row tile and a
(bn, d) column tile held in VMEM.  The squared distance uses the
``|x|^2 + |y|^2 - 2 x.y`` decomposition so the inner product runs on the MXU;
bm/bn default to 128/128 (MXU-aligned), and the feature dim is kept whole in
VMEM (spectral-clustering inputs are short-and-wide: n >> d).

VMEM budget per cell (f32, defaults, d<=512):
  x tile 128*512*4 = 256 KiB, y tile 256 KiB, out 64 KiB  << 16 MiB VMEM.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _rbf_kernel(x_ref, y_ref, inv2s2_ref, o_ref):
    x = x_ref[...]                    # (bm, d)
    y = y_ref[...]                    # (bn, d)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = jax.lax.dot_general(
        x, y, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)   # MXU matmul, f32 accumulate
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    o_ref[...] = jnp.exp(-d2 * inv2s2_ref[0]).astype(o_ref.dtype)


@functools.partial(jax.jit,
                   static_argnames=("bm", "bn", "grid_order", "interpret"))
def rbf_similarity(x: jax.Array, y: jax.Array, sigma,
                   *, bm: int = 128, bn: int = 128,
                   grid_order: str = "row-major",
                   interpret: bool = True) -> jax.Array:
    """Tiled RBF similarity; shapes must be multiples of (bm, bn) — use
    ``ops.rbf_similarity`` for the padded public entry point.

    ``grid_order`` is a schedule knob: "row-major" sweeps column tiles
    fastest (the x row tile stays resident across the row stripe),
    "col-major" sweeps row tiles fastest (the y tile stays resident) —
    legal here because every output tile is written exactly once, so the
    traversal order is free."""
    n, d = x.shape
    m = y.shape[0]
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    assert grid_order in ("row-major", "col-major"), grid_order
    inv2s2 = (1.0 / (2.0 * jnp.asarray(sigma, jnp.float32) ** 2)).reshape(1)
    if grid_order == "row-major":
        grid = (n // bm, m // bn)
        row = lambda i, j: (i, j)               # noqa: E731
    else:                                        # grid dims swapped: row
        grid = (m // bn, n // bm)                # tile index is the LAST
        row = lambda j, i: (i, j)               # noqa: E731 - grid arg
    return pl.pallas_call(
        _rbf_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda *ij: (row(*ij)[0], 0)),
            pl.BlockSpec((bn, d), lambda *ij: (row(*ij)[1], 0)),
            pl.BlockSpec((1,), lambda *ij: (0,)),  # 1/(2 sigma^2), replicated
        ],
        out_specs=pl.BlockSpec((bm, bn), row),
        out_shape=jax.ShapeDtypeStruct((n, m), x.dtype),
        interpret=interpret,
    )(x, y, inv2s2)
