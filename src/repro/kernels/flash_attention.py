"""Pallas TPU flash attention (beyond-paper optimization for the LM cells).

The roofline analysis (EXPERIMENTS.md §Perf) shows every *_32k attention
cell is memory-bound on XLA's chunked online-softmax: the mask/exp/reduce
passes materialize f32 score tiles in HBM ~4x per (q,k) block.  A fused
kernel keeps the (bq, bk) score tile in VMEM: HBM traffic collapses to
q/k/v reads + one output write —

    bytes_xla   ~= S*T*(4 passes)*4B      per (batch, head)
    bytes_flash ~= (S + 2T)*hd*2B + S*hd*2B

For S=T=32k, hd=128: ~17 GB -> ~0.03 GB per (batch, head): the memory
term drops below the compute term, i.e. attention becomes MXU-bound.

Grid: (batch*kv_heads*q_groups, S/bq); the kv loop runs *inside* the
kernel body (fori over T/bk) with the online-softmax state in VMEM
registers.  Causal + local-window masking is applied per tile; fully
masked tiles are skipped by bounding the fori range (the window start /
causal end are affine in the q-block index, so the trip bounds stay SPMD-
uniform).  Validated against ref.flash_attention on CPU in interpret mode
(tests/test_kernels_flash.py).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

NEG_INF = -1e30


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, *, bq, bk, seq_k, scale,
                  causal, window):
    qi = pl.program_id(1)
    q = q_ref[0]                       # (bq, hd); leading block dim is 1
    hd = q.shape[-1]

    q0 = qi * bq                       # first query position of this block
    # kv tile range: causal => tiles with t0 <= q_end; window => t_end >
    # q0 - window (affine bounds, identical structure on every program)
    hi = (q0 + bq + bk - 1) // bk if causal else seq_k // bk
    lo = jnp.maximum(0, q0 - window + 1) // bk if window > 0 else 0

    def body(ti, acc):
        m, l, o = acc
        t0 = ti * bk
        # size-1 dslice, not int 0: jax 0.4's interpret-mode discharge rule
        # cannot handle raw scalar indices
        k = pl.load(k_ref, (pl.dslice(0, 1), pl.dslice(t0, bk),
                            slice(None)))[0]                      # (bk, hd)
        v = pl.load(v_ref, (pl.dslice(0, 1), pl.dslice(t0, bk),
                            slice(None)))[0]
        s = jax.lax.dot_general(q, k, (((1,), (1,)), ((), ())),
                                preferred_element_type=jnp.float32) * scale
        qpos = q0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        kpos = t0 + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = jnp.ones((bq, bk), jnp.bool_)
        if causal:
            ok = ok & (kpos <= qpos)
        if window > 0:
            ok = ok & (qpos - kpos < window)
        s = jnp.where(ok, s, NEG_INF)
        m_new = jnp.maximum(m, jnp.max(s, axis=1))
        p = jnp.exp(s - m_new[:, None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + jnp.sum(p, axis=1)
        o_new = o * corr[:, None] + jax.lax.dot_general(
            p.astype(v.dtype), v, (((1,), (0,)), ((), ())),
            preferred_element_type=jnp.float32)
        return m_new, l_new, o_new

    m0 = jnp.full((bq,), NEG_INF, jnp.float32)
    l0 = jnp.zeros((bq,), jnp.float32)
    o0 = jnp.zeros((bq, hd), jnp.float32)
    m, l, o = jax.lax.fori_loop(lo, hi, body, (m0, l0, o0))
    o_ref[0] = (o / jnp.maximum(l, 1e-30)[:, None]).astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "bq", "bk", "causal", "window", "interpret"))
def flash_attention(q: jax.Array, k: jax.Array, v: jax.Array, *,
                    scale: float | None = None, causal: bool = True,
                    window: int = -1, bq: int = 256, bk: int = 256,
                    interpret: bool = True) -> jax.Array:
    """q: (B, H, S, hd); k/v: (B, H, T, hd) (kv heads pre-broadcast).
    S % bq == 0 and T % bk == 0 (use ops.flash_attention for padding)."""
    B, H, S, hd = q.shape
    T = k.shape[2]
    assert S % bq == 0 and T % bk == 0, (S, T, bq, bk)
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    qf = q.reshape(B * H, S, hd)
    kf = k.reshape(B * H, T, hd)
    vf = v.reshape(B * H, T, hd)
    kernel = functools.partial(_flash_kernel, bq=bq, bk=bk, seq_k=T,
                               scale=scale, causal=causal, window=window)
    out = pl.pallas_call(
        kernel,
        grid=(B * H, S // bq),
        in_specs=[
            pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
            pl.BlockSpec((1, T, hd), lambda b, i: (b, 0, 0)),
            pl.BlockSpec((1, T, hd), lambda b, i: (b, 0, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, hd), lambda b, i: (b, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B * H, S, hd), q.dtype),
        interpret=interpret,
    )(qf, kf, vf)
    return out.reshape(B, H, S, hd)
