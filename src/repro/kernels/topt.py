"""Tile-level top-t selection for the out-of-core map stage.

A map task computes one (rows, cols) similarity tile with the Pallas RBF
kernel and immediately reduces it to the per-row top-t candidates (value +
*global* column id) before anything leaves the device — the tile itself is
never shipped to the shuffle.  Candidate blocks are padded to a fixed width
``t`` with value -1 / column -1 (RBF similarities are positive, so the
sentinel can never win a merge), which keeps every shuffle record the same
shape regardless of ragged edge chunks.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np

_PAD_VAL = -1.0
_PAD_COL = -1


@functools.partial(jax.jit, static_argnames=("t",))
def _tile_topt(tile: jax.Array, t: int):
    return jax.lax.top_k(tile, t)


def tile_topt(tile, col0: int, t: int) -> tuple[np.ndarray, np.ndarray]:
    """Per-row top-t of one tile. ``tile`` is (rows, cols) similarities,
    ``col0`` the tile's global column offset.  Returns host arrays
    ``(vals (rows, t) f32, cols (rows, t) i64)`` padded with the -1
    sentinels when the tile has fewer than ``t`` columns."""
    tile = jnp.asarray(tile)
    rows, cols = tile.shape
    te = int(min(t, cols))
    vals, idx = _tile_topt(tile, te)
    vals = np.asarray(vals, np.float32)
    # global ids in host int64: device ints are 32-bit without jax x64,
    # which would wrap past 2^31 rows
    gcols = np.asarray(idx, np.int64) + col0
    if te < t:
        vals = np.concatenate(
            [vals, np.full((rows, t - te), _PAD_VAL, np.float32)], axis=1)
        gcols = np.concatenate(
            [gcols, np.full((rows, t - te), _PAD_COL, np.int64)], axis=1)
    return vals, gcols


def merge_topt(vals: np.ndarray, cols: np.ndarray, t: int
               ) -> tuple[np.ndarray, np.ndarray]:
    """Reduce-side merge: candidates (rows, c) from several map tasks ->
    final per-row top-t, sentinel-padded like :func:`tile_topt`."""
    rows, c = vals.shape
    if c > t:
        part = np.argpartition(-vals, t - 1, axis=1)[:, :t]
        vals = np.take_along_axis(vals, part, axis=1)
        cols = np.take_along_axis(cols, part, axis=1)
    order = np.argsort(-vals, axis=1, kind="stable")
    return (np.take_along_axis(vals, order, axis=1),
            np.take_along_axis(cols, order, axis=1))
