"""Pure-jnp oracles for every Pallas kernel in this package."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rbf_similarity(x: jax.Array, y: jax.Array, sigma) -> jax.Array:
    """S_ij = exp(-||x_i - y_j||^2 / (2 sigma^2))."""
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    d2 = jnp.maximum(xx + yy - 2.0 * (x @ y.T), 0.0)
    return jnp.exp(-d2 / (2.0 * jnp.asarray(sigma, x.dtype) ** 2))


def fused_rbf_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                     row_scale: jax.Array, col_scale: jax.Array) -> jax.Array:
    """diag(row_scale) @ RBF(x, y) @ diag(col_scale) @ V — materialized."""
    S = rbf_similarity(x, y, sigma)
    return row_scale[:, None] * (S @ (col_scale[:, None] * V))


def fused_nystrom_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                         col_scale: jax.Array,
                         col_valid: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(RBF(x, y) @ (col_scale * V), RBF(x, y) @ col_valid) — materialized."""
    K = rbf_similarity(x, y, sigma)
    return K @ (col_scale[:, None] * V), (K @ col_valid)[:, None]


def block_matvec(A: jax.Array, v: jax.Array) -> jax.Array:
    """A @ v."""
    return A @ v


def block_matmat(A: jax.Array, V: jax.Array) -> jax.Array:
    """A @ V."""
    return A @ V


def flash_attention(q, k, v, scale=None, causal=True, window=-1):
    """Oracle softmax attention. q/k/v: (B, H, S|T, hd)."""
    hd = q.shape[-1]
    scale = scale if scale is not None else 1.0 / (hd ** 0.5)
    s = jnp.einsum("bhqd,bhtd->bhqt", q.astype(jnp.float32),
                   k.astype(jnp.float32)) * scale
    S, T = q.shape[2], k.shape[2]
    qpos = jnp.arange(S)[:, None]
    kpos = jnp.arange(T)[None, :]
    ok = jnp.ones((S, T), bool)
    if causal:
        ok = ok & (kpos <= qpos)
    if window > 0:
        ok = ok & (qpos - kpos < window)
    s = jnp.where(ok, s, -1e30)
    w = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhqt,bhtd->bhqd", w, v.astype(jnp.float32)).astype(q.dtype)


def kmeans_assign(points: jax.Array, centers: jax.Array) -> tuple[jax.Array, jax.Array]:
    """(argmin_j ||p_i - c_j||^2, min_j ||p_i - c_j||^2)."""
    pp = jnp.sum(points * points, axis=-1)[:, None]
    cc = jnp.sum(centers * centers, axis=-1)[None, :]
    d2 = jnp.maximum(pp + cc - 2.0 * (points @ centers.T), 0.0)
    return jnp.argmin(d2, axis=1).astype(jnp.int32), jnp.min(d2, axis=1)
