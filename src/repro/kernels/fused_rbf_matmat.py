"""Flash-style fused RBF matmat: the matrix-free affinity hot loop.

One Pallas kernel computes

    O = diag(row_scale) . exp(-||x_i - y_j||^2 / 2 sigma^2) . diag(col_scale) @ V

without ever materializing the (n, m) similarity matrix: each grid cell
streams a (bm, d) row tile of ``x``, a (bn, d) column tile of ``y`` and the
matching (bn, b) tile of ``V`` into VMEM, builds the RBF tile *in register*
(squared distances via the ``|x|^2 + |y|^2 - 2 x.y`` MXU decomposition),
applies the D^{-1/2} normalization scales in place, and accumulates the
(bm, b) product directly into the output tile — the flash-attention
recompute trick applied to the spectral-clustering kernel matrix (Jin &
JaJa 2018: recomputing kernel tiles beats storing them once bandwidth is
the bottleneck).  Affinity memory drops from O(n^2) to O(n*d + n*b).

Mixed precision: ``compute_dtype`` selects the dtype the two MXU products
run in — bf16 operands double MXU throughput on TPU (the cast happens in
register, so HBM traffic is unchanged); the squared-norm terms, the exp,
and BOTH accumulations always stay in f32
(``preferred_element_type=jnp.float32``), so bf16 only perturbs the tile
entries, not the reduction.

Tile/grid conventions follow ``kernels/rbf_similarity`` (points short and
wide: feature dim kept whole in VMEM) and ``kernels/block_matmat`` (output
row tile revisited across the column grid dimension, initialized at
``j == 0`` and accumulated in place).

VMEM per cell (f32, bm=bn=128, d<=512, b<=64):
  x tile 256 KiB + y tile 256 KiB + V tile 32 KiB + RBF tile 64 KiB
  + out 32 KiB  << 16 MiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.block_matvec import check_tiles, interpret_default

# names accepted by the public ``compute_dtype`` knob (estimator kwarg /
# --compute-dtype CLI flag); None means full f32
_COMPUTE_DTYPES = {
    None: jnp.float32,
    "f32": jnp.float32, "float32": jnp.float32,
    "bf16": jnp.bfloat16, "bfloat16": jnp.bfloat16,
}


def resolve_compute_dtype(spec) -> jnp.dtype:
    """'bf16' | 'float32' | dtype | None -> the kernel compute dtype."""
    if isinstance(spec, str):
        try:
            return _COMPUTE_DTYPES[spec.lower()]
        except KeyError:
            raise ValueError(
                f"unknown compute_dtype {spec!r}; expected one of "
                f"{sorted(k for k in _COMPUTE_DTYPES if k)}") from None
    if spec is None:
        return jnp.float32
    dt = jnp.dtype(spec)
    if dt not in (jnp.dtype(jnp.float32), jnp.dtype(jnp.bfloat16)):
        raise ValueError(f"compute_dtype must be float32 or bfloat16, "
                         f"got {dt}")
    return jnp.bfloat16 if dt == jnp.dtype(jnp.bfloat16) else jnp.float32


def default_tile(n: int) -> int:
    """MXU-aligned tile side for the fused kernels (fit- and serving-side
    share one rule): larger tiles quarter the grid-cell count — which is
    what interpret mode pays for — and on TPU amortize more MXU work per
    VMEM fill; small problems stay at 128 so padding overhead stays
    bounded."""
    return 256 if n >= 2048 else 128


def _fused_tile_product(x_ref, y_ref, v_ref, cs_ref, inv2s2_ref,
                        *, compute_dtype):
    """Shared tile body: the in-register RBF tile times the scaled V tile
    — the algorithm; where the (bm, b) partial sum then accumulates is the
    schedule's business (inplace vs scratch kernel variants below)."""
    x = x_ref[...]                              # (bm, d) f32
    y = y_ref[...]                              # (bn, d) f32
    # squared norms in f32 (cheap VPU work; keeping them full precision
    # makes bf16 perturb only the cross term, not the distance scale)
    xx = jnp.sum(x * x, axis=-1)[:, None]
    yy = jnp.sum(y * y, axis=-1)[None, :]
    xy = jax.lax.dot_general(
        x.astype(compute_dtype), y.astype(compute_dtype),
        (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)     # MXU, f32 accumulate
    d2 = jnp.maximum(xx + yy - 2.0 * xy, 0.0)
    tile = jnp.exp(-d2 * inv2s2_ref[0])         # RBF tile, in-register only
    w = cs_ref[...] * v_ref[...]                # (bn, b): D^{-1/2} V tile
    acc = jax.lax.dot_general(
        tile.astype(compute_dtype), w.astype(compute_dtype),
        (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)     # (bm, b), f32 accumulate
    return tile, acc


def _fused_kernel(x_ref, y_ref, v_ref, rs_ref, cs_ref, inv2s2_ref, o_ref,
                  *, compute_dtype):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    _, acc = _fused_tile_product(x_ref, y_ref, v_ref, cs_ref, inv2s2_ref,
                                 compute_dtype=compute_dtype)
    o_ref[...] += rs_ref[...] * acc             # row D^{-1/2}, in place


def _fused_kernel_scratch(x_ref, y_ref, v_ref, rs_ref, cs_ref, inv2s2_ref,
                          o_ref, acc_ref, *, compute_dtype):
    """acc='scratch' schedule variant: partial sums live in an f32 VMEM
    scratch tile; the output tile is written once, at the last column
    step, instead of being read-modified-written per step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    _, acc = _fused_tile_product(x_ref, y_ref, v_ref, cs_ref, inv2s2_ref,
                                 compute_dtype=compute_dtype)
    acc_ref[...] += acc

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = rs_ref[...] * acc_ref[...]


def _nystrom_kernel(x_ref, y_ref, v_ref, cs_ref, cv_ref, inv2s2_ref,
                    o_ref, deg_ref, *, compute_dtype):
    """Rectangular serving twin of :func:`_fused_kernel`: one sweep over the
    training tiles accumulates BOTH the product ``K @ (col_scale * V)`` and
    the query-side degree column ``K @ col_valid`` — the two quantities the
    Nystrom out-of-sample extension needs, so ``transform`` costs exactly
    one pass over the training set per query batch."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)
        deg_ref[...] = jnp.zeros_like(deg_ref)

    tile, acc = _fused_tile_product(x_ref, y_ref, v_ref, cs_ref, inv2s2_ref,
                                    compute_dtype=compute_dtype)
    # degree counts every VALID training column (padding masked by cv);
    # the product is masked through col_scale (0 on padding) instead, so
    # isolated training points (valid but zero-degree) still contribute to
    # the query degree exactly like the materialized dense path
    deg_ref[...] += jnp.sum(tile * cv_ref[...][:, 0][None, :], axis=1,
                            keepdims=True)
    o_ref[...] += acc


def _nystrom_kernel_scratch(x_ref, y_ref, v_ref, cs_ref, cv_ref, inv2s2_ref,
                            o_ref, deg_ref, acc_ref, dacc_ref,
                            *, compute_dtype):
    """acc='scratch' variant of :func:`_nystrom_kernel`: both running sums
    (product and degree) live in VMEM scratch; one output write each at
    the last training-tile step."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        dacc_ref[...] = jnp.zeros_like(dacc_ref)

    tile, acc = _fused_tile_product(x_ref, y_ref, v_ref, cs_ref, inv2s2_ref,
                                    compute_dtype=compute_dtype)
    dacc_ref[...] += jnp.sum(tile * cv_ref[...][:, 0][None, :], axis=1,
                             keepdims=True)
    acc_ref[...] += acc

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...]
        deg_ref[...] = dacc_ref[...]


@functools.partial(jax.jit, static_argnames=("bm", "bn", "compute_dtype",
                                             "acc", "interpret"))
def _nystrom(x, y, V, inv2s2, col_scale, col_valid, *, bm, bn, compute_dtype,
             acc, interpret):
    from jax.experimental.pallas import tpu as pltpu
    m, d = x.shape                               # m queries vs n training
    n = y.shape[0]
    b = V.shape[1]
    grid = (m // bm, n // bn)
    body = _nystrom_kernel if acc == "inplace" else _nystrom_kernel_scratch
    scratch = [] if acc == "inplace" else \
        [pltpu.VMEM((bm, b), jnp.float32), pltpu.VMEM((bm, 1), jnp.float32)]
    kernel = functools.partial(body, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        scratch_shapes=scratch,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, b), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),  # 1/(2 sigma^2)
        ],
        out_specs=[
            pl.BlockSpec((bm, b), lambda i, j: (i, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        ],
        out_shape=[jax.ShapeDtypeStruct((m, b), jnp.float32),
                   jax.ShapeDtypeStruct((m, 1), jnp.float32)],
        interpret=interpret,
    )(x, y, V, col_scale, col_valid, inv2s2)


def fused_nystrom_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                         col_scale: jax.Array, col_valid: jax.Array,
                         *, bm: int = 128, bn: int = 128,
                         compute_dtype=None, acc: str = "inplace",
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """One fused pass of the Nystrom out-of-sample extension.

    Returns ``(K @ (col_scale * V), K @ col_valid)`` for the RBF kernel
    ``K = RBF(x, y; sigma)`` — the unnormalized embedding product and the
    query degree column, computed from the same in-register kernel tiles
    (the similarity never exists).  ``x`` (m, d) queries, ``y`` (n, d)
    training points, ``V`` (n, b); m, n must divide the (bm, bn) tiles —
    ``ops.fused_nystrom_matmat`` is the padded public entry point.  Both
    outputs are f32 regardless of ``compute_dtype``."""
    if interpret is None:
        interpret = interpret_default()
    check_tiles(bm, bn, interpret=bool(interpret),
                kernel="fused_nystrom_matmat")
    m, d = x.shape                               # m queries vs n training
    n = y.shape[0]
    assert V.ndim == 2 and V.shape[0] == n, (x.shape, y.shape, V.shape)
    assert m % bm == 0 and n % bn == 0, (m, n, bm, bn)
    cdtype = resolve_compute_dtype(compute_dtype)
    inv2s2 = (1.0 / (2.0 * jnp.asarray(sigma, jnp.float32) ** 2)).reshape(1)
    return _nystrom(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
                    jnp.asarray(V, jnp.float32), inv2s2,
                    jnp.asarray(col_scale, jnp.float32).reshape(n, 1),
                    jnp.asarray(col_valid, jnp.float32).reshape(n, 1),
                    bm=bm, bn=bn, compute_dtype=cdtype, acc=acc,
                    interpret=bool(interpret))


@functools.partial(jax.jit, static_argnames=("bm", "bn", "compute_dtype",
                                             "acc", "interpret"))
def _fused(x, y, V, inv2s2, row_scale, col_scale, *, bm, bn, compute_dtype,
           acc, interpret):
    from jax.experimental.pallas import tpu as pltpu
    n, d = x.shape
    m = y.shape[0]
    b = V.shape[1]
    grid = (n // bm, m // bn)
    body = _fused_kernel if acc == "inplace" else _fused_kernel_scratch
    scratch = [] if acc == "inplace" else \
        [pltpu.VMEM((bm, b), jnp.float32)]
    kernel = functools.partial(body, compute_dtype=compute_dtype)
    return pl.pallas_call(
        kernel,
        grid=grid,
        scratch_shapes=scratch,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, d), lambda i, j: (j, 0)),
            pl.BlockSpec((bn, b), lambda i, j: (j, 0)),
            pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
            pl.BlockSpec((1,), lambda i, j: (0,)),  # 1/(2 sigma^2)
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(x, y, V, row_scale, col_scale, inv2s2)


def fused_rbf_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                     row_scale: jax.Array, col_scale: jax.Array,
                     *, bm: int = 128, bn: int = 128,
                     compute_dtype=None, acc: str = "inplace",
                     interpret: bool | None = None) -> jax.Array:
    """diag(row_scale) @ RBF(x, y; sigma) @ diag(col_scale) @ V, fused.

    ``x`` (n, d), ``y`` (m, d), ``V`` (m, b), scales (n,)/(m,); n, m must
    divide the (bm, bn) tiles — ``ops.fused_rbf_matmat`` is the padded
    public entry point.  Output is (n, b) f32 regardless of
    ``compute_dtype`` (accumulation is always f32)."""
    if interpret is None:
        interpret = interpret_default()
    check_tiles(bm, bn, interpret=bool(interpret), kernel="fused_rbf_matmat")
    n, d = x.shape
    m = y.shape[0]
    assert V.ndim == 2 and V.shape[0] == m, (x.shape, y.shape, V.shape)
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    cdtype = resolve_compute_dtype(compute_dtype)
    inv2s2 = (1.0 / (2.0 * jnp.asarray(sigma, jnp.float32) ** 2)).reshape(1)
    return _fused(jnp.asarray(x, jnp.float32), jnp.asarray(y, jnp.float32),
                  jnp.asarray(V, jnp.float32), inv2s2,
                  jnp.asarray(row_scale, jnp.float32).reshape(n, 1),
                  jnp.asarray(col_scale, jnp.float32).reshape(m, 1),
                  bm=bm, bn=bn, compute_dtype=cdtype, acc=acc,
                  interpret=bool(interpret))


def pass_bytes(n: int, m: int, d: int, b: int,
               *, bm: int = 128, bn: int = 128) -> int:
    """HBM->VMEM traffic model of ONE fused pass (the ``bytes_streamed``
    accounting unit the operator advertises): every (i, j) grid cell loads
    its x/y point tiles, V tile and scale columns; the output row tile is
    written once per row stripe.  Compare against the materialized path's
    n*m*4 bytes per pass to see the recompute-vs-store trade.

    Everything is billed at f32: the points live in HBM as f32 and the
    bf16 ``compute_dtype`` cast happens *in register*, after the load —
    it halves MXU operand volume, not HBM traffic (storing the points in
    bf16 would be the traffic lever, and would also perturb the norms)."""
    cells = (n // bm) * (m // bn)
    per_cell = (bm * d + bn * d) * 4 + (bn * b + bm + bn) * 4
    return cells * per_cell + (n // bm) * bm * b * 4
