"""Pallas TPU kernel for the paper's phase-3 map function: fused
distance + argmin assignment.

One grid cell assigns a (bm,) row tile of points: distances to all k
centers are computed in VMEM ((bm, k) intermediate, never written to HBM)
and reduced to (argmin, min) — fusing the paper's per-point map loop into
one MXU matmul + VPU reduction per tile.  Centers (k, d) are small and
replicated to every cell (the paper's "center file").
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _assign_kernel(p_ref, c_ref, idx_ref, dist_ref):
    p = p_ref[...]                    # (bm, d)
    c = c_ref[...]                    # (k, d)
    pp = jnp.sum(p * p, axis=-1)[:, None]
    cc = jnp.sum(c * c, axis=-1)[None, :]
    pc = jax.lax.dot_general(
        p, c, (((1,), (1,)), ((), ())),
        preferred_element_type=jnp.float32)
    d2 = jnp.maximum(pp + cc - 2.0 * pc, 0.0)          # (bm, k)
    idx_ref[...] = jnp.argmin(d2, axis=1).astype(jnp.int32)
    dist_ref[...] = jnp.min(d2, axis=1).astype(dist_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "interpret"))
def kmeans_assign(points: jax.Array, centers: jax.Array,
                  *, bm: int = 512, interpret: bool = True
                  ) -> tuple[jax.Array, jax.Array]:
    """(labels int32 (n,), sq-dists (n,)); n must divide bm — see ops.py."""
    n, d = points.shape
    k = centers.shape[0]
    assert n % bm == 0, (n, bm)
    grid = (n // bm,)
    idx, dist = pl.pallas_call(
        _assign_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, d), lambda i: (i, 0)),
            pl.BlockSpec((k, d), lambda i: (0, 0)),
        ],
        out_specs=[
            pl.BlockSpec((bm,), lambda i: (i,)),
            pl.BlockSpec((bm,), lambda i: (i,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((n,), jnp.int32),
            jax.ShapeDtypeStruct((n,), points.dtype),
        ],
        interpret=interpret,
    )(points, centers)
    return idx, dist
