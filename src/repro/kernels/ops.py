"""Public jit'd wrappers for the Pallas kernels.

Handles: padding to tile multiples, backend dispatch (TPU -> compiled
kernel; CPU/other -> interpret mode, which runs the same kernel body in
Python for correctness), and un-padding of results.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp

from repro.kernels import block_matvec as _mv
from repro.kernels import kmeans_assign as _ka
from repro.kernels import rbf_similarity as _rbf
from repro.kernels import ref


_interpret_default = _mv.interpret_default   # one TPU-detection rule


def _pad_rows(a: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = a.shape[0]
    n_pad = ((n + mult - 1) // mult) * mult
    if n_pad == n:
        return a, n
    pad = [(0, n_pad - n)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad), n


def rbf_similarity(x: jax.Array, y: jax.Array, sigma, *, bm: int = 128,
                   bn: int = 128, interpret: bool | None = None) -> jax.Array:
    """exp(-||x_i - y_j||^2 / 2 sigma^2) for all pairs; any (n, m)."""
    if interpret is None:
        interpret = _interpret_default()
    xp, n = _pad_rows(x, bm)
    yp, m = _pad_rows(y, bn)
    out = _rbf.rbf_similarity(xp, yp, sigma, bm=bm, bn=bn, interpret=interpret)
    return out[:n, :m]


def fused_rbf_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                     row_scale: jax.Array | None = None,
                     col_scale: jax.Array | None = None, *,
                     bm: int = 128, bn: int = 128, compute_dtype=None,
                     interpret: bool | None = None) -> jax.Array:
    """diag(row_scale) @ RBF(x, y; sigma) @ diag(col_scale) @ V for any
    (n, d)/(m, d)/(m, b) — the similarity tile is recomputed in-register,
    never materialized.  Omitted scales default to ones; padded rows get
    scale 0 so they contribute nothing."""
    from repro.kernels import fused_rbf_matmat as _frm
    if interpret is None:
        interpret = _interpret_default()
    n, m = x.shape[0], y.shape[0]
    rs = jnp.ones((n,), jnp.float32) if row_scale is None \
        else jnp.asarray(row_scale, jnp.float32)
    cs = jnp.ones((m,), jnp.float32) if col_scale is None \
        else jnp.asarray(col_scale, jnp.float32)
    xp, _ = _pad_rows(x, bm)
    yp, _ = _pad_rows(y, bn)
    Vp, _ = _pad_rows(V, bn)
    rsp, _ = _pad_rows(rs, bm)
    csp, _ = _pad_rows(cs, bn)
    out = _frm.fused_rbf_matmat(xp, yp, Vp, sigma, rsp, csp, bm=bm, bn=bn,
                                compute_dtype=compute_dtype,
                                interpret=interpret)
    return out[:n]


def fused_nystrom_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                         col_scale: jax.Array, col_valid: jax.Array | None = None,
                         *, bm: int = 128, bn: int = 128, compute_dtype=None,
                         interpret: bool | None = None
                         ) -> tuple[jax.Array, jax.Array]:
    """(K @ (col_scale * V), K @ col_valid) for K = RBF(x, y; sigma), any
    (m, d)/(n, d)/(n, b) — the serving-side fused pass: embedding product
    and query degree column from one in-register sweep over the training
    tiles.  ``col_valid`` defaults to ones on the true rows; padded
    training rows get scale/valid 0 so they contribute to neither output."""
    from repro.kernels import fused_rbf_matmat as _frm
    if interpret is None:
        interpret = _interpret_default()
    m, n = x.shape[0], y.shape[0]
    cs = jnp.asarray(col_scale, jnp.float32)
    cv = jnp.ones((n,), jnp.float32) if col_valid is None \
        else jnp.asarray(col_valid, jnp.float32)
    xp, _ = _pad_rows(x, bm)
    yp, _ = _pad_rows(y, bn)
    Vp, _ = _pad_rows(V, bn)
    csp, _ = _pad_rows(cs, bn)
    cvp, _ = _pad_rows(cv, bn)
    O, deg = _frm.fused_nystrom_matmat(xp, yp, Vp, sigma, csp, cvp,
                                       bm=bm, bn=bn,
                                       compute_dtype=compute_dtype,
                                       interpret=interpret)
    return O[:m], deg[:m, 0]


def block_matmat(A: jax.Array, V: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """A @ V for any (n, m) A and (m, b) V (one matrix pass per block)."""
    if interpret is None:
        interpret = _interpret_default()
    n, m = A.shape
    Ap, _ = _pad_rows(A, bm)
    if m % bn:
        m_pad = ((m + bn - 1) // bn) * bn
        Ap = jnp.pad(Ap, ((0, 0), (0, m_pad - m)))
        Vp = jnp.pad(V, ((0, m_pad - m), (0, 0)))
    else:
        Vp = V
    out = _mv.block_matmat(Ap, Vp, bm=bm, bn=bn, interpret=interpret)
    return out[:n]


def block_matvec(A: jax.Array, v: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """A @ v for any (n, m) A — the width-1 view of :func:`block_matmat`."""
    return block_matmat(A, v.reshape(-1, 1), bm=bm, bn=bn,
                        interpret=interpret).reshape(A.shape[0])


def _mv_pad(n: int, bm: int) -> int:
    return ((n + bm - 1) // bm) * bm


def kmeans_assign(points: jax.Array, centers: jax.Array, *, bm: int = 512,
                  interpret: bool | None = None) -> tuple[jax.Array, jax.Array]:
    """(labels, sq-dists) for any n; padded rows are discarded."""
    if interpret is None:
        interpret = _interpret_default()
    p, n = _pad_rows(points, bm)
    idx, dist = _ka.kmeans_assign(p, centers, bm=bm, interpret=interpret)
    return idx[:n], dist[:n]


def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    bq: int = 256, bk: int = 256,
                    interpret: bool | None = None):
    """Fused attention; q (B,H,S,hd), k/v (B,KV,T,hd) — kv heads are
    broadcast to H, sequences padded to tile multiples."""
    from repro.kernels import flash_attention as _fa
    if interpret is None:
        interpret = _interpret_default()
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = min(bq, S)
    bk = min(bk, T)
    s_pad = ((S + bq - 1) // bq) * bq
    t_pad = ((T + bk - 1) // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - S), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    if t_pad != T:
        # mask padded keys via a window/causal trick is insufficient for
        # non-causal; shift them out of range with -inf via key zeroing +
        # causal bound. Simplest robust: rely on causal masking when
        # S==T; otherwise require exact tiles.
        assert causal and s_pad == t_pad, "non-causal padding unsupported"
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :S]


# Re-export oracles for test convenience.
reference = ref
