"""Public jit'd wrappers for the Pallas kernels.

Handles: padding to tile multiples, backend dispatch (TPU -> compiled
kernel; CPU/other -> interpret mode, which runs the same kernel body in
Python for correctness), un-padding of results, and **schedule
resolution**: every wrapper takes ``schedule=`` — ``None`` reproduces the
keyword-tile defaults bit-for-bit, ``"auto"`` consults the persistent
schedule cache (:mod:`repro.tune.cache`), and a
:class:`~repro.tune.Schedule` (or dict of its fields) forces an explicit,
legality-checked schedule.  The per-kernel pad + interpret-autodetect +
legality boilerplate lives in one place (:func:`_resolve` /
:func:`_pad_rows`), not copy-pasted per wrapper.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import (block_matvec as _mv, kmeans_assign as _ka,
                           rbf_similarity as _rbf, ref)


_interpret_default = _mv.interpret_default   # one TPU-detection rule


def _resolve(kernel: str, schedule, *, bm=None, bn=None, compute_dtype=None,
             interpret=None, **shape):
    """One boilerplate site for every wrapper: resolve the schedule value
    against the call-site keyword defaults (auto-detecting ``interpret``
    when unset) and legality-check it for this kernel/shape.  Returns the
    concrete :class:`~repro.tune.Schedule`."""
    from repro.tune.schedule import resolve
    sched, _source = resolve(kernel, schedule, bm=bm, bn=bn,
                             compute_dtype=compute_dtype,
                             interpret=interpret, **shape)
    return sched


def _pad_rows(a: jax.Array, mult: int) -> tuple[jax.Array, int]:
    n = a.shape[0]
    n_pad = ((n + mult - 1) // mult) * mult
    if n_pad == n:
        return a, n
    pad = [(0, n_pad - n)] + [(0, 0)] * (a.ndim - 1)
    return jnp.pad(a, pad), n


def rbf_similarity(x: jax.Array, y: jax.Array, sigma, *, bm: int = 128,
                   bn: int = 128, interpret: bool | None = None,
                   schedule=None) -> jax.Array:
    """exp(-||x_i - y_j||^2 / 2 sigma^2) for all pairs; any (n, m)."""
    s = _resolve("rbf_similarity", schedule, bm=bm, bn=bn,
                 interpret=interpret, n=x.shape[0], m=y.shape[0],
                 d=x.shape[1])
    xp, n = _pad_rows(x, s.bm)
    yp, m = _pad_rows(y, s.bn)
    out = _rbf.rbf_similarity(xp, yp, sigma, bm=s.bm, bn=s.bn,
                              grid_order=s.grid_order, interpret=s.interpret)
    return out[:n, :m]


def fused_rbf_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                     row_scale: jax.Array | None = None,
                     col_scale: jax.Array | None = None, *,
                     bm: int = 128, bn: int = 128, compute_dtype=None,
                     interpret: bool | None = None, schedule=None
                     ) -> jax.Array:
    """diag(row_scale) @ RBF(x, y; sigma) @ diag(col_scale) @ V for any
    (n, d)/(m, d)/(m, b) — the similarity tile is recomputed in-register,
    never materialized.  Omitted scales default to ones; padded rows get
    scale 0 so they contribute nothing."""
    from repro.kernels import fused_rbf_matmat as _frm
    n, m = x.shape[0], y.shape[0]
    s = _resolve("fused_rbf_matmat", schedule, bm=bm, bn=bn,
                 compute_dtype=compute_dtype, interpret=interpret,
                 n=n, m=m, d=x.shape[1], b=V.shape[1])
    rs = jnp.ones((n,), jnp.float32) if row_scale is None \
        else jnp.asarray(row_scale, jnp.float32)
    cs = jnp.ones((m,), jnp.float32) if col_scale is None \
        else jnp.asarray(col_scale, jnp.float32)
    xp, _ = _pad_rows(x, s.bm)
    yp, _ = _pad_rows(y, s.bn)
    Vp, _ = _pad_rows(V, s.bn)
    rsp, _ = _pad_rows(rs, s.bm)
    csp, _ = _pad_rows(cs, s.bn)
    out = _frm.fused_rbf_matmat(xp, yp, Vp, sigma, rsp, csp, bm=s.bm,
                                bn=s.bn, compute_dtype=s.compute_dtype,
                                acc=s.acc, interpret=s.interpret)
    return out[:n]


def fused_nystrom_matmat(x: jax.Array, y: jax.Array, V: jax.Array, sigma,
                         col_scale: jax.Array, col_valid: jax.Array | None = None,
                         *, bm: int = 128, bn: int = 128, compute_dtype=None,
                         interpret: bool | None = None, schedule=None
                         ) -> tuple[jax.Array, jax.Array]:
    """(K @ (col_scale * V), K @ col_valid) for K = RBF(x, y; sigma), any
    (m, d)/(n, d)/(n, b) — the serving-side fused pass: embedding product
    and query degree column from one in-register sweep over the training
    tiles.  ``col_valid`` defaults to ones on the true rows; padded
    training rows get scale/valid 0 so they contribute to neither output."""
    from repro.kernels import fused_rbf_matmat as _frm
    m, n = x.shape[0], y.shape[0]
    s = _resolve("fused_nystrom_matmat", schedule, bm=bm, bn=bn,
                 compute_dtype=compute_dtype, interpret=interpret,
                 m=m, n=n, d=x.shape[1], b=V.shape[1])
    cs = jnp.asarray(col_scale, jnp.float32)
    cv = jnp.ones((n,), jnp.float32) if col_valid is None \
        else jnp.asarray(col_valid, jnp.float32)
    xp, _ = _pad_rows(x, s.bm)
    yp, _ = _pad_rows(y, s.bn)
    Vp, _ = _pad_rows(V, s.bn)
    csp, _ = _pad_rows(cs, s.bn)
    cvp, _ = _pad_rows(cv, s.bn)
    O, deg = _frm.fused_nystrom_matmat(xp, yp, Vp, sigma, csp, cvp,
                                       bm=s.bm, bn=s.bn,
                                       compute_dtype=s.compute_dtype,
                                       acc=s.acc, interpret=s.interpret)
    return O[:m], deg[:m, 0]


def block_matmat(A: jax.Array, V: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool | None = None, schedule=None) -> jax.Array:
    """A @ V for any (n, m) A and (m, b) V (one matrix pass per block)."""
    n, m = A.shape
    s = _resolve("block_matmat", schedule, bm=bm, bn=bn,
                 interpret=interpret, n=n, m=m, b=V.shape[1])
    Ap, _ = _pad_rows(A, s.bm)
    if m % s.bn:
        m_pad = ((m + s.bn - 1) // s.bn) * s.bn
        Ap = jnp.pad(Ap, ((0, 0), (0, m_pad - m)))
        Vp = jnp.pad(V, ((0, m_pad - m), (0, 0)))
    else:
        Vp = V
    out = _mv.block_matmat(Ap, Vp, bm=s.bm, bn=s.bn, acc=s.acc,
                           interpret=s.interpret)
    return out[:n]


def block_matvec(A: jax.Array, v: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool | None = None, schedule=None) -> jax.Array:
    """A @ v for any (n, m) A — the width-1 view of :func:`block_matmat`."""
    return block_matmat(A, v.reshape(-1, 1), bm=bm, bn=bn,
                        interpret=interpret,
                        schedule=schedule).reshape(A.shape[0])


def kmeans_assign(points: jax.Array, centers: jax.Array, *, bm: int = 512,
                  interpret: bool | None = None, schedule=None
                  ) -> tuple[jax.Array, jax.Array]:
    """(labels, sq-dists) for any n; padded rows are discarded."""
    s = _resolve("kmeans_assign", schedule, bm=bm, interpret=interpret,
                 n=points.shape[0], d=points.shape[1], k=centers.shape[0])
    p, n = _pad_rows(points, s.bm)
    idx, dist = _ka.kmeans_assign(p, centers, bm=s.bm, interpret=s.interpret)
    return idx[:n], dist[:n]


def flash_attention(q, k, v, *, causal: bool = True, window: int = -1,
                    bq: int = 256, bk: int = 256,
                    interpret: bool | None = None):
    """Fused attention; q (B,H,S,hd), k/v (B,KV,T,hd) — kv heads are
    broadcast to H, sequences padded to tile multiples.  (Outside the
    schedule layer: its tiles are clamped to the sequence shape, see
    API.md.)"""
    from repro.kernels import flash_attention as _fa
    if interpret is None:
        interpret = _interpret_default()
    B, H, S, hd = q.shape
    KV, T = k.shape[1], k.shape[2]
    if KV != H:
        rep = H // KV
        k = jnp.repeat(k, rep, axis=1)
        v = jnp.repeat(v, rep, axis=1)
    bq = min(bq, S)
    bk = min(bk, T)
    s_pad = ((S + bq - 1) // bq) * bq
    t_pad = ((T + bk - 1) // bk) * bk
    qp = jnp.pad(q, ((0, 0), (0, 0), (0, s_pad - S), (0, 0)))
    kp = jnp.pad(k, ((0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    vp = jnp.pad(v, ((0, 0), (0, 0), (0, t_pad - T), (0, 0)))
    if t_pad != T:
        # mask padded keys via a window/causal trick is insufficient for
        # non-causal; shift them out of range with -inf via key zeroing +
        # causal bound. Simplest robust: rely on causal masking when
        # S==T; otherwise require exact tiles.
        assert causal and s_pad == t_pad, "non-causal padding unsupported"
    out = _fa.flash_attention(qp, kp, vp, causal=causal, window=window,
                              bq=bq, bk=bk, interpret=interpret)
    return out[:, :, :S]


# Re-export oracles for test convenience.
reference = ref
