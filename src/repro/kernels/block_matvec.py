"""Pallas TPU kernels for the eigensolver hot spot: row-blocked mat-vec and
its multi-vector generalization, the row-blocked **mat-mat** (paper §4.3.2).

Grid = (row tiles, col tiles); the output row tile is revisited across the
column dimension and accumulated in place (initialized at j == 0), so the
matrix streams HBM->VMEM once while the vector/block tile stays resident —
the TPU translation of the paper's "move the vector to the data, not the
data".

``block_matmat`` is the canonical kernel: an MXU-shaped
``(bm, bn) @ (bn, b)`` tile product per grid step, amortizing each sweep of
``A`` over all ``b`` columns of ``V`` at once (one matrix pass per block
instead of one per vector).  ``block_matvec`` is its width-1 view.

``interpret`` defaults to auto-detection from ``jax.default_backend()``:
compiled on TPU, interpreter elsewhere — so real TPU runs never silently
take the interpreter path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def interpret_default() -> bool:
    """Interpret only off-TPU (CPU/GPU run the kernel body in Python for
    correctness; TPU compiles it)."""
    return jax.default_backend() != "tpu"


def _matmat_kernel(a_ref, v_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                       # (bm, bn)
    v = v_ref[...]                       # (bn, b)
    acc = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bm, b)
    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def _matmat(A: jax.Array, V: jax.Array, *, bm: int, bn: int,
            interpret: bool) -> jax.Array:
    n, m = A.shape
    b = V.shape[1]
    grid = (n // bm, m // bn)
    return pl.pallas_call(
        _matmat_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        interpret=interpret,
    )(A, V)


def block_matmat(A: jax.Array, V: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """A @ V with (bm, bn) VMEM tiles; A (n, m), V (m, b); shapes must
    divide the tiles — see ops.py for the padding wrapper."""
    if interpret is None:
        interpret = interpret_default()
    n, m = A.shape
    assert V.ndim == 2 and V.shape[0] == m, (A.shape, V.shape)
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    out = _matmat(A, V, bm=bm, bn=bn, interpret=bool(interpret))
    return out.astype(V.dtype)


def block_matvec(A: jax.Array, v: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool | None = None) -> jax.Array:
    """A @ v — the width-1 view of :func:`block_matmat` (the vector is
    reshaped to (m, 1) so the product is an MXU ``dot``, not a VPU
    reduction)."""
    n, m = A.shape
    out = block_matmat(A, v.reshape(m, 1), bm=bm, bn=bn, interpret=interpret)
    return out.reshape(n)
