"""Pallas TPU kernel for the Lanczos hot spot: row-blocked mat-vec (paper §4.3.2).

Grid = (row tiles, col tiles); the output row tile is revisited across the
column dimension and accumulated in place (initialized at j == 0), so the
matrix streams HBM->VMEM once while the vector tile stays resident — the
TPU translation of the paper's "move the vector to the data, not the data".

The vector is reshaped to (m, 1) so the product is an MXU ``dot`` rather
than a VPU reduction.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _matvec_kernel(a_ref, v_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                       # (bm, bn)
    v = v_ref[...]                       # (bn, 1)
    acc = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bm, 1)
    o_ref[...] += acc.astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "interpret"))
def block_matvec(A: jax.Array, v: jax.Array, *, bm: int = 256, bn: int = 512,
                 interpret: bool = True) -> jax.Array:
    """A @ v with (bm, bn) VMEM tiles; shapes must divide — see ops.py."""
    n, m = A.shape
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    v2 = v.reshape(m, 1)
    grid = (n // bm, m // bn)
    out = pl.pallas_call(
        _matvec_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, 1), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, 1), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, 1), jnp.float32),
        interpret=interpret,
    )(A, v2)
    return out.reshape(n).astype(v.dtype)
