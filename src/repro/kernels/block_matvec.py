"""Pallas TPU kernels for the eigensolver hot spot: row-blocked mat-vec and
its multi-vector generalization, the row-blocked **mat-mat** (paper §4.3.2).

Grid = (row tiles, col tiles); the output row tile is revisited across the
column dimension and accumulated in place (initialized at j == 0), so the
matrix streams HBM->VMEM once while the vector/block tile stays resident —
the TPU translation of the paper's "move the vector to the data, not the
data".

``block_matmat`` is the canonical kernel: an MXU-shaped
``(bm, bn) @ (bn, b)`` tile product per grid step, amortizing each sweep of
``A`` over all ``b`` columns of ``V`` at once (one matrix pass per block
instead of one per vector).  ``block_matvec`` is its width-1 view.

``interpret`` defaults to auto-detection from ``jax.default_backend()``:
compiled on TPU, interpreter elsewhere — so real TPU runs never silently
take the interpreter path.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def interpret_default() -> bool:
    """Interpret only off-TPU (CPU/GPU run the kernel body in Python for
    correctness; TPU compiles it)."""
    return jax.default_backend() != "tpu"


def check_tiles(bm: int, bn: int, *, interpret: bool = False,
                kernel: str = "block_matmat") -> None:
    """Reject illegal tile edges with a one-line error instead of a Pallas
    lowering failure: ``bm``/``bn`` must be positive multiples of the f32
    sublane count (8); the reduction tile ``bn`` (the lane dimension of
    the A tile) must additionally be a multiple of the 128 lane width on
    the compiled path (interpret mode relaxes it, so small-tile tests can
    exercise multi-tile grids on small inputs)."""
    for name, v in (("bm", bm), ("bn", bn)):
        if v <= 0 or v % 8:
            raise ValueError(
                f"{kernel}: tile {name}={v} must be a positive multiple of "
                f"8 (the f32 sublane count)")
    if not interpret and bn % 128:
        raise ValueError(
            f"{kernel}: tile bn={bn} must be a multiple of 128 (the TPU "
            f"lane width) for the compiled path; pick bn from "
            f"{{128, 256, 512, ...}} or pass interpret=True")


def _matmat_kernel(a_ref, v_ref, o_ref):
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    a = a_ref[...]                       # (bm, bn)
    v = v_ref[...]                       # (bn, b)
    acc = jax.lax.dot_general(
        a, v, (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)  # (bm, b)
    o_ref[...] += acc.astype(o_ref.dtype)


def _matmat_kernel_scratch(a_ref, v_ref, o_ref, acc_ref):
    """acc='scratch' variant: the running sum lives in an f32 VMEM scratch
    tile and the output is written ONCE, at the last reduction step — the
    revisited output tile is never read back."""
    j = pl.program_id(1)

    @pl.when(j == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    acc_ref[...] += jax.lax.dot_general(
        a_ref[...], v_ref[...], (((1,), (0,)), ((), ())),
        preferred_element_type=jnp.float32)

    @pl.when(j == pl.num_programs(1) - 1)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("bm", "bn", "acc", "interpret"))
def _matmat(A: jax.Array, V: jax.Array, *, bm: int, bn: int, acc: str,
            interpret: bool) -> jax.Array:
    from jax.experimental.pallas import tpu as pltpu
    n, m = A.shape
    b = V.shape[1]
    grid = (n // bm, m // bn)
    kernel = _matmat_kernel if acc == "inplace" else _matmat_kernel_scratch
    scratch = [] if acc == "inplace" else [pltpu.VMEM((bm, b), jnp.float32)]
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((bm, bn), lambda i, j: (i, j)),
            pl.BlockSpec((bn, b), lambda i, j: (j, 0)),
        ],
        out_specs=pl.BlockSpec((bm, b), lambda i, j: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, b), jnp.float32),
        scratch_shapes=scratch,
        interpret=interpret,
    )(A, V)


def block_matmat(A: jax.Array, V: jax.Array, *, bm: int = 256, bn: int = 512,
                 acc: str = "inplace",
                 interpret: bool | None = None) -> jax.Array:
    """A @ V with (bm, bn) VMEM tiles; A (n, m), V (m, b); shapes must
    divide the tiles — see ops.py for the padding wrapper."""
    if interpret is None:
        interpret = interpret_default()
    check_tiles(bm, bn, interpret=bool(interpret))
    if acc not in ("inplace", "scratch"):
        raise ValueError(f"block_matmat: acc must be 'inplace' or "
                         f"'scratch', got {acc!r}")
    n, m = A.shape
    assert V.ndim == 2 and V.shape[0] == m, (A.shape, V.shape)
    assert n % bm == 0 and m % bn == 0, (n, m, bm, bn)
    out = _matmat(A, V, bm=bm, bn=bn, acc=acc, interpret=bool(interpret))
    return out.astype(V.dtype)


def block_matvec(A: jax.Array, v: jax.Array, *, bm: int = 256, bn: int = 512,
                 acc: str = "inplace",
                 interpret: bool | None = None) -> jax.Array:
    """A @ v — the width-1 view of :func:`block_matmat` (the vector is
    reshaped to (m, 1) so the product is an MXU ``dot``, not a VPU
    reduction)."""
    n, m = A.shape
    out = block_matmat(A, v.reshape(m, 1), bm=bm, bn=bn, acc=acc,
                       interpret=interpret)
    return out.reshape(n)
