"""Train-step builders: standard pjit/GSPMD step, and the explicit
shard_map data-parallel step with int8 error-feedback gradient
compression (beyond-paper distributed-optimization option)."""
from __future__ import annotations

import functools
from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distrib import mesh_utils
from repro.models.api import Model
from repro.train import optimizer as opt_lib


def make_train_step(model: Model, optimizer: opt_lib.Optimizer,
                    lr_fn: Callable | None = None):
    """(params, opt_state, batch) -> (params, opt_state, metrics).
    Distribution comes from in/out shardings at jit time (GSPMD)."""
    lr_fn = lr_fn or functools.partial(opt_lib.cosine_lr)
    n_micro = max(1, model.cfg.microbatches)

    def _grads(params, batch):
        if n_micro == 1:
            return jax.value_and_grad(model.loss_fn, has_aux=True)(params, batch)
        # gradient accumulation: scan over microbatches (divides activation
        # memory by n_micro; grads accumulate in f32 at param sharding)
        micro = jax.tree.map(
            lambda x: x.reshape((n_micro, x.shape[0] // n_micro) + x.shape[1:]),
            batch)

        def acc_step(carry, mb):
            g_acc, loss_acc = carry
            (loss, aux), g = jax.value_and_grad(model.loss_fn, has_aux=True)(
                params, mb)
            g_acc = jax.tree.map(
                lambda a, b: a + b.astype(jnp.float32) / n_micro, g_acc, g)
            return (g_acc, loss_acc + loss / n_micro), aux

        g0 = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (grads, loss), auxs = jax.lax.scan(
            acc_step, (g0, jnp.zeros((), jnp.float32)), micro)
        aux = jax.tree.map(lambda a: a[-1], auxs)
        return (loss, aux), grads

    def step(params, opt_state, batch):
        (loss, aux), grads = _grads(params, batch)
        lr = lr_fn(opt_state["count"])
        new_params, new_state = optimizer.update(grads, opt_state, params, lr)
        # NB: sum(g*g) without reshape — jnp.vdot flattens, and reshaping a
        # non-leading-sharded tensor makes GSPMD all-gather the full grads
        gnorm = jnp.sqrt(sum(jnp.sum(jnp.square(g.astype(jnp.float32)))
                             for g in jax.tree.leaves(grads)))
        metrics = {"loss": loss, "lr": lr, "grad_norm": gnorm}
        if model.cfg.num_experts:
            metrics["lb_loss"] = aux["lb_loss"]
        return new_params, new_state, metrics

    return step


# ---------------------------------------------------------------------------
# int8 error-feedback gradient compression over the DP axis
# ---------------------------------------------------------------------------

def _quantize(x: jax.Array):
    scale = jnp.max(jnp.abs(x)) / 127.0 + 1e-12
    q = jnp.clip(jnp.round(x / scale), -127, 127).astype(jnp.int8)
    return q, scale


def compressed_grad_mean(grads, ef_state, mesh: Mesh, axis: str = "data"):
    """All-reduce-mean per-shard grads in int8 with error feedback.

    grads: per-device local gradients (inside shard_map over ``axis``).
    ef_state: residual tree from the previous step (same shapes).
    Returns (mean_grads_f32, new_ef_state).  8x less DP all-reduce traffic
    at the cost of one quantization error carried forward (EF keeps the
    iterate asymptotically unbiased)."""
    def one(g, ef):
        g = g.astype(jnp.float32) + ef
        q, scale = _quantize(g)
        deq = q.astype(jnp.float32) * scale
        new_ef = g - deq
        mean = lax.pmean(deq, axis)
        return mean, new_ef

    flat_g, tdef = jax.tree.flatten(grads)
    flat_e = jax.tree.leaves(ef_state)
    out = [one(g, e) for g, e in zip(flat_g, flat_e)]
    means = jax.tree.unflatten(tdef, [o[0] for o in out])
    efs = jax.tree.unflatten(tdef, [o[1] for o in out])
    return means, efs


def make_compressed_train_step(model: Model, optimizer: opt_lib.Optimizer,
                               mesh: Mesh, lr_fn: Callable | None = None,
                               axis: str = "data"):
    """Pure-DP train step via shard_map: per-shard grads -> int8+EF
    all-reduce -> optimizer.  Params/opt-state replicated; batch sharded on
    dim 0.  (TP/EP composition stays on the GSPMD path — this explicit path
    exists to express the compression, which GSPMD cannot.)"""
    lr_fn = lr_fn or functools.partial(opt_lib.cosine_lr)

    def inner(params, opt_state, ef, batch):
        loss, grads = jax.value_and_grad(
            lambda p, b: model.loss_fn(p, b)[0])(params, batch)
        mean_grads, new_ef = compressed_grad_mean(grads, ef, mesh, axis)
        lr = lr_fn(opt_state["count"])
        new_params, new_state = optimizer.update(mean_grads, opt_state, params, lr)
        return new_params, new_state, new_ef, lax.pmean(loss, axis)

    def step(params, opt_state, ef, batch):
        rep = jax.tree.map(lambda _: P(), params)
        rep_o = jax.tree.map(lambda _: P(), opt_state)
        efp = jax.tree.map(lambda _: P(), ef)
        bspec = jax.tree.map(lambda _: P(axis), batch)
        fn = mesh_utils.shard_map(
            inner, mesh=mesh,
            in_specs=(rep, rep_o, efp, bspec),
            out_specs=(rep, rep_o, efp, P()),
            check_vma=False,
        )
        return fn(params, opt_state, ef, batch)

    # jit the whole round: without it each call re-dispatches the shard_map
    # eagerly (prohibitively slow on jax 0.4's python dispatch path).
    return jax.jit(step)


def init_ef_state(params):
    return jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
