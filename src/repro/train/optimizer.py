"""Optimizers in pure JAX: AdamW and an Adafactor-style factored-moment
variant (needed to fit the trillion-param MoE's optimizer state in HBM).

State trees mirror the param tree so sharding rules propagate 1:1; with
``cfg.shard_opt_over_data`` the launcher additionally shards moments over
the data axis (ZeRO-1)."""
from __future__ import annotations

import dataclasses
from typing import Callable

import jax
import jax.numpy as jnp

from repro.models.params import Spec, is_spec


@dataclasses.dataclass(frozen=True)
class Optimizer:
    init_spec: Callable      # (param_spec_tree) -> state spec tree
    init: Callable           # (params) -> state
    update: Callable         # (grads, state, params, lr) -> (new_params, new_state)
    name: str = "opt"


def _moment_spec(s: Spec, dtype=jnp.float32) -> Spec:
    return Spec(s.shape, s.axes, dtype, init="zeros")


# ---------------------------------------------------------------------------
# AdamW
# ---------------------------------------------------------------------------

def adamw(b1: float = 0.9, b2: float = 0.95, eps: float = 1e-8,
          weight_decay: float = 0.1) -> Optimizer:
    def init_spec(spec_tree):
        return {
            "m": jax.tree.map(_moment_spec, spec_tree, is_leaf=is_spec),
            "v": jax.tree.map(_moment_spec, spec_tree, is_leaf=is_spec),
            "count": Spec((), (), jnp.int32, init="zeros"),
        }

    def init(params):
        zeros = jax.tree.map(lambda p: jnp.zeros(p.shape, jnp.float32), params)
        return {"m": zeros,
                "v": jax.tree.map(jnp.zeros_like, zeros),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1
        bc1 = 1 - b1 ** c.astype(jnp.float32)
        bc2 = 1 - b2 ** c.astype(jnp.float32)

        def upd(g, m, v, p):
            g = g.astype(jnp.float32)
            m = b1 * m + (1 - b1) * g
            v = b2 * v + (1 - b2) * g * g
            step = (m / bc1) / (jnp.sqrt(v / bc2) + eps)
            step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), m, v

        out = jax.tree.map(upd, grads, state["m"], state["v"], params)
        new_p = jax.tree.map(lambda t: t[0], out, is_leaf=lambda x: isinstance(x, tuple))
        new_m = jax.tree.map(lambda t: t[1], out, is_leaf=lambda x: isinstance(x, tuple))
        new_v = jax.tree.map(lambda t: t[2], out, is_leaf=lambda x: isinstance(x, tuple))
        return new_p, {"m": new_m, "v": new_v, "count": c}

    return Optimizer(init_spec, init, update, "adamw")


# ---------------------------------------------------------------------------
# Adafactor-style (factored second moment for >=2-D tensors, first moment
# in bf16): ~2.5 bytes/param of state vs Adam's 8.
# ---------------------------------------------------------------------------

def adafactor(b2: float = 0.999, eps: float = 1e-30,
              weight_decay: float = 0.0, clip: float = 1.0) -> Optimizer:
    def factored(shape) -> bool:
        return len(shape) >= 2 and shape[-1] > 1 and shape[-2] > 1

    def init_spec(spec_tree):
        def vr(s: Spec):
            if factored(s.shape):
                return Spec(s.shape[:-1], s.axes[:-1], jnp.float32, init="zeros")
            return _moment_spec(s)

        def vc(s: Spec):
            if factored(s.shape):
                return Spec(s.shape[:-2] + s.shape[-1:], s.axes[:-2] + s.axes[-1:],
                            jnp.float32, init="zeros")
            return Spec((1,), (None,), jnp.float32, init="zeros")

        return {
            "vr": jax.tree.map(vr, spec_tree, is_leaf=is_spec),
            "vc": jax.tree.map(vc, spec_tree, is_leaf=is_spec),
            "count": Spec((), (), jnp.int32, init="zeros"),
        }

    def init(params):
        def vr(p):
            return jnp.zeros(p.shape[:-1] if factored(p.shape) else p.shape, jnp.float32)

        def vc(p):
            return jnp.zeros(p.shape[:-2] + p.shape[-1:] if factored(p.shape) else (1,),
                             jnp.float32)

        return {"vr": jax.tree.map(vr, params), "vc": jax.tree.map(vc, params),
                "count": jnp.zeros((), jnp.int32)}

    def update(grads, state, params, lr):
        c = state["count"] + 1

        def upd(g, vr, vc, p):
            g = g.astype(jnp.float32)
            g2 = g * g + eps
            if factored(g.shape):
                vr = b2 * vr + (1 - b2) * jnp.mean(g2, axis=-1)
                vc = b2 * vc + (1 - b2) * jnp.mean(g2, axis=-2)
                r = vr / jnp.maximum(jnp.mean(vr, axis=-1, keepdims=True), eps)
                denom = jnp.sqrt(r[..., None] * vc[..., None, :] + eps)
            else:
                vr = b2 * vr + (1 - b2) * g2
                denom = jnp.sqrt(vr + eps)
            step = g / denom
            norm = jnp.sqrt(jnp.mean(step * step))
            step = step / jnp.maximum(1.0, norm / clip)
            if weight_decay:
                step = step + weight_decay * p.astype(jnp.float32)
            return (p.astype(jnp.float32) - lr * step).astype(p.dtype), vr, vc

        out = jax.tree.map(upd, grads, state["vr"], state["vc"], params)
        pick = lambda i: jax.tree.map(lambda t: t[i], out,
                                      is_leaf=lambda x: isinstance(x, tuple))
        return pick(0), {"vr": pick(1), "vc": pick(2), "count": c}

    return Optimizer(init_spec, init, update, "adafactor")


def get(name: str) -> Optimizer:
    return {"adamw": adamw, "adafactor": adafactor}[name]()


def cosine_lr(step, *, peak: float = 3e-4, warmup: int = 100,
              total: int = 10_000, floor: float = 0.1):
    step = step.astype(jnp.float32) if hasattr(step, "astype") else float(step)
    warm = peak * jnp.minimum(1.0, step / warmup)
    t = jnp.clip((step - warmup) / jnp.maximum(total - warmup, 1), 0.0, 1.0)
    cos = peak * (floor + (1 - floor) * 0.5 * (1 + jnp.cos(jnp.pi * t)))
    return jnp.where(step < warmup, warm, cos)
