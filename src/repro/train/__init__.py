# Training substrate: optimizers, train-step builders, LR schedules,
# gradient compression, distributed-training glue.
