"""GPipe-style pipeline parallelism over a mesh axis (the "pod" axis of
the multi-pod production mesh).

The L stacked layers are split into P = |axis| contiguous stages; layer
params shard their leading (layers) dim over the axis, so each pod holds
only its stage's weights.  M microbatches flow through the classic GPipe
schedule (T = M + P - 1 ticks); stage boundaries are one
``lax.ppermute`` per tick — autodiff transposes it to the reverse
permute, so ``jax.grad`` through :func:`pipeline_apply` yields the 1B1F
backward schedule for free.

Bubble fraction = (P-1)/(M+P-1); pick M >= 4P in production.
"""
from __future__ import annotations

from typing import Callable

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import Mesh, PartitionSpec as P

from repro.distrib import mesh_utils


def pipeline_apply(layer_fn: Callable, stacked_params, x: jax.Array,
                   mesh: Mesh, axis: str = "pod",
                   microbatches: int | None = None) -> jax.Array:
    """Run ``layer_fn`` over L stacked layers, pipelined over ``axis``.

    layer_fn: (layer_params, x_mb) -> x_mb  (one layer, one microbatch)
    stacked_params: pytree with leading dim L (L % P == 0)
    x: (B, ...) global batch; B % microbatches == 0
    Returns (B, ...) with the same sharding as the input batch dim.
    """
    n_stage = mesh.shape[axis]
    leaves = jax.tree.leaves(stacked_params)
    L = leaves[0].shape[0]
    assert L % n_stage == 0, (L, n_stage)
    per_stage = L // n_stage
    M = microbatches or n_stage * 2
    B = x.shape[0]
    assert B % M == 0, (B, M)
    mb = B // M

    p_specs = jax.tree.map(lambda _: P(axis), stacked_params)
    other = tuple(a for a in mesh.axis_names if a != axis)

    def stage_body(params_local, xs):
        # params_local: (per_stage, ...) this stage's layers
        # xs: (M, mb, ...) microbatches, replicated over `axis`
        idx = lax.axis_index(axis)
        T = M + n_stage - 1
        xs = jnp.concatenate(
            [xs, jnp.zeros((n_stage - 1,) + xs.shape[1:], xs.dtype)], 0)

        def stage_fn(x_mb):
            def one(x, lp):
                return layer_fn(lp, x), None
            out, _ = lax.scan(one, x_mb, params_local)
            return out

        def tick(carry, t):
            buf, prev_out = carry
            # receive from the previous stage (stage 0 keeps its own feed)
            recv = lax.ppermute(
                prev_out, axis,
                perm=[(i, (i + 1) % n_stage) for i in range(n_stage)])
            feed_idx = jnp.clip(t, 0, T - 1)
            own = lax.dynamic_index_in_dim(xs, feed_idx, 0, keepdims=False)
            inp = jnp.where(idx == 0, own, recv)
            out = stage_fn(inp)
            # last stage writes its result for microbatch m = t - (P-1)
            write_m = jnp.clip(t - (n_stage - 1), 0, M - 1)
            do_write = (t >= n_stage - 1) & (idx == n_stage - 1)
            cur = lax.dynamic_index_in_dim(buf, write_m, 0, keepdims=False)
            new = jnp.where(do_write, out, cur)
            buf = lax.dynamic_update_index_in_dim(buf, new, write_m, 0)
            return (buf, out), None

        buf0 = jnp.zeros((M,) + xs.shape[1:], x.dtype)
        buf0 = mesh_utils.pvary(buf0, (axis,) + tuple(other))
        prev0 = jnp.zeros(xs.shape[1:], x.dtype)
        prev0 = mesh_utils.pvary(prev0, (axis,) + tuple(other))
        (buf, _), _ = lax.scan(tick, (buf0, prev0), jnp.arange(T))
        # broadcast the last stage's buffer to every stage (masked psum)
        buf = lax.psum(jnp.where(idx == n_stage - 1, buf, 0.0), axis)
        return buf

    xs = x.reshape((M, mb) + x.shape[1:])
    fn = mesh_utils.shard_map(
        stage_body, mesh=mesh,
        in_specs=(p_specs, P()),
        out_specs=P(),
        check_vma=False,
    )
    out = fn(stacked_params, xs)
    return out.reshape((B,) + out.shape[2:])


def bubble_fraction(n_stage: int, microbatches: int) -> float:
    return (n_stage - 1) / (microbatches + n_stage - 1)
