"""Fault-tolerance substrate: step-addressed, async, atomic checkpoints
with keep-last-k GC and *elastic* restore (resharding onto whatever mesh
the restarted job runs with).

The paper relies on Hadoop/HBase persistence for mid-pipeline recovery;
here every long-running loop (Lanczos state, k-means centers, LM train
state) checkpoints through this manager.  Layout: one ``.npz`` per step
holding the flattened pytree (logical, unsharded arrays), so a job killed
on 512 devices restores fine on 8 (or vice versa) — restore simply
``device_put``s each leaf with the *current* sharding."""
from __future__ import annotations

import os
import re
import threading
from typing import Any

import jax
import numpy as np


def _flatten_with_paths(tree) -> dict[str, np.ndarray]:
    flat = {}
    for path, leaf in jax.tree_util.tree_flatten_with_path(tree)[0]:
        key = "/".join(str(getattr(p, "key", getattr(p, "idx", p))) for p in path)
        flat[key] = np.asarray(leaf)
    return flat


class CheckpointManager:
    def __init__(self, directory: str, keep: int = 3, async_write: bool = True):
        self.dir = directory
        self.keep = keep
        self.async_write = async_write
        self._lock = threading.Lock()
        self._pending: threading.Thread | None = None
        os.makedirs(directory, exist_ok=True)

    # -- write ---------------------------------------------------------------
    def save(self, step: int, tree: Any, name: str = "state") -> str:
        """Atomic (tmp + rename) write; async by default."""
        flat = _flatten_with_paths(jax.device_get(tree))
        path = os.path.join(self.dir, f"{name}_{step:010d}.npz")

        def write():
            tmp = path + ".tmp"
            with open(tmp, "wb") as f:
                np.savez(f, **flat)
            os.replace(tmp, path)
            self._gc(name)

        if self.async_write:
            self.wait()
            t = threading.Thread(target=write, daemon=True)
            t.start()
            self._pending = t
        else:
            write()
        return path

    def save_phase(self, phase: str, tree: Any) -> str:
        """Named phase snapshot (the spectral pipeline's HBase analogue)."""
        return self.save(0, tree, name=f"phase_{phase}")

    def wait(self):
        if self._pending is not None:
            self._pending.join()
            self._pending = None

    def _gc(self, name: str):
        with self._lock:
            steps = self._list_steps(name)
            for s in steps[: -self.keep]:
                try:
                    os.remove(os.path.join(self.dir, f"{name}_{s:010d}.npz"))
                except OSError:
                    pass

    # -- read ----------------------------------------------------------------
    def _list_steps(self, name: str) -> list[int]:
        """Directory scan WITHOUT the lock — callers must hold ``_lock``
        (the async writer GCs under it, so an unlocked listing can observe
        a torn set of files mid-removal)."""
        pat = re.compile(rf"{re.escape(name)}_(\d+)\.npz$")
        steps = []
        for fn in os.listdir(self.dir):
            m = pat.match(fn)
            if m:
                steps.append(int(m.group(1)))
        return sorted(steps)

    def all_steps(self, name: str = "state") -> list[int]:
        with self._lock:
            return self._list_steps(name)

    def latest_step(self, name: str = "state") -> int | None:
        steps = self.all_steps(name)
        return steps[-1] if steps else None

    def restore(self, template: Any, step: int | None = None,
                name: str = "state", shardings: Any = None) -> Any:
        """Restore into the structure of ``template``; if ``shardings`` is
        given each leaf is placed with it (elastic resharding)."""
        self.wait()
        if step is None:
            step = self.latest_step(name)
            if step is None:
                raise FileNotFoundError(f"no checkpoint '{name}' in {self.dir}")
        path = os.path.join(self.dir, f"{name}_{step:010d}.npz")
        with np.load(path) as data:
            flat = dict(data)
        paths, treedef = jax.tree_util.tree_flatten_with_path(template)
        keys = ["/".join(str(getattr(p, "key", getattr(p, "idx", p)))
                         for p in path_keys) for path_keys, _ in paths]
        missing = sorted(k for k in keys if k not in flat)
        extra = sorted(set(flat) - set(keys))
        if missing or extra:
            raise ValueError(
                f"checkpoint '{name}' step {step} does not match the "
                f"restore template: missing from checkpoint {missing}, "
                f"not in template {extra}")
        leaves = []
        shard_leaves = (jax.tree.leaves(shardings) if shardings is not None
                        else [None] * len(paths))
        for key, shard in zip(keys, shard_leaves):
            arr = flat[key]
            if shard is not None:
                leaves.append(jax.device_put(arr, shard))
            else:
                leaves.append(jax.numpy.asarray(arr))
        return jax.tree_util.tree_unflatten(treedef, leaves)
