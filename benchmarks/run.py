"""Benchmark harness — one function per paper table/figure.

Prints ``name,us_per_call,derived`` CSV rows.

  table1_phases     paper Table 5-1: the three pipeline phases.  Measured
                    single-worker wall time at n=4096, plus the
                    balanced-schedule projection T(m) for m workers
                    (tiles-per-device model validated by the schedule
                    property tests; wall speedup is unmeasurable on one
                    CPU core, and the projection is labeled as such).
  fig5_speedup      paper Fig. 5 trend: projected total speedup vs m,
                    including the comm term that produces the paper's
                    critical-machine-count plateau.
  rings_quality     paper §3.1 claim: spectral vs k-means on non-convex data.
  lanczos_residual  eigensolver quality vs iteration count.
  assigner_backends registry assigners: full Lloyd vs mini-batch rounds.
  kernels           Pallas kernel wrappers (interpret) vs jnp oracle.
  engine_ooc        the out-of-core MapReduce engine: (a) label agreement
                    vs the in-memory knn-topt backend on a shared
                    reference problem, (b) clustering an n whose dense
                    (n, n) similarity would not fit the shard-store
                    budget — shards demonstrably spilled to disk.
  eigensolver_sweep lanczos vs block-lanczos vs chebdav on the dense and
                    out-of-core paths at n=4096: matrix passes per
                    eigenpair, wall time, shard-store loads per
                    eigensolve, and chebdav-vs-eigh label agreement on
                    the paper config.  Writes BENCH_eigensolvers.json.
  fused_sweep       dense vs fused-rbf vs ooc across an n sweep: wall
                    time, peak affinity-stage bytes, ARI vs dense/eigh
                    labels, and the engine's prefetch hit counters under
                    a spill-forcing budget.  Writes BENCH_fused.json.
  async_sweep       the async engine vs its own sequential ancestor at
                    n=4096 under a spill-forcing budget: pipelined build
                    + prefetched/double-buffered eigensolve + async spill
                    writes vs the PR-7 schedule (workers=1, synchronous
                    spills, per-column scatter), plus prefetch hit rate,
                    ooc-vs-fused matmat cost, bitwise scheduler parity
                    and the dense-oracle ARI.  Writes BENCH_async.json.
  serve_sweep       the serving path: fused vs dense out-of-sample
                    transform (wall + peak bytes + label parity) at
                    m queries vs an n=8192 model, save/load round-trip
                    bitwise predict parity, and the batched predict
                    service's throughput.  Writes BENCH_serve.json.
  obs_overhead      the observability tax: the fused fit path with the
                    obs layer on vs off (best-of-3 each), asserting the
                    <= 2% overhead contract.  Writes BENCH_obs.json.
  chaos_sweep       fault-tolerance acceptance: injected task failures,
                    spill corruption and stragglers at n=4096 must
                    recover to labels bitwise-equal to the fault-free
                    run (ARI == 1); the resilience machinery costs <= 3%
                    build wall when nothing fails; and the serve path
                    under 2x overload sheds with typed rejections while
                    admitted p99 stays <= 2x the unloaded p99.  Writes
                    BENCH_chaos.json.

Run ``python benchmarks/run.py [mode ...]`` — no mode runs the full
default suite; ``eigensolver_sweep`` / ``fused_sweep`` run just the
sweeps.
"""
from __future__ import annotations

import argparse
import json
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import SpectralClustering, ari
from repro.core import kmeans as km
from repro.core import lanczos as lz
from repro.core import laplacian as lp
from repro.core import similarity as sim
from repro.data import synthetic

ROWS: list[tuple[str, float, str]] = []


def row(name: str, us: float, derived: str = ""):
    ROWS.append((name, us, derived))
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


# ---------------------------------------------------------------------------

def table1_phases(n: int = 4096, k: int = 8):
    """Measured phase times (m=1) + balanced-schedule projections."""
    pts, _ = synthetic.blobs(n, k, dim=8, seed=0)
    x = jnp.asarray(pts)

    sim_fn = jax.jit(lambda a: sim.dense_similarity(a, 1.0))
    us_sim, S = _timeit(sim_fn, x)
    row("table1/similarity_m1", us_sim, f"n={n}")

    mv = lp.make_dense_shifted_operator(S)
    lan_fn = jax.jit(lambda s: lz.run(mv, s, 8))
    state0 = lz.init_state(n, 64, jax.random.PRNGKey(0))
    us_lan8, state = _timeit(lan_fn, state0)
    us_lan = us_lan8 / 8 * 64          # 64 iterations total
    row("table1/lanczos_m1", us_lan, "64 iters")

    evals, Z = lz.topk_of_shifted(lz.run(mv, state0, 64), k)
    Y = km.normalize_rows(Z)
    c0 = km.kmeans_plusplus_init(Y, k, jax.random.PRNGKey(1))
    km_fn = jax.jit(lambda y, c: km.lloyd_step(
        y, jnp.ones((y.shape[0],)), km.KMeansState(
            it=jnp.zeros((), jnp.int32), centers=c, shift=jnp.asarray(jnp.inf))))
    us_km1, _ = _timeit(km_fn, Y, c0)
    us_km = us_km1 * 50
    row("table1/kmeans_m1", us_km, "50 rounds")

    # projection: the triangular schedule gives each of m workers (2m+1)
    # tiles out of 2m(2m+1)/2 upper tiles -> per-worker share (2m+1)/(2m)
    # of one row-block; lanczos matvec and kmeans shard 1/m.  The comm
    # term alpha*log2(m) is a collective-latency proxy (the paper's
    # critical-machine-count effect).
    alpha_us = 2000.0
    for m in (1, 2, 4, 6, 8, 10):
        t_sim = us_sim * (2 * m + 1) / (2 * m) / m
        t_lan = us_lan / m + 64 * alpha_us * np.log2(max(m, 2))
        t_km = us_km / m + 50 * alpha_us * np.log2(max(m, 2))
        row(f"table1/projected_total_m{m}", t_sim + t_lan + t_km,
            f"sim={t_sim:.0f}us lan={t_lan:.0f}us km={t_km:.0f}us")


def fig5_speedup():
    """Paper Fig. 5: speedup flattens past the critical machine count."""
    base = None
    for m in (1, 2, 4, 6, 8, 10):
        work = 1e6 / m
        comm = 12000.0 * np.log2(max(m, 2)) * 10
        total = work + comm
        if base is None:
            base = total
        row(f"fig5/speedup_m{m}", total, f"speedup={base / total:.2f}")


def rings_quality(n: int = 400):
    pts, truth = synthetic.rings(n, 2, seed=0)
    est = SpectralClustering(k=2, affinity="dense", eigensolver="eigh",
                             sigma=0.25, lanczos_steps=48)
    t0 = time.perf_counter()
    est.fit(jnp.asarray(pts))
    us = (time.perf_counter() - t0) * 1e6
    labels = np.asarray(est.labels_)
    acc_s = max(np.mean(labels == truth), np.mean(labels == 1 - truth))
    kl, _ = km.kmeans(jnp.asarray(pts), 2, jax.random.PRNGKey(0))
    kl = np.asarray(kl)
    acc_k = max(np.mean(kl == truth), np.mean(kl == 1 - truth))
    row("rings/spectral", us, f"acc={acc_s:.3f}")
    row("rings/kmeans_baseline", 0.0, f"acc={acc_k:.3f}")


def lanczos_residual(n: int = 512):
    pts, _ = synthetic.blobs(n, 4, seed=3)
    S = sim.dense_similarity(jnp.asarray(pts), 1.0)
    mv = lp.make_dense_shifted_operator(S)
    for steps in (8, 16, 32, 64):
        t0 = time.perf_counter()
        state = lz.lanczos(mv, n, steps, jax.random.PRNGKey(0))
        vals, vecs = lz.topk_of_shifted(state, 4)
        us = (time.perf_counter() - t0) * 1e6
        res = float(jnp.max(lz.residuals(mv, vals, vecs, shift=2.0)))
        row(f"lanczos/steps{steps}", us, f"max_residual={res:.2e}")


def assigner_backends(n: int = 8192, k: int = 8):
    """Registry assigners on one embedding: full Lloyd vs mini-batch.

    Mini-batch touches ``batch`` points per round instead of ``n`` — the
    large-n phase-3 backend of the estimator API."""
    y = jax.random.normal(jax.random.PRNGKey(0), (n, k))
    valid = jnp.ones((n,))
    key = jax.random.PRNGKey(1)
    c0 = km.kmeans_plusplus_init(y, k, key)

    lloyd = jax.jit(lambda y, c: km.lloyd_step(
        y, jnp.ones((y.shape[0],)), km.KMeansState(
            it=jnp.zeros((), jnp.int32), centers=c,
            shift=jnp.asarray(jnp.inf))).centers)
    us_l, _ = _timeit(lloyd, y, c0)
    row("assigner/lloyd_round", us_l, f"n={n}")

    mb = jax.jit(lambda y, v, c: km.minibatch_kmeans(
        y, v, k, jax.random.PRNGKey(2), iters=1, batch=256, centers0=c)[1])
    us_m, _ = _timeit(mb, y, valid, c0)
    row("assigner/minibatch_round", us_m, f"batch=256 speedup={us_l / us_m:.1f}x")


def kernels():
    from repro.kernels import ops, ref
    x = jax.random.normal(jax.random.PRNGKey(0), (256, 64))
    y = jax.random.normal(jax.random.PRNGKey(1), (256, 64))
    us, _ = _timeit(lambda: ops.rbf_similarity(x, y, 1.0, interpret=True))
    flops = 2 * 256 * 256 * 64
    row("kernels/rbf_similarity_interp", us, f"{flops / us / 1e3:.2f} GFLOP/s")
    us_r, _ = _timeit(lambda: ref.rbf_similarity(x, y, 1.0))
    row("kernels/rbf_similarity_ref", us_r, "jnp oracle")

    A = jax.random.normal(jax.random.PRNGKey(2), (1024, 1024))
    v = jax.random.normal(jax.random.PRNGKey(3), (1024,))
    us, _ = _timeit(lambda: ops.block_matvec(A, v, interpret=True))
    row("kernels/block_matvec_interp", us, f"{2 * 1024**2 / us / 1e3:.2f} GFLOP/s")
    us_r, _ = _timeit(lambda: ref.block_matvec(A, v))
    row("kernels/block_matvec_ref", us_r, "jnp oracle")

    V8 = jax.random.normal(jax.random.PRNGKey(6), (1024, 8))
    us, _ = _timeit(lambda: ops.block_matmat(A, V8, interpret=True))
    row("kernels/block_matmat_b8_interp", us,
        f"{2 * 8 * 1024**2 / us / 1e3:.2f} GFLOP/s (8 vectors, one A pass)")
    us_r, _ = _timeit(lambda: ref.block_matmat(A, V8))
    row("kernels/block_matmat_b8_ref", us_r, "jnp oracle")

    p = jax.random.normal(jax.random.PRNGKey(4), (2048, 16))
    c = jax.random.normal(jax.random.PRNGKey(5), (16, 16))
    us, _ = _timeit(lambda: ops.kmeans_assign(p, c, interpret=True))
    row("kernels/kmeans_assign_interp", us, "")
    us_r, _ = _timeit(lambda: ref.kmeans_assign(p, c))
    row("kernels/kmeans_assign_ref", us_r, "jnp oracle")


def engine_ooc(n_ref: int = 512, n_big: int = 4096, k: int = 3):
    """The out-of-core engine vs the in-memory dense-path ceiling.

    Quality: ooc-topt and knn-topt labels on the same reference points,
    scored with ARI (>= 0.95 is the engine's backend contract).  Scale:
    cluster ``n_big`` points under a shard-store budget that could hold at
    most a (budget/4)^0.5-point dense similarity — n_big is several times
    that ceiling, so finishing at all requires the shards to spill.
    """
    from repro import engine
    from repro.cluster import ari
    from repro.data.chunked import BlobChunks

    # (a) agreement on a shared reference problem (spread 0.8: weakly
    # connected blobs -> distinct small eigenvalues, stable eigenvectors)
    pts, _ = synthetic.blobs(n_ref, k, dim=4, spread=0.8, seed=0)
    t = 16
    ref = SpectralClustering(k=k, affinity="knn-topt", sparsify_t=t,
                             sigma=1.0, seed=0,
                             lanczos_steps=96).fit(jnp.asarray(pts))
    t0 = time.perf_counter()
    ooc = SpectralClustering(k=k, affinity="ooc-topt", sparsify_t=t,
                             sigma=1.0, seed=0, chunk_size=128,
                             lanczos_steps=96).fit(jnp.asarray(pts))
    us = (time.perf_counter() - t0) * 1e6
    a = ari(np.asarray(ref.labels_), np.asarray(ooc.labels_))
    row("engine/agreement_vs_knn_topt", us, f"n={n_ref} ari={a:.3f}")

    # (b) past the dense ceiling: budget fits at most a ~n_dense dense S
    budget = 1 << 19                              # 512 KiB shard-store RAM
    n_dense = int(np.sqrt(budget / 4))            # dense f32 S ceiling
    reader = BlobChunks(n_big, k, chunk_size=512, dim=4, spread=0.8, seed=0)
    # path="ooc" pins the classic spilling pipeline: this benchmark is the
    # CSR-shard demonstration (the auto router would send a fits-in-memory
    # point set to the fused path — that trade is fused_sweep's subject)
    plan = engine.JobPlan(n=n_big, chunk_size=512, t=t, k=k, sigma=1.0,
                          memory_budget=budget, lanczos_steps=96, seed=0,
                          path="ooc")
    t0 = time.perf_counter()
    res = engine.run_job(plan, reader)
    us = (time.perf_counter() - t0) * 1e6
    quality = ari(reader.all_labels(), res.labels)
    st = res.stats
    row("engine/ooc_beyond_dense_ceiling", us,
        f"n={n_big} ({n_big / n_dense:.1f}x dense ceiling {n_dense}) "
        f"budget={budget} spilled_shards={st['spilled_shards']} "
        f"bytes_spilled={st['store_bytes_spilled']} "
        f"peak_ram={st['store_peak_ram_bytes']} ari_vs_planted={quality:.3f}")
    assert st["store_bytes_spilled"] > 0, "budget was meant to force spills"


def eigensolver_sweep(n: int = 4096, k: int = 3, block_size: int = 8,
                      out_json: str = "BENCH_eigensolvers.json"):
    """lanczos vs block-lanczos vs chebdav: matrix passes per eigenpair,
    wall time, and (out-of-core) shard-store loads per eigensolve.

    The block contract this validates: at block width b the same Krylov
    dimension costs ~1/b the matrix passes, and on the engine path each
    pass pulls every CSR shard from the (spilling) store once per BLOCK
    instead of once per vector — so store loads per eigensolve drop by
    the same factor.
    """
    from repro import engine
    from repro.cluster.affinity import AFFINITIES
    from repro.cluster.eigensolvers import EIGENSOLVERS
    from repro.data.chunked import BlobChunks
    from repro.distrib import mesh_utils

    results: dict = {"n": n, "k": k, "block_size": block_size, "rows": []}
    solvers = ("lanczos", "block-lanczos", "chebdav")

    def solve(est, op, path, extra=None):
        key = jax.random.PRNGKey(1)
        t0 = time.perf_counter()
        evals, Z, info = EIGENSOLVERS.get(est.eigensolver)(est, op, key)
        jax.block_until_ready(Z)
        wall = time.perf_counter() - t0
        rec = {"path": path, "solver": est.eigensolver,
               "matrix_passes": int(info["matrix_passes"]),
               "passes_per_eigenpair": info["matrix_passes"] / est.k,
               "wall_s": round(wall, 4),
               "eigenvalues": np.asarray(evals).tolist()}
        rec.update(extra or {})
        results["rows"].append(rec)
        row(f"eigsweep/{path}_{est.eigensolver}", wall * 1e6,
            f"passes={rec['matrix_passes']} "
            f"per_pair={rec['passes_per_eigenpair']:.1f}")
        return rec

    def est_for(solver):
        return SpectralClustering(
            k=k, eigensolver=solver, sigma=1.0, lanczos_steps=64,
            block_size=block_size if solver == "block-lanczos" else None)

    # ---- dense in-memory path ------------------------------------------
    # eigh rides along: its matrix_passes (n_pad — the O(n^3)
    # factorization in the iterative solvers' cost unit) makes the rows
    # comparable across ALL registered eigensolvers
    pts, _ = synthetic.blobs(n, k, dim=4, spread=0.8, seed=0)
    mesh = mesh_utils.local_mesh("rows")
    op = AFFINITIES.get("dense")(est_for("lanczos"), jnp.asarray(pts),
                                 jnp.asarray(1.0), mesh)
    dense_recs = {s: solve(est_for(s), op, "dense")
                  for s in (*solvers, "eigh")}

    # ---- out-of-core engine path (budget forces spills) ----------------
    budget = 1 << 19
    reader = BlobChunks(n, k, chunk_size=512, dim=4, spread=0.8, seed=0)
    plan = engine.JobPlan(n=n, chunk_size=512, t=16, k=k, sigma=1.0,
                          memory_budget=budget, seed=0)
    graph, _sig = engine.build_graph(reader, plan)
    op_ooc = engine.make_normalized_operator(graph)
    ooc_recs = {}
    for s in solvers:
        before = dict(graph.store.stats)
        ooc_recs[s] = solve(
            est_for(s), op_ooc, "ooc-topt",
            extra={"store_gets": None})  # filled below
        after = graph.store.stats
        ooc_recs[s]["store_gets"] = after["gets"] - before["gets"]
        ooc_recs[s]["store_loads"] = after["loads"] - before["loads"]
        row(f"eigsweep/ooc_store_{s}", 0.0,
            f"gets={ooc_recs[s]['store_gets']} "
            f"loads={ooc_recs[s]['store_loads']}")

    for path, recs in (("dense", dense_recs), ("ooc", ooc_recs)):
        red = (recs["lanczos"]["matrix_passes"]
               / max(recs["block-lanczos"]["matrix_passes"], 1))
        results[f"{path}_pass_reduction_b{block_size}"] = red
        row(f"eigsweep/{path}_pass_reduction", 0.0,
            f"b={block_size} -> {red:.1f}x fewer passes/eigenpair")
        assert red >= 4, (path, red)
    load_red = (ooc_recs["lanczos"]["store_gets"]
                / max(ooc_recs["block-lanczos"]["store_gets"], 1))
    results["ooc_store_get_reduction"] = load_red
    row("eigsweep/ooc_store_get_reduction", 0.0, f"{load_red:.1f}x")

    # ---- chebdav vs eigh oracle on the paper config --------------------
    from repro.configs import spectral_paper
    kk = spectral_paper.CONFIG.k
    pts_p, _ = synthetic.blobs(600, kk, dim=8, spread=0.6, seed=0)
    xp = jnp.asarray(pts_p)
    base = dict(affinity="triangular", sigma=1.0, seed=0,
                lanczos_steps=spectral_paper.CONFIG.lanczos_steps)
    eigh_est = SpectralClustering(kk, eigensolver="eigh", **base).fit(xp)
    chb_est = SpectralClustering(kk, eigensolver="chebdav", **base).fit(xp)
    a = ari(np.asarray(eigh_est.labels_), np.asarray(chb_est.labels_))
    results["chebdav_vs_eigh_ari"] = float(a)
    row("eigsweep/chebdav_vs_eigh", 0.0,
        f"paper config k={kk} ari={a:.3f} "
        f"passes={chb_est.info_['matrix_passes']}")
    assert a >= 0.95, a

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json}")


def fused_sweep(ns=(1024, 2048, 8192), k: int = 8,
                out_json: str = "BENCH_fused.json"):
    """dense vs fused-rbf vs ooc across an n sweep (paper-config blobs:
    k=8, dim=8, lanczos_steps=64, block width 8).

    Per n: wall seconds, peak affinity-stage bytes (dense: the
    materialized n_pad^2 similarity; fused: points + scale vectors +
    VMEM tiles, as advertised by the operator; ooc: shard-store peak
    RAM), and label agreement — fused vs the dense-path labels at every
    n, both vs the exact eigh labels where eigh is affordable.  The ooc
    rows run under a spill-forcing budget and report the prefetch
    hit/miss counters of the double-buffered shard stream.

    The contract this validates (ISSUE 4 acceptance): at n=8192 the
    fused path matches dense labels at ARI >= 0.99 with <= 10% of the
    dense path's affinity memory.
    """
    from repro import engine
    from repro.cluster import ari
    from repro.data.chunked import ArrayChunks
    from repro.distrib import mesh_utils

    results: dict = {"k": k, "dim": 8, "lanczos_steps": 64, "block_size": 8,
                     "rows": []}

    def fit(affinity, pts, **kw):
        est = SpectralClustering(
            k=k, affinity=affinity, eigensolver="block-lanczos",
            block_size=8, sigma=1.0, seed=0, lanczos_steps=64, **kw)
        t0 = time.perf_counter()
        est.fit(jnp.asarray(pts))
        return est, time.perf_counter() - t0

    mesh = mesh_utils.local_mesh("rows")
    m = mesh_utils.mesh_size(mesh)
    for n in ns:
        pts, _truth = synthetic.blobs(n, k, dim=8, spread=0.6, seed=0)
        n_pad = ((n + m - 1) // m) * m

        dense_est, dense_s = fit("dense", pts)
        dense_labels = np.asarray(dense_est.labels_)
        dense_peak = n_pad * n_pad * 4               # materialized f32 S
        row(f"fused_sweep/dense_n{n}", dense_s * 1e6,
            f"peak_affinity_bytes={dense_peak}")

        fused_est, fused_s = fit("fused-rbf", pts)
        st = fused_est.info_["engine"]
        a_fd = ari(dense_labels, np.asarray(fused_est.labels_))
        row(f"fused_sweep/fused_n{n}", fused_s * 1e6,
            f"peak_affinity_bytes={st['affinity_peak_bytes']} "
            f"({st['affinity_peak_bytes'] / dense_peak:.4f}x dense) "
            f"passes={st['matrix_passes']} "
            f"bytes_streamed={st['bytes_streamed']} ari_vs_dense={a_fd:.3f}")

        rec = {"n": n, "dense_wall_s": round(dense_s, 3),
               "fused_wall_s": round(fused_s, 3),
               "dense_peak_affinity_bytes": dense_peak,
               "fused_peak_affinity_bytes": int(st["affinity_peak_bytes"]),
               "fused_matrix_passes": int(st["matrix_passes"]),
               "fused_bytes_streamed": int(st["bytes_streamed"]),
               "fused_vs_dense_ari": float(a_fd)}

        if n <= 2048:                                # eigh oracle affordable
            eigh_est = SpectralClustering(
                k=k, affinity="dense", eigensolver="eigh", sigma=1.0,
                seed=0).fit(jnp.asarray(pts))
            rec["dense_vs_eigh_ari"] = float(
                ari(np.asarray(eigh_est.labels_), dense_labels))
            rec["fused_vs_eigh_ari"] = float(
                ari(np.asarray(eigh_est.labels_),
                    np.asarray(fused_est.labels_)))
            rec["eigh_matrix_passes"] = int(eigh_est.info_["matrix_passes"])
            row(f"fused_sweep/eigh_n{n}", 0.0,
                f"ari_dense={rec['dense_vs_eigh_ari']:.3f} "
                f"ari_fused={rec['fused_vs_eigh_ari']:.3f}")

        if n <= 2048:                                # the engine sweep rows
            budget = 1 << 18                         # 256 KiB forces spills
            plan = engine.JobPlan(n=n, chunk_size=256, t=16, k=k, sigma=1.0,
                                  memory_budget=budget, lanczos_steps=64,
                                  block_size=8, seed=0, path="ooc")
            t0 = time.perf_counter()
            res = engine.run_job(plan, ArrayChunks(pts.astype(np.float32),
                                                   256))
            ooc_s = time.perf_counter() - t0
            est_stats = res.stats
            a_od = ari(dense_labels, res.labels)
            rec.update(ooc_wall_s=round(ooc_s, 3),
                       ooc_peak_ram_bytes=int(
                           est_stats["store_peak_ram_bytes"]),
                       ooc_bytes_spilled=int(
                           est_stats["store_bytes_spilled"]),
                       ooc_prefetch_hits=int(est_stats["prefetch_hits"]),
                       ooc_prefetch_misses=int(
                           est_stats["prefetch_misses"]),
                       ooc_vs_dense_ari=float(a_od))
            row(f"fused_sweep/ooc_n{n}", ooc_s * 1e6,
                f"peak_ram={rec['ooc_peak_ram_bytes']} "
                f"spilled={rec['ooc_bytes_spilled']} "
                f"prefetch_hits={rec['ooc_prefetch_hits']} "
                f"ari_vs_dense={a_od:.3f}")
            assert est_stats["store_bytes_spilled"] > 0, "budget too lax"

            # same job, RAM-resident store: the readahead is now faster
            # than the consumer, so the hit counter shows the stream
            # staying warm (under the spill budget above the disk stream
            # is producer-bound and hits are rare — that contrast is the
            # point of reporting both)
            plan_ram = engine.JobPlan(n=n, chunk_size=256, t=16, k=k,
                                      sigma=1.0, memory_budget=None,
                                      lanczos_steps=64, block_size=8,
                                      seed=0, path="ooc")
            res_ram = engine.run_job(plan_ram,
                                     ArrayChunks(pts.astype(np.float32),
                                                 256))
            rec.update(
                ooc_ram_prefetch_hits=int(res_ram.stats["prefetch_hits"]),
                ooc_ram_prefetch_misses=int(
                    res_ram.stats["prefetch_misses"]))
            row(f"fused_sweep/ooc_ram_n{n}", 0.0,
                f"prefetch_hits={rec['ooc_ram_prefetch_hits']} "
                f"misses={rec['ooc_ram_prefetch_misses']}")

        results["rows"].append(rec)

    big = results["rows"][-1]
    mem_ratio = (big["fused_peak_affinity_bytes"]
                 / big["dense_peak_affinity_bytes"])
    results["fused_mem_ratio_at_max_n"] = mem_ratio
    row("fused_sweep/acceptance", 0.0,
        f"n={big['n']} ari={big['fused_vs_dense_ari']:.3f} "
        f"mem_ratio={mem_ratio:.4f}")
    assert big["fused_vs_dense_ari"] >= 0.99, big
    assert mem_ratio <= 0.10, mem_ratio
    assert any(r.get("ooc_prefetch_hits", 0)
               + r.get("ooc_ram_prefetch_hits", 0) > 0
               for r in results["rows"]), \
        "engine sweep produced no prefetch hits"

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json}")


def _async_problem(n: int, k: int):
    """The async_sweep problem: n blob points + the spill-forcing plan
    kwargs shared by every run (including the pr7 subprocess)."""
    pts, _ = synthetic.blobs(n, k, dim=4, spread=0.6, seed=0)
    return pts.astype(np.float32), dict(
        n=n, chunk_size=512, t=16, k=k, sigma=1.0, memory_budget=1 << 19,
        lanczos_steps=96, seed=0, path="ooc")


def _pr7_child(out_path: str, n: int = 4096, k: int = 3) -> None:
    """Subprocess body for the async_sweep baseline: the PR-7 pipeline,
    stage by stage — sequential build (workers=1), synchronous spills,
    per-column bincount scatter, no prewarm, and the eigensolve traced
    through the ``pure_callback`` matmat — exactly how the engine shipped
    before the async rework (the host-stepped driver and the single-pass
    scatter are both PR 8 optimizations, so the baseline must not borrow
    them).  Runs the pipeline twice (cold compiles, warm is the reported
    wall) and writes labels + both walls to ``out_path``.

    This runs in its OWN process because the callback eigensolve is the
    deadlock PR 8 fixed: on single-thread CPU runtimes it terminates only
    some of the time (the parent retries on timeout), and a hang must not
    take the whole sweep down with it."""
    from repro import engine
    from repro.data.chunked import ArrayChunks
    from repro.engine import kmeans as skm

    pts, common = _async_problem(n, k)
    plan = engine.JobPlan(**common, workers=1, prefetch_depth=1,
                          async_spill=False)

    def pipeline():
        t0 = time.perf_counter()
        graph, _sigma = engine.build_graph(ArrayChunks(pts, 512), plan,
                                           prewarm=False)
        graph.matmat_impl = "loop"
        op = engine.make_normalized_operator(graph)
        key = jax.random.PRNGKey(plan.seed)
        _, k_lan, _k_km = jax.random.split(key, 3)
        state = lz.block_lanczos(op.matmat, plan.n, plan.num_block_steps(),
                                 k_lan, block_size=plan.eff_block_size())
        evals, Z = lz.block_topk_of_shifted(state, plan.k)
        jax.block_until_ready(Z)
        Y = np.asarray(km.normalize_rows(Z))
        ranges = plan.ranges
        labels, _centers = skm.streaming_kmeans(
            lambda c: Y[ranges[c][0]:ranges[c][1]], plan.nchunks, plan.k,
            rounds=plan.kmeans_rounds, seed=plan.seed)
        wall = time.perf_counter() - t0
        graph.close()
        return labels, wall

    _labels, cold = pipeline()
    labels, warm = pipeline()
    np.savez(out_path, labels=labels, cold_wall=cold, warm_wall=warm)


def async_sweep(n: int = 4096, k: int = 3,
                out_json: str = "BENCH_async.json"):
    """The fully-async engine against its own sequential ancestor.

    One problem (n=4096 blobs, spill-forcing 512 KiB shard-store budget),
    three runs of the identical math:

      pr7        the pre-async engine exactly as it shipped (see
                 :func:`_pr7_child`), measured WARM in a fresh subprocess
                 with timeout+retry — its callback eigensolve is the
                 self-deadlock PR 8 fixed, so it cannot be trusted inside
                 the sweep process (or to terminate at all)
      seq        the async engine at width 1 (workers=1, depth=1, sync
                 spills) — the bitwise-parity reference
      async      workers=4, prefetch_depth=4, async spills, single-pass
                 scatter, warm-started eigensolve

    Acceptance (asserted): async wall <= 0.75x the pr7 wall; prefetch
    hit rate > 0.90; async labels BITWISE-identical to seq labels; ooc
    ARI vs the dense eigh oracle == 1.0; and the streaming ooc matmat
    stays within 2x of the fused in-memory matmat at equal n.
    """
    import os
    import subprocess
    import sys
    import tempfile

    from repro import engine
    from repro.cluster import ari
    from repro.cluster.affinity import AFFINITIES
    from repro.data.chunked import ArrayChunks
    from repro.distrib import mesh_utils

    pts, common = _async_problem(n, k)
    budget = common["memory_budget"]
    results: dict = {"n": n, "k": k, "budget": budget, **common}

    seq_plan = engine.JobPlan(**common, workers=1, prefetch_depth=1,
                              async_spill=False)
    async_plan = engine.JobPlan(**common, workers=4, prefetch_depth=4,
                                async_spill=True)

    # run the width-1 reference first: it also warms every jit the timed
    # async run shares, so the timed wall does not pay compile time
    t0 = time.perf_counter()
    res_seq = engine.run_job(seq_plan, ArrayChunks(pts, 512))
    seq_s = time.perf_counter() - t0
    row("async_sweep/seq_w1", seq_s * 1e6, "async engine at width 1")

    # PR-7 baseline in a fresh subprocess (see _pr7_child): retry on
    # deadlock-timeout, record how many attempts the callback path needed
    pr7_out = os.path.join(tempfile.mkdtemp(prefix="repro-bench-"),
                           "pr7.npz")
    attempts = 0
    while True:
        attempts += 1
        try:
            subprocess.run([sys.executable, os.path.abspath(__file__),
                            "_pr7_child", pr7_out], timeout=120, check=True)
            break
        except subprocess.TimeoutExpired:
            if attempts >= 8:
                raise RuntimeError(
                    "PR-7 callback baseline deadlocked in all 8 attempts")
    with np.load(pr7_out) as z:
        labels_pr7 = np.asarray(z["labels"])
        pr7_s = float(z["warm_wall"])
        pr7_cold_s = float(z["cold_wall"])
    row("async_sweep/pr7_baseline", pr7_s * 1e6,
        f"sequential schedule + sync spills + loop scatter + callback "
        f"eigensolve (fresh-process warm wall, attempts={attempts})")

    # best of 2, mirroring the baseline's cold+warm structure (the seq_w1
    # run above already compiled everything, so both runs here are warm)
    runs = []
    for _ in range(2):
        t0 = time.perf_counter()
        res_async = engine.run_job(async_plan, ArrayChunks(pts, 512))
        runs.append((time.perf_counter() - t0, res_async))
    async_s, res_async = min(runs, key=lambda r: r[0])
    st = res_async.stats
    hits, misses = st["prefetch_hits"], st["prefetch_misses"]
    hit_rate = hits / max(hits + misses, 1)
    speedup = pr7_s / async_s
    row("async_sweep/async_w4", async_s * 1e6,
        f"speedup={speedup:.2f}x hit_rate={hit_rate:.3f} "
        f"overlap_s={st['overlap_s']} build_wall_s={st['build_wall_s']} "
        f"spills={st['store_spills']} spill_joins={st['store_spill_joins']}")
    assert st["store_bytes_spilled"] > 0, "budget was meant to force spills"

    bitwise = bool(np.array_equal(res_seq.labels, res_async.labels))
    a_pr7 = float(ari(labels_pr7, res_async.labels))
    row("async_sweep/scheduler_parity", 0.0,
        f"bitwise_w1={bitwise} ari_vs_pr7={a_pr7:.3f}")

    # dense eigh oracle on the same points
    eigh_est = SpectralClustering(k=k, affinity="dense", eigensolver="eigh",
                                  sigma=1.0, seed=0).fit(jnp.asarray(pts))
    a_dense = float(ari(np.asarray(eigh_est.labels_), res_async.labels))
    row("async_sweep/ari_vs_dense_oracle", 0.0, f"ari={a_dense:.3f}")

    # streaming matmat vs the fused in-memory matmat at equal n (both
    # through the NormalizedOperator interface, best of 3).  The ooc side
    # times host_matmat — the product the eigensolve actually drives on
    # CPU runtimes; the traced-callback twin is the self-deadlock this PR
    # routed the hot path around, so it must not sit in a benchmark loop.
    graph, _s = engine.build_graph(ArrayChunks(pts, 512), async_plan)
    op_ooc = engine.make_normalized_operator(graph)
    mesh = mesh_utils.local_mesh("rows")
    est = SpectralClustering(k=k, sigma=1.0, seed=0)
    op_fused = AFFINITIES.get("fused-rbf")(est, jnp.asarray(pts),
                                           jnp.asarray(1.0), mesh)
    V = jnp.asarray(np.random.RandomState(0).randn(op_ooc.n_pad, 8),
                    jnp.float32)
    Vh = np.asarray(V)
    ooc_us, _ = _timeit(op_ooc.host_matmat, Vh)
    Vf = V[:op_fused.n_pad] if op_fused.n_pad <= op_ooc.n_pad else \
        jnp.zeros((op_fused.n_pad, 8), jnp.float32).at[:op_ooc.n_pad].set(V)
    fused_us, _ = _timeit(op_fused.matmat, Vf)
    matmat_ratio = ooc_us / fused_us
    row("async_sweep/matmat_ooc_vs_fused", ooc_us,
        f"fused={fused_us:.0f}us ratio={matmat_ratio:.2f}x")
    graph.close()

    results.update(
        pr7_wall_s=round(pr7_s, 3), pr7_cold_wall_s=round(pr7_cold_s, 3),
        pr7_subprocess_attempts=attempts, seq_wall_s=round(seq_s, 3),
        async_wall_s=round(async_s, 3), speedup_vs_pr7=round(speedup, 3),
        prefetch_hits=int(hits), prefetch_misses=int(misses),
        prefetch_hit_rate=round(hit_rate, 4),
        overlap_s=st["overlap_s"], build_wall_s=st["build_wall_s"],
        store_spills=int(st["store_spills"]),
        store_spill_joins=int(st["store_spill_joins"]),
        bytes_spilled=int(st["store_bytes_spilled"]),
        labels_bitwise_identical_w1=bitwise,
        ari_vs_pr7=a_pr7, ari_vs_dense_oracle=a_dense,
        matmat_ooc_us=round(ooc_us, 1), matmat_fused_us=round(fused_us, 1),
        matmat_ooc_vs_fused=round(matmat_ratio, 3))

    row("async_sweep/acceptance", 0.0,
        f"speedup={speedup:.2f}x (need >=1.33) hit_rate={hit_rate:.3f} "
        f"(need >0.90) bitwise={bitwise} ari_dense={a_dense:.3f} "
        f"matmat_ratio={matmat_ratio:.2f}x (need <=2)")
    assert async_s <= 0.75 * pr7_s, (async_s, pr7_s)
    assert hit_rate > 0.90, hit_rate
    assert bitwise, "workers=4 labels diverged from workers=1"
    assert a_dense == 1.0, a_dense
    assert matmat_ratio <= 2.0, matmat_ratio

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json}")


def serve_sweep(n: int = 8192, k: int = 8, ms=(1024, 8192),
                out_json: str = "BENCH_serve.json"):
    """The serving path (ISSUE 5 acceptance): fused vs dense out-of-sample
    transform at m queries against an n=8192-point fitted model.

    Per m: wall seconds and peak transform-stage bytes for both paths
    (dense: the materialized (m, n) query-vs-train kernel; fused: the
    O((m+n)*d + n*k) working set the serving layer advertises), plus
    predict-label parity.  Then the persistence contract — save -> load ->
    predict must be bitwise-equal to the fitted estimator — and the
    batched predict service's throughput/latency on the loaded model.

    Acceptance gates asserted here: fused peak <= 5% of dense at m=n=8192
    with label parity, and the round-trip bitwise equality.
    """
    import os
    import tempfile

    from repro.cluster import serving
    from repro.launch.cluster_serve import (ClusterServer, PredictRequest,
                                            summarize)

    results: dict = {"n": n, "k": k, "dim": 8, "rows": []}
    pts, _ = synthetic.blobs(n, k, dim=8, spread=0.6, seed=0)
    est = SpectralClustering(k=k, affinity="fused-rbf",
                             eigensolver="block-lanczos", block_size=8,
                             sigma=1.0, seed=0, lanczos_steps=64)
    t0 = time.perf_counter()
    est.fit(jnp.asarray(pts))
    fit_s = time.perf_counter() - t0
    results["fit_wall_s"] = round(fit_s, 3)
    row("serve_sweep/fit", fit_s * 1e6, f"n={n} affinity=fused-rbf")

    rng = np.random.RandomState(1)
    for m in ms:
        idx = rng.choice(n, size=m)
        q = jnp.asarray((pts[idx] + 0.05 * rng.randn(m, pts.shape[1])
                         ).astype(np.float32))

        def timed_labels(path):
            est.transform_path = path
            jax.block_until_ready(est.predict(q))        # warm/compile
            t0 = time.perf_counter()
            labels = jax.block_until_ready(est.predict(q))
            return np.asarray(labels), time.perf_counter() - t0

        dense_labels, dense_s = timed_labels("dense")
        dense_peak = m * n * 4                           # the (m, n) kernel
        row(f"serve_sweep/dense_m{m}", dense_s * 1e6,
            f"peak_transform_bytes={dense_peak}")

        fused_labels, fused_s = timed_labels("fused")
        fused_peak = serving.transform_peak_bytes(m, n, pts.shape[1], k)
        a = ari(dense_labels, fused_labels)
        exact = float(np.mean(dense_labels == fused_labels))
        row(f"serve_sweep/fused_m{m}", fused_s * 1e6,
            f"peak_transform_bytes={fused_peak} "
            f"({fused_peak / dense_peak:.4f}x dense) "
            f"ari_vs_dense={a:.3f} label_match={exact:.4f}")
        results["rows"].append({
            "m": m, "dense_wall_s": round(dense_s, 4),
            "fused_wall_s": round(fused_s, 4),
            "dense_peak_transform_bytes": dense_peak,
            "fused_peak_transform_bytes": int(fused_peak),
            "fused_vs_dense_ari": float(a),
            "fused_vs_dense_label_match": exact,
        })

    big = results["rows"][-1]
    mem_ratio = (big["fused_peak_transform_bytes"]
                 / big["dense_peak_transform_bytes"])
    results["fused_mem_ratio_at_max_m"] = mem_ratio
    row("serve_sweep/acceptance", 0.0,
        f"m={big['m']} mem_ratio={mem_ratio:.4f} "
        f"ari={big['fused_vs_dense_ari']:.3f}")
    assert mem_ratio <= 0.05, mem_ratio
    assert big["fused_vs_dense_ari"] >= 0.99, big

    # -- persistence round trip: bitwise predict parity -------------------
    est.transform_path = "auto"
    with tempfile.TemporaryDirectory() as d:
        model_dir = os.path.join(d, "model")
        t0 = time.perf_counter()
        est.save(model_dir)
        save_s = time.perf_counter() - t0
        t0 = time.perf_counter()
        est2 = SpectralClustering.load(model_dir)
        load_s = time.perf_counter() - t0
        q = jnp.asarray((pts[:2048] + 0.05).astype(np.float32))
        p1 = np.asarray(est.predict(q))
        p2 = np.asarray(est2.predict(q))
        bitwise = bool((p1 == p2).all())
        e1 = np.asarray(est.transform(q))
        e2 = np.asarray(est2.transform(q))
        bitwise = bitwise and bool((e1 == e2).all())
        results["save_wall_s"] = round(save_s, 3)
        results["load_wall_s"] = round(load_s, 3)
        results["roundtrip_predict_bitwise_equal"] = bitwise
        row("serve_sweep/roundtrip", (save_s + load_s) * 1e6,
            f"save={save_s:.2f}s load={load_s:.2f}s bitwise={bitwise}")
        assert bitwise

        # -- batched predict service on the loaded model ------------------
        est2.transform_path = "fused"
        queue = []
        for rid in range(16):
            mi = 512 + rng.randint(-64, 65)
            idx = rng.choice(n, size=mi)
            queue.append(PredictRequest(
                rid=rid, points=(pts[idx]
                                 + 0.05 * rng.randn(mi, pts.shape[1])
                                 ).astype(np.float32)))
        srv = ClusterServer(est2, batch_rows=1024)
        t0 = time.perf_counter()
        done = srv.run(queue)
        wall = time.perf_counter() - t0
        s = summarize(done, wall)
        fill = srv.stats["rows_live"] / max(
            srv.stats["rows_live"] + srv.stats["rows_padded"], 1)
        results["service"] = {
            "batch_rows": 1024, **{k2: (round(v, 2) if isinstance(v, float)
                                        else v) for k2, v in s.items()},
            "batch_steps": srv.steps, "fill": round(fill, 3),
        }
        row("serve_sweep/service", wall * 1e6,
            f"{s['points']} pts in {srv.steps} steps "
            f"{s['points_per_s']:.0f} pts/s fill={fill:.0%} "
            f"p50={s['latency_p50_ms']:.0f}ms")
        assert all(r.done for r in done)

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json}")


def chaos_sweep(n: int = 4096, k: int = 3,
                out_json: str = "BENCH_chaos.json"):
    """Hadoop-grade fault tolerance (ISSUE 9 acceptance) in three acts.

    (a) Recovery is invisible: the n=4096 out-of-core job is run clean,
        then under injected map/shuffle/reduce task failures — including
        MID-fold failures, where the dying attempt has already consumed
        part of its input set and the retry must re-materialize the
        missing blocks from lineage — then with
        spilled CSR shards corrupted on disk (bitflip + truncate), then
        with a 3 s map straggler under speculative re-execution — every
        faulted run must produce labels BITWISE-equal to the clean run
        (so ARI == 1 by construction, and it is still asserted).
    (b) Resilience is ~free: best-of-3 graph builds with the retry
        machinery at its defaults vs max_retries=0 — <= 3% overhead.
    (c) Overload degrades, not collapses: the batched predict service
        under 2x its admission bound sheds the excess with typed
        rejections while the admitted requests' p99 stays <= 2x the
        unloaded p99.
    """
    from repro import engine
    from repro.data.chunked import ArrayChunks
    from repro.launch.cluster_serve import (ClusterServer, PredictRequest,
                                            summarize)

    pts, common = _async_problem(n, k)
    results: dict = {"n": n, "k": k, "budget": common["memory_budget"],
                     "runs": {}}

    def run_engine(faults=None, **kw):
        plan = engine.JobPlan(**common, workers=4, prefetch_depth=4,
                              faults=faults, **kw)
        t0 = time.perf_counter()
        res = engine.run_job(plan, ArrayChunks(pts, 512))
        return res, time.perf_counter() - t0

    # -- (a) fault injection: bitwise recovery ----------------------------
    run_engine()                                      # warm every jit
    res_clean, clean_s = run_engine()
    row("chaos_sweep/clean", clean_s * 1e6,
        f"spills={res_clean.stats['store_spills']}")
    results["runs"]["clean"] = {"wall_s": round(clean_s, 3)}

    fault_runs = {
        "task_failures": dict(
            faults=(engine.FaultPlan()
                    .fail("map", (0, 1))
                    .fail_n("map", (2, 3), 2)
                    .fail("shuffle", 1)
                    .fail_midfold("shuffle", 2, after_inputs=3)
                    .fail("reduce", 0)
                    .fail_midfold("reduce", 3, after_inputs=2)),
            kw=dict(retry_backoff_s=0.01)),
        "spill_corruption": dict(
            faults=(engine.FaultPlan()
                    .corrupt("shard/0", "bitflip")
                    .corrupt("shard/3", "truncate")),
            kw={}),
        "straggler": dict(
            faults=engine.FaultPlan().delay("map", (1, 1), 3.0),
            kw=dict(speculation_factor=3.0)),
    }
    for tag, cfg in fault_runs.items():
        faults = cfg["faults"]
        res, wall = run_engine(faults=faults, **cfg["kw"])
        st = res.stats
        bitwise = bool(np.array_equal(res_clean.labels, res.labels))
        a = float(ari(res_clean.labels, res.labels))
        detail = (f"bitwise={bitwise} ari={a:.3f} "
                  f"retries={st['retries']} "
                  f"healed={st['inputs_healed']} "
                  f"recoveries={st['store_recoveries']} "
                  f"spec_launched={st['speculative_launched']} "
                  f"spec_won={st['speculative_won']} fired={faults.fired}")
        row(f"chaos_sweep/{tag}", wall * 1e6, detail)
        results["runs"][tag] = {
            "wall_s": round(wall, 3), "bitwise_equal_labels": bitwise,
            "ari_vs_clean": a, "retries": int(st["retries"]),
            "task_failures": int(st["task_failures"]),
            "inputs_healed": int(st["inputs_healed"]),
            "store_recoveries": int(st["store_recoveries"]),
            "speculative_launched": int(st["speculative_launched"]),
            "speculative_won": int(st["speculative_won"]),
            "faults_fired": dict(faults.fired),
        }
        assert bitwise, f"{tag}: labels diverged from the fault-free run"
        assert a == 1.0, (tag, a)
    assert results["runs"]["task_failures"]["retries"] >= 6
    # shuffle 2 consumed 3 cand blocks, reduce 3 consumed topt + 1 mirror
    assert results["runs"]["task_failures"]["inputs_healed"] >= 5
    assert results["runs"]["spill_corruption"]["store_recoveries"] >= 1
    assert results["runs"]["straggler"]["speculative_won"] >= 1

    # -- (b) zero-fault overhead of the resilience machinery --------------
    def best_build(**kw):
        walls = []
        for _ in range(3):
            plan = engine.JobPlan(**common, workers=4, prefetch_depth=4,
                                  **kw)
            t0 = time.perf_counter()
            graph, _sig = engine.build_graph(ArrayChunks(pts, 512), plan,
                                             prewarm=False)
            walls.append(time.perf_counter() - t0)
            graph.close()
        return min(walls)

    base_s = best_build(max_retries=0)
    resil_s = best_build()                 # defaults: max_retries=2
    overhead = resil_s / base_s - 1.0
    row("chaos_sweep/overhead", resil_s * 1e6,
        f"base={base_s:.3f}s resilient={resil_s:.3f}s "
        f"overhead={overhead:.2%} (need <=3%)")
    results["overhead"] = {
        "build_wall_s_no_retry": round(base_s, 4),
        "build_wall_s_resilient": round(resil_s, 4),
        "overhead_frac": round(overhead, 4),
    }
    assert overhead <= 0.03, f"resilience overhead {overhead:.2%} > 3%"

    # -- (c) serve under 2x overload: typed shed, bounded p99 -------------
    serve_n, m, n_req = 2048, 256, 8
    spts, _ = synthetic.blobs(serve_n, k, dim=8, spread=0.6, seed=0)
    est = SpectralClustering(k=k, affinity="fused-rbf", sigma=1.0,
                             seed=0, lanczos_steps=48)
    est.fit(jnp.asarray(spts))
    rng = np.random.RandomState(2)

    def make_queue(count):
        return [PredictRequest(
            rid=rid,
            points=(spts[rng.choice(serve_n, size=m)]
                    + 0.05 * rng.randn(m, spts.shape[1])
                    ).astype(np.float32)) for rid in range(count)]

    np.asarray(est.predict(jnp.asarray(spts[:256])))  # warm the compile
    bound = n_req * m                                 # rows of capacity

    srv_u = ClusterServer(est, batch_rows=256)
    t0 = time.perf_counter()
    done_u = srv_u.run(make_queue(n_req))             # offered = capacity
    s_u = summarize(done_u, time.perf_counter() - t0)

    srv_o = ClusterServer(est, batch_rows=256, max_pending_rows=bound)
    t0 = time.perf_counter()
    done_o = srv_o.run(make_queue(2 * n_req))         # offered = 2x
    s_o = summarize(done_o, time.perf_counter() - t0)

    shed = [r for r in done_o if r.status == "shed"]
    p99_ratio = s_o["latency_p99_ms"] / max(s_u["latency_p99_ms"], 1e-9)
    row("chaos_sweep/serve_overload", 0.0,
        f"unloaded_p99={s_u['latency_p99_ms']:.0f}ms "
        f"overload_p99={s_o['latency_p99_ms']:.0f}ms "
        f"ratio={p99_ratio:.2f}x (need <=2) shed={len(shed)}")
    results["serve"] = {
        "batch_rows": 256, "rows_per_request": m,
        "max_pending_rows": bound,
        "offered_requests_unloaded": n_req,
        "offered_requests_overload": 2 * n_req,
        "unloaded_p99_ms": s_u["latency_p99_ms"],
        "overload_admitted_p99_ms": s_o["latency_p99_ms"],
        "p99_ratio": round(p99_ratio, 3),
        "completed": s_o["completed"], "shed": s_o["shed"],
        "expired": s_o["expired"],
    }
    assert all(r.done for r in done_u)
    assert shed, "2x overload against a bounded queue must shed"
    assert all(r.error and "shed" in r.error for r in shed)
    assert s_o["completed"] >= 1
    assert p99_ratio <= 2.0, p99_ratio

    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json}")


def tune_sweep(ns=(1024, 4096), quick: bool = False,
               out_json: str = "BENCH_tune.json"):
    """The schedule autotuner sweep (repro.tune): every schedulable Pallas
    kernel at n in ``ns``, default schedule vs best-of-candidates wall
    time, winners persisted in the schedule cache (REPRO_SCHEDULE_CACHE or
    ~/.cache/repro/schedules.json).  Re-running prints cache_hit=True rows
    and does no timing — delete the cache file to retune.  ``--quick``
    shrinks n and the candidate grid for the CI smoke job.

    The default schedule is always among the candidates, so tuned wall is
    <= default wall on every kernel by construction (asserted here).
    """
    from repro import tune

    cache = tune.default_cache()
    if quick:
        ns = (256,)
    reports = tune.tune_all(ns, cache=cache, quick=quick,
                            log=lambda msg: print(f"# {msg}", flush=True))
    results = {"device": tune.device_kind(), "cache_path": cache.path,
               "quick": quick, "rows": reports}
    for rep in reports:
        name = f"tune_sweep/{rep['kernel']}_n{rep['shape']['n']}"
        if rep["cache_hit"]:
            row(name, float(rep.get("best_us") or 0.0),
                f"cache_hit=True schedule={rep['best']}")
            continue
        row(name, rep["best_us"],
            f"cache_hit=False default_us={rep['default_us']} "
            f"speedup={rep['speedup']}x schedule={rep['best']}")
        assert rep["best_us"] <= rep["default_us"] + 1e-9, rep
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json} (cache: {cache.path})")


def obs_overhead(n: int = 4096, k: int = 8, iters: int = 3,
                 out_json: str = "BENCH_obs.json"):
    """The observability tax (ISSUE 7 acceptance): the fused fit path at
    n=4096 with the obs layer ON vs OFF (``obs.set_enabled(False)`` turns
    spans and stat absorption into no-ops).  Each config takes the best of
    ``iters`` full fits (fits retrace, so min-of-k beats the noise), and
    the gate is overhead <= 2% of the disabled-path wall.
    """
    from repro import obs

    pts, _ = synthetic.blobs(n, k, dim=8, spread=0.6, seed=0)
    x = jnp.asarray(pts)

    def one_fit():
        est = SpectralClustering(k=k, affinity="fused-rbf",
                                 eigensolver="block-lanczos", block_size=8,
                                 sigma=1.0, seed=0, lanczos_steps=64)
        t0 = time.perf_counter()
        est.fit(x)
        return time.perf_counter() - t0, est

    def best_of(iters):
        walls = []
        for _ in range(iters):
            wall, est = one_fit()
            walls.append(wall)
        return min(walls), walls, est

    one_fit()                                        # shared warmup
    obs.set_enabled(False)
    try:
        off_s, off_walls, _ = best_of(iters)
    finally:
        obs.set_enabled(True)
    obs.reset()
    on_s, on_walls, est = best_of(iters)

    overhead = on_s / off_s - 1.0
    o = est.info_["obs"]
    results = {
        "n": n, "k": k, "affinity": "fused-rbf", "iters": iters,
        "enabled_wall_s": round(on_s, 4),
        "disabled_wall_s": round(off_s, 4),
        "enabled_walls_s": [round(w, 4) for w in on_walls],
        "disabled_walls_s": [round(w, 4) for w in off_walls],
        "overhead_frac": round(overhead, 5),
        "coverage": o["coverage"],
        "spans_recorded": len(obs.spans()),
        "phases": o["phases"],
    }
    row("obs_overhead/fused_fit", on_s * 1e6,
        f"disabled={off_s * 1e6:.0f}us overhead={overhead:+.2%} "
        f"coverage={o['coverage']:.0%}")
    assert o["coverage"] >= 0.95, o
    assert overhead <= 0.02, f"obs overhead {overhead:.2%} > 2%"
    with open(out_json, "w") as f:
        json.dump(results, f, indent=2)
    print(f"# wrote {out_json}")


MODES = {
    "table1_phases": table1_phases,
    "fig5_speedup": fig5_speedup,
    "rings_quality": rings_quality,
    "lanczos_residual": lanczos_residual,
    "assigner_backends": assigner_backends,
    "kernels": kernels,
    "engine_ooc": engine_ooc,
    "eigensolver_sweep": eigensolver_sweep,
    "fused_sweep": fused_sweep,
    "async_sweep": async_sweep,
    "serve_sweep": serve_sweep,
    "tune_sweep": tune_sweep,
    "obs_overhead": obs_overhead,
    "chaos_sweep": chaos_sweep,
}

# modes the bare invocation runs (the sweep is opt-in: it is a benchmark
# of its own with a JSON artifact)
DEFAULT_MODES = ("table1_phases", "fig5_speedup", "rings_quality",
                 "lanczos_residual", "assigner_backends", "kernels",
                 "engine_ooc")


def main(argv=None) -> None:
    if argv is None:
        import sys
        argv = sys.argv[1:]
    if argv and argv[0] == "_pr7_child":
        # async_sweep subprocess entry point (see _pr7_child): the PR-7
        # callback baseline must run in its own process
        _pr7_child(argv[1])
        return
    ap = argparse.ArgumentParser(description=__doc__)
    ap.add_argument("modes", nargs="*", choices=[[], *MODES],
                    help="benchmark modes to run (default: full suite "
                         "minus eigensolver_sweep)")
    ap.add_argument("--quick", action="store_true",
                    help="tune_sweep only: small n + reduced candidate "
                         "grid (the CI autotune smoke configuration)")
    args = ap.parse_args(argv)
    print("name,us_per_call,derived")
    for mode in (args.modes or DEFAULT_MODES):
        if mode == "tune_sweep":
            tune_sweep(quick=args.quick)
        else:
            MODES[mode]()
    print(f"# {len(ROWS)} rows")


if __name__ == "__main__":
    main()
