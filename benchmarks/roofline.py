"""Roofline table generator: reads dryrun_results.json, emits the
EXPERIMENTS.md §Roofline markdown table with the three terms, dominant
bottleneck, MODEL_FLOPS/HLO_FLOPs usefulness ratio, and a one-line lever.

    PYTHONPATH=src python -m benchmarks.roofline [--json dryrun_results.json]
"""
from __future__ import annotations

import argparse
import json

from repro.models.config import SHAPES_BY_NAME

# per-chip constants (TPU v5e) — keep in sync with launch/dryrun.py
PEAK_FLOPS = 197e12
N_CHIPS = {"single": 256, "multi": 512}

# ---------------------------------------------------------------------------
# kernel-level peak model — used by the schedule autotuner (repro.tune) to
# report achieved vs peak FLOPs/bytes per candidate schedule.
# ---------------------------------------------------------------------------

# (peak FLOP/s, peak HBM/DRAM bytes/s) per normalized device kind.  The CPU
# row is a deliberately conservative host estimate: interpret-mode numbers
# are only meaningful relative to each other, not against silicon peaks.
DEVICE_PEAKS = {
    "tpu-v5e": {"flops": PEAK_FLOPS, "bytes": 819e9},
    "cpu": {"flops": 5e10, "bytes": 2e10},
}


def device_peaks(kind: str | None = None) -> dict:
    """Peak {flops, bytes}/s for a device kind (default: current backend).
    Unknown TPU generations fall back to the v5e row, anything else to the
    CPU row — the autotuner only needs a consistent yardstick."""
    if kind is None:
        from repro.tune.cache import device_kind
        kind = device_kind()
    if kind in DEVICE_PEAKS:
        return DEVICE_PEAKS[kind]
    return DEVICE_PEAKS["tpu-v5e" if kind.startswith("tpu") else "cpu"]


def kernel_roofline(flops: float, bytes_moved: float, wall_s: float,
                    kind: str | None = None) -> dict:
    """Achieved vs peak for one timed kernel call.  Returns ``gflops`` /
    ``gbs`` (achieved rates), ``frac_peak_flops`` / ``frac_peak_bytes``
    (fraction of the device roofline), and the ``dominant`` bottleneck
    (whichever peak-time term is larger)."""
    peaks = device_peaks(kind)
    wall_s = max(float(wall_s), 1e-12)
    t_comp = flops / peaks["flops"]
    t_mem = bytes_moved / peaks["bytes"]
    return {
        "gflops": round(flops / wall_s / 1e9, 2),
        "gbs": round(bytes_moved / wall_s / 1e9, 2),
        "frac_peak_flops": round(flops / wall_s / peaks["flops"], 4),
        "frac_peak_bytes": round(bytes_moved / wall_s / peaks["bytes"], 4),
        "dominant": "compute" if t_comp >= t_mem else "memory",
    }


def model_flops(rec: dict) -> float:
    """6*N*D (dense) or 6*N_active*D (MoE) for train; 2*N*D for one
    forward-ish serving step (prefill full seq; decode 1 token/seq)."""
    shape = rec["shape"]
    if shape not in SHAPES_BY_NAME:
        return 0.0
    cell = SHAPES_BY_NAME[shape]
    n_active = rec.get("num_active_params", 0)
    if not n_active:
        return 0.0
    if cell.kind == "train":
        tokens = cell.global_batch * cell.seq_len
        return 6.0 * n_active * tokens
    if cell.kind == "prefill":
        tokens = cell.global_batch * cell.seq_len
        return 2.0 * n_active * tokens
    # decode: one new token per sequence
    return 2.0 * n_active * cell.global_batch


def lever(rec: dict) -> str:
    dom = rec["roofline"]["dominant"]
    cb = rec["hlo"]["collective_bytes"]
    if dom == "collective":
        top = max(cb, key=cb.get)
        return f"cut {top} traffic (resharding/overlap)"
    if dom == "memory":
        return "reduce HBM traffic (fusion/bf16/flash-style attention)"
    return "already compute-bound: raise MXU utilization (layout/tiling)"


def table(results: dict, mesh: str = "single") -> str:
    lines = [
        "| arch | shape | chips | t_comp (s) | t_mem (s) | t_coll (s) | "
        "bound | roofline frac | MODEL/HLO flops | lever |",
        "|---|---|---|---|---|---|---|---|---|---|",
    ]
    for key in sorted(results):
        rec = results[key]
        if rec.get("mesh") != mesh or rec.get("tag"):
            continue  # perf-variant records appear in EXPERIMENTS.md §Perf
        if "skipped" in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | - | "
                         f"skipped | - | - | {rec['skipped']} |")
            continue
        if "error" in rec:
            lines.append(f"| {rec['arch']} | {rec['shape']} | - | - | - | - | "
                         f"ERROR | - | - | {rec['error'][:60]} |")
            continue
        r = rec["roofline"]
        mf = model_flops(rec)
        hlo_total = rec["hlo"]["flops"] * rec["n_chips"]
        ratio = f"{mf / hlo_total:.2f}" if mf and hlo_total else "-"
        lines.append(
            f"| {rec['arch']} | {rec['shape']} | {rec['n_chips']} "
            f"| {r['t_compute_s']:.3g} | {r['t_memory_s']:.3g} "
            f"| {r['t_collective_s']:.3g} | {r['dominant']} "
            f"| {r['roofline_fraction']:.3f} | {ratio} | {lever(rec)} |")
    return "\n".join(lines)


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--json", default="dryrun_results.json")
    ap.add_argument("--mesh", default="single", choices=["single", "multi"])
    args = ap.parse_args(argv)
    with open(args.json) as f:
        results = json.load(f)
    print(table(results, args.mesh))


if __name__ == "__main__":
    main()
