"""Shared fixtures.  NOTE: device count must stay 1 here (the dry-run sets
its own 512-device flag in-process); multi-device tests spawn subprocesses
with their own XLA_FLAGS.

``REPRO_LOCKCHECK=1`` arms the runtime lock-discipline checker
(:mod:`repro.analysis.lockcheck`) for the whole session: every
``threading.Lock``/``RLock`` created after this point is tracked, and the
session FAILS at exit if the recorded acquisition-order graph has a cycle
(a latent deadlock), cross-validating the static C002 rule."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")

_LOCKCHECK = os.environ.get("REPRO_LOCKCHECK") == "1"
if _LOCKCHECK:
    if SRC not in sys.path:
        sys.path.insert(0, SRC)
    from repro.analysis import lockcheck
    lockcheck.install()


def pytest_sessionfinish(session, exitstatus):
    if not _LOCKCHECK:
        return
    rep = lockcheck.report()
    print(f"\n[lockcheck] {rep['locks']} locks from {rep['sites']} sites, "
          f"{rep['acquisitions']} acquisitions, {len(rep['edges'])} "
          f"order edges, {len(rep['cycles'])} cycles")
    # an exception here fails the run — exactly what the CI gate wants
    lockcheck.assert_acyclic()


def run_with_devices(code: str, n_devices: int, timeout: int = 900) -> str:
    """Run a python snippet in a subprocess with N fake XLA devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
