"""Shared fixtures.  NOTE: device count must stay 1 here (the dry-run sets
its own 512-device flag in-process); multi-device tests spawn subprocesses
with their own XLA_FLAGS."""
import os
import subprocess
import sys

import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_with_devices(code: str, n_devices: int, timeout: int = 900) -> str:
    """Run a python snippet in a subprocess with N fake XLA devices."""
    env = dict(os.environ)
    env["XLA_FLAGS"] = f"--xla_force_host_platform_device_count={n_devices}"
    env["PYTHONPATH"] = SRC
    out = subprocess.run([sys.executable, "-c", code], capture_output=True,
                         text=True, env=env, timeout=timeout)
    assert out.returncode == 0, f"stdout:\n{out.stdout}\nstderr:\n{out.stderr}"
    return out.stdout


@pytest.fixture(scope="session")
def subproc():
    return run_with_devices
