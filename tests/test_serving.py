"""The serving path: fused Nystrom transform parity, model persistence,
and the batched predict service.

Contracts under test (ISSUE 5):
  * fused transform == dense reference to <= 1e-4 in f32, for the kernel
    (ops vs ref) and the estimator routing (transform_path fused vs dense);
  * held-out points near training clusters inherit their cluster under
    every feature-space affinity (dense / fused-rbf / ooc-topt);
  * save -> load -> predict is BITWISE identical to the fitted estimator,
    including across a different device count (elastic restore);
  * zero-degree query rows (far from every training point) produce finite
    all-zero embeddings, never NaNs;
  * the service completes every request, splits requests larger than the
    batch, and its labels match direct predict.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import SpectralClustering, ari, serving
from repro.data import synthetic
from repro.kernels import ops, ref
from repro.launch.cluster_serve import ClusterServer, PredictRequest


def _fitted(affinity="triangular", n=160, k=3, **kw):
    pts, truth = synthetic.blobs(n, k, dim=4, spread=0.08, seed=4)
    est = SpectralClustering(k, affinity=affinity, sigma=1.0,
                             lanczos_steps=48, seed=0, **kw)
    est.fit(jnp.asarray(pts))
    return est, pts, truth


# ---------------------------------------------------------------------------
# kernel: fused dual-output pass vs materialized oracle
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("m,n,b", [(50, 137, 3), (128, 128, 1), (1, 200, 8)])
def test_fused_nystrom_kernel_matches_oracle(m, n, b):
    rng = np.random.RandomState(0)
    x = jnp.asarray(rng.randn(m, 5).astype(np.float32))
    y = jnp.asarray(rng.randn(n, 5).astype(np.float32))
    V = jnp.asarray(rng.randn(n, b).astype(np.float32))
    cs = jnp.asarray(np.abs(rng.randn(n)).astype(np.float32))
    O, deg = ops.fused_nystrom_matmat(x, y, V, 0.9, cs, interpret=True)
    Or, degr = ref.fused_nystrom_matmat(x, y, V, 0.9, cs, jnp.ones((n,)))
    assert O.shape == (m, b) and deg.shape == (m,)
    np.testing.assert_allclose(np.asarray(O), np.asarray(Or), atol=1e-4)
    np.testing.assert_allclose(np.asarray(deg), np.asarray(degr)[:, 0],
                               atol=1e-4)


def test_fused_nystrom_kernel_masks_padded_training_rows():
    # col_valid=0 rows must contribute to NEITHER output (the wrapper pads
    # with zero scale/valid; zero-point rows still have RBF weight 1 at
    # distance 0 from other zero rows, so masking is load-bearing)
    rng = np.random.RandomState(1)
    x = jnp.asarray(rng.randn(40, 3).astype(np.float32))
    y = np.zeros((96, 3), np.float32)
    y[:60] = rng.randn(60, 3)
    V = jnp.asarray(rng.randn(96, 2).astype(np.float32))
    cs = np.zeros((96,), np.float32)
    cs[:60] = 1.0
    O, deg = ops.fused_nystrom_matmat(jnp.asarray(x), jnp.asarray(y), V, 1.0,
                                      jnp.asarray(cs), jnp.asarray(cs),
                                      interpret=True)
    Or, degr = ref.fused_nystrom_matmat(x, jnp.asarray(y[:60]), V[:60], 1.0,
                                        jnp.ones((60,)), jnp.ones((60,)))
    np.testing.assert_allclose(np.asarray(O), np.asarray(Or), atol=1e-4)
    np.testing.assert_allclose(np.asarray(deg), np.asarray(degr)[:, 0],
                               atol=1e-4)


# ---------------------------------------------------------------------------
# estimator routing: fused vs dense parity, route rules
# ---------------------------------------------------------------------------

def test_transform_fused_matches_dense_path():
    est, pts, _ = _fitted()
    rng = np.random.RandomState(0)
    held = pts[:50] + 0.01 * rng.randn(50, pts.shape[1]).astype(np.float32)
    est.transform_path = "dense"
    e_dense = np.asarray(est.transform(jnp.asarray(held)))
    p_dense = np.asarray(est.predict(jnp.asarray(held)))
    assert est.info_["transform"]["path"] == "dense"
    est.transform_path = "fused"
    e_fused = np.asarray(est.transform(jnp.asarray(held)))
    p_fused = np.asarray(est.predict(jnp.asarray(held)))
    assert est.info_["transform"]["path"] == "fused"
    np.testing.assert_allclose(e_fused, e_dense, atol=1e-4)
    np.testing.assert_array_equal(p_fused, p_dense)
    # the fused route's working set beats the (m, n) kernel well before
    # serving scale; at this toy size it just has to be what it claims
    assert est.info_["transform"]["dense_equiv_bytes"] == 50 * 160 * 4


def test_route_transform_rules():
    # forced paths win
    assert serving.route_transform(10**6, 10**6, path="dense") == "dense"
    assert serving.route_transform(4, 4, path="fused") == "fused"
    with pytest.raises(ValueError, match="transform_path"):
        serving.route_transform(4, 4, path="nope")
    with pytest.raises(ValueError, match="transform_path"):
        SpectralClustering(2, transform_path="nope")
    # auto: the (m, n) kernel bytes against the budget
    assert serving.route_transform(1024, 1024) == "dense"      # 4 MiB
    assert serving.route_transform(8192, 8192) == "fused"      # 256 MiB
    assert serving.route_transform(
        1024, 1024, memory_budget=1 << 20) == "fused"          # over budget
    assert serving.route_transform(
        8192, 8192, memory_budget=1 << 30) == "dense"          # huge budget


def test_transform_path_constructor_roundtrip():
    est, pts, _ = _fitted(transform_path="fused")
    emb = np.asarray(est.transform(jnp.asarray(pts[:10])))
    assert est.info_["transform"]["path"] == "fused"
    assert emb.shape == (10, 3)


# ---------------------------------------------------------------------------
# out-of-sample label agreement across affinities
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("affinity,kw", [
    ("dense", {}),
    ("fused-rbf", {}),
    ("ooc-topt", {"chunk_size": 64, "sparsify_t": 10}),
])
def test_heldout_labels_across_affinities(affinity, kw):
    est, pts, _ = _fitted(affinity=affinity, **kw)
    rng = np.random.RandomState(0)
    idx = rng.choice(len(pts), size=40, replace=False)
    held = pts[idx] + 0.01 * rng.randn(40, pts.shape[1]).astype(np.float32)
    for path in ("dense", "fused"):
        est.transform_path = path
        pred = np.asarray(est.predict(jnp.asarray(held)))
        agree = np.mean(pred == np.asarray(est.labels_)[idx])
        assert agree > 0.9, (affinity, path, agree)


# ---------------------------------------------------------------------------
# zero-degree queries (far from every training point)
# ---------------------------------------------------------------------------

def test_far_away_queries_do_not_nan():
    est, pts, _ = _fitted()
    far = np.full((6, pts.shape[1]), 1e4, np.float32)
    for path in ("dense", "fused"):
        est.transform_path = path
        emb = np.asarray(est.transform(jnp.asarray(far)))
        assert np.isfinite(emb).all(), path
        np.testing.assert_array_equal(emb, 0.0)     # pinned to null row
        labels = np.asarray(est.predict(jnp.asarray(far)))
        assert ((labels >= 0) & (labels < est.k)).all()


# ---------------------------------------------------------------------------
# persistence: save -> load -> predict bitwise, elastic device count
# ---------------------------------------------------------------------------

def test_save_load_predict_bitwise(tmp_path):
    est, pts, _ = _fitted(affinity="fused-rbf")
    held = pts[:30] + 0.02
    for path in ("dense", "fused"):
        est.transform_path = path
        est.save(str(tmp_path / path))
        est2 = SpectralClustering.load(str(tmp_path / path))
        assert est2.transform_path == path
        np.testing.assert_array_equal(
            np.asarray(est.labels_), np.asarray(est2.labels_))
        e1 = np.asarray(est.transform(jnp.asarray(held)))
        e2 = np.asarray(est2.transform(jnp.asarray(held)))
        np.testing.assert_array_equal(e1, e2)       # bitwise
        np.testing.assert_array_equal(
            np.asarray(est.predict(jnp.asarray(held))),
            np.asarray(est2.predict(jnp.asarray(held))))


def test_save_requires_feature_space_fit(tmp_path):
    from repro.core import similarity as sim
    pts, _ = synthetic.blobs(40, 2, seed=1)
    S = sim.dense_similarity(jnp.asarray(pts), 1.0)
    est = SpectralClustering(2, affinity="precomputed").fit(S)
    with pytest.raises(ValueError, match="precomputed"):
        est.save(str(tmp_path))
    with pytest.raises(ValueError, match="not .*fitted|fit"):
        SpectralClustering(2).save(str(tmp_path))


def test_save_load_elastic_device_count(tmp_path, subproc):
    # fit + save on 4 devices, load + predict on 2: the checkpoint holds
    # logical arrays, so restore re-places them on whatever mesh exists
    model_dir = str(tmp_path / "elastic")
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.cluster import SpectralClustering
from repro.data import synthetic
pts, _ = synthetic.blobs(242, 3, dim=4, spread=0.08, seed=4)
assert len(jax.devices()) == {nd}
est = SpectralClustering(3, affinity="fused-rbf", sigma=1.0,
                         lanczos_steps=48, seed=0)
if {nd} == 4:
    est.fit(jnp.asarray(pts)).save({d!r})
est2 = SpectralClustering.load({d!r})
held = pts[:37] + 0.01
np.save({d!r} + "/pred_{nd}.npy",
        np.asarray(est2.predict(jnp.asarray(held))))
print("OK")
"""
    assert "OK" in subproc(code.format(nd=4, d=model_dir), n_devices=4)
    assert "OK" in subproc(code.format(nd=2, d=model_dir), n_devices=2)
    np.testing.assert_array_equal(np.load(model_dir + "/pred_4.npy"),
                                  np.load(model_dir + "/pred_2.npy"))


def test_sharded_fused_transform_multi_device(subproc):
    # queries row-shard over the mesh (no collective); uneven m exercises
    # the mesh*tile padding; parity vs the dense path must hold exactly
    # like on one device
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.cluster import SpectralClustering
from repro.data import synthetic
pts, _ = synthetic.blobs(242, 3, dim=4, spread=0.08, seed=4)
est = SpectralClustering(3, affinity="fused-rbf", sigma=1.0,
                         lanczos_steps=48, seed=0).fit(jnp.asarray(pts))
held = pts[:77] + 0.01
est.transform_path = "dense"; e_d = np.asarray(est.transform(jnp.asarray(held)))
est.transform_path = "fused"; e_f = np.asarray(est.transform(jnp.asarray(held)))
assert np.abs(e_d - e_f).max() <= 1e-4, np.abs(e_d - e_f).max()
assert len(est._transform_cache) == 1
np.asarray(est.transform(jnp.asarray(held)))   # cache hit, no retrace
assert len(est._transform_cache) == 1
print("OK")
""", n_devices=4)
    assert "OK" in out


# ---------------------------------------------------------------------------
# batched predict service
# ---------------------------------------------------------------------------

def test_cluster_server_completes_and_matches_direct_predict():
    est, pts, _ = _fitted(affinity="fused-rbf")
    rng = np.random.RandomState(0)
    queue = []
    for rid in range(5):
        m = 30 + rid * 17                     # 30..98 rows, uneven
        idx = rng.choice(len(pts), size=m)
        queue.append(PredictRequest(
            rid=rid,
            points=(pts[idx] + 0.01 * rng.randn(m, pts.shape[1])
                    ).astype(np.float32)))
    srv = ClusterServer(est, batch_rows=64)
    done = srv.run(queue)
    assert all(r.done for r in done)
    assert all(r.latency_s >= 0 for r in done)
    total = sum(len(r.points) for r in done)
    assert srv.stats["rows_live"] == total
    # batching must actually pack: far fewer steps than requests * rows
    assert srv.steps <= -(-total // 64) + len(queue)
    for r in done:
        np.testing.assert_array_equal(
            r.labels, np.asarray(est.predict(jnp.asarray(r.points))))


def test_cluster_server_splits_requests_larger_than_batch():
    est, pts, _ = _fitted()
    rng = np.random.RandomState(1)
    big = (np.tile(pts, (2, 1)) + 0.01 * rng.randn(2 * len(pts),
                                                   pts.shape[1])
           ).astype(np.float32)               # 320 rows >> batch 64
    srv = ClusterServer(est, batch_rows=64)
    done = srv.run([PredictRequest(rid=0, points=big)])
    assert done[0].done and len(done[0].labels) == len(big)
    assert srv.steps == -(-len(big) // 64)    # streamed, fully packed
    np.testing.assert_array_equal(
        done[0].labels, np.asarray(est.predict(jnp.asarray(big))))
