"""Correctness of the paper's pipeline: distributed pieces vs dense oracle,
plus hypothesis property tests on the system invariants."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (kmeans as km, lanczos as lz, laplacian as lp,
                        similarity as sim, spectral)
from repro.data import synthetic


# ---------------------------------------------------------------------------
# schedule properties (the paper's load-balance claim, exactly)
# ---------------------------------------------------------------------------

@given(st.integers(1, 64), st.integers(2, 500))
@settings(max_examples=60, deadline=None)
def test_schedule_balanced_and_complete(m, n):
    """Every device gets exactly 2m+1 tiles (paper's i/n-i+1 pairing) and
    every block pair (p<=q in original order) is computed exactly once."""
    sched = sim.make_schedule(n, m)
    assert sched.table.shape == (m, 2 * m + 1, 3)
    # completeness: each unordered original-block pair exactly once
    seen = set()
    orig_of_perm = sched.perm[::sched.b] // sched.b
    for d in range(m):
        own = [d, 2 * m - 1 - d]
        for p_local, q, _ in sched.table[d]:
            op = own[p_local]
            oq = orig_of_perm[q]
            pair = (min(op, oq), max(op, oq))
            assert op <= oq
            assert pair not in seen
            seen.add(pair)
    B = 2 * m
    assert len(seen) == B * (B + 1) // 2
    # permutation is a bijection
    assert np.array_equal(np.sort(sched.perm), np.arange(sched.n_pad))


@given(st.integers(4, 60), st.integers(1, 4), st.floats(0.3, 3.0))
@settings(max_examples=25, deadline=None)
def test_similarity_matrix_properties(n, d, sigma):
    """S is symmetric, entries in [0, 1] (exp underflows to 0.0 for far
    pairs in f32), diagonal exactly 1."""
    x = jax.random.normal(jax.random.PRNGKey(n), (n, d))
    S = np.asarray(sim.dense_similarity(x, sigma))
    assert np.allclose(S, S.T, atol=1e-6)
    assert (S >= 0).all() and (S <= 1 + 1e-6).all()
    assert np.allclose(np.diag(S), 1.0, atol=1e-6)


def test_laplacian_psd_and_trivial_eigvec():
    x, _ = synthetic.blobs(60, 3, seed=1)
    S = np.asarray(sim.dense_similarity(jnp.asarray(x), 1.0))
    L = np.asarray(lp.dense_lsym(jnp.asarray(S)))
    w = np.linalg.eigvalsh(L)
    assert w.min() > -1e-4, "L_sym must be PSD"
    assert w.max() < 2 + 1e-4, "L_sym spectrum lies in [0, 2]"
    d = S.sum(1)
    v = np.sqrt(d) / np.linalg.norm(np.sqrt(d))
    assert np.linalg.norm(L @ v) < 1e-4, "D^{1/2}1 is the 0-eigenvector"


# ---------------------------------------------------------------------------
# Lanczos
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("n,k", [(50, 3), (120, 5)])
def test_lanczos_matches_eigh(n, k):
    key = jax.random.PRNGKey(0)
    A = jax.random.normal(key, (n, n))
    A = (A + A.T) / 2

    state = lz.lanczos(lambda v: A @ v, n, min(n - 1, 40), key)
    evals, vecs = lz.ritz_pairs(state)
    top = np.asarray(evals[-k:])
    want = np.linalg.eigvalsh(np.asarray(A))[-k:]
    np.testing.assert_allclose(top, want, atol=1e-3, rtol=1e-3)
    # Ritz vectors are orthonormal (full reorthogonalization works)
    V = np.asarray(vecs[:, -k:])
    np.testing.assert_allclose(V.T @ V, np.eye(k), atol=1e-3)


def test_lanczos_smallest_of_lsym_via_shift():
    x, _ = synthetic.blobs(90, 3, spread=0.1, seed=2)
    S = sim.dense_similarity(jnp.asarray(x), 1.0)
    L = lp.dense_lsym(S)
    mv = lp.make_dense_shifted_operator(S)
    state = lz.lanczos(mv, 90, 60, jax.random.PRNGKey(1))
    vals, vecs = lz.topk_of_shifted(state, 3)
    want = np.linalg.eigvalsh(np.asarray(L))[:3]
    np.testing.assert_allclose(np.asarray(vals), want, atol=2e-3)
    res = lz.residuals(mv, vals, vecs, shift=2.0)
    assert float(jnp.max(res)) < 1e-2


def test_lanczos_checkpoint_resume_identical():
    """run(20) == run(10); checkpoint; run(10) — fault-tolerance invariant."""
    n = 64
    key = jax.random.PRNGKey(3)
    A = jax.random.normal(key, (n, n))
    A = (A + A.T) / 2
    mv = lambda v: A @ v
    full = lz.run(mv, lz.init_state(n, 20, key), 20)
    half = lz.run(mv, lz.init_state(n, 20, key), 10)
    resumed = lz.run(mv, half, 10)
    np.testing.assert_allclose(np.asarray(full.alpha), np.asarray(resumed.alpha),
                               atol=1e-5)
    np.testing.assert_allclose(np.asarray(full.V), np.asarray(resumed.V), atol=1e-5)


# ---------------------------------------------------------------------------
# k-means
# ---------------------------------------------------------------------------

@given(st.integers(20, 100), st.integers(2, 5))
@settings(max_examples=15, deadline=None)
def test_kmeans_inertia_monotone(n, k):
    y = jax.random.normal(jax.random.PRNGKey(n * k), (n, 4))
    centers = km.kmeans_plusplus_init(y, k, jax.random.PRNGKey(0))
    valid = jnp.ones((n,))
    inertias = []
    state = km.KMeansState(it=jnp.zeros((), jnp.int32), centers=centers,
                           shift=jnp.asarray(jnp.inf))
    for _ in range(8):
        _, _, inertia = km._update(y, valid, state.centers)
        inertias.append(float(inertia))
        state = km.lloyd_step(y, valid, state)
    assert all(b <= a + 1e-4 for a, b in zip(inertias, inertias[1:])), inertias


def test_kmeans_recovers_blobs():
    x, truth = synthetic.blobs(120, 3, spread=0.05, seed=4)
    labels, _ = km.kmeans(jnp.asarray(x), 3, jax.random.PRNGKey(1))
    labels = np.asarray(labels)
    from itertools import permutations
    acc = max(np.mean(np.array([p[t] for t in truth]) == labels)
              for p in permutations(range(3)))
    assert acc > 0.98


# ---------------------------------------------------------------------------
# end-to-end
# ---------------------------------------------------------------------------

def test_fit_dense_rings():
    pts, truth = synthetic.rings(300, 2, seed=0)
    res = spectral.fit_dense(jnp.asarray(pts), spectral.SpectralConfig(
        k=2, sigma=0.25, kmeans_iters=40, seed=0))
    labels = np.asarray(res.labels)
    acc = max(np.mean(labels == truth), np.mean(labels == 1 - truth))
    assert acc > 0.95


def test_fit_distributed_matches_dense_single_device():
    pts, truth = synthetic.blobs(100, 3, seed=5)
    cfg = spectral.SpectralConfig(k=3, sigma=1.0, lanczos_steps=40, seed=0)
    res_d = spectral.fit_dense(jnp.asarray(pts), cfg)
    res = spectral.fit(jnp.asarray(pts), cfg)   # mesh = all local devices (1)
    np.testing.assert_allclose(np.asarray(res.eigenvalues),
                               np.asarray(res_d.eigenvalues), atol=1e-3)
    from itertools import permutations
    labels = np.asarray(res.labels)
    acc = max(np.mean(np.array([p[t] for t in truth]) == labels)
              for p in permutations(range(3)))
    assert acc == 1.0


def test_fit_from_similarity_graph():
    edges, truth = synthetic.synthetic_graph(n=160, n_edges=900, k=3, seed=0)
    from repro.data.graph_file import adjacency_dense
    S = adjacency_dense(160, edges)
    res = spectral.fit_from_similarity(jnp.asarray(S), spectral.SpectralConfig(
        k=3, lanczos_steps=48, seed=0))
    labels = np.asarray(res.labels)
    from itertools import permutations
    acc = max(np.mean(np.array([p[t] for t in truth]) == labels)
              for p in permutations(range(3)))
    assert acc > 0.9, acc
