"""Per-architecture smoke tests (reduced configs): one forward + one train
step on CPU asserting shapes and finiteness; decode-vs-forward consistency
for every family with a serve path; chunked-attention equivalence."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import api
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.step import make_train_step

B, S = 2, 32


def _batch(cfg, seed=1):
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(seed), (B, S), 0,
                                          cfg.vocab_size)}
    if cfg.frontend == "embed":
        batch["embeds"] = jax.random.normal(jax.random.PRNGKey(seed + 1),
                                            (B, S, cfg.d_model), cfg.compute_dtype)
    return batch


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_smoke_forward_and_train_step(arch):
    cfg = configs.get_smoke(arch)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, aux = jax.jit(model.forward)(params, batch)
    assert logits.shape == (B, S, cfg.vocab_size)
    assert bool(jnp.all(jnp.isfinite(logits))), f"{arch}: non-finite logits"

    optz = opt_lib.get(cfg.optimizer)
    step = jax.jit(make_train_step(model, optz, lr_fn=lambda c: 1e-3))
    params2, opt2, metrics = step(params, optz.init(params), batch)
    assert np.isfinite(float(metrics["loss"]))
    # params actually changed
    delta = max(float(jnp.abs(a - b).max())
                for a, b in zip(jax.tree.leaves(params), jax.tree.leaves(params2)))
    assert delta > 0


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_arch_serve_smoke(arch):
    cfg = configs.get_smoke(arch)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    batch = _batch(cfg)
    logits, cache = model.prefill(params, batch, max_seq=S + 4)
    assert logits.shape[0] == B and logits.shape[-1] == cfg.vocab_size
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    logits2, cache2 = model.decode_step(params, cache, tok)
    assert bool(jnp.all(jnp.isfinite(logits2)))
    assert int(cache2["pos"]) == int(cache["pos"]) + 1


@pytest.mark.parametrize("arch", ["gemma3-1b", "qwen1.5-0.5b", "mixtral-8x7b",
                                  "xlstm-1.3b", "zamba2-2.7b"])
def test_decode_matches_forward(arch):
    """prefill(8) + decode chain reproduces full-forward logits.
    MoE: capacity_factor high enough that nothing is dropped in either the
    teacher-forced forward or the decode chain (drop-free equivalence)."""
    cfg = configs.get_smoke(arch).with_(compute_dtype=jnp.float32,
                                        capacity_factor=8.0)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (2, 16), 0, cfg.vocab_size)
    full, _ = model.forward(params, {"tokens": toks})
    lg, cache = model.prefill(params, {"tokens": toks[:, :8]}, max_seq=16)
    scale = float(jnp.abs(full).max())
    assert float(jnp.abs(lg[:, 0] - full[:, 7]).max()) < 2e-3 * scale
    for t in range(8, 12):
        lg, cache = model.decode_step(params, cache, toks[:, t:t + 1])
        err = float(jnp.abs(lg[:, 0] - full[:, t]).max())
        assert err < 2e-3 * scale, (arch, t, err)


def test_chunked_attention_equals_dense():
    cfg = ModelConfig(name="d", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=2, d_ff=128, vocab_size=128,
                      local_window=16, local_ratio=1, compute_dtype=jnp.float32)
    m_dense = api.build(cfg.with_(dense_attn_max_seq=8192))
    m_chunk = api.build(cfg.with_(dense_attn_max_seq=8, attn_chunk=16))
    params = m_dense.init(jax.random.PRNGKey(0))
    batch = {"tokens": jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, 128)}
    ld, _ = m_dense.forward(params, batch)
    lc, _ = m_chunk.forward(params, batch)
    np.testing.assert_allclose(np.asarray(ld), np.asarray(lc), atol=1e-4)


def test_gemma_window_pattern():
    cfg = configs.get("gemma3-1b")
    w = cfg.windows()
    assert len(w) == 26
    assert w[5] == -1 and w[11] == -1, "every 6th layer is global"
    assert all(x == 512 for i, x in enumerate(w) if (i + 1) % 6 != 0)


def test_moe_load_balance_and_dispatch():
    from repro.models import moe as moe_lib
    from repro.models import params as pp
    cfg = configs.get_smoke("mixtral-8x7b")
    spec = moe_lib.moe_specs(cfg)
    p = pp.init_params(spec, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 16, cfg.d_model),
                          jnp.float32)
    out, aux = moe_lib.moe_ffn(x, p, cfg)
    assert out.shape == x.shape
    assert bool(jnp.all(jnp.isfinite(out)))
    assert float(aux["lb_loss"]) > 0
    assert int(jnp.sum(aux["expert_load"])) == 2 * 16 * cfg.top_k


def test_moe_capacity_drops_are_bounded():
    from repro.models import moe as moe_lib
    from repro.models import params as pp
    cfg = configs.get_smoke("mixtral-8x7b").with_(capacity_factor=2.0)
    spec = moe_lib.moe_specs(cfg)
    p = pp.init_params(spec, jax.random.PRNGKey(0))
    x = jax.random.normal(jax.random.PRNGKey(2), (4, 32, cfg.d_model))
    _, aux = moe_lib.moe_ffn(x, p, cfg)
    assert float(aux["frac_dropped"]) < 0.5
