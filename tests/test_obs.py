"""The observability layer: spans, metrics, and the wiring through the
estimator / engine / serving paths.

Contracts under test (ISSUE 7):
  * spans nest through a thread-local stack (each thread its own), record
    monotonic durations, and tolerate leaked inner spans;
  * histogram percentiles match the numpy nearest-rank oracle exactly
    while every observation is retained (incl. n=1 and n=2 edges);
  * the Chrome-trace export is schema-valid (ph/ts/dur/pid/tid in us,
    metadata events, child spans contained in their parents);
  * metrics snapshots round-trip through to_json, and absorb_stats is
    idempotent (re-absorbing a live dict updates, never double-counts);
  * every fit path (dense / fused-rbf / ooc-topt) publishes
    info_["obs"] with the three phase keys and coverage >= 0.95;
  * refitting the same estimator does NOT accumulate fused-rbf pass
    counters, and a REUSED operator resets to its post-build baseline;
  * summarize() reports correct nearest-rank p50/p95/p99 on small n.
"""
from __future__ import annotations

import json
import threading

import jax.numpy as jnp
import numpy as np
import pytest

from repro import obs
from repro.cluster import SpectralClustering
from repro.data import synthetic
from repro.obs.metrics import Histogram, MetricsRegistry, nearest_rank
from repro.obs.trace import Tracer


@pytest.fixture(autouse=True)
def _fresh_obs():
    """Each test sees empty process-wide tracer/registry state."""
    obs.reset()
    yield
    obs.reset()


# -- spans --------------------------------------------------------------------

def test_span_nesting_and_depth():
    tr = Tracer()
    with tr.span("outer") as so:
        with tr.span("inner") as si:
            assert tr.current() is si
            assert si.depth == 1
        assert tr.current() is so
    assert tr.current() is None
    inner, outer = tr.spans()[0], tr.spans()[1]
    assert (inner.name, outer.name) == ("inner", "outer")
    # containment: the child's window lies inside the parent's
    assert outer.t0 <= inner.t0 and inner.t1 <= outer.t1
    assert outer.duration_s >= inner.duration_s >= 0.0


def test_span_decorator_and_attrs():
    tr = Tracer()

    @tr.traced("work.unit", kind="test")
    def work(a, b):
        return a + b

    assert work(2, 3) == 5
    (sp,) = tr.spans()
    assert sp.name == "work.unit" and sp.attrs["kind"] == "test"


def test_span_error_attr_and_leak_tolerance():
    tr = Tracer()
    with pytest.raises(ValueError):
        with tr.span("boom"):
            raise ValueError("x")
    assert tr.spans()[0].attrs["error"] == "ValueError"
    # a leaked (never-exited) inner span must not corrupt the outer pop
    ctx_o = tr.span("outer")
    sp_o = ctx_o.__enter__()
    tr.span("leaked").__enter__()
    ctx_o.__exit__(None, None, None)
    assert tr.current() is None
    assert sp_o.t1 is not None


def test_span_thread_safety():
    tr = Tracer(jax_annotations=False)
    errs = []

    def worker(i):
        try:
            for j in range(25):
                with tr.span(f"t{i}") as sp:
                    with tr.span(f"t{i}.child"):
                        assert tr.current().name == f"t{i}.child"
                    assert tr.current() is sp
        except Exception as e:  # pragma: no cover
            errs.append(e)

    threads = [threading.Thread(target=worker, args=(i,)) for i in range(8)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errs
    assert len(tr.spans()) == 8 * 25 * 2


def test_disabled_tracer_records_nothing():
    tr = Tracer(enabled=False)
    with tr.span("x") as sp:
        sp.set(a=1)        # the null span accepts the same surface
    assert tr.spans() == []


# -- histogram / percentile ---------------------------------------------------

def test_nearest_rank_small_n_edges():
    assert nearest_rank([5.0], 50) == 5.0
    assert nearest_rank([5.0], 99) == 5.0
    # p50 of two samples is the FIRST (rank ceil(0.5*2)=1) — the old
    # len//2 indexing returned the second
    assert nearest_rank([1.0, 2.0], 50) == 1.0
    assert nearest_rank([1.0, 2.0], 99) == 2.0
    assert nearest_rank([], 50) == 0.0


@pytest.mark.parametrize("n", [1, 2, 3, 10, 137])
def test_histogram_matches_numpy_oracle(n):
    rng = np.random.RandomState(n)
    vals = rng.gamma(2.0, 10.0, size=n)
    h = Histogram("lat")
    for v in vals:
        h.observe(v)
    s = np.sort(vals)
    for q in (50, 90, 95, 99, 100):
        oracle = s[min(max(1, int(np.ceil(q / 100 * n))), n) - 1]
        assert h.percentile(q) == pytest.approx(float(oracle))
    snap = h.snapshot()
    assert snap["count"] == n
    assert snap["min"] == pytest.approx(float(s[0]))
    assert snap["max"] == pytest.approx(float(s[-1]))


def test_histogram_beyond_cap_uses_bucket_edges():
    h = Histogram("lat", buckets=(1.0, 10.0, 100.0), sample_cap=4)
    for v in (0.5, 0.5, 5.0, 5.0, 50.0, 50.0):   # 6 obs > cap of 4
        h.observe(v)
    assert h.count == 6
    # estimate is the containing bucket's upper edge: monotone, bounded
    assert h.percentile(50) == 10.0
    assert h.percentile(99) == 100.0


# -- chrome-trace export ------------------------------------------------------

def test_chrome_trace_schema(tmp_path):
    tr = Tracer(jax_annotations=False)
    with tr.span("fit", n=64):
        with tr.span("fit.affinity"):
            pass
    path = str(tmp_path / "sub" / "trace.json")
    tr.export(path)
    doc = json.load(open(path))
    assert doc["displayTimeUnit"] == "ms"
    evs = doc["traceEvents"]
    meta = [e for e in evs if e["ph"] == "M"]
    assert {"process_name", "thread_name"} <= {e["name"] for e in meta}
    xs = {e["name"]: e for e in evs if e["ph"] == "X"}
    assert set(xs) == {"fit", "fit.affinity"}
    parent, child = xs["fit"], xs["fit.affinity"]
    for e in (parent, child):
        assert e["ts"] >= 0 and e["dur"] >= 0
        assert e["pid"] == meta[0]["pid"] and e["tid"] == 0
    # nesting is containment on the tid, in microseconds
    assert parent["ts"] <= child["ts"]
    assert child["ts"] + child["dur"] <= parent["ts"] + parent["dur"] + 1e-3
    assert parent["args"]["n"] == 64
    assert parent["cat"] == "fit"


# -- metrics registry ---------------------------------------------------------

def test_metrics_snapshot_round_trip(tmp_path):
    reg = MetricsRegistry()
    reg.counter("a.events").inc(3)
    reg.gauge("a.fill").set(0.5)
    reg.histogram("a.lat_ms").observe(2.0)
    reg.counter("a.events", model="x").inc()          # labeled child
    path = str(tmp_path / "metrics.json")
    text = reg.to_json(path)
    assert json.loads(text) == reg.snapshot()
    assert json.load(open(path)) == reg.snapshot()
    snap = reg.snapshot()
    assert snap["a.events"] == {"type": "counter", "value": 3}
    assert snap["a.events{model=x}"]["value"] == 1
    assert snap["a.lat_ms"]["p50"] == 2.0
    # prefix filtering
    assert set(reg.snapshot("a.events")) == {"a.events", "a.events{model=x}"}


def test_registry_kind_mismatch_raises():
    reg = MetricsRegistry()
    reg.counter("x")
    with pytest.raises(TypeError):
        reg.gauge("x")


def test_absorb_stats_idempotent_and_typed():
    reg = MetricsRegistry()
    stats = {"spills": np.int64(4), "fill": 0.25, "name": "skip",
             "flag": True}
    reg.absorb_stats("store", stats)
    reg.absorb_stats("store", stats)        # re-absorb: update, not double
    snap = reg.snapshot()
    assert snap["store.spills"] == {"type": "counter", "value": 4}
    assert snap["store.fill"] == {"type": "gauge", "value": 0.25}
    assert "store.name" not in snap and "store.flag" not in snap
    stats["spills"] = 9                     # live dict moved on
    reg.absorb_stats("store", stats)
    assert reg.get("store.spills").value == 9


def test_absorb_disabled_is_noop():
    reg = MetricsRegistry(enabled=False)
    reg.absorb_stats("x", {"a": 1})
    assert reg.snapshot() == {}


# -- estimator wiring ---------------------------------------------------------

PTS, _ = synthetic.blobs(96, 3, dim=4, spread=0.08, seed=4)


@pytest.mark.parametrize("affinity", ["dense", "fused-rbf", "ooc-topt"])
def test_fit_publishes_obs_phases(affinity):
    est = SpectralClustering(k=3, affinity=affinity, sigma=1.0,
                             chunk_size=48).fit(jnp.asarray(PTS))
    o = est.info_["obs"]
    assert set(o["phases"]) == {"affinity", "eigensolve", "assign"}
    assert o["coverage"] >= 0.95
    assert o["wall_s"] > 0
    for ph in o["phases"].values():
        assert 0.0 <= ph["frac"] <= 1.0
    # the trace recorded properly nested fit spans...
    names = {s.name for s in obs.spans("fit")}
    assert {"fit", "fit.affinity", "fit.eigensolve", "fit.assign"} <= names
    # ...and the numeric fit stats were mirrored into the registry
    assert obs.metrics.get("fit.matrix_passes").value > 0


def test_refit_does_not_accumulate_fused_counters():
    est = SpectralClustering(k=3, affinity="fused-rbf", sigma=1.0)
    est.fit(jnp.asarray(PTS))
    first = dict(est.info_["obs"]["counters"])
    est.fit(jnp.asarray(PTS))
    second = dict(est.info_["obs"]["counters"])
    assert second["matrix_passes"] == first["matrix_passes"]
    assert second["bytes_streamed"] == first["bytes_streamed"]


def test_reused_operator_resets_to_post_build_baseline():
    from repro.cluster.affinity import build_fused_rbf_operator
    from repro.distrib import mesh_utils

    op = build_fused_rbf_operator(jnp.asarray(PTS, jnp.float32), 1.0,
                                  mesh_utils.local_mesh("rows"))
    base = op.stats_snapshot()["matrix_passes"]
    import jax
    jax.block_until_ready(op.matmat(jnp.ones((op.n_pad, 2), jnp.float32)))
    assert op.stats_snapshot()["matrix_passes"] == base + 1
    op.reset_stats()
    assert op.stats_snapshot()["matrix_passes"] == base


# -- serving summarize --------------------------------------------------------

def test_summarize_percentiles_small_n():
    from repro.launch.cluster_serve import PredictRequest, summarize

    reqs = []
    for i, lat in enumerate([0.010, 0.020, 0.030]):
        r = PredictRequest(rid=i, points=np.zeros((2, 2), np.float32),
                           labels=np.zeros(2, np.int32), _filled=2)
        r.t_submit, r.t_done = 0.0, lat
        reqs.append(r)
    s = summarize(reqs, wall_s=0.5)
    # nearest-rank over [10, 20, 30] ms: p50 -> 20, p95/p99 -> 30
    assert s["latency_p50_ms"] == pytest.approx(20.0)
    assert s["latency_p95_ms"] == pytest.approx(30.0)
    assert s["latency_p99_ms"] == pytest.approx(30.0)
    assert s["latency_max_ms"] == pytest.approx(30.0)
    assert s["points"] == 6


def test_server_step_feeds_shared_histograms():
    from repro.launch.cluster_serve import ClusterServer, PredictRequest

    est = SpectralClustering(k=3, affinity="dense", sigma=1.0,
                             transform_path="dense").fit(jnp.asarray(PTS))
    srv = ClusterServer(est, batch_rows=32)
    queue = [PredictRequest(rid=i, points=np.asarray(PTS[:20], np.float32))
             for i in range(3)]
    srv.run(queue)
    assert srv.request_ms.count == 3
    assert srv.batch_ms.count == srv.stats["batches"] > 0
    snap = obs.metrics.snapshot("serve")
    assert snap["serve.request_ms"]["p99"] >= snap["serve.request_ms"]["p50"]
    assert 0.0 < snap["serve.fill"]["value"] <= 1.0
    assert {s.name for s in obs.spans("serve")} == {"serve.step"}


# -- toggling -----------------------------------------------------------------

def test_set_enabled_false_silences_everything():
    obs.set_enabled(False)
    try:
        with obs.span("quiet"):
            obs.absorb_stats("q", {"a": 1})
        assert obs.spans() == []
        assert obs.metrics.snapshot("q") == {}
    finally:
        obs.set_enabled(True)
