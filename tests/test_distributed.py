"""Multi-device behaviour (subprocess with fake XLA devices): the
distributed similarity schedule, sym_matvec, k-means MapReduce, and the
full pipeline must match the dense oracle bit-for-bit-ish on 4/8 devices."""
def test_triangular_similarity_4dev(subproc):
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import similarity as sim, spectral
from repro.distrib import mesh_utils
rng = np.random.RandomState(0)
pts = np.concatenate([rng.randn(37,2)*0.2 + c for c in [(0,0),(5,5),(0,6)]]).astype(np.float32)
mesh = mesh_utils.local_mesh("rows")
assert mesh_utils.mesh_size(mesh) == 4
up = sim.similarity_upper_blocks(jnp.asarray(pts), 1.0, mesh)
S_dense = sim.dense_similarity(jnp.asarray(pts), 1.0)
sched = up.schedule
S_back = np.asarray(sim.materialize(up))[np.ix_(sched.inv_perm, sched.inv_perm)][:111,:111]
assert np.abs(S_back - np.asarray(S_dense)).max() < 1e-4
v = rng.randn(sched.n_pad).astype(np.float32)
got = np.asarray(sim.sym_matvec(up, jnp.asarray(v)))[sched.inv_perm][:111]
ref = np.asarray(S_dense) @ v[sched.inv_perm][:111]
assert np.abs(got - ref).max() < 1e-3
Sf = np.asarray(sim.distributed_similarity_full(jnp.asarray(pts), 1.0, mesh))[:111,:111]
assert np.abs(Sf - np.asarray(S_dense)).max() < 1e-4
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_full_pipeline_8dev_matches_truth(subproc):
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from itertools import permutations
from repro.core import spectral
from repro.data import synthetic
from repro.distrib import mesh_utils
pts, truth = synthetic.blobs(200, 3, seed=1)
mesh = mesh_utils.local_mesh("rows")
assert mesh_utils.mesh_size(mesh) == 8
for mode in ("triangular", "full"):
    cfg = spectral.SpectralConfig(k=3, sigma=1.0, lanczos_steps=40, mode=mode)
    res = spectral.fit(jnp.asarray(pts), cfg, mesh)
    labels = np.asarray(res.labels)
    acc = max(np.mean(np.array([p[t] for t in truth]) == labels) for p in permutations(range(3)))
    assert acc > 0.99, (mode, acc)
    ev = np.asarray(res.eigenvalues)
    assert (ev > -1e-3).all() and (ev < 0.5).all(), ev
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_compact_triangular_layout_4dev(subproc):
    """Perf-iteration S1 storage: compact tiles reproduce the wide-layout
    symmetric mat-vec exactly."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import similarity as sim
from repro.distrib import mesh_utils
rng = np.random.RandomState(0)
pts = rng.randn(111, 3).astype(np.float32)
mesh = mesh_utils.local_mesh("rows")
upc = sim.similarity_upper_blocks_compact(jnp.asarray(pts), 1.0, mesh)
up = sim.similarity_upper_blocks(jnp.asarray(pts), 1.0, mesh)
v = jnp.asarray(rng.randn(upc.schedule.n_pad).astype(np.float32))
a = sim.sym_matvec_compact(upc, v)
b = sim.sym_matvec(up, v)
assert float(jnp.abs(a - b).max()) < 1e-4
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_distributed_kmeans_equals_single(subproc):
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.core import kmeans as km
from repro.distrib import mesh_utils
mesh = mesh_utils.local_mesh("rows")
y = jax.random.normal(jax.random.PRNGKey(0), (96, 4))
valid = jnp.ones((96,))
c0 = km.kmeans_plusplus_init(y, 4, jax.random.PRNGKey(1))
st_d = km.KMeansState(it=jnp.zeros((), jnp.int32), centers=c0, shift=jnp.asarray(jnp.inf))
st_s = st_d
for _ in range(5):
    st_d = km.distributed_lloyd_step(y, valid, st_d, mesh)
    st_s = km.lloyd_step(y, valid, st_s)
assert np.abs(np.asarray(st_d.centers) - np.asarray(st_s.centers)).max() < 1e-4
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_compressed_dp_training_4dev(subproc):
    """int8+EF compressed DP step trains (loss decreases) on 4 devices."""
    out = subproc("""
import numpy as np, jax, jax.numpy as jnp
from repro.models.config import ModelConfig
from repro.models import api
from repro.train import optimizer as opt_lib
from repro.train.step import make_compressed_train_step, init_ef_state
from repro.distrib import mesh_utils
from repro.data import synthetic
cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64, num_heads=4,
                  num_kv_heads=4, d_ff=128, vocab_size=64, compute_dtype=jnp.float32)
model = api.build(cfg)
mesh = mesh_utils.make_mesh((4,), ("data",))
optz = opt_lib.adamw()
params = model.init(jax.random.PRNGKey(0))
opt_state = optz.init(params)
ef = init_ef_state(params)
step = make_compressed_train_step(model, optz, mesh,
                                  lr_fn=lambda c: 1e-2, axis="data")
data = synthetic.lm_batches(8, 32, 64, seed=0)
losses = []
for i in range(30):
    b = {k: jnp.asarray(v) for k, v in next(data).items()}
    params, opt_state, ef, loss = step(params, opt_state, ef, b)
    losses.append(float(loss))
assert losses[-1] < losses[0] - 0.5, losses
print("OK", losses[0], losses[-1])
""", n_devices=4)
    assert "OK" in out


def test_moe_ep_shard_map_matches_gather_8dev(subproc):
    """Explicit EP (B1 in EXPERIMENTS §Perf) is bit-exact vs the GSPMD
    gather path at drop-free capacity."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.distrib import mesh_utils
from repro.models import moe as moe_lib
from repro.models import params as pp
from repro import configs
from repro.distrib import act_sharding
cfg = configs.get_smoke("kimi-k2-1t-a32b").with_(capacity_factor=8.0,
                                                 compute_dtype=jnp.float32,
                                                 moe_impl="gather")
spec = moe_lib.moe_specs(cfg)
p = pp.init_params(spec, jax.random.PRNGKey(0))
x = jax.random.normal(jax.random.PRNGKey(1), (4, 16, cfg.d_model), jnp.float32)
out_ref, _ = moe_lib.moe_ffn(x, p, cfg)
mesh = mesh_utils.make_mesh((2, 4), ("data", "model"))
cfg2 = cfg.with_(moe_impl="ep_shard_map")
with act_sharding.use_mesh(mesh):
    out_ep, _ = jax.jit(lambda x, p: moe_lib.moe_ffn(x, p, cfg2))(x, p)
assert float(jnp.abs(out_ep - out_ref).max()) < 1e-5
print("OK")
""", n_devices=8)
    assert "OK" in out


def test_sp_serve_preset_matches_default_8dev(subproc):
    """Sequence-parallel serving (A1 in EXPERIMENTS §Perf) returns the
    same prefill logits as the default sharding."""
    out = subproc("""
import jax, jax.numpy as jnp, numpy as np
from repro.distrib import mesh_utils
from repro import configs
from repro.distrib import act_sharding
from repro.models import api
cfg = configs.get_smoke("minitron-4b").with_(compute_dtype=jnp.float32,
                                             dense_attn_max_seq=8, attn_chunk=16)
m = api.build(cfg)
params = m.init(jax.random.PRNGKey(0))
toks = jax.random.randint(jax.random.PRNGKey(1), (2, 64), 0, cfg.vocab_size)
lg_ref, _ = m.prefill(params, {"tokens": toks})
mesh = mesh_utils.make_mesh((2, 4), ("data", "model"))
cfg_sp = cfg.with_(sharding_preset="sp_serve")
m_sp = api.build(cfg_sp)
with act_sharding.use_mesh(mesh):
    lg_sp, _ = jax.jit(lambda p, b: m_sp.prefill(p, b))(params, {"tokens": toks})
err = float(jnp.abs(lg_sp - lg_ref).max())
assert err < 1e-3, err
print("OK", err)
""", n_devices=8)
    assert "OK" in out


def test_elastic_checkpoint_restore_1_to_4_devices(subproc, tmp_path):
    """Fault tolerance + elasticity: a checkpoint written on 1 device
    restores onto a 4-device mesh with resharded placement and identical
    values (the restart-on-different-world-size path)."""
    ckpt = str(tmp_path)
    out1 = subproc(f"""
import jax, jax.numpy as jnp
from repro.checkpoint import CheckpointManager
mgr = CheckpointManager({ckpt!r}, async_write=False)
tree = {{"w": jnp.arange(64, dtype=jnp.float32).reshape(8, 8),
         "count": jnp.asarray(5)}}
mgr.save(5, tree)
print("SAVED", len(jax.devices()))
""", n_devices=1)
    assert "SAVED 1" in out1
    out4 = subproc(f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro.distrib import mesh_utils
from repro.checkpoint import CheckpointManager
mesh = mesh_utils.make_mesh((4,), ("data",))
shardings = {{"w": NamedSharding(mesh, P("data", None)),
              "count": NamedSharding(mesh, P())}}
tmpl = {{"w": jnp.zeros((8, 8)), "count": jnp.asarray(0)}}
mgr = CheckpointManager({ckpt!r})
out = mgr.restore(tmpl, shardings=shardings)
assert int(out["count"]) == 5
np.testing.assert_array_equal(np.asarray(out["w"]),
                              np.arange(64, dtype=np.float32).reshape(8, 8))
assert len(out["w"].sharding.device_set) == 4
print("RESTORED", len(jax.devices()))
""", n_devices=4)
    assert "RESTORED 4" in out4


def test_mini_dryrun_8dev(subproc):
    """A reduced-mesh dry-run of one LM cell + spectral lanczos lowers,
    compiles and produces roofline terms on an 8-device mesh."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.distrib import mesh_utils
from repro import configs
from repro.configs import specs as cfg_specs
from repro.distrib import hlo_analysis, sharding
from repro.models import api, params as pp
from repro.models.config import ShapeCell
from repro.train import optimizer as opt_lib
from repro.train.step import make_train_step

cfg = configs.get_smoke("mixtral-8x7b")
model = api.build(cfg)
mesh = mesh_utils.make_mesh((2, 4), ("data", "model"))
cell = ShapeCell("mini", "train", 64, 8)
p_shard = sharding.param_shardings(cfg, model.spec, mesh)
batch = cfg_specs.input_specs(cfg, cell)
b_shard = sharding.input_shardings(mesh, batch)
optz = opt_lib.get(cfg.optimizer)
o_spec = optz.init_spec(model.spec)
o_shard = sharding.opt_shardings(cfg, o_spec, mesh)
step = make_train_step(model, optz)
lowered = jax.jit(step, in_shardings=(p_shard, o_shard, b_shard),
                  out_shardings=(p_shard, o_shard, None)).lower(
    model.abstract_params(), pp.abstract_params(o_spec), batch)
compiled = lowered.compile()
r = hlo_analysis.analyze(compiled.as_text())
assert r["flops"] > 0 and r["bytes"] > 0
assert compiled.memory_analysis() is not None
print("OK flops=%.2e coll=%d" % (r["flops"], r["collective_total"]))
""", n_devices=8)
    assert "OK" in out
