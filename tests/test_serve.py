"""Continuous-batching server: all requests complete, slots are reused,
and a single-request run matches direct prefill+decode."""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.serve import Request, Server
from repro.models import api


def _setup(slots=2, prompt_len=8, max_seq=24):
    cfg = configs.get_smoke("qwen1.5-0.5b").with_(compute_dtype=jnp.float32)
    model = api.build(cfg)
    return cfg, model, Server(model, slots, prompt_len, max_seq)


def test_server_completes_more_requests_than_slots():
    cfg, model, srv = _setup(slots=2)
    rng = np.random.RandomState(0)
    queue = [Request(rid=i, prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                     max_new=4 + (i % 3)) for i in range(5)]
    done = srv.run(queue)
    assert len(done) == 5
    assert all(r.done for r in done)
    assert all(len(r.out) >= r.max_new for r in done)
    assert srv.steps < 5 * 7, "slots must be shared, not sequential"


def test_max_new_one_emits_exactly_one_token():
    # regression: the prefill token already consumes the whole budget of a
    # max_new=1 request — it must finish at prefill (one token, zero decode
    # steps), not emit a second token from a burned decode step
    cfg, model, srv = _setup(slots=2)
    rng = np.random.RandomState(2)
    prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    done = srv.run([Request(rid=0, prompt=prompt, max_new=1)])
    assert len(done) == 1 and done[0].done
    assert len(done[0].out) == 1, done[0].out
    assert srv.steps == 0, "no live slot -> no decode step"


def test_token_budget_is_exact_in_mixed_batches():
    # max_new=1 requests mixed with longer ones: every request emits
    # EXACTLY its budget (the off-by-one appended max_new + 1 tokens)
    cfg, model, srv = _setup(slots=2)
    rng = np.random.RandomState(3)
    queue = [Request(rid=i,
                     prompt=rng.randint(0, cfg.vocab_size, 8).astype(np.int32),
                     max_new=1 + (i % 3)) for i in range(6)]
    done = srv.run(queue)
    assert len(done) == 6
    assert all(len(r.out) == r.max_new for r in done), \
        [(r.rid, r.max_new, len(r.out)) for r in done]


def test_server_matches_direct_decode():
    cfg, model, srv = _setup(slots=2)
    rng = np.random.RandomState(1)
    prompt = rng.randint(0, cfg.vocab_size, 8).astype(np.int32)
    done = srv.run([Request(rid=0, prompt=prompt, max_new=5)])
    got = done[0].out[:5]

    params = srv.params
    logits, cache = model.prefill(params, {"tokens": jnp.asarray(prompt)[None]},
                                  max_seq=24)
    want = [int(jnp.argmax(logits[0, -1]))]
    tok = jnp.asarray([[want[-1]]], jnp.int32)
    for _ in range(4):
        logits, cache = model.decode_step(params, cache, tok)
        want.append(int(jnp.argmax(logits[0, 0])))
        tok = jnp.asarray([[want[-1]]], jnp.int32)
    assert got == want, (got, want)
