"""Per-kernel shape/dtype sweeps: Pallas (interpret mode) vs pure-jnp oracle."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref


def _rand(shape, dtype, seed):
    x = jax.random.normal(jax.random.PRNGKey(seed), shape, jnp.float32)
    return x.astype(dtype)


@pytest.mark.parametrize("n,m,d", [(64, 64, 4), (128, 96, 17), (200, 150, 33),
                                   (257, 129, 8), (512, 512, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_rbf_similarity(n, m, d, dtype):
    x = _rand((n, d), dtype, 0)
    y = _rand((m, d), dtype, 1)
    got = ops.rbf_similarity(x, y, 1.3, interpret=True)
    want = ref.rbf_similarity(x.astype(jnp.float32), y.astype(jnp.float32), 1.3)
    tol = 1e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol, rtol=tol)
    assert got.shape == (n, m)


@pytest.mark.parametrize("n,m", [(256, 512), (300, 700), (1024, 256), (65, 130)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matvec(n, m, dtype):
    A = _rand((n, m), dtype, 2)
    v = _rand((m,), dtype, 3)
    got = ops.block_matvec(A, v, interpret=True)
    want = ref.block_matvec(A.astype(jnp.float32), v.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol * np.abs(np.asarray(want)).max(), rtol=tol)


@pytest.mark.parametrize("n,m,b", [(256, 512, 8), (300, 700, 3),
                                   (1024, 256, 16), (65, 130, 1)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_block_matmat(n, m, b, dtype):
    A = _rand((n, m), dtype, 6)
    V = _rand((m, b), dtype, 7)
    got = ops.block_matmat(A, V, interpret=True)
    want = ref.block_matmat(A.astype(jnp.float32), V.astype(jnp.float32))
    tol = 1e-4 if dtype == jnp.float32 else 5e-2
    np.testing.assert_allclose(np.asarray(got, np.float32), np.asarray(want),
                               atol=tol * np.abs(np.asarray(want)).max(),
                               rtol=tol)
    assert got.shape == (n, b)


def test_block_kernels_interpret_autodetect():
    """No hardcoded interpret default: off-TPU the wrappers auto-select
    interpret mode (and still match the oracle) without the caller
    passing anything."""
    from repro.kernels import block_matvec as raw
    assert raw.interpret_default() == (jax.default_backend() != "tpu")
    A = _rand((128, 128), jnp.float32, 8)
    v = _rand((128,), jnp.float32, 9)
    np.testing.assert_allclose(np.asarray(ops.block_matvec(A, v)),
                               np.asarray(ref.block_matvec(A, v)), atol=1e-4)
    V = _rand((128, 4), jnp.float32, 10)
    np.testing.assert_allclose(np.asarray(ops.block_matmat(A, V)),
                               np.asarray(ref.block_matmat(A, V)), atol=1e-4)


@pytest.mark.parametrize("n,d,k", [(512, 8, 7), (513, 16, 3), (1000, 4, 11),
                                   (64, 32, 2)])
def test_kmeans_assign(n, d, k):
    p = _rand((n, d), jnp.float32, 4)
    c = _rand((k, d), jnp.float32, 5)
    idx, dist = ops.kmeans_assign(p, c, interpret=True)
    ri, rd = ref.kmeans_assign(p, c)
    assert bool(jnp.all(idx == ri))
    np.testing.assert_allclose(np.asarray(dist), np.asarray(rd), atol=1e-4)


def test_kernels_match_core_pipeline_pieces():
    """The kernels compute exactly what core/similarity + core/kmeans use."""
    from repro.core.similarity import rbf_kernel
    x = _rand((96, 5), jnp.float32, 6)
    np.testing.assert_allclose(
        np.asarray(ops.rbf_similarity(x, x, 0.9, interpret=True)),
        np.asarray(rbf_kernel(x, x, 0.9)), atol=2e-5, rtol=2e-5)
