"""The kernel schedule layer + autotuner (repro.tune).

Contracts:
  * Schedule round-trips through its dict form; unknown fields and bad
    dtypes fail loudly.
  * Legality checks fire BEFORE lowering: non-sublane tiles, lane-width
    violations on the compiled path, col-major on reducing kernels,
    scratch on non-reducing kernels, VMEM-budget blowouts — each a
    one-line ScheduleError naming the kernel.
  * schedule=None through the public ops wrappers is bit-for-bit the old
    keyword-tile behavior; any legal explicit schedule matches the
    default within 1e-4 (f32).
  * The JSON cache round-trips schedules per (kernel, shape bucket,
    device, dtype), tolerates corrupt files, merges on write, excludes
    the matmat width b from its keys, and honors REPRO_SCHEDULE_CACHE.
  * autotune() always includes the default among its candidates (tuned
    <= default by construction), persists the winner, and short-circuits
    on a cache hit; schedule="auto" consumes the cached winner.
  * The estimator accepts schedule=, records what ran in info_, and
    persists the setting through save/load.
"""
import json
import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ops, ref
from repro.kernels.block_matvec import check_tiles
from repro.tune import (KERNELS, Schedule, ScheduleCache, ScheduleError,
                        autotune, bucket, cache_key, candidates,
                        default_cache, resolve, spec)


def _pts(n, d, seed=0):
    return jnp.asarray(np.random.RandomState(seed).randn(n, d)
                       .astype(np.float32))


# ---------------------------------------------------------------------------
# Schedule value semantics


def test_schedule_roundtrip():
    s = Schedule(bm=256, bn=128, compute_dtype="bfloat16", acc="scratch")
    assert Schedule.from_dict(s.to_dict()) == s
    # None fields are dropped from the dict form
    assert "interpret" not in Schedule(bm=8).to_dict()


def test_schedule_rejects_unknown_fields_and_bad_dtype():
    with pytest.raises(ScheduleError, match="unknown schedule field"):
        Schedule.from_dict({"bm": 128, "tile_rows": 4})
    with pytest.raises(ScheduleError, match="compute_dtype"):
        Schedule.from_dict({"compute_dtype": "fp8"})
    # short dtype aliases normalize
    assert Schedule.from_dict({"compute_dtype": "bf16"}).compute_dtype \
        == "bfloat16"


def test_every_kernel_default_is_legal():
    for name, sp in KERNELS.items():
        sp.check(sp.default.replace(interpret=True))


# ---------------------------------------------------------------------------
# Legality checks (satellite: clear errors instead of Pallas lowering blowups)


def test_check_tiles_rejects_non_sublane_multiples():
    with pytest.raises(ValueError, match="multiple of 8"):
        check_tiles(30, 64, interpret=True)
    with pytest.raises(ValueError, match="multiple of 8"):
        check_tiles(64, 12, interpret=True)
    check_tiles(32, 64, interpret=True)        # legal in interpret mode


def test_check_tiles_enforces_lane_width_when_compiled():
    # bn is the reduction/lane-side tile: 64 is sublane-legal but not a
    # lane multiple, so the compiled path must refuse it with a clear
    # message (the old behavior was an opaque Mosaic lowering error)
    with pytest.raises(ValueError, match="lane width"):
        check_tiles(128, 64, interpret=False)
    check_tiles(128, 64, interpret=True)


def test_ops_block_matmat_bad_tile_is_clear_error():
    A, V = _pts(64, 64), _pts(64, 4, seed=1)
    with pytest.raises(ScheduleError, match="block_matmat.*bm=30"):
        ops.block_matmat(A, V, schedule=Schedule(bm=30, bn=32))


def test_colmajor_illegal_for_reducing_kernels():
    with pytest.raises(ScheduleError, match="col-major"):
        spec("block_matmat").check(
            Schedule(bm=8, bn=8, grid_order="col-major", interpret=True))
    # ...but legal for the write-once rbf_similarity grid
    spec("rbf_similarity").check(
        Schedule(bm=8, bn=8, grid_order="col-major", interpret=True))


def test_scratch_illegal_for_nonreducing_kernels():
    with pytest.raises(ScheduleError, match="scratch"):
        spec("rbf_similarity").check(
            Schedule(bm=8, bn=8, acc="scratch", interpret=True))


def test_compute_dtype_only_on_fused_kernels():
    with pytest.raises(ScheduleError, match="compute_dtype"):
        spec("block_matmat").check(
            Schedule(bm=8, bn=8, compute_dtype="bfloat16", interpret=True))


def test_vmem_budget_rejects_giant_tiles():
    with pytest.raises(ScheduleError, match="VMEM"):
        spec("rbf_similarity").check(
            Schedule(bm=4096, bn=4096, interpret=True),
            n=8192, m=8192, d=64)


def test_kmeans_assign_has_no_bn():
    with pytest.raises(ScheduleError, match="no bn"):
        spec("kmeans_assign").check(
            Schedule(bm=512, bn=64, interpret=True))


# ---------------------------------------------------------------------------
# Schedule-aware entry points: default equivalence


def test_schedule_none_is_bitwise_default():
    x, y = _pts(100, 6), _pts(72, 6, seed=1)
    a = ops.rbf_similarity(x, y, 1.3)
    b = ops.rbf_similarity(x, y, 1.3, schedule=None)
    c = ops.rbf_similarity(x, y, 1.3, schedule="default")
    assert (np.asarray(a) == np.asarray(b)).all()
    assert (np.asarray(a) == np.asarray(c)).all()


def test_explicit_schedules_match_reference():
    x, y, V = _pts(96, 5), _pts(80, 5, seed=1), _pts(80, 4, seed=2)
    want = np.asarray(ref.rbf_similarity(x, y, 0.9)) @ np.asarray(V)
    for s in (Schedule(bm=32, bn=32),
              Schedule(bm=64, bn=16, acc="scratch"),
              Schedule(bm=16, bn=48, compute_dtype="f32")):
        got = np.asarray(ops.fused_rbf_matmat(x, y, V, 0.9, schedule=s))
        np.testing.assert_allclose(got, want, atol=1e-4)


def test_grid_order_swap_is_exact():
    x, y = _pts(64, 4), _pts(96, 4, seed=1)
    a = ops.rbf_similarity(x, y, 1.1, schedule=Schedule(bm=16, bn=32))
    b = ops.rbf_similarity(
        x, y, 1.1, schedule=Schedule(bm=16, bn=32, grid_order="col-major"))
    assert (np.asarray(a) == np.asarray(b)).all()


def test_partial_schedule_inherits_call_site_defaults():
    s, source = resolve("fused_rbf_matmat", Schedule(compute_dtype="bf16"),
                        bm=128, bn=128, n=256, m=256, d=8, b=8)
    assert source == "explicit"
    assert (s.bm, s.bn, s.compute_dtype) == (128, 128, "bfloat16")
    assert s.interpret is not None      # auto-detected


# ---------------------------------------------------------------------------
# Persistent cache


def test_bucket_rounds_to_next_pow2():
    assert [bucket(v) for v in (1, 2, 3, 1000, 1024, 1025)] \
        == [1, 2, 4, 1024, 1024, 2048]


def test_cache_roundtrip_and_bucketing(tmp_path):
    c = ScheduleCache(str(tmp_path / "sched.json"))
    s = Schedule(bm=256, bn=128, acc="scratch")
    c.put("block_matmat", s, n=1000, m=1000, wall_us=12.5)
    # same bucket (1024) regardless of exact n/m; b is not in the key
    got = c.get("block_matmat", n=700, m=513, b=99)
    assert got == s
    assert c.get("block_matmat", n=5000, m=5000) is None
    assert c.stats == {"hits": 1, "misses": 1, "puts": 1}
    rec = c.entry("block_matmat", n=1024, m=1024)
    assert rec["wall_us"] == 12.5


def test_cache_key_excludes_batch_width():
    k1 = cache_key("block_matmat", device="cpu", n=100, m=100, b=1)
    k2 = cache_key("block_matmat", device="cpu", n=100, m=100, b=64)
    assert k1 == k2
    with pytest.raises(ValueError, match="missing"):
        cache_key("block_matmat", device="cpu", n=100)


def test_cache_tolerates_corrupt_and_foreign_files(tmp_path):
    p = tmp_path / "sched.json"
    p.write_text("{ not json")
    c = ScheduleCache(str(p))
    assert c.get("block_matmat", n=64, m=64) is None
    c.put("block_matmat", Schedule(bm=64, bn=128), n=64, m=64)
    assert c.get("block_matmat", n=64, m=64) is not None
    # a future-version file reads as empty, not as an error
    p.write_text(json.dumps({"version": 999, "entries": {"x": {}}}))
    assert c.keys() == []


def test_cache_write_is_atomic_and_merges(tmp_path):
    p = str(tmp_path / "sched.json")
    a, b = ScheduleCache(p), ScheduleCache(p)
    a.put("block_matmat", Schedule(bm=64, bn=128), n=64, m=64)
    b.put("rbf_similarity", Schedule(bm=32, bn=128), n=64, m=64, d=8)
    # second writer re-read before merging: both entries survive
    assert len(ScheduleCache(p).keys()) == 2
    assert not [f for f in os.listdir(tmp_path) if ".tmp" in f]


def test_default_cache_follows_env_var(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "env.json"))
    assert default_cache().path == str(tmp_path / "env.json")


# ---------------------------------------------------------------------------
# "auto" resolution + autotuner


def test_auto_miss_falls_back_to_default(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "none.json"))
    s, source = resolve("block_matmat", "auto", bm=256, bn=512,
                        n=64, m=64, b=4)
    assert source == "auto-default"
    assert (s.bm, s.bn) == (256, 512)


def test_auto_hit_uses_cached_schedule(tmp_path, monkeypatch):
    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "c.json"))
    default_cache().put("block_matmat", Schedule(bm=64, bn=128), n=64, m=64)
    s, source = resolve("block_matmat", "auto", bm=256, bn=512,
                        n=64, m=64, b=4)
    assert source == "cache"
    assert (s.bm, s.bn) == (64, 128)
    A, V = _pts(64, 64), _pts(64, 4, seed=1)
    got = ops.block_matmat(A, V, schedule="auto")
    np.testing.assert_allclose(np.asarray(got),
                               np.asarray(A) @ np.asarray(V), atol=1e-4)


def test_candidates_include_default_first():
    cands = candidates("block_matmat", quick=True, n=512, m=512, b=8)
    assert cands[0] == spec("block_matmat").default
    assert len(cands) > 1
    assert len(set(cands)) == len(cands)


def test_autotune_quick_writes_cache_and_hits(tmp_path):
    c = ScheduleCache(str(tmp_path / "tuned.json"))
    rep = autotune("block_matmat", 128, b=4, cache=c, quick=True)
    assert not rep["cache_hit"]
    assert rep["best_us"] <= rep["default_us"] + 1e-9
    assert rep["rows"] and all("wall_us" in r for r in rep["rows"])
    assert c.get("block_matmat", n=128, m=128) is not None
    rep2 = autotune("block_matmat", 128, b=4, cache=c, quick=True)
    assert rep2["cache_hit"] and rep2["best"] == rep["best"]


# ---------------------------------------------------------------------------
# Estimator wiring


def test_estimator_validates_schedule_eagerly():
    from repro.cluster import SpectralClustering
    with pytest.raises(ScheduleError):
        SpectralClustering(3, schedule={"bogus_field": 1})
    SpectralClustering(3, schedule="auto")      # accepted


def test_estimator_records_schedule_in_info(tmp_path, monkeypatch):
    from repro.cluster import SpectralClustering
    from repro.data import synthetic

    pts, _ = synthetic.blobs(96, 3, dim=4, spread=0.6, seed=0)
    sched = {"bm": 32, "bn": 32}
    est = SpectralClustering(3, affinity="fused-rbf", sigma=1.0, seed=0,
                             lanczos_steps=24, schedule=sched)
    est.fit(jnp.asarray(pts))
    rec = est.info_["schedule"]
    assert rec["source"] == "explicit"
    assert rec["value"]["bm"] == 32
    assert est.info_["engine"]["schedule"]["bm"] == 32
    # transform over the fused path records its serving-side schedule
    est.transform_path = "fused"
    est.transform(jnp.asarray(pts[:16]))
    assert est.info_["transform"]["schedule"]["bm"] == 32


def test_estimator_auto_consumes_tuned_cache(tmp_path, monkeypatch):
    from repro.cluster import SpectralClustering
    from repro.data import synthetic

    monkeypatch.setenv("REPRO_SCHEDULE_CACHE", str(tmp_path / "t.json"))
    pts, _ = synthetic.blobs(96, 3, dim=4, spread=0.6, seed=0)
    n = 96
    default_cache().put("fused_rbf_matmat", Schedule(bm=32, bn=32),
                        n=n, m=n, d=4)
    est = SpectralClustering(3, affinity="fused-rbf", sigma=1.0, seed=0,
                             lanczos_steps=24, schedule="auto")
    est.fit(jnp.asarray(pts))
    rec = est.info_["schedule"]
    assert rec["source"] == "cache"
    assert rec["value"]["bm"] == 32


def test_schedule_survives_save_load(tmp_path):
    from repro.cluster import SpectralClustering
    from repro.data import synthetic

    pts, _ = synthetic.blobs(64, 2, dim=4, spread=0.6, seed=0)
    est = SpectralClustering(2, affinity="fused-rbf", sigma=1.0, seed=0,
                             lanczos_steps=16,
                             schedule=Schedule(bm=32, bn=32))
    est.fit(jnp.asarray(pts))
    est.save(str(tmp_path / "model"))
    est2 = SpectralClustering.load(str(tmp_path / "model"))
    assert est2.schedule == {"bm": 32, "bn": 32,
                             "grid_order": "row-major", "acc": "inplace"}
    q = jnp.asarray(pts[:8] + 0.01)
    assert (np.asarray(est.predict(q)) == np.asarray(est2.predict(q))).all()
