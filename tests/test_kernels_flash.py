"""Flash-attention Pallas kernel vs oracle: shape/dtype/mask sweeps in
interpret mode (CPU), including the tile-skip bounds (causal + window)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import ref
from repro.kernels.flash_attention import flash_attention


def _qkv(B, H, S, T, hd, dtype, seed=0):
    ks = jax.random.split(jax.random.PRNGKey(seed), 3)
    q = jax.random.normal(ks[0], (B, H, S, hd), jnp.float32).astype(dtype)
    k = jax.random.normal(ks[1], (B, H, T, hd), jnp.float32).astype(dtype)
    v = jax.random.normal(ks[2], (B, H, T, hd), jnp.float32).astype(dtype)
    return q, k, v


@pytest.mark.parametrize("S,T,bq,bk", [(128, 128, 32, 32), (256, 256, 64, 32),
                                       (64, 256, 32, 64)])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_causal(S, T, bq, bk, dtype):
    q, k, v = _qkv(2, 3, S, T, 32, dtype)
    out = flash_attention(q, k, v, causal=True, bq=bq, bk=bk, interpret=True)
    want = ref.flash_attention(q, k, v, causal=True)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


@pytest.mark.parametrize("window", [16, 64])
def test_flash_local_window(window):
    """Window masking + the lo-bound tile skip agree with the oracle."""
    q, k, v = _qkv(1, 2, 128, 128, 16, jnp.float32, seed=1)
    out = flash_attention(q, k, v, causal=True, window=window, bq=32, bk=32,
                          interpret=True)
    want = ref.flash_attention(q, k, v, causal=True, window=window)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


def test_flash_noncausal():
    q, k, v = _qkv(1, 1, 64, 128, 16, jnp.float32, seed=2)
    out = flash_attention(q, k, v, causal=False, bq=32, bk=32, interpret=True)
    want = ref.flash_attention(q, k, v, causal=False)
    np.testing.assert_allclose(np.asarray(out), np.asarray(want), atol=3e-5,
                               rtol=3e-5)


def test_flash_tile_skip_counts():
    """Causal hi-bound: last q block visits all kv tiles, first visits one."""
    # structural check via output equality at block granularity is covered
    # above; here assert the bounds arithmetic used by the kernel
    bq = bk = 32
    S = 128
    for qi in range(S // bq):
        hi = (qi * bq + bq + bk - 1) // bk
        assert hi == qi + 1
    window = 64
    for qi in range(S // bq):
        lo = max(0, (qi * bq - window + 1)) // bk
        assert lo <= qi
