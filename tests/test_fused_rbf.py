"""The fused matrix-free affinity stack.

* hypothesis property: the flash-style fused RBF matmat kernel matches the
  materialized reference ``diag(rs) S diag(cs) V`` product — uneven n vs
  tile size, padding rows, f32 and (looser) bf16 compute;
* operator law: the ``fused-rbf`` NormalizedOperator agrees with the
  ``dense`` backend's operator on shared rows, including zero-degree rows;
* estimator/CLI: fused-rbf is selectable end to end and reports the
  matrix-free stats (``matrix_passes`` / ``bytes_streamed``);
* engine routing: the planner sends fits-in-memory-but-dense-doesn't jobs
  to the fused path instead of spilling CSR shards;
* engine prefetch: shard readahead overlaps compute and reports
  ``prefetch_hits``.
"""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.cluster import SpectralClustering, ari
from repro.cluster.affinity import AFFINITIES
from repro.data import synthetic
from repro.distrib import mesh_utils
from repro.kernels import ops, ref


# ---------------------------------------------------------------------------
# kernel-level property: fused == materialized reference
# ---------------------------------------------------------------------------

@settings(max_examples=10, deadline=None)
@given(st.integers(5, 80), st.integers(1, 70), st.integers(1, 6),
       st.integers(1, 9), st.integers(0, 2**16))
def test_fused_matmat_matches_reference_f32(n, m, d, b, seed):
    """<= 1e-4 agreement in f32 at any (n, m) — including shapes far from
    the 32-row tiles used here, so the zero-padded tail rows/cols are
    exercised on both sides of the product."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 4)
    x = jax.random.normal(ks[0], (n, d))
    y = jax.random.normal(ks[1], (m, d))
    V = jax.random.normal(ks[2], (m, b))
    rs = jax.random.uniform(ks[3], (n,))
    cs = jax.random.uniform(ks[3], (m,), minval=0.1)
    got = np.asarray(ops.fused_rbf_matmat(x, y, V, 0.9, rs, cs,
                                          bm=32, bn=32, interpret=True))
    want = np.asarray(ref.fused_rbf_matmat(x, y, V, 0.9, rs, cs))
    assert got.shape == (n, b)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-4)


@settings(max_examples=6, deadline=None)
@given(st.integers(8, 70), st.integers(1, 5), st.integers(0, 2**16))
def test_fused_matmat_bf16_loose_bound(n, b, seed):
    """bf16 compute perturbs only the tile entries (accumulation stays
    f32): the error bound is the bf16 epsilon times the row mass, far
    looser than f32 but still a few decimal digits."""
    ks = jax.random.split(jax.random.PRNGKey(seed), 2)
    x = jax.random.normal(ks[0], (n, 4))
    V = jax.random.normal(ks[1], (n, b))
    ones = jnp.ones((n,))
    got = np.asarray(ops.fused_rbf_matmat(x, x, V, 1.0, ones, ones, bm=32,
                                          bn=32, compute_dtype="bf16",
                                          interpret=True))
    want = np.asarray(ref.fused_rbf_matmat(x, x, V, 1.0, ones, ones))
    scale = np.abs(want).max() + 1.0
    np.testing.assert_allclose(got / scale, want / scale, atol=4e-2)


def test_compute_dtype_resolution_and_validation():
    from repro.kernels.fused_rbf_matmat import resolve_compute_dtype
    assert resolve_compute_dtype(None) == jnp.float32
    assert resolve_compute_dtype("float32") == jnp.float32
    assert resolve_compute_dtype("bf16") == jnp.bfloat16
    assert resolve_compute_dtype(jnp.bfloat16) == jnp.bfloat16
    with pytest.raises(ValueError, match="compute_dtype"):
        resolve_compute_dtype("fp8")
    with pytest.raises(ValueError, match="compute_dtype"):
        SpectralClustering(2, affinity="fused-rbf", compute_dtype="int8")


# ---------------------------------------------------------------------------
# operator law: fused-rbf == dense backend (padding + zero-degree rows)
# ---------------------------------------------------------------------------

def _blob_x(n=97, d=4):
    pts, _ = synthetic.blobs(n, 3, dim=d, spread=0.8, seed=0)
    return jnp.asarray(pts)


def test_fused_operator_matches_dense_operator():
    x = _blob_x()
    n = int(x.shape[0])
    mesh = mesh_utils.local_mesh("rows")
    est = SpectralClustering(3, sigma=1.0)
    op_f = AFFINITIES.get("fused-rbf")(est, x, jnp.asarray(1.0), mesh)
    op_d = AFFINITIES.get("dense")(est, x, jnp.asarray(1.0), mesh)
    assert op_f.n == n and op_f.n_pad % 128 == 0      # tile-padded
    V = jax.random.normal(jax.random.PRNGKey(1), (op_f.n_pad, 4))
    got = np.asarray(op_f.matmat(V))
    want = np.asarray(op_d.matmat(V[:op_d.n_pad]))
    np.testing.assert_allclose(got[:n], want[:n], rtol=1e-4, atol=1e-4)
    # padding rows live in the operator's null space
    assert np.abs(got[n:]).max() < 1e-5
    # and the eigh-oracle materializer agrees on the shared block
    A_f = np.asarray(op_f.materialize())
    A_d = np.asarray(op_d.materialize())
    np.testing.assert_allclose(A_f[:n, :n], A_d[:n, :n],
                               rtol=1e-4, atol=1e-4)


def test_fused_operator_zero_degree_and_isolated_rows():
    """Zero-degree (padding) rows must be pinned out of the S-term exactly
    like the dense backend's, and an isolated outlier point (off-diagonal
    similarity underflows to 0, degree = the RBF self-similarity 1) must
    reduce to the same detached 2x-identity row on both paths."""
    x = np.array(_blob_x(40))
    x[7] = 1e4                    # off-diagonal similarity underflows to 0
    x = jnp.asarray(x)
    mesh = mesh_utils.local_mesh("rows")
    est = SpectralClustering(3, sigma=1.0)
    op_f = AFFINITIES.get("fused-rbf")(est, x, jnp.asarray(1.0), mesh)
    op_d = AFFINITIES.get("dense")(est, x, jnp.asarray(1.0), mesh)
    # padding rows: degree 0 -> D^{-1/2} pinned to 0 (masked_inv_sqrt)
    assert np.abs(np.asarray(op_f.inv_sqrt[40:])).max() == 0.0
    assert float(op_f.valid[40:].max()) == 0.0
    V = jax.random.normal(jax.random.PRNGKey(2), (op_f.n_pad, 3))
    got = np.asarray(op_f.matmat(V))
    want = np.asarray(op_d.matmat(V[:op_d.n_pad]))
    np.testing.assert_allclose(got[:40], want[:40], rtol=1e-4, atol=1e-4)
    # the detached point sees only its self-similarity: A row = 2 * I row
    np.testing.assert_allclose(got[7], 2.0 * np.asarray(V)[7], rtol=1e-4,
                               atol=1e-4)
    assert np.abs(got[40:]).max() < 1e-5        # padding stays null


# ---------------------------------------------------------------------------
# estimator + CLI
# ---------------------------------------------------------------------------

def test_estimator_fused_matches_dense_labels():
    pts, _ = synthetic.blobs(200, 3, dim=4, spread=0.8, seed=0)
    x = jnp.asarray(pts)
    kw = dict(sigma=1.0, seed=0, lanczos_steps=96)
    dense = SpectralClustering(3, affinity="dense", **kw).fit(x)
    fused = SpectralClustering(3, affinity="fused-rbf", **kw).fit(x)
    assert ari(np.asarray(dense.labels_), np.asarray(fused.labels_)) >= 0.99
    np.testing.assert_allclose(np.asarray(dense.eigenvalues_),
                               np.asarray(fused.eigenvalues_), atol=1e-3)
    stats = fused.info_["engine"]               # operator build stats
    assert stats["matrix_passes"] >= 96         # degree pass + lanczos
    assert stats["bytes_streamed"] > 0
    assert stats["affinity_peak_bytes"] < stats["dense_equiv_bytes"]

    bf16 = SpectralClustering(3, affinity="fused-rbf", compute_dtype="bf16",
                              **kw).fit(x)
    assert ari(np.asarray(dense.labels_), np.asarray(bf16.labels_)) >= 0.99
    assert bf16.info_["engine"]["compute_dtype"] == "bfloat16"


def test_eigh_reports_matrix_passes():
    pts, _ = synthetic.blobs(48, 2, dim=3, seed=1)
    est = SpectralClustering(2, affinity="dense", eigensolver="eigh",
                             sigma=1.0).fit(jnp.asarray(pts))
    # the dense factorization sweeps the padded matrix ~n_pad times
    assert est.info_["matrix_passes"] == est.info_["n_pad"]


def test_cli_fused_rbf_selectable(capsys):
    from repro.launch import spectral_job
    spectral_job.main(["--blobs", "80", "--k", "3", "--affinity", "fused-rbf",
                       "--compute-dtype", "bf16", "--eigensolver",
                       "block-lanczos", "--block-size", "4"])
    out = capsys.readouterr().out
    assert "affinity=fused-rbf" in out
    assert "compute_dtype=bfloat16" in out
    assert "bytes_streamed=" in out


# ---------------------------------------------------------------------------
# engine routing + prefetch
# ---------------------------------------------------------------------------

def test_route_path_budget_rules():
    from repro import engine
    from repro.engine.plan import route_path
    # dense fits the budget -> classic ooc (nothing would spill anyway)
    small = engine.JobPlan(n=64, chunk_size=32, path="auto",
                           memory_budget=1 << 20)
    assert route_path(small, d=4) == "ooc"
    # points fit, dense S doesn't -> fused
    mid = engine.JobPlan(n=2048, chunk_size=512, path="auto",
                         memory_budget=1 << 20)       # 1 MiB << 16 MiB S
    assert route_path(mid, d=4) == "fused"
    # not even the points fit -> ooc shards
    big = engine.JobPlan(n=2048, chunk_size=512, path="auto",
                         memory_budget=8 * 1024)
    assert route_path(big, d=4) == "ooc"
    # no budget -> historical in-RAM ooc; forced paths always win
    assert route_path(engine.JobPlan(n=2048, path="auto"), d=4) == "ooc"
    forced = engine.JobPlan(n=64, path="fused", memory_budget=1 << 20)
    assert route_path(forced, d=4) == "fused"
    with pytest.raises(ValueError, match="path"):
        engine.JobPlan(n=10, path="dense")


def test_run_job_routes_to_fused_and_clusters():
    from repro import engine
    from repro.data.chunked import BlobChunks
    n = 768
    reader = BlobChunks(n, 3, chunk_size=256, dim=4, spread=0.8, seed=0)
    budget = 256 * 1024            # points 12 KiB fit; dense S 2.25 MiB not
    plan = engine.JobPlan(n=n, chunk_size=256, k=3, sigma=1.0, seed=0,
                          path="auto", memory_budget=budget,
                          lanczos_steps=96, kmeans_rounds=30)
    res = engine.run_job(plan, reader)
    assert res.stats["path"] == "fused"
    assert res.graph is None                       # no CSR shards built
    assert res.stats["matrix_passes"] > 0
    assert res.stats["affinity_peak_bytes"] <= budget
    assert ari(reader.all_labels(), res.labels) >= 0.95


def test_shard_prefetch_hits_and_stats(tmp_path):
    from repro import engine
    from repro.data.chunked import ArrayChunks
    pts, _ = synthetic.blobs(200, 3, dim=4, spread=0.8, seed=0)
    plan = engine.JobPlan(n=200, chunk_size=25, t=8, k=3, sigma=1.0,
                          memory_budget=16 * 1024, spill_dir=str(tmp_path))
    graph, _ = engine.build_graph(ArrayChunks(pts, 25), plan)
    assert graph.stats_snapshot()["store_bytes_spilled"] > 0
    V = np.random.RandomState(0).randn(200, 4).astype(np.float32)
    got = graph.matmat(V)
    np.testing.assert_allclose(got, graph.to_dense() @ V, rtol=1e-4,
                               atol=1e-5)          # prefetch changes nothing
    snap = graph.stats_snapshot()
    assert snap["prefetch_hits"] + snap["prefetch_misses"] == 8
    # the cross-call warm start overlaps the CALLER's work between passes
    # (the eigensolver's Rayleigh-Ritz step); emulate that gap so the
    # shard-0 readahead deterministically lands before the next call
    import time
    for _ in range(3):
        time.sleep(0.05)
        graph.matmat(V)
    assert graph.stats_snapshot()["prefetch_hits"] > 0


def test_prefetch_stats_reach_estimator_info(tmp_path):
    pts, _ = synthetic.blobs(160, 3, dim=4, spread=0.8, seed=0)
    est = SpectralClustering(k=3, affinity="ooc-topt", sparsify_t=8,
                             sigma=1.0, seed=0, chunk_size=40,
                             lanczos_steps=48,
                             memory_budget=16 * 1024,
                             spill_dir=str(tmp_path)).fit(jnp.asarray(pts))
    eng = est.info_["engine"]
    # hit/miss accounting is plumbed end to end; whether a toy problem's
    # inter-pass gap beats the shard-load latency is timing-dependent, so
    # hits > 0 is asserted where timing is controlled (the direct graph
    # test above and the fused_sweep benchmark)
    assert eng["prefetch_hits"] + eng["prefetch_misses"] > 0
    assert eng["store_bytes_spilled"] > 0
