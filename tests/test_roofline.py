"""The kernel-level roofline peak model (benchmarks/roofline.py) and the
schedule-equivalence property: any legal schedule computes the same
function as the default, within 1e-4 in f32 — the contract that makes the
autotuner's search safe by construction.
"""
import os
import sys

import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.dirname(os.path.dirname(os.path.abspath(
    __file__))))  # repo root, so `benchmarks` imports without installation

from benchmarks import roofline  # noqa: E402

from _hypothesis_compat import given, settings, st  # noqa: E402
from repro.kernels import ops, ref  # noqa: E402
from repro.tune import Schedule  # noqa: E402


# ---------------------------------------------------------------------------
# peak model sanity (CPU interpret path: numbers must be consistent, the
# absolute peaks are a yardstick, not a silicon claim)


def test_device_peaks_lookup():
    assert roofline.device_peaks("cpu") == roofline.DEVICE_PEAKS["cpu"]
    assert roofline.device_peaks("tpu-v5e")["flops"] == roofline.PEAK_FLOPS
    # unknown TPU generations fall back to the v5e row, anything else to cpu
    assert roofline.device_peaks("tpu-v9") == roofline.DEVICE_PEAKS["tpu-v5e"]
    assert roofline.device_peaks("gpu-x") == roofline.DEVICE_PEAKS["cpu"]
    # None = current backend; this suite runs on CPU
    assert roofline.device_peaks() == roofline.DEVICE_PEAKS["cpu"]


def test_kernel_roofline_fractions():
    peaks = roofline.DEVICE_PEAKS["cpu"]
    # exactly one second at exactly half of each peak
    rec = roofline.kernel_roofline(peaks["flops"] / 2, peaks["bytes"] / 2,
                                   1.0, kind="cpu")
    assert abs(rec["frac_peak_flops"] - 0.5) < 1e-6
    assert abs(rec["frac_peak_bytes"] - 0.5) < 1e-6
    assert rec["gflops"] == round(peaks["flops"] / 2 / 1e9, 2)


def test_kernel_roofline_dominant_bottleneck():
    peaks = roofline.DEVICE_PEAKS["cpu"]
    # lots of flops, few bytes -> compute-bound; and vice versa
    hi_flops = roofline.kernel_roofline(peaks["flops"], 1.0, 1.0, kind="cpu")
    hi_bytes = roofline.kernel_roofline(1.0, peaks["bytes"], 1.0, kind="cpu")
    assert hi_flops["dominant"] == "compute"
    assert hi_bytes["dominant"] == "memory"


def test_kernel_roofline_never_divides_by_zero():
    rec = roofline.kernel_roofline(1e9, 1e6, 0.0, kind="cpu")
    assert np.isfinite(rec["gflops"])


def test_spec_models_positive_for_defaults():
    from repro.tune import KERNELS
    shapes = {"rbf_similarity": dict(n=256, m=256, d=8),
              "fused_rbf_matmat": dict(n=256, m=256, d=8, b=8),
              "fused_nystrom_matmat": dict(n=256, m=256, d=8, b=8),
              "block_matmat": dict(n=256, m=256, b=8),
              "kmeans_assign": dict(n=256, d=8, k=8)}
    for name, sp in KERNELS.items():
        s = sp.default
        assert sp.flops_model(s, **shapes[name]) > 0
        assert sp.bytes_model(s, **shapes[name]) > 0
        assert sp.vmem_model(s, **shapes[name]) > 0


# ---------------------------------------------------------------------------
# schedule-equivalence property: legal schedule == default, <= 1e-4
# (indices into candidate tile lists — the compat shim only has
# st.integers/st.floats)

_TILES = (8, 16, 32, 64)
_ACCS = ("inplace", "scratch")

_x = jnp.asarray(np.random.RandomState(0).randn(96, 5).astype(np.float32))
_y = jnp.asarray(np.random.RandomState(1).randn(80, 5).astype(np.float32))
_V = jnp.asarray(np.random.RandomState(2).randn(80, 4).astype(np.float32))
_A = jnp.asarray(np.random.RandomState(3).randn(96, 80).astype(np.float32))

_FUSED_DEFAULT = np.asarray(ops.fused_rbf_matmat(_x, _y, _V, 0.9))
_MATMAT_DEFAULT = np.asarray(ops.block_matmat(_A, _V))


@settings(max_examples=12)
@given(st.integers(0, len(_TILES) - 1), st.integers(0, len(_TILES) - 1),
       st.integers(0, 1))
def test_fused_rbf_schedule_equivalence(bi, bj, ai):
    s = Schedule(bm=_TILES[bi], bn=_TILES[bj], acc=_ACCS[ai])
    got = np.asarray(ops.fused_rbf_matmat(_x, _y, _V, 0.9, schedule=s))
    np.testing.assert_allclose(got, _FUSED_DEFAULT, atol=1e-4)


@settings(max_examples=12)
@given(st.integers(0, len(_TILES) - 1), st.integers(0, len(_TILES) - 1),
       st.integers(0, 1))
def test_block_matmat_schedule_equivalence(bi, bj, ai):
    s = Schedule(bm=_TILES[bi], bn=_TILES[bj], acc=_ACCS[ai])
    got = np.asarray(ops.block_matmat(_A, _V, schedule=s))
    np.testing.assert_allclose(got, _MATMAT_DEFAULT, atol=1e-4)


def test_equivalence_against_oracle():
    # the defaults themselves are right (anchors the property tests)
    want = np.asarray(ref.rbf_similarity(_x, _y, 0.9)) @ np.asarray(_V)
    np.testing.assert_allclose(_FUSED_DEFAULT, want, atol=1e-4)
    np.testing.assert_allclose(_MATMAT_DEFAULT,
                               np.asarray(_A) @ np.asarray(_V), atol=1e-4)
