"""The unified estimator API: backend registries, oracle agreement,
precomputed round-trip, out-of-sample prediction, and legacy-shim parity."""
import itertools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.cluster import (AFFINITIES, ASSIGNERS, EIGENSOLVERS,
                           SpectralClustering)
from repro.core import similarity as sim, spectral
from repro.data import synthetic
from repro.data.graph_file import adjacency_dense, parse_topology, write_topology


def _perm_acc(labels, truth, k):
    from itertools import permutations
    labels = np.asarray(labels)
    return max(np.mean(np.array([p[t] for t in truth]) == labels)
               for p in permutations(range(k)))


# ---------------------------------------------------------------------------
# registry behaviour
# ---------------------------------------------------------------------------

def test_registry_unknown_backend_messages():
    with pytest.raises(ValueError, match=r"unknown affinity backend 'rbf\?'"):
        SpectralClustering(3, affinity="rbf?")
    with pytest.raises(ValueError, match="unknown eigensolver backend"):
        SpectralClustering(3, eigensolver="power-iteration")
    with pytest.raises(ValueError, match="unknown assigner backend"):
        SpectralClustering(3, assigner="gonzalez")
    # the error names what IS registered
    with pytest.raises(ValueError, match="triangular"):
        SpectralClustering(3, affinity="nope")


def test_registry_contents_and_custom_registration():
    assert set(AFFINITIES.names()) >= {"dense", "triangular", "compact",
                                       "precomputed", "knn-topt"}
    assert set(EIGENSOLVERS.names()) >= {"eigh", "lanczos"}
    assert set(ASSIGNERS.names()) >= {"lloyd", "minibatch"}

    @ASSIGNERS.register("test-constant")
    def constant_assigner(est, Y, valid, key, mesh):
        return jnp.zeros((Y.shape[0],), jnp.int32), jnp.zeros(
            (est.k, Y.shape[1]), Y.dtype)

    try:
        pts, _ = synthetic.blobs(24, 2, seed=0)
        est = SpectralClustering(2, assigner="test-constant", sigma=1.0)
        est.fit(jnp.asarray(pts))
        assert np.asarray(est.labels_).max() == 0
        with pytest.raises(ValueError, match="already registered"):
            ASSIGNERS.register("test-constant")(constant_assigner)
    finally:
        ASSIGNERS._entries.pop("test-constant", None)


def test_precomputed_rejects_nonsquare():
    with pytest.raises(ValueError, match="square"):
        SpectralClustering(2, affinity="precomputed").fit(jnp.ones((4, 3)))


def test_predict_before_fit_raises():
    with pytest.raises(ValueError, match="not .*fitted"):
        SpectralClustering(2).predict(jnp.ones((3, 2)))


# ---------------------------------------------------------------------------
# legacy parity / oracle agreement
# ---------------------------------------------------------------------------

def test_estimator_matches_legacy_fit_bit_for_bit():
    """The acceptance invariant: triangular/lanczos/lloyd reproduces the
    legacy spectral.fit pipeline exactly (same RNG discipline, same ops)."""
    pts, _ = synthetic.blobs(100, 3, seed=5)
    x = jnp.asarray(pts)
    cfg = spectral.SpectralConfig(k=3, sigma=1.0, lanczos_steps=40, seed=0)
    with pytest.deprecated_call():
        res = spectral.fit(x, cfg)
    est = SpectralClustering(3, affinity="triangular", eigensolver="lanczos",
                             assigner="lloyd", sigma=1.0, lanczos_steps=40,
                             seed=0).fit(x)
    np.testing.assert_array_equal(np.asarray(res.labels),
                                  np.asarray(est.labels_))
    np.testing.assert_array_equal(np.asarray(res.embedding),
                                  np.asarray(est.embedding_))
    np.testing.assert_array_equal(np.asarray(res.eigenvalues),
                                  np.asarray(est.eigenvalues_))


def test_estimator_agrees_with_dense_oracle_blobs():
    pts, truth = synthetic.blobs(90, 3, seed=7)
    x = jnp.asarray(pts)
    oracle = SpectralClustering(3, affinity="dense", eigensolver="eigh",
                                sigma=1.0, seed=0).fit(x)
    dist = SpectralClustering(3, affinity="triangular", eigensolver="lanczos",
                              sigma=1.0, lanczos_steps=40, seed=0).fit(x)
    np.testing.assert_allclose(np.asarray(dist.eigenvalues_),
                               np.asarray(oracle.eigenvalues_), atol=1e-3)
    assert _perm_acc(oracle.labels_, truth, 3) == 1.0
    assert _perm_acc(dist.labels_, truth, 3) == 1.0


def test_estimator_agrees_with_dense_oracle_rings():
    pts, truth = synthetic.rings(300, 2, seed=0)
    x = jnp.asarray(pts)
    for backend in ({"affinity": "dense", "eigensolver": "eigh"},
                    {"affinity": "triangular", "eigensolver": "lanczos",
                     "lanczos_steps": 64}):
        est = SpectralClustering(2, sigma=0.25, kmeans_iters=40, seed=0,
                                 **backend).fit(x)
        labels = np.asarray(est.labels_)
        acc = max(np.mean(labels == truth), np.mean(labels == 1 - truth))
        assert acc > 0.95, (backend, acc)


# ---------------------------------------------------------------------------
# every combination of registered backends runs end-to-end
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("affinity,eigensolver,assigner", list(
    itertools.product(["dense", "triangular", "compact", "precomputed",
                       "knn-topt"],
                      ["eigh", "lanczos"], ["lloyd", "minibatch"])))
def test_backend_combinations_end_to_end(affinity, eigensolver, assigner):
    pts, truth = synthetic.blobs(60, 3, seed=2)
    x = jnp.asarray(pts)
    arg = sim.dense_similarity(x, 1.0) if affinity == "precomputed" else x
    est = SpectralClustering(3, affinity=affinity, eigensolver=eigensolver,
                             assigner=assigner, sigma=1.0, lanczos_steps=40,
                             seed=0).fit(arg)
    assert np.asarray(est.labels_).shape == (60,)
    assert np.asarray(est.embedding_).shape == (60, 3)
    assert _perm_acc(est.labels_, truth, 3) > 0.9
    evals = np.asarray(est.eigenvalues_)
    assert (evals > -1e-3).all() and (evals < 2 + 1e-3).all()


# ---------------------------------------------------------------------------
# precomputed affinity round-trip on the §5 topology format
# ---------------------------------------------------------------------------

def test_precomputed_topology_graph_roundtrip(tmp_path):
    edges, truth = synthetic.synthetic_graph(n=160, n_edges=900, k=3, seed=0)
    path = str(tmp_path / "topo.txt")
    write_topology(path, 160, edges)
    n, edges_back = parse_topology(path)
    assert n == 160
    S = adjacency_dense(n, edges_back)
    est = SpectralClustering(3, affinity="precomputed", lanczos_steps=48,
                             seed=0).fit(jnp.asarray(S))
    assert _perm_acc(est.labels_, truth, 3) > 0.9
    # fit() with affinity="precomputed" and fit_affinity() are the same path
    est2 = SpectralClustering(3, affinity="triangular", lanczos_steps=48,
                              seed=0).fit_affinity(jnp.asarray(S))
    np.testing.assert_array_equal(np.asarray(est.labels_),
                                  np.asarray(est2.labels_))


# ---------------------------------------------------------------------------
# out-of-sample transform / predict
# ---------------------------------------------------------------------------

def test_predict_heldout_points():
    rng = np.random.RandomState(0)
    pts, truth = synthetic.blobs(120, 3, spread=0.08, seed=4)
    x = jnp.asarray(pts)
    est = SpectralClustering(3, affinity="triangular", sigma=1.0,
                             lanczos_steps=40, seed=0).fit(x)

    # training points map back to their own clusters
    self_pred = np.asarray(est.predict(x))
    assert np.mean(self_pred == np.asarray(est.labels_)) > 0.97

    # held-out points drawn near training points inherit their cluster
    idx = rng.choice(120, size=30, replace=False)
    held = pts[idx] + rng.randn(30, pts.shape[1]).astype(np.float32) * 0.01
    pred = np.asarray(est.predict(jnp.asarray(held)))
    assert np.mean(pred == np.asarray(est.labels_)[idx]) > 0.9

    emb = np.asarray(est.transform(jnp.asarray(held)))
    assert emb.shape == (30, 3)
    np.testing.assert_allclose(np.linalg.norm(emb, axis=1), 1.0, atol=1e-4)


def test_precomputed_fit_cannot_predict():
    pts, _ = synthetic.blobs(40, 2, seed=1)
    S = sim.dense_similarity(jnp.asarray(pts), 1.0)
    est = SpectralClustering(2, affinity="precomputed").fit(S)
    with pytest.raises(ValueError, match="precomputed"):
        est.predict(jnp.asarray(pts))


# ---------------------------------------------------------------------------
# mini-batch assigner quality
# ---------------------------------------------------------------------------

def test_minibatch_assigner_recovers_blobs():
    pts, truth = synthetic.blobs(200, 3, spread=0.05, seed=9)
    est = SpectralClustering(3, affinity="dense", eigensolver="eigh",
                             assigner="minibatch", sigma=1.0,
                             minibatch_size=64, seed=0).fit(jnp.asarray(pts))
    assert _perm_acc(est.labels_, truth, 3) > 0.97
