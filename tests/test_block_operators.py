"""The block-operator contract across the refactored stack.

* property: ``op.matmat(V)`` equals column-stacked ``op.matvec(v_i)`` for
  EVERY registered affinity backend (the interface every eigensolver now
  leans on);
* block Lanczos: oracle agreement, pass accounting, resumable state;
* Chebyshev-Davidson: eigenvalue agreement with the exact ``eigh`` oracle
  on the paper's synthetic blobs;
* estimator/CLI: the new backends are selectable end-to-end;
* seeding: the jax and numpy D^2-sampling twins agree statistically.
"""
import functools

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro.cluster import SpectralClustering, ari
from repro.cluster.affinity import AFFINITIES
from repro.core import (chebdav as cd, lanczos as lz, laplacian as lp,
                        seeding, similarity as sim)
from repro.data import synthetic
from repro.distrib import mesh_utils

# every affinity must satisfy the matmat == stacked-matvec law
BACKENDS = ("dense", "triangular", "compact", "precomputed", "knn-topt",
            "ooc-topt", "fused-rbf")


@functools.lru_cache(maxsize=None)
def _operator(backend: str):
    pts, _ = synthetic.blobs(42, 3, dim=3, seed=11)
    x = jnp.asarray(pts)
    est = SpectralClustering(3, sigma=1.0, sparsify_t=8, chunk_size=16,
                             seed=0)
    mesh = mesh_utils.local_mesh("rows")
    arg = sim.dense_similarity(x, 1.0) if backend == "precomputed" else x
    return AFFINITIES.get(backend)(est, arg, jnp.asarray(1.0), mesh)


@settings(max_examples=12, deadline=None)
@given(st.integers(0, len(BACKENDS) - 1), st.integers(1, 5),
       st.integers(0, 2**16))
def test_matmat_equals_stacked_matvec(backend_idx, width, seed):
    op = _operator(BACKENDS[backend_idx])
    V = jax.random.normal(jax.random.PRNGKey(seed), (op.n_pad, width))
    got = np.asarray(op.matmat(V))
    want = np.stack([np.asarray(op.matvec(V[:, j]))
                     for j in range(width)], axis=1)
    np.testing.assert_allclose(got, want, rtol=1e-4, atol=1e-5)
    assert got.shape == (op.n_pad, width)


def test_matvec_only_backend_gets_matmat_fallback():
    """Third-party backends that still supply only matvec keep working:
    the operator derives a column-loop matmat (API.md migration note)."""
    from repro.cluster.operator import NormalizedOperator
    n = 12
    A = np.random.RandomState(0).randn(n, n).astype(np.float32)
    A = A + A.T
    op = NormalizedOperator(
        matvec=lambda v: jnp.asarray(A) @ v,
        valid=jnp.ones((n,)), inv_sqrt=jnp.ones((n,)), n=n, n_pad=n,
        mesh=None)
    V = np.random.RandomState(1).randn(n, 3).astype(np.float32)
    np.testing.assert_allclose(np.asarray(op.matmat(jnp.asarray(V))), A @ V,
                               rtol=1e-4, atol=1e-5)
    # and materialize() assembles A through identity blocks
    np.testing.assert_allclose(np.asarray(op.materialize(block=5)), A,
                               rtol=1e-4, atol=1e-5)


def test_operator_requires_some_product():
    from repro.cluster.operator import NormalizedOperator
    with pytest.raises(ValueError, match="matmat"):
        NormalizedOperator(valid=jnp.ones((4,)), inv_sqrt=jnp.ones((4,)),
                           n=4, n_pad=4, mesh=None)


# ---------------------------------------------------------------------------
# block Lanczos
# ---------------------------------------------------------------------------

def _dense_op(n=96, k=3, seed=3):
    pts, truth = synthetic.blobs(n, k, dim=4, spread=0.6, seed=seed)
    S = sim.dense_similarity(jnp.asarray(pts), 1.0)
    valid = jnp.ones((n,), jnp.float32)
    matmat, _ = lp.make_dense_operator(S, valid)
    A = lp.dense_shifted_matrix(S, valid)
    return matmat, A, truth


@pytest.mark.parametrize("block_size", [1, 2, 4, 8])
def test_block_lanczos_matches_eigh(block_size):
    matmat, A, _ = _dense_op()
    n = A.shape[0]
    steps = max(1, 48 // block_size)
    state = lz.block_lanczos(matmat, n, steps, jax.random.PRNGKey(0),
                             block_size=block_size)
    vals, vecs = lz.block_topk_of_shifted(state, 3)
    evals_A = np.asarray(jnp.linalg.eigh(A)[0])
    want = (2.0 - evals_A[-3:])[::-1]
    np.testing.assert_allclose(np.asarray(vals), want, atol=1e-4)
    # Ritz vectors are true eigenvectors: small residuals
    res = lz.residuals(lambda v: matmat(v[:, None])[:, 0],
                       vals, vecs, shift=2.0)
    assert float(jnp.max(res)) < 1e-3


def test_block_lanczos_resumable_checkpoint_state():
    matmat, A, _ = _dense_op(n=64)
    n = A.shape[0]
    key = jax.random.PRNGKey(5)
    full = lz.block_run(matmat, lz.init_block_state(n, 10, key, 4), 10)
    half = lz.block_run(matmat, lz.init_block_state(n, 10, key, 4), 5)
    resumed = lz.block_run(matmat, half, 5)
    np.testing.assert_allclose(np.asarray(full.A), np.asarray(resumed.A),
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(np.asarray(full.B), np.asarray(resumed.B),
                               rtol=1e-4, atol=1e-5)


def test_block_basis_orthonormal():
    matmat, A, _ = _dense_op(n=80)
    n = A.shape[0]
    b, s = 4, 8
    state = lz.block_lanczos(matmat, n, s, jax.random.PRNGKey(2),
                             block_size=b)
    V = np.asarray(state.V)[: s * b]          # filled basis rows
    G = V @ V.T
    np.testing.assert_allclose(G, np.eye(s * b), atol=1e-4)


def test_scalar_lanczos_is_width1_view():
    """The scalar recurrence (now the b=1 view of the block step) still
    reproduces the eigh oracle and stays resumable."""
    matmat, A, _ = _dense_op(n=72)
    n = A.shape[0]
    mv = lambda v: matmat(v[:, None])[:, 0]                   # noqa: E731
    state = lz.lanczos(mv, n, 40, jax.random.PRNGKey(0))
    vals, _ = lz.topk_of_shifted(state, 3)
    evals_A = np.asarray(jnp.linalg.eigh(A)[0])
    np.testing.assert_allclose(np.asarray(vals),
                               (2.0 - evals_A[-3:])[::-1], atol=1e-4)
    assert float(state.beta[0]) == 0.0
    assert np.all(np.asarray(state.beta) >= 0.0)   # QR sign-fixed


# ---------------------------------------------------------------------------
# Chebyshev-Davidson
# ---------------------------------------------------------------------------

def test_chebdav_matches_eigh_oracle_on_paper_blobs():
    """The satellite oracle: "chebdav" matches "eigh" eigenvalues to 1e-4
    on the paper's synthetic blobs."""
    pts, _ = synthetic.blobs(120, 3, dim=2, spread=0.15, seed=0)
    x = jnp.asarray(pts)
    eigh_est = SpectralClustering(3, affinity="dense", eigensolver="eigh",
                                  sigma=1.0, seed=0).fit(x)
    chb = SpectralClustering(3, affinity="dense", eigensolver="chebdav",
                             sigma=1.0, seed=0).fit(x)
    np.testing.assert_allclose(np.asarray(chb.eigenvalues_),
                               np.asarray(eigh_est.eigenvalues_), atol=1e-4)
    assert ari(np.asarray(eigh_est.labels_), np.asarray(chb.labels_)) >= 0.95
    assert chb.info_["matrix_passes"] > 0
    assert chb.info_["max_residual"] < 1e-4


def test_chebdav_counts_passes_and_filter_amplifies():
    matmat, A, _ = _dense_op(n=64)
    n = A.shape[0]
    res = cd.chebdav(matmat, n, 3, jax.random.PRNGKey(0), block_size=3,
                     degree=8)
    assert res.passes > 0 and res.iters >= 1
    evals_A = np.asarray(jnp.linalg.eigh(A)[0])
    np.testing.assert_allclose(np.asarray(res.evals), evals_A[-3:][::-1],
                               atol=1e-4)
    # the filter really does amplify the wanted end relative to the damp
    # interval: a random block gains alignment with the top eigenvector
    top = jnp.linalg.eigh(A)[1][:, -1]
    X = jax.random.normal(jax.random.PRNGKey(1), (n, 2))
    X = X / jnp.linalg.norm(X, axis=0, keepdims=True)
    Y = cd.chebyshev_filter(matmat, X, 10, 0.0, 1.2, 2.0)
    Y = Y / jnp.maximum(jnp.linalg.norm(Y, axis=0, keepdims=True), 1e-30)
    before = float(jnp.max(jnp.abs(top @ X)))
    after = float(jnp.max(jnp.abs(top @ Y)))
    assert after > before


# ---------------------------------------------------------------------------
# estimator / engine / CLI integration
# ---------------------------------------------------------------------------

def test_block_size_clamped_and_validated():
    pts, _ = synthetic.blobs(40, 3, seed=1)
    est = SpectralClustering(3, eigensolver="block-lanczos", block_size=64,
                             sigma=1.0, seed=0).fit(jnp.asarray(pts))
    assert est.info_["block_size"] == 40          # clamped to n_pad
    with pytest.raises(ValueError, match="block_size must be positive"):
        SpectralClustering(3, eigensolver="block-lanczos",
                           block_size=0, sigma=1.0).fit(jnp.asarray(pts))
    with pytest.raises(ValueError, match="cheb_degree"):
        SpectralClustering(3, eigensolver="chebdav", cheb_degree=0)


@pytest.mark.parametrize("solver", ["block-lanczos", "chebdav"])
def test_new_eigensolvers_end_to_end(solver):
    pts, truth = synthetic.blobs(90, 3, seed=7)
    est = SpectralClustering(3, affinity="triangular", eigensolver=solver,
                             sigma=1.0, lanczos_steps=40, seed=0)
    est.fit(jnp.asarray(pts))
    assert ari(truth, np.asarray(est.labels_)) >= 0.95
    assert est.info_["matrix_passes"] > 0
    if solver == "block-lanczos":
        # ceil(40 / 8) block steps — 8x fewer passes than scalar lanczos
        assert est.info_["matrix_passes"] == 5


def test_block_lanczos_cuts_engine_shard_gets():
    """The spill-traffic claim: one eigensolve's shard-store gets drop by
    ~the block width when each CSR shard is pulled once per block."""
    from repro import engine
    from repro.cluster.eigensolvers import EIGENSOLVERS
    from repro.data.chunked import ArrayChunks

    pts, _ = synthetic.blobs(200, 3, dim=4, spread=0.8, seed=0)
    plan = engine.JobPlan(n=200, chunk_size=50, t=8, k=3, sigma=1.0)
    graph, _ = engine.build_graph(ArrayChunks(pts, 50), plan)
    op = engine.make_normalized_operator(graph)
    gets = {}
    for solver in ("lanczos", "block-lanczos"):
        est = SpectralClustering(3, eigensolver=solver, sigma=1.0,
                                 lanczos_steps=32, block_size=8, seed=0)
        graph._drain_prefetch()          # settle the async warm-start get
        before = graph.store.stats["gets"]
        _, Z, info = EIGENSOLVERS.get(solver)(est, op, jax.random.PRNGKey(0))
        jax.block_until_ready(Z)
        graph._drain_prefetch()          # ...so both counts are exact
        gets[solver] = graph.store.stats["gets"] - before
    # 32 scalar passes vs ceil(32/8)=4 block passes over 4 shards; each
    # eigensolve pays one extra warm-start get (129 vs 17), so the
    # reduction bound is 7x, not the asymptotic 8x
    assert gets["lanczos"] >= 7 * gets["block-lanczos"] > 0


def test_cli_chebdav_selectable(capsys):
    from repro.launch import spectral_job
    spectral_job.main(["--blobs", "60", "--k", "3", "--affinity", "dense",
                       "--eigensolver", "chebdav", "--cheb-degree", "8"])
    out = capsys.readouterr().out
    assert "eigensolver=chebdav" in out
    assert "matrix_passes=" in out


# ---------------------------------------------------------------------------
# shared k-means++ seeding (the dedupe satellite)
# ---------------------------------------------------------------------------

def test_seeding_twins_share_behaviour():
    """Both substrates pick k distinct, well-spread centers from the same
    blob data, and the numpy twin is what the engine imports."""
    from repro.engine import kmeans as skm
    assert skm._kmeanspp is seeding.kmeans_plusplus_np

    pts, truth = synthetic.blobs(120, 3, dim=2, spread=0.05, seed=2)
    got_np = seeding.kmeans_plusplus_np(pts.astype(np.float64), 3,
                                        np.random.RandomState(0))
    got_jx = np.asarray(seeding.kmeans_plusplus_init(
        jnp.asarray(pts), 3, jax.random.PRNGKey(0)))
    centers = pts[np.arange(120) % 3 == 0].mean(axis=0)  # sanity anchor
    del centers
    for got in (got_np, got_jx):
        # one seed per blob: nearest true blob center of each pick differs
        blob_means = np.stack([pts[truth == c].mean(axis=0)
                               for c in range(3)])
        d = ((got[:, None, :] - blob_means[None]) ** 2).sum(-1)
        assert sorted(np.argmin(d, axis=1).tolist()) == [0, 1, 2]


def test_weighted_seeding_never_picks_masked_rows():
    y = np.zeros((10, 2), np.float64)
    y[5:] = 100.0                      # masked-out far rows
    w = np.array([1.0] * 5 + [0.0] * 5)
    centers = seeding.kmeans_plusplus_np(y, 3, np.random.RandomState(1), w)
    assert np.all(centers < 50.0)
    got = np.asarray(seeding.kmeans_plusplus_init(
        jnp.asarray(y, jnp.float32), 3, jax.random.PRNGKey(4),
        weights=jnp.asarray(w, jnp.float32)))
    assert np.all(got < 50.0)
