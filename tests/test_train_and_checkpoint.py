"""Training substrate: optimizers step correctly, loss decreases, the
checkpoint manager round-trips / GCs / resumes, HLO analysis is exact on
known programs."""
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import CheckpointManager
from repro.data import synthetic
from repro.models import api
from repro.models.config import ModelConfig
from repro.train import optimizer as opt_lib
from repro.train.step import make_train_step


def _tiny_model():
    cfg = ModelConfig(name="t", family="dense", num_layers=2, d_model=64,
                      num_heads=4, num_kv_heads=4, d_ff=128, vocab_size=64,
                      compute_dtype=jnp.float32)
    return api.build(cfg)


def test_adamw_single_step_matches_reference():
    optz = opt_lib.adamw(b1=0.9, b2=0.95, weight_decay=0.0)
    p = {"w": jnp.asarray([1.0, -2.0])}
    g = {"w": jnp.asarray([0.5, -0.5])}
    state = optz.init(p)
    new_p, new_s = optz.update(g, state, p, lr=0.1)
    # after bias correction the first step is lr * sign-ish(g)
    m_hat = 0.1 * np.asarray([0.5, -0.5]) / (1 - 0.9)
    v_hat = 0.05 * np.asarray([0.25, 0.25]) / (1 - 0.95)
    want = np.asarray([1.0, -2.0]) - 0.1 * (m_hat / (np.sqrt(v_hat) + 1e-8))
    np.testing.assert_allclose(np.asarray(new_p["w"]), want, rtol=1e-5)
    assert int(new_s["count"]) == 1


def test_adafactor_state_is_factored():
    optz = opt_lib.adafactor()
    p = {"w": jnp.zeros((8, 16)), "b": jnp.zeros((16,))}
    s = optz.init(p)
    assert s["vr"]["w"].shape == (8,)
    assert s["vc"]["w"].shape == (16,)
    assert s["vr"]["b"].shape == (16,)


@pytest.mark.parametrize("opt_name", ["adamw", "adafactor"])
def test_loss_decreases(opt_name):
    model = _tiny_model()
    optz = opt_lib.get(opt_name)
    step = jax.jit(make_train_step(model, optz, lr_fn=lambda c: 1e-2))
    params = model.init(jax.random.PRNGKey(0))
    state = optz.init(params)
    data = synthetic.lm_batches(8, 32, 64, seed=0)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(data).items()}
        params, state, metrics = step(params, state, batch)
        losses.append(float(metrics["loss"]))
    assert losses[-1] < losses[0] - 0.5, (opt_name, losses[0], losses[-1])


def test_checkpoint_roundtrip_and_gc(tmp_path):
    mgr = CheckpointManager(str(tmp_path), keep=2, async_write=False)
    tree = {"a": jnp.arange(6).reshape(2, 3), "nested": {"b": jnp.ones((4,))},
            "count": jnp.asarray(7)}
    for step in (1, 2, 3, 4):
        mgr.save(step, tree)
    assert mgr.all_steps() == [3, 4], "keep-last-2 GC"
    out = mgr.restore(tree, step=4)
    np.testing.assert_array_equal(np.asarray(out["a"]), np.asarray(tree["a"]))
    np.testing.assert_array_equal(np.asarray(out["nested"]["b"]), np.ones((4,)))


def test_checkpoint_restore_mismatch_names_keys(tmp_path):
    # regression: a template whose pytree doesn't match the saved flat keys
    # used to surface as a bare KeyError from the first missing lookup;
    # now it's a ValueError naming BOTH the missing and the extra keys
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    mgr.save(0, {"a": jnp.ones((2,)), "b": jnp.zeros((3,))})
    with pytest.raises(ValueError) as ei:
        mgr.restore({"a": 0, "c": 0}, step=0)
    msg = str(ei.value)
    assert "c" in msg and "b" in msg, msg
    # subset templates are a mismatch too (silent partial restores hid
    # renamed fields), and the error still names the leftover key
    with pytest.raises(ValueError, match="b"):
        mgr.restore({"a": 0}, step=0)


def test_checkpoint_reads_are_locked_against_async_gc(tmp_path):
    # regression: all_steps/latest_step listed the directory with no lock
    # while the async writer thread GC'd under it — torn listings could
    # show a step that was mid-removal.  Hammer reads against async saves
    # with keep=1: every listed step must still be restorable.
    mgr = CheckpointManager(str(tmp_path), keep=1, async_write=True)
    tree = {"w": jnp.arange(64.0)}
    errors = []

    import threading

    def reader():
        last = -1
        for _ in range(200):
            try:
                steps = mgr.all_steps()
                assert steps == sorted(steps)
                # keep=1 plus at most one not-yet-GC'd fresh write
                assert len(steps) <= 2, steps
                latest = mgr.latest_step()
                if latest is not None:
                    assert latest >= last, (latest, last)
                    last = latest
            except Exception as e:       # pragma: no cover - failure path
                errors.append(e)
                return

    t = threading.Thread(target=reader)
    t.start()
    for step in range(30):
        mgr.save(step, tree)
    mgr.wait()
    t.join()
    assert not errors, errors
    out = mgr.restore(tree, step=mgr.latest_step())
    np.testing.assert_array_equal(np.asarray(out["w"]), np.arange(64.0))


def test_checkpoint_resume_training_continues(tmp_path):
    model = _tiny_model()
    optz = opt_lib.adamw()
    step = jax.jit(make_train_step(model, optz, lr_fn=lambda c: 1e-2))
    params = model.init(jax.random.PRNGKey(0))
    state = optz.init(params)
    data = synthetic.lm_batches(8, 32, 64, seed=0)
    batches = [next(data) for _ in range(10)]
    mgr = CheckpointManager(str(tmp_path), async_write=False)
    # run 10 steps straight
    p1, s1 = params, state
    for b in batches:
        p1, s1, _ = step(p1, s1, {k: jnp.asarray(v) for k, v in b.items()})
    # run 5, checkpoint, restore, run 5
    p2, s2 = params, state
    for b in batches[:5]:
        p2, s2, _ = step(p2, s2, {k: jnp.asarray(v) for k, v in b.items()})
    mgr.save(5, {"params": p2, "opt": s2})
    restored = mgr.restore({"params": p2, "opt": s2}, step=5)
    p3, s3 = restored["params"], restored["opt"]
    for b in batches[5:]:
        p3, s3, _ = step(p3, s3, {k: jnp.asarray(v) for k, v in b.items()})
    for a, b in zip(jax.tree.leaves(p1), jax.tree.leaves(p3)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), atol=1e-5)


def test_hlo_analysis_exact_on_nested_scans():
    from jax import lax
    from repro.distrib import hlo_analysis as ha
    A = jax.ShapeDtypeStruct((256, 256), jnp.float32)

    def nested(x):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ x, None
            c2, _ = lax.scan(inner, c, None, length=5)
            return c2, None
        c, _ = lax.scan(outer, jnp.eye(256), None, length=3)
        return c

    txt = jax.jit(nested).lower(A).compile().as_text()
    r = ha.analyze(txt)
    assert r["flops"] == 15 * 2 * 256**3
    assert r["collective_total"] == 0


def test_lr_schedule_shape():
    lrs = [float(opt_lib.cosine_lr(jnp.asarray(s), peak=1.0, warmup=10,
                                   total=100)) for s in range(0, 100, 5)]
    assert lrs[0] < 0.6 and max(lrs) <= 1.0 + 1e-6
    assert lrs[-1] < 0.2, "decays"
    assert abs(lrs[2] - 1.0) < 0.01, "peak after warmup"
