"""Fallback for the ``hypothesis`` property-testing library.

The CI image doesn't always ship hypothesis (and the repo must not add
dependencies), so the property tests import ``given``/``settings``/``st``
from here.  When hypothesis is available it is used unchanged; otherwise a
minimal deterministic sampler runs each property on a fixed number of
pseudo-random examples drawn from the declared ranges — weaker than real
shrinking/search, but it keeps the invariants exercised.
"""
from __future__ import annotations

try:  # pragma: no cover - exercised when hypothesis is installed
    from hypothesis import given, settings, strategies as st  # noqa: F401
    HAVE_HYPOTHESIS = True
except ImportError:
    import random

    HAVE_HYPOTHESIS = False

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def sample(self, rng: random.Random):
            return self._sample(rng)

    class _St:
        @staticmethod
        def integers(min_value: int, max_value: int) -> _Strategy:
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def floats(min_value: float, max_value: float) -> _Strategy:
            return _Strategy(lambda rng: rng.uniform(min_value, max_value))

    st = _St()

    _MAX_EXAMPLES = 20

    def given(*strategies: _Strategy):
        def deco(fn):
            def wrapper():
                # zero-arg on purpose: pytest must not see the property's
                # parameters (it would try to resolve them as fixtures)
                rng = random.Random(0)
                n = min(getattr(wrapper, "_max_examples", _MAX_EXAMPLES),
                        _MAX_EXAMPLES)
                for _ in range(n):
                    ex = tuple(s.sample(rng) for s in strategies)
                    fn(*ex)
            wrapper.__name__ = fn.__name__
            wrapper.__module__ = fn.__module__
            wrapper.__doc__ = fn.__doc__
            wrapper.__dict__.update(fn.__dict__)
            return wrapper
        return deco

    def settings(max_examples: int | None = None, **_ignored):
        def deco(fn):
            if max_examples is not None:
                fn._max_examples = max_examples
            return fn
        return deco
