"""Fault tolerance (PR 9): retry & speculation, checksummed spills with
lineage recovery, deterministic fault injection, serve admission control.

The load-bearing contract is *bitwise determinism under faults*: any
FaultPlan whose per-task failure count stays within the retry budget must
yield the exact same graph (degrees, dense form) and the same labels as
the fault-free build — recovery is invisible to the numerics.
"""
from __future__ import annotations

import os
import pickle
import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from _hypothesis_compat import given, settings, st

from repro import engine, obs
from repro.cluster import SpectralClustering, ari
from repro.cluster.serving import DeadlineExceededError, QueueFullError
from repro.data import synthetic
from repro.data.chunked import ArrayChunks
from repro.engine.faults import FaultPlan, InjectedFault, task_key
from repro.engine.plan import JobPlan, producer_of
from repro.engine.store import (ShardCorruptionError, ShardLostError,
                                ShardStore, load_entry, save_entry)
from repro.launch.cluster_serve import ClusterServer, PredictRequest, summarize


# ---------------------------------------------------------------------------
# spill format v2: atomic writes, verification, legacy compat
# ---------------------------------------------------------------------------

def _arrays(seed=0):
    rng = np.random.RandomState(seed)
    return {"x": rng.randn(37, 3).astype(np.float32),
            "idx": np.arange(11, dtype=np.int64)}


def test_save_entry_roundtrip_and_no_tmp_litter(tmp_path):
    path = str(tmp_path / "e.bin")
    arrays = _arrays()
    save_entry(path, arrays)
    got = load_entry(path)
    for name, a in arrays.items():
        np.testing.assert_array_equal(got[name], a)
    # the atomic-write protocol must not leave tmp files behind
    assert [f for f in os.listdir(tmp_path) if ".tmp." in f] == []


@pytest.mark.parametrize("mode", ["truncate", "bitflip"])
def test_corruption_is_detected(tmp_path, mode):
    path = str(tmp_path / "e.bin")
    save_entry(path, _arrays())
    size = os.path.getsize(path)
    if mode == "truncate":
        os.truncate(path, size // 2)
    else:
        with open(path, "r+b") as f:
            f.seek(size - 1)
            b = f.read(1)
            f.seek(size - 1)
            f.write(bytes([b[0] ^ 0xFF]))
    with pytest.raises(ShardCorruptionError) as ei:
        load_entry(path)
    assert path in str(ei.value)


def test_header_corruption_is_detected(tmp_path):
    # the v2 CRC covers the pickled header too: a flipped byte inside a
    # shape/dtype literal must not deserialize into a wrongly-shaped
    # array — it has to fail verification like any payload flip
    path = str(tmp_path / "e.bin")
    save_entry(path, _arrays())
    with open(path, "r+b") as f:
        f.seek(30)                          # inside the pickled header
        b = f.read(1)
        f.seek(30)
        f.write(bytes([b[0] ^ 0x01]))
    with pytest.raises(ShardCorruptionError):
        load_entry(path)


def test_bitflip_on_empty_payload_entry_still_detected(tmp_path):
    # an entry whose arrays are all empty has payload length 0, so the
    # fault injector's last-byte flip lands on a header byte — which the
    # header-covering checksum must still catch
    path = str(tmp_path / "empty.bin")
    save_entry(path, {"x": np.empty((0,), np.float32)})
    faults = FaultPlan().corrupt("blk/empty", "bitflip")
    faults.on_spill("blk/empty", path)
    assert faults.fired["corrupt"] == 1
    with pytest.raises(ShardCorruptionError):
        load_entry(path)


def test_legacy_v1_spill_files_still_load(tmp_path):
    # v1 layout: 8-byte little-endian header length, pickled
    # [(name, dtype, shape)], then raw buffers — no magic, no checksum
    arrays = _arrays(seed=3)
    hdr = pickle.dumps([(k, a.dtype.str, a.shape) for k, a in arrays.items()],
                      protocol=4)
    path = str(tmp_path / "v1.bin")
    with open(path, "wb") as f:
        f.write(len(hdr).to_bytes(8, "little"))
        f.write(hdr)
        for a in arrays.values():
            f.write(memoryview(np.ascontiguousarray(a)).cast("B"))
    got = load_entry(path)
    for name, a in arrays.items():
        np.testing.assert_array_equal(got[name], a)


def test_missing_spill_file_raises_typed_lost_error(tmp_path):
    store = ShardStore(memory_budget=2000, spill_dir=str(tmp_path),
                       async_spill=False)
    for i in range(6):
        store.put(f"blk/{i}", {"x": np.full(256, i, np.float32)})
    spilled = store.spilled_keys()
    assert spilled
    victim = spilled[0]
    path = os.path.join(str(tmp_path), victim.replace("/", "__") + ".bin")
    os.remove(path)
    with pytest.raises(ShardLostError) as ei:
        store.get(victim)
    assert ei.value.key == victim
    assert victim.replace("/", "__") in str(ei.value)   # names the path


def test_store_recovery_hook_remakes_corrupt_entries(tmp_path):
    store = ShardStore(memory_budget=2000, spill_dir=str(tmp_path),
                       async_spill=False)
    originals = {f"blk/{i}": {"x": np.full(256, i, np.float32)}
                 for i in range(6)}
    for key, arrays in originals.items():
        store.put(key, arrays)
    victim = store.spilled_keys()[0]
    path = os.path.join(str(tmp_path), victim.replace("/", "__") + ".bin")
    os.truncate(path, os.path.getsize(path) // 2)

    def recover(key, err):
        assert key == victim
        assert isinstance(err, ShardCorruptionError)
        store.put(key, originals[key])
        return True

    store.recovery = recover
    np.testing.assert_array_equal(store.get(victim)["x"],
                                  originals[victim]["x"])
    assert store.stats["recoveries"] == 1


# ---------------------------------------------------------------------------
# lineage: every store key names its producing task
# ---------------------------------------------------------------------------

def test_producer_of_maps_every_key_family():
    assert producer_of("cand/2/1-3") == ("map", (1, 3))
    assert producer_of("topt/4") == ("shuffle", 4)
    assert producer_of("mirror/2/0") == ("shuffle", 0)
    assert producer_of("shard/1") == ("reduce", 1)
    with pytest.raises(KeyError):
        producer_of("nonsense/0")


# ---------------------------------------------------------------------------
# engine under injected faults: bitwise-identical recovery
# ---------------------------------------------------------------------------

_N, _CHUNK, _T = 96, 24, 5


def _points():
    pts, _ = synthetic.blobs(_N, 3, dim=3, spread=0.8, seed=7)
    return np.asarray(pts, np.float32)


def _build(tmp_dir, faults=None, memory_budget=8 * 1024, **kw):
    plan = JobPlan(n=_N, chunk_size=_CHUNK, t=_T, k=3, sigma=1.0,
                   memory_budget=memory_budget, spill_dir=str(tmp_dir),
                   workers=2, faults=faults, **kw)
    graph, _ = engine.build_graph(ArrayChunks(_points(), _CHUNK), plan)
    return graph


@pytest.fixture(scope="module")
def baseline(tmp_path_factory):
    g = _build(tmp_path_factory.mktemp("baseline"))
    return np.asarray(g.deg).copy(), g.to_dense()


def test_task_failures_within_budget_are_bitwise_invisible(tmp_path, baseline):
    deg0, dense0 = baseline
    faults = (FaultPlan()
              .fail_n("map", (0, 1), 2)
              .fail("shuffle", 1)
              .fail("reduce", 2))
    g = _build(tmp_path, faults=faults, max_retries=2, retry_backoff_s=0.01)
    stats = g.stats_snapshot()
    assert stats["task_failures"] == 4
    assert stats["retries"] == 4
    np.testing.assert_array_equal(np.asarray(g.deg), deg0)
    np.testing.assert_array_equal(g.to_dense(), dense0)


def test_midfold_failure_retry_heals_consumed_inputs(tmp_path, baseline):
    """The reviewer's scenario: a consume-mode shuffle/reduce that fails
    MID-fold has already deleted part of its input set.  The retry must
    re-materialize the consumed blocks from lineage and rebuild the exact
    graph — not silently fold the not-yet-consumed remainder."""
    deg0, dense0 = baseline
    faults = (FaultPlan()
              .fail_midfold("shuffle", 1, after_inputs=2)
              .fail_midfold("reduce", 2, after_inputs=1))
    g = _build(tmp_path, faults=faults, max_retries=2, retry_backoff_s=0.01)
    stats = g.stats_snapshot()
    assert faults.fired["midfold"] == 2
    assert stats["task_failures"] == 2
    assert stats["retries"] == 2
    # shuffle 1 consumed 2 cand blocks, reduce 2 consumed topt/2
    assert stats["inputs_healed"] == 3
    np.testing.assert_array_equal(np.asarray(g.deg), deg0)
    np.testing.assert_array_equal(g.to_dense(), dense0)


def test_spill_corruption_recovers_through_lineage(tmp_path, baseline):
    deg0, dense0 = baseline
    faults = (FaultPlan()
              .corrupt("shard/0", "bitflip")
              .corrupt("shard/2", "truncate"))
    g = _build(tmp_path, faults=faults, memory_budget=2 * 1024)
    g_dense = g.to_dense()          # forces every shard through store.get
    assert faults.fired["corrupt"] >= 1
    assert g.stats_snapshot()["store_recoveries"] >= 1
    np.testing.assert_array_equal(np.asarray(g.deg), deg0)
    np.testing.assert_array_equal(g_dense, dense0)


@settings(max_examples=4, deadline=None)
@given(st.integers(0, 2), st.integers(0, 3), st.integers(1, 2))
def test_chaos_property_bitwise_equal_within_budget(stage_idx, key_idx,
                                                    n_failures):
    """Any FaultPlan whose per-task failures stay <= max_retries yields a
    bitwise-identical graph: deg, dense form, and the downstream labels
    can't tell a retried build from a clean one."""
    stage = ("map", "shuffle", "reduce")[stage_idx]
    if stage == "map":
        tiles = [(i, j) for i in range(_N // _CHUNK)
                 for j in range(i, _N // _CHUNK)]
        key = tiles[key_idx % len(tiles)]
    else:
        key = key_idx % (_N // _CHUNK)
    faults = FaultPlan().fail_n(stage, key, n_failures)
    import tempfile
    with tempfile.TemporaryDirectory() as d_f, \
            tempfile.TemporaryDirectory() as d_0:
        g0 = _build(d_0)
        g = _build(d_f, faults=faults, max_retries=2, retry_backoff_s=0.01)
        assert faults.fired["fail"] == n_failures
        np.testing.assert_array_equal(np.asarray(g.deg), np.asarray(g0.deg))
        np.testing.assert_array_equal(g.to_dense(), g0.to_dense())


def _engine_threads():
    return [t for t in threading.enumerate()
            if t.name.startswith("repro-engine")]


def test_retry_exhaustion_raises_and_leaks_no_threads(tmp_path):
    before = len(_engine_threads())
    faults = FaultPlan().fail_n("map", (0, 0), 3)
    with pytest.raises(InjectedFault):
        _build(tmp_path, faults=faults, max_retries=1, retry_backoff_s=0.01)
    assert len(_engine_threads()) == before


def test_straggler_speculation_wins_and_stays_bitwise(tmp_path, baseline):
    deg0, dense0 = baseline
    faults = FaultPlan().delay("map", (1, 2), 1.5)
    g = _build(tmp_path, faults=faults, speculation_factor=4.0)
    stats = g.stats_snapshot()
    assert stats["speculative_launched"] >= 1
    assert stats["speculative_won"] >= 1
    np.testing.assert_array_equal(np.asarray(g.deg), deg0)
    np.testing.assert_array_equal(g.to_dense(), dense0)


def test_stage_timeout_raises_typed_error(tmp_path):
    faults = FaultPlan().delay("map", (0, 0), 1.5)
    with pytest.raises(engine.EngineTimeoutError) as ei:
        _build(tmp_path, faults=faults, stage_timeout_s=0.3)
    assert ei.value.stage == "map"
    assert "0.3" in str(ei.value)


def test_stage_timeout_bounds_wall_despite_hung_task(tmp_path):
    # an attempt stuck far past the deadline must not hang the job: the
    # scheduler abandons running attempts (daemon workers) on expiry
    # instead of joining them, so the caller gets control back ~on time
    faults = FaultPlan().delay("map", (0, 0), 6.0)
    t0 = time.monotonic()
    with pytest.raises(engine.EngineTimeoutError):
        _build(tmp_path, faults=faults, stage_timeout_s=0.3)
    assert time.monotonic() - t0 < 3.0


def test_fault_plan_from_spec_round_trip():
    plan = FaultPlan.from_spec(
        '{"fail": [["map", "0-1", 0], ["reduce", "2"]],'
        ' "fail_midfold": [["shuffle", "1", 2], ["reduce", "0"]],'
        ' "delay": [["shuffle", "1", 0.5]],'
        ' "corrupt": {"shard/0": "truncate"}}')
    assert ("map", "0-1", 0) in plan._fail
    assert ("reduce", "2", 0) in plan._fail
    assert plan._midfold[("shuffle", "1")] == 2
    assert plan._midfold[("reduce", "0")] == 1
    assert plan._delay[("shuffle", "1", 0)] == 0.5
    assert plan._corrupt["shard/0"] == "truncate"
    assert FaultPlan.from_spec(None) is None
    assert FaultPlan.from_spec("") is None
    assert task_key((3, 4)) == "3-4"
    with pytest.raises(ValueError):
        FaultPlan().corrupt("shard/0", "melt")
    with pytest.raises(ValueError):
        FaultPlan().fail_midfold("map", (0, 0))     # map consumes nothing
    with pytest.raises(ValueError):
        FaultPlan().fail_midfold("shuffle", 1, after_inputs=0)


# ---------------------------------------------------------------------------
# estimator: graceful degradation + resilience knobs
# ---------------------------------------------------------------------------

def test_estimator_falls_back_to_in_memory_on_timeout():
    pts, _ = synthetic.blobs(90, 3, dim=3, spread=0.08, seed=4)
    faults = FaultPlan().delay("map", (0, 0), 2.0)
    est = SpectralClustering(3, affinity="ooc-topt", sigma=1.0,
                             sparsify_t=6, chunk_size=30, seed=0,
                             stage_timeout_s=0.3, faults=faults)
    est.fit(jnp.asarray(pts))
    assert est.info_["affinity_fallback"].startswith("ooc-topt->knn-topt")
    # degraded != different: the fallback runs the same knn-topt affinity
    # a direct fit would, so the labels agree exactly
    ref = SpectralClustering(3, affinity="knn-topt", sigma=1.0,
                             sparsify_t=6, seed=0).fit(jnp.asarray(pts))
    assert ari(np.asarray(ref.labels_), np.asarray(est.labels_)) == 1.0


def test_estimator_validates_resilience_knobs():
    with pytest.raises(ValueError):
        SpectralClustering(3, max_retries=-1)
    with pytest.raises(ValueError):
        SpectralClustering(3, speculation_factor=-0.5)
    with pytest.raises(ValueError):
        SpectralClustering(3, stage_timeout_s=0.0)


# ---------------------------------------------------------------------------
# serving: bounded admission, deadlines, typed rejections
# ---------------------------------------------------------------------------

def _served_est():
    pts, _ = synthetic.blobs(120, 3, dim=4, spread=0.08, seed=4)
    est = SpectralClustering(3, affinity="triangular", sigma=1.0,
                             lanczos_steps=32, seed=0)
    est.fit(jnp.asarray(pts))
    return est, np.asarray(pts, np.float32)


def test_server_sheds_past_admission_bound():
    est, pts = _served_est()
    srv = ClusterServer(est, batch_rows=32, max_pending_rows=64)
    queue = [PredictRequest(rid=i, points=pts[:40].copy()) for i in range(4)]
    done = srv.run(queue)
    ok = [r for r in done if r.status == "ok"]
    shed = [r for r in done if r.status == "shed"]
    assert len(ok) == 1 and len(shed) == 3      # 40 + 40 > 64 on the 2nd
    assert srv.stats["shed"] == 3
    for r in shed:
        assert r.error and "shed" in r.error and r.labels is None
    for r in ok:
        assert r.done


def test_oversized_request_admitted_when_queue_empty():
    est, pts = _served_est()
    srv = ClusterServer(est, batch_rows=32, max_pending_rows=16)
    done = srv.run([PredictRequest(rid=0, points=pts[:100].copy())])
    assert done[0].status == "ok" and done[0].done


def test_deadline_expires_stalled_requests():
    est, pts = _served_est()
    srv = ClusterServer(est, batch_rows=16, default_deadline_s=10.0)
    fast = PredictRequest(rid=0, points=pts[:16].copy())
    slow = PredictRequest(rid=1, points=pts[16:32].copy(), deadline_s=0.01)
    real_predict = srv._predict

    def slow_predict(xb):
        import time
        time.sleep(0.05)                        # one batch outlives slow's
        return real_predict(xb)                 # per-request deadline

    srv._predict = slow_predict
    done = srv.run([fast, slow])
    assert done[0].status == "ok"
    assert done[1].status == "expired"
    assert "expired" in done[1].error
    assert srv.stats["expired"] == 1


def test_typed_rejections_and_summary_counts():
    err_q = QueueFullError(3, 40, 60, 64)
    assert err_q.status == "shed" and isinstance(err_q, RuntimeError)
    err_d = DeadlineExceededError(7, 0.5, 0.9)
    assert err_d.status == "expired" and isinstance(err_d, RuntimeError)

    reqs = []
    for rid, status in enumerate(["ok", "shed", "expired", "ok"]):
        r = PredictRequest(rid=rid, points=np.zeros((2, 4), np.float32),
                           t_submit=1.0, t_done=2.0, status=status)
        if status == "ok":
            r.labels = np.zeros(2, np.int32)
            r._filled = 2
        reqs.append(r)
    s = summarize(reqs, wall_s=1.0)
    assert s["completed"] == 2 and s["shed"] == 1 and s["expired"] == 1
