"""Data pipeline (paper §5.1 format) and sharding-rule unit tests."""
import jax
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.data import graph_file, synthetic
from repro.models import params as pp
from repro.models.params import Spec


def test_topology_roundtrip(tmp_path):
    edges, _ = synthetic.synthetic_graph(n=50, n_edges=120, k=3, seed=1)
    path = str(tmp_path / "topo.txt")
    graph_file.write_topology(path, 50, edges)
    n, back = graph_file.parse_topology(path)
    assert n == 50
    np.testing.assert_array_equal(np.sort(back[:, :2], axis=0),
                                  np.sort(edges[:, :2], axis=0))


def test_topology_vertex_labels_roundtrip(tmp_path):
    edges, labels = synthetic.synthetic_graph(n=50, n_edges=120, k=3, seed=1)
    path = str(tmp_path / "topo.txt")
    graph_file.write_topology(path, 50, edges, vertex_labels=labels)
    n, back, labels_back = graph_file.parse_topology(path, with_labels=True)
    assert n == 50
    np.testing.assert_array_equal(labels_back, labels)
    np.testing.assert_array_equal(back, edges)


def test_topology_streaming_batches_and_weightless_edges(tmp_path):
    path = str(tmp_path / "topo.txt")
    with open(path, "w") as f:
        f.write("t # 0\n")
        for i in range(7):
            f.write(f"v {i} {i % 2}\n")
        f.write("e 0 1\n")            # weight omitted -> 1
        f.write("e 1 2 5\n")
        f.write("e 5 6 2\n")
    n, edges, labels = graph_file.parse_topology(path, with_labels=True)
    assert n == 7
    np.testing.assert_array_equal(edges, [[0, 1, 1], [1, 2, 5], [5, 6, 2]])
    np.testing.assert_array_equal(labels, np.arange(7) % 2)
    batches = list(graph_file.iter_topology_edges(path))
    np.testing.assert_array_equal(np.concatenate(batches), edges)


def test_topology_parser_tag_matching(tmp_path):
    # tags match the whole first token: leading whitespace is tolerated,
    # unknown tags starting with v/e are NOT misparsed as vertices/edges
    path = str(tmp_path / "topo.txt")
    with open(path, "w") as f:
        f.write(" v 0 1\n")          # leading space, still a vertex
        f.write("edge 7 8 9\n")      # unknown tag, ignored
        f.write("vertex 9 9\n")      # unknown tag, ignored
        f.write("e 0 1 3\n")
    n, edges, labels = graph_file.parse_topology(path, with_labels=True)
    assert n == 2
    np.testing.assert_array_equal(edges, [[0, 1, 3]])
    np.testing.assert_array_equal(labels, [1, 0])


def test_adjacency_symmetric():
    edges, _ = synthetic.synthetic_graph(n=40, n_edges=100, k=2, seed=2)
    A = graph_file.adjacency_dense(40, edges)
    assert np.allclose(A, A.T)
    assert (np.diag(A) == 1).all()


def test_lm_batches_learnable_structure():
    it = synthetic.lm_batches(4, 16, 97, seed=0)
    b = next(it)
    assert b["tokens"].shape == (4, 16)
    assert b["tokens"].dtype == np.int32
    assert (b["tokens"] >= 0).all() and (b["tokens"] < 97).all()


class _FakeMesh:
    def __init__(self, shape):
        self.shape = shape
        self.axis_names = tuple(shape)


def test_partition_spec_divisibility_and_dedupe():
    mesh = _FakeMesh({"data": 16, "model": 16})
    rules = {"experts": "model", "mlp": "model", "heads": "model"}
    # dedupe: experts wins, mlp falls back to None
    s = Spec((384, 512, 1024), ("experts", "embed", "mlp"))
    assert pp.partition_spec(s, rules, mesh) == P("model", None, None)
    # divisibility: 14 heads don't divide 16
    s2 = Spec((14, 64), ("heads", "head_dim"))
    assert pp.partition_spec(s2, rules, mesh) == P(None, None)
    s3 = Spec((32, 64), ("heads", "head_dim"))
    assert pp.partition_spec(s3, rules, mesh) == P("model", None)


@pytest.mark.parametrize("arch", configs.ARCHS)
def test_input_specs_cover_all_supported_cells(arch):
    from repro.configs import specs as cfg_specs
    from repro.models.config import SHAPES_BY_NAME
    cfg = configs.get(arch)
    for shape, cell in SHAPES_BY_NAME.items():
        if not configs.cell_supported(arch, shape):
            continue
        spec = cfg_specs.input_specs(cfg, cell)
        if cell.kind in ("train", "prefill"):
            assert spec["tokens"].shape == (cell.global_batch, cell.seq_len)
            if cfg.frontend == "embed":
                assert spec["embeds"].shape == (
                    cell.global_batch, cell.seq_len, cfg.d_model)
        else:
            assert spec["token"].shape == (cell.global_batch, 1)


def test_long_context_skips_match_design():
    assert not configs.cell_supported("glm4-9b", "long_500k")
    assert not configs.cell_supported("seamless-m4t-medium", "long_500k")
    assert configs.cell_supported("xlstm-1.3b", "long_500k")
    assert configs.cell_supported("zamba2-2.7b", "long_500k")
    assert configs.cell_supported("gemma3-1b", "long_500k")
    assert configs.cell_supported("mixtral-8x7b", "long_500k")
    for a in configs.ARCHS:
        assert configs.cell_supported(a, "train_4k")
