"""PR 8 async engine: thread-safe store + async spill writer, the
dependency-driven build scheduler, prefetch window/thread hygiene, the
single-pass scatter, and the strided sigma sample.

The load-bearing invariants:

* concurrency never changes results — labels/embeddings are
  bitwise-identical at every ``workers`` width;
* the store never loses an entry or miscounts a byte under concurrent
  put/get/delete, and async spilling is invisible except in the stats;
* no fit strands a background thread.
"""
from __future__ import annotations

import gc
import os
import threading

import numpy as np
import pytest

from repro import engine
from repro.cluster import SpectralClustering, ari
from repro.data import synthetic
from repro.data.chunked import ArrayChunks
from repro.engine.operator import (_bincount_loop_rows, _csr_segment_matmat,
                                   scatter_rows)
from repro.engine.plan import JobPlan
from repro.engine.runner import _resolve_sigma
from repro.engine.store import ShardStore


def _repro_threads(prefix: str = "repro-") -> list[str]:
    return [t.name for t in threading.enumerate()
            if t.name.startswith(prefix)]


# ---------------------------------------------------------------------------
# store: async spill semantics
# ---------------------------------------------------------------------------

def test_async_spill_roundtrip_and_flush(tmp_path):
    store = ShardStore(memory_budget=900, spill_dir=str(tmp_path))
    blocks = {f"k{i}": {"x": np.full(200, i, np.float32)} for i in range(4)}
    for k, v in blocks.items():
        store.put(k, v)                    # evictions queue async writes
    store.flush()
    # after the quiescence point every spilled entry's spill file exists and the
    # spilling state is fully drained
    assert store._spilling == {} and store._spilling_bytes == 0
    for k in store.spilled_keys():
        assert os.path.exists(os.path.join(
            str(tmp_path), k.replace("/", "__") + ".bin"))
    for k, v in blocks.items():            # any order, data intact
        np.testing.assert_array_equal(store.get(k)["x"], v["x"])
    store.close()


def test_get_joins_in_flight_spill(tmp_path):
    # a get() during the spill window must return the still-held arrays
    # without a disk round-trip, and the entry is resident again
    store = ShardStore(memory_budget=800, spill_dir=str(tmp_path))
    a = {"x": np.arange(200, dtype=np.float32)}
    store.put("a", a)
    store.put("b", {"x": np.zeros(200, np.float32)})   # evicts a (async)
    got = store.get("a")                   # joins or loads, timing decides
    np.testing.assert_array_equal(got["x"], a["x"])
    assert store.stats["spill_joins"] + store.stats["loads"] == 1
    assert "a" in store._ram
    store.flush()
    # the joined entry's write still landed: evicting it again is a drop
    assert "a" in store._disk
    store.close()


def test_delete_during_in_flight_spill_leaves_no_orphan(tmp_path):
    store = ShardStore(memory_budget=800, spill_dir=str(tmp_path))
    for i in range(8):
        store.put(f"k{i}", {"x": np.full(200, i, np.float32)})
        store.delete(f"k{i}")              # race the background writer
    store.flush()
    assert list(store.keys()) == []
    # stale writers detected their seq was forgotten and removed the file
    assert [f for f in os.listdir(str(tmp_path)) if f.endswith(".bin")] == []
    store.close()


def test_store_concurrency_torture(tmp_path):
    # satellite (d): 8 threads hammer one store under a tight shared
    # budget; nothing may be lost and the byte accounting must be exact
    budget = 4000
    store = ShardStore(memory_budget=budget, spill_dir=str(tmp_path))
    n_threads, n_keys = 8, 12
    errors: list[BaseException] = []

    def worker(tid: int):
        try:
            rng = np.random.RandomState(tid)
            for i in range(n_keys):
                store.put(f"t{tid}/k{i}",
                          {"x": np.full(100 + 8 * i, tid * 100 + i,
                                        np.float32)})
                j = rng.randint(0, i + 1)
                got = store.get(f"t{tid}/k{j}")     # reload or join
                assert got["x"][0] == tid * 100 + j
            for i in range(0, n_keys, 3):           # delete every third
                store.delete(f"t{tid}/k{i}")
        except BaseException as e:                  # pragma: no cover
            errors.append(e)

    threads = [threading.Thread(target=worker, args=(tid,))
               for tid in range(n_threads)]
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert errors == []
    store.flush()
    # no lost entries: every non-deleted key present with correct data
    for tid in range(n_threads):
        for i in range(n_keys):
            key = f"t{tid}/k{i}"
            if i % 3 == 0:
                assert key not in store
            else:
                np.testing.assert_array_equal(
                    store.get(key)["x"],
                    np.full(100 + 8 * i, tid * 100 + i, np.float32))
    store.flush()
    # exact accounting at quiescence: ram_bytes is the sum of resident
    # entries and the budget is respected
    with store._lock:
        resident = sum(sum(a.nbytes for a in e.values())
                       for e in store._ram.values())
    assert store.ram_bytes == resident
    assert store.ram_bytes <= budget
    assert store._spilling == {} and store._spilling_bytes == 0
    store.close()
    assert _repro_threads("repro-store") == []


# ---------------------------------------------------------------------------
# scatter implementations (satellite b)
# ---------------------------------------------------------------------------

@pytest.mark.parametrize("sorted_rows", [True, False])
def test_scatter_rows_matches_bincount_loop(sorted_rows):
    rng = np.random.RandomState(0)
    nrows, nnz, b = 37, 500, 6
    rows = rng.randint(0, nrows, nnz)
    if sorted_rows:
        rows = np.sort(rows)
    prods = rng.randn(nnz, b).astype(np.float32)
    Y = np.zeros((nrows, b), np.float32)
    scatter_rows(Y, rows, prods)
    # the loop accumulates in float64 (np.bincount), the single-pass
    # scatter in float32 — identical up to f32 rounding
    np.testing.assert_allclose(Y, _bincount_loop_rows(rows, prods, nrows),
                               rtol=1e-4, atol=1e-5)


def test_scatter_rows_empty_is_noop():
    Y = np.ones((3, 2), np.float32)
    scatter_rows(Y, np.empty(0, np.int64), np.empty((0, 2), np.float32))
    np.testing.assert_array_equal(Y, np.ones((3, 2), np.float32))


def test_device_segment_matmat_matches_loop():
    rng = np.random.RandomState(1)
    nrows, nnz, b = 19, 230, 4
    rows = np.sort(rng.randint(0, nrows, nnz))
    data = rng.rand(nnz).astype(np.float32)
    indices = rng.randint(0, 50, nnz)
    V = rng.randn(50, b).astype(np.float32)
    out = np.asarray(_csr_segment_matmat(data, indices, rows, V, nrows))
    ref = _bincount_loop_rows(rows, data[:, None] * V[indices], nrows)
    np.testing.assert_allclose(out, ref, rtol=1e-4, atol=1e-5)
    # zero padding (the pow2 nnz buckets) is inert
    pad = 64
    out_p = np.asarray(_csr_segment_matmat(
        np.pad(data, (0, pad)), np.pad(indices, (0, pad)),
        np.pad(rows, (0, pad)), V, nrows))
    np.testing.assert_allclose(out_p, out, rtol=1e-6)


def test_matmat_impls_agree_on_real_graph(tmp_path):
    pts = np.asarray(synthetic.blobs(160, 3, seed=3)[0])
    plan = JobPlan(n=160, chunk_size=48, t=10, k=3, sigma=1.0,
                   memory_budget=60_000, spill_dir=str(tmp_path))
    graph, _ = engine.build_graph(ArrayChunks(pts, 48), plan)
    V = np.random.RandomState(0).randn(160, 5).astype(np.float32)
    outs = {}
    for impl in ("host", "loop", "device"):
        graph.matmat_impl = impl
        outs[impl] = graph.matmat(V)
    graph.close()
    np.testing.assert_allclose(outs["host"], outs["loop"],
                               rtol=1e-4, atol=1e-5)
    np.testing.assert_allclose(outs["device"], outs["loop"],
                               rtol=1e-4, atol=1e-5)


# ---------------------------------------------------------------------------
# sigma sampling (satellite c)
# ---------------------------------------------------------------------------

def test_resolve_sigma_unbiased_by_chunk_order():
    # class-sorted data used to put only ONE blob in the leading-chunk
    # sample, estimating an intra-cluster bandwidth; the strided sample
    # must agree with the shuffled estimate to within 10%
    pts, labels = synthetic.blobs(1200, 3, seed=0)
    pts, labels = np.asarray(pts), np.asarray(labels)
    ordered = pts[np.argsort(labels, kind="stable")]
    shuffled = pts[np.random.RandomState(0).permutation(len(pts))]
    plan = JobPlan(n=len(pts), chunk_size=100, t=10, k=3)
    s_sorted = _resolve_sigma(ArrayChunks(ordered, 100), plan)
    s_shuffled = _resolve_sigma(ArrayChunks(shuffled, 100), plan)
    assert s_sorted == pytest.approx(s_shuffled, rel=0.10)


# ---------------------------------------------------------------------------
# scheduler: bitwise-identical at any width, plan validation
# ---------------------------------------------------------------------------

def _run(workers, prefetch_depth, async_spill, spill_dir):
    pts = np.asarray(synthetic.blobs(400, 3, seed=0)[0])
    plan = JobPlan(n=400, chunk_size=64, t=12, k=3, memory_budget=150_000,
                   spill_dir=spill_dir, seed=0, workers=workers,
                   prefetch_depth=prefetch_depth, async_spill=async_spill)
    return engine.run_job(plan, ArrayChunks(pts, 64))


def test_run_job_bitwise_identical_across_workers(tmp_path):
    seq = _run(1, 1, False, str(tmp_path / "seq"))
    par = _run(4, 4, True, str(tmp_path / "par"))
    np.testing.assert_array_equal(seq.labels, par.labels)
    np.testing.assert_array_equal(np.asarray(seq.embedding),
                                  np.asarray(par.embedding))
    np.testing.assert_array_equal(np.asarray(seq.eigenvalues),
                                  np.asarray(par.eigenvalues))
    # the parallel run reports the overlap instrumentation
    for key in ("build_wall_s", "overlap_s", "workers"):
        assert key in par.stats
    assert par.stats["workers"] == 4


def test_jobplan_validates_async_knobs():
    with pytest.raises(ValueError, match="workers"):
        JobPlan(n=10, workers=0)
    with pytest.raises(ValueError, match="prefetch_depth"):
        JobPlan(n=10, prefetch_depth=0)
    with pytest.raises(ValueError, match="workers"):
        SpectralClustering(k=2, workers=0)
    with pytest.raises(ValueError, match="prefetch_depth"):
        SpectralClustering(k=2, prefetch_depth=0)


# ---------------------------------------------------------------------------
# thread hygiene (satellite a)
# ---------------------------------------------------------------------------

def test_fit_leaves_no_background_threads(tmp_path):
    # regression: the shard-prefetch pool used to outlive the fit (one
    # stranded "repro-shard-prefetch" thread per fitted estimator)
    pts, truth = synthetic.blobs(300, 3, dim=4, spread=0.8, seed=1)
    est = SpectralClustering(
        k=3, affinity="ooc-topt", eigensolver="block-lanczos",
        assigner="streaming", sparsify_t=10, sigma=1.0, lanczos_steps=96,
        chunk_size=64, memory_budget=100_000,
        spill_dir=str(tmp_path), workers=3, prefetch_depth=3, seed=0)
    est.fit(pts)
    assert ari(np.asarray(truth), np.asarray(est.labels_)) >= 0.95
    gc.collect()
    assert _repro_threads("repro-shard-prefetch") == []
    assert _repro_threads("repro-store-spill") == []
    assert _repro_threads("repro-engine-task") == []
    eng = est.info_["engine"]
    assert eng["prefetch_hits"] + eng["prefetch_misses"] > 0
    assert eng["store_spills"] > 0          # the budget actually bit


def test_graph_close_is_idempotent_and_nonfinal(tmp_path):
    pts = np.asarray(synthetic.blobs(120, 2, seed=2)[0])
    plan = JobPlan(n=120, chunk_size=40, t=8, k=2, sigma=1.0,
                   spill_dir=str(tmp_path), prefetch_depth=2)
    graph, _ = engine.build_graph(ArrayChunks(pts, 40), plan)
    V = np.ones((120, 3), np.float32)
    y1 = graph.matmat(V)
    graph.close()
    graph.close()                           # idempotent
    assert _repro_threads("repro-shard-prefetch") == []
    y2 = graph.matmat(V)                    # non-final: pool restarts
    np.testing.assert_array_equal(y1, y2)
    graph.close()
    assert _repro_threads("repro-shard-prefetch") == []
