"""Tests for the static-analysis engine (repro.analysis): one positive
(flags) and one negative (silent) fixture per rule, the baseline
add/expire round-trip, the JSON report schema, inline suppression, and
the runtime lockcheck's cycle detector (exercised in subprocesses so its
global threading patch never leaks into this session)."""
import json
import os
import subprocess
import sys
import textwrap

import pytest

from repro import analysis
from repro.analysis.__main__ import main as cli_main
from repro.analysis.baseline import load_baseline, save_baseline
from repro.analysis.rules import RULES

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


def run_check(tmp_path, files, only=None, baseline_path=None):
    """Write the fixture tree under tmp_path and run the one-call API."""
    for rel, src in files.items():
        p = tmp_path / rel
        p.parent.mkdir(parents=True, exist_ok=True)
        p.write_text(textwrap.dedent(src))
    return analysis.check(sorted(files), root=str(tmp_path), only=only,
                          baseline_path=baseline_path)


def rules_of(report):
    return sorted({f.rule for f in report.findings})


# -- registry ----------------------------------------------------------------

def test_registry_has_every_documented_rule():
    assert {"S000", "C001", "C002", "C003",
            "J001", "J002", "J003",
            "K001", "K002", "K003"} <= set(RULES)
    for info in RULES.values():
        assert info.severity in ("error", "warning")
        assert info.summary


def test_syntax_error_is_a_finding_not_a_crash(tmp_path):
    rep = run_check(tmp_path, {"bad.py": "def oops(:\n    pass\n"},
                    only=["S000"])
    assert rules_of(rep) == ["S000"]
    assert rep.new[0].path == "bad.py"


# -- C001: mixed lock discipline ---------------------------------------------

C001_POS = """\
    import threading

    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def reset(self):
            self.n = 0
"""

C001_NEG = """\
    import threading

    class Counter:
        def __init__(self):
            self._lock = threading.Lock()
            self.n = 0

        def bump(self):
            with self._lock:
                self.n += 1

        def reset(self):
            with self._lock:
                self.n = 0

        def _zero_locked(self):
            self.n = 0
"""


def test_c001_flags_unguarded_mutation(tmp_path):
    rep = run_check(tmp_path, {"m.py": C001_POS}, only=["C001"])
    assert len(rep.new) == 1
    f = rep.new[0]
    assert f.rule == "C001" and "reset" in f.message and "'self.n'" in f.message


def test_c001_silent_when_guarded_or_held_by_convention(tmp_path):
    rep = run_check(tmp_path, {"m.py": C001_NEG}, only=["C001"])
    assert rep.new == []


def test_c001_subscript_mutation_counts(tmp_path):
    src = C001_POS.replace("self.n = 0", 'self.n = {"k": 0}') \
                  .replace("self.n += 1", 'self.n["k"] += 1')
    rep = run_check(tmp_path, {"m.py": src}, only=["C001"])
    assert len(rep.new) == 1


# -- C002: lock-order cycle + non-reentrant self-nesting ----------------------

C002_CYCLE = """\
    def forward(a, b):
        with a.mu:
            with b.mu:
                pass

    def backward(a, b):
        with b.mu:
            with a.mu:
                pass
"""

C002_ORDERED = """\
    def forward(a, b):
        with a.mu:
            with b.mu:
                pass

    def also_forward(a, b):
        with a.mu:
            with b.mu:
                pass
"""

C002_SELF_NEST = """\
    import threading

    class S:
        def __init__(self):
            self._lock = threading.Lock()

        def outer(self):
            with self._lock:
                with self._lock:
                    pass
"""


def test_c002_flags_lock_order_cycle(tmp_path):
    rep = run_check(tmp_path, {"m.py": C002_CYCLE}, only=["C002"])
    assert len(rep.new) == 1
    assert "cycle" in rep.new[0].message


def test_c002_silent_on_consistent_order(tmp_path):
    rep = run_check(tmp_path, {"m.py": C002_ORDERED}, only=["C002"])
    assert rep.new == []


def test_c002_flags_nonreentrant_self_nesting(tmp_path):
    rep = run_check(tmp_path, {"m.py": C002_SELF_NEST}, only=["C002"])
    assert len(rep.new) == 1
    assert "already held" in rep.new[0].message


def test_c002_rlock_self_nesting_is_fine(tmp_path):
    rep = run_check(tmp_path,
                    {"m.py": C002_SELF_NEST.replace("Lock()", "RLock()")},
                    only=["C002"])
    assert rep.new == []


# -- C003: dropped concurrency results ----------------------------------------

C003_POS = """\
    import threading

    def fire(pool):
        pool.submit(work)

    def spawn():
        t = threading.Thread(target=work)
        t.start()

    def work():
        pass
"""

C003_NEG = """\
    import threading

    def fire(pool):
        fut = pool.submit(work)
        return fut.result()

    def spawn():
        t = threading.Thread(target=work, daemon=True)
        t.start()

    def work():
        pass
"""


def test_c003_flags_dropped_future_and_unjoined_thread(tmp_path):
    rep = run_check(tmp_path, {"m.py": C003_POS}, only=["C003"])
    msgs = " | ".join(f.message for f in rep.new)
    assert len(rep.new) == 2
    assert "discarded" in msgs and "never joined" in msgs


def test_c003_silent_when_consumed(tmp_path):
    rep = run_check(tmp_path, {"m.py": C003_NEG}, only=["C003"])
    assert rep.new == []


# -- J001: impure calls reachable from traced code ----------------------------

J001_POS = """\
    import time
    import jax

    def _stamp():
        return time.time()

    @jax.jit
    def f(x):
        return x * _stamp()
"""

J001_NEG = """\
    import time
    import jax

    @jax.jit
    def f(x, t):
        return x * t

    def stamp_outside():
        return time.time()
"""


def test_j001_flags_clock_reachable_from_jit(tmp_path):
    rep = run_check(tmp_path, {"m.py": J001_POS}, only=["J001"])
    assert len(rep.new) == 1
    assert "time.time" in rep.new[0].message


def test_j001_silent_for_host_side_clock(tmp_path):
    rep = run_check(tmp_path, {"m.py": J001_NEG}, only=["J001"])
    assert rep.new == []


def test_j001_flags_unseeded_numpy_rng(tmp_path):
    src = J001_POS.replace("import time", "import numpy as np") \
                  .replace("time.time()", "np.random.rand()")
    rep = run_check(tmp_path, {"m.py": src}, only=["J001"])
    assert len(rep.new) == 1


# -- J002: host side effects in kernel bodies ---------------------------------

J002_POS = """\
    from jax.experimental import pallas as pl

    def _kernel(x_ref, o_ref):
        print("trace me")
        o_ref[...] = x_ref[...]

    def run(x):
        return pl.pallas_call(_kernel, out_shape=x)(x)
"""


def test_j002_flags_print_in_kernel(tmp_path):
    rep = run_check(tmp_path, {"m.py": J002_POS}, only=["J002"])
    assert len(rep.new) == 1
    assert "print" in rep.new[0].message


def test_j002_allows_pl_debug_print(tmp_path):
    src = J002_POS.replace('print("trace me")',
                           'pl.debug_print("x = {}", x_ref[0])')
    rep = run_check(tmp_path, {"m.py": src}, only=["J002"])
    assert rep.new == []


# -- J003: tracer concretization ----------------------------------------------

J003_POS = """\
    import jax

    @jax.jit
    def f(x):
        return float(x)
"""

J003_NEG = """\
    import jax

    @jax.jit
    def f(x, *, scale):
        return x * float(scale)
"""


def test_j003_flags_float_on_positional_param(tmp_path):
    rep = run_check(tmp_path, {"m.py": J003_POS}, only=["J003"])
    assert len(rep.new) == 1
    assert "float()" in rep.new[0].message


def test_j003_keyword_only_params_are_static(tmp_path):
    rep = run_check(tmp_path, {"m.py": J003_NEG}, only=["J003"])
    assert rep.new == []


def test_j003_flags_item_in_reachable_helper(tmp_path):
    src = """\
        import jax

        def _peek(x):
            return x.item()

        @jax.jit
        def f(x):
            return _peek(x)
    """
    rep = run_check(tmp_path, {"m.py": src}, only=["J003"])
    assert len(rep.new) == 1
    assert ".item()" in rep.new[0].message


# -- K001: ref.py oracle twin -------------------------------------------------

K_KERNEL = """\
    from jax.experimental import pallas as pl

    def _body(x_ref, o_ref):
        o_ref[...] = x_ref[...]

    def run(x):
        return pl.pallas_call(_body, out_shape=x)(x)
"""


def test_k001_flags_missing_ref_twin(tmp_path):
    rep = run_check(tmp_path, {
        "src/repro/kernels/foo.py": K_KERNEL,
        "src/repro/kernels/ref.py": "def other(x):\n    return x\n",
    }, only=["K001"])
    assert len(rep.new) == 1
    assert "run()" in rep.new[0].message


def test_k001_silent_with_ref_twin(tmp_path):
    rep = run_check(tmp_path, {
        "src/repro/kernels/foo.py": K_KERNEL,
        "src/repro/kernels/ref.py": "def run(x):\n    return x\n",
    }, only=["K001"])
    assert rep.new == []


# -- K002: ops.py wrappers route through _resolve -----------------------------

K002_POS = """\
    from repro.kernels import foo

    def matmul(x):
        return foo.run(x)
"""

K002_NEG = """\
    from repro.kernels import foo

    def _resolve(name, shape):
        return None

    def matmul(x):
        sched = _resolve("matmul", x.shape)
        return foo.run(x, sched)
"""


def test_k002_flags_wrapper_bypassing_resolve(tmp_path):
    rep = run_check(tmp_path, {"src/repro/kernels/ops.py": K002_POS},
                    only=["K002"])
    assert len(rep.new) == 1
    assert "_resolve" in rep.new[0].message


def test_k002_silent_when_resolving(tmp_path):
    rep = run_check(tmp_path, {"src/repro/kernels/ops.py": K002_NEG},
                    only=["K002"])
    assert rep.new == []


# -- K003: tile literals outside the schedule layer ---------------------------

K003_SRC = """\
    def run(op):
        return op(bm=128)
"""


def test_k003_flags_tile_literal_outside_kernels(tmp_path):
    rep = run_check(tmp_path, {"src/repro/engine/glue.py": K003_SRC},
                    only=["K003"])
    assert len(rep.new) == 1
    assert "bm=128" in rep.new[0].message


def test_k003_silent_inside_kernels_and_tune(tmp_path):
    rep = run_check(tmp_path, {
        "src/repro/kernels/foo.py": K003_SRC,
        "src/repro/tune/sched.py": K003_SRC,
    }, only=["K003"])
    assert rep.new == []


# -- inline suppression -------------------------------------------------------

def test_inline_suppression_mutes_named_rule(tmp_path):
    src = C001_POS.replace("self.n = 0\n",
                           "self.n = 0  # repro: ignore[C001]\n")
    rep = run_check(tmp_path, {"m.py": src}, only=["C001"])
    assert rep.new == []


def test_inline_suppression_other_rule_still_fires(tmp_path):
    src = C001_POS.replace("self.n = 0\n",
                           "self.n = 0  # repro: ignore[K003]\n")
    rep = run_check(tmp_path, {"m.py": src}, only=["C001"])
    assert len(rep.new) == 1


# -- baseline round-trip ------------------------------------------------------

def test_baseline_add_then_expire_round_trip(tmp_path):
    bl = str(tmp_path / "baseline.json")
    rep = run_check(tmp_path, {"m.py": C001_POS}, only=["C001"])
    assert len(rep.new) == 1 and rep.baselined == []

    save_baseline(bl, rep.findings)
    assert set(load_baseline(bl)) == {f.fingerprint for f in rep.findings}

    # baselined: same finding no longer gates
    rep2 = run_check(tmp_path, {"m.py": C001_POS}, only=["C001"],
                     baseline_path=bl)
    assert rep2.new == [] and len(rep2.baselined) == 1 and rep2.expired == []

    # an edit ABOVE the finding must not expire it (line-stable fingerprint)
    rep3 = run_check(tmp_path, {"m.py": "    import os  # padding\n"
                                + C001_POS},
                     only=["C001"], baseline_path=bl)
    assert rep3.new == [] and len(rep3.baselined) == 1 and rep3.expired == []

    # fixing the code expires the entry
    rep4 = run_check(tmp_path, {"m.py": C001_NEG}, only=["C001"],
                     baseline_path=bl)
    assert rep4.new == [] and rep4.baselined == [] and len(rep4.expired) == 1


def test_corrupt_baseline_version_is_an_error(tmp_path):
    bl = tmp_path / "baseline.json"
    bl.write_text(json.dumps({"version": 99, "findings": []}))
    with pytest.raises(ValueError):
        load_baseline(str(bl))


# -- CLI + JSON schema --------------------------------------------------------

def test_cli_json_report_schema_and_exit_codes(tmp_path, capsys):
    (tmp_path / "m.py").write_text(textwrap.dedent(C001_POS))
    rc = cli_main(["check", "m.py", "--root", str(tmp_path), "--json",
                   "--rules", "C001"])
    doc = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert doc["version"] == 1
    assert doc["counts"] == {"total": 1, "new": 1, "baselined": 0,
                             "expired": 0}
    f = doc["findings"][0]
    assert set(f) == {"rule", "severity", "path", "line", "message",
                      "snippet", "fingerprint"}
    assert f["rule"] == "C001" and f["path"] == "m.py" and f["line"] > 0
    assert len(f["fingerprint"]) == 16

    # clean tree exits 0
    (tmp_path / "m.py").write_text(textwrap.dedent(C001_NEG))
    rc = cli_main(["check", "m.py", "--root", str(tmp_path), "--json",
                   "--rules", "C001"])
    capsys.readouterr()
    assert rc == 0


def test_cli_update_baseline_then_gate_passes(tmp_path, capsys):
    (tmp_path / "m.py").write_text(textwrap.dedent(C001_POS))
    bl = str(tmp_path / "bl.json")
    rc = cli_main(["check", "m.py", "--root", str(tmp_path),
                   "--baseline", bl, "--update-baseline"])
    capsys.readouterr()
    assert rc == 0
    rc = cli_main(["check", "m.py", "--root", str(tmp_path),
                   "--baseline", bl])
    out = capsys.readouterr().out
    assert rc == 0
    assert "OK: 0 new finding(s), 1 baselined" in out


# -- dogfood: the repo itself gates clean against its baseline ----------------

def test_repo_is_clean_against_committed_baseline():
    rep = analysis.check(["src"], root=REPO,
                         baseline_path=os.path.join(
                             REPO, ".analysis-baseline.json"))
    assert rep.new == [], "\n".join(f.format() for f in rep.new)
    assert rep.expired == [], f"stale baseline entries: {rep.expired}"


# -- runtime lockcheck (subprocess: its patch is process-global) --------------

def _run_lockcheck_snippet(tmp_path, body: str) -> subprocess.CompletedProcess:
    # runs from a real file, not -c: lockcheck only tracks locks whose
    # allocation site is a repo-ish path, and "<string>" is foreign
    script = tmp_path / "lockcheck_snippet.py"
    script.write_text(textwrap.dedent("""\
        import threading
        from repro.analysis import lockcheck
        lockcheck.install()
    """) + textwrap.dedent(body))
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, str(script)],
                          capture_output=True, text=True, env=env,
                          timeout=120)


def test_lockcheck_detects_induced_cycle(tmp_path):
    out = _run_lockcheck_snippet(tmp_path, """\
        a = threading.Lock()
        b = threading.Lock()
        with a:
            with b:
                pass
        with b:
            with a:
                pass
        assert lockcheck.find_cycles(), "cycle not detected"
        try:
            lockcheck.assert_acyclic()
        except lockcheck.LockOrderError as e:
            print("CAUGHT:", e)
        else:
            raise SystemExit("assert_acyclic did not raise")
    """)
    assert out.returncode == 0, out.stderr
    assert "CAUGHT:" in out.stdout and "cycle" in out.stdout


def test_lockcheck_ordered_acquisition_passes(tmp_path):
    out = _run_lockcheck_snippet(tmp_path, """\
        a = threading.Lock()
        b = threading.Lock()
        for _ in range(3):
            with a:
                with b:
                    pass
        rep = lockcheck.report()
        assert rep["locks"] == 2 and rep["cycles"] == []
        assert len(rep["edges"]) == 1
        lockcheck.assert_acyclic()
        print("EDGE:", rep["edges"][0]["from"], "->", rep["edges"][0]["to"])
    """)
    assert out.returncode == 0, out.stderr
    assert "EDGE:" in out.stdout


def test_lockcheck_ignores_stdlib_allocated_locks(tmp_path):
    # ThreadPoolExecutor's internal locks (allocated from the stdlib)
    # must stay untracked: their orderings are CPython's business and
    # produce false-positive cycles if recorded.
    out = _run_lockcheck_snippet(tmp_path, """\
        from concurrent.futures import ThreadPoolExecutor
        mine = threading.Lock()
        with ThreadPoolExecutor(max_workers=2) as pool:
            for f in [pool.submit(lambda i=i: i * i) for i in range(8)]:
                f.result()
        rep = lockcheck.report()
        assert all("concurrent" not in s["from"] and "concurrent" not in
                   s["to"] for s in rep["edges"]), rep["edges"]
        lockcheck.assert_acyclic()
        print("SITES:", rep["sites"])
    """)
    assert out.returncode == 0, out.stderr
    assert "SITES:" in out.stdout


def test_lockcheck_rlock_reentry_is_not_an_edge(tmp_path):
    out = _run_lockcheck_snippet(tmp_path, """\
        r = threading.RLock()
        with r:
            with r:
                pass
        rep = lockcheck.report()
        assert rep["edges"] == [], rep["edges"]
        lockcheck.assert_acyclic()
        print("OK")
    """)
    assert out.returncode == 0, out.stderr
    assert "OK" in out.stdout
