"""GPipe pipeline parallelism (train/pipeline.py): forward and gradients
through the ppermute schedule match the plain layer scan."""
import pytest


def test_pipeline_matches_scan_4stages(subproc):
    out = subproc("""
import jax, jax.numpy as jnp
from repro.distrib import mesh_utils
from repro.train.pipeline import pipeline_apply
mesh = mesh_utils.make_mesh((4,), ("pod",))
L, D, B = 8, 16, 8
W = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
layer = lambda w, x: jnp.tanh(x @ w)
def ref(W, x):
    out, _ = jax.lax.scan(lambda x, w: (layer(w, x), None), x, W)
    return out
got = jax.jit(lambda W, x: pipeline_apply(layer, W, x, mesh, microbatches=4))(W, x)
assert float(jnp.abs(got - ref(W, x)).max()) < 1e-5
gp = jax.grad(lambda W, x: jnp.sum(pipeline_apply(layer, W, x, mesh, microbatches=4)**2))(W, x)
gr = jax.grad(lambda W, x: jnp.sum(ref(W, x)**2))(W, x)
assert float(jnp.abs(gp - gr).max()) < 1e-4
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_pipeline_2stage_with_other_axes(subproc):
    """Pipeline axis composes with a data axis in the same mesh."""
    out = subproc("""
import jax, jax.numpy as jnp
from repro.distrib import mesh_utils
from repro.train.pipeline import pipeline_apply
mesh = mesh_utils.make_mesh((2, 2), ("pod", "data"))
L, D, B = 4, 8, 4
W = jax.random.normal(jax.random.PRNGKey(0), (L, D, D)) * 0.3
x = jax.random.normal(jax.random.PRNGKey(1), (B, D))
layer = lambda w, x: jnp.tanh(x @ w)
def ref(W, x):
    out, _ = jax.lax.scan(lambda x, w: (layer(w, x), None), x, W)
    return out
got = jax.jit(lambda W, x: pipeline_apply(layer, W, x, mesh, microbatches=2))(W, x)
assert float(jnp.abs(got - ref(W, x)).max()) < 1e-5
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_bubble_fraction():
    from repro.train.pipeline import bubble_fraction
    assert bubble_fraction(2, 8) == pytest.approx(1 / 9)
    assert bubble_fraction(4, 4) == pytest.approx(3 / 7)
