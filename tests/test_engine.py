"""Out-of-core engine (repro.engine) + §5 metrics + chunked data readers.

The load-bearing test is the oracle agreement: the engine's map/shuffle/
reduce graph must reproduce the in-memory ``knn-topt`` backend — same
top-t similarity graph (up to threshold ties), same labels up to
permutation (checked with the paper's ARI/NMI metrics).
"""
from __future__ import annotations

import os

import jax.numpy as jnp
import numpy as np
import pytest

from repro import engine
from repro.cluster import SpectralClustering, ari, nmi, purity
from repro.core import similarity as sim
from repro.data import synthetic
from repro.data.chunked import ArrayChunks, BlobChunks
from repro.engine.plan import JobPlan, chunk_ranges
from repro.engine.store import ShardStore


# ---------------------------------------------------------------------------
# metrics (paper §5): closed-form cases
# ---------------------------------------------------------------------------

def test_metrics_perfect_and_permuted():
    a = np.array([0, 0, 1, 1, 2, 2])
    b = np.array([2, 2, 0, 0, 1, 1])        # same partition, renamed
    for m in (ari, nmi, purity):
        assert m(a, a) == pytest.approx(1.0)
        assert m(a, b) == pytest.approx(1.0)


def test_metrics_disagreement_is_low():
    a = np.array([0, 0, 0, 1, 1, 1])
    b = np.array([0, 1, 0, 1, 0, 1])        # orthogonal split
    assert ari(a, b) < 0.4
    assert nmi(a, b) < 0.4
    assert purity(a, b) == pytest.approx(4 / 6)


def test_metrics_match_sklearn_when_available():
    sk = pytest.importorskip("sklearn.metrics")
    rng = np.random.RandomState(0)
    for _ in range(5):
        a = rng.randint(0, 4, 60)
        b = rng.randint(0, 3, 60)
        assert ari(a, b) == pytest.approx(sk.adjusted_rand_score(a, b))
        assert nmi(a, b) == pytest.approx(
            sk.normalized_mutual_info_score(a, b))


# ---------------------------------------------------------------------------
# shard store: budget, spill, reload
# ---------------------------------------------------------------------------

def test_shard_store_spill_and_reload_roundtrip(tmp_path):
    store = ShardStore(memory_budget=3000, spill_dir=str(tmp_path))
    blocks = {f"blk/{i}": {"x": np.arange(i, i + 256, dtype=np.float32),
                           "y": np.full(8, i, np.int64)}
              for i in range(6)}               # ~1KB each >> 3KB budget
    for key, arrays in blocks.items():
        store.put(key, arrays)
    assert store.ram_bytes <= 3000
    spilled = store.spilled_keys()
    assert spilled, "budget should have forced spills"
    assert all(os.path.exists(os.path.join(
        str(tmp_path), k.replace("/", "__") + ".bin")) for k in spilled)
    for key, arrays in blocks.items():         # reload == original, any order
        got = store.get(key)
        for name, a in arrays.items():
            np.testing.assert_array_equal(got[name], a)
    assert store.stats["loads"] > 0


def test_shard_store_unlimited_never_spills(tmp_path):
    store = ShardStore(memory_budget=None, spill_dir=str(tmp_path))
    for i in range(5):
        store.put(f"k{i}", {"a": np.zeros(1000, np.float32)})
    assert store.stats["spills"] == 0 and not store.spilled_keys()


def test_shard_store_get_keeps_larger_than_budget_entry(tmp_path):
    # regression: _enforce_budget(keep=key) used to spill the just-loaded
    # entry whenever it was the only resident one, so every get() of a
    # larger-than-budget shard reloaded and re-dropped it while the spill
    # counter inflated with entries that were already on disk
    # (async_spill off: this test pins the synchronous loads/spills
    # accounting; the async variants live in test_engine_async.py)
    store = ShardStore(memory_budget=100, spill_dir=str(tmp_path),
                       async_spill=False)
    a = {"x": np.arange(200, dtype=np.float32)}     # 800 B >> budget
    b = {"x": np.zeros(200, np.float32)}
    store.put("a", a)                               # spilled on put
    store.put("b", b)
    assert store.stats["spills"] == 2               # two first-time writes
    got = store.get("a")                            # reload over budget
    np.testing.assert_array_equal(got["x"], a["x"])
    assert "a" in store._ram, "get() must keep the entry it just loaded"
    store.get("a")                                  # second get: RAM hit
    assert store.stats["loads"] == 1, "resident entry reloaded from disk"
    # the one reload never re-wrote the spill file or counted as a fresh spill
    assert store.stats["spills"] == 2
    assert store.stats["drops"] == 0


def test_shard_store_redrop_counts_as_drop_not_spill(tmp_path):
    # a reloaded entry evicted AGAIN (to make room for another get) is a
    # drop — its spill file is already current — not a new spill
    store = ShardStore(memory_budget=900, spill_dir=str(tmp_path),
                       async_spill=False)
    blocks = {k: {"x": np.full(200, i, np.float32)}   # 800 B each
              for i, k in enumerate("abc")}
    for k, v in blocks.items():
        store.put(k, v)
    store.get("a")                # evicts c (first-time spill); all on disk
    spills0 = store.stats["spills"]
    bytes0 = store.stats["bytes_spilled"]
    assert spills0 == 3
    store.get("b")                                  # evicts a -> drop
    store.get("c")                                  # evicts b -> drop
    assert store.stats["drops"] == 2
    assert store.stats["spills"] == spills0, "re-drop counted as spill"
    assert store.stats["bytes_spilled"] == bytes0
    for k, v in blocks.items():                     # data still intact
        np.testing.assert_array_equal(store.get(k)["x"], v["x"])


def test_shard_store_delete_removes_spill_file(tmp_path):
    store = ShardStore(memory_budget=10, spill_dir=str(tmp_path),
                       async_spill=False)
    store.put("a", {"x": np.zeros(100)})       # immediately over budget
    (path,) = [os.path.join(str(tmp_path), "a.bin")]
    assert os.path.exists(path)
    store.delete("a")
    assert not os.path.exists(path) and "a" not in store


# ---------------------------------------------------------------------------
# graph build: oracle agreement with the in-memory top-t graph
# ---------------------------------------------------------------------------

def _oracle_topt(pts: np.ndarray, sigma: float, t: int) -> np.ndarray:
    S = sim.rbf_kernel(jnp.asarray(pts), jnp.asarray(pts), sigma)
    return np.asarray(sim.sparsify_topt(S, t))


@pytest.mark.parametrize("n,chunk", [
    (120, 40),     # divides evenly
    (130, 40),     # ragged last chunk
    (90, 128),     # chunk size >= n (single chunk)
    (64, 1),       # degenerate 1-row chunks
])
def test_engine_graph_matches_in_memory_topt(n, chunk):
    rng = np.random.RandomState(1)
    pts = rng.randn(n, 3).astype(np.float32)
    plan = JobPlan(n=n, chunk_size=chunk, t=5, k=2, sigma=1.0)
    graph, sigma = engine.build_graph(ArrayChunks(pts, chunk), plan)
    np.testing.assert_allclose(graph.to_dense(), _oracle_topt(pts, 1.0, 5),
                               atol=1e-5)
    # degrees accumulated by the reduce tasks match the materialized graph
    np.testing.assert_allclose(graph.deg, graph.to_dense().sum(axis=1),
                               rtol=1e-5)


def test_engine_matvec_streams_shards_correctly():
    rng = np.random.RandomState(2)
    pts = rng.randn(75, 4).astype(np.float32)
    plan = JobPlan(n=75, chunk_size=20, t=6, k=2, sigma=0.8)
    graph, _ = engine.build_graph(ArrayChunks(pts, 20), plan)
    v = rng.randn(75).astype(np.float32)
    np.testing.assert_allclose(graph.matvec(v), graph.to_dense() @ v,
                               rtol=1e-4, atol=1e-5)


def test_engine_graph_identical_under_spilling(tmp_path):
    pts, _ = synthetic.blobs(150, 3, dim=3, seed=4)
    plan_ram = JobPlan(n=150, chunk_size=48, t=8, k=3, sigma=1.0)
    plan_ooc = JobPlan(n=150, chunk_size=48, t=8, k=3, sigma=1.0,
                       memory_budget=16 * 1024, spill_dir=str(tmp_path))
    g_ram, _ = engine.build_graph(ArrayChunks(pts, 48), plan_ram)
    g_ooc, _ = engine.build_graph(ArrayChunks(pts, 48), plan_ooc)
    assert g_ooc.stats_snapshot()["store_bytes_spilled"] > 0
    np.testing.assert_array_equal(g_ram.to_dense(), g_ooc.to_dense())


# ---------------------------------------------------------------------------
# end-to-end: ooc-topt vs knn-topt label agreement (ARI/NMI), spill forced
# ---------------------------------------------------------------------------

def test_ooc_topt_agrees_with_knn_topt(tmp_path):
    # spread 0.8 keeps the blobs weakly connected: distinct small
    # eigenvalues, so Lanczos resolves the same subspace on both paths
    # (perfectly separated blobs give an exactly-degenerate null space
    # where *any* eigensolver's basis is arbitrary).
    pts, _ = synthetic.blobs(240, 3, dim=4, spread=0.8, seed=0)
    x = jnp.asarray(pts)
    ref = SpectralClustering(k=3, affinity="knn-topt", sparsify_t=10,
                             sigma=1.0, seed=0, lanczos_steps=96).fit(x)
    ooc = SpectralClustering(k=3, affinity="ooc-topt", sparsify_t=10,
                             sigma=1.0, seed=0, chunk_size=64,
                             lanczos_steps=96, memory_budget=32 * 1024,
                             spill_dir=str(tmp_path)).fit(x)
    la, lb = np.asarray(ref.labels_), np.asarray(ooc.labels_)
    assert ari(la, lb) >= 0.95
    assert nmi(la, lb) >= 0.95
    eng = ooc.info_["engine"]
    assert eng["store_bytes_spilled"] > 0          # budget forced spills
    assert eng["map_tasks"] == 4 * 5 // 2          # 4 chunks -> 10 tiles
    np.testing.assert_allclose(np.asarray(ref.eigenvalues_),
                               np.asarray(ooc.eigenvalues_), atol=1e-3)


def test_run_job_full_pipeline_and_streaming_assigner():
    reader = BlobChunks(300, 3, chunk_size=90, dim=4, spread=0.8, seed=1)
    plan = JobPlan(n=300, chunk_size=90, t=10, k=3, sigma=1.0, seed=0,
                   lanczos_steps=96, kmeans_rounds=30)
    res = engine.run_job(plan, reader)
    assert res.labels.shape == (300,)
    assert ari(reader.all_labels(), res.labels) >= 0.95
    assert res.stats["nnz"] > 0 and res.stats["reduce_tasks"] == 4

    # the registry "streaming" assigner reproduces sane labels too
    est = SpectralClustering(k=3, affinity="ooc-topt", assigner="streaming",
                             sparsify_t=10, sigma=1.0, seed=0, chunk_size=90,
                             lanczos_steps=96)
    x = np.concatenate([reader[c] for c in range(len(reader))])
    est.fit(jnp.asarray(x))
    assert ari(reader.all_labels(), np.asarray(est.labels_)) >= 0.95


def test_ooc_topt_multi_device_uneven_n(subproc):
    # n=242 not divisible by 4 devices: the operator must pad to the mesh
    # multiple like every other affinity or the estimator's shard_map
    # stages reject the uneven rows
    out = subproc("""
import numpy as np, jax.numpy as jnp
from repro.cluster import SpectralClustering, ari
from repro.data import synthetic
from repro.distrib import mesh_utils
pts, truth = synthetic.blobs(242, 3, dim=4, spread=0.8, seed=0)
mesh = mesh_utils.local_mesh("rows")
assert mesh_utils.mesh_size(mesh) == 4
est = SpectralClustering(k=3, affinity="ooc-topt", sparsify_t=10, sigma=1.0,
                         seed=0, chunk_size=64, lanczos_steps=96,
                         mesh=mesh).fit(jnp.asarray(pts))
assert ari(truth, np.asarray(est.labels_)) >= 0.95
print("OK")
""", n_devices=4)
    assert "OK" in out


def test_operator_padding_matches_unpadded():
    rng = np.random.RandomState(3)
    pts = rng.randn(50, 3).astype(np.float32)
    plan = JobPlan(n=50, chunk_size=16, t=6, k=2, sigma=1.0)
    graph, _ = engine.build_graph(ArrayChunks(pts, 16), plan)
    op = engine.make_normalized_operator(graph)
    op_pad = engine.make_normalized_operator(graph, pad_to=56)
    assert op_pad.n_pad == 56 and op.n_pad == 50
    v = rng.randn(56).astype(np.float32)
    got = np.asarray(op_pad.matvec(jnp.asarray(v)))
    ref = np.asarray(op.matvec(jnp.asarray(v[:50])))
    np.testing.assert_allclose(got[:50], ref, rtol=1e-5, atol=1e-6)
    assert np.all(got[50:] == 0)                  # pad rows stay null
    A = np.asarray(op_pad.dense())
    np.testing.assert_allclose(A[:50, :50], np.asarray(op.dense()),
                               rtol=1e-5, atol=1e-6)
    # the traced-callback matvec above immortalizes its closure (and so
    # the graph) in jax's dispatch cache: close the shared prefetch pool
    # explicitly, as every non-test operator consumer does
    op.close()


def test_engine_eigh_backend_uses_dense_fallback():
    pts, _ = synthetic.blobs(96, 2, dim=3, spread=0.8, seed=5)
    ooc = SpectralClustering(k=2, affinity="ooc-topt", eigensolver="eigh",
                             sparsify_t=8, sigma=1.0, seed=0,
                             chunk_size=32).fit(jnp.asarray(pts))
    ref = SpectralClustering(k=2, affinity="knn-topt", eigensolver="eigh",
                             sparsify_t=8, sigma=1.0, seed=0).fit(
                                 jnp.asarray(pts))
    assert ari(np.asarray(ref.labels_), np.asarray(ooc.labels_)) >= 0.95


# ---------------------------------------------------------------------------
# chunked readers + plan edge cases
# ---------------------------------------------------------------------------

def test_streaming_kmeans_tolerates_coincident_points():
    # degenerate sample: fewer distinct points than k must not crash the
    # ++ init (d2 goes all-zero -> weight-uniform fallback)
    y = np.repeat(np.array([[0.0, 0.0], [1.0, 1.0]]), 10, axis=0)
    labels, centers = engine.streaming_kmeans(
        lambda c: y, 1, k=5, rounds=5, seed=0)
    assert labels.shape == (20,) and centers.shape == (5, 2)


def test_shard_store_owned_tempdir_removed_on_close():
    store = ShardStore(memory_budget=10)          # own temp dir
    store.put("a", {"x": np.zeros(100)})          # spills immediately
    d = store.spill_dir
    assert os.path.isdir(d)
    store.close()
    assert not os.path.exists(d)


def test_chunk_ranges_boundaries():
    assert chunk_ranges(10, 4) == [(0, 4), (4, 8), (8, 10)]
    assert chunk_ranges(10, 100) == [(0, 10)]    # chunk >= n clamps
    assert chunk_ranges(1, 1) == [(0, 1)]
    with pytest.raises(ValueError):
        chunk_ranges(0, 4)


def test_blob_chunks_deterministic_random_access():
    r = BlobChunks(100, 4, chunk_size=30, seed=7)
    c2a = r[2]
    _ = r[0], r[3], r[1]
    np.testing.assert_array_equal(r[2], c2a)     # pure re-generation
    assert sum(len(r[c]) for c in range(len(r))) == 100
    assert len(r.all_labels()) == 100


def test_array_chunks_matches_source():
    x = np.random.RandomState(0).randn(55, 3).astype(np.float32)
    r = ArrayChunks(x, 20)
    np.testing.assert_array_equal(np.concatenate([r[c] for c in range(3)]), x)
