"""The paper's technique integrated with the LM substrate: spectral
clustering of MoE expert co-activation for balanced expert placement.

Experts that co-activate on the same tokens exchange the most all-to-all
traffic when split across devices; clustering the co-activation similarity
matrix and placing each cluster on one device minimizes cross-device
dispatch — the same graph-partitioning objective (normalized cut) the
paper's pipeline optimizes.

    PYTHONPATH=src python examples/moe_spectral_routing.py
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.cluster import SpectralClustering
from repro.models import api
from repro.models import moe as moe_lib


def main():
    cfg = configs.get_smoke("mixtral-8x7b").with_(num_experts=16, top_k=2)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))

    # run batches through layer 0's router and collect co-activation counts.
    # Inputs are drawn from 8 synthetic "domains" (clustered activations):
    # experts that win on the same domain co-activate, giving the
    # similarity graph its community structure.
    E = cfg.num_experts
    co = np.zeros((E, E))
    lp = jax.tree.map(lambda a: a[0], params["layers"])  # layer 0
    domains = jax.random.normal(jax.random.PRNGKey(100), (8, cfg.d_model)) * 3.0
    for seed in range(16):
        dom = domains[seed % 8]
        x = dom[None, None, :] + jax.random.normal(
            jax.random.PRNGKey(seed), (4, 64, cfg.d_model), jnp.float32)
        logits = jnp.einsum("bsd,de->bse", x, lp["moe"]["router"])
        _, idx = jax.lax.top_k(jax.nn.softmax(logits, -1), cfg.top_k)
        idx = np.asarray(idx).reshape(-1, cfg.top_k)
        for row in idx:
            for a in row:
                for b in row:
                    co[a, b] += 1
    np.fill_diagonal(co, co.diagonal() + 1)
    co = co / co.max()

    n_groups = 4  # devices holding experts
    est = SpectralClustering(k=n_groups, affinity="precomputed",
                             lanczos_steps=12)
    est.fit(jnp.asarray(co, jnp.float32))
    placement = np.asarray(est.labels_)
    sizes = np.bincount(placement, minlength=n_groups)

    # traffic model: co-activation mass cut by the placement
    cut = sum(co[i, j] for i in range(E) for j in range(E)
              if placement[i] != placement[j])
    total = co.sum()
    rng = np.random.RandomState(0)
    rand_cut = np.mean([
        sum(co[i, j] for i in range(E) for j in range(E)
            if p[i] != p[j])
        for p in [rng.randint(0, n_groups, E) for _ in range(20)]])

    print(f"experts={E} groups={n_groups} placement sizes={sizes}")
    print(f"co-activation cut: spectral={cut / total:.3f} "
          f"random={rand_cut / total:.3f} (lower = less all-to-all traffic)")


if __name__ == "__main__":
    main()
