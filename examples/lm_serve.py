"""Serving example: batched prefill + autoregressive decode with a KV
cache, over three architecture families (attention / xLSTM / hybrid) to
show the unified serve path.

    PYTHONPATH=src python examples/lm_serve.py
"""
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.models import api


def serve(arch: str, batch: int = 4, prompt_len: int = 32, gen: int = 16):
    cfg = configs.get_smoke(arch)
    model = api.build(cfg)
    params = model.init(jax.random.PRNGKey(0))
    toks = jax.random.randint(jax.random.PRNGKey(1), (batch, prompt_len),
                              0, cfg.vocab_size)
    batch_in = {"tokens": toks}
    if cfg.frontend == "embed":
        batch_in["embeds"] = jax.random.normal(
            jax.random.PRNGKey(2), (batch, prompt_len, cfg.d_model),
            cfg.compute_dtype)

    decode = jax.jit(model.decode_step)
    t0 = time.perf_counter()
    logits, cache = model.prefill(params, batch_in, max_seq=prompt_len + gen)
    t_prefill = time.perf_counter() - t0

    out_tokens = []
    tok = jnp.argmax(logits[:, -1:], axis=-1).astype(jnp.int32)
    t0 = time.perf_counter()
    for _ in range(gen):
        out_tokens.append(tok)
        logits, cache = decode(params, cache, tok)
        tok = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    jax.block_until_ready(tok)
    t_decode = (time.perf_counter() - t0) / gen

    seq = jnp.concatenate(out_tokens, axis=1)
    print(f"{arch:22s} prefill({prompt_len} tok) {t_prefill * 1e3:7.1f} ms   "
          f"decode {t_decode * 1e3:6.1f} ms/tok   sample: {seq[0, :8].tolist()}")


def main():
    print(f"devices: {len(jax.devices())}")
    for arch in ("qwen1.5-0.5b", "xlstm-1.3b", "zamba2-2.7b"):
        serve(arch)


if __name__ == "__main__":
    main()
