"""End-to-end training driver example: train a reduced qwen1.5 config for a
few hundred steps on synthetic structured data, with checkpointing — then
kill/resume to demonstrate fault tolerance.

    PYTHONPATH=src python examples/lm_train.py [--steps 200]
"""
import argparse
import shutil
import tempfile

from repro.launch.train import train


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--arch", default="qwen1.5-0.5b")
    args = ap.parse_args()

    ckpt = tempfile.mkdtemp(prefix="repro_train_")
    try:
        # phase 1: run the first half, checkpointing every 50 steps
        half = max(50, args.steps // 2)
        print(f"=== phase 1: train to step {half} (simulated pre-failure run)")
        train(args.arch, steps=half, batch=8, seq=128, smoke=True,
              ckpt_dir=ckpt, lr=1e-3)
        # phase 2: "restart after node failure" — resumes from checkpoint
        print(f"=== phase 2: restart and resume to step {args.steps}")
        _, _, metrics = train(args.arch, steps=args.steps, batch=8, seq=128,
                              smoke=True, ckpt_dir=ckpt, lr=1e-3)
        print(f"final loss: {float(metrics['loss']):.4f}")
    finally:
        shutil.rmtree(ckpt, ignore_errors=True)


if __name__ == "__main__":
    main()
