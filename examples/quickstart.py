"""Quickstart: the paper end to end in ~30 lines.

Clusters two concentric rings — the non-convex case where plain k-means
fails and spectral clustering succeeds (paper §3.1) — with the unified
estimator API: one ``SpectralClustering`` whose three phases (affinity,
eigensolver, assigner) are pluggable registry backends, distributed over
every local device.

    PYTHONPATH=src python examples/quickstart.py

Migrating from the deprecated ``repro.core.spectral.fit(x, cfg)``: build a
``SpectralClustering`` with the same knobs (``mode="triangular"`` is
``affinity="triangular"``, ``mode="full"`` is ``affinity="dense"``) and read
``labels_`` / ``eigenvalues_`` off the fitted estimator.  See API.md.
"""
import jax
import jax.numpy as jnp
import numpy as np

from repro.cluster import SpectralClustering
from repro.core.kmeans import kmeans
from repro.data import rings


def main():
    pts, truth = rings(512, k=2, seed=0)
    est = SpectralClustering(k=2, affinity="triangular",
                             eigensolver="lanczos", assigner="lloyd",
                             sigma=0.25, lanczos_steps=48)
    est.fit(jnp.asarray(pts))

    labels = np.asarray(est.labels_)
    acc_spectral = max(np.mean(labels == truth), np.mean(labels == 1 - truth))

    km_labels, _ = kmeans(jnp.asarray(pts), 2, jax.random.PRNGKey(0))
    km_labels = np.asarray(km_labels)
    acc_kmeans = max(np.mean(km_labels == truth), np.mean(km_labels == 1 - truth))

    print(f"devices: {len(jax.devices())}")
    print(f"smallest eigenvalues of L_sym: {np.asarray(est.eigenvalues_)}")
    print(f"spectral clustering accuracy: {acc_spectral:.3f}   (rings)")
    print(f"plain k-means accuracy:       {acc_kmeans:.3f}   (fails on rings)")
    assert acc_spectral > 0.95, "spectral clustering should separate the rings"


if __name__ == "__main__":
    main()
